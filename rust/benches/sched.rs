//! Scheduler scaling study: pool/cache scaling on an uncontended board,
//! then shared carrier-board DRAM contention.
//!
//! ```sh
//! cargo bench --bench sched
//! ```
//!
//! Acceptance bars for the subsystem:
//!
//! * pool=4 with binary caching delivers at least 2x the simulated
//!   throughput (jobs per megacycle of pool makespan) of pool=1 uncached —
//!   with bit-identical job results regardless of policy, pool size,
//!   batching or caching.
//! * With the shared-DRAM coupling enabled on a constrained board, a
//!   DMA-heavy stream scales **sub-linearly**: pool=4 throughput strictly
//!   between 1x and 4x of pool=1 — while pool=1 stays cycle-identical
//!   (makespan and digest) to the uncontended baseline.

use herov2::config::aurora;
use herov2::sched::{BoardSpec, Policy, Scheduler, ServeReport};
use herov2::workloads::synth;

fn run(pool: usize, policy: Policy, cache: bool, batch: bool, jobs: &[synth::JobDesc]) -> ServeReport {
    let mut s = Scheduler::new(aurora(), pool, policy)
        .with_cache(cache)
        .with_batching(batch)
        .with_verify(false); // numerics are covered by the digest identity
    s.submit_all(jobs);
    s.drain().expect("drain");
    s.report()
}

fn run_board(pool: usize, board: BoardSpec, jobs: &[synth::JobDesc]) -> ServeReport {
    // Batching off so placement spreads evenly: the contention study
    // measures the board, not batch imbalance.
    let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
        .with_board(board)
        .with_batching(false)
        .with_verify(false);
    s.submit_all(jobs);
    s.drain().expect("drain");
    s.report()
}

fn main() {
    let jobs = synth::mixed_jobs(48, 7);
    println!("{} mixed jobs (8 kernels, 3 tiled variants, 2 sizes each)\n", jobs.len());
    println!(
        "{:<26} {:>14} {:>12} {:>10} {:>8}",
        "configuration", "makespan (cy)", "jobs/Mcycle", "compile cy", "lowered"
    );

    let mut baseline = None;
    let mut scaled = None;
    for (label, pool, policy, cache, batch) in [
        ("pool=1 fifo uncached", 1usize, Policy::Fifo, false, false),
        ("pool=1 fifo cached", 1, Policy::Fifo, true, true),
        ("pool=2 fifo cached", 2, Policy::Fifo, true, true),
        ("pool=4 fifo cached", 4, Policy::Fifo, true, true),
        ("pool=4 sjf cached", 4, Policy::Sjf, true, true),
    ] {
        let r = run(pool, policy, cache, batch, &jobs);
        assert_eq!(r.completed, jobs.len(), "{label}: all jobs must complete");
        println!(
            "{label:<26} {:>14} {:>12.3} {:>10} {:>8}",
            r.makespan_cycles,
            r.jobs_per_mcycle(),
            r.compile_cycles,
            r.cache_misses
        );
        if pool == 1 && !cache {
            baseline = Some(r);
        } else if pool == 4 && policy == Policy::Fifo {
            scaled = Some(r);
        }
    }

    let baseline = baseline.unwrap();
    let scaled = scaled.unwrap();
    assert_eq!(
        baseline.digest, scaled.digest,
        "job results must be bit-identical across scheduler configurations"
    );
    let speedup = scaled.jobs_per_mcycle() / baseline.jobs_per_mcycle();
    println!(
        "\npool=4 + binary cache vs pool=1 uncached: {speedup:.2}x simulated throughput \
         (target >= 2x)"
    );
    assert!(speedup >= 2.0, "scheduler scaling regressed: {speedup:.2}x < 2x");
    println!("results bit-identical across configurations: OK");

    // --- shared carrier-board DRAM contention -----------------------------
    // A DMA-heavy stream on a board whose DRAM peak (12 B/cy) covers one
    // instance's 8 B/cy NoC drain rate but not four of them.
    let heavy = synth::dma_heavy_jobs(24, 11);
    let bw = 12u64;
    println!("\n{} DMA-heavy jobs, board DRAM capped at {bw} B/cycle\n", heavy.len());
    println!(
        "{:<26} {:>14} {:>12} {:>14} {:>10}",
        "configuration", "makespan (cy)", "jobs/Mcycle", "dram stall cy", "dram util"
    );
    let solo_open = run_board(1, BoardSpec::uncontended(), &heavy);
    let mut contended = Vec::new();
    for pool in [1usize, 2, 4] {
        let r = run_board(pool, BoardSpec::with_bandwidth(bw), &heavy);
        assert_eq!(r.completed, heavy.len());
        println!(
            "pool={pool} fifo board={bw}B/cy{:<4} {:>14} {:>12.3} {:>14} {:>9.1}%",
            "",
            r.makespan_cycles,
            r.jobs_per_mcycle(),
            r.dram_stall_cycles,
            100.0 * r.dram_utilization
        );
        contended.push(r);
    }
    let solo = &contended[0];
    let quad = &contended[2];
    // pool=1 with contention accounting is cycle-identical to uncontended.
    assert_eq!(
        solo.makespan_cycles, solo_open.makespan_cycles,
        "pool=1 must be cycle-identical with the shared-DRAM model enabled"
    );
    assert_eq!(solo.digest, solo_open.digest);
    assert_eq!(solo.dram_stall_cycles, 0);
    // Contention never touches numerics.
    assert_eq!(quad.digest, solo.digest);
    assert!(quad.dram_stall_cycles > 0, "a DMA-heavy pool=4 stream must contend");
    let sp = quad.jobs_per_mcycle() / solo.jobs_per_mcycle();
    println!(
        "\npool=4 vs pool=1 on the contended board: {sp:.2}x \
         (sub-linear target: strictly between 1x and 4x)"
    );
    assert!(sp > 1.0, "pool=4 regressed below pool=1: {sp:.2}x");
    assert!(sp < 4.0, "pool=4 scaled linearly despite DRAM contention: {sp:.2}x");
    println!("shared-DRAM contention bends pool scaling sub-linear: OK");
}
