//! Scheduler scaling study: pool/cache scaling on an uncontended board,
//! shared carrier-board DRAM contention, board-aware placement, QoS
//! priority classes, self-tuning prediction refinement with lookahead
//! placement, priority preemption, and fault injection with resilient
//! fleet serving.
//!
//! ```sh
//! cargo bench --bench sched
//! ```
//!
//! Acceptance bars for the subsystem:
//!
//! * pool=4 with binary caching delivers at least 2x the simulated
//!   throughput (jobs per megacycle of pool makespan) of pool=1 uncached —
//!   with bit-identical job results regardless of policy, pool size,
//!   batching or caching.
//! * With the shared-DRAM coupling enabled on a constrained board, a
//!   DMA-heavy stream scales **sub-linearly**: pool=4 throughput strictly
//!   between 1x and 4x of pool=1 — while pool=1 stays cycle-identical
//!   (makespan and digest) to the uncontended baseline.
//! * On a mixed compute/DMA stream over a bandwidth-constrained board
//!   with a mixed-width pool (64/32/128-bit instances), pressure-aware
//!   placement strictly beats earliest-free on makespan at pool 2 and 4 —
//!   the per-slot window term steers DMA-heavy jobs away from narrow
//!   instances and the stall probe keeps DRAM windows from stacking —
//!   while a homogeneous uncontended pool stays **bit-identical** to
//!   earliest-free (same events, makespan, digest).
//! * Marking a slice of the stream latency-critical (`Priority::High` +
//!   priority headroom) improves that slice's p95 turnaround vs the same
//!   jobs in the same stream unprioritized.
//! * On a stream whose trip counts are opaque to the static cycle model,
//!   online EWMA refinement (`--learn`) plus joint lookahead placement
//!   (`--lookahead`) strictly beats static-SJF makespan — with
//!   bit-identical digests (learning moves time, never numerics).
//! * Priority preemption (`--preempt`) displaces queued-but-assigned
//!   batch followers so a High arrival jumps the batch: its p95
//!   turnaround strictly improves, again with bit-identical digests.
//! * Fleet affinity routing (`--fleet N --route finish`) strictly beats
//!   round-robin makespan on a cache-heavy repeated-kernel stream over a
//!   2-board fleet — fewer cold compiles, bit-identical digests (routing
//!   moves time, never numerics).
//! * Per-tenant in-flight quotas stop a noisy tenant's burst from
//!   degrading a victim tenant's p95 turnaround on a shared fleet.
//! * Schedule-time AutoDMA tuning (`--autotune`) strictly beats the
//!   single default recipe's makespan on a mixed-size GEMM/stencil
//!   stream — one memoized knob search per kernel, and bit-identical
//!   digests (tuning moves time, never numerics).
//! * Killing one board of a 2-board fleet mid-stream loses no jobs: every
//!   queued job evacuates to the survivor, the fleet digest stays
//!   bit-identical to the healthy run, and the degraded makespan stays
//!   under 2x healthy. Seeded transient faults with a retry budget
//!   complete every job with the fault-free digest — faults move time,
//!   never numerics.
//!
//! Every headline number is emitted to `BENCH_sched.json`
//! (`bench_harness::emit`) for the `bench-gate` CI job: the sim is
//! deterministic, so any cycle regression or digest drift vs the committed
//! baseline fails CI exactly.

use herov2::bench_harness::emit::BenchJson;
use herov2::config::aurora;
use herov2::config::preset::with_dma_width;
use herov2::sched::{
    BoardSpec, JobHandle, Placement, Policy, Priority, Scheduler, ServeReport,
};
use herov2::workloads::synth;

fn run(pool: usize, policy: Policy, cache: bool, batch: bool, jobs: &[synth::JobDesc]) -> ServeReport {
    let mut s = Scheduler::new(aurora(), pool, policy)
        .with_cache(cache)
        .with_batching(batch)
        .with_verify(false); // numerics are covered by the digest identity
    s.submit_all(jobs);
    s.drain().expect("drain");
    s.report()
}

fn run_board(pool: usize, board: BoardSpec, jobs: &[synth::JobDesc]) -> ServeReport {
    // Batching off so placement spreads evenly: the contention study
    // measures the board, not batch imbalance.
    let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
        .with_board(board)
        .with_batching(false)
        .with_verify(false);
    s.submit_all(jobs);
    s.drain().expect("drain");
    s.report()
}

fn run_placed(
    pool: usize,
    placement: Placement,
    board: BoardSpec,
    jobs: &[synth::JobDesc],
) -> Scheduler {
    let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
        .with_placement(placement)
        .with_board(board)
        .with_batching(false)
        .with_verify(false);
    s.submit_all(jobs);
    s.drain().expect("drain");
    s
}

fn main() {
    let mut out = BenchJson::new("sched");
    let jobs = synth::mixed_jobs(48, 7);
    println!("{} mixed jobs (8 kernels, 3 tiled variants, 2 sizes each)\n", jobs.len());
    println!(
        "{:<26} {:>14} {:>12} {:>10} {:>8}",
        "configuration", "makespan (cy)", "jobs/Mcycle", "compile cy", "lowered"
    );

    let mut baseline = None;
    let mut scaled = None;
    for (label, key, pool, policy, cache, batch) in [
        ("pool=1 fifo uncached", "mixed.pool1_uncached", 1usize, Policy::Fifo, false, false),
        ("pool=1 fifo cached", "mixed.pool1_cached", 1, Policy::Fifo, true, true),
        ("pool=2 fifo cached", "mixed.pool2_cached", 2, Policy::Fifo, true, true),
        ("pool=4 fifo cached", "mixed.pool4_cached", 4, Policy::Fifo, true, true),
        ("pool=4 sjf cached", "mixed.pool4_sjf", 4, Policy::Sjf, true, true),
    ] {
        let r = run(pool, policy, cache, batch, &jobs);
        assert_eq!(r.completed, jobs.len(), "{label}: all jobs must complete");
        println!(
            "{label:<26} {:>14} {:>12.3} {:>10} {:>8}",
            r.makespan_cycles,
            r.jobs_per_mcycle(),
            r.compile_cycles,
            r.cache_misses
        );
        out.metric(format!("{key}.makespan_cycles"), r.makespan_cycles);
        if pool == 1 && !cache {
            baseline = Some(r);
        } else if pool == 4 && policy == Policy::Fifo {
            scaled = Some(r);
        }
    }

    let baseline = baseline.unwrap();
    let scaled = scaled.unwrap();
    assert_eq!(
        baseline.digest, scaled.digest,
        "job results must be bit-identical across scheduler configurations"
    );
    out.digest("mixed.digest", baseline.digest);
    let speedup = scaled.jobs_per_mcycle() / baseline.jobs_per_mcycle();
    println!(
        "\npool=4 + binary cache vs pool=1 uncached: {speedup:.2}x simulated throughput \
         (target >= 2x)"
    );
    assert!(speedup >= 2.0, "scheduler scaling regressed: {speedup:.2}x < 2x");
    println!("results bit-identical across configurations: OK");

    // --- shared carrier-board DRAM contention -----------------------------
    // A DMA-heavy stream on a board whose DRAM peak (12 B/cy) covers one
    // instance's 8 B/cy NoC drain rate but not four of them.
    let heavy = synth::dma_heavy_jobs(24, 11);
    let bw = 12u64;
    println!("\n{} DMA-heavy jobs, board DRAM capped at {bw} B/cycle\n", heavy.len());
    println!(
        "{:<26} {:>14} {:>12} {:>14} {:>10}",
        "configuration", "makespan (cy)", "jobs/Mcycle", "dram stall cy", "dram util"
    );
    let solo_open = run_board(1, BoardSpec::uncontended(), &heavy);
    let mut contended = Vec::new();
    for pool in [1usize, 2, 4] {
        let r = run_board(pool, BoardSpec::with_bandwidth(bw), &heavy);
        assert_eq!(r.completed, heavy.len());
        println!(
            "pool={pool} fifo board={bw}B/cy{:<4} {:>14} {:>12.3} {:>14} {:>9.1}%",
            "",
            r.makespan_cycles,
            r.jobs_per_mcycle(),
            r.dram_stall_cycles,
            100.0 * r.dram_utilization
        );
        out.metric(format!("heavy.pool{pool}.makespan_cycles"), r.makespan_cycles);
        out.metric(format!("heavy.pool{pool}.dram_stall_cycles"), r.dram_stall_cycles);
        contended.push(r);
    }
    let solo = &contended[0];
    let quad = &contended[2];
    // pool=1 with contention accounting is cycle-identical to uncontended.
    assert_eq!(
        solo.makespan_cycles, solo_open.makespan_cycles,
        "pool=1 must be cycle-identical with the shared-DRAM model enabled"
    );
    assert_eq!(solo.digest, solo_open.digest);
    assert_eq!(solo.dram_stall_cycles, 0);
    // Contention never touches numerics.
    assert_eq!(quad.digest, solo.digest);
    assert!(quad.dram_stall_cycles > 0, "a DMA-heavy pool=4 stream must contend");
    out.digest("heavy.digest", solo.digest);
    let sp = quad.jobs_per_mcycle() / solo.jobs_per_mcycle();
    println!(
        "\npool=4 vs pool=1 on the contended board: {sp:.2}x \
         (sub-linear target: strictly between 1x and 4x)"
    );
    assert!(sp > 1.0, "pool=4 regressed below pool=1: {sp:.2}x");
    assert!(sp < 4.0, "pool=4 scaled linearly despite DRAM contention: {sp:.2}x");
    println!("shared-DRAM contention bends pool scaling sub-linear: OK");

    // --- board-aware placement: pressure vs earliest-free -----------------
    // A mixed compute/DMA stream on a *mixed-width* pool (64/32/128-bit
    // wide-NoC instances — the `--mixed-widths` heterogeneity) over a
    // bandwidth-constrained board. A DMA-heavy job on the 32-bit instance
    // drains at 4 B/cycle — nearly double the occupancy it has on the
    // 64-bit slot — and earliest-free placement is blind to that.
    // Pressure placement's window term (bytes over the slot's drain rate)
    // steers DMA-heavy jobs onto wide slots and fills the narrow slot with
    // compute-heavy work, and its stall probe keeps their DRAM windows
    // from stacking. (Digests legitimately differ across placements here:
    // a different instance width tiles a job differently.)
    let mix = synth::pressure_mix_jobs(32, 13);
    let bw_mix = 12u64;
    let widths = [64u32, 32, 128];
    println!(
        "\n{} mixed compute/DMA jobs, mixed-width pool, board DRAM at {bw_mix} B/cycle\n",
        mix.len()
    );
    println!(
        "{:<30} {:>14} {:>14} {:>12}",
        "configuration", "makespan (cy)", "dram stall cy", "util inst0"
    );
    let run_mixed = |pool: usize, placement: Placement| {
        let cfgs: Vec<_> =
            (0..pool).map(|i| with_dma_width(&aurora(), widths[i % widths.len()])).collect();
        let mut s = Scheduler::new_heterogeneous(cfgs, Policy::Fifo)
            .with_placement(placement)
            .with_board(BoardSpec::with_bandwidth(bw_mix))
            .with_batching(false)
            .with_verify(false);
        s.submit_all(&mix);
        s.drain().expect("drain");
        s.report()
    };
    for pool in [2usize, 4] {
        let ef = run_mixed(pool, Placement::EarliestFree);
        let pr = run_mixed(pool, Placement::Pressure);
        for r in [&ef, &pr] {
            assert_eq!(r.completed, mix.len());
            println!(
                "pool={pool} {:<22} {:>14} {:>14} {:>11.1}%",
                r.placement,
                r.makespan_cycles,
                r.dram_stall_cycles,
                100.0 * r.instances[0].utilization
            );
        }
        assert!(
            pr.makespan_cycles < ef.makespan_cycles,
            "pool={pool}: pressure placement must strictly beat earliest-free on a \
             constrained mixed-width board ({} vs {})",
            pr.makespan_cycles,
            ef.makespan_cycles
        );
        out.metric(format!("mix.pool{pool}.earliest.makespan_cycles"), ef.makespan_cycles);
        out.metric(format!("mix.pool{pool}.pressure.makespan_cycles"), pr.makespan_cycles);
        out.metric(format!("mix.pool{pool}.earliest.dram_stall_cycles"), ef.dram_stall_cycles);
        out.metric(format!("mix.pool{pool}.pressure.dram_stall_cycles"), pr.dram_stall_cycles);
        if pool == 2 {
            out.digest("mix.pool2.pressure.digest", pr.digest);
        }
    }
    println!("pressure placement strictly beats earliest-free under contention: OK");

    // On an uncontended board the two placements must be bit-identical —
    // not just equal makespans: the same dispatch event sequence.
    let ef = run_placed(4, Placement::EarliestFree, BoardSpec::uncontended(), &mix);
    let pr = run_placed(4, Placement::Pressure, BoardSpec::uncontended(), &mix);
    assert_eq!(ef.trace.events, pr.trace.events, "uncontended placement must be bit-identical");
    let (ref_, rpr) = (ef.report(), pr.report());
    assert_eq!(ref_.makespan_cycles, rpr.makespan_cycles);
    assert_eq!(ref_.digest, rpr.digest);
    out.metric("mix.uncontended.makespan_cycles", rpr.makespan_cycles);
    println!("uncontended pool is bit-identical to earliest-free: OK");

    // --- QoS: priority class + DRAM headroom ------------------------------
    // Mark every 4th job of the mix latency-critical and give a
    // *homogeneous* pool (so priorities cannot touch numerics) a board
    // with a small priority headroom: those jobs jump the arrived queue
    // and their DRAM traffic rides the reserved slice.
    let hi_every = 4;
    let marked: Vec<synth::JobDesc> = mix
        .iter()
        .enumerate()
        .map(|(i, j)| {
            let mut j = *j;
            if i % hi_every == 0 {
                j.priority = Priority::High;
            }
            j
        })
        .collect();
    let board = BoardSpec::with_bandwidth(8).with_priority_headroom(2);
    let prioritized = run_placed(2, Placement::Pressure, board, &marked);
    let unprioritized = run_placed(2, Placement::Pressure, board, &mix);
    let turnaround = |s: &Scheduler, i: usize| {
        let o = s.poll(JobHandle(i)).expect("mix jobs all complete");
        o.end - marked[i].arrival
    };
    let mut hi_with: Vec<u64> = (0..marked.len())
        .filter(|i| i % hi_every == 0)
        .map(|i| turnaround(&prioritized, i))
        .collect();
    let mut hi_without: Vec<u64> = (0..marked.len())
        .filter(|i| i % hi_every == 0)
        .map(|i| turnaround(&unprioritized, i))
        .collect();
    hi_with.sort_unstable();
    hi_without.sort_unstable();
    let p95 = |v: &[u64]| herov2::sched::report::percentile(v, 95);
    let (with_p95, without_p95) = (p95(&hi_with), p95(&hi_without));
    let r = prioritized.report();
    let high_class = r.class(Priority::High).expect("high class completed jobs");
    println!(
        "\npriority study: {} high jobs | class p50 {} cy, p95 {} cy",
        high_class.jobs, high_class.p50_turnaround_cycles, high_class.p95_turnaround_cycles
    );
    println!(
        "p95 turnaround of the marked jobs: {with_p95} cy prioritized vs \
         {without_p95} cy unprioritized"
    );
    assert_eq!(
        r.digest,
        unprioritized.report().digest,
        "priorities must never change numerics"
    );
    assert!(
        with_p95 < without_p95,
        "priority class must improve its p95 turnaround ({with_p95} vs {without_p95})"
    );
    out.metric("qos.high.p95_turnaround_cycles", with_p95);
    out.metric("qos.unprioritized.p95_turnaround_cycles", without_p95);
    out.metric("qos.high.p50_turnaround_cycles", high_class.p50_turnaround_cycles);
    println!("priority class improves p95 turnaround: OK");

    // --- cross-launch dataflow: device-resident pipeline ------------------
    // An 8-stage chained pipeline (each stage doubles a 4 KiB buffer in
    // place) through a pool=2 session: consumers dispatch only once their
    // producer settles, payloads flow scheduler-side through the feed
    // store, and the result is bit-identical to the explicit
    // read-back/re-upload baseline on the same pool.
    {
        use herov2::compiler::ir::{cf, ci, ld, par_for, st, var, KernelBuilder};
        use herov2::Session;
        let n = 1024usize;
        let stages = 8usize;
        let scale_kernel = || {
            let mut b = KernelBuilder::new("pipe_scale");
            let x = b.host_array("X", vec![ci(n as i32)]);
            let i = b.loop_var("i");
            b.body(vec![par_for(
                i,
                ci(0),
                ci(n as i32),
                vec![st(x, vec![var(i)], ld(x, vec![var(i)]).mul(cf(2.0)))],
            )])
        };
        let data: Vec<f32> = (0..n).map(|i| (i % 13) as f32).collect();
        // Chained: submit every stage up front, resolve once at the tail.
        let mut chained = Session::pool(aurora(), 2);
        let xb = chained.buffer_from_f32(&data);
        let mut tail = None;
        for _ in 0..stages {
            tail = Some(chained.launch(&scale_kernel()).writes(&xb).submit().expect("submit"));
        }
        let chain_digest = chained.wait(&tail.expect("stages >= 1")).expect("wait").digest;
        let chain_out = chained.read_f32(&xb).expect("read");
        let chain_makespan = chained.report().expect("report").makespan_cycles;
        // Baseline: wait + read_f32 + buffer_from_f32 between every stage.
        let mut rt = Session::pool(aurora(), 2);
        let mut cur = data.clone();
        let mut rt_digest = 0u64;
        for _ in 0..stages {
            let b = rt.buffer_from_f32(&cur);
            let l = rt.launch(&scale_kernel()).writes(&b).submit().expect("submit");
            rt_digest = rt.wait(&l).expect("wait").digest;
            cur = rt.read_f32(&b).expect("read");
            rt.free(&b).expect("free");
        }
        assert_eq!(
            chain_digest, rt_digest,
            "chained pipeline must be bit-identical to the host-round-trip baseline"
        );
        assert_eq!(chain_out, cur);
        assert_eq!(rt.resident_bytes(), 0, "freed stage buffers must not leak");
        println!(
            "\n{stages}-stage device-resident pipeline: digest {chain_digest:#018x}, \
             makespan {chain_makespan} cy — bit-identical to the host-round-trip baseline"
        );
        out.metric("pipeline.chained.makespan_cycles", chain_makespan);
        out.digest("pipeline.digest", chain_digest);
    }

    // --- shared virtual memory: the pin-vs-copy offload tradeoff ----------
    // A 16-job stream alternating small reused buffers (where zero-copy
    // pinned SVM access wins once the TLB warms) and large streaming
    // buffers (where up-front DMA staging wins), served three times:
    // forced pin, forced copy, and auto (exact predicted-cost selection).
    // The strategy moves cycles, never numerics — digests are
    // bit-identical — and auto must be no worse than the better fixed
    // strategy (the Cheshire tradeoff, arXiv:2305.04760).
    {
        use herov2::svm::{self, SvmConfig, SvmMode};
        let n_jobs = 16usize;
        println!("\nsvm study: {n_jobs} kernel jobs, pin vs copy vs auto\n");
        println!(
            "{:<26} {:>14} {:>14} {:>14}",
            "strategy", "makespan (cy)", "host dram B", "host stall cy"
        );
        let run_svm = |over: Option<SvmMode>| {
            let mut s = Scheduler::new(aurora(), 1, Policy::Fifo)
                .with_board(BoardSpec::with_bandwidth(16))
                .with_svm(SvmConfig::new(SvmMode::Auto).with_host_bw(8))
                .with_verify(false);
            svm::submit_svm_stream(&mut s, n_jobs, 21, over).expect("svm stream");
            s.drain().expect("drain");
            s.report()
        };
        let mut reports = Vec::new();
        for (label, key, over) in [
            ("svm pin (forced)", "svm.pin", Some(SvmMode::Pin)),
            ("svm copy (forced)", "svm.copy", Some(SvmMode::Copy)),
            ("svm auto", "svm.auto", None),
        ] {
            let r = run_svm(over);
            assert_eq!(r.completed, n_jobs);
            println!(
                "{label:<26} {:>14} {:>14} {:>14}",
                r.makespan_cycles, r.host_dram_bytes, r.host_dram_stall_cycles
            );
            out.metric(format!("{key}.makespan_cycles"), r.makespan_cycles);
            out.metric(format!("{key}.host_dram_bytes"), r.host_dram_bytes);
            reports.push(r);
        }
        let (pin, copy, auto) = (&reports[0], &reports[1], &reports[2]);
        assert_eq!(pin.digest, copy.digest, "offload strategy must never touch numerics");
        assert_eq!(copy.digest, auto.digest);
        out.digest("svm.digest", auto.digest);
        assert!(
            auto.makespan_cycles <= pin.makespan_cycles.min(copy.makespan_cycles),
            "auto ({}) must be no worse than pin ({}) / copy ({})",
            auto.makespan_cycles,
            pin.makespan_cycles,
            copy.makespan_cycles
        );
        println!(
            "\nauto {} cy <= min(pin {} cy, copy {} cy): OK (digests bit-identical)",
            auto.makespan_cycles, pin.makespan_cycles, copy.makespan_cycles
        );

        // Host traffic as a contender: copy-staging an SVM stream over a
        // pool=2 board tight enough that the host port fights the
        // instances' DMA for DRAM bandwidth. Host stall must be visible
        // and disjoint from the per-instance stall accounting.
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo)
            .with_board(BoardSpec::with_bandwidth(12))
            .with_svm(SvmConfig::new(SvmMode::Copy).with_host_bw(8))
            .with_batching(false)
            .with_verify(false);
        svm::submit_svm_stream(&mut s, n_jobs, 23, None).expect("svm stream");
        s.submit_all(&synth::dma_heavy_jobs(8, 25));
        s.drain().expect("drain");
        let r = s.report();
        assert_eq!(r.completed, n_jobs + 8);
        let inst_bytes: u64 = r.instances.iter().map(|i| i.dram_bytes).sum();
        assert_eq!(
            r.dram_bytes,
            inst_bytes + r.host_dram_bytes,
            "conservation: board total = instance sum + host port"
        );
        assert!(
            r.host_dram_stall_cycles > 0,
            "the host port must contend on a {}-B/cy board",
            r.dram_peak_bytes_per_cycle
        );
        println!(
            "contended copy staging: host moved {} B with {} stall cy \
             (instances stalled {} cy): OK",
            r.host_dram_bytes, r.host_dram_stall_cycles, r.dram_stall_cycles
        );
        out.metric("svm.contended.host_dram_stall_cycles", r.host_dram_stall_cycles);
        out.metric("svm.contended.makespan_cycles", r.makespan_cycles);
    }

    // --- self-tuning: online refinement + lookahead vs the static model ---
    // Three kernels identical in *shape* but with a `let`-bound trip count
    // (600 / 900 / 1200) the static predictor cannot fold: it sees the same
    // 16-trip default for all three, so static SJF degenerates to
    // submission order. A warmup phase teaches the EWMA store each class's
    // true cost; the tuned run then dispatches an adversarially ordered
    // burst in true shortest-first order. Same jobs, same numerics — the
    // makespan gap is pure prediction quality.
    {
        use herov2::compiler::ir::{ci, cf, for_, ld, st, var, Kernel, KernelBuilder, Stmt};
        use herov2::sched::policy::predict_kernel_job;
        use herov2::sched::KernelJob;

        fn opaque(name: &str, trips: i32) -> Kernel {
            let mut b = KernelBuilder::new(name);
            let x = b.host_array("X", vec![ci(64)]);
            let n = b.let_i32("n");
            let i = b.loop_var("i");
            b.body(vec![
                Stmt::Let { var: n, value: ci(trips) },
                for_(i, ci(0), var(n), vec![st(x, vec![ci(0)], ld(x, vec![ci(0)]).add(cf(1.0)))]),
            ])
        }
        fn job(k: &Kernel, arrival: u64) -> KernelJob {
            let mut j = KernelJob::new(k.clone(), vec![vec![0.0f32; 64]], Vec::new());
            j.arrival = arrival;
            j
        }
        let short = opaque("tune_short", 600);
        let mid = opaque("tune_mid", 900);
        let long = opaque("tune_long", 1200);
        let cfg = aurora();
        let p = |k: &Kernel| predict_kernel_job(k, false, &cfg, 8);
        assert_eq!(p(&short), p(&mid), "let-bound trips must be opaque to the static model");
        assert_eq!(p(&mid), p(&long), "let-bound trips must be opaque to the static model");

        // The burst lands long after the warmup drains, ordered so that a
        // position-tie-broken static SJF interleaves classes adversarially.
        const BURST_AT: u64 = 50_000_000;
        let serve = |tuned: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Sjf)
                .with_cache(true)
                .with_batching(false)
                .with_verify(false);
            if tuned {
                s = s.with_learning(true).with_lookahead(4);
            }
            for _ in 0..3 {
                for k in [&short, &mid, &long] {
                    s.submit_kernel(job(k, 0));
                }
            }
            for k in [&short, &mid, &short, &mid, &short, &long] {
                s.submit_kernel(job(k, BURST_AT));
            }
            s.drain().expect("drain");
            s.report()
        };
        let stat = serve(false);
        let tuned = serve(true);
        assert_eq!(stat.completed, 15);
        assert_eq!(tuned.completed, 15);
        assert_eq!(stat.digest, tuned.digest, "learning moves time, never numerics");
        println!(
            "\nself-tuning study: makespan {} cy static-SJF vs {} cy learned-SJF+lookahead",
            stat.makespan_cycles, tuned.makespan_cycles
        );
        assert!(
            tuned.makespan_cycles < stat.makespan_cycles,
            "learned SJF + lookahead must strictly beat the static model ({} vs {})",
            tuned.makespan_cycles,
            stat.makespan_cycles
        );
        println!(
            "prediction error over {} samples: {}% static -> {}% learned",
            tuned.predict_samples, tuned.predict_err_static_pct, tuned.predict_err_learned_pct
        );
        assert!(
            tuned.predict_err_learned_pct < tuned.predict_err_static_pct,
            "refinement must shrink the mean prediction error ({}% vs {}%)",
            tuned.predict_err_learned_pct,
            tuned.predict_err_static_pct
        );
        out.metric("selftune.static.makespan_cycles", stat.makespan_cycles);
        out.metric("selftune.learned.makespan_cycles", tuned.makespan_cycles);
        out.metric("selftune.predict_err_static_pct", tuned.predict_err_static_pct);
        out.metric("selftune.predict_err_learned_pct", tuned.predict_err_learned_pct);
        out.digest("selftune.digest", tuned.digest);
        println!("learned schedule strictly faster, digests bit-identical: OK");

        // --- preemption: a High arrival jumps a planned Normal batch ------
        // One instance, batching on: eight identical Normal jobs gather
        // into a single batch at cycle 0, then a High job arrives at cycle
        // 1. With preemption the seven queued-but-assigned followers are
        // displaced back into the queue (the in-flight head is never
        // touched), the High job dispatches next, and the followers
        // re-batch behind it against the already-cached binary.
        let worker = opaque("preempt_worker", 800);
        let urgent = opaque("preempt_urgent", 400);
        let serve_pre = |preempt: bool| {
            let mut s = Scheduler::new(aurora(), 1, Policy::Fifo)
                .with_cache(true)
                .with_batching(true)
                .with_verify(false);
            if preempt {
                s = s.with_preemption(true);
            }
            for _ in 0..8 {
                s.submit_kernel(job(&worker, 0));
            }
            let mut h = job(&urgent, 1);
            h.priority = Priority::High;
            s.submit_kernel(h);
            s.drain().expect("drain");
            s.report()
        };
        let off = serve_pre(false);
        let on = serve_pre(true);
        assert_eq!(off.completed, 9);
        assert_eq!(on.completed, 9);
        assert_eq!(off.digest, on.digest, "preemption moves time, never numerics");
        assert_eq!(off.preemptions, 0);
        assert_eq!(on.preemptions, 7, "all seven batch followers must be displaced");
        let normal = on.class(Priority::Normal).expect("normal class completed jobs");
        assert_eq!(normal.preempted, 7);
        let hp95 = |r: &ServeReport| {
            r.class(Priority::High).expect("high class completed jobs").p95_turnaround_cycles
        };
        let (p95_on, p95_off) = (hp95(&on), hp95(&off));
        println!(
            "\npreemption study: High p95 turnaround {p95_on} cy preempting vs \
             {p95_off} cy waiting out the batch"
        );
        assert!(
            p95_on < p95_off,
            "displacing batch followers must improve High turnaround ({p95_on} vs {p95_off})"
        );
        out.metric("preempt.on.high_p95_turnaround_cycles", p95_on);
        out.metric("preempt.off.high_p95_turnaround_cycles", p95_off);
        out.metric("preempt.displacements", on.preemptions);
        out.digest("preempt.digest", on.digest);
        println!("High jumps the batch with bit-identical numerics: OK");
    }

    // --- fleet serving: affinity routing + tenant quotas ------------------
    // Two studies over the front-tier router. (a) A cache-heavy stream of
    // repeated kernels over 2 boards x pool 2: predicted-finish routing
    // with the binary-cache affinity bonus concentrates each kernel's
    // repeats on the board that already compiled it, while round-robin
    // splits every kernel across both boards and pays the compile twice.
    // Routing moves time, never numerics — fleet digests are bit-identical.
    // (b) A noisy tenant bursting 60 jobs against a 12-job victim on a
    // shared single-board fleet: capping the noisy tenant's in-flight
    // quota at the front tier strictly improves the victim's p95
    // turnaround, without touching the victim's admission.
    {
        use herov2::bench_harness::Variant;
        use herov2::fleet::{FleetReport, RoutePolicy, Router, TenantSpec};

        let job = |kernel: &'static str, size: usize, seed: u64| synth::JobDesc {
            kernel,
            size,
            variant: Variant::Handwritten,
            threads: 8,
            seed,
            arrival: 0,
            priority: Priority::Normal,
        };

        // Four distinct binaries, each submitted twice per repetition: the
        // second copy of each pair is where affinity routing cashes in.
        let mut stream = Vec::new();
        for _rep in 0..4 {
            for (k, size) in [
                ("darknet", 14usize),
                ("darknet", 14),
                ("covar", 12),
                ("covar", 12),
                ("3mm", 10),
                ("3mm", 10),
                ("2mm", 12),
                ("2mm", 12),
            ] {
                stream.push(job(k, size, 100 + stream.len() as u64));
            }
        }
        println!(
            "\nfleet study: {} repeated-kernel jobs on a 2-board fleet (pool 2 per board)\n",
            stream.len()
        );
        println!("{:<26} {:>14} {:>12} {:>12}", "route", "makespan (cy)", "compiles", "affinity");
        let serve_fleet = |route: RoutePolicy| {
            let mut r = Router::homogeneous(&aurora(), 2, 2).with_route(route);
            for j in &stream {
                r.submit(*j);
            }
            r.drain().expect("fleet drain");
            r.report()
        };
        let misses = |r: &FleetReport| r.boards.iter().map(|b| b.cache_misses).sum::<u64>();
        let aff = serve_fleet(RoutePolicy::Finish);
        let rr = serve_fleet(RoutePolicy::RoundRobin);
        for r in [&aff, &rr] {
            assert_eq!(r.completed, stream.len(), "all fleet jobs must complete");
            println!(
                "{:<26} {:>14} {:>12} {:>11.0}%",
                r.route,
                r.makespan_cycles,
                misses(r),
                100.0 * r.affinity_hit_rate()
            );
        }
        assert_eq!(aff.digest, rr.digest, "routing must never touch numerics");
        assert_eq!(rr.affinity_decisions, 0, "round-robin takes no finish-routing decisions");
        assert_eq!(aff.affinity_decisions, stream.len() as u64);
        assert!(aff.affinity_hits > 0, "the repeated stream must land warm routes");
        assert!(
            misses(&aff) < misses(&rr),
            "affinity routing must compile on fewer boards ({} vs {} misses)",
            misses(&aff),
            misses(&rr)
        );
        assert!(
            aff.makespan_cycles < rr.makespan_cycles,
            "affinity routing must strictly beat round-robin makespan ({} vs {})",
            aff.makespan_cycles,
            rr.makespan_cycles
        );
        out.metric("fleet.affinity.makespan_cycles", aff.makespan_cycles);
        out.metric("fleet.affinity.cache_misses", misses(&aff));
        out.metric("fleet.affinity.hits", aff.affinity_hits);
        out.metric("fleet.rr.makespan_cycles", rr.makespan_cycles);
        out.metric("fleet.rr.cache_misses", misses(&rr));
        out.digest("fleet.digest", aff.digest);
        println!("affinity routing strictly beats round-robin, digests bit-identical: OK");

        // (b) Noisy-neighbor isolation. The noisy tenant fronts every
        // victim job with a 5-job burst; all 72 jobs land at cycle 0, so
        // an in-flight cap of 10 admits exactly the first 10 noisy jobs
        // and refuses the rest at the front tier — no board ever sees
        // them. The victim's admission is untouched in both runs.
        let serve_quota = |noisy_cap: usize| {
            let mut r = Router::homogeneous(&aurora(), 1, 2);
            let noisy = r.tenant(TenantSpec {
                name: "noisy".to_string(),
                max_in_flight: noisy_cap,
                max_resident_bytes: 0,
                priority: None,
            });
            let victim = r.tenant(TenantSpec::unlimited("victim"));
            let mut n = 0u64;
            for i in 0..12u64 {
                for _ in 0..5 {
                    r.submit_for(noisy, job("gemm", 12, 500 + n));
                    n += 1;
                }
                r.submit_for(victim, job("atax", 24, 900 + i));
            }
            r.drain().expect("fleet drain");
            r.report()
        };
        let open = serve_quota(0);
        let capped = serve_quota(10);
        let victim_p95 = |r: &FleetReport| {
            r.tenant("victim")
                .expect("victim tenant reported")
                .class(Priority::Normal)
                .expect("victim jobs completed")
                .p95_turnaround_cycles
        };
        for (label, r, noisy_admitted) in [("open", &open, 60usize), ("capped", &capped, 10)] {
            let noisy = r.tenant("noisy").expect("noisy tenant reported");
            assert_eq!(noisy.submitted, 60);
            assert_eq!(noisy.admitted, noisy_admitted, "{label}: noisy admission");
            assert_eq!(r.tenant("victim").expect("victim").admitted, 12, "{label}: victim");
            assert_eq!(r.completed, noisy_admitted + 12, "{label}: admitted jobs complete");
        }
        assert_eq!(capped.quota_rejected, 50, "the cap must refuse the burst's tail");
        let (p95_open, p95_capped) = (victim_p95(&open), victim_p95(&capped));
        println!(
            "\nquota study: victim p95 turnaround {p95_capped} cy with the noisy tenant \
             capped at 10 in-flight vs {p95_open} cy uncapped"
        );
        assert!(
            p95_capped < p95_open,
            "capping the noisy tenant must improve the victim's p95 ({p95_capped} vs {p95_open})"
        );
        out.metric("fleet.quota.capped.victim_p95_turnaround_cycles", p95_capped);
        out.metric("fleet.quota.open.victim_p95_turnaround_cycles", p95_open);
        out.metric(
            "fleet.quota.noisy_admitted",
            capped.tenant("noisy").expect("noisy").admitted as u64,
        );
        println!("tenant quota isolates the noisy neighbor: OK");
    }

    // --- autotune: schedule-time AutoDMA recipe search --------------------
    // A mixed-size GEMM/stencil stream on the sizes where the default
    // recipe's halving descent overshoots: gemm N=112 halves its tile side
    // 97 -> 48 (a 3x3 tile grid) where the power-of-two side 64 fits
    // outright (2x2), and conv2d N=182 halves 119 -> 59 (4x4) where 64
    // fits (3x3). `--autotune` searches the knob space once per kernel,
    // memoizes the winner, and dispatches its binary; every candidate
    // computes the same values, so only the makespan moves.
    {
        use herov2::bench_harness::Variant;

        let stream: Vec<synth::JobDesc> = [("gemm", 112usize), ("conv2d", 182), ("gemm", 112), ("conv2d", 182)]
            .iter()
            .enumerate()
            .map(|(i, &(kernel, size))| synth::JobDesc {
                kernel,
                size,
                variant: Variant::AutoDma,
                threads: 8,
                seed: 300 + i as u64,
                arrival: 0,
                priority: Priority::Normal,
            })
            .collect();
        println!(
            "\nautotune study: {} mixed-size autodma jobs (gemm 112 / conv2d 182) on pool 2\n",
            stream.len()
        );
        let serve_tuned = |autotune: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Fifo)
                .with_batching(false)
                .with_autotune(autotune);
            s.submit_all(&stream);
            s.drain().expect("drain");
            s.report()
        };
        let plain = serve_tuned(false);
        let tuned = serve_tuned(true);
        for r in [&plain, &tuned] {
            assert_eq!(r.completed, stream.len());
            assert_eq!(r.verify_failures, 0);
        }
        assert_eq!(plain.digest, tuned.digest, "tuning must never change numerics");
        assert_eq!((plain.tune_searches, plain.tune_hits), (0, 0));
        assert_eq!(tuned.tune_searches, 2, "one search per distinct kernel");
        assert_eq!(tuned.tune_hits, 2, "repeats must hit the memo table");
        println!(
            "single-recipe {} cy vs tuned {} cy ({} search(es), {} memo hit(s))",
            plain.makespan_cycles, tuned.makespan_cycles, tuned.tune_searches, tuned.tune_hits
        );
        assert!(
            tuned.makespan_cycles < plain.makespan_cycles,
            "the tuned schedule must strictly beat the single recipe ({} vs {})",
            tuned.makespan_cycles,
            plain.makespan_cycles
        );
        out.metric("autotune.off.makespan_cycles", plain.makespan_cycles);
        out.metric("autotune.on.makespan_cycles", tuned.makespan_cycles);
        out.metric("autotune.searches", tuned.tune_searches);
        out.digest("autotune.digest", tuned.digest);
        println!("tuned recipes strictly faster, digests bit-identical: OK");
    }

    // --- resilience: board death mid-stream + deterministic retries -------
    // (a) A 2-board fleet loses board 1 halfway through the healthy
    // makespan: every queued job evacuates to the survivor, nothing is
    // lost, digests stay bit-identical to the healthy fleet, and the
    // degraded makespan stays under 2x healthy (graceful, not cliff-edge).
    // (b) Seeded transient faults with a retry budget on a single board:
    // every fault is retried to completion and the digest never moves —
    // faults cost time, never numerics.
    {
        use herov2::fault;
        use herov2::fleet::{RoutePolicy, Router};

        let stream = synth::mixed_jobs(32, 31);
        println!(
            "\nresilience study: {} jobs on a 2-board fleet, board 1 dies mid-stream\n",
            stream.len()
        );
        let serve_resilient = |plan: Option<&fault::FaultPlan>| {
            let board = || {
                Scheduler::new(aurora(), 2, Policy::Fifo)
                    .with_batching(false)
                    .with_verify(false)
                    .with_retry(3)
            };
            let mut r =
                Router::new(vec![board(), board()]).with_route(RoutePolicy::RoundRobin);
            if let Some(p) = plan {
                r = r.with_faults(p);
            }
            for j in &stream {
                r.submit(*j);
            }
            r.drain().expect("fleet drain");
            r.report()
        };
        let healthy = serve_resilient(None);
        assert_eq!(healthy.completed, stream.len());
        // Kill board 1 halfway through the healthy makespan: it has
        // dispatched roughly half its share and still queues the rest.
        let mid = healthy.makespan_cycles / 2;
        let kill = fault::parse(&format!("kill=1@{mid}")).expect("kill plan");
        let degraded = serve_resilient(Some(&kill));
        println!(
            "{:<26} {:>14} {:>12} {:>12}",
            "fleet", "makespan (cy)", "completed", "migrations"
        );
        for (label, r) in [("healthy", &healthy), ("board 1 down", &degraded)] {
            println!(
                "{label:<26} {:>14} {:>12} {:>12}",
                r.makespan_cycles, r.completed, r.migrations
            );
        }
        assert_eq!(
            degraded.completed,
            stream.len(),
            "a board death must lose no queued job"
        );
        assert_eq!(
            degraded.digest, healthy.digest,
            "evacuation moves time, never numerics"
        );
        assert!(degraded.migrations > 0, "the killed board must still hold queued work");
        assert_eq!(degraded.board_health[1], vec![(mid, false)]);
        assert!(
            degraded.makespan_cycles > healthy.makespan_cycles,
            "losing a board must cost time"
        );
        assert!(
            degraded.makespan_cycles < 2 * healthy.makespan_cycles,
            "degradation must be graceful: {} cy degraded vs {} cy healthy",
            degraded.makespan_cycles,
            healthy.makespan_cycles
        );
        out.metric("fault.healthy.makespan_cycles", healthy.makespan_cycles);
        out.metric("fault.degraded.makespan_cycles", degraded.makespan_cycles);
        out.metric("fault.degraded.migrations", degraded.migrations);
        out.digest("fault.degraded.digest", degraded.digest);
        println!("board death loses nothing, digests bit-identical, makespan < 2x: OK");

        // (b) Transient faults + retries on a single board.
        let plan = fault::parse("seed=9,transient=20").expect("fault plan");
        // Premises, checked against the same pure draw the scheduler uses:
        // the seed faults someone, and everyone clears within the budget.
        assert!((0..stream.len() as u64).any(|j| plan.draw(j, 0).is_some()));
        for j in 0..stream.len() as u64 {
            assert!(
                (0..=8).any(|a| plan.draw(j, a).is_none()),
                "job {j} must clear within the retry budget"
            );
        }
        let run_faulty = |armed: bool| {
            let mut s =
                Scheduler::new(aurora(), 2, Policy::Fifo).with_verify(false).with_retry(8);
            if armed {
                s = s.with_faults(plan.clone());
            }
            s.submit_all(&stream);
            s.drain().expect("drain");
            s.report()
        };
        let clean = run_faulty(false);
        let faulted = run_faulty(true);
        assert_eq!(faulted.completed, stream.len(), "every faulted job must retry through");
        assert_eq!(faulted.fault_failures, 0);
        assert!(faulted.faults_transient > 0, "seed 9 must inject at least one fault");
        assert_eq!(faulted.retries, faulted.faults_transient);
        assert_eq!(
            clean.digest, faulted.digest,
            "retried faults must be numerically invisible"
        );
        println!(
            "transient study: {} fault(s), {} retry(ies), makespan {} cy faulted vs \
             {} cy clean — digests bit-identical: OK",
            faulted.faults_transient,
            faulted.retries,
            faulted.makespan_cycles,
            clean.makespan_cycles
        );
        out.metric("fault.retry.faults", faulted.faults_transient);
        out.metric("fault.retry.retries", faulted.retries);
        out.metric("fault.retry.makespan_cycles", faulted.makespan_cycles);
        out.digest("fault.retry.digest", faulted.digest);
    }

    let path = out.emit().expect("emit BENCH_sched.json");
    println!("\nwrote {}", path.display());
}
