//! Scheduler scaling study: one FIFO instance without a binary cache vs a
//! pooled, batched, cached configuration.
//!
//! ```sh
//! cargo bench --bench sched
//! ```
//!
//! The acceptance bar for the subsystem: pool=4 with binary caching must
//! deliver at least 2x the simulated throughput (jobs per megacycle of
//! pool makespan) of pool=1 uncached — with bit-identical job results,
//! regardless of policy, pool size, batching or caching.

use herov2::config::aurora;
use herov2::sched::{Policy, Scheduler, ServeReport};
use herov2::workloads::synth;

fn run(pool: usize, policy: Policy, cache: bool, batch: bool, jobs: &[synth::JobDesc]) -> ServeReport {
    let mut s = Scheduler::new(aurora(), pool, policy)
        .with_cache(cache)
        .with_batching(batch)
        .with_verify(false); // numerics are covered by the digest identity
    s.submit_all(jobs);
    s.drain().expect("drain");
    s.report()
}

fn main() {
    let jobs = synth::mixed_jobs(48, 7);
    println!("{} mixed jobs (8 kernels, 3 tiled variants, 2 sizes each)\n", jobs.len());
    println!(
        "{:<26} {:>14} {:>12} {:>10} {:>8}",
        "configuration", "makespan (cy)", "jobs/Mcycle", "compile cy", "lowered"
    );

    let mut baseline = None;
    let mut scaled = None;
    for (label, pool, policy, cache, batch) in [
        ("pool=1 fifo uncached", 1usize, Policy::Fifo, false, false),
        ("pool=1 fifo cached", 1, Policy::Fifo, true, true),
        ("pool=2 fifo cached", 2, Policy::Fifo, true, true),
        ("pool=4 fifo cached", 4, Policy::Fifo, true, true),
        ("pool=4 sjf cached", 4, Policy::Sjf, true, true),
    ] {
        let r = run(pool, policy, cache, batch, &jobs);
        assert_eq!(r.completed, jobs.len(), "{label}: all jobs must complete");
        println!(
            "{label:<26} {:>14} {:>12.3} {:>10} {:>8}",
            r.makespan_cycles,
            r.jobs_per_mcycle(),
            r.compile_cycles,
            r.cache_misses
        );
        if pool == 1 && !cache {
            baseline = Some(r);
        } else if pool == 4 && policy == Policy::Fifo {
            scaled = Some(r);
        }
    }

    let baseline = baseline.unwrap();
    let scaled = scaled.unwrap();
    assert_eq!(
        baseline.digest, scaled.digest,
        "job results must be bit-identical across scheduler configurations"
    );
    let speedup = scaled.jobs_per_mcycle() / baseline.jobs_per_mcycle();
    println!(
        "\npool=4 + binary cache vs pool=1 uncached: {speedup:.2}x simulated throughput \
         (target >= 2x)"
    );
    assert!(speedup >= 2.0, "scheduler scaling regressed: {speedup:.2}x < 2x");
    println!("results bit-identical across configurations: OK");
}
