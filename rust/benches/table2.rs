//! Table 2: evaluated kernels and applications.
use herov2::bench_harness::figures;

fn main() {
    println!("{}", figures::table2());
}
