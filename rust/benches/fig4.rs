//! Fig 4: speed-up of execution on local memory with handwritten DMA
//! transfers compared to execution on external main memory (1 thread),
//! and the share of cycles spent on DMA transfers.
//!
//! Paper: speed-ups 2.2x (covar, reload factor 2) to 5.3x (darknet),
//! geomean 4.3x; DMA share max 1.9 %, average 0.2 %.

use herov2::bench_harness::figures;
use herov2::bench_harness::geomean;
use herov2::config::aurora;

fn main() {
    let rows = figures::fig4(&aurora()).expect("fig4");
    println!("Fig 4 — handwritten DMA tiling vs external-memory execution (1 thread)");
    println!("{:<10} {:>10} {:>10}", "kernel", "speedup", "dma-share");
    let mut xs = Vec::new();
    for r in &rows {
        println!("{:<10} {:>9.2}x {:>9.2}%", r.name, r.speedup, r.dma_share_pct);
        xs.push(r.speedup);
    }
    println!("geomean speedup: {:.2}x   (paper: 4.3x, range 2.2–5.3x)", geomean(&xs));
    let max_dma = rows.iter().map(|r| r.dma_share_pct).fold(0.0, f64::max);
    println!("max DMA share: {max_dma:.2}%   (paper: 1.9 %)");
}
