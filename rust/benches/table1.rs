//! Table 1: current target platforms and configurations of HEROv2, plus the
//! E9 FPGA resource-model check against the paper's reported utilization.
use herov2::bench_harness::figures;

fn main() {
    println!("{}", figures::table1());
}
