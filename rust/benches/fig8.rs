//! Fig 8: accelerator on-chip network data width sweep (32 and 128 bit vs
//! the 64-bit default): DMA cycles, computation cycles, total cycles.
//!
//! Paper: halving the width halves DMA speed (0.5x) and doubling doubles
//! it (2x) for 1D-transfer kernels; darknet/covar (2D transfers of short
//! bursts) see only 0.6x / 1.5x. At 32 bit the instruction-fetch bandwidth
//! costs computation cycles; at 128 bit the rearranged TCDM interconnect
//! adds ~15 % contention, costing ~10 % total on average.

use herov2::bench_harness::figures;
use herov2::bench_harness::geomean;
use herov2::config::aurora;

fn main() {
    let rows = figures::fig8(&aurora()).expect("fig8");
    println!("Fig 8 — on-chip network data-width sweep (speedup vs 64-bit)");
    println!("{:<10} {:>6} {:>8} {:>8} {:>8}", "kernel", "width", "dma", "comp", "total");
    let mut tot128 = Vec::new();
    for r in &rows {
        println!(
            "{:<10} {:>5}b {:>7.2}x {:>7.2}x {:>7.2}x",
            r.name, r.width_bits, r.dma_ratio, r.comp_ratio, r.total_ratio
        );
        if r.width_bits == 128 {
            tot128.push(r.total_ratio);
        }
    }
    println!(
        "128-bit total geomean: {:.2}x   (paper: ~0.90x — wider is slower without \
         cluster co-design)",
        geomean(&tot128)
    );
}
