//! Fig 6: code complexity of handwritten tiling + DMA vs unmodified code
//! (CCCC lines-of-code and McCabe cyclomatic complexity).
//!
//! Paper: 1D-tiled kernels 1.7–2.5x LoC / 1.3–1.5x cyclomatic; darknet
//! (2D) 3.4x / 3.7x; covar (two 2D passes) 6.3x / 4.0x; averages 2.6x LoC,
//! 1.8x cyclomatic.

use herov2::bench_harness::figures;
use herov2::bench_harness::geomean;

fn main() {
    let rows = figures::fig6();
    println!("Fig 6 — handwritten tiling code-complexity overhead");
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "kernel", "LoC", "LoC'", "ratio", "cyclo", "cyclo'", "ratio"
    );
    let (mut ls, mut cs) = (Vec::new(), Vec::new());
    for r in &rows {
        println!(
            "{:<10} {:>8} {:>8} {:>7.2}x {:>8} {:>8} {:>7.2}x",
            r.name,
            r.loc_unmodified,
            r.loc_handwritten,
            r.loc_ratio(),
            r.cyc_unmodified,
            r.cyc_handwritten,
            r.cyc_ratio()
        );
        ls.push(r.loc_ratio());
        cs.push(r.cyc_ratio());
    }
    println!(
        "geomean: LoC {:.2}x (paper 2.6x), cyclomatic {:.2}x (paper 1.8x)",
        geomean(&ls),
        geomean(&cs)
    );
}
