//! Fig 9: speed-up of the Xpulpv2 ISA extension over standard RV32IMAFC
//! (handwritten DMA, 8 threads). Bars: compiler-generated Xpulpv2; + manual
//! register promotion; + expert inline assembly (modeled comparator).
//!
//! Paper: 1.5x average from Xpulpv2 alone; gemm 2.5x (inner loop 10 -> 5
//! instructions, two hardware loops; promotion: 5 -> 4); conv2d/atax/bicg
//! only 1.1–1.5x; covar needs manual promotion to get its hardware loop;
//! final range 1.1–3.5x, average 2.1x.

use herov2::bench_harness::figures;
use herov2::bench_harness::geomean;
use herov2::config::aurora;

fn main() {
    let rows = figures::fig9(&aurora()).expect("fig9");
    println!("Fig 9 — Xpulpv2 vs RV32IMAFC (handwritten DMA, 8 threads)");
    println!(
        "{:<10} {:>8} {:>9} {:>8} | {:>5} {:>6} {:>5}",
        "kernel", "xpulpv2", "promoted", "expert", "inner", "xpulp", "prom"
    );
    let mut xs = Vec::new();
    for r in &rows {
        println!(
            "{:<10} {:>7.2}x {:>8.2}x {:>7.2}x | {:>5} {:>6} {:>5}",
            r.name,
            r.xpulp_speedup,
            r.promoted_speedup,
            r.expert_speedup,
            r.inner_base,
            r.inner_xpulp,
            r.inner_promoted
        );
        xs.push(r.promoted_speedup);
    }
    println!(
        "geomean (promoted): {:.2}x   (paper: 2.1x average, range 1.1–3.5x)",
        geomean(&xs)
    );
}
