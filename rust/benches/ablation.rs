//! Ablations over the platform's design parameters — the §3.3-style
//! exploration the platform exists to enable, applied to our own design
//! choices: TCDM banking factor, IOMMU TLB capacity, DMA burst overhead,
//! and the AutoDMA tile-side formula. Every configuration point is one
//! `Session` over the tweaked platform.

use herov2::bench_harness::{verify_arrays, Variant};
use herov2::config::aurora;
use herov2::trace::Event;
use herov2::workloads;
use herov2::Session;

fn main() {
    let seed = 13;
    let w = workloads::gemm::build(96);

    println!("TCDM banking factor (gemm-96, handwritten, 8 threads):");
    for bf in [1usize, 2, 4] {
        let mut cfg = aurora();
        cfg.accel.banking_factor = bf;
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::Handwritten, 8, seed).unwrap();
        verify_arrays(&w, &out.arrays, seed).unwrap();
        println!(
            "  factor {bf} ({:2} banks): {:>8} cycles, {:>8} conflicts",
            bf * 8,
            out.result.device_cycles,
            out.result.perf.get(Event::TcdmConflict)
        );
    }

    println!("\nIOMMU TLB capacity (atax-256 unmodified, 8 threads — column walks):");
    let wa = workloads::atax::build(256);
    for tlb in [8usize, 32, 128, 1024] {
        let mut cfg = aurora();
        cfg.iommu.tlb_entries = tlb;
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&wa, Variant::Unmodified, 8, seed).unwrap();
        verify_arrays(&wa, &out.arrays, seed).unwrap();
        println!(
            "  {tlb:>4} entries: {:>9} cycles, {:>6} misses",
            out.result.device_cycles,
            out.result.perf.get(Event::TlbMiss)
        );
    }

    println!("\nDMA burst issue overhead (darknet-96 2D tiling, 8 threads):");
    let wd = workloads::darknet::build(96);
    for oh in [0u64, 10, 20, 40] {
        let mut cfg = aurora();
        cfg.dma.burst_overhead = oh;
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&wd, Variant::Handwritten, 8, seed).unwrap();
        verify_arrays(&wd, &out.arrays, seed).unwrap();
        println!(
            "  {oh:>2} cycles/burst: {:>8} total cycles, {:>8} dma cycles",
            out.result.device_cycles,
            out.result.dma_cycles()
        );
    }

    println!("\nAutoDMA L1 budget sensitivity (gemm-96, autodma, 8 threads):");
    for frac in [4u32, 2, 1] {
        let mut cfg = aurora();
        // Shrink the usable TCDM by the factor (smaller tiles, more phases).
        cfg.accel.l1_bytes = 128 * 1024 / frac as usize;
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::AutoDma, 8, seed).unwrap();
        verify_arrays(&w, &out.arrays, seed).unwrap();
        let tiles =
            out.result.autodma.as_ref().and_then(|r| r.tile_sides.first().copied()).flatten();
        println!(
            "  L1 {:>3} KiB: {:>8} cycles (tile side {:?})",
            128 / frac,
            out.result.device_cycles,
            tiles
        );
    }
}
