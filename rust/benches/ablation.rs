//! Ablations over the platform's design parameters — the §3.3-style
//! exploration the platform exists to enable, applied to our own design
//! choices: TCDM banking factor, IOMMU TLB capacity, DMA burst overhead,
//! and the AutoDMA tile-side formula.

use herov2::bench_harness::{run_workload, verify, Variant};
use herov2::config::aurora;
use herov2::trace::Event;
use herov2::workloads;

fn main() {
    let seed = 13;
    let w = workloads::gemm::build(96);

    println!("TCDM banking factor (gemm-96, handwritten, 8 threads):");
    for bf in [1usize, 2, 4] {
        let mut cfg = aurora();
        cfg.accel.banking_factor = bf;
        let out = run_workload(&cfg, &w, Variant::Handwritten, 8, seed, 1e10 as u64).unwrap();
        verify(&w, &out, seed).unwrap();
        println!(
            "  factor {bf} ({:2} banks): {:>8} cycles, {:>8} conflicts",
            bf * 8,
            out.cycles(),
            out.result.perf.get(Event::TcdmConflict)
        );
    }

    println!("\nIOMMU TLB capacity (atax-256 unmodified, 8 threads — column walks):");
    let wa = workloads::atax::build(256);
    for tlb in [8usize, 32, 128, 1024] {
        let mut cfg = aurora();
        cfg.iommu.tlb_entries = tlb;
        let out = run_workload(&cfg, &wa, Variant::Unmodified, 8, seed, 1e10 as u64).unwrap();
        verify(&wa, &out, seed).unwrap();
        println!(
            "  {tlb:>4} entries: {:>9} cycles, {:>6} misses",
            out.cycles(),
            out.result.perf.get(Event::TlbMiss)
        );
    }

    println!("\nDMA burst issue overhead (darknet-96 2D tiling, 8 threads):");
    let wd = workloads::darknet::build(96);
    for oh in [0u64, 10, 20, 40] {
        let mut cfg = aurora();
        cfg.dma.burst_overhead = oh;
        let out = run_workload(&cfg, &wd, Variant::Handwritten, 8, seed, 1e10 as u64).unwrap();
        verify(&wd, &out, seed).unwrap();
        println!(
            "  {oh:>2} cycles/burst: {:>8} total cycles, {:>8} dma cycles",
            out.cycles(),
            out.dma_cycles()
        );
    }

    println!("\nAutoDMA L1 budget sensitivity (gemm-96, autodma, 8 threads):");
    for frac in [4u32, 2, 1] {
        let mut cfg = aurora();
        // Shrink the usable TCDM by the factor (smaller tiles, more phases).
        cfg.accel.l1_bytes = 128 * 1024 / frac as usize;
        let out = run_workload(&cfg, &w, Variant::AutoDma, 8, seed, 1e10 as u64).unwrap();
        verify(&w, &out, seed).unwrap();
        let tiles = out.report.as_ref().and_then(|r| r.tile_sides.first().copied()).flatten();
        println!(
            "  L1 {:>3} KiB: {:>8} cycles (tile side {:?})",
            128 / frac,
            out.cycles(),
            tiles
        );
    }
}
