//! E10 (§2.3): offload overhead and TLB miss-handling microbenchmarks.
//!
//! The paper's offloading model is coarse-grained: kernels of at least a
//! few ten thousand cycles amortize the mailbox/driver overhead. A TLB hit
//! adds 3 cycles to a remote access; misses are handled in software by the
//! faulting core or a dedicated core (configurable per offload). All runs
//! go through the unified `Session` front door.

use herov2::config::{aurora, MissMode};
use herov2::host::Mailbox;
use herov2::trace::Event;
use herov2::workloads;
use herov2::{bench_harness::Variant, Session};

fn main() {
    let cfg = aurora();
    println!("Offload overhead (mailbox + driver): {} cycles", Mailbox::round_trip_cycles(&cfg));
    println!("\nkernel-size sweep (gemm, handwritten, 8 threads): overhead share");
    let mut sess = Session::single(cfg);
    for n in [8usize, 12, 16, 24, 32, 48] {
        let w = workloads::gemm::build(n);
        let out = sess.run_workload(&w, Variant::Handwritten, 8, 1).unwrap();
        let dev = out.result.device_cycles;
        let tot = out.result.total_cycles;
        println!(
            "  N={n:3}: device {dev:>9} cy, end-to-end {tot:>9} cy, overhead {:.2}%",
            100.0 * (tot - dev) as f64 / tot as f64
        );
    }
    println!("\nTLB miss handling (atax unmodified, 8 threads — pointer-heavy):");
    for mode in [MissMode::SelfService, MissMode::DedicatedCore] {
        let mut cfg = aurora();
        cfg.iommu.miss_mode = mode;
        cfg.iommu.tlb_entries = 16; // pressure the TLB to expose the modes
        let w = workloads::atax::build(256);
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::Unmodified, 8, 1).unwrap();
        println!(
            "  {mode:?}: {} cycles, {} TLB misses",
            out.result.device_cycles,
            out.result.perf.get(Event::TlbMiss)
        );
    }
}
