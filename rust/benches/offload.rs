//! E10 (§2.3): offload overhead and TLB miss-handling microbenchmarks.
//!
//! The paper's offloading model is coarse-grained: kernels of at least a
//! few ten thousand cycles amortize the mailbox/driver overhead. A TLB hit
//! adds 3 cycles to a remote access; misses are handled in software by the
//! faulting core or a dedicated core (configurable per offload). All runs
//! go through the unified `Session` front door.
//!
//! Every reported cycle count is deterministic and emitted to
//! `BENCH_offload.json` for the `bench-gate` CI job.

use herov2::bench_harness::emit::BenchJson;
use herov2::config::{aurora, MissMode};
use herov2::host::Mailbox;
use herov2::trace::Event;
use herov2::workloads;
use herov2::{bench_harness::Variant, Session};

fn main() {
    let mut out = BenchJson::new("offload");
    let cfg = aurora();
    let overhead = Mailbox::round_trip_cycles(&cfg);
    println!("Offload overhead (mailbox + driver): {overhead} cycles");
    out.metric("mailbox.round_trip_cycles", overhead);
    println!("\nkernel-size sweep (gemm, handwritten, 8 threads): overhead share");
    let mut sess = Session::single(cfg);
    for n in [8usize, 12, 16, 24, 32, 48] {
        let w = workloads::gemm::build(n);
        let out_n = sess.run_workload(&w, Variant::Handwritten, 8, 1).unwrap();
        let dev = out_n.result.device_cycles;
        let tot = out_n.result.total_cycles;
        println!(
            "  N={n:3}: device {dev:>9} cy, end-to-end {tot:>9} cy, overhead {:.2}%",
            100.0 * (tot - dev) as f64 / tot as f64
        );
        out.metric(format!("gemm{n}.device_cycles"), dev);
        out.metric(format!("gemm{n}.total_cycles"), tot);
    }
    println!("\nTLB miss handling (atax unmodified, 8 threads — pointer-heavy):");
    for mode in [MissMode::SelfService, MissMode::DedicatedCore] {
        let mut cfg = aurora();
        cfg.iommu.miss_mode = mode;
        cfg.iommu.tlb_entries = 16; // pressure the TLB to expose the modes
        let w = workloads::atax::build(256);
        let mut sess = Session::single(cfg);
        let out_m = sess.run_workload(&w, Variant::Unmodified, 8, 1).unwrap();
        let misses = out_m.result.perf.get(Event::TlbMiss);
        println!(
            "  {mode:?}: {} cycles, {misses} TLB misses",
            out_m.result.device_cycles
        );
        let key = match mode {
            MissMode::SelfService => "tlb.self_service",
            MissMode::DedicatedCore => "tlb.dedicated_core",
        };
        out.metric(format!("{key}.device_cycles"), out_m.result.device_cycles);
        out.metric(format!("{key}.misses"), misses);
    }
    let path = out.emit().expect("emit BENCH_offload.json");
    println!("\nwrote {}", path.display());
}
