//! Fig 5: speed-up of 8-thread over 1-thread execution (handwritten DMA).
//!
//! Paper: computation-only 6.5–7.1x (avg 6.9x); overall 5.9–7.1x (avg
//! 6.7x); DMA share grows with the speed-up (covar: 10.3 % at 8 threads).

use herov2::bench_harness::figures;
use herov2::bench_harness::geomean;
use herov2::config::aurora;

fn main() {
    let rows = figures::fig5(&aurora()).expect("fig5");
    println!("Fig 5 — parallelization speed-up (8 vs 1 accelerator threads)");
    println!("{:<10} {:>10} {:>10} {:>10}", "kernel", "comp-only", "overall", "dma-share");
    let (mut cs, mut os) = (Vec::new(), Vec::new());
    for r in &rows {
        println!(
            "{:<10} {:>9.2}x {:>9.2}x {:>9.2}%",
            r.name, r.comp_speedup, r.overall_speedup, r.dma_share_pct
        );
        cs.push(r.comp_speedup);
        os.push(r.overall_speedup);
    }
    println!(
        "geomean: comp {:.2}x (paper 6.9x), overall {:.2}x (paper 6.7x)",
        geomean(&cs),
        geomean(&os)
    );
}
