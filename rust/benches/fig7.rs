//! Fig 7: speed-up of compiler-generated (AutoDMA) tiling and DMA over
//! execution on external main memory, compared with handwritten tiling
//! (8 threads).
//!
//! Paper: AutoDMA reaches up to 4.4x with zero code changes and ~85 % of
//! the handwritten speed-up for kernels with high spatial locality; for
//! covar and atax the gain is marginal (column-wise accesses).

use herov2::bench_harness::figures;
use herov2::config::aurora;

fn main() {
    let rows = figures::fig7(&aurora()).expect("fig7");
    println!("Fig 7 — AutoDMA (compiler) vs handwritten tiling, 8 threads");
    println!("{:<10} {:>10} {:>12} {:>12}", "kernel", "autodma", "handwritten", "auto/hand");
    let mut best = 0.0f64;
    for r in &rows {
        println!(
            "{:<10} {:>9.2}x {:>11.2}x {:>11.1}%",
            r.name,
            r.autodma_speedup,
            r.handwritten_speedup,
            100.0 * r.autodma_speedup / r.handwritten_speedup
        );
        best = best.max(r.autodma_speedup);
    }
    println!("max AutoDMA speedup: {best:.2}x   (paper: up to 4.4x)");
}
