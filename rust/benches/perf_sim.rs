//! §Perf: simulator hot-path throughput (simulated core-cycles per second).
//!
//! This is the L3 optimization target of EXPERIMENTS.md §Perf: the gemm
//! compute loop must simulate fast enough that every figure bench runs in
//! seconds. Reports simulated cycles/sec over repeated runs.

use herov2::bench_harness::stats;
use herov2::bench_harness::{run_workload, Variant};
use herov2::config::aurora;
use herov2::workloads;

fn main() {
    let cfg = aurora();
    for (label, w, v, threads) in [
        ("gemm-96-hand-8t", workloads::gemm::build(96), Variant::Handwritten, 8u32),
        ("gemm-96-unmod-1t", workloads::gemm::build(96), Variant::Unmodified, 1),
        ("darknet-96-hand-8t", workloads::darknet::build(96), Variant::Handwritten, 8),
    ] {
        let mut cycles = 0u64;
        let secs = stats::time_runs(3, || {
            let out = run_workload(&cfg, &w, v, threads, 1, 10_000_000_000).unwrap();
            cycles = out.cycles();
        });
        let s = stats::summarize(&secs);
        println!(
            "{label:<20} {:>10} sim-cycles  median {:.3}s  {:>6.1} M simulated cycles/s",
            cycles,
            s.median,
            cycles as f64 / s.median / 1e6
        );
    }
}
