//! §Perf: simulator hot-path throughput (simulated core-cycles per second).
//!
//! This is the L3 optimization target: the gemm compute loop must simulate
//! fast enough that every figure bench runs in seconds. Reports simulated
//! cycles/sec over repeated runs, driving the stack through the `Session`
//! front door (a fresh session per run keeps the compile inside the timed
//! region, like the original harness).
//!
//! Only the **simulated** cycle counts go to `BENCH_perf_sim.json` — the
//! wall-clock throughput is machine-dependent and stays out of the
//! `bench-gate` comparison by design.

use herov2::bench_harness::emit::BenchJson;
use herov2::bench_harness::stats;
use herov2::bench_harness::Variant;
use herov2::config::aurora;
use herov2::workloads;
use herov2::Session;

fn main() {
    let mut out = BenchJson::new("perf_sim");
    let cfg = aurora();
    for (label, w, v, threads) in [
        ("gemm-96-hand-8t", workloads::gemm::build(96), Variant::Handwritten, 8u32),
        ("gemm-96-unmod-1t", workloads::gemm::build(96), Variant::Unmodified, 1),
        ("darknet-96-hand-8t", workloads::darknet::build(96), Variant::Handwritten, 8),
    ] {
        let mut cycles = 0u64;
        let secs = stats::time_runs(3, || {
            let mut sess = Session::single(cfg.clone());
            let out = sess.run_workload(&w, v, threads, 1).unwrap();
            cycles = out.result.device_cycles;
        });
        let s = stats::summarize(&secs);
        println!(
            "{label:<20} {:>10} sim-cycles  median {:.3}s  {:>6.1} M simulated cycles/s",
            cycles,
            s.median,
            cycles as f64 / s.median / 1e6
        );
        out.metric(format!("{label}.device_cycles"), cycles);
    }
    let path = out.emit().expect("emit BENCH_perf_sim.json");
    println!("\nwrote {}", path.display());
}
