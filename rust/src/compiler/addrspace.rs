//! Address-space analysis (§2.2.1).
//!
//! The paper's Clang frontend infers which pointers may hold 64-bit host
//! addresses (promoting them to the host address space) and which are
//! provably 32-bit native; a backend legalizer pass then implements
//! wider-than-native loads/stores through the address-extension CSR.
//!
//! In our IR, arrays carry their space in the symbol table (`HostArray` vs
//! `LocalBuf`), so the inference reduces to a propagation + validation pass:
//! every access must resolve to a known space, DMA statements must connect a
//! host array with a local buffer, local buffers must be allocated before
//! use, and host-space accesses are counted so the lowering's `*.ext`
//! emission can be cross-checked.

use super::ir::{Expr, Kernel, Stmt, Sym, VarId};
use std::collections::HashSet;

/// Result of the address-space pass.
#[derive(Debug, Clone, Default)]
pub struct SpaceInfo {
    /// Number of accesses in the host (64-bit) address space.
    pub host_accesses: u32,
    /// Number of accesses in the native (32-bit) space.
    pub native_accesses: u32,
    /// Arrays accessed directly (not only via DMA) from compute code.
    pub direct_host_arrays: Vec<VarId>,
}

/// Run the pass; returns analysis info or a diagnostic.
pub fn analyze(k: &Kernel) -> Result<SpaceInfo, String> {
    let mut info = SpaceInfo::default();
    let mut allocated: HashSet<VarId> = HashSet::new();
    let mut direct: HashSet<VarId> = HashSet::new();
    check_block(k, &k.body, &mut info, &mut allocated, &mut direct)?;
    info.direct_host_arrays = direct.into_iter().collect();
    info.direct_host_arrays.sort_unstable();
    Ok(info)
}

fn check_expr(
    k: &Kernel,
    e: &Expr,
    info: &mut SpaceInfo,
    allocated: &HashSet<VarId>,
    direct: &mut HashSet<VarId>,
) -> Result<(), String> {
    match e {
        Expr::Load(a, idx) => {
            visit_access(k, *a, info, allocated, direct)?;
            for i in idx {
                check_expr(k, i, info, allocated, direct)?;
            }
            Ok(())
        }
        Expr::Bin(_, a, b) => {
            check_expr(k, a, info, allocated, direct)?;
            check_expr(k, b, info, allocated, direct)
        }
        _ => Ok(()),
    }
}

fn visit_access(
    k: &Kernel,
    a: VarId,
    info: &mut SpaceInfo,
    allocated: &HashSet<VarId>,
    direct: &mut HashSet<VarId>,
) -> Result<(), String> {
    match k.sym(a) {
        Sym::HostArray { .. } => {
            info.host_accesses += 1;
            direct.insert(a);
            Ok(())
        }
        Sym::LocalBuf { .. } => {
            if !allocated.contains(&a) {
                return Err(format!("local buffer {} used before allocation", k.sym_name(a)));
            }
            info.native_accesses += 1;
            Ok(())
        }
        other => Err(format!("{} is not an array ({other:?})", k.sym_name(a))),
    }
}

fn check_block(
    k: &Kernel,
    body: &[Stmt],
    info: &mut SpaceInfo,
    allocated: &mut HashSet<VarId>,
    direct: &mut HashSet<VarId>,
) -> Result<(), String> {
    for s in body {
        match s {
            Stmt::For { lo, hi, body, .. } => {
                check_expr(k, lo, info, allocated, direct)?;
                check_expr(k, hi, info, allocated, direct)?;
                check_block(k, body, info, allocated, direct)?;
            }
            Stmt::Store { dst, idx, value } => {
                visit_access(k, *dst, info, allocated, direct)?;
                for i in idx {
                    check_expr(k, i, info, allocated, direct)?;
                }
                check_expr(k, value, info, allocated, direct)?;
            }
            Stmt::Let { value, .. } | Stmt::Assign { value, .. } => {
                check_expr(k, value, info, allocated, direct)?;
            }
            Stmt::LocalAlloc { var, .. } => {
                if !matches!(k.sym(*var), Sym::LocalBuf { .. }) {
                    return Err(format!("{} allocated but not a local buffer", k.sym_name(*var)));
                }
                allocated.insert(*var);
            }
            Stmt::Dma { host, local, .. } => {
                if !matches!(k.sym(*host), Sym::HostArray { .. }) {
                    return Err(format!(
                        "DMA host operand {} is not in the host address space",
                        k.sym_name(*host)
                    ));
                }
                if !matches!(k.sym(*local), Sym::LocalBuf { .. }) {
                    return Err(format!(
                        "DMA local operand {} is not a local buffer",
                        k.sym_name(*local)
                    ));
                }
                if !allocated.contains(local) {
                    return Err(format!(
                        "DMA uses unallocated local buffer {}",
                        k.sym_name(*local)
                    ));
                }
            }
            Stmt::DmaWaitAll => {}
            Stmt::LocalFreeAll => {
                allocated.clear();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;

    #[test]
    fn untiled_kernel_is_all_host() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 8);
        let a = b.host_array("A", vec![var(n)]);
        let i = b.loop_var("i");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![st(a, vec![var(i)], ld(a, vec![var(i)]).mul(cf(2.0)))],
        )]);
        let info = analyze(&k).unwrap();
        assert_eq!(info.native_accesses, 0);
        assert_eq!(info.host_accesses, 2);
        assert_eq!(info.direct_host_arrays, vec![a]);
    }

    #[test]
    fn rejects_use_before_alloc() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 8);
        let l = b.local_buf("buf", vec![var(n)]);
        let i = b.loop_var("i");
        let k = b.body(vec![for_(i, ci(0), var(n), vec![st(l, vec![var(i)], cf(0.0))])]);
        assert!(analyze(&k).is_err());
    }

    #[test]
    fn rejects_dma_between_two_host_arrays() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 8);
        let a = b.host_array("A", vec![var(n)]);
        let c = b.host_array("C", vec![var(n)]);
        let k = b.body(vec![Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: a,
            host_off: ci(0),
            local: c, // not a local buffer!
            local_off: ci(0),
            rows: ci(1),
            row_elems: var(n),
            host_stride: ci(0),
            local_stride: ci(0),
        }]);
        assert!(analyze(&k).is_err());
    }

    #[test]
    fn tiled_kernel_counts_native() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 8);
        let a = b.host_array("A", vec![var(n)]);
        let l = b.local_buf("la", vec![var(n)]);
        let i = b.loop_var("i");
        let k = b.body(vec![
            Stmt::LocalAlloc { var: l, elems: var(n) },
            Stmt::Dma {
                dir: Dir::HostToLocal,
                kind: DmaKind::Merged1D,
                host: a,
                host_off: ci(0),
                local: l,
                local_off: ci(0),
                rows: ci(1),
                row_elems: var(n),
                host_stride: ci(0),
                local_stride: ci(0),
            },
            Stmt::DmaWaitAll,
            for_(i, ci(0), var(n), vec![st(l, vec![var(i)], ld(l, vec![var(i)]).mul(cf(2.0)))]),
        ]);
        let info = analyze(&k).unwrap();
        assert_eq!(info.host_accesses, 0);
        assert_eq!(info.native_accesses, 2);
        assert!(info.direct_host_arrays.is_empty());
    }
}
