//! The heterogeneous device compiler (§2.2).
//!
//! Mirrors the paper's LLVM 9-based toolchain at IR level:
//!
//! | Paper component | Module |
//! |---|---|
//! | Kernel source (OpenMP target region after outlining) | [`ir`] |
//! | Clang address-space inference + host-pointer legalizer (§2.2.1) | [`addrspace`] + `*.ext` emission in [`lower`] |
//! | AutoDMA tiling + DMA inference plugin (§2.2.2) | [`autodma`] |
//! | AutoDMA knob search (tile side, double-buffering, variant) | [`autotune`] |
//! | Xpulpv2 codegen: hwloops, post-increment, MAC (§2.2.3) | [`lower`] |
//! | CCCC code metrics used in Fig 6 | [`metrics`] |
//!
//! [`compile`] is the full pipeline: address-space validation → (optional)
//! AutoDMA → lowering to a device [`Program`]. See `rust/src/compiler/README.md`
//! for the pipeline walk-through.

pub mod addrspace;
pub mod analyze;
pub mod autodma;
pub mod autotune;
pub mod ir;
pub mod lower;
pub mod metrics;

pub use autodma::{AutoDmaOpts, AutoDmaReport};
pub use autotune::{tune, TuneResult, TunedVariant};
pub use ir::Kernel;
pub use lower::{Lowered, LowerOpts};

use crate::isa::Program;
use anyhow::{anyhow, Result};

/// Compile a kernel to a device program.
///
/// `autodma`: run the AutoDMA transform first (for kernels written in plain
/// OpenMP form); handwritten-tiled kernels pass `None`.
pub fn compile(
    k: &Kernel,
    opts: &LowerOpts,
    autodma: Option<&AutoDmaOpts>,
) -> Result<(Lowered, Option<AutoDmaReport>)> {
    addrspace::analyze(k).map_err(|e| anyhow!("address-space check failed: {e}"))?;
    if let Some(ad) = autodma {
        let (tiled, report) = autodma::transform(k, ad)?;
        addrspace::analyze(&tiled)
            .map_err(|e| anyhow!("AutoDMA output failed address-space check: {e}"))?;
        let lowered = lower::lower(&tiled, opts)?;
        Ok((lowered, Some(report)))
    } else {
        let lowered = lower::lower(k, opts)?;
        Ok((lowered, None))
    }
}

/// Disassemble for diagnostics.
pub fn disasm(p: &Program) -> String {
    crate::isa::disasm::program(p)
}
