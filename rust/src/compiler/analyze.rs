//! Affine access analysis.
//!
//! Array subscripts in the evaluated kernels are affine in the loop
//! variables with compile-time-constant coefficients (static problem sizes,
//! as in Polybench). This module extracts `c0 + Σ coeff_v · v` forms from
//! index expressions; the lowering uses them for pointer strength reduction
//! and post-increment legality, AutoDMA for footprint/region analysis.

use super::ir::{BinOp, Expr, Kernel, Sym, VarId};

/// An affine form over scalar variables: `constant + Σ terms`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Affine {
    pub constant: i64,
    /// (variable, coefficient); variables appear at most once, coeff ≠ 0.
    pub terms: Vec<(VarId, i64)>,
}

impl Affine {
    pub fn constant(c: i64) -> Self {
        Affine { constant: c, terms: Vec::new() }
    }

    pub fn var(v: VarId) -> Self {
        Affine { constant: 0, terms: vec![(v, 1)] }
    }

    pub fn coeff(&self, v: VarId) -> i64 {
        self.terms.iter().find(|(t, _)| *t == v).map(|(_, c)| *c).unwrap_or(0)
    }

    pub fn is_const(&self) -> bool {
        self.terms.is_empty()
    }

    pub fn add(&self, o: &Affine) -> Affine {
        let mut r = self.clone();
        r.constant += o.constant;
        for (v, c) in &o.terms {
            r.add_term(*v, *c);
        }
        r
    }

    pub fn sub(&self, o: &Affine) -> Affine {
        self.add(&o.scale(-1))
    }

    pub fn scale(&self, s: i64) -> Affine {
        if s == 0 {
            return Affine::constant(0);
        }
        Affine {
            constant: self.constant * s,
            terms: self.terms.iter().map(|(v, c)| (*v, c * s)).collect(),
        }
    }

    fn add_term(&mut self, v: VarId, c: i64) {
        if c == 0 {
            return;
        }
        if let Some(t) = self.terms.iter_mut().find(|(t, _)| *t == v) {
            t.1 += c;
            if t.1 == 0 {
                self.terms.retain(|(_, c)| *c != 0);
            }
        } else {
            self.terms.push((v, c));
        }
    }

    /// Substitute `v := repl` (an affine form).
    pub fn substitute(&self, v: VarId, repl: &Affine) -> Affine {
        let c = self.coeff(v);
        if c == 0 {
            return self.clone();
        }
        let mut r = self.clone();
        r.terms.retain(|(t, _)| *t != v);
        r.add(&repl.scale(c))
    }
}

/// Extract an affine form from an integer expression. Const parameters fold
/// into constants; loop variables and i32 lets stay symbolic. Returns `None`
/// for non-affine expressions (products of variables, Min/Max, loads...).
pub fn affine_of(k: &Kernel, e: &Expr) -> Option<Affine> {
    match e {
        Expr::ConstI(c) => Some(Affine::constant(*c as i64)),
        Expr::Var(v) => match k.sym(*v) {
            Sym::ConstParam { value } => Some(Affine::constant(*value as i64)),
            Sym::LoopVar | Sym::LetI32 => Some(Affine::var(*v)),
            _ => None,
        },
        Expr::Bin(op, a, b) => {
            let (a, b) = (affine_of(k, a)?, affine_of(k, b)?);
            match op {
                BinOp::Add => Some(a.add(&b)),
                BinOp::Sub => Some(a.sub(&b)),
                BinOp::Mul => {
                    if a.is_const() {
                        Some(b.scale(a.constant))
                    } else if b.is_const() {
                        Some(a.scale(b.constant))
                    } else {
                        None
                    }
                }
                BinOp::Div if b.is_const() && a.is_const() && b.constant != 0 => {
                    Some(Affine::constant(a.constant / b.constant))
                }
                _ => None,
            }
        }
        _ => None,
    }
}

/// Flattened element offset of a multi-dimensional access, as an affine
/// form: `Σ affine(idx_d) · stride_d`.
pub fn flat_offset(k: &Kernel, array: VarId, idx: &[Expr]) -> Option<Affine> {
    let strides = k.array_strides(array)?;
    if strides.len() != idx.len() {
        return None;
    }
    let mut acc = Affine::constant(0);
    for (e, s) in idx.iter().zip(strides) {
        acc = acc.add(&affine_of(k, e)?.scale(s));
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;

    fn kernel() -> (Kernel, VarId, VarId, VarId, VarId) {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 16);
        let a = b.host_array("A", vec![var(n), var(n)]);
        let i = b.loop_var("i");
        let j = b.loop_var("j");
        let k = b.body(vec![]);
        (k, n, a, i, j)
    }

    #[test]
    fn affine_extraction() {
        let (k, n, _, i, j) = kernel();
        // 2*i + j*N + 3
        let e = ci(2).mul(var(i)).add(var(j).mul(var(n))).add(ci(3));
        let a = affine_of(&k, &e).unwrap();
        assert_eq!(a.constant, 3);
        assert_eq!(a.coeff(i), 2);
        assert_eq!(a.coeff(j), 16);
    }

    #[test]
    fn nonaffine_rejected() {
        let (k, _, arr, i, j) = kernel();
        assert!(affine_of(&k, &var(i).mul(var(j))).is_none());
        assert!(affine_of(&k, &var(i).min(var(j))).is_none());
        assert!(affine_of(&k, &ld(arr, vec![var(i), var(j)])).is_none());
    }

    #[test]
    fn flat_offset_row_major() {
        let (k, _, a, i, j) = kernel();
        // A[i][j] -> i*16 + j
        let f = flat_offset(&k, a, &[var(i), var(j)]).unwrap();
        assert_eq!(f.coeff(i), 16);
        assert_eq!(f.coeff(j), 1);
        // A[j][i] -> column-wise
        let f = flat_offset(&k, a, &[var(j), var(i)]).unwrap();
        assert_eq!(f.coeff(j), 16);
        assert_eq!(f.coeff(i), 1);
    }

    #[test]
    fn substitute() {
        let (k, _, a, i, j) = kernel();
        let f = flat_offset(&k, a, &[var(i), var(j)]).unwrap();
        // i := 2 (constant)
        let g = f.substitute(i, &Affine::constant(2));
        assert_eq!(g.constant, 32);
        assert_eq!(g.coeff(i), 0);
        assert_eq!(g.coeff(j), 1);
    }

    #[test]
    fn scale_and_cancel() {
        let a = Affine::var(3).scale(4);
        let b = a.sub(&Affine::var(3).scale(4));
        assert!(b.is_const());
        assert_eq!(b.constant, 0);
    }
}
