//! Kernel intermediate representation.
//!
//! Device kernels are expressed as structured loop nests over arrays — the
//! same abstraction level as the C kernels the paper's Clang/LLVM toolchain
//! consumes after OpenMP outlining. The IR is built with a Rust builder API
//! (see [`crate::workloads`]), pretty-printed to C-like source for the Fig 6
//! code-complexity analysis, transformed by [`crate::compiler::autodma`],
//! and lowered to accelerator machine code by [`crate::compiler::lower`].
//!
//! Scalar integer parameters are compile-time constants (polybench-style
//! static problem sizes), which the affine analyses and the post-increment
//! legality checks rely on, exactly as the paper's statically-sized
//! benchmarks do.

/// Address space of an array (§2.2.1): `Host` pointers are 64-bit and reach
/// main memory through the ext-address path or DMA; `Local` buffers live in
/// the cluster's TCDM (native 32-bit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Space {
    Host,
    Local,
}

/// Symbol table index.
pub type VarId = usize;

/// Symbol kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Sym {
    /// f32 array parameter in the host address space; `dims` are extents in
    /// elements (innermost last, row-major).
    HostArray { dims: Vec<Expr> },
    /// f32 buffer in L1 TCDM, allocated by `Stmt::LocalAlloc`; `dims` are
    /// compile-time-constant extents (row-major).
    LocalBuf { dims: Vec<Expr> },
    /// Compile-time-constant i32 parameter (static problem size).
    ConstParam { value: i32 },
    /// f32 scalar parameter (passed in an f-register).
    FloatParam,
    /// Loop induction variable (i32).
    LoopVar,
    /// Mutable i32 scalar introduced by `Let`.
    LetI32,
    /// Mutable f32 scalar introduced by `Let`.
    LetF32,
}

/// Binary operators (typed by context: ints for index math, floats for data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    ConstI(i32),
    ConstF(f32),
    Var(VarId),
    /// Multi-dimensional array load, `A[idx0][idx1]...`.
    Load(VarId, Vec<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn add(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Add, Box::new(self), Box::new(o))
    }
    pub fn sub(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Sub, Box::new(self), Box::new(o))
    }
    pub fn mul(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Mul, Box::new(self), Box::new(o))
    }
    pub fn div(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Div, Box::new(self), Box::new(o))
    }
    pub fn min(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Min, Box::new(self), Box::new(o))
    }
    pub fn max(self, o: Expr) -> Expr {
        Expr::Bin(BinOp::Max, Box::new(self), Box::new(o))
    }

    /// Does this expression (transitively) contain a `Min`/`Max`? Loop
    /// bounds derived from tile clamping are `Min`-shaped; the paper's
    /// compiler does not infer hardware loops for them (§3.4).
    pub fn has_minmax(&self) -> bool {
        match self {
            Expr::Bin(BinOp::Min | BinOp::Max, ..) => true,
            Expr::Bin(_, a, b) => a.has_minmax() || b.has_minmax(),
            Expr::Load(_, idx) => idx.iter().any(|e| e.has_minmax()),
            _ => false,
        }
    }

    /// Variables referenced.
    pub fn vars(&self, out: &mut Vec<VarId>) {
        match self {
            Expr::Var(v) => out.push(*v),
            Expr::Load(a, idx) => {
                out.push(*a);
                idx.iter().for_each(|e| e.vars(out));
            }
            Expr::Bin(_, a, b) => {
                a.vars(out);
                b.vars(out);
            }
            _ => {}
        }
    }
}

/// Parallelism annotation on a loop (OpenMP `distribute` / `for`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Par {
    /// Sequential.
    None,
    /// `#pragma omp for`: iterations distributed over the cores of a
    /// cluster (fork/join).
    Cores,
    /// `#pragma omp teams distribute`: iterations distributed over clusters.
    Teams,
}

/// DMA transfer kind (maps onto the HERO API, §2.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaKind {
    /// `hero_memcpy_*`: one contiguous run; a single merged burst train.
    Merged1D,
    /// `hero_memcpy2d_*`: `rows` runs of `row_elems`, one burst per row,
    /// executed by the DMA hardware from a single descriptor.
    Hw2D,
}

/// DMA direction in IR terms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    HostToLocal,
    LocalToHost,
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `for (var = lo; var < hi; var++) body` (step is always 1).
    For { var: VarId, lo: Expr, hi: Expr, par: Par, body: Vec<Stmt> },
    /// `dst[idx...] = value`.
    Store { dst: VarId, idx: Vec<Expr>, value: Expr },
    /// Introduce (and initialize) a mutable scalar.
    Let { var: VarId, value: Expr },
    /// Update a scalar.
    Assign { var: VarId, value: Expr },
    /// Allocate `elems` f32 in L1 (`hero_l1_malloc`). Sizes must be
    /// compile-time constants (static tiling).
    LocalAlloc { var: VarId, elems: Expr },
    /// Free all L1 buffers allocated so far (between sequential nests).
    LocalFreeAll,
    /// Asynchronous DMA between a host array and a local buffer.
    /// Offsets/strides are in f32 elements.
    Dma {
        dir: Dir,
        kind: DmaKind,
        host: VarId,
        host_off: Expr,
        local: VarId,
        local_off: Expr,
        rows: Expr,
        row_elems: Expr,
        host_stride: Expr,
        local_stride: Expr,
    },
    /// Wait for all outstanding DMA transfers (`hero_memcpy_wait`).
    DmaWaitAll,
}

/// A device kernel: the body of an OpenMP `target` region.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    /// Symbol table; params come first, in declaration order.
    pub syms: Vec<(String, Sym)>,
    /// Number of leading symbols that are parameters.
    pub n_params: usize,
    pub body: Vec<Stmt>,
}

impl Kernel {
    pub fn sym(&self, v: VarId) -> &Sym {
        &self.syms[v].1
    }

    pub fn sym_name(&self, v: VarId) -> &str {
        &self.syms[v].0
    }

    /// Value of a const parameter.
    pub fn const_of(&self, v: VarId) -> Option<i32> {
        match self.sym(v) {
            Sym::ConstParam { value } => Some(*value),
            _ => None,
        }
    }

    /// Evaluate a compile-time-constant expression (const params folded).
    pub fn eval_const(&self, e: &Expr) -> Option<i64> {
        match e {
            Expr::ConstI(c) => Some(*c as i64),
            Expr::Var(v) => self.const_of(*v).map(|c| c as i64),
            Expr::Bin(op, a, b) => {
                let (a, b) = (self.eval_const(a)?, self.eval_const(b)?);
                Some(match op {
                    BinOp::Add => a + b,
                    BinOp::Sub => a - b,
                    BinOp::Mul => a * b,
                    BinOp::Div => {
                        if b == 0 {
                            return None;
                        }
                        a / b
                    }
                    BinOp::Min => a.min(b),
                    BinOp::Max => a.max(b),
                })
            }
            _ => None,
        }
    }

    /// Row-major element strides of an array (innermost dim has stride 1).
    /// All dims must be const-evaluable.
    pub fn array_strides(&self, v: VarId) -> Option<Vec<i64>> {
        let dims = match self.sym(v) {
            Sym::HostArray { dims } | Sym::LocalBuf { dims } => dims,
            _ => return None,
        };
        let exts: Option<Vec<i64>> = dims.iter().map(|d| self.eval_const(d)).collect();
        let exts = exts?;
        let mut strides = vec![1i64; exts.len()];
        for d in (0..exts.len().saturating_sub(1)).rev() {
            strides[d] = strides[d + 1] * exts[d + 1];
        }
        Some(strides)
    }

    /// Total elements of an array.
    pub fn array_elems(&self, v: VarId) -> Option<i64> {
        let dims = match self.sym(v) {
            Sym::HostArray { dims } | Sym::LocalBuf { dims } => dims,
            _ => return None,
        };
        dims.iter().map(|d| self.eval_const(d)).product::<Option<i64>>()
    }
}

/// Builder for kernels.
pub struct KernelBuilder {
    k: Kernel,
}

impl KernelBuilder {
    pub fn new(name: &str) -> Self {
        KernelBuilder {
            k: Kernel { name: name.into(), syms: Vec::new(), n_params: 0, body: Vec::new() },
        }
    }

    fn add_sym(&mut self, name: &str, s: Sym) -> VarId {
        self.k.syms.push((name.into(), s));
        self.k.syms.len() - 1
    }

    /// Declare a host f32 array parameter with the given extents.
    pub fn host_array(&mut self, name: &str, dims: Vec<Expr>) -> VarId {
        let v = self.add_sym(name, Sym::HostArray { dims });
        self.k.n_params = self.k.syms.len();
        v
    }

    /// Declare a compile-time-constant i32 parameter.
    pub fn const_param(&mut self, name: &str, value: i32) -> VarId {
        let v = self.add_sym(name, Sym::ConstParam { value });
        self.k.n_params = self.k.syms.len();
        v
    }

    /// Declare an f32 scalar parameter.
    pub fn float_param(&mut self, name: &str) -> VarId {
        let v = self.add_sym(name, Sym::FloatParam);
        self.k.n_params = self.k.syms.len();
        v
    }

    /// Declare a loop variable (used with `Stmt::For`).
    pub fn loop_var(&mut self, name: &str) -> VarId {
        self.add_sym(name, Sym::LoopVar)
    }

    /// Declare a mutable i32 scalar.
    pub fn let_i32(&mut self, name: &str) -> VarId {
        self.add_sym(name, Sym::LetI32)
    }

    /// Declare a mutable f32 scalar.
    pub fn let_f32(&mut self, name: &str) -> VarId {
        self.add_sym(name, Sym::LetF32)
    }

    /// Declare an L1-local buffer with compile-time-constant extents.
    pub fn local_buf(&mut self, name: &str, dims: Vec<Expr>) -> VarId {
        self.add_sym(name, Sym::LocalBuf { dims })
    }

    pub fn body(mut self, body: Vec<Stmt>) -> Kernel {
        self.k.body = body;
        self.k
    }
}

/// Shorthand constructors.
pub fn ci(v: i32) -> Expr {
    Expr::ConstI(v)
}
pub fn cf(v: f32) -> Expr {
    Expr::ConstF(v)
}
pub fn var(v: VarId) -> Expr {
    Expr::Var(v)
}
pub fn ld(a: VarId, idx: Vec<Expr>) -> Expr {
    Expr::Load(a, idx)
}
/// Serial loop.
pub fn for_(var: VarId, lo: Expr, hi: Expr, body: Vec<Stmt>) -> Stmt {
    Stmt::For { var, lo, hi, par: Par::None, body }
}
/// Parallel (`omp for`) loop.
pub fn par_for(var: VarId, lo: Expr, hi: Expr, body: Vec<Stmt>) -> Stmt {
    Stmt::For { var, lo, hi, par: Par::Cores, body }
}
pub fn st(dst: VarId, idx: Vec<Expr>, value: Expr) -> Stmt {
    Stmt::Store { dst, idx, value }
}

// --- pretty printer (C-like; the Fig 6 complexity metrics run on this) ----

/// Render a kernel as C-like source.
pub fn pretty(k: &Kernel) -> String {
    let mut out = String::new();
    let params: Vec<String> = (0..k.n_params)
        .map(|v| match k.sym(v) {
            Sym::HostArray { .. } => format!("float *{}", k.sym_name(v)),
            Sym::ConstParam { .. } => format!("int {}", k.sym_name(v)),
            Sym::FloatParam => format!("float {}", k.sym_name(v)),
            _ => unreachable!("non-param in param range"),
        })
        .collect();
    out.push_str(&format!("void {}({}) {{\n", k.name, params.join(", ")));
    for s in &k.body {
        pretty_stmt(k, s, 1, &mut out);
    }
    out.push_str("}\n");
    out
}

fn ind(n: usize) -> String {
    "  ".repeat(n)
}

fn pretty_expr(k: &Kernel, e: &Expr) -> String {
    match e {
        Expr::ConstI(c) => format!("{c}"),
        Expr::ConstF(c) => format!("{c:?}f"),
        Expr::Var(v) => k.sym_name(*v).to_string(),
        Expr::Load(a, idx) => {
            let idx: Vec<String> =
                idx.iter().map(|e| format!("[{}]", pretty_expr(k, e))).collect();
            format!("{}{}", k.sym_name(*a), idx.join(""))
        }
        Expr::Bin(op, a, b) => {
            let (a, b) = (pretty_expr(k, a), pretty_expr(k, b));
            match op {
                BinOp::Add => format!("({a} + {b})"),
                BinOp::Sub => format!("({a} - {b})"),
                BinOp::Mul => format!("({a} * {b})"),
                BinOp::Div => format!("({a} / {b})"),
                BinOp::Min => format!("MIN({a}, {b})"),
                BinOp::Max => format!("MAX({a}, {b})"),
            }
        }
    }
}

fn pretty_stmt(k: &Kernel, s: &Stmt, d: usize, out: &mut String) {
    match s {
        Stmt::For { var, lo, hi, par, body } => {
            let pragma = match par {
                Par::None => String::new(),
                Par::Cores => format!("{}#pragma omp for\n", ind(d)),
                Par::Teams => format!("{}#pragma omp teams distribute\n", ind(d)),
            };
            out.push_str(&pragma);
            let v = k.sym_name(*var);
            out.push_str(&format!(
                "{}for (int {v} = {}; {v} < {}; {v}++) {{\n",
                ind(d),
                pretty_expr(k, lo),
                pretty_expr(k, hi)
            ));
            for s in body {
                pretty_stmt(k, s, d + 1, out);
            }
            out.push_str(&format!("{}}}\n", ind(d)));
        }
        Stmt::Store { dst, idx, value } => {
            let idx: Vec<String> =
                idx.iter().map(|e| format!("[{}]", pretty_expr(k, e))).collect();
            // Render accumulations as `+=` like the source programs do.
            if let Expr::Bin(BinOp::Add, a, b) = value {
                if **a == Expr::Load(*dst, idx_exprs(s)) {
                    out.push_str(&format!(
                        "{}{}{} += {};\n",
                        ind(d),
                        k.sym_name(*dst),
                        idx.join(""),
                        pretty_expr(k, b)
                    ));
                    return;
                }
            }
            out.push_str(&format!(
                "{}{}{} = {};\n",
                ind(d),
                k.sym_name(*dst),
                idx.join(""),
                pretty_expr(k, value)
            ));
        }
        Stmt::Let { var, value } => {
            let ty = if matches!(k.sym(*var), Sym::LetF32) { "float" } else { "int" };
            out.push_str(&format!(
                "{}{ty} {} = {};\n",
                ind(d),
                k.sym_name(*var),
                pretty_expr(k, value)
            ));
        }
        Stmt::Assign { var, value } => {
            out.push_str(&format!(
                "{}{} = {};\n",
                ind(d),
                k.sym_name(*var),
                pretty_expr(k, value)
            ));
        }
        Stmt::LocalAlloc { var, elems } => {
            out.push_str(&format!(
                "{}float *{} = hero_l1_malloc(sizeof(float) * {});\n",
                ind(d),
                k.sym_name(*var),
                pretty_expr(k, elems)
            ));
        }
        Stmt::Dma {
            dir, kind, host, host_off, local, local_off, rows, row_elems, host_stride, ..
        } => {
            let f = match (dir, kind) {
                (Dir::HostToLocal, DmaKind::Merged1D) => "hero_memcpy_host2dev_async",
                (Dir::LocalToHost, DmaKind::Merged1D) => "hero_memcpy_dev2host_async",
                (Dir::HostToLocal, DmaKind::Hw2D) => "hero_memcpy2d_host2dev_async",
                (Dir::LocalToHost, DmaKind::Hw2D) => "hero_memcpy2d_dev2host_async",
            };
            let args = match kind {
                DmaKind::Merged1D => format!(
                    "{} + {}, {} + {}, sizeof(float) * {}",
                    k.sym_name(*local),
                    pretty_expr(k, local_off),
                    k.sym_name(*host),
                    pretty_expr(k, host_off),
                    pretty_expr(k, row_elems)
                ),
                DmaKind::Hw2D => format!(
                    "{} + {}, {} + {}, sizeof(float) * {}, {}, {}",
                    k.sym_name(*local),
                    pretty_expr(k, local_off),
                    k.sym_name(*host),
                    pretty_expr(k, host_off),
                    pretty_expr(k, row_elems),
                    pretty_expr(k, rows),
                    pretty_expr(k, host_stride)
                ),
            };
            out.push_str(&format!("{}{f}({args});\n", ind(d)));
        }
        Stmt::DmaWaitAll => out.push_str(&format!("{}hero_memcpy_wait_all();\n", ind(d))),
        Stmt::LocalFreeAll => out.push_str(&format!("{}hero_l1_free_all();\n", ind(d))),
    }
}

fn idx_exprs(s: &Stmt) -> Vec<Expr> {
    match s {
        Stmt::Store { idx, .. } => idx.clone(),
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Kernel {
        // for i in 0..N: Y[i] = a * X[i]
        let mut b = KernelBuilder::new("saxpy0");
        let x = b.host_array("X", vec![ci(64)]);
        let y = b.host_array("Y", vec![ci(64)]);
        let n = b.const_param("N", 64);
        let a = b.float_param("a");
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            var(n),
            vec![st(y, vec![var(i)], var(a).mul(ld(x, vec![var(i)])))],
        )])
    }

    #[test]
    fn builder_and_pretty() {
        let k = tiny();
        let src = pretty(&k);
        assert!(src.contains("void saxpy0(float *X, float *Y, int N, float a)"));
        assert!(src.contains("#pragma omp for"));
        assert!(src.contains("for (int i = 0; i < N; i++)"));
        assert!(src.contains("Y[i] = (a * X[i]);"));
    }

    #[test]
    fn const_eval() {
        let k = tiny();
        let n = 2; // VarId of N
        assert_eq!(k.eval_const(&var(n)), Some(64));
        assert_eq!(k.eval_const(&var(n).mul(ci(4)).add(ci(1))), Some(257));
        assert_eq!(k.eval_const(&var(4)), None); // loop var
        assert_eq!(k.eval_const(&ci(100).min(var(n))), Some(64));
    }

    #[test]
    fn array_strides_row_major() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 8);
        let a = b.host_array("A", vec![var(n), var(n).mul(ci(2))]);
        let k = b.body(vec![]);
        assert_eq!(k.array_strides(a), Some(vec![16, 1]));
        assert_eq!(k.array_elems(a), Some(128));
    }

    #[test]
    fn minmax_detection() {
        let e = ci(3).min(ci(5)).add(ci(1));
        assert!(e.has_minmax());
        assert!(!ci(3).add(ci(5)).has_minmax());
    }

    #[test]
    fn accumulate_pretty_prints_plus_eq() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let c = b.host_array("C", vec![var(n)]);
        let i = b.loop_var("i");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![st(c, vec![var(i)], ld(c, vec![var(i)]).add(cf(1.0)))],
        )]);
        assert!(pretty(&k).contains("C[i] += 1.0f;"));
    }
}
