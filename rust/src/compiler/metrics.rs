//! Code-complexity metrics (Fig 6).
//!
//! The paper quantifies the cost of manual tiling with two CCCC metrics on
//! the accelerated part of each application: **lines of code** (without
//! comments) and **McCabe's cyclomatic complexity** (linearly independent
//! paths = decision points + 1). We compute both on the kernel IR's C-like
//! rendering: every statement is a line (loops add their header line), and
//! decision points are `for` loops plus `MIN`/`MAX` (which expand to C
//! ternaries, which CCCC counts).

use super::ir::{Expr, Kernel, Stmt};

/// Complexity metrics of one kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Complexity {
    /// Lines of code (without comments/braces-only lines).
    pub loc: u32,
    /// McCabe cyclomatic complexity.
    pub cyclomatic: u32,
}

fn expr_decisions(e: &Expr) -> u32 {
    match e {
        Expr::Bin(op, a, b) => {
            let own = matches!(op, super::ir::BinOp::Min | super::ir::BinOp::Max) as u32;
            own + expr_decisions(a) + expr_decisions(b)
        }
        Expr::Load(_, idx) => idx.iter().map(expr_decisions).sum(),
        _ => 0,
    }
}

fn stmt_metrics(s: &Stmt) -> (u32, u32) {
    match s {
        Stmt::For { lo, hi, body, .. } => {
            let (mut loc, mut dec) = (1, 1 + expr_decisions(lo) + expr_decisions(hi));
            for s in body {
                let (l, d) = stmt_metrics(s);
                loc += l;
                dec += d;
            }
            (loc, dec)
        }
        Stmt::Store { idx, value, .. } => {
            (1, idx.iter().map(expr_decisions).sum::<u32>() + expr_decisions(value))
        }
        Stmt::Let { value, .. } | Stmt::Assign { value, .. } => (1, expr_decisions(value)),
        Stmt::LocalAlloc { elems, .. } => (1, expr_decisions(elems)),
        Stmt::Dma { host_off, local_off, rows, row_elems, host_stride, local_stride, .. } => (
            1,
            [host_off, local_off, rows, row_elems, host_stride, local_stride]
                .iter()
                .map(|e| expr_decisions(e))
                .sum(),
        ),
        Stmt::DmaWaitAll | Stmt::LocalFreeAll => (1, 0),
    }
}

/// Compute Fig 6 metrics for a kernel.
pub fn complexity(k: &Kernel) -> Complexity {
    let mut loc = 1; // function signature line
    let mut dec = 0;
    for s in &k.body {
        let (l, d) = stmt_metrics(s);
        loc += l;
        dec += d;
    }
    Complexity { loc, cyclomatic: dec + 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;

    #[test]
    fn simple_nest() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let a = b.host_array("A", vec![var(n), var(n)]);
        let i = b.loop_var("i");
        let j = b.loop_var("j");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![for_(j, ci(0), var(n), vec![st(a, vec![var(i), var(j)], cf(0.0))])],
        )]);
        let c = complexity(&k);
        // signature + 2 for-lines + 1 store
        assert_eq!(c.loc, 4);
        // 2 loops + 1
        assert_eq!(c.cyclomatic, 3);
    }

    #[test]
    fn min_counts_as_decision() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let len = b.let_i32("len");
        let i = b.loop_var("i");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![Stmt::Let { var: len, value: ci(8).min(var(n).sub(var(i))) }],
        )]);
        let c = complexity(&k);
        assert_eq!(c.cyclomatic, 3); // for + MIN + 1
        assert_eq!(c.loc, 3);
    }

    #[test]
    fn dma_statements_count_as_lines() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let a = b.host_array("A", vec![var(n)]);
        let l = b.local_buf("la", vec![var(n)]);
        let k = b.body(vec![
            Stmt::LocalAlloc { var: l, elems: var(n) },
            Stmt::Dma {
                dir: Dir::HostToLocal,
                kind: DmaKind::Merged1D,
                host: a,
                host_off: ci(0),
                local: l,
                local_off: ci(0),
                rows: ci(1),
                row_elems: var(n),
                host_stride: ci(0),
                local_stride: ci(0),
            },
            Stmt::DmaWaitAll,
        ]);
        assert_eq!(complexity(&k).loc, 4);
        assert_eq!(complexity(&k).cyclomatic, 1);
    }
}
