//! Code-complexity metrics (Fig 6).
//!
//! The paper quantifies the cost of manual tiling with two CCCC metrics on
//! the accelerated part of each application: **lines of code** (without
//! comments) and **McCabe's cyclomatic complexity** (linearly independent
//! paths = decision points + 1). We compute both on the kernel IR's C-like
//! rendering: every statement is a line (loops add their header line), and
//! decision points are `for` loops plus `MIN`/`MAX` (which expand to C
//! ternaries, which CCCC counts).
//!
//! This module also hosts the static cycle predictor ([`predict_cycles`]):
//! a walk over the same IR with per-access remote/local/DMA costs. Loop
//! bounds it cannot fold to a constant (anything but literals, `const`
//! params and arithmetic over them — e.g. a `let`-bound scalar) fall back
//! to [`PredictOpts::default_trips`], which is exactly the blind spot the
//! scheduler's online refinement ([`crate::sched::learn`]) closes by
//! blending measured device cycles into the prediction per kernel key.

use super::ir::{Expr, Kernel, Stmt};

/// Complexity metrics of one kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Complexity {
    /// Lines of code (without comments/braces-only lines).
    pub loc: u32,
    /// McCabe cyclomatic complexity.
    pub cyclomatic: u32,
}

fn expr_decisions(e: &Expr) -> u32 {
    match e {
        Expr::Bin(op, a, b) => {
            let own = matches!(op, super::ir::BinOp::Min | super::ir::BinOp::Max) as u32;
            own + expr_decisions(a) + expr_decisions(b)
        }
        Expr::Load(_, idx) => idx.iter().map(expr_decisions).sum(),
        _ => 0,
    }
}

fn stmt_metrics(s: &Stmt) -> (u32, u32) {
    match s {
        Stmt::For { lo, hi, body, .. } => {
            let (mut loc, mut dec) = (1, 1 + expr_decisions(lo) + expr_decisions(hi));
            for s in body {
                let (l, d) = stmt_metrics(s);
                loc += l;
                dec += d;
            }
            (loc, dec)
        }
        Stmt::Store { idx, value, .. } => {
            (1, idx.iter().map(expr_decisions).sum::<u32>() + expr_decisions(value))
        }
        Stmt::Let { value, .. } | Stmt::Assign { value, .. } => (1, expr_decisions(value)),
        Stmt::LocalAlloc { elems, .. } => (1, expr_decisions(elems)),
        Stmt::Dma { host_off, local_off, rows, row_elems, host_stride, local_stride, .. } => (
            1,
            [host_off, local_off, rows, row_elems, host_stride, local_stride]
                .iter()
                .map(|e| expr_decisions(e))
                .sum(),
        ),
        Stmt::DmaWaitAll | Stmt::LocalFreeAll => (1, 0),
    }
}

/// Options for the static cycle predictor.
#[derive(Debug, Clone, Copy)]
pub struct PredictOpts {
    /// Trip count assumed for loops whose bounds do not fold to constants
    /// (typically the job's problem size N).
    pub default_trips: u64,
    /// Ways a `Par::Cores`/`Par::Teams` loop is split across (thread count);
    /// its trip count is divided by this.
    pub par_ways: u64,
}

impl Default for PredictOpts {
    fn default() -> Self {
        PredictOpts { default_trips: 16, par_ways: 1 }
    }
}

/// Rough per-access costs for the predictor, mirroring the simulator's cost
/// model at the order-of-magnitude level: host-array accesses go over the
/// narrow NoC (§2.3: ext-CSR + NoC + DRAM, tens of cycles), local accesses
/// are single-cycle TCDM hits.
const REMOTE_LOAD_COST: u64 = 30;
const REMOTE_STORE_COST: u64 = 5;
const LOCAL_ACCESS_COST: u64 = 1;
const DMA_SETUP_COST: u64 = 30;
const DMA_WAIT_COST: u64 = 60;

fn expr_predict(k: &Kernel, e: &Expr) -> u64 {
    match e {
        Expr::Bin(_, a, b) => 1 + expr_predict(k, a) + expr_predict(k, b),
        Expr::Load(v, idx) => {
            let access = match k.sym(*v) {
                super::ir::Sym::HostArray { .. } => REMOTE_LOAD_COST,
                _ => LOCAL_ACCESS_COST,
            };
            access + idx.iter().map(|i| expr_predict(k, i)).sum::<u64>()
        }
        _ => 0,
    }
}

fn stmt_predict(k: &Kernel, s: &Stmt, opts: &PredictOpts) -> u64 {
    match s {
        Stmt::For { lo, hi, par, body, .. } => {
            let mut trips = match (k.eval_const(lo), k.eval_const(hi)) {
                (Some(l), Some(h)) => (h - l).max(0) as u64,
                _ => opts.default_trips,
            };
            if !matches!(par, super::ir::Par::None) {
                trips = trips.div_ceil(opts.par_ways.max(1));
            }
            let body_cost: u64 = body.iter().map(|s| stmt_predict(k, s, opts)).sum();
            2 + trips * (1 + body_cost)
        }
        Stmt::Store { dst, idx, value } => {
            let access = match k.sym(*dst) {
                super::ir::Sym::HostArray { .. } => REMOTE_STORE_COST,
                _ => LOCAL_ACCESS_COST,
            };
            access
                + idx.iter().map(|i| expr_predict(k, i)).sum::<u64>()
                + expr_predict(k, value)
        }
        Stmt::Let { value, .. } | Stmt::Assign { value, .. } => 1 + expr_predict(k, value),
        Stmt::LocalAlloc { .. } | Stmt::LocalFreeAll => 10,
        Stmt::Dma { rows, row_elems, .. } => {
            // Setup + a bandwidth term when the extent folds to a constant.
            let elems = match (k.eval_const(rows), k.eval_const(row_elems)) {
                (Some(r), Some(e)) => (r.max(0) as u64) * (e.max(0) as u64),
                _ => opts.default_trips * opts.default_trips,
            };
            DMA_SETUP_COST + elems / 2
        }
        Stmt::DmaWaitAll => DMA_WAIT_COST,
    }
}

/// Statically predict the device cycles of one kernel execution.
///
/// This is the cost model behind the scheduler's shortest-predicted-first
/// policy (`sched::policy`): a recursive walk of the IR that multiplies
/// const-folded trip counts through loop nests, divides parallel loops by
/// the thread count, and charges address-space-aware access costs (remote
/// host-array accesses are ~30x a TCDM hit, as in §2.3). It is intentionally
/// cheap and deterministic — an *ordering* heuristic, not a simulator.
pub fn predict_cycles(k: &Kernel, opts: &PredictOpts) -> u64 {
    100 + k.body.iter().map(|s| stmt_predict(k, s, opts)).sum::<u64>()
}

/// Tighter trip-count bound for the overlap-aware predictor: a `Min`-shaped
/// bound takes whichever side folds to a constant (tile-clamped extents and
/// the 0/1 pipeline guards of `autodma` are `Min`-shaped by construction).
fn const_upper(k: &Kernel, e: &Expr) -> Option<i64> {
    if let Some(c) = k.eval_const(e) {
        return Some(c);
    }
    if let Expr::Bin(super::ir::BinOp::Min, a, b) = e {
        return match (const_upper(k, a), const_upper(k, b)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (x, None) => x,
            (None, y) => y,
        };
    }
    None
}

fn stmt_predict_overlap(k: &Kernel, s: &Stmt, opts: &PredictOpts, outstanding: &mut u64) -> u64 {
    match s {
        Stmt::For { lo, hi, par, body, .. } => {
            let mut trips = match (k.eval_const(lo), const_upper(k, hi)) {
                (Some(l), Some(h)) => (h - l).max(0) as u64,
                _ => opts.default_trips,
            };
            if !matches!(par, super::ir::Par::None) {
                trips = trips.div_ceil(opts.par_ways.max(1));
            }
            if trips == 0 {
                return 2;
            }
            // First trip against the current in-flight state, then one
            // steady-state trip whose cost the remaining trips repeat.
            let first: u64 =
                body.iter().map(|s| stmt_predict_overlap(k, s, opts, outstanding)).sum();
            if trips == 1 {
                return 3 + first;
            }
            let steady: u64 =
                body.iter().map(|s| stmt_predict_overlap(k, s, opts, outstanding)).sum();
            2 + (1 + first) + (trips - 1) * (1 + steady)
        }
        Stmt::Dma { rows, row_elems, .. } => {
            let elems = match (k.eval_const(rows), k.eval_const(row_elems)) {
                (Some(r), Some(e)) => (r.max(0) as u64) * (e.max(0) as u64),
                _ => opts.default_trips * opts.default_trips,
            };
            *outstanding += elems / 2;
            DMA_SETUP_COST
        }
        Stmt::DmaWaitAll => {
            let c = DMA_WAIT_COST + *outstanding;
            *outstanding = 0;
            c
        }
        other => {
            let c = stmt_predict(k, other, opts);
            *outstanding = outstanding.saturating_sub(c);
            c
        }
    }
}

/// Overlap-aware variant of [`predict_cycles`], the scoring model of the
/// AutoDMA autotuner ([`crate::compiler::autotune`]): issuing a DMA charges
/// only its descriptor setup, the transfer's bandwidth term rides along as
/// in-flight work that subsequent compute drains cycle-for-cycle, and
/// `DmaWaitAll` pays whatever is left — so a software-pipelined kernel that
/// hides its transfers scores below the stop-and-go recipe. Loop bounds
/// additionally fold through `Min` (tile clamps and the pipeline's 0/1
/// guards), so candidates with different tile sides are scored by their
/// actual descriptor counts. Deliberately a *separate* entry point:
/// [`predict_cycles`] feeds the scheduler's SJF ordering, whose event
/// sequences must not move when tuning is off.
pub fn predict_cycles_overlap(k: &Kernel, opts: &PredictOpts) -> u64 {
    let mut outstanding = 0u64;
    100 + k.body.iter().map(|s| stmt_predict_overlap(k, s, opts, &mut outstanding)).sum::<u64>()
}

/// Compute Fig 6 metrics for a kernel.
pub fn complexity(k: &Kernel) -> Complexity {
    let mut loc = 1; // function signature line
    let mut dec = 0;
    for s in &k.body {
        let (l, d) = stmt_metrics(s);
        loc += l;
        dec += d;
    }
    Complexity { loc, cyclomatic: dec + 1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;

    #[test]
    fn simple_nest() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let a = b.host_array("A", vec![var(n), var(n)]);
        let i = b.loop_var("i");
        let j = b.loop_var("j");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![for_(j, ci(0), var(n), vec![st(a, vec![var(i), var(j)], cf(0.0))])],
        )]);
        let c = complexity(&k);
        // signature + 2 for-lines + 1 store
        assert_eq!(c.loc, 4);
        // 2 loops + 1
        assert_eq!(c.cyclomatic, 3);
    }

    #[test]
    fn min_counts_as_decision() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let len = b.let_i32("len");
        let i = b.loop_var("i");
        let k = b.body(vec![for_(
            i,
            ci(0),
            var(n),
            vec![Stmt::Let { var: len, value: ci(8).min(var(n).sub(var(i))) }],
        )]);
        let c = complexity(&k);
        assert_eq!(c.cyclomatic, 3); // for + MIN + 1
        assert_eq!(c.loc, 3);
    }

    #[test]
    fn predictor_scales_with_problem_size() {
        let w12 = crate::workloads::gemm::build(12);
        let w24 = crate::workloads::gemm::build(24);
        let opts = PredictOpts { default_trips: 12, par_ways: 8 };
        let opts24 = PredictOpts { default_trips: 24, par_ways: 8 };
        let p12 = predict_cycles(&w12.handwritten, &opts);
        let p24 = predict_cycles(&w24.handwritten, &opts24);
        // gemm is O(N^3): doubling N must predict much more than 2x.
        assert!(p24 > 4 * p12, "p24 {p24} vs p12 {p12}");
    }

    #[test]
    fn predictor_charges_remote_accesses() {
        // The unmodified (external-memory) form must predict slower than the
        // handwritten (SPM-tiled) form of the same problem.
        let w = crate::workloads::gemm::build(16);
        let opts = PredictOpts { default_trips: 16, par_ways: 8 };
        let unm = predict_cycles(&w.unmodified, &opts);
        let hand = predict_cycles(&w.handwritten, &opts);
        assert!(unm > 2 * hand, "unmodified {unm} vs handwritten {hand}");
    }

    #[test]
    fn predictor_parallelism_reduces_prediction() {
        let w = crate::workloads::gemm::build(16);
        let p1 = predict_cycles(&w.handwritten, &PredictOpts { default_trips: 16, par_ways: 1 });
        let p8 = predict_cycles(&w.handwritten, &PredictOpts { default_trips: 16, par_ways: 8 });
        assert!(p1 > p8, "p1 {p1} vs p8 {p8}");
    }

    #[test]
    fn dma_statements_count_as_lines() {
        let mut b = KernelBuilder::new("t");
        let n = b.const_param("N", 4);
        let a = b.host_array("A", vec![var(n)]);
        let l = b.local_buf("la", vec![var(n)]);
        let k = b.body(vec![
            Stmt::LocalAlloc { var: l, elems: var(n) },
            Stmt::Dma {
                dir: Dir::HostToLocal,
                kind: DmaKind::Merged1D,
                host: a,
                host_off: ci(0),
                local: l,
                local_off: ci(0),
                rows: ci(1),
                row_elems: var(n),
                host_stride: ci(0),
                local_stride: ci(0),
            },
            Stmt::DmaWaitAll,
        ]);
        assert_eq!(complexity(&k).loc, 4);
        assert_eq!(complexity(&k).cyclomatic, 1);
    }
}
