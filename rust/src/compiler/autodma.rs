//! AutoDMA: automatic tiling and DMA inference (§2.2.2, §3.2).
//!
//! AutoDMA transforms an *unmodified* OpenMP kernel into a tiled kernel that
//! stages data through the L1 SPM with DMA transfers — the paper's answer to
//! "how to relieve the programmer of the burden of specializing an algorithm
//! to the memory hierarchy of the accelerator". It derives from HePREM:
//! kernels become *load / execute / store* phases per tile.
//!
//! The model reproduces the paper's compiler behaviour including its
//! documented limitations:
//!
//! * **Tiling**: loops are tiled in program order along the *perfect prefix*
//!   of the nest (loops whose body is exactly one inner loop); the tile side
//!   starts from the paper's `S = floor((L/N)^(1/D))` and is halved until
//!   the footprint fits. Loop reordering is *not* performed (§3.2 footnote:
//!   polyhedral tools could; AutoDMA does not).
//! * **Region formation**: for every access group (same array, same linear
//!   coefficients) the staged region is a rows×len box. The *len*
//!   (contiguous) direction is the deepest contributing loop variable — and
//!   only if the access is unit-stride in it. Because of array-to-pointer
//!   decay the compiler cannot prove that consecutive rows are adjacent, so
//!   rows are transferred with **one DMA call per row** (the ~15 % gap to
//!   handwritten code, which merges rows into single bursts).
//! * **Column-wise accesses** (non-unit stride along the deepest
//!   contributing loop) degrade to **blocking single-word transfers** — "the
//!   DMA engine in this case is used to transfer individual words" — which
//!   is why covar and atax see only marginal gains (§3.2).

use super::analyze::flat_offset;
use super::ir::*;
use anyhow::{bail, Result};

/// AutoDMA options.
#[derive(Debug, Clone)]
pub struct AutoDmaOpts {
    /// L1 words available for user data (`hero_l1_capacity`), e.g. 28 Ki.
    pub l1_words: i64,
    /// Starting tile side for the halve-until-fit descent (`None` = the
    /// paper's `S = floor((L/N)^(1/D))` default). An infeasible override is
    /// halved until the footprint fits, so any requested side degrades
    /// deterministically. Only consulted when the nest needs tiling at all.
    pub tile_side: Option<i64>,
    /// Double-buffer the innermost tiled loop: ping-pong staging halves so
    /// the next tile's loads overlap the current tile's compute (§2.2.2).
    /// Doubles the staged footprint (the fit check uses half the budget)
    /// and is skipped — reported as `false` — when no loop ends up tiled or
    /// when a written group's tile regions could overlap across pipeline
    /// steps (the transform is applied only when it provably preserves the
    /// default recipe's values bit-for-bit).
    pub double_buffer: bool,
}

impl AutoDmaOpts {
    pub fn for_config(cfg: &crate::config::HeroConfig) -> Self {
        AutoDmaOpts {
            l1_words: cfg.l1_user_words() as i64,
            tile_side: None,
            double_buffer: false,
        }
    }
}

/// What AutoDMA did, for reporting and tests.
#[derive(Debug, Clone, Default)]
pub struct AutoDmaReport {
    /// Nests transformed.
    pub nests: usize,
    /// Tile side chosen per nest (None = whole footprint fit untiled).
    pub tile_sides: Vec<Option<i64>>,
    /// Array groups staged with row-wise (per-row DMA call) transfers.
    pub row_wise: Vec<String>,
    /// Array groups staged as one contiguous run.
    pub run_wise: Vec<String>,
    /// Array groups degraded to word-wise transfers.
    pub word_wise: Vec<String>,
    /// Column-wise access groups the pass declined to stage (their accesses
    /// stay in the host address space) — the covar/atax pathology of §3.2.
    pub remote: Vec<String>,
    /// Whether each nest was double-buffered (parallel to `tile_sides`).
    pub double_buffered: Vec<bool>,
    /// Nests left untouched (non-affine or otherwise unanalyzable).
    pub declined: usize,
}

/// One analyzed loop: nest-prefix loops may be tiled; deeper loops never.
#[derive(Debug, Clone)]
struct LoopInfo {
    var: VarId,
    extent: i64,
    par: Par,
    /// In the tileable perfect prefix?
    #[allow(dead_code)]
    tileable: bool,
    /// Tile side (== extent when untiled).
    tile: i64,
    /// Tile loop variable (when actually tiled).
    tvar: Option<VarId>,
    /// Point loop variable (== var when untiled).
    pvar: VarId,
}

impl LoopInfo {
    fn tiled(&self) -> bool {
        self.tvar.is_some()
    }
}

/// An access group.
#[derive(Debug)]
struct Group {
    array: VarId,
    /// Coefficient per loop (parallel to the `loops` list).
    coeffs: Vec<i64>,
    /// Constant offsets of member accesses (conv2d taps).
    consts: Vec<i64>,
    read: bool,
    written: bool,
    local: VarId,
    local_dims: Vec<i64>,
    /// (row bias, len bias) per member const, parallel to `consts`.
    biases: Vec<(i64, i64)>,
    /// Indices into `loops`; -1 = none.
    row_var: i32,
    len_var: i32,
    word_wise: bool,
    /// Left in the host address space (not staged).
    remote: bool,
    row_stride: i64,
    base_const: i64,
}

/// Transform a kernel; returns the tiled kernel and a report.
pub fn transform(k: &Kernel, opts: &AutoDmaOpts) -> Result<(Kernel, AutoDmaReport)> {
    let mut out = k.clone();
    out.name = format!("{}_autodma", k.name);
    let mut report = AutoDmaReport::default();
    let body = std::mem::take(&mut out.body);
    let mut new_body = Vec::new();
    let mut staged_any = false;
    for s in body {
        match s {
            Stmt::For { .. } => {
                if staged_any {
                    // Sequential nests reuse the L1 heap.
                    new_body.push(Stmt::LocalFreeAll);
                }
                match transform_nest(&mut out, &s, opts, &mut report) {
                    Ok(stmts) => {
                        staged_any = true;
                        new_body.extend(stmts);
                    }
                    Err(_) => {
                        report.declined += 1;
                        if staged_any {
                            new_body.pop(); // drop the free-all
                        }
                        new_body.push(s);
                    }
                }
            }
            other => new_body.push(other),
        }
    }
    out.body = new_body;
    Ok(out_with_report(out, report))
}

fn out_with_report(k: Kernel, r: AutoDmaReport) -> (Kernel, AutoDmaReport) {
    (k, r)
}

fn transform_nest(
    k: &mut Kernel,
    nest: &Stmt,
    opts: &AutoDmaOpts,
    report: &mut AutoDmaReport,
) -> Result<Vec<Stmt>> {
    // 1. Collect the perfect-prefix chain and the remaining body.
    let mut loops: Vec<LoopInfo> = Vec::new();
    let mut cur = nest;
    let inner_body: Vec<Stmt>;
    loop {
        let Stmt::For { var, lo, hi, par, body } = cur else { unreachable!() };
        if k.eval_const(lo) != Some(0) {
            bail!("nest loop lower bound must be 0");
        }
        let Some(extent) = k.eval_const(hi) else { bail!("non-constant extent") };
        loops.push(LoopInfo {
            var: *var,
            extent,
            par: *par,
            tileable: true,
            tile: extent,
            tvar: None,
            pvar: *var,
        });
        if body.len() == 1 {
            if let Stmt::For { .. } = &body[0] {
                cur = &body[0];
                continue;
            }
        }
        inner_body = body.clone();
        break;
    }
    let prefix_len = loops.len();
    // Deeper loops (inside the imperfect body) are analyzable but untileable.
    collect_deep_loops(k, &inner_body, &mut loops)?;

    // 2. Group host-array accesses.
    let mut groups = collect_groups(k, &inner_body, &loops)?;
    if groups.is_empty() {
        bail!("no host array accesses");
    }

    // 3. Tiling decision.
    let budget = opts.l1_words;
    let n_arrays = {
        let mut arrs: Vec<VarId> = groups.iter().map(|g| g.array).collect();
        arrs.sort_unstable();
        arrs.dedup();
        arrs.len() as i64
    };
    let dims = groups
        .iter()
        .map(|g| match k.sym(g.array) {
            Sym::HostArray { dims } => dims.len() as u32,
            _ => 1,
        })
        .max()
        .unwrap_or(1);
    let mut tile_side: Option<i64> = None;
    if footprint(&groups, &loops) > budget {
        // The nest does not fit as-is. Column-wise access groups whose only
        // unit-stride direction is the *work-distribution* (parallel) loop
        // are the pass's documented weakness (§3.2): tiles along that
        // dimension are partitioned across cores, so the per-core gather
        // degenerates to word-granular transfers ("the DMA engine is used
        // to transfer individual words"). The pass declines to stage such
        // groups; their accesses stay in the host address space — which is
        // why covar and atax end up only marginally faster than the
        // OpenMP baseline.
        for g in &mut groups {
            let contributing: Vec<usize> =
                (0..g.coeffs.len()).filter(|i| g.coeffs[*i] != 0).collect();
            let pathological = match contributing.as_slice() {
                [a] => g.coeffs[*a] != 1,
                [a, b] => {
                    g.coeffs[*b] != 1
                        && g.coeffs[*a] == 1
                        && loops[*a].par == Par::Cores
                }
                _ => false,
            };
            if pathological {
                g.remote = true;
                report.remote.push(k.sym_name(g.array).to_string());
            }
        }
        let staged: Vec<&Group> = groups.iter().filter(|g| !g.remote).collect();
        if footprint_of(&staged, &loops) > budget {
            // Ping-pong halves double every staged buffer, so a
            // double-buffered nest must fit its tiles in half the budget.
            let eff = if opts.double_buffer { (budget / 2).max(1) } else { budget };
            let mut s = match opts.tile_side {
                Some(side) => side,
                None => ((eff as f64 / n_arrays as f64).powf(1.0 / dims as f64)).floor() as i64,
            };
            s = s.max(4);
            loop {
                for l in loops.iter_mut().take(prefix_len) {
                    l.tile = s.min(l.extent);
                }
                let staged: Vec<&Group> = groups.iter().filter(|g| !g.remote).collect();
                if footprint_of(&staged, &loops) <= eff {
                    tile_side = Some(s);
                    break;
                }
                if s <= 4 {
                    bail!("cannot tile nest to fit L1");
                }
                s /= 2;
            }
            // Materialize tile/point vars for loops actually tiled.
            for l in loops.iter_mut().take(prefix_len) {
                if l.tile < l.extent {
                    let name = k.syms[l.var].0.clone();
                    k.syms.push((format!("t_{name}"), Sym::LoopVar));
                    l.tvar = Some(k.syms.len() - 1);
                    k.syms.push((format!("{name}p"), Sym::LoopVar));
                    l.pvar = k.syms.len() - 1;
                }
            }
        }
    }
    // Double-buffering pipelines the innermost tiled loop; it engages only
    // when that loop exists and the store pattern is provably step-disjoint.
    let pipe = loops[..prefix_len].iter().rposition(|l| l.tiled());
    let db = opts.double_buffer
        && pipe.map(|p| db_safe(&groups, p)).unwrap_or(false);
    report.nests += 1;
    report.tile_sides.push(tile_side);
    report.double_buffered.push(db);

    // 4. Local buffers + transfer shapes.
    let mut allocs: Vec<Stmt> = Vec::new();
    for g in &mut groups {
        if g.remote {
            continue;
        }
        decide_shape(k, g, &loops, report)?;
        let name = format!("l_{}{}", k.sym_name(g.array), k.syms.len());
        let mut dims: Vec<Expr> = g.local_dims.iter().map(|d| ci(*d as i32)).collect();
        if db {
            // Leading ping-pong dimension: half 0 / half 1.
            dims.insert(0, ci(2));
        }
        k.syms.push((name, Sym::LocalBuf { dims }));
        g.local = k.syms.len() - 1;
        let elems: i64 = g.local_dims.iter().product();
        if elems <= 0 {
            bail!("empty staging buffer");
        }
        let alloc_elems = if db { 2 * elems } else { elems };
        allocs.push(Stmt::LocalAlloc { var: g.local, elems: ci(alloc_elems as i32) });
    }

    // 5. Rewrite the execute phase.
    let rewritten = rewrite_block(k, &inner_body, &groups, &loops)?;

    // 6. Assemble load / execute / store phases.
    let mut loads: Vec<Stmt> = Vec::new();
    for g in &groups {
        if g.read && !g.remote {
            loads.extend(emit_transfers(k, g, &loops, Dir::HostToLocal));
        }
    }
    let compute = build_point_nest(&loops, 0, prefix_len, rewritten);
    let mut stores: Vec<Stmt> = Vec::new();
    for g in &groups {
        if g.written && !g.remote {
            stores.extend(emit_transfers(k, g, &loops, Dir::LocalToHost));
        }
    }

    // 7. Wrap in tile loops (innermost tiled loop closest to the phases).
    let mut body = if db {
        pipeline_innermost(k, &loops[pipe.unwrap()], &groups, loads, compute, stores)
    } else {
        let mut phase = loads;
        phase.push(Stmt::DmaWaitAll);
        phase.extend(compute);
        phase.extend(stores);
        phase.push(Stmt::DmaWaitAll);
        phase
    };
    for (li, l) in loops[..prefix_len].iter().enumerate().rev() {
        if db && Some(li) == pipe {
            continue; // replaced by the software-pipeline loop
        }
        if let Some(tv) = l.tvar {
            let n_tiles = (l.extent + l.tile - 1) / l.tile;
            body = vec![Stmt::For {
                var: tv,
                lo: ci(0),
                hi: ci(n_tiles as i32),
                par: Par::None,
                body,
            }];
        }
    }
    let mut out = allocs;
    out.extend(body);
    Ok(out)
}

/// Is double-buffering along pipeline loop `pipe` value-preserving?
///
/// The pipeline reorders `loads(t)` before `stores(t-1)` (that is the whole
/// point: the next tile's loads fly while the current tile computes), and
/// partial tiles store from alternating halves. Both are only safe when
/// every written staged group advances with the pipeline loop, covers
/// exactly its tile box (no tap spread), and shares its array with no other
/// staged group — then consecutive pipeline steps touch provably disjoint
/// host regions and the enqueue-order data movement matches the default
/// recipe bit-for-bit.
fn db_safe(groups: &[Group], pipe: usize) -> bool {
    let staged: Vec<&Group> = groups.iter().filter(|g| !g.remote).collect();
    for (i, g) in staged.iter().enumerate() {
        if !g.written {
            continue;
        }
        if g.coeffs[pipe] == 0 || spread_of(g) != 0 {
            return false;
        }
        if staged.iter().enumerate().any(|(j, h)| j != i && h.array == g.array) {
            return false;
        }
    }
    true
}

/// A 0/1-trip guard loop (the IR has no `if`; `hi` folds to 0 or 1).
fn guard(var: VarId, hi: Expr, body: Vec<Stmt>) -> Stmt {
    Stmt::For { var, lo: ci(0), hi, par: Par::None, body }
}

/// Software-pipeline the phases along the innermost tiled loop `l`:
///
/// ```text
/// int half = 0;
/// for (t = 0; t < n_tiles + 1; t++) {
///   if (t > 0)       dma_wait_all();          // loads(t-1) + stores(t-2)
///   if (t < n_tiles) loads(t)  -> buf[half];  // fly during compute(t-1)
///   if (t > 0)       compute(t-1), stores(t-1) from buf[1-half];
///   half = 1 - half;
/// }
/// dma_wait_all();                             // stores(n_tiles-1)
/// ```
///
/// The wait *precedes* the loads within a step, so a step's loads stay in
/// flight while the previous tile computes — waiting after issuing them
/// would serialize everything again (the engine retires in order).
fn pipeline_innermost(
    k: &mut Kernel,
    l: &LoopInfo,
    groups: &[Group],
    loads: Vec<Stmt>,
    compute: Vec<Stmt>,
    stores: Vec<Stmt>,
) -> Vec<Stmt> {
    let staged: std::collections::HashMap<VarId, i64> = groups
        .iter()
        .filter(|g| !g.remote)
        .map(|g| (g.local, g.local_dims.iter().product()))
        .collect();
    let tv = l.tvar.unwrap();
    let n_tiles = (l.extent + l.tile - 1) / l.tile;
    let t = fresh_loop_var(k, "db");
    let half = {
        let name = format!("half{}", k.syms.len());
        k.syms.push((name, Sym::LetI32));
        k.syms.len() - 1
    };
    let other = {
        let name = format!("ohalf{}", k.syms.len());
        k.syms.push((name, Sym::LetI32));
        k.syms.len() - 1
    };
    let gw = fresh_loop_var(k, "g");
    let gl = fresh_loop_var(k, "g");
    let gc = fresh_loop_var(k, "g");

    let loads_t: Vec<Stmt> = loads
        .iter()
        .map(|s| parity_stmt(&subst_stmt(s, tv, &var(t)), &var(half), &staged))
        .collect();
    let tm1 = var(t).sub(ci(1));
    let mut comp: Vec<Stmt> = vec![Stmt::Let { var: other, value: ci(1).sub(var(half)) }];
    for s in compute.iter().chain(stores.iter()) {
        comp.push(parity_stmt(&subst_stmt(s, tv, &tm1), &var(other), &staged));
    }

    let pipe_body = vec![
        guard(gw, var(t).min(ci(1)), vec![Stmt::DmaWaitAll]),
        guard(gl, ci(n_tiles as i32).sub(var(t)).min(ci(1)), loads_t),
        guard(gc, var(t).min(ci(1)), comp),
        Stmt::Assign { var: half, value: ci(1).sub(var(half)) },
    ];
    vec![
        Stmt::Let { var: half, value: ci(0) },
        Stmt::For {
            var: t,
            lo: ci(0),
            hi: ci((n_tiles + 1) as i32),
            par: Par::None,
            body: pipe_body,
        },
        Stmt::DmaWaitAll,
    ]
}

/// Substitute `Var(from)` with `to` throughout a statement.
fn subst_stmt(s: &Stmt, from: VarId, to: &Expr) -> Stmt {
    match s {
        Stmt::For { var, lo, hi, par, body } => Stmt::For {
            var: *var,
            lo: subst_expr(lo, from, to),
            hi: subst_expr(hi, from, to),
            par: *par,
            body: body.iter().map(|s| subst_stmt(s, from, to)).collect(),
        },
        Stmt::Store { dst, idx, value } => Stmt::Store {
            dst: *dst,
            idx: idx.iter().map(|e| subst_expr(e, from, to)).collect(),
            value: subst_expr(value, from, to),
        },
        Stmt::Let { var, value } => Stmt::Let { var: *var, value: subst_expr(value, from, to) },
        Stmt::Assign { var, value } => {
            Stmt::Assign { var: *var, value: subst_expr(value, from, to) }
        }
        Stmt::Dma {
            dir,
            kind,
            host,
            host_off,
            local,
            local_off,
            rows,
            row_elems,
            host_stride,
            local_stride,
        } => Stmt::Dma {
            dir: *dir,
            kind: *kind,
            host: *host,
            host_off: subst_expr(host_off, from, to),
            local: *local,
            local_off: subst_expr(local_off, from, to),
            rows: subst_expr(rows, from, to),
            row_elems: subst_expr(row_elems, from, to),
            host_stride: subst_expr(host_stride, from, to),
            local_stride: subst_expr(local_stride, from, to),
        },
        other => other.clone(),
    }
}

fn subst_expr(e: &Expr, from: VarId, to: &Expr) -> Expr {
    match e {
        Expr::Var(v) if *v == from => to.clone(),
        Expr::Load(a, idx) => {
            Expr::Load(*a, idx.iter().map(|i| subst_expr(i, from, to)).collect())
        }
        Expr::Bin(op, a, b) => Expr::Bin(
            *op,
            Box::new(subst_expr(a, from, to)),
            Box::new(subst_expr(b, from, to)),
        ),
        other => other.clone(),
    }
}

/// Retarget every staged-buffer access in a phase to ping-pong half
/// `parity`: compute-side accesses gain a leading index, DMA local offsets
/// gain `parity * elems` (DMA offsets are flat).
fn parity_stmt(
    s: &Stmt,
    parity: &Expr,
    staged: &std::collections::HashMap<VarId, i64>,
) -> Stmt {
    match s {
        Stmt::For { var, lo, hi, par, body } => Stmt::For {
            var: *var,
            lo: parity_expr(lo, parity, staged),
            hi: parity_expr(hi, parity, staged),
            par: *par,
            body: body.iter().map(|s| parity_stmt(s, parity, staged)).collect(),
        },
        Stmt::Store { dst, idx, value } => {
            let mut idx: Vec<Expr> =
                idx.iter().map(|e| parity_expr(e, parity, staged)).collect();
            if staged.contains_key(dst) {
                idx.insert(0, parity.clone());
            }
            Stmt::Store { dst: *dst, idx, value: parity_expr(value, parity, staged) }
        }
        Stmt::Let { var, value } => {
            Stmt::Let { var: *var, value: parity_expr(value, parity, staged) }
        }
        Stmt::Assign { var, value } => {
            Stmt::Assign { var: *var, value: parity_expr(value, parity, staged) }
        }
        Stmt::Dma {
            dir,
            kind,
            host,
            host_off,
            local,
            local_off,
            rows,
            row_elems,
            host_stride,
            local_stride,
        } => {
            let local_off = match staged.get(local) {
                Some(elems) => parity.clone().mul(ci(*elems as i32)).add(local_off.clone()),
                None => local_off.clone(),
            };
            Stmt::Dma {
                dir: *dir,
                kind: *kind,
                host: *host,
                host_off: host_off.clone(),
                local: *local,
                local_off,
                rows: rows.clone(),
                row_elems: row_elems.clone(),
                host_stride: host_stride.clone(),
                local_stride: local_stride.clone(),
            }
        }
        other => other.clone(),
    }
}

fn parity_expr(
    e: &Expr,
    parity: &Expr,
    staged: &std::collections::HashMap<VarId, i64>,
) -> Expr {
    match e {
        Expr::Load(a, idx) => {
            let mut idx: Vec<Expr> =
                idx.iter().map(|i| parity_expr(i, parity, staged)).collect();
            if staged.contains_key(a) {
                idx.insert(0, parity.clone());
            }
            Expr::Load(*a, idx)
        }
        Expr::Bin(op, a, b) => Expr::Bin(
            *op,
            Box::new(parity_expr(a, parity, staged)),
            Box::new(parity_expr(b, parity, staged)),
        ),
        other => other.clone(),
    }
}

fn collect_deep_loops(k: &Kernel, body: &[Stmt], out: &mut Vec<LoopInfo>) -> Result<()> {
    for s in body {
        if let Stmt::For { var, lo, hi, par, body } = s {
            if k.eval_const(lo) != Some(0) {
                bail!("inner loop lower bound must be 0");
            }
            let Some(e) = k.eval_const(hi) else { bail!("non-constant inner extent") };
            out.push(LoopInfo {
                var: *var,
                extent: e,
                par: *par,
                tileable: false,
                tile: e,
                tvar: None,
                pvar: *var,
            });
            collect_deep_loops(k, body, out)?;
        }
    }
    Ok(())
}

fn collect_groups(k: &Kernel, body: &[Stmt], loops: &[LoopInfo]) -> Result<Vec<Group>> {
    let mut groups: Vec<Group> = Vec::new();
    walk_accesses(k, body, &mut |k, arr, idx, is_store| {
        if !matches!(k.sym(arr), Sym::HostArray { .. }) {
            bail!("AutoDMA input must access host arrays only");
        }
        let Some(aff) = flat_offset(k, arr, idx) else { bail!("non-affine access") };
        let coeffs: Vec<i64> = loops.iter().map(|l| aff.coeff(l.var)).collect();
        let known: i64 = coeffs.iter().map(|c| c.abs()).sum();
        let total: i64 = aff.terms.iter().map(|(_, c)| c.abs()).sum();
        if known != total {
            bail!("access depends on non-loop variables");
        }
        if let Some(g) = groups.iter_mut().find(|g| g.array == arr && g.coeffs == coeffs) {
            if !g.consts.contains(&aff.constant) {
                g.consts.push(aff.constant);
            }
            g.read |= !is_store;
            g.written |= is_store;
        } else {
            groups.push(Group {
                array: arr,
                coeffs,
                consts: vec![aff.constant],
                read: !is_store,
                written: is_store,
                local: 0,
                local_dims: Vec::new(),
                biases: Vec::new(),
                row_var: -1,
                len_var: -1,
                word_wise: false,
                remote: false,
                row_stride: 0,
                base_const: 0,
            });
        }
        Ok(())
    })?;
    Ok(groups)
}

fn walk_accesses(
    k: &Kernel,
    body: &[Stmt],
    f: &mut impl FnMut(&Kernel, VarId, &[Expr], bool) -> Result<()>,
) -> Result<()> {
    fn expr(
        k: &Kernel,
        e: &Expr,
        f: &mut impl FnMut(&Kernel, VarId, &[Expr], bool) -> Result<()>,
    ) -> Result<()> {
        match e {
            Expr::Load(a, idx) => {
                f(k, *a, idx, false)?;
                for i in idx {
                    expr(k, i, f)?;
                }
                Ok(())
            }
            Expr::Bin(_, a, b) => {
                expr(k, a, f)?;
                expr(k, b, f)
            }
            _ => Ok(()),
        }
    }
    for s in body {
        match s {
            Stmt::For { body, .. } => walk_accesses(k, body, f)?,
            Stmt::Store { dst, idx, value } => {
                expr(k, value, f)?;
                for i in idx {
                    expr(k, i, f)?;
                }
                f(k, *dst, idx, true)?;
            }
            Stmt::Let { value, .. } | Stmt::Assign { value, .. } => expr(k, value, f)?,
            Stmt::Dma { .. }
            | Stmt::DmaWaitAll
            | Stmt::LocalAlloc { .. }
            | Stmt::LocalFreeAll => {
                bail!("AutoDMA input already contains DMA statements")
            }
        }
    }
    Ok(())
}

/// Footprint in words of all groups under the current tiling.
fn footprint(groups: &[Group], loops: &[LoopInfo]) -> i64 {
    footprint_of(&groups.iter().collect::<Vec<_>>(), loops)
}

fn footprint_of(groups: &[&Group], loops: &[LoopInfo]) -> i64 {
    groups
        .iter()
        .map(|g| {
            let mut words = 1i64;
            for (i, c) in g.coeffs.iter().enumerate() {
                if *c != 0 {
                    words *= loops[i].tile;
                }
            }
            let spread = g.consts.iter().max().unwrap() - g.consts.iter().min().unwrap();
            words + spread.max(0)
        })
        .sum()
}

/// Decide rows/len decomposition and local layout for a group.
fn decide_shape(
    k: &Kernel,
    g: &mut Group,
    loops: &[LoopInfo],
    report: &mut AutoDmaReport,
) -> Result<()> {
    let contributing: Vec<usize> =
        (0..g.coeffs.len()).filter(|i| g.coeffs[*i] != 0).collect();
    if contributing.len() > 2 {
        bail!("access contributes more than two dimensions");
    }
    g.base_const = *g.consts.iter().min().unwrap();
    let name = k.sym_name(g.array).to_string();
    match contributing.as_slice() {
        [] => {
            g.local_dims = vec![1];
        }
        [a] => {
            let unit = g.coeffs[*a] == 1;
            if unit {
                g.len_var = *a as i32;
                let spread = spread_of(g);
                g.local_dims = vec![loops[*a].tile + spread];
                report.run_wise.push(name);
            } else {
                g.row_var = *a as i32;
                g.row_stride = g.coeffs[*a];
                g.word_wise = true;
                g.local_dims = vec![loops[*a].tile];
                report.word_wise.push(name);
            }
        }
        [a, b] => {
            // `b` is deeper in the nest (loops are in nesting order). The
            // transfer's contiguous (len) direction is whichever var has
            // unit stride; rows go along the other. Column-major accesses
            // (unit stride on the shallow var) still stage row-by-row, but
            // with short rows and one descriptor each — the degradation the
            // paper attributes to its 15 % gap. Only accesses with *no*
            // unit-stride direction degrade to word-wise gathers.
            let (shallow, deep) = (*a, *b);
            if g.coeffs[deep] == 1 && g.coeffs[shallow] > 0 {
                g.row_var = shallow as i32;
                g.len_var = deep as i32;
                g.row_stride = g.coeffs[shallow];
                let (rspread, lspread) = decompose_spread(g, g.row_stride);
                g.word_wise = false;
                g.local_dims = vec![loops[shallow].tile + rspread, loops[deep].tile + lspread];
                report.row_wise.push(name);
            } else if g.coeffs[shallow] == 1 && g.coeffs[deep] > 0 {
                // Column-major: rows along the deep var.
                g.row_var = deep as i32;
                g.len_var = shallow as i32;
                g.row_stride = g.coeffs[deep];
                let (rspread, lspread) = decompose_spread(g, g.row_stride);
                g.word_wise = false;
                g.local_dims = vec![loops[deep].tile + rspread, loops[shallow].tile + lspread];
                report.row_wise.push(name);
            } else {
                g.row_var = shallow as i32;
                g.len_var = deep as i32;
                g.row_stride = g.coeffs[shallow];
                g.word_wise = true;
                g.local_dims = vec![loops[shallow].tile, loops[deep].tile];
                report.word_wise.push(name);
            }
        }
        _ => unreachable!(),
    }
    let rs = g.row_stride;
    g.biases = g
        .consts
        .iter()
        .map(|c| {
            let d = c - g.base_const;
            if rs > 0 {
                (d / rs, d % rs)
            } else {
                (0, d)
            }
        })
        .collect();
    Ok(())
}

fn spread_of(g: &Group) -> i64 {
    g.consts.iter().max().unwrap() - g.consts.iter().min().unwrap()
}

fn decompose_spread(g: &Group, row_stride: i64) -> (i64, i64) {
    let spread = spread_of(g);
    if row_stride > 0 {
        (spread / row_stride, spread % row_stride)
    } else {
        (0, spread)
    }
}

/// Point-range length of loop `vi`, `Min`-clamped when tiled.
fn extent_expr(loops: &[LoopInfo], vi: usize) -> Expr {
    let l = &loops[vi];
    if l.tiled() {
        ci(l.tile as i32).min(ci(l.extent as i32).sub(var(l.tvar.unwrap()).mul(ci(l.tile as i32))))
    } else {
        ci(l.extent as i32)
    }
}

/// Emit the load or store phase for one group.
fn emit_transfers(k: &mut Kernel, g: &Group, loops: &[LoopInfo], dir: Dir) -> Vec<Stmt> {
    // Host base offset: constant + tile-base contributions.
    let mut host_base = ci(g.base_const as i32);
    for (i, c) in g.coeffs.iter().enumerate() {
        if *c == 0 {
            continue;
        }
        if let Some(tv) = loops[i].tvar {
            host_base = host_base.add(var(tv).mul(ci((loops[i].tile * c) as i32)));
        }
    }
    match (g.word_wise, g.row_var, g.len_var) {
        (false, -1, -1) => vec![dma1d(g, dir, host_base, ci(0), ci(1))],
        (false, -1, lv) => {
            // One contiguous run.
            let len = extent_expr(loops, lv as usize).add_spread(spread_of(g));
            vec![dma1d(g, dir, host_base, ci(0), len)]
        }
        (false, rv, lv) => {
            // Row loop: one 1D DMA call (descriptor setup) per row — the
            // pass cannot merge rows after pointer decay (§3.2).
            let (rspread, lspread) = decompose_spread(g, g.row_stride);
            let rows = extent_expr(loops, rv as usize).add_spread(rspread);
            let len = extent_expr(loops, lv as usize).add_spread(lspread);
            let r = fresh_loop_var(k, "r");
            let local_row = ci(g.local_dims[1] as i32);
            vec![Stmt::For {
                var: r,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![dma1d(
                    g,
                    dir,
                    host_base.clone().add(var(r).mul(ci(g.row_stride as i32))),
                    var(r).mul(local_row),
                    len,
                )],
            }]
        }
        (true, rv, -1) => {
            // Single non-unit direction: one blocking word per iteration.
            let rows = extent_expr(loops, rv as usize);
            let a = fresh_loop_var(k, "w");
            vec![Stmt::For {
                var: a,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![
                    dma1d(
                        g,
                        dir,
                        host_base.clone().add(var(a).mul(ci(g.row_stride as i32))),
                        var(a),
                        ci(1),
                    ),
                ],
            }]
        }
        (true, rv, lv) => {
            // Word-wise box: blocking per-element transfers.
            let rows = extent_expr(loops, rv as usize);
            let lens = extent_expr(loops, lv as usize);
            let a = fresh_loop_var(k, "wa");
            let b = fresh_loop_var(k, "wb");
            let local_row = ci(g.local_dims.get(1).copied().unwrap_or(1) as i32);
            let len_coeff = g.coeffs[lv as usize];
            vec![Stmt::For {
                var: a,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![Stmt::For {
                    var: b,
                    lo: ci(0),
                    hi: lens,
                    par: Par::None,
                    body: vec![
                        dma1d(
                            g,
                            dir,
                            host_base
                                .clone()
                                .add(var(a).mul(ci(g.row_stride as i32)))
                                .add(var(b).mul(ci(len_coeff as i32))),
                            var(a).mul(local_row).add(var(b)),
                            ci(1),
                        ),
                    ],
                }],
            }]
        }
    }
}

fn dma1d(g: &Group, dir: Dir, host_off: Expr, local_off: Expr, elems: Expr) -> Stmt {
    Stmt::Dma {
        dir,
        kind: DmaKind::Merged1D,
        host: g.array,
        host_off,
        local: g.local,
        local_off,
        rows: ci(1),
        row_elems: elems,
        host_stride: ci(0),
        local_stride: ci(0),
    }
}

trait AddSpread {
    fn add_spread(self, s: i64) -> Expr;
}

impl AddSpread for Expr {
    fn add_spread(self, s: i64) -> Expr {
        if s == 0 {
            self
        } else {
            self.add(ci(s as i32))
        }
    }
}

fn fresh_loop_var(k: &mut Kernel, base: &str) -> VarId {
    let name = format!("{base}{}", k.syms.len());
    k.syms.push((name, Sym::LoopVar));
    k.syms.len() - 1
}

/// Rebuild the point nest over the (possibly tiled) prefix loops.
fn build_point_nest(
    loops: &[LoopInfo],
    d: usize,
    prefix_len: usize,
    inner: Vec<Stmt>,
) -> Vec<Stmt> {
    if d >= prefix_len {
        return inner;
    }
    let body = build_point_nest(loops, d + 1, prefix_len, inner);
    let l = &loops[d];
    vec![Stmt::For { var: l.pvar, lo: ci(0), hi: extent_expr(loops, d), par: l.par, body }]
}

/// Rewrite accesses to local buffers and loop vars to tile_base + point.
fn rewrite_block(
    k: &Kernel,
    body: &[Stmt],
    groups: &[Group],
    loops: &[LoopInfo],
) -> Result<Vec<Stmt>> {
    body.iter().map(|s| rewrite_stmt(k, s, groups, loops)).collect()
}

fn rewrite_stmt(k: &Kernel, s: &Stmt, groups: &[Group], loops: &[LoopInfo]) -> Result<Stmt> {
    Ok(match s {
        Stmt::For { var, lo, hi, par, body } => Stmt::For {
            var: *var,
            lo: rewrite_expr(k, lo, groups, loops)?,
            hi: rewrite_expr(k, hi, groups, loops)?,
            par: *par,
            body: rewrite_block(k, body, groups, loops)?,
        },
        Stmt::Store { dst, idx, value } => {
            let value = rewrite_expr(k, value, groups, loops)?;
            let (local, lidx) = rewrite_access(k, *dst, idx, groups, loops)?;
            Stmt::Store { dst: local, idx: lidx, value }
        }
        Stmt::Let { var, value } => {
            Stmt::Let { var: *var, value: rewrite_expr(k, value, groups, loops)? }
        }
        Stmt::Assign { var, value } => {
            Stmt::Assign { var: *var, value: rewrite_expr(k, value, groups, loops)? }
        }
        other => other.clone(),
    })
}

fn rewrite_expr(k: &Kernel, e: &Expr, groups: &[Group], loops: &[LoopInfo]) -> Result<Expr> {
    Ok(match e {
        Expr::Load(a, idx) => {
            let (local, lidx) = rewrite_access(k, *a, idx, groups, loops)?;
            Expr::Load(local, lidx)
        }
        Expr::Var(v) => {
            if let Some(l) = loops.iter().find(|l| l.var == *v) {
                if l.tiled() {
                    var(l.tvar.unwrap()).mul(ci(l.tile as i32)).add(var(l.pvar))
                } else {
                    e.clone()
                }
            } else {
                e.clone()
            }
        }
        Expr::Bin(op, a, b) => Expr::Bin(
            *op,
            Box::new(rewrite_expr(k, a, groups, loops)?),
            Box::new(rewrite_expr(k, b, groups, loops)?),
        ),
        _ => e.clone(),
    })
}

/// Rewrite one access to its local buffer.
fn rewrite_access(
    k: &Kernel,
    arr: VarId,
    idx: &[Expr],
    groups: &[Group],
    loops: &[LoopInfo],
) -> Result<(VarId, Vec<Expr>)> {
    let aff = flat_offset(k, arr, idx)
        .ok_or_else(|| anyhow::anyhow!("non-affine access survived grouping"))?;
    let coeffs: Vec<i64> = loops.iter().map(|l| aff.coeff(l.var)).collect();
    for g in groups {
        if g.array != arr || g.coeffs != coeffs || !g.consts.contains(&aff.constant) {
            continue;
        }
        if g.remote {
            // Left in the host address space: only substitute tiled loop
            // variables inside the subscripts.
            let lidx: Vec<Expr> = idx
                .iter()
                .map(|e| rewrite_expr(k, e, groups, loops))
                .collect::<Result<_>>()?;
            return Ok((arr, lidx));
        }
        let pos = g.consts.iter().position(|c| *c == aff.constant).unwrap();
        let (rbias, lbias) = g.biases[pos];
        let mut lidx: Vec<Expr> = Vec::new();
        if g.row_var >= 0 {
            let p = var(loops[g.row_var as usize].pvar);
            lidx.push(if rbias == 0 { p } else { p.add(ci(rbias as i32)) });
        }
        if g.len_var >= 0 {
            let p = var(loops[g.len_var as usize].pvar);
            lidx.push(if lbias == 0 { p } else { p.add(ci(lbias as i32)) });
        }
        if lidx.is_empty() {
            lidx.push(ci(0));
        }
        return Ok((g.local, lidx));
    }
    bail!("access to {} not covered by any group", k.sym_name(arr))
}
