//! AutoDMA: automatic tiling and DMA inference (§2.2.2, §3.2).
//!
//! AutoDMA transforms an *unmodified* OpenMP kernel into a tiled kernel that
//! stages data through the L1 SPM with DMA transfers — the paper's answer to
//! "how to relieve the programmer of the burden of specializing an algorithm
//! to the memory hierarchy of the accelerator". It derives from HePREM:
//! kernels become *load / execute / store* phases per tile.
//!
//! The model reproduces the paper's compiler behaviour including its
//! documented limitations:
//!
//! * **Tiling**: loops are tiled in program order along the *perfect prefix*
//!   of the nest (loops whose body is exactly one inner loop); the tile side
//!   starts from the paper's `S = floor((L/N)^(1/D))` and is halved until
//!   the footprint fits. Loop reordering is *not* performed (§3.2 footnote:
//!   polyhedral tools could; AutoDMA does not).
//! * **Region formation**: for every access group (same array, same linear
//!   coefficients) the staged region is a rows×len box. The *len*
//!   (contiguous) direction is the deepest contributing loop variable — and
//!   only if the access is unit-stride in it. Because of array-to-pointer
//!   decay the compiler cannot prove that consecutive rows are adjacent, so
//!   rows are transferred with **one DMA call per row** (the ~15 % gap to
//!   handwritten code, which merges rows into single bursts).
//! * **Column-wise accesses** (non-unit stride along the deepest
//!   contributing loop) degrade to **blocking single-word transfers** — "the
//!   DMA engine in this case is used to transfer individual words" — which
//!   is why covar and atax see only marginal gains (§3.2).

use super::analyze::flat_offset;
use super::ir::*;
use anyhow::{bail, Result};

/// AutoDMA options.
#[derive(Debug, Clone)]
pub struct AutoDmaOpts {
    /// L1 words available for user data (`hero_l1_capacity`), e.g. 28 Ki.
    pub l1_words: i64,
}

impl AutoDmaOpts {
    pub fn for_config(cfg: &crate::config::HeroConfig) -> Self {
        AutoDmaOpts { l1_words: cfg.l1_user_words() as i64 }
    }
}

/// What AutoDMA did, for reporting and tests.
#[derive(Debug, Clone, Default)]
pub struct AutoDmaReport {
    /// Nests transformed.
    pub nests: usize,
    /// Tile side chosen per nest (None = whole footprint fit untiled).
    pub tile_sides: Vec<Option<i64>>,
    /// Array groups staged with row-wise (per-row DMA call) transfers.
    pub row_wise: Vec<String>,
    /// Array groups staged as one contiguous run.
    pub run_wise: Vec<String>,
    /// Array groups degraded to word-wise transfers.
    pub word_wise: Vec<String>,
    /// Column-wise access groups the pass declined to stage (their accesses
    /// stay in the host address space) — the covar/atax pathology of §3.2.
    pub remote: Vec<String>,
    /// Nests left untouched (non-affine or otherwise unanalyzable).
    pub declined: usize,
}

/// One analyzed loop: nest-prefix loops may be tiled; deeper loops never.
#[derive(Debug, Clone)]
struct LoopInfo {
    var: VarId,
    extent: i64,
    par: Par,
    /// In the tileable perfect prefix?
    #[allow(dead_code)]
    tileable: bool,
    /// Tile side (== extent when untiled).
    tile: i64,
    /// Tile loop variable (when actually tiled).
    tvar: Option<VarId>,
    /// Point loop variable (== var when untiled).
    pvar: VarId,
}

impl LoopInfo {
    fn tiled(&self) -> bool {
        self.tvar.is_some()
    }
}

/// An access group.
#[derive(Debug)]
struct Group {
    array: VarId,
    /// Coefficient per loop (parallel to the `loops` list).
    coeffs: Vec<i64>,
    /// Constant offsets of member accesses (conv2d taps).
    consts: Vec<i64>,
    read: bool,
    written: bool,
    local: VarId,
    local_dims: Vec<i64>,
    /// (row bias, len bias) per member const, parallel to `consts`.
    biases: Vec<(i64, i64)>,
    /// Indices into `loops`; -1 = none.
    row_var: i32,
    len_var: i32,
    word_wise: bool,
    /// Left in the host address space (not staged).
    remote: bool,
    row_stride: i64,
    base_const: i64,
}

/// Transform a kernel; returns the tiled kernel and a report.
pub fn transform(k: &Kernel, opts: &AutoDmaOpts) -> Result<(Kernel, AutoDmaReport)> {
    let mut out = k.clone();
    out.name = format!("{}_autodma", k.name);
    let mut report = AutoDmaReport::default();
    let body = std::mem::take(&mut out.body);
    let mut new_body = Vec::new();
    let mut staged_any = false;
    for s in body {
        match s {
            Stmt::For { .. } => {
                if staged_any {
                    // Sequential nests reuse the L1 heap.
                    new_body.push(Stmt::LocalFreeAll);
                }
                match transform_nest(&mut out, &s, opts, &mut report) {
                    Ok(stmts) => {
                        staged_any = true;
                        new_body.extend(stmts);
                    }
                    Err(_) => {
                        report.declined += 1;
                        if staged_any {
                            new_body.pop(); // drop the free-all
                        }
                        new_body.push(s);
                    }
                }
            }
            other => new_body.push(other),
        }
    }
    out.body = new_body;
    Ok(out_with_report(out, report))
}

fn out_with_report(k: Kernel, r: AutoDmaReport) -> (Kernel, AutoDmaReport) {
    (k, r)
}

fn transform_nest(
    k: &mut Kernel,
    nest: &Stmt,
    opts: &AutoDmaOpts,
    report: &mut AutoDmaReport,
) -> Result<Vec<Stmt>> {
    // 1. Collect the perfect-prefix chain and the remaining body.
    let mut loops: Vec<LoopInfo> = Vec::new();
    let mut cur = nest;
    let inner_body: Vec<Stmt>;
    loop {
        let Stmt::For { var, lo, hi, par, body } = cur else { unreachable!() };
        if k.eval_const(lo) != Some(0) {
            bail!("nest loop lower bound must be 0");
        }
        let Some(extent) = k.eval_const(hi) else { bail!("non-constant extent") };
        loops.push(LoopInfo {
            var: *var,
            extent,
            par: *par,
            tileable: true,
            tile: extent,
            tvar: None,
            pvar: *var,
        });
        if body.len() == 1 {
            if let Stmt::For { .. } = &body[0] {
                cur = &body[0];
                continue;
            }
        }
        inner_body = body.clone();
        break;
    }
    let prefix_len = loops.len();
    // Deeper loops (inside the imperfect body) are analyzable but untileable.
    collect_deep_loops(k, &inner_body, &mut loops)?;

    // 2. Group host-array accesses.
    let mut groups = collect_groups(k, &inner_body, &loops)?;
    if groups.is_empty() {
        bail!("no host array accesses");
    }

    // 3. Tiling decision.
    let budget = opts.l1_words;
    let n_arrays = {
        let mut arrs: Vec<VarId> = groups.iter().map(|g| g.array).collect();
        arrs.sort_unstable();
        arrs.dedup();
        arrs.len() as i64
    };
    let dims = groups
        .iter()
        .map(|g| match k.sym(g.array) {
            Sym::HostArray { dims } => dims.len() as u32,
            _ => 1,
        })
        .max()
        .unwrap_or(1);
    let mut tile_side: Option<i64> = None;
    if footprint(&groups, &loops) > budget {
        // The nest does not fit as-is. Column-wise access groups whose only
        // unit-stride direction is the *work-distribution* (parallel) loop
        // are the pass's documented weakness (§3.2): tiles along that
        // dimension are partitioned across cores, so the per-core gather
        // degenerates to word-granular transfers ("the DMA engine is used
        // to transfer individual words"). The pass declines to stage such
        // groups; their accesses stay in the host address space — which is
        // why covar and atax end up only marginally faster than the
        // OpenMP baseline.
        for g in &mut groups {
            let contributing: Vec<usize> =
                (0..g.coeffs.len()).filter(|i| g.coeffs[*i] != 0).collect();
            let pathological = match contributing.as_slice() {
                [a] => g.coeffs[*a] != 1,
                [a, b] => {
                    g.coeffs[*b] != 1
                        && g.coeffs[*a] == 1
                        && loops[*a].par == Par::Cores
                }
                _ => false,
            };
            if pathological {
                g.remote = true;
                report.remote.push(k.sym_name(g.array).to_string());
            }
        }
        let staged: Vec<&Group> = groups.iter().filter(|g| !g.remote).collect();
        if footprint_of(&staged, &loops) > budget {
            let mut s =
                ((budget as f64 / n_arrays as f64).powf(1.0 / dims as f64)).floor() as i64;
            s = s.max(4);
            loop {
                for l in loops.iter_mut().take(prefix_len) {
                    l.tile = s.min(l.extent);
                }
                let staged: Vec<&Group> = groups.iter().filter(|g| !g.remote).collect();
                if footprint_of(&staged, &loops) <= budget {
                    tile_side = Some(s);
                    break;
                }
                if s <= 4 {
                    bail!("cannot tile nest to fit L1");
                }
                s /= 2;
            }
            // Materialize tile/point vars for loops actually tiled.
            for l in loops.iter_mut().take(prefix_len) {
                if l.tile < l.extent {
                    let name = k.syms[l.var].0.clone();
                    k.syms.push((format!("t_{name}"), Sym::LoopVar));
                    l.tvar = Some(k.syms.len() - 1);
                    k.syms.push((format!("{name}p"), Sym::LoopVar));
                    l.pvar = k.syms.len() - 1;
                }
            }
        }
    }
    report.nests += 1;
    report.tile_sides.push(tile_side);

    // 4. Local buffers + transfer shapes.
    let mut allocs: Vec<Stmt> = Vec::new();
    for g in &mut groups {
        if g.remote {
            continue;
        }
        decide_shape(k, g, &loops, report)?;
        let name = format!("l_{}{}", k.sym_name(g.array), k.syms.len());
        let dims: Vec<Expr> = g.local_dims.iter().map(|d| ci(*d as i32)).collect();
        k.syms.push((name, Sym::LocalBuf { dims }));
        g.local = k.syms.len() - 1;
        let elems: i64 = g.local_dims.iter().product();
        if elems <= 0 {
            bail!("empty staging buffer");
        }
        allocs.push(Stmt::LocalAlloc { var: g.local, elems: ci(elems as i32) });
    }

    // 5. Rewrite the execute phase.
    let rewritten = rewrite_block(k, &inner_body, &groups, &loops)?;

    // 6. Assemble load / execute / store phases.
    let mut phase: Vec<Stmt> = Vec::new();
    for g in &groups {
        if g.read && !g.remote {
            phase.extend(emit_transfers(k, g, &loops, Dir::HostToLocal));
        }
    }
    phase.push(Stmt::DmaWaitAll);
    phase.extend(build_point_nest(&loops, 0, prefix_len, rewritten));
    for g in &groups {
        if g.written && !g.remote {
            phase.extend(emit_transfers(k, g, &loops, Dir::LocalToHost));
        }
    }
    phase.push(Stmt::DmaWaitAll);

    // 7. Wrap in tile loops (innermost tiled loop closest to the phases).
    let mut body = phase;
    for l in loops[..prefix_len].iter().rev() {
        if let Some(tv) = l.tvar {
            let n_tiles = (l.extent + l.tile - 1) / l.tile;
            body = vec![Stmt::For {
                var: tv,
                lo: ci(0),
                hi: ci(n_tiles as i32),
                par: Par::None,
                body,
            }];
        }
    }
    let mut out = allocs;
    out.extend(body);
    Ok(out)
}

fn collect_deep_loops(k: &Kernel, body: &[Stmt], out: &mut Vec<LoopInfo>) -> Result<()> {
    for s in body {
        if let Stmt::For { var, lo, hi, par, body } = s {
            if k.eval_const(lo) != Some(0) {
                bail!("inner loop lower bound must be 0");
            }
            let Some(e) = k.eval_const(hi) else { bail!("non-constant inner extent") };
            out.push(LoopInfo {
                var: *var,
                extent: e,
                par: *par,
                tileable: false,
                tile: e,
                tvar: None,
                pvar: *var,
            });
            collect_deep_loops(k, body, out)?;
        }
    }
    Ok(())
}

fn collect_groups(k: &Kernel, body: &[Stmt], loops: &[LoopInfo]) -> Result<Vec<Group>> {
    let mut groups: Vec<Group> = Vec::new();
    walk_accesses(k, body, &mut |k, arr, idx, is_store| {
        if !matches!(k.sym(arr), Sym::HostArray { .. }) {
            bail!("AutoDMA input must access host arrays only");
        }
        let Some(aff) = flat_offset(k, arr, idx) else { bail!("non-affine access") };
        let coeffs: Vec<i64> = loops.iter().map(|l| aff.coeff(l.var)).collect();
        let known: i64 = coeffs.iter().map(|c| c.abs()).sum();
        let total: i64 = aff.terms.iter().map(|(_, c)| c.abs()).sum();
        if known != total {
            bail!("access depends on non-loop variables");
        }
        if let Some(g) = groups.iter_mut().find(|g| g.array == arr && g.coeffs == coeffs) {
            if !g.consts.contains(&aff.constant) {
                g.consts.push(aff.constant);
            }
            g.read |= !is_store;
            g.written |= is_store;
        } else {
            groups.push(Group {
                array: arr,
                coeffs,
                consts: vec![aff.constant],
                read: !is_store,
                written: is_store,
                local: 0,
                local_dims: Vec::new(),
                biases: Vec::new(),
                row_var: -1,
                len_var: -1,
                word_wise: false,
                remote: false,
                row_stride: 0,
                base_const: 0,
            });
        }
        Ok(())
    })?;
    Ok(groups)
}

fn walk_accesses(
    k: &Kernel,
    body: &[Stmt],
    f: &mut impl FnMut(&Kernel, VarId, &[Expr], bool) -> Result<()>,
) -> Result<()> {
    fn expr(
        k: &Kernel,
        e: &Expr,
        f: &mut impl FnMut(&Kernel, VarId, &[Expr], bool) -> Result<()>,
    ) -> Result<()> {
        match e {
            Expr::Load(a, idx) => {
                f(k, *a, idx, false)?;
                for i in idx {
                    expr(k, i, f)?;
                }
                Ok(())
            }
            Expr::Bin(_, a, b) => {
                expr(k, a, f)?;
                expr(k, b, f)
            }
            _ => Ok(()),
        }
    }
    for s in body {
        match s {
            Stmt::For { body, .. } => walk_accesses(k, body, f)?,
            Stmt::Store { dst, idx, value } => {
                expr(k, value, f)?;
                for i in idx {
                    expr(k, i, f)?;
                }
                f(k, *dst, idx, true)?;
            }
            Stmt::Let { value, .. } | Stmt::Assign { value, .. } => expr(k, value, f)?,
            Stmt::Dma { .. }
            | Stmt::DmaWaitAll
            | Stmt::LocalAlloc { .. }
            | Stmt::LocalFreeAll => {
                bail!("AutoDMA input already contains DMA statements")
            }
        }
    }
    Ok(())
}

/// Footprint in words of all groups under the current tiling.
fn footprint(groups: &[Group], loops: &[LoopInfo]) -> i64 {
    footprint_of(&groups.iter().collect::<Vec<_>>(), loops)
}

fn footprint_of(groups: &[&Group], loops: &[LoopInfo]) -> i64 {
    groups
        .iter()
        .map(|g| {
            let mut words = 1i64;
            for (i, c) in g.coeffs.iter().enumerate() {
                if *c != 0 {
                    words *= loops[i].tile;
                }
            }
            let spread = g.consts.iter().max().unwrap() - g.consts.iter().min().unwrap();
            words + spread.max(0)
        })
        .sum()
}

/// Decide rows/len decomposition and local layout for a group.
fn decide_shape(
    k: &Kernel,
    g: &mut Group,
    loops: &[LoopInfo],
    report: &mut AutoDmaReport,
) -> Result<()> {
    let contributing: Vec<usize> =
        (0..g.coeffs.len()).filter(|i| g.coeffs[*i] != 0).collect();
    if contributing.len() > 2 {
        bail!("access contributes more than two dimensions");
    }
    g.base_const = *g.consts.iter().min().unwrap();
    let name = k.sym_name(g.array).to_string();
    match contributing.as_slice() {
        [] => {
            g.local_dims = vec![1];
        }
        [a] => {
            let unit = g.coeffs[*a] == 1;
            if unit {
                g.len_var = *a as i32;
                let spread = spread_of(g);
                g.local_dims = vec![loops[*a].tile + spread];
                report.run_wise.push(name);
            } else {
                g.row_var = *a as i32;
                g.row_stride = g.coeffs[*a];
                g.word_wise = true;
                g.local_dims = vec![loops[*a].tile];
                report.word_wise.push(name);
            }
        }
        [a, b] => {
            // `b` is deeper in the nest (loops are in nesting order). The
            // transfer's contiguous (len) direction is whichever var has
            // unit stride; rows go along the other. Column-major accesses
            // (unit stride on the shallow var) still stage row-by-row, but
            // with short rows and one descriptor each — the degradation the
            // paper attributes to its 15 % gap. Only accesses with *no*
            // unit-stride direction degrade to word-wise gathers.
            let (shallow, deep) = (*a, *b);
            if g.coeffs[deep] == 1 && g.coeffs[shallow] > 0 {
                g.row_var = shallow as i32;
                g.len_var = deep as i32;
                g.row_stride = g.coeffs[shallow];
                let (rspread, lspread) = decompose_spread(g, g.row_stride);
                g.word_wise = false;
                g.local_dims = vec![loops[shallow].tile + rspread, loops[deep].tile + lspread];
                report.row_wise.push(name);
            } else if g.coeffs[shallow] == 1 && g.coeffs[deep] > 0 {
                // Column-major: rows along the deep var.
                g.row_var = deep as i32;
                g.len_var = shallow as i32;
                g.row_stride = g.coeffs[deep];
                let (rspread, lspread) = decompose_spread(g, g.row_stride);
                g.word_wise = false;
                g.local_dims = vec![loops[deep].tile + rspread, loops[shallow].tile + lspread];
                report.row_wise.push(name);
            } else {
                g.row_var = shallow as i32;
                g.len_var = deep as i32;
                g.row_stride = g.coeffs[shallow];
                g.word_wise = true;
                g.local_dims = vec![loops[shallow].tile, loops[deep].tile];
                report.word_wise.push(name);
            }
        }
        _ => unreachable!(),
    }
    let rs = g.row_stride;
    g.biases = g
        .consts
        .iter()
        .map(|c| {
            let d = c - g.base_const;
            if rs > 0 {
                (d / rs, d % rs)
            } else {
                (0, d)
            }
        })
        .collect();
    Ok(())
}

fn spread_of(g: &Group) -> i64 {
    g.consts.iter().max().unwrap() - g.consts.iter().min().unwrap()
}

fn decompose_spread(g: &Group, row_stride: i64) -> (i64, i64) {
    let spread = spread_of(g);
    if row_stride > 0 {
        (spread / row_stride, spread % row_stride)
    } else {
        (0, spread)
    }
}

/// Point-range length of loop `vi`, `Min`-clamped when tiled.
fn extent_expr(loops: &[LoopInfo], vi: usize) -> Expr {
    let l = &loops[vi];
    if l.tiled() {
        ci(l.tile as i32).min(ci(l.extent as i32).sub(var(l.tvar.unwrap()).mul(ci(l.tile as i32))))
    } else {
        ci(l.extent as i32)
    }
}

/// Emit the load or store phase for one group.
fn emit_transfers(k: &mut Kernel, g: &Group, loops: &[LoopInfo], dir: Dir) -> Vec<Stmt> {
    // Host base offset: constant + tile-base contributions.
    let mut host_base = ci(g.base_const as i32);
    for (i, c) in g.coeffs.iter().enumerate() {
        if *c == 0 {
            continue;
        }
        if let Some(tv) = loops[i].tvar {
            host_base = host_base.add(var(tv).mul(ci((loops[i].tile * c) as i32)));
        }
    }
    match (g.word_wise, g.row_var, g.len_var) {
        (false, -1, -1) => vec![dma1d(g, dir, host_base, ci(0), ci(1))],
        (false, -1, lv) => {
            // One contiguous run.
            let len = extent_expr(loops, lv as usize).add_spread(spread_of(g));
            vec![dma1d(g, dir, host_base, ci(0), len)]
        }
        (false, rv, lv) => {
            // Row loop: one 1D DMA call (descriptor setup) per row — the
            // pass cannot merge rows after pointer decay (§3.2).
            let (rspread, lspread) = decompose_spread(g, g.row_stride);
            let rows = extent_expr(loops, rv as usize).add_spread(rspread);
            let len = extent_expr(loops, lv as usize).add_spread(lspread);
            let r = fresh_loop_var(k, "r");
            let local_row = ci(g.local_dims[1] as i32);
            vec![Stmt::For {
                var: r,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![dma1d(
                    g,
                    dir,
                    host_base.clone().add(var(r).mul(ci(g.row_stride as i32))),
                    var(r).mul(local_row),
                    len,
                )],
            }]
        }
        (true, rv, -1) => {
            // Single non-unit direction: one blocking word per iteration.
            let rows = extent_expr(loops, rv as usize);
            let a = fresh_loop_var(k, "w");
            vec![Stmt::For {
                var: a,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![
                    dma1d(
                        g,
                        dir,
                        host_base.clone().add(var(a).mul(ci(g.row_stride as i32))),
                        var(a),
                        ci(1),
                    ),
                ],
            }]
        }
        (true, rv, lv) => {
            // Word-wise box: blocking per-element transfers.
            let rows = extent_expr(loops, rv as usize);
            let lens = extent_expr(loops, lv as usize);
            let a = fresh_loop_var(k, "wa");
            let b = fresh_loop_var(k, "wb");
            let local_row = ci(g.local_dims.get(1).copied().unwrap_or(1) as i32);
            let len_coeff = g.coeffs[lv as usize];
            vec![Stmt::For {
                var: a,
                lo: ci(0),
                hi: rows,
                par: Par::None,
                body: vec![Stmt::For {
                    var: b,
                    lo: ci(0),
                    hi: lens,
                    par: Par::None,
                    body: vec![
                        dma1d(
                            g,
                            dir,
                            host_base
                                .clone()
                                .add(var(a).mul(ci(g.row_stride as i32)))
                                .add(var(b).mul(ci(len_coeff as i32))),
                            var(a).mul(local_row).add(var(b)),
                            ci(1),
                        ),
                    ],
                }],
            }]
        }
    }
}

fn dma1d(g: &Group, dir: Dir, host_off: Expr, local_off: Expr, elems: Expr) -> Stmt {
    Stmt::Dma {
        dir,
        kind: DmaKind::Merged1D,
        host: g.array,
        host_off,
        local: g.local,
        local_off,
        rows: ci(1),
        row_elems: elems,
        host_stride: ci(0),
        local_stride: ci(0),
    }
}

trait AddSpread {
    fn add_spread(self, s: i64) -> Expr;
}

impl AddSpread for Expr {
    fn add_spread(self, s: i64) -> Expr {
        if s == 0 {
            self
        } else {
            self.add(ci(s as i32))
        }
    }
}

fn fresh_loop_var(k: &mut Kernel, base: &str) -> VarId {
    let name = format!("{base}{}", k.syms.len());
    k.syms.push((name, Sym::LoopVar));
    k.syms.len() - 1
}

/// Rebuild the point nest over the (possibly tiled) prefix loops.
fn build_point_nest(
    loops: &[LoopInfo],
    d: usize,
    prefix_len: usize,
    inner: Vec<Stmt>,
) -> Vec<Stmt> {
    if d >= prefix_len {
        return inner;
    }
    let body = build_point_nest(loops, d + 1, prefix_len, inner);
    let l = &loops[d];
    vec![Stmt::For { var: l.pvar, lo: ci(0), hi: extent_expr(loops, d), par: l.par, body }]
}

/// Rewrite accesses to local buffers and loop vars to tile_base + point.
fn rewrite_block(
    k: &Kernel,
    body: &[Stmt],
    groups: &[Group],
    loops: &[LoopInfo],
) -> Result<Vec<Stmt>> {
    body.iter().map(|s| rewrite_stmt(k, s, groups, loops)).collect()
}

fn rewrite_stmt(k: &Kernel, s: &Stmt, groups: &[Group], loops: &[LoopInfo]) -> Result<Stmt> {
    Ok(match s {
        Stmt::For { var, lo, hi, par, body } => Stmt::For {
            var: *var,
            lo: rewrite_expr(k, lo, groups, loops)?,
            hi: rewrite_expr(k, hi, groups, loops)?,
            par: *par,
            body: rewrite_block(k, body, groups, loops)?,
        },
        Stmt::Store { dst, idx, value } => {
            let value = rewrite_expr(k, value, groups, loops)?;
            let (local, lidx) = rewrite_access(k, *dst, idx, groups, loops)?;
            Stmt::Store { dst: local, idx: lidx, value }
        }
        Stmt::Let { var, value } => {
            Stmt::Let { var: *var, value: rewrite_expr(k, value, groups, loops)? }
        }
        Stmt::Assign { var, value } => {
            Stmt::Assign { var: *var, value: rewrite_expr(k, value, groups, loops)? }
        }
        other => other.clone(),
    })
}

fn rewrite_expr(k: &Kernel, e: &Expr, groups: &[Group], loops: &[LoopInfo]) -> Result<Expr> {
    Ok(match e {
        Expr::Load(a, idx) => {
            let (local, lidx) = rewrite_access(k, *a, idx, groups, loops)?;
            Expr::Load(local, lidx)
        }
        Expr::Var(v) => {
            if let Some(l) = loops.iter().find(|l| l.var == *v) {
                if l.tiled() {
                    var(l.tvar.unwrap()).mul(ci(l.tile as i32)).add(var(l.pvar))
                } else {
                    e.clone()
                }
            } else {
                e.clone()
            }
        }
        Expr::Bin(op, a, b) => Expr::Bin(
            *op,
            Box::new(rewrite_expr(k, a, groups, loops)?),
            Box::new(rewrite_expr(k, b, groups, loops)?),
        ),
        _ => e.clone(),
    })
}

/// Rewrite one access to its local buffer.
fn rewrite_access(
    k: &Kernel,
    arr: VarId,
    idx: &[Expr],
    groups: &[Group],
    loops: &[LoopInfo],
) -> Result<(VarId, Vec<Expr>)> {
    let aff = flat_offset(k, arr, idx)
        .ok_or_else(|| anyhow::anyhow!("non-affine access survived grouping"))?;
    let coeffs: Vec<i64> = loops.iter().map(|l| aff.coeff(l.var)).collect();
    for g in groups {
        if g.array != arr || g.coeffs != coeffs || !g.consts.contains(&aff.constant) {
            continue;
        }
        if g.remote {
            // Left in the host address space: only substitute tiled loop
            // variables inside the subscripts.
            let lidx: Vec<Expr> = idx
                .iter()
                .map(|e| rewrite_expr(k, e, groups, loops))
                .collect::<Result<_>>()?;
            return Ok((arr, lidx));
        }
        let pos = g.consts.iter().position(|c| *c == aff.constant).unwrap();
        let (rbias, lbias) = g.biases[pos];
        let mut lidx: Vec<Expr> = Vec::new();
        if g.row_var >= 0 {
            let p = var(loops[g.row_var as usize].pvar);
            lidx.push(if rbias == 0 { p } else { p.add(ci(rbias as i32)) });
        }
        if g.len_var >= 0 {
            let p = var(loops[g.len_var as usize].pvar);
            lidx.push(if lbias == 0 { p } else { p.add(ci(lbias as i32)) });
        }
        if lidx.is_empty() {
            lidx.push(ci(0));
        }
        return Ok((g.local, lidx));
    }
    bail!("access to {} not covered by any group", k.sym_name(arr))
}
