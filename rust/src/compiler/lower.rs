//! Lowering: kernel IR → accelerator machine code.
//!
//! Mirrors the paper's device compiler (§2.2):
//! * **Host-pointer legalization** (§2.2.1): accesses to host-space arrays
//!   become `*.ext` instructions through the address-extension CSR, set once
//!   in the prologue.
//! * **Pointer strength reduction**: affine accesses inside loops become
//!   induction pointers initialized in the preheader and bumped per
//!   iteration — the classic `-O3` shape the paper's instruction counts
//!   reflect (gemm base inner loop: 2 loads, 4 adds, 2 muls, 1 store,
//!   1 branch).
//! * **Xpulpv2 codegen** (§2.2.3): post-increment load/store fusion
//!   (immediate strides < 2 KiB only — the paper's atax column walk is "too
//!   large to be used in post-increment"), MAC fusion, and hardware-loop
//!   inference for up to two nested levels. Hardware loops are *not*
//!   inferred when the trip count is tile-dependent (`Min`-shaped bounds) or
//!   when the body carries a may-alias load/store pair (the covar case,
//!   which manual register promotion resolves — §3.4).
//! * **Accumulator caching**: loop-invariant accumulator loads are hoisted
//!   into a register; the store stays in the loop (the paper notes its
//!   compiler lacks the memory-to-register pass to hoist it; doing it
//!   manually in the source is Fig 9's second bar).
//! * **OpenMP lowering**: `Par::Cores` loops become fork/join regions with
//!   static chunking by `mhartid`; `Par::Teams` loops chunk by cluster id.

use super::analyze::{flat_offset, Affine};
use super::ir::*;
use crate::isa::{AluOp, Cond, Csr, DmaDir, FpOp, Inst, Program, Reg};
use crate::mem::map;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Lowering options.
#[derive(Debug, Clone)]
pub struct LowerOpts {
    /// Enable Xpulpv2 codegen (post-increment, MAC, hardware loops).
    pub xpulp: bool,
    /// Cores per cluster (for `Par::Cores` chunking).
    pub n_cores: u32,
    /// Clusters (for `Par::Teams` chunking).
    pub n_clusters: u32,
    /// Byte offset within the TCDM where kernel-static buffers start (below
    /// it lives the runtime + stacks; 1/8 of the TCDM on Aurora).
    pub l1_base_off: u32,
    /// TCDM capacity in bytes (for allocation overflow checks).
    pub l1_bytes: u32,
}

impl LowerOpts {
    pub fn for_config(cfg: &crate::config::HeroConfig) -> Self {
        LowerOpts {
            xpulp: cfg.accel.isa.xpulp,
            n_cores: cfg.accel.cores_per_cluster as u32,
            n_clusters: cfg.accel.n_clusters as u32,
            l1_base_off: (cfg.accel.l1_bytes / 8) as u32,
            l1_bytes: cfg.accel.l1_bytes as u32,
        }
    }
}

/// Result of lowering a kernel.
#[derive(Debug, Clone)]
pub struct Lowered {
    pub program: Program,
    /// Host arrays in parameter order: the runtime passes `x10 = VA[63:32]`
    /// (common to all buffers) and `x11+i = VA[31:0]` of array i.
    pub arrays: Vec<VarId>,
    /// Float parameters in order (passed in `f10+i`).
    pub floats: Vec<VarId>,
    /// Bytes of TCDM used by kernel-static buffers.
    pub l1_used: u32,
}

/// Maximum immediate for post-increment forms (12-bit signed, bytes).
const POST_INC_MAX: i64 = 2048;

/// Marker prefix of the error raised when a kernel's static SPM allocation
/// exceeds the TCDM. The scheduler's capacity-aware admission
/// (`sched::Scheduler`) keys on this exact string — change both together.
pub const L1_OVERFLOW_MARKER: &str = "L1 overflow";

/// Whether an error (anywhere in its chain) is an L1 allocation overflow.
pub fn is_l1_overflow(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.to_string().contains(L1_OVERFLOW_MARKER))
}

pub fn lower(k: &Kernel, opts: &LowerOpts) -> Result<Lowered> {
    let mut lw = Lower::new(k, opts)?;
    lw.prologue()?;
    let body = k.body.clone();
    lw.emit_block(&body, &LoopCtx::default())?;
    lw.asm.push(Inst::Halt);
    let program = lw.finish()?;
    let l1_used = lw.l1_peak.max(lw.l1_cursor) - opts.l1_base_off;
    Ok(Lowered { program, arrays: lw.arrays, floats: lw.floats, l1_used })
}

// --- assembler with label fixups ------------------------------------------

#[derive(Default)]
struct Asm {
    insts: Vec<Inst>,
    labels: Vec<Option<u32>>,
    /// (inst index, label, which operand) fixups.
    fixups: Vec<(usize, usize, FixKind)>,
}

#[derive(Clone, Copy)]
enum FixKind {
    Branch,
    Fork,
    HwStart,
    HwEnd,
}

impl Asm {
    fn push(&mut self, i: Inst) -> usize {
        self.insts.push(i);
        self.insts.len() - 1
    }

    fn new_label(&mut self) -> usize {
        self.labels.push(None);
        self.labels.len() - 1
    }

    fn bind(&mut self, l: usize) {
        self.labels[l] = Some(self.insts.len() as u32);
    }

    fn push_branch(&mut self, cond: Cond, rs1: Reg, rs2: Reg, label: usize) {
        let i = self.push(Inst::Branch { cond, rs1, rs2, target: 0 });
        self.fixups.push((i, label, FixKind::Branch));
    }

    fn push_fork(&mut self, label: usize) {
        let i = self.push(Inst::Fork { target: 0 });
        self.fixups.push((i, label, FixKind::Fork));
    }

    fn push_hwloop(&mut self, l: u8, count: Reg, start: usize, end: usize) {
        let i = self.push(Inst::HwLoop { l, count, start: 0, end: 0 });
        self.fixups.push((i, start, FixKind::HwStart));
        self.fixups.push((i, end, FixKind::HwEnd));
    }

    fn finish(mut self) -> Result<Vec<Inst>> {
        for (idx, label, kind) in &self.fixups {
            let target =
                self.labels[*label].ok_or_else(|| anyhow!("unbound label {label}"))?;
            match (&mut self.insts[*idx], kind) {
                (Inst::Branch { target: t, .. }, FixKind::Branch) => *t = target,
                (Inst::Fork { target: t }, FixKind::Fork) => *t = target,
                (Inst::HwLoop { start, .. }, FixKind::HwStart) => *start = target,
                (Inst::HwLoop { end, .. }, FixKind::HwEnd) => *end = target,
                _ => bail!("fixup mismatch at {idx}"),
            }
        }
        Ok(self.insts)
    }
}

// --- register allocation ----------------------------------------------------

struct Regs {
    free_i: Vec<Reg>,
    free_f: Vec<Reg>,
    temp_i: Vec<Reg>,
    temp_f: Vec<Reg>,
}

/// A value in a register: temps must be freed, homes must not.
#[derive(Clone, Copy, PartialEq)]
enum Val {
    Temp(Reg),
    Home(Reg),
}

impl Val {
    fn reg(self) -> Reg {
        match self {
            Val::Temp(r) | Val::Home(r) => r,
        }
    }
}

impl Regs {
    fn new(n_arrays: usize, n_floats: usize) -> Self {
        // x0 zero, x1-x4 expr temps, x5 last-dma-id, x10 host-hi,
        // x11.. array los, x28 tcdm base.
        let mut free_i: Vec<Reg> = vec![6, 7, 8, 9];
        let first_free = 11 + n_arrays as u8;
        for r in first_free..28 {
            free_i.push(r);
        }
        free_i.extend([29, 30, 31]);
        // f0-f3 temps, f10.. float params.
        let mut free_f: Vec<Reg> = (4..10).collect();
        for r in (10 + n_floats as u8)..32 {
            free_f.push(r);
        }
        Regs { free_i, free_f, temp_i: vec![1, 2, 3, 4], temp_f: vec![0, 1, 2, 3] }
    }

    fn alloc_i(&mut self) -> Result<Reg> {
        self.free_i.pop().ok_or_else(|| anyhow!("out of integer registers"))
    }

    fn alloc_f(&mut self) -> Result<Reg> {
        self.free_f.pop().ok_or_else(|| anyhow!("out of float registers"))
    }

    fn release_i(&mut self, r: Reg) {
        self.free_i.push(r);
    }

    fn release_f(&mut self, r: Reg) {
        self.free_f.push(r);
    }

    fn tmp_i(&mut self) -> Result<Reg> {
        self.temp_i.pop().ok_or_else(|| anyhow!("integer temp pool exhausted"))
    }

    fn tmp_f(&mut self) -> Result<Reg> {
        self.temp_f.pop().ok_or_else(|| anyhow!("float temp pool exhausted"))
    }

    fn free_val_i(&mut self, v: Val) {
        if let Val::Temp(r) = v {
            self.temp_i.push(r);
        }
    }

    fn free_val_f(&mut self, v: Val) {
        if let Val::Temp(r) = v {
            self.temp_f.push(r);
        }
    }
}

// --- strength-reduction entries --------------------------------------------

/// One induction pointer for an access in the current loop body.
struct SrEntry {
    array: VarId,
    /// Flat affine offset of the access (in elements).
    affine: Affine,
    /// Pointer register (byte address: native TCDM or host-lo).
    ptr: Reg,
    /// Stride in bytes per iteration of the owning loop var.
    stride: i64,
    /// Uses per iteration (bump after the last one).
    uses: u32,
    uses_left: u32,
    /// Host (ext) or local access.
    host: bool,
}

/// Per-loop lowering context.
#[derive(Default, Clone)]
struct LoopCtx {
    /// Enclosing loop variables (outermost first).
    loop_vars: Vec<VarId>,
    /// Hardware-loop nesting level already in use above us.
    hw_depth: u8,
    /// Are we inside a parallel (forked) region?
    in_parallel: bool,
}

// --- accumulator-cache bookkeeping -----------------------------------------

struct AccCache {
    array: VarId,
    idx: Vec<Expr>,
    freg: Reg,
    /// Pointer register holding the (invariant) address.
    ptr: Reg,
    host: bool,
}

// --- the lowering driver ----------------------------------------------------

struct Lower<'k> {
    k: &'k Kernel,
    opts: LowerOpts,
    asm: Asm,
    regs: Regs,
    /// Home registers of scalar vars (loop vars, lets).
    home_i: HashMap<VarId, Reg>,
    home_f: HashMap<VarId, Reg>,
    /// Array base registers: host arrays → arg reg (VA lo); local buffers →
    /// computed TCDM pointer.
    base: HashMap<VarId, Reg>,
    /// Static L1 allocation cursor (byte offset in TCDM).
    l1_cursor: u32,
    /// Peak cursor (for `Lowered::l1_used`).
    l1_peak: u32,
    arrays: Vec<VarId>,
    floats: Vec<VarId>,
    /// Active SR entries, innermost loop last.
    sr_stack: Vec<Vec<SrEntry>>,
    /// Active accumulator caches.
    acc_stack: Vec<Vec<AccCache>>,
    /// Register holding this cluster's TCDM base (x28), set in prologue.
    tcdm_base_reg: Reg,
    has_locals: bool,
}

impl<'k> Lower<'k> {
    fn new(k: &'k Kernel, opts: &LowerOpts) -> Result<Self> {
        let arrays: Vec<VarId> = (0..k.n_params)
            .filter(|v| matches!(k.sym(*v), Sym::HostArray { .. }))
            .collect();
        let floats: Vec<VarId> =
            (0..k.n_params).filter(|v| matches!(k.sym(*v), Sym::FloatParam)).collect();
        if arrays.len() > 14 {
            bail!("too many array parameters");
        }
        let has_locals = k.syms.iter().any(|(_, s)| matches!(s, Sym::LocalBuf { .. }));
        let mut base = HashMap::new();
        for (i, a) in arrays.iter().enumerate() {
            base.insert(*a, 11 + i as u8);
        }
        let mut home_f = HashMap::new();
        for (i, f) in floats.iter().enumerate() {
            home_f.insert(*f, 10 + i as u8);
        }
        Ok(Lower {
            k,
            opts: opts.clone(),
            asm: Asm::default(),
            regs: Regs::new(arrays.len(), floats.len()),
            home_i: HashMap::new(),
            home_f,
            base,
            l1_cursor: opts.l1_base_off,
            l1_peak: opts.l1_base_off,
            arrays,
            floats,
            sr_stack: Vec::new(),
            acc_stack: Vec::new(),
            tcdm_base_reg: 28,
            has_locals,
        })
    }

    fn prologue(&mut self) -> Result<()> {
        if !self.arrays.is_empty() {
            // Host pointers share one 4 GiB window; the legalizer sets the
            // address-extension CSR once (§2.2.1).
            self.asm.push(Inst::CsrW { csr: Csr::ExtAddr, rs1: 10 });
        }
        if self.has_locals {
            // x28 = TCDM base of *this* cluster.
            let t = self.regs.tmp_i()?;
            self.asm.push(Inst::CsrR { rd: t, csr: Csr::MClusterId });
            let u = self.regs.tmp_i()?;
            self.asm.push(Inst::Li { rd: u, imm: map::CLUSTER_STRIDE as i32 });
            self.asm.push(Inst::Alu { op: AluOp::Mul, rd: t, rs1: t, rs2: u });
            self.asm.push(Inst::Li { rd: self.tcdm_base_reg, imm: map::TCDM_BASE as i32 });
            self.asm.push(Inst::Alu {
                op: AluOp::Add,
                rd: self.tcdm_base_reg,
                rs1: self.tcdm_base_reg,
                rs2: t,
            });
            self.regs.temp_i.push(t);
            self.regs.temp_i.push(u);
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<Program> {
        let asm = std::mem::take(&mut self.asm);
        let insts = asm.finish()?;
        let mut p = Program::new(insts);
        p.entry = 0;
        p.validate().map_err(|e| anyhow!("lowered program invalid: {e}"))?;
        Ok(p)
    }

    // --- integer expressions ---

    fn is_host(&self, array: VarId) -> bool {
        matches!(self.k.sym(array), Sym::HostArray { .. })
    }

    fn eval_i(&mut self, e: &Expr) -> Result<Val> {
        // Constant folding first (const params are immediates).
        if let Some(c) = self.k.eval_const(e) {
            let t = self.regs.tmp_i()?;
            self.asm.push(Inst::Li { rd: t, imm: c as i32 });
            return Ok(Val::Temp(t));
        }
        match e {
            Expr::Var(v) => {
                let r = *self
                    .home_i
                    .get(v)
                    .ok_or_else(|| anyhow!("use of undefined i32 var {}", self.k.sym_name(*v)))?;
                Ok(Val::Home(r))
            }
            Expr::Bin(op, a, b) => {
                let va = self.eval_i(a)?;
                let vb = self.eval_i(b)?;
                let rd = match (va, vb) {
                    (Val::Temp(r), _) => r,
                    (_, Val::Temp(r)) => r,
                    _ => self.regs.tmp_i()?,
                };
                self.emit_int_binop(*op, rd, va.reg(), vb.reg())?;
                // Free the temp we didn't reuse.
                match (va, vb) {
                    (Val::Temp(_), Val::Temp(b)) if b != rd => self.regs.temp_i.push(b),
                    (Val::Temp(a), Val::Temp(_)) if a != rd => self.regs.temp_i.push(a),
                    _ => {}
                }
                Ok(Val::Temp(rd))
            }
            Expr::ConstI(_) => unreachable!("folded above"),
            _ => bail!("expression is not an integer expression: {e:?}"),
        }
    }

    fn emit_int_binop(&mut self, op: BinOp, rd: Reg, a: Reg, b: Reg) -> Result<()> {
        let alu = match op {
            BinOp::Add => AluOp::Add,
            BinOp::Sub => AluOp::Sub,
            BinOp::Mul => AluOp::Mul,
            BinOp::Div => AluOp::Div,
            BinOp::Min | BinOp::Max => {
                if self.opts.xpulp {
                    let alu = if op == BinOp::Min { AluOp::Min } else { AluOp::Max };
                    self.asm.push(Inst::Alu { op: alu, rd, rs1: a, rs2: b });
                } else {
                    // Branchless RV32IM min/max:
                    //   t = (a < b); u = a - b; u *= t; rd = b + u   (min)
                    let t = self.regs.tmp_i()?;
                    let u = self.regs.tmp_i()?;
                    let (x, y) = if op == BinOp::Min { (a, b) } else { (b, a) };
                    self.asm.push(Inst::Alu { op: AluOp::Slt, rd: t, rs1: x, rs2: y });
                    self.asm.push(Inst::Alu { op: AluOp::Sub, rd: u, rs1: x, rs2: y });
                    self.asm.push(Inst::Alu { op: AluOp::Mul, rd: u, rs1: u, rs2: t });
                    self.asm.push(Inst::Alu { op: AluOp::Add, rd, rs1: y, rs2: u });
                    self.regs.temp_i.push(t);
                    self.regs.temp_i.push(u);
                }
                return Ok(());
            }
        };
        self.asm.push(Inst::Alu { op: alu, rd, rs1: a, rs2: b });
        Ok(())
    }

    // --- addresses & memory accesses ---

    /// Find an active SR entry for this access.
    fn find_sr(&mut self, array: VarId, affine: &Affine) -> Option<(usize, usize)> {
        for (li, entries) in self.sr_stack.iter().enumerate().rev() {
            for (ei, e) in entries.iter().enumerate() {
                if e.array == array && &e.affine == affine {
                    return Some((li, ei));
                }
            }
        }
        None
    }

    /// Compute the byte address of an access into a temp register
    /// (fallback path when no SR pointer covers it).
    fn eval_address(&mut self, array: VarId, idx: &[Expr]) -> Result<Val> {
        let strides = self
            .k
            .array_strides(array)
            .ok_or_else(|| anyhow!("{} is not an array", self.k.sym_name(array)))?;
        let base = *self.base.get(&array).ok_or_else(|| {
            anyhow!("array {} has no base register (unallocated local?)", self.k.sym_name(array))
        })?;
        // addr = base + 4 * Σ idx_d * stride_d
        let acc = self.regs.tmp_i()?;
        self.asm.push(Inst::Li { rd: acc, imm: 0 });
        for (e, s) in idx.iter().zip(strides) {
            let v = self.eval_i(e)?;
            if s == 1 {
                self.asm.push(Inst::Alu { op: AluOp::Add, rd: acc, rs1: acc, rs2: v.reg() });
            } else {
                let t = self.regs.tmp_i()?;
                self.asm.push(Inst::Li { rd: t, imm: s as i32 });
                self.asm.push(Inst::Alu { op: AluOp::Mul, rd: t, rs1: v.reg(), rs2: t });
                self.asm.push(Inst::Alu { op: AluOp::Add, rd: acc, rs1: acc, rs2: t });
                self.regs.temp_i.push(t);
            }
            self.regs.free_val_i(v);
        }
        self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: acc, rs1: acc, imm: 2 });
        self.asm.push(Inst::Alu { op: AluOp::Add, rd: acc, rs1: acc, rs2: base });
        Ok(Val::Temp(acc))
    }

    /// Emit a float load of `array[idx]` into a register.
    fn emit_fload(&mut self, array: VarId, idx: &[Expr]) -> Result<Val> {
        let host = self.is_host(array);
        let affine = flat_offset(self.k, array, idx);
        if let Some(aff) = &affine {
            if let Some((li, ei)) = self.find_sr(array, aff) {
                return self.sr_access(li, ei, AccessKind::FLoad).map(Val::Temp);
            }
        }
        let addr = self.eval_address(array, idx)?;
        let fd = self.regs.tmp_f()?;
        if host {
            self.asm.push(Inst::FlwExt { fd, rs1: addr.reg(), offset: 0 });
        } else {
            self.asm.push(Inst::Flw { fd, rs1: addr.reg(), offset: 0 });
        }
        self.regs.free_val_i(addr);
        Ok(Val::Temp(fd))
    }

    /// Emit a float store of `freg` into `array[idx]`.
    fn emit_fstore(&mut self, array: VarId, idx: &[Expr], freg: Reg) -> Result<()> {
        let host = self.is_host(array);
        let affine = flat_offset(self.k, array, idx);
        if let Some(aff) = &affine {
            if let Some((li, ei)) = self.find_sr(array, aff) {
                self.sr_access(li, ei, AccessKind::FStore(freg))?;
                return Ok(());
            }
        }
        let addr = self.eval_address(array, idx)?;
        if host {
            self.asm.push(Inst::FswExt { fs2: freg, rs1: addr.reg(), offset: 0 });
        } else {
            self.asm.push(Inst::Fsw { fs2: freg, rs1: addr.reg(), offset: 0 });
        }
        self.regs.free_val_i(addr);
        Ok(())
    }

    /// Access through an SR pointer; fuses the pointer bump into a
    /// post-increment form when legal (Xpulpv2, last use, small stride).
    fn sr_access(&mut self, li: usize, ei: usize, kind: AccessKind) -> Result<Reg> {
        let (ptr, stride, host, is_last, imm_ok) = {
            let e = &mut self.sr_stack[li][ei];
            e.uses_left -= 1;
            let is_last = e.uses_left == 0;
            if is_last {
                e.uses_left = e.uses; // reset for next iteration
            }
            (e.ptr, e.stride, e.host, is_last, e.stride.abs() < POST_INC_MAX)
        };
        let bump = is_last && stride != 0;
        let use_post = self.opts.xpulp && bump && imm_ok && !host;
        let ret = match kind {
            AccessKind::FLoad => {
                let fd = self.regs.tmp_f()?;
                match (host, use_post) {
                    (true, _) => {
                        self.asm.push(Inst::FlwExt { fd, rs1: ptr, offset: 0 });
                    }
                    (false, true) => {
                        self.asm.push(Inst::FlwPost { fd, rs1: ptr, imm: stride as i32 });
                    }
                    (false, false) => {
                        self.asm.push(Inst::Flw { fd, rs1: ptr, offset: 0 });
                    }
                }
                fd
            }
            AccessKind::FStore(fs) => {
                match (host, use_post) {
                    (true, _) => {
                        self.asm.push(Inst::FswExt { fs2: fs, rs1: ptr, offset: 0 });
                    }
                    (false, true) => {
                        self.asm.push(Inst::FswPost { fs2: fs, rs1: ptr, imm: stride as i32 });
                    }
                    (false, false) => {
                        self.asm.push(Inst::Fsw { fs2: fs, rs1: ptr, offset: 0 });
                    }
                }
                0
            }
        };
        if bump && !use_post {
            // Separate pointer bump (an "addition" in the paper's counts).
            if (-2048..2048).contains(&stride) {
                self.asm.push(Inst::AluImm { op: AluOp::Add, rd: ptr, rs1: ptr, imm: stride as i32 });
            } else {
                let t = self.regs.tmp_i()?;
                self.asm.push(Inst::Li { rd: t, imm: stride as i32 });
                self.asm.push(Inst::Alu { op: AluOp::Add, rd: ptr, rs1: ptr, rs2: t });
                self.regs.temp_i.push(t);
            }
        }
        Ok(ret)
    }

    // --- float expressions ---

    fn eval_f(&mut self, e: &Expr) -> Result<Val> {
        match e {
            Expr::ConstF(c) => {
                let t = self.regs.tmp_i()?;
                self.asm.push(Inst::Li { rd: t, imm: c.to_bits() as i32 });
                let fd = self.regs.tmp_f()?;
                self.asm.push(Inst::FmvWX { fd, rs1: t });
                self.regs.temp_i.push(t);
                Ok(Val::Temp(fd))
            }
            Expr::ConstI(c) => {
                // Integer constant in float context.
                let t = self.regs.tmp_i()?;
                self.asm.push(Inst::Li { rd: t, imm: *c });
                let fd = self.regs.tmp_f()?;
                self.asm.push(Inst::FcvtSW { fd, rs1: t });
                self.regs.temp_i.push(t);
                Ok(Val::Temp(fd))
            }
            Expr::Var(v) => {
                if let Some(r) = self.home_f.get(v) {
                    Ok(Val::Home(*r))
                } else if let Some(r) = self.home_i.get(v) {
                    // int var used in float context: convert.
                    let r = *r;
                    let fd = self.regs.tmp_f()?;
                    self.asm.push(Inst::FcvtSW { fd, rs1: r });
                    Ok(Val::Temp(fd))
                } else {
                    bail!("use of undefined float var {}", self.k.sym_name(*v))
                }
            }
            Expr::Load(a, idx) => {
                // Accumulator-cached?
                for caches in self.acc_stack.iter().rev() {
                    for c in caches {
                        if c.array == *a && c.idx == *idx {
                            return Ok(Val::Home(c.freg));
                        }
                    }
                }
                self.emit_fload(*a, idx)
            }
            Expr::Bin(op, a, b) => {
                let va = self.eval_f(a)?;
                let vb = self.eval_f(b)?;
                let rd = match (va, vb) {
                    (Val::Temp(r), _) => r,
                    (_, Val::Temp(r)) => r,
                    _ => self.regs.tmp_f()?,
                };
                let fop = match op {
                    BinOp::Add => FpOp::Add,
                    BinOp::Sub => FpOp::Sub,
                    BinOp::Mul => FpOp::Mul,
                    BinOp::Div => FpOp::Div,
                    BinOp::Min => FpOp::Min,
                    BinOp::Max => FpOp::Max,
                };
                self.asm.push(Inst::Fp { op: fop, fd: rd, fs1: va.reg(), fs2: vb.reg() });
                match (va, vb) {
                    (Val::Temp(_), Val::Temp(y)) if y != rd => self.regs.temp_f.push(y),
                    (Val::Temp(x), Val::Temp(_)) if x != rd => self.regs.temp_f.push(x),
                    _ => {}
                }
                Ok(Val::Temp(rd))
            }
        }
    }

    /// Float register move (via the integer file, as RV32F without
    /// sign-injection shortcuts would).
    fn emit_fmove(&mut self, fd: Reg, fs: Reg) -> Result<()> {
        let z = self.regs.tmp_i()?;
        self.asm.push(Inst::FmvXW { rd: z, fs1: fs });
        self.asm.push(Inst::FmvWX { fd, rs1: z });
        self.regs.temp_i.push(z);
        Ok(())
    }

    /// Accumulate `e` into float register `acc`: `acc += e`, fusing a MAC
    /// when `e` is a product and Xpulpv2 is enabled.
    fn eval_accumulate(&mut self, acc: Reg, e: &Expr) -> Result<()> {
        if let Expr::Bin(BinOp::Mul, a, b) = e {
            let va = self.eval_f(a)?;
            let vb = self.eval_f(b)?;
            if self.opts.xpulp {
                self.asm.push(Inst::Fmac { fd: acc, fs1: va.reg(), fs2: vb.reg() });
            } else {
                let t = self.regs.tmp_f()?;
                self.asm.push(Inst::Fp { op: FpOp::Mul, fd: t, fs1: va.reg(), fs2: vb.reg() });
                self.asm.push(Inst::Fp { op: FpOp::Add, fd: acc, fs1: acc, fs2: t });
                self.regs.temp_f.push(t);
            }
            self.regs.free_val_f(va);
            self.regs.free_val_f(vb);
        } else {
            let v = self.eval_f(e)?;
            self.asm.push(Inst::Fp { op: FpOp::Add, fd: acc, fs1: acc, fs2: v.reg() });
            self.regs.free_val_f(v);
        }
        Ok(())
    }

    // --- statements ---

    fn emit_block(&mut self, stmts: &[Stmt], ctx: &LoopCtx) -> Result<()> {
        for s in stmts {
            self.emit_stmt(s, ctx)?;
        }
        Ok(())
    }

    /// Emit a loop body as a scope: `Let` variables first defined inside it
    /// release their home registers afterwards (block scoping, like the C
    /// sources the IR mirrors).
    fn emit_block_scoped(&mut self, stmts: &[Stmt], ctx: &LoopCtx) -> Result<()> {
        let snap_i: Vec<VarId> = self.home_i.keys().copied().collect();
        let snap_f: Vec<VarId> = self.home_f.keys().copied().collect();
        self.emit_block(stmts, ctx)?;
        let new_i: Vec<VarId> = self
            .home_i
            .keys()
            .copied()
            .filter(|v| !snap_i.contains(v) && matches!(self.k.sym(*v), Sym::LetI32))
            .collect();
        for v in new_i {
            let r = self.home_i.remove(&v).unwrap();
            self.regs.release_i(r);
        }
        let new_f: Vec<VarId> = self
            .home_f
            .keys()
            .copied()
            .filter(|v| !snap_f.contains(v) && matches!(self.k.sym(*v), Sym::LetF32))
            .collect();
        for v in new_f {
            let r = self.home_f.remove(&v).unwrap();
            self.regs.release_f(r);
        }
        Ok(())
    }

    fn emit_stmt(&mut self, s: &Stmt, ctx: &LoopCtx) -> Result<()> {
        match s {
            Stmt::For { .. } => self.emit_for(s, ctx),
            Stmt::Let { var, value } => {
                match self.k.sym(*var) {
                    Sym::LetF32 => {
                        let r = if let Some(r) = self.home_f.get(var) {
                            *r
                        } else {
                            let r = self.regs.alloc_f()?;
                            self.home_f.insert(*var, r);
                            r
                        };
                        let v = self.eval_f(value)?;
                        if v.reg() != r {
                            self.emit_fmove(r, v.reg())?;
                        }
                        self.regs.free_val_f(v);
                    }
                    _ => {
                        let r = if let Some(r) = self.home_i.get(var) {
                            *r
                        } else {
                            let r = self.regs.alloc_i()?;
                            self.home_i.insert(*var, r);
                            r
                        };
                        let v = self.eval_i(value)?;
                        if v.reg() != r {
                            self.asm.push(Inst::AluImm {
                                op: AluOp::Add,
                                rd: r,
                                rs1: v.reg(),
                                imm: 0,
                            });
                        }
                        self.regs.free_val_i(v);
                    }
                }
                Ok(())
            }
            Stmt::Assign { var, value } => {
                if matches!(self.k.sym(*var), Sym::LetF32) {
                    let r = *self
                        .home_f
                        .get(var)
                        .ok_or_else(|| anyhow!("assign to undefined {}", self.k.sym_name(*var)))?;
                    // Accumulation pattern: var = var + e
                    if let Expr::Bin(BinOp::Add, a, b) = value {
                        if **a == Expr::Var(*var) {
                            return self.eval_accumulate(r, b);
                        }
                    }
                    let v = self.eval_f(value)?;
                    if v.reg() != r {
                        self.emit_fmove(r, v.reg())?;
                    }
                    self.regs.free_val_f(v);
                } else {
                    let r = *self
                        .home_i
                        .get(var)
                        .ok_or_else(|| anyhow!("assign to undefined {}", self.k.sym_name(*var)))?;
                    let v = self.eval_i(value)?;
                    if v.reg() != r {
                        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: r, rs1: v.reg(), imm: 0 });
                    }
                    self.regs.free_val_i(v);
                }
                Ok(())
            }
            Stmt::Store { dst, idx, value } => {
                // Accumulator-cached store: update the register, store through.
                for caches in self.acc_stack.iter().rev() {
                    for c in caches {
                        if c.array == *dst && c.idx == *idx {
                            let (freg, ptr, host) = (c.freg, c.ptr, c.host);
                            // value must be Load(dst,idx) + e (checked at setup)
                            if let Expr::Bin(BinOp::Add, _, e) = value {
                                let e = e.clone();
                                self.eval_accumulate(freg, &e)?;
                            } else {
                                let v = self.eval_f(value)?;
                                self.emit_fmove(freg, v.reg())?;
                                self.regs.free_val_f(v);
                            }
                            // Store-through (the paper's compiler keeps the
                            // store in the loop; manual promotion removes it).
                            if host {
                                self.asm.push(Inst::FswExt { fs2: freg, rs1: ptr, offset: 0 });
                            } else {
                                self.asm.push(Inst::Fsw { fs2: freg, rs1: ptr, offset: 0 });
                            }
                            return Ok(());
                        }
                    }
                }
                let v = self.eval_f(value)?;
                self.emit_fstore(*dst, idx, v.reg())?;
                self.regs.free_val_f(v);
                Ok(())
            }
            Stmt::LocalAlloc { var, elems } => {
                let n = self
                    .k
                    .eval_const(elems)
                    .ok_or_else(|| anyhow!("local buffer size must be compile-time constant"))?;
                let bytes = (n as u32) * 4;
                if self.l1_cursor + bytes > self.opts.l1_bytes {
                    bail!(
                        "{L1_OVERFLOW_MARKER}: {} needs {} B at offset {} (capacity {})",
                        self.k.sym_name(*var),
                        bytes,
                        self.l1_cursor,
                        self.opts.l1_bytes
                    );
                }
                let r = self.regs.alloc_i()?;
                self.asm.push(Inst::Li { rd: r, imm: self.l1_cursor as i32 });
                self.asm.push(Inst::Alu { op: AluOp::Add, rd: r, rs1: r, rs2: self.tcdm_base_reg });
                self.base.insert(*var, r);
                self.l1_cursor += bytes;
                Ok(())
            }
            Stmt::Dma { .. } => self.emit_dma(s),
            Stmt::DmaWaitAll => {
                self.asm.push(Inst::DmaWait { rs1: 5 });
                Ok(())
            }
            Stmt::LocalFreeAll => {
                // Static allocator: reset the cursor; base pointers of freed
                // buffers become invalid (their registers are released).
                self.l1_peak = self.l1_peak.max(self.l1_cursor);
                self.l1_cursor = self.opts.l1_base_off;
                let locals: Vec<VarId> = self
                    .base
                    .iter()
                    .filter(|(v, _)| matches!(self.k.sym(**v), Sym::LocalBuf { .. }))
                    .map(|(v, _)| *v)
                    .collect();
                for v in locals {
                    let r = self.base.remove(&v).unwrap();
                    self.regs.release_i(r);
                }
                Ok(())
            }
        }
    }

    fn emit_dma(&mut self, s: &Stmt) -> Result<()> {
        let Stmt::Dma {
            dir, kind, host, host_off, local, local_off, rows, row_elems, host_stride,
            local_stride,
        } = s
        else {
            unreachable!()
        };
        let ddir = match dir {
            Dir::HostToLocal => DmaDir::HostToDev,
            Dir::LocalToHost => DmaDir::DevToHost,
        };
        // dev address = local base + 4*local_off
        let dev = {
            let base = *self
                .base
                .get(local)
                .ok_or_else(|| anyhow!("DMA local buffer {} unallocated", self.k.sym_name(*local)))?;
            let off = self.eval_i(local_off)?;
            let r = self.regs.alloc_i()?;
            self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: r, rs1: off.reg(), imm: 2 });
            self.asm.push(Inst::Alu { op: AluOp::Add, rd: r, rs1: r, rs2: base });
            self.regs.free_val_i(off);
            r
        };
        // host lo = host base + 4*host_off
        let hlo = {
            let base = *self.base.get(host).ok_or_else(|| anyhow!("bad DMA host array"))?;
            let off = self.eval_i(host_off)?;
            let r = self.regs.alloc_i()?;
            self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: r, rs1: off.reg(), imm: 2 });
            self.asm.push(Inst::Alu { op: AluOp::Add, rd: r, rs1: r, rs2: base });
            self.regs.free_val_i(off);
            r
        };
        // bytes per row
        let bytes = {
            let v = self.eval_i(row_elems)?;
            let r = self.regs.alloc_i()?;
            self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: r, rs1: v.reg(), imm: 2 });
            self.regs.free_val_i(v);
            r
        };
        match kind {
            DmaKind::Merged1D => {
                self.asm.push(Inst::DmaStart1D {
                    rd: 5,
                    dir: ddir,
                    dev,
                    host_lo: hlo,
                    host_hi: 10,
                    bytes,
                });
            }
            DmaKind::Hw2D => {
                let cnt = {
                    let v = self.eval_i(rows)?;
                    let r = self.regs.alloc_i()?;
                    self.asm.push(Inst::AluImm { op: AluOp::Add, rd: r, rs1: v.reg(), imm: 0 });
                    self.regs.free_val_i(v);
                    r
                };
                let dstr = {
                    let v = self.eval_i(local_stride)?;
                    let r = self.regs.alloc_i()?;
                    self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: r, rs1: v.reg(), imm: 2 });
                    self.regs.free_val_i(v);
                    r
                };
                let hstr = {
                    let v = self.eval_i(host_stride)?;
                    let r = self.regs.alloc_i()?;
                    self.asm.push(Inst::AluImm { op: AluOp::Sll, rd: r, rs1: v.reg(), imm: 2 });
                    self.regs.free_val_i(v);
                    r
                };
                self.asm.push(Inst::DmaStart2D {
                    rd: 5,
                    dir: ddir,
                    dev,
                    host_lo: hlo,
                    host_hi: 10,
                    bytes,
                    count: cnt,
                    dev_stride: dstr,
                    host_stride: hstr,
                });
                self.regs.release_i(cnt);
                self.regs.release_i(dstr);
                self.regs.release_i(hstr);
            }
        }
        self.regs.release_i(dev);
        self.regs.release_i(hlo);
        self.regs.release_i(bytes);
        Ok(())
    }

    // --- loops ---

    fn emit_for(&mut self, s: &Stmt, ctx: &LoopCtx) -> Result<()> {
        let Stmt::For { var, lo, hi, par, body } = s else { unreachable!() };
        match par {
            Par::None => self.emit_serial_for(*var, lo, hi, body, ctx),
            Par::Cores => self.emit_parallel_for(*var, lo, hi, body, ctx, false),
            Par::Teams => self.emit_parallel_for(*var, lo, hi, body, ctx, true),
        }
    }

    fn emit_parallel_for(
        &mut self,
        var: VarId,
        lo: &Expr,
        hi: &Expr,
        body: &[Stmt],
        ctx: &LoopCtx,
        teams: bool,
    ) -> Result<()> {
        if ctx.in_parallel && !teams {
            bail!("nested parallel regions are not supported");
        }
        // Single-participant "parallel" regions lower to plain serial loops
        // (OMP_NUM_THREADS=1 runs, Fig 4).
        let p1 = if teams { self.opts.n_clusters } else { self.opts.n_cores };
        if p1 == 1 {
            return self.emit_serial_for(var, lo, hi, body, ctx);
        }
        let region = self.asm.new_label();
        if !teams {
            self.asm.push_fork(region);
        }
        self.asm.bind(region);
        // c = my index, p = participant count (compile-time constant).
        let p = if teams { self.opts.n_clusters } else { self.opts.n_cores };
        let c = self.regs.alloc_i()?;
        self.asm.push(Inst::CsrR {
            rd: c,
            csr: if teams { Csr::MClusterId } else { Csr::MHartId },
        });
        // chunk = ceil((hi - lo) / p)
        let lo_v = self.eval_i(lo)?;
        let hi_v = self.eval_i(hi)?;
        let chunk = self.regs.alloc_i()?;
        self.asm.push(Inst::Alu { op: AluOp::Sub, rd: chunk, rs1: hi_v.reg(), rs2: lo_v.reg() });
        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: chunk, rs1: chunk, imm: p as i32 - 1 });
        let pr = self.regs.tmp_i()?;
        self.asm.push(Inst::Li { rd: pr, imm: p as i32 });
        self.asm.push(Inst::Alu { op: AluOp::Div, rd: chunk, rs1: chunk, rs2: pr });
        self.regs.temp_i.push(pr);
        // my_lo = lo + c * chunk ; my_hi = min(hi, my_lo + chunk)
        let my_lo = self.regs.alloc_i()?;
        self.asm.push(Inst::Alu { op: AluOp::Mul, rd: my_lo, rs1: c, rs2: chunk });
        self.asm.push(Inst::Alu { op: AluOp::Add, rd: my_lo, rs1: my_lo, rs2: lo_v.reg() });
        let my_hi = self.regs.alloc_i()?;
        self.asm.push(Inst::Alu { op: AluOp::Add, rd: my_hi, rs1: my_lo, rs2: chunk });
        self.emit_int_binop(BinOp::Min, my_hi, my_hi, hi_v.reg())?;
        self.regs.free_val_i(lo_v);
        self.regs.free_val_i(hi_v);
        self.regs.release_i(c);
        self.regs.release_i(chunk);
        // Serial loop over [my_lo, my_hi) with register bounds.
        let inner_ctx = LoopCtx {
            loop_vars: ctx.loop_vars.clone(),
            hw_depth: ctx.hw_depth,
            // Teams regions may still contain a (cluster-local) parallel for.
            in_parallel: ctx.in_parallel || !teams,
        };
        // `my_lo` doubles as the (pre-initialized) loop variable register.
        self.emit_counted_loop(var, my_lo, RegBound(my_hi), body, &inner_ctx)?;
        self.regs.release_i(my_lo);
        self.regs.release_i(my_hi);
        if !teams {
            self.asm.push(Inst::Join);
        }
        Ok(())
    }

    fn emit_serial_for(
        &mut self,
        var: VarId,
        lo: &Expr,
        hi: &Expr,
        body: &[Stmt],
        ctx: &LoopCtx,
    ) -> Result<()> {
        // Decide hardware loop eligibility.
        let hw_levels = self.hwloopable_levels(hi, body, ctx);
        if self.opts.xpulp && hw_levels > 0 && ctx.hw_depth + hw_levels <= 2 {
            return self.emit_hw_loop(var, lo, hi, body, ctx, hw_levels);
        }
        // Initialize the loop variable from `lo` (no register retained).
        let var_r = self.regs.alloc_i()?;
        let lo_v = self.eval_i(lo)?;
        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: var_r, rs1: lo_v.reg(), imm: 0 });
        self.regs.free_val_i(lo_v);
        let hi_v = self.eval_i(hi)?;
        let hi_r = self.regs.alloc_i()?;
        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: hi_r, rs1: hi_v.reg(), imm: 0 });
        self.regs.free_val_i(hi_v);
        self.emit_counted_loop(var, var_r, RegBound(hi_r), body, ctx)?;
        self.regs.release_i(var_r);
        self.regs.release_i(hi_r);
        Ok(())
    }

    /// Branch-form loop over `[var_r (pre-initialized), hi_reg)`.
    fn emit_counted_loop(
        &mut self,
        var: VarId,
        var_r: Reg,
        hi: RegBound,
        body: &[Stmt],
        ctx: &LoopCtx,
    ) -> Result<()> {
        self.home_i.insert(var, var_r);
        let l_end = self.asm.new_label();
        let l_loop = self.asm.new_label();
        // Zero-trip guard.
        self.asm.push_branch(Cond::Ge, var_r, hi.0, l_end);
        let inner_ctx = LoopCtx {
            loop_vars: {
                let mut v = ctx.loop_vars.clone();
                v.push(var);
                v
            },
            hw_depth: ctx.hw_depth,
            in_parallel: ctx.in_parallel,
        };
        // Preheader: SR pointers + accumulator caches.
        self.setup_sr(var, body)?;
        self.setup_acc_cache(var, body, &inner_ctx)?;
        self.asm.bind(l_loop);
        self.emit_block_scoped(body, &inner_ctx)?;
        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: var_r, rs1: var_r, imm: 1 });
        self.asm.push_branch(Cond::Lt, var_r, hi.0, l_loop);
        self.asm.bind(l_end);
        self.teardown_acc_cache();
        self.teardown_sr();
        self.home_i.remove(&var);
        // var_r is owned (and released) by the caller.
        Ok(())
    }

    /// Hardware-loop form. `levels` = 1 (this loop only) or 2 (this loop and
    /// the single inner loop both become hardware loops).
    fn emit_hw_loop(
        &mut self,
        var: VarId,
        lo: &Expr,
        hi: &Expr,
        body: &[Stmt],
        ctx: &LoopCtx,
        levels: u8,
    ) -> Result<()> {
        // Loop level: inner loops use l0, outer l1 (CV32E40P convention).
        let l = levels - 1;
        let lo_v = self.eval_i(lo)?;
        // Trip count = hi - lo.
        let hi_v = self.eval_i(hi)?;
        let count = self.regs.alloc_i()?;
        self.asm.push(Inst::Alu { op: AluOp::Sub, rd: count, rs1: hi_v.reg(), rs2: lo_v.reg() });
        self.regs.free_val_i(hi_v);
        // The loop variable is always materialized for the preheader (SR
        // pointer initialization evaluates affine forms at var = lo); the
        // per-iteration increment is only emitted if the body still uses it.
        let uses_var = body_uses_var_beyond_sr(self.k, var, body) || levels == 2;
        let var_r = self.regs.alloc_i()?;
        self.asm.push(Inst::AluImm { op: AluOp::Add, rd: var_r, rs1: lo_v.reg(), imm: 0 });
        self.home_i.insert(var, var_r);
        self.regs.free_val_i(lo_v);
        let inner_ctx = LoopCtx {
            loop_vars: {
                let mut v = ctx.loop_vars.clone();
                v.push(var);
                v
            },
            hw_depth: ctx.hw_depth + 1,
            in_parallel: ctx.in_parallel,
        };
        self.setup_sr(var, body)?;
        self.setup_acc_cache(var, body, &inner_ctx)?;
        if !uses_var {
            // The variable was only needed by the SR preheader evaluation.
            self.home_i.remove(&var);
            self.regs.release_i(var_r);
        }
        let l_start = self.asm.new_label();
        let l_end = self.asm.new_label();
        self.asm.push_hwloop(l, count, l_start, l_end);
        self.asm.bind(l_start);
        self.emit_block_scoped(body, &inner_ctx)?;
        if uses_var {
            self.asm.push(Inst::AluImm { op: AluOp::Add, rd: var_r, rs1: var_r, imm: 1 });
        }
        self.asm.bind(l_end);
        self.teardown_acc_cache();
        self.teardown_sr();
        if uses_var {
            self.home_i.remove(&var);
            self.regs.release_i(var_r);
        }
        self.regs.release_i(count);
        Ok(())
    }

    /// How many hardware-loop levels this loop supports: 0 (none), 1, or 2.
    ///
    /// Rules modelled on §3.4:
    /// * the trip count must not be tile-dependent (`Min`-shaped);
    /// * the lowered body must be branch-free: only simple statements, or
    ///   exactly one inner `For` that is itself hardware-loopable;
    /// * no may-alias load/store pair in the body (covar's symmetric store
    ///   defeats the analysis until manual register promotion).
    fn hwloopable_levels(&self, hi: &Expr, body: &[Stmt], ctx: &LoopCtx) -> u8 {
        if hi.has_minmax() {
            return 0;
        }
        let mut inner_for: Option<&Stmt> = None;
        for s in body {
            match s {
                Stmt::Store { .. } | Stmt::Let { .. } | Stmt::Assign { .. } => {}
                Stmt::For { .. } => {
                    if inner_for.is_some() {
                        return 0; // two inner loops -> branches in body
                    }
                    inner_for = Some(s);
                }
                _ => return 0, // DMA / alloc / wait in body
            }
        }
        if has_alias_hazard(self.k, body) {
            return 0;
        }
        match inner_for {
            None => 1,
            Some(Stmt::For { hi: ihi, body: ibody, par: Par::None, .. }) => {
                let inner_ctx =
                    LoopCtx { loop_vars: ctx.loop_vars.clone(), hw_depth: ctx.hw_depth, in_parallel: ctx.in_parallel };
                let inner = self.hwloopable_levels(ihi, ibody, &inner_ctx);
                if inner == 1 && ctx.hw_depth == 0 {
                    2
                } else {
                    0
                }
            }
            Some(_) => 0,
        }
    }

    // --- strength reduction setup ---

    /// Create induction pointers for the affine accesses lexically in `body`
    /// (not inside nested loops — those get their own preheaders).
    fn setup_sr(&mut self, var: VarId, body: &[Stmt]) -> Result<()> {
        let mut accesses: Vec<(VarId, Vec<Expr>, bool)> = Vec::new(); // (array, idx, is_store)
        for s in body {
            collect_direct_accesses(s, &mut accesses);
        }
        // Accesses that the accumulator cache will own get no SR pointer.
        let acc = self.acc_candidates(var, body);
        accesses.retain(|(a, i, _)| !acc.iter().any(|(ca, ci)| ca == a && ci == i));
        let mut entries: Vec<SrEntry> = Vec::new();
        for (array, idx, _) in &accesses {
            let Some(aff) = flat_offset(self.k, *array, idx) else { continue };
            // Already have an entry for this exact affine?
            if let Some(e) = entries.iter_mut().find(|e| e.array == *array && e.affine == aff) {
                e.uses += 1;
                e.uses_left += 1;
                continue;
            }
            // Stride w.r.t. this loop var must be compile-time constant; all
            // other terms must be evaluable in the preheader (loop vars of
            // enclosing loops have home registers).
            let stride = aff.coeff(var) * 4;
            // Materialize the pointer in the preheader: base + 4*aff(var=cur).
            let Ok(ptr) = self.regs.alloc_i() else { continue }; // pool pressure: skip SR
            let base = match self.base.get(array) {
                Some(b) => *b,
                None => {
                    self.regs.release_i(ptr);
                    continue;
                }
            };
            // ptr = base; then add 4*coeff*var for each term + 4*const.
            self.asm.push(Inst::AluImm { op: AluOp::Add, rd: ptr, rs1: base, imm: 0 });
            let mut ok = true;
            for (tv, c) in &aff.terms {
                let Some(&vr) = self.home_i.get(tv) else {
                    ok = false;
                    break;
                };
                let t = self.regs.tmp_i()?;
                self.asm.push(Inst::Li { rd: t, imm: (*c * 4) as i32 });
                self.asm.push(Inst::Alu { op: AluOp::Mul, rd: t, rs1: vr, rs2: t });
                self.asm.push(Inst::Alu { op: AluOp::Add, rd: ptr, rs1: ptr, rs2: t });
                self.regs.temp_i.push(t);
            }
            if !ok {
                self.regs.release_i(ptr);
                continue;
            }
            if aff.constant != 0 {
                let c = (aff.constant * 4) as i32;
                if (-2048..2048).contains(&c) {
                    self.asm.push(Inst::AluImm { op: AluOp::Add, rd: ptr, rs1: ptr, imm: c });
                } else {
                    let t = self.regs.tmp_i()?;
                    self.asm.push(Inst::Li { rd: t, imm: c });
                    self.asm.push(Inst::Alu { op: AluOp::Add, rd: ptr, rs1: ptr, rs2: t });
                    self.regs.temp_i.push(t);
                }
            }
            entries.push(SrEntry {
                array: *array,
                affine: aff,
                ptr,
                stride,
                uses: 1,
                uses_left: 1,
                host: self.is_host(*array),
            });
        }
        self.sr_stack.push(entries);
        Ok(())
    }

    fn teardown_sr(&mut self) {
        if let Some(entries) = self.sr_stack.pop() {
            for e in entries {
                self.regs.release_i(e.ptr);
            }
        }
    }

    /// Accesses in `body` that [`Lower::setup_acc_cache`] will cache:
    /// stores of the shape `dst[idx] = dst[idx] + e` with `idx` invariant in
    /// `var` and no may-aliasing second store to the same array (covar's
    /// symmetric store defeats it, §3.4).
    fn acc_candidates(&self, var: VarId, body: &[Stmt]) -> Vec<(VarId, Vec<Expr>)> {
        let mut out = Vec::new();
        for s in body {
            let Stmt::Store { dst, idx, value } = s else { continue };
            let Expr::Bin(BinOp::Add, a, _) = value else { continue };
            if **a != Expr::Load(*dst, idx.clone()) {
                continue;
            }
            let Some(aff) = flat_offset(self.k, *dst, idx) else { continue };
            if aff.coeff(var) != 0 {
                continue;
            }
            let other_store = body.iter().any(|s2| {
                if let Stmt::Store { dst: d2, idx: i2, .. } = s2 {
                    *d2 == *dst && i2 != idx
                } else {
                    false
                }
            });
            if !other_store {
                out.push((*dst, idx.clone()));
            }
        }
        out
    }

    /// Hoist loop-invariant accumulator loads into registers
    /// (`C[i][j] += ...` inside the k-loop: load once, MAC in register,
    /// store through).
    fn setup_acc_cache(&mut self, var: VarId, body: &[Stmt], _ctx: &LoopCtx) -> Result<()> {
        let candidates = self.acc_candidates(var, body);
        let mut caches: Vec<AccCache> = Vec::new();
        for s in body {
            let Stmt::Store { dst, idx, .. } = s else { continue };
            if !candidates.iter().any(|(a, i)| a == dst && i == idx) {
                continue;
            }
            let host = self.is_host(*dst);
            // Pointer (invariant): computed in preheader.
            let addr = self.eval_address(*dst, idx)?;
            let ptr = self.regs.alloc_i()?;
            self.asm.push(Inst::AluImm { op: AluOp::Add, rd: ptr, rs1: addr.reg(), imm: 0 });
            self.regs.free_val_i(addr);
            let freg = self.regs.alloc_f()?;
            if host {
                self.asm.push(Inst::FlwExt { fd: freg, rs1: ptr, offset: 0 });
            } else {
                self.asm.push(Inst::Flw { fd: freg, rs1: ptr, offset: 0 });
            }
            caches.push(AccCache { array: *dst, idx: idx.clone(), freg, ptr, host });
        }
        self.acc_stack.push(caches);
        Ok(())
    }

    fn teardown_acc_cache(&mut self) {
        if let Some(caches) = self.acc_stack.pop() {
            for c in caches {
                self.regs.release_i(c.ptr);
                self.regs.release_f(c.freg);
            }
        }
    }
}

struct RegBound(Reg);

#[derive(Clone, Copy)]
enum AccessKind {
    FLoad,
    FStore(Reg),
}

/// Collect array accesses appearing directly in a statement (descending into
/// expressions but not into nested loops).
fn collect_direct_accesses(s: &Stmt, out: &mut Vec<(VarId, Vec<Expr>, bool)>) {
    fn expr(e: &Expr, out: &mut Vec<(VarId, Vec<Expr>, bool)>) {
        match e {
            Expr::Load(a, idx) => {
                out.push((*a, idx.clone(), false));
                idx.iter().for_each(|e| expr(e, out));
            }
            Expr::Bin(_, a, b) => {
                expr(a, out);
                expr(b, out);
            }
            _ => {}
        }
    }
    match s {
        Stmt::Store { dst, idx, value } => {
            expr(value, out);
            out.push((*dst, idx.clone(), true));
        }
        Stmt::Let { value, .. } | Stmt::Assign { value, .. } => expr(value, out),
        _ => {}
    }
}

/// True if the body has a load and a store to the same array with different
/// index expressions (a may-alias pair the model's dependence analysis gives
/// up on — §3.4 covar).
fn has_alias_hazard(k: &Kernel, body: &[Stmt]) -> bool {
    let mut acc: Vec<(VarId, Vec<Expr>, bool)> = Vec::new();
    for s in body {
        collect_direct_accesses(s, &mut acc);
        if let Stmt::For { body: inner, .. } = s {
            for s2 in inner {
                collect_direct_accesses(s2, &mut acc);
            }
        }
    }
    let _ = k;
    for (a, ia, sa) in &acc {
        for (b, ib, sb) in &acc {
            if a == b && (*sa || *sb) && ia != ib {
                return true;
            }
        }
    }
    false
}

/// Does the body reference `var` outside of SR-covered (affine) subscript
/// positions? Affine subscripts fold into induction pointers, so a loop
/// whose variable only appears there needs no explicit counter.
fn body_uses_var_beyond_sr(k: &Kernel, var: VarId, body: &[Stmt]) -> bool {
    fn expr_uses(k: &Kernel, var: VarId, e: &Expr, in_idx: bool) -> bool {
        match e {
            Expr::Var(v) => *v == var && !in_idx,
            Expr::Load(a, idx) => {
                // If the whole subscript is affine, it folds into a pointer.
                let affine_ok = flat_offset(k, *a, idx).is_some();
                idx.iter().any(|i| expr_uses(k, var, i, affine_ok))
            }
            Expr::Bin(_, a, b) => expr_uses(k, var, a, in_idx) || expr_uses(k, var, b, in_idx),
            _ => false,
        }
    }
    body.iter().any(|s| match s {
        Stmt::Store { dst, idx, value } => {
            let affine_ok = flat_offset(k, *dst, idx).is_some();
            idx.iter().any(|i| expr_uses(k, var, i, affine_ok))
                || expr_uses(k, var, value, false)
        }
        Stmt::Let { value, .. } | Stmt::Assign { value, .. } => expr_uses(k, var, value, false),
        Stmt::For { lo, hi, body, .. } => {
            expr_uses(k, var, lo, false) || expr_uses(k, var, hi, false)
                || body_uses_var_beyond_sr(k, var, body)
        }
        Stmt::Dma { host_off, local_off, rows, row_elems, host_stride, local_stride, .. } => {
            [host_off, local_off, rows, row_elems, host_stride, local_stride]
                .iter()
                .any(|e| expr_uses(k, var, e, false))
        }
        _ => false,
    })
}
