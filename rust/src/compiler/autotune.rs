//! AutoDMA tiling autotuner: deterministic, exhaustive-within-bounds search
//! over the AutoDMA knobs (§2.2.2, §V).
//!
//! The paper's headline compiler result — 4.4× from inferred tiling + DMA,
//! within 15 % of handwritten code — assumes the *right* tile recipe per
//! kernel, yet [`super::autodma::transform`] applies exactly one: the
//! `S = floor((L/N)^(1/D))` descent, halved until the footprint fits. That
//! descent can overshoot badly (a start side that misses the budget by a few
//! words jumps a full 2× down, doubling the tile count per dimension), and
//! it never considers double-buffering or skipping the staging altogether.
//!
//! [`tune`] enumerates the bounded candidate space
//!
//! * the **default recipe** (what every kernel got before tuning — always
//!   candidate 0, and the tie-break winner),
//! * **direct lowering** (no staging; small problems can beat the transform
//!   overhead),
//! * **power-of-two tile sides** `4, 8, …` up to the L1 word budget, each
//!   with double-buffering **off and on** — every candidate goes through
//!   [`super::autodma::transform`] itself, so the L1-fit rule of §3.2
//!   (halve-until-fit, half budget when double-buffered) clamps infeasible
//!   knobs instead of trusting them,
//!
//! deduplicates candidates by the recipe actually *achieved*, validates
//! that each one lowers (register pressure, L1 allocation), and scores them
//! with the overlap-aware integer cycle model
//! ([`super::metrics::predict_cycles_overlap`]). Everything is integer and
//! ordered: same kernel, config and thread count ⇒ same candidate list and
//! the same winner, on every run. The scheduler caches results in
//! [`crate::sched::tune::TuneStore`] and re-ranks candidates as measured
//! cycles arrive.

use super::autodma::{self, AutoDmaOpts};
use super::ir::Kernel;
use super::lower::{self, LowerOpts};
use super::metrics::{predict_cycles_overlap, PredictOpts};
use crate::config::HeroConfig;

/// One point in the AutoDMA tuning space. The three knobs of the search:
/// lowering variant (staged vs direct), tile side, double-buffering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TunedVariant {
    /// Stage through L1 with the AutoDMA transform (`false` = lower the
    /// kernel directly against host memory).
    pub staging: bool,
    /// Tile-side override for the halve-until-fit descent (`None` = the
    /// paper's default start).
    pub tile_side: Option<i64>,
    /// Software-pipeline the innermost tiled loop (ping-pong halves).
    pub double_buffer: bool,
}

impl TunedVariant {
    /// The single recipe every AutoDMA kernel got before tuning existed:
    /// default tile descent, no double-buffering. Tuning disabled compiles
    /// exactly this.
    pub fn default_recipe() -> Self {
        TunedVariant { staging: true, tile_side: None, double_buffer: false }
    }

    pub fn is_default(&self) -> bool {
        *self == Self::default_recipe()
    }

    /// The AutoDMA options this variant compiles with (`None` = direct
    /// lowering, no transform).
    pub fn autodma_opts(&self, cfg: &HeroConfig) -> Option<AutoDmaOpts> {
        self.staging.then(|| AutoDmaOpts {
            tile_side: self.tile_side,
            double_buffer: self.double_buffer,
            ..AutoDmaOpts::for_config(cfg)
        })
    }

    /// Compact display form: `default`, `direct`, `tile=64`, `tile=64+db`.
    pub fn label(&self) -> String {
        if self.is_default() {
            return "default".into();
        }
        if !self.staging {
            return "direct".into();
        }
        let side = match self.tile_side {
            Some(s) => format!("tile={s}"),
            None => "tile=auto".into(),
        };
        if self.double_buffer {
            format!("{side}+db")
        } else {
            side
        }
    }
}

/// One scored candidate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuneCandidate {
    pub variant: TunedVariant,
    /// Overlap-aware static device-cycle prediction.
    pub predicted: u64,
}

/// Outcome of one tuning search.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuneResult {
    /// Surviving candidates in enumeration order; the default recipe is
    /// always first.
    pub candidates: Vec<TuneCandidate>,
    /// Knob combinations examined (including deduplicated and failed ones).
    pub evaluated: usize,
}

impl TuneResult {
    /// Prediction of the default recipe (candidate 0).
    pub fn default_predicted(&self) -> u64 {
        self.candidates[0].predicted
    }

    /// The statically best candidate: strict argmin over `predicted`,
    /// first-wins on ties — so the default recipe is only ever displaced by
    /// a candidate that scores strictly better.
    pub fn best(&self) -> &TuneCandidate {
        let mut best = &self.candidates[0];
        for c in &self.candidates[1..] {
            if c.predicted < best.predicted {
                best = c;
            }
        }
        best
    }
}

/// Search the AutoDMA knob space for `k` on `cfg` at `threads`.
///
/// Deterministic: fixed enumeration order, integer scoring, strict-less
/// winner selection. Every returned candidate both transformed (where
/// staged) and lowered successfully, so the scheduler can compile whichever
/// one ranks first without a fallback path. If the *default* recipe does
/// not transform, the result carries it alone — the caller then fails
/// exactly like the untuned path would, keeping failure semantics
/// identical with tuning on and off.
pub fn tune(k: &Kernel, cfg: &HeroConfig, threads: u32) -> TuneResult {
    let eff = threads.min(cfg.accel.cores_per_cluster as u32).max(1);
    let popts = PredictOpts { default_trips: 16, par_ways: eff as u64 };
    let mut lopts = LowerOpts::for_config(cfg);
    lopts.n_cores = threads.min(cfg.accel.cores_per_cluster as u32);

    let base = AutoDmaOpts::for_config(cfg);
    let mut candidates: Vec<TuneCandidate> = Vec::new();
    let mut seen: Vec<(Vec<Option<i64>>, Vec<bool>)> = Vec::new();
    let mut evaluated = 1;
    match score_staged(k, &base, &lopts, &popts) {
        Some((predicted, shape)) => {
            seen.push(shape);
            candidates.push(TuneCandidate { variant: TunedVariant::default_recipe(), predicted });
        }
        None => {
            return TuneResult {
                candidates: vec![TuneCandidate {
                    variant: TunedVariant::default_recipe(),
                    predicted: predict_cycles_overlap(k, &popts),
                }],
                evaluated,
            };
        }
    }

    // Direct lowering: skip the staging transform entirely.
    evaluated += 1;
    if lower::lower(k, &lopts).is_ok() {
        candidates.push(TuneCandidate {
            variant: TunedVariant { staging: false, tile_side: None, double_buffer: false },
            predicted: predict_cycles_overlap(k, &popts),
        });
    }

    // Power-of-two tile sides × double-buffering. A side that cannot fit
    // halves down inside the transform; a double-buffer request that cannot
    // engage reports itself off — both fold into an already-seen recipe and
    // are deduplicated, so the list holds only distinct binaries.
    let mut side = 4i64;
    while side <= base.l1_words {
        for db in [false, true] {
            evaluated += 1;
            let opts =
                AutoDmaOpts { tile_side: Some(side), double_buffer: db, ..base.clone() };
            if let Some((predicted, shape)) = score_staged(k, &opts, &lopts, &popts) {
                if !seen.contains(&shape) {
                    seen.push(shape);
                    candidates.push(TuneCandidate {
                        variant: TunedVariant {
                            staging: true,
                            tile_side: Some(side),
                            double_buffer: db,
                        },
                        predicted,
                    });
                }
            }
        }
        side *= 2;
    }
    TuneResult { candidates, evaluated }
}

/// Transform, lower and score one staged candidate; `None` when any stage
/// fails. Also returns the achieved recipe (tile side + double-buffering
/// per nest) for deduplication.
#[allow(clippy::type_complexity)]
fn score_staged(
    k: &Kernel,
    opts: &AutoDmaOpts,
    lopts: &LowerOpts,
    popts: &PredictOpts,
) -> Option<(u64, (Vec<Option<i64>>, Vec<bool>))> {
    let (tk, report) = autodma::transform(k, opts).ok()?;
    lower::lower(&tk, lopts).ok()?;
    Some((predict_cycles_overlap(&tk, popts), (report.tile_sides, report.double_buffered)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;

    #[test]
    fn tuning_is_deterministic() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let a = tune(&w.unmodified, &cfg, 8);
        let b = tune(&w.unmodified, &cfg, 8);
        assert_eq!(a, b, "same inputs must tune to the same result");
        assert_eq!(a.best(), b.best());
    }

    #[test]
    fn default_recipe_is_candidate_zero_and_wins_ties() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(24);
        let r = tune(&w.unmodified, &cfg, 8);
        assert!(r.candidates[0].variant.is_default());
        assert!(r.evaluated >= r.candidates.len());
        // best() only displaces the default on a strictly better score.
        let best = r.best();
        if best.predicted == r.default_predicted() {
            assert!(best.variant.is_default());
        }
    }

    #[test]
    fn overshooting_descent_is_beaten_by_a_power_of_two_side() {
        // gemm n=112 on aurora: the default start S=97 misses the budget
        // and halves to 48 (3×3 tiles per dim); the tuner's side 64 fits
        // (2×2 tiles) and must score strictly better.
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let r = tune(&w.unmodified, &cfg, 8);
        let best = r.best();
        assert!(
            best.predicted < r.default_predicted(),
            "best {:?} vs default {}",
            best,
            r.default_predicted()
        );
        assert!(!best.variant.is_default());
    }

    #[test]
    fn every_candidate_compiles() {
        let cfg = aurora();
        for w in [crate::workloads::gemm::build(112), crate::workloads::conv2d::build(96)] {
            let r = tune(&w.unmodified, &cfg, 8);
            for c in &r.candidates {
                let lowered = crate::bench_harness::compile_kernel_tuned(
                    &cfg,
                    &w.unmodified,
                    &c.variant,
                    8,
                );
                assert!(lowered.is_ok(), "{} {:?}: {:?}", w.name, c.variant, lowered.err());
            }
        }
    }

    #[test]
    fn tuned_variants_are_bit_identical_to_the_default_recipe() {
        // Every surviving candidate must produce byte-identical arrays:
        // strip-mining preserves per-element accumulation order, and
        // double-buffering only engages when provably value-preserving.
        let cfg = aurora();
        for w in [crate::workloads::gemm::build(112), crate::workloads::conv2d::build(112)] {
            let (def, _) =
                crate::bench_harness::compile_kernel(&cfg, &w.unmodified, true, 8).unwrap();
            let base =
                crate::bench_harness::run_lowered(&cfg, &w, &def, 11, 500_000_000).unwrap();
            let r = tune(&w.unmodified, &cfg, 8);
            assert!(r.candidates.len() > 1, "{}: search space collapsed", w.name);
            for c in &r.candidates {
                let (lowered, _) = crate::bench_harness::compile_kernel_tuned(
                    &cfg,
                    &w.unmodified,
                    &c.variant,
                    8,
                )
                .unwrap();
                let out =
                    crate::bench_harness::run_lowered(&cfg, &w, &lowered, 11, 500_000_000)
                        .unwrap();
                assert_eq!(
                    out.arrays,
                    base.arrays,
                    "{} variant {} diverged",
                    w.name,
                    c.variant.label()
                );
            }
        }
    }

    #[test]
    fn double_buffering_engages_and_wins_somewhere() {
        // At least one workload/size in the search space must see a
        // double-buffered candidate survive (the transform's safety gate
        // admits single-writer, spread-free stores — gemm and conv2d both
        // qualify once they need tiling).
        let cfg = aurora();
        let w = crate::workloads::conv2d::build(182);
        let r = tune(&w.unmodified, &cfg, 8);
        assert!(
            r.candidates.iter().any(|c| c.variant.double_buffer),
            "no double-buffered candidate survived: {:?}",
            r.candidates
        );
        assert!(r.best().predicted < r.default_predicted());
    }

    #[test]
    fn labels_are_compact() {
        assert_eq!(TunedVariant::default_recipe().label(), "default");
        assert_eq!(
            TunedVariant { staging: false, tile_side: None, double_buffer: false }.label(),
            "direct"
        );
        assert_eq!(
            TunedVariant { staging: true, tile_side: Some(64), double_buffer: true }.label(),
            "tile=64+db"
        );
    }
}
