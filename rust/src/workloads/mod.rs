//! Evaluated kernels and applications (Table 2).
//!
//! Eight workloads spanning linear algebra (2mm, 3mm, atax, bicg, gemm),
//! stencils (conv2d), data mining (covar), and an end-to-end CNN application
//! (darknet, whose convolutional layers are matrix-matrix multiplications).
//!
//! Each workload provides:
//! * `unmodified` — the plain OpenMP form (host arrays, `#pragma omp for` on
//!   the outermost computational loop, no tiling): the baseline of Figs 4/7
//!   and the AutoDMA input;
//! * `handwritten` — manually tiled with explicit `hero_memcpy*` DMA
//!   transfers (1D row-strip tiling for the six left kernels of Fig 6,
//!   2D tiling for darknet/covar), the Figs 4/5/8/9 configuration;
//! * `promoted` — the handwritten variant after *manual register promotion*
//!   (scalar accumulators, stores hoisted out of inner loops): Fig 9's
//!   second bar;
//! * `golden` — a host-side Rust reference producing expected outputs;
//! * `pjrt` — the artifact name + shapes of the AOT JAX/Pallas golden model.

pub mod atax;
pub mod bicg;
pub mod conv2d;
pub mod covar;
pub mod darknet;
pub mod gemm;
pub mod mm2;
pub mod mm3;
pub mod synth;

use crate::compiler::ir::Kernel;

/// Array role in the offload's `map` clauses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    In,
    Out,
    InOut,
}

/// One mapped array.
#[derive(Debug, Clone)]
pub struct ArraySpec {
    pub name: &'static str,
    pub elems: usize,
    pub role: Role,
    /// Logical shape (for the PJRT artifact).
    pub shape: Vec<usize>,
}

/// PJRT golden-model binding.
#[derive(Debug, Clone)]
pub struct PjrtSpec {
    /// Artifact name (`artifacts/<name>.hlo.txt`).
    pub name: String,
    /// Indices (into `arrays`) of the artifact inputs, in order.
    pub inputs: Vec<usize>,
    /// Indices of the arrays the artifact outputs correspond to, in order.
    pub outputs: Vec<usize>,
}

/// A fully-specified workload instance.
pub struct Workload {
    pub name: &'static str,
    /// Problem-size label (e.g. "128" for N=128).
    pub size: usize,
    pub arrays: Vec<ArraySpec>,
    /// Float kernel parameters (alpha, beta, ...).
    pub fargs: Vec<f32>,
    pub unmodified: Kernel,
    pub handwritten: Kernel,
    /// Manual register promotion variant (Fig 9 bar 2); `None` when the
    /// handwritten form already has nothing to promote.
    pub promoted: Option<Kernel>,
    /// Host reference: given input arrays (in `arrays` order, with zeroed
    /// outputs), returns the expected contents of every array after the
    /// offload.
    pub golden: fn(&Workload, &mut [Vec<f32>]),
    pub pjrt: PjrtSpec,
}

impl Workload {
    /// Deterministic input data for array `i` (xorshift-based, seedable).
    pub fn gen_data(&self, seed: u64) -> Vec<Vec<f32>> {
        self.arrays
            .iter()
            .enumerate()
            .map(|(i, a)| match a.role {
                Role::Out => vec![0.0; a.elems],
                _ => gen_f32(seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9), a.elems),
            })
            .collect()
    }

    /// Expected array contents after the offload.
    pub fn expected(&self, seed: u64) -> Vec<Vec<f32>> {
        let mut data = self.gen_data(seed);
        (self.golden)(self, &mut data);
        data
    }
}

/// Deterministic pseudo-random f32 in [-1, 1) (values kept small so long
/// accumulations stay well-conditioned in fp32).
pub fn gen_f32(seed: u64, n: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        })
        .collect()
}

/// All eight workloads at their paper-scale default sizes.
pub fn all_default() -> Vec<Workload> {
    vec![
        mm2::build(128),
        mm3::build(96),
        atax::build(512),
        bicg::build(512),
        conv2d::build(256),
        covar::build(128),
        darknet::build(192),
        gemm::build(128),
    ]
}

/// All eight at tiny sizes for fast correctness tests.
pub fn all_tiny() -> Vec<Workload> {
    vec![
        mm2::build(12),
        mm3::build(10),
        atax::build(24),
        bicg::build(24),
        conv2d::build(18),
        covar::build(12),
        darknet::build(14),
        gemm::build(12),
    ]
}

/// Look a workload up by name at its default size.
pub fn by_name(name: &str) -> Option<Workload> {
    all_default().into_iter().find(|w| w.name == name)
}

/// Build a workload by name at an explicit problem size.
pub fn build(name: &str, size: usize) -> Option<Workload> {
    Some(match name {
        "2mm" => mm2::build(size),
        "3mm" => mm3::build(size),
        "atax" => atax::build(size),
        "bicg" => bicg::build(size),
        "conv2d" => conv2d::build(size),
        "covar" => covar::build(size),
        "darknet" => darknet::build(size),
        "gemm" => gemm::build(size),
        _ => return None,
    })
}

/// Whether `name` is a registered kernel (cheaper than building one).
pub fn known(name: &str) -> bool {
    canonical(name).is_some()
}

/// The registry's `&'static str` for a kernel name — lets parsers that hold
/// owned strings (e.g. `hero serve --trace` ingestion) build [`crate::workloads::synth::JobDesc`]s,
/// whose kernel field is a static registry name.
pub fn canonical(name: &str) -> Option<&'static str> {
    match name {
        "2mm" => Some("2mm"),
        "3mm" => Some("3mm"),
        "atax" => Some("atax"),
        "bicg" => Some("bicg"),
        "conv2d" => Some("conv2d"),
        "covar" => Some("covar"),
        "darknet" => Some("darknet"),
        "gemm" => Some("gemm"),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic_and_bounded() {
        let a = gen_f32(42, 1000);
        let b = gen_f32(42, 1000);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| (-1.0..1.0).contains(v)));
        let c = gen_f32(43, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn registry_has_eight() {
        assert_eq!(all_default().len(), 8);
        assert_eq!(all_tiny().len(), 8);
        let names: Vec<&str> = all_default().iter().map(|w| w.name).collect();
        assert_eq!(names, ["2mm", "3mm", "atax", "bicg", "conv2d", "covar", "darknet", "gemm"]);
    }
}
