//! 2mm: `C = alpha * A * B` (Table 2) — like gemm but with a write-only
//! output (no `beta` rescale), so the handwritten tiling needs no C
//! gather before compute.

use super::*;
use crate::compiler::ir::*;

fn unmodified(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("2mm");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let (i, j, k) = (b.loop_var("i"), b.loop_var("j"), b.loop_var("k"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(n),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(c, vec![var(i), var(j)], cf(0.0)),
                for_(
                    k,
                    ci(0),
                    ci(n),
                    vec![st(
                        c,
                        vec![var(i), var(j)],
                        ld(c, vec![var(i), var(j)]).add(
                            var(alpha)
                                .mul(ld(a, vec![var(i), var(k)]))
                                .mul(ld(bb, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }])
}

fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    let r = super::gemm::strip_rows(n as usize, l1_words) as i32;
    let n_strips = (n + r - 1) / r;
    let mut b = KernelBuilder::new(if promoted { "2mm_promoted" } else { "2mm_hand" });
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let la = b.local_buf("lA", vec![ci(r), ci(n)]);
    let lb = b.local_buf("lB", vec![ci(n), ci(n)]);
    let lc = b.local_buf("lC", vec![ci(r), ci(n)]);
    let is = b.loop_var("is");
    let rows = b.let_i32("rows");
    let (ip, j, k) = (b.loop_var("ip"), b.loop_var("j"), b.loop_var("k"));
    let acc = b.let_f32("acc");
    let inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc, value: cf(0.0) },
            for_(
                k,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(
                        var(alpha)
                            .mul(ld(la, vec![var(ip), var(k)]))
                            .mul(ld(lb, vec![var(k), var(j)])),
                    ),
                }],
            ),
            st(lc, vec![var(ip), var(j)], var(acc)),
        ]
    } else {
        vec![
            st(lc, vec![var(ip), var(j)], cf(0.0)),
            for_(
                k,
                ci(0),
                ci(n),
                vec![st(
                    lc,
                    vec![var(ip), var(j)],
                    ld(lc, vec![var(ip), var(j)]).add(
                        var(alpha)
                            .mul(ld(la, vec![var(ip), var(k)]))
                            .mul(ld(lb, vec![var(k), var(j)])),
                    ),
                )],
            ),
        ]
    };
    b.body(vec![
        Stmt::LocalAlloc { var: lb, elems: ci(n * n) },
        Stmt::LocalAlloc { var: la, elems: ci(r * n) },
        Stmt::LocalAlloc { var: lc, elems: ci(r * n) },
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: bb,
            host_off: ci(0),
            local: lb,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n * n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r).min(ci(n).sub(var(is).mul(ci(r)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: a,
                    host_off: var(is).mul(ci(r * n)),
                    local: la,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For {
                    var: ip,
                    lo: ci(0),
                    hi: var(rows),
                    par: Par::Cores,
                    body: vec![for_(j, ci(0), ci(n), inner)],
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: c,
                    host_off: var(is).mul(ci(r * n)),
                    local: lc,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
    ])
}

/// C = alpha*A*B, matching the simulated association.
pub fn golden_mm(n: usize, alpha: f32, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += (alpha * a[i * n + k]) * b[k * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let a = data[0].clone();
    let b = data[1].clone();
    golden_mm(n, w.fargs[0], &a, &b, &mut data[2]);
}

pub fn build(n: usize) -> Workload {
    let ni = n as i32;
    Workload {
        name: "2mm",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "B", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "C", elems: n * n, role: Role::Out, shape: vec![n, n] },
        ],
        fargs: vec![1.5],
        unmodified: unmodified(ni),
        handwritten: handwritten(ni, 28 * 1024, false),
        promoted: Some(handwritten(ni, 28 * 1024, true)),
        golden,
        pjrt: PjrtSpec { name: format!("mm2_{n}"), inputs: vec![0, 1], outputs: vec![2] },
    }
}
