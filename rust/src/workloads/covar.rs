//! covar: `E_j = α Σ_i D_{i,j}; D_{i,j} -= E_j; S_{i,j} = S_{j,i} =
//! Σ_k D_{k,i} D_{k,j}` (Table 2, "datamining" domain).
//!
//! Iterates the full square (both triangles) so all variants compute
//! identical values; the unmodified form keeps the symmetric mirror store
//! *inside* the reduction loop — the may-alias pair that defeats both the
//! compiler's accumulator caching and its hardware-loop inference until
//! *manual register promotion* resolves it (§3.4, Fig 9).
//!
//! Temporal locality is covar's defining property: every element of D is
//! needed twice (mean pass + covariance pass) and the covariance pass
//! re-reads column tiles per tile pair — the "reload factor of two [that]
//! reduces the speed-up by DMA transfers to only 2.2×" (§3.1).

use super::*;
use crate::compiler::ir::*;

fn unmodified(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("covar");
    let d = b.host_array("D", vec![ci(n), ci(n)]);
    let e = b.host_array("E", vec![ci(n)]);
    let s = b.host_array("S", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let (j, i) = (b.loop_var("j"), b.loop_var("i"));
    let (i2, j2) = (b.loop_var("i2"), b.loop_var("j2"));
    let (j1c, j2c, k) = (b.loop_var("j1"), b.loop_var("jj"), b.loop_var("k"));
    b.body(vec![
        // Mean: E_j = alpha * Σ_i D[i][j]  (column-wise reduction).
        Stmt::For {
            var: j,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![
                st(e, vec![var(j)], cf(0.0)),
                for_(
                    i,
                    ci(0),
                    ci(n),
                    vec![st(
                        e,
                        vec![var(j)],
                        ld(e, vec![var(j)]).add(var(alpha).mul(ld(d, vec![var(i), var(j)]))),
                    )],
                ),
            ],
        },
        // Subtract the mean.
        Stmt::For {
            var: i2,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![for_(
                j2,
                ci(0),
                ci(n),
                vec![st(
                    d,
                    vec![var(i2), var(j2)],
                    ld(d, vec![var(i2), var(j2)]).sub(ld(e, vec![var(j2)])),
                )],
            )],
        },
        // Covariance with the in-loop symmetric mirror store.
        for_(
            j1c,
            ci(0),
            ci(n),
            vec![Stmt::For {
                var: j2c,
                lo: ci(0),
                hi: ci(n),
                par: Par::Cores,
                body: vec![
                    st(s, vec![var(j1c), var(j2c)], cf(0.0)),
                    for_(
                        k,
                        ci(0),
                        ci(n),
                        vec![
                            st(
                                s,
                                vec![var(j1c), var(j2c)],
                                ld(s, vec![var(j1c), var(j2c)]).add(
                                    ld(d, vec![var(k), var(j1c)])
                                        .mul(ld(d, vec![var(k), var(j2c)])),
                                ),
                            ),
                            st(
                                s,
                                vec![var(j2c), var(j1c)],
                                ld(s, vec![var(j1c), var(j2c)]),
                            ),
                        ],
                    ),
                ],
            }],
        ),
    ])
}

/// Handwritten: 2D column-tile gathers for both passes. This is the paper's
/// "implementation split over two separate iterations through the entire
/// data" with ~3× LoC overhead incurred twice (Fig 6: 6.3× total).
fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    // Column-tile width for the covariance pass: two D column tiles + one
    // S tile must fit.
    let t = {
        let mut t = 48.min(n);
        while 2 * (t * n) + t * t > l1_words as i32 {
            t /= 2;
        }
        t.max(1)
    };
    let n_tiles = (n + t - 1) / t;
    let mut b = KernelBuilder::new(if promoted { "covar_promoted" } else { "covar_hand" });
    let d = b.host_array("D", vec![ci(n), ci(n)]);
    let e = b.host_array("E", vec![ci(n)]);
    let s = b.host_array("S", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    // Pass 1 locals: one column tile of D + the E tile.
    let ld1 = b.local_buf("lD", vec![ci(n), ci(t)]);
    let le = b.local_buf("lE", vec![ci(t)]);
    let it = b.loop_var("it");
    let cols = b.let_i32("cols");
    let (cp, i1) = (b.loop_var("cp"), b.loop_var("i1"));
    let (cp2, i3) = (b.loop_var("cp2"), b.loop_var("i3"));
    let acc = b.let_f32("macc");
    // Pass 2 locals: two column tiles + S tile.
    let lda = b.local_buf("lDa", vec![ci(n), ci(t)]);
    let ldb = b.local_buf("lDb", vec![ci(n), ci(t)]);
    let lst = b.local_buf("lS", vec![ci(t), ci(t)]);
    let (ta, tb) = (b.loop_var("ta"), b.loop_var("tb"));
    let (ca, cb2) = (b.let_i32("ca"), b.let_i32("cb"));
    let (pa, pb, k) = (b.loop_var("pa"), b.loop_var("pb"), b.loop_var("k"));
    let acc2 = b.let_f32("sacc");

    // Pass 1 compute: E tile + subtract, per column of the tile.
    let mean_body: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc, value: cf(0.0) },
            for_(
                i1,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(var(alpha).mul(ld(ld1, vec![var(i1), var(cp)]))),
                }],
            ),
            st(le, vec![var(cp)], var(acc)),
        ]
    } else {
        vec![
            st(le, vec![var(cp)], cf(0.0)),
            for_(
                i1,
                ci(0),
                ci(n),
                vec![st(
                    le,
                    vec![var(cp)],
                    ld(le, vec![var(cp)])
                        .add(var(alpha).mul(ld(ld1, vec![var(i1), var(cp)]))),
                )],
            ),
        ]
    };
    let cov_body: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc2, value: cf(0.0) },
            for_(
                k,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc2,
                    value: var(acc2)
                        .add(ld(lda, vec![var(k), var(pa)]).mul(ld(ldb, vec![var(k), var(pb)]))),
                }],
            ),
            st(lst, vec![var(pa), var(pb)], var(acc2)),
        ]
    } else {
        vec![
            st(lst, vec![var(pa), var(pb)], cf(0.0)),
            for_(
                k,
                ci(0),
                ci(n),
                vec![st(
                    lst,
                    vec![var(pa), var(pb)],
                    ld(lst, vec![var(pa), var(pb)]).add(
                        ld(lda, vec![var(k), var(pa)]).mul(ld(ldb, vec![var(k), var(pb)])),
                    ),
                )],
            ),
        ]
    };

    b.body(vec![
        // ---- pass 1: mean + subtract, one column tile at a time ----
        Stmt::LocalAlloc { var: ld1, elems: ci(n * t) },
        Stmt::LocalAlloc { var: le, elems: ci(t) },
        for_(
            it,
            ci(0),
            ci(n_tiles),
            vec![
                Stmt::Let { var: cols, value: ci(t).min(ci(n).sub(var(it).mul(ci(t)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Hw2D,
                    host: d,
                    host_off: var(it).mul(ci(t)),
                    local: ld1,
                    local_off: ci(0),
                    rows: ci(n),
                    row_elems: var(cols),
                    host_stride: ci(n),
                    local_stride: ci(t),
                },
                Stmt::DmaWaitAll,
                Stmt::For { var: cp, lo: ci(0), hi: var(cols), par: Par::Cores, body: mean_body },
                // Subtract the mean in place.
                Stmt::For {
                    var: cp2,
                    lo: ci(0),
                    hi: var(cols),
                    par: Par::Cores,
                    body: vec![for_(
                        i3,
                        ci(0),
                        ci(n),
                        vec![st(
                            ld1,
                            vec![var(i3), var(cp2)],
                            ld(ld1, vec![var(i3), var(cp2)]).sub(ld(le, vec![var(cp2)])),
                        )],
                    )],
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Hw2D,
                    host: d,
                    host_off: var(it).mul(ci(t)),
                    local: ld1,
                    local_off: ci(0),
                    rows: ci(n),
                    row_elems: var(cols),
                    host_stride: ci(n),
                    local_stride: ci(t),
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: e,
                    host_off: var(it).mul(ci(t)),
                    local: le,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(cols),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
        // ---- pass 2: covariance over tile pairs (full square) ----
        Stmt::LocalFreeAll,
        Stmt::LocalAlloc { var: lda, elems: ci(n * t) },
        Stmt::LocalAlloc { var: ldb, elems: ci(n * t) },
        Stmt::LocalAlloc { var: lst, elems: ci(t * t) },
        for_(
            ta,
            ci(0),
            ci(n_tiles),
            vec![
                Stmt::Let { var: ca, value: ci(t).min(ci(n).sub(var(ta).mul(ci(t)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Hw2D,
                    host: d,
                    host_off: var(ta).mul(ci(t)),
                    local: lda,
                    local_off: ci(0),
                    rows: ci(n),
                    row_elems: var(ca),
                    host_stride: ci(n),
                    local_stride: ci(t),
                },
                Stmt::DmaWaitAll,
                for_(
                    tb,
                    ci(0),
                    ci(n_tiles),
                    vec![
                        Stmt::Let { var: cb2, value: ci(t).min(ci(n).sub(var(tb).mul(ci(t)))) },
                        // The second tile is re-gathered for every (ta, tb)
                        // pair: the reload factor the paper discusses.
                        Stmt::Dma {
                            dir: Dir::HostToLocal,
                            kind: DmaKind::Hw2D,
                            host: d,
                            host_off: var(tb).mul(ci(t)),
                            local: ldb,
                            local_off: ci(0),
                            rows: ci(n),
                            row_elems: var(cb2),
                            host_stride: ci(n),
                            local_stride: ci(t),
                        },
                        Stmt::DmaWaitAll,
                        Stmt::For {
                            var: pa,
                            lo: ci(0),
                            hi: var(ca),
                            par: Par::Cores,
                            body: vec![for_(pb, ci(0), var(cb2), cov_body)],
                        },
                        // Scatter the S tile: one 2D descriptor.
                        Stmt::Dma {
                            dir: Dir::LocalToHost,
                            kind: DmaKind::Hw2D,
                            host: s,
                            host_off: var(ta).mul(ci(t)).mul(ci(n)).add(var(tb).mul(ci(t))),
                            local: lst,
                            local_off: ci(0),
                            rows: var(ca),
                            row_elems: var(cb2),
                            host_stride: ci(n),
                            local_stride: ci(t),
                        },
                        Stmt::DmaWaitAll,
                    ],
                ),
            ],
        ),
    ])
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let alpha = w.fargs[0];
    // Mean.
    for j in 0..n {
        let mut acc = 0.0f32;
        for i in 0..n {
            acc += alpha * data[0][i * n + j];
        }
        data[1][j] = acc;
    }
    // Subtract.
    for i in 0..n {
        for j in 0..n {
            data[0][i * n + j] -= data[1][j];
        }
    }
    // Covariance (full square).
    for j1 in 0..n {
        for j2 in 0..n {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += data[0][k * n + j1] * data[0][k * n + j2];
            }
            data[2][j1 * n + j2] = acc;
        }
    }
}

pub fn build(n: usize) -> Workload {
    Workload {
        name: "covar",
        size: n,
        arrays: vec![
            ArraySpec { name: "D", elems: n * n, role: Role::InOut, shape: vec![n, n] },
            ArraySpec { name: "E", elems: n, role: Role::Out, shape: vec![n] },
            ArraySpec { name: "S", elems: n * n, role: Role::Out, shape: vec![n, n] },
        ],
        fargs: vec![1.0 / n as f32],
        unmodified: unmodified(n as i32),
        handwritten: handwritten(n as i32, 28 * 1024, false),
        promoted: Some(handwritten(n as i32, 28 * 1024, true)),
        golden,
        pjrt: PjrtSpec { name: format!("covar_{n}"), inputs: vec![0], outputs: vec![0, 1, 2] },
    }
}
