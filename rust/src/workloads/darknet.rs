//! darknet: one YOLO convolutional layer as a matrix-matrix multiplication
//! `C = α·A·B` (Table 2) at a size where **no operand fits L1**, so the
//! handwritten implementation uses two-dimensional tiling with 2D
//! scatter/gather DMA — "the tile side length of the two input matrices A
//! and B and the output matrix C is S = 97" (§3.1). darknet and covar are
//! the only applications using 2D DMA transfers, which is why their DMA
//! bars behave differently in the Fig 8 data-width sweep.

use super::*;
use crate::compiler::ir::*;

/// Paper tile side: `S = floor((L/N)^(1/D))` with L = 28 Ki words,
/// N = 3 matrices, D = 2 → 97.
pub fn tile_side(n: usize, l1_words: usize) -> usize {
    (((l1_words / 3) as f64).sqrt().floor() as usize).min(n)
}

fn unmodified(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("darknet");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let (i, j, k) = (b.loop_var("i"), b.loop_var("j"), b.loop_var("k"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(n),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(c, vec![var(i), var(j)], cf(0.0)),
                for_(
                    k,
                    ci(0),
                    ci(n),
                    vec![st(
                        c,
                        vec![var(i), var(j)],
                        ld(c, vec![var(i), var(j)]).add(
                            var(alpha)
                                .mul(ld(a, vec![var(i), var(k)]))
                                .mul(ld(bb, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }])
}

fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    let s = tile_side(n as usize, l1_words) as i32;
    let nt = (n + s - 1) / s;
    let mut b = KernelBuilder::new(if promoted { "darknet_promoted" } else { "darknet_hand" });
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let la = b.local_buf("lA", vec![ci(s), ci(s)]);
    let lb = b.local_buf("lB", vec![ci(s), ci(s)]);
    let lc = b.local_buf("lC", vec![ci(s), ci(s)]);
    let (ti, tj, tk) = (b.loop_var("ti"), b.loop_var("tj"), b.loop_var("tk"));
    let (il, jl, kl) = (b.let_i32("il"), b.let_i32("jl"), b.let_i32("kl"));
    let (ip, jp, kp) = (b.loop_var("ip"), b.loop_var("jp"), b.loop_var("kp"));
    let acc = b.let_f32("acc");
    let (zi, zj) = (b.loop_var("zi"), b.loop_var("zj"));

    let inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc, value: ld(lc, vec![var(ip), var(jp)]) },
            for_(
                kp,
                ci(0),
                var(kl),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(
                        var(alpha)
                            .mul(ld(la, vec![var(ip), var(kp)]))
                            .mul(ld(lb, vec![var(kp), var(jp)])),
                    ),
                }],
            ),
            st(lc, vec![var(ip), var(jp)], var(acc)),
        ]
    } else {
        vec![for_(
            kp,
            ci(0),
            var(kl),
            vec![st(
                lc,
                vec![var(ip), var(jp)],
                ld(lc, vec![var(ip), var(jp)]).add(
                    var(alpha)
                        .mul(ld(la, vec![var(ip), var(kp)]))
                        .mul(ld(lb, vec![var(kp), var(jp)])),
                ),
            )],
        )]
    };

    b.body(vec![
        Stmt::LocalAlloc { var: la, elems: ci(s * s) },
        Stmt::LocalAlloc { var: lb, elems: ci(s * s) },
        Stmt::LocalAlloc { var: lc, elems: ci(s * s) },
        for_(
            ti,
            ci(0),
            ci(nt),
            vec![
                Stmt::Let { var: il, value: ci(s).min(ci(n).sub(var(ti).mul(ci(s)))) },
                for_(
                    tj,
                    ci(0),
                    ci(nt),
                    vec![
                        Stmt::Let { var: jl, value: ci(s).min(ci(n).sub(var(tj).mul(ci(s)))) },
                        // Zero the C tile (C is write-only).
                        Stmt::For {
                            var: zi,
                            lo: ci(0),
                            hi: var(il),
                            par: Par::Cores,
                            body: vec![for_(
                                zj,
                                ci(0),
                                var(jl),
                                vec![st(lc, vec![var(zi), var(zj)], cf(0.0))],
                            )],
                        },
                        for_(
                            tk,
                            ci(0),
                            ci(nt),
                            vec![
                                Stmt::Let {
                                    var: kl,
                                    value: ci(s).min(ci(n).sub(var(tk).mul(ci(s)))),
                                },
                                // 2D gathers: one descriptor per tile.
                                Stmt::Dma {
                                    dir: Dir::HostToLocal,
                                    kind: DmaKind::Hw2D,
                                    host: a,
                                    host_off: var(ti).mul(ci(s)).mul(ci(n)).add(var(tk).mul(ci(s))),
                                    local: la,
                                    local_off: ci(0),
                                    rows: var(il),
                                    row_elems: var(kl),
                                    host_stride: ci(n),
                                    local_stride: ci(s),
                                },
                                Stmt::Dma {
                                    dir: Dir::HostToLocal,
                                    kind: DmaKind::Hw2D,
                                    host: bb,
                                    host_off: var(tk).mul(ci(s)).mul(ci(n)).add(var(tj).mul(ci(s))),
                                    local: lb,
                                    local_off: ci(0),
                                    rows: var(kl),
                                    row_elems: var(jl),
                                    host_stride: ci(n),
                                    local_stride: ci(s),
                                },
                                Stmt::DmaWaitAll,
                                Stmt::For {
                                    var: ip,
                                    lo: ci(0),
                                    hi: var(il),
                                    par: Par::Cores,
                                    body: vec![for_(jp, ci(0), var(jl), inner.clone())],
                                },
                            ],
                        ),
                        // Scatter the finished C tile.
                        Stmt::Dma {
                            dir: Dir::LocalToHost,
                            kind: DmaKind::Hw2D,
                            host: c,
                            host_off: var(ti).mul(ci(s)).mul(ci(n)).add(var(tj).mul(ci(s))),
                            local: lc,
                            local_off: ci(0),
                            rows: var(il),
                            row_elems: var(jl),
                            host_stride: ci(n),
                            local_stride: ci(s),
                        },
                        Stmt::DmaWaitAll,
                    ],
                ),
            ],
        ),
    ])
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let a = data[0].clone();
    let b = data[1].clone();
    super::mm2::golden_mm(n, w.fargs[0], &a, &b, &mut data[2]);
}

pub fn build(n: usize) -> Workload {
    Workload {
        name: "darknet",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "B", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "C", elems: n * n, role: Role::Out, shape: vec![n, n] },
        ],
        fargs: vec![1.0],
        unmodified: unmodified(n as i32),
        handwritten: handwritten(n as i32, 28 * 1024, false),
        promoted: Some(handwritten(n as i32, 28 * 1024, true)),
        golden,
        pjrt: PjrtSpec { name: format!("darknet_{n}"), inputs: vec![0, 1], outputs: vec![2] },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tile_side_is_97() {
        assert_eq!(tile_side(192, 28 * 1024), 97);
    }
}
