//! bicg: `Q_i = Σ_j A_{i,j} P_j → S_j = Σ_i R_i A_{i,j}` (Table 2) — two
//! consecutive offloads; the second reduces down the columns of A, so its
//! race-free OpenMP form parallelizes over `j` with `i` innermost.

use super::*;
use crate::compiler::ir::*;

fn unmodified(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("bicg");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let p = b.host_array("P", vec![ci(n)]);
    let r = b.host_array("R", vec![ci(n)]);
    let q = b.host_array("Q", vec![ci(n)]);
    let s = b.host_array("S", vec![ci(n)]);
    let _n = b.const_param("N", n);
    let (i1, j1) = (b.loop_var("i"), b.loop_var("j"));
    let (j2, i2) = (b.loop_var("j2"), b.loop_var("i2"));
    b.body(vec![
        // Q_i = Σ_j A[i][j] P[j]  (row-wise).
        Stmt::For {
            var: i1,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![
                st(q, vec![var(i1)], cf(0.0)),
                for_(
                    j1,
                    ci(0),
                    ci(n),
                    vec![st(
                        q,
                        vec![var(i1)],
                        ld(q, vec![var(i1)])
                            .add(ld(a, vec![var(i1), var(j1)]).mul(ld(p, vec![var(j1)]))),
                    )],
                ),
            ],
        },
        // S_j = Σ_i R[i] A[i][j]  (column-wise inner loop).
        Stmt::For {
            var: j2,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![
                st(s, vec![var(j2)], cf(0.0)),
                for_(
                    i2,
                    ci(0),
                    ci(n),
                    vec![st(
                        s,
                        vec![var(j2)],
                        ld(s, vec![var(j2)])
                            .add(ld(r, vec![var(i2)]).mul(ld(a, vec![var(i2), var(j2)]))),
                    )],
                ),
            ],
        },
    ])
}

/// Handwritten: phase 1 = row strips (like atax); phase 2 = row strips too,
/// but with the *strip-local* reduction order (the handwritten programmer
/// knows S can be accumulated strip by strip): S_j += Σ_{i in strip} R_i
/// A[i][j], keeping all DMA transfers long and contiguous.
fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    let r1 = ((l1_words as i32 - 3 * n) / n).clamp(1, n).min(48);
    let n_strips = (n + r1 - 1) / r1;
    let mut b = KernelBuilder::new(if promoted { "bicg_promoted" } else { "bicg_hand" });
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let p = b.host_array("P", vec![ci(n)]);
    let r = b.host_array("R", vec![ci(n)]);
    let q = b.host_array("Q", vec![ci(n)]);
    let s = b.host_array("S", vec![ci(n)]);
    let _n = b.const_param("N", n);
    let lp = b.local_buf("lP", vec![ci(n)]);
    let lr = b.local_buf("lR", vec![ci(r1)]);
    let la = b.local_buf("lA", vec![ci(r1), ci(n)]);
    let lq = b.local_buf("lQ", vec![ci(r1)]);
    let ls = b.local_buf("lS", vec![ci(n)]);
    let is = b.loop_var("is");
    let rows = b.let_i32("rows");
    let (ip, j) = (b.loop_var("ip"), b.loop_var("j"));
    let (jp, i2) = (b.loop_var("jp"), b.loop_var("i2"));
    let acc = b.let_f32("acc");
    let acc2 = b.let_f32("acc2");

    // Per-strip Q compute (row-major).
    let q_inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc, value: cf(0.0) },
            for_(
                j,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(ld(la, vec![var(ip), var(j)]).mul(ld(lp, vec![var(j)]))),
                }],
            ),
            st(lq, vec![var(ip)], var(acc)),
        ]
    } else {
        vec![
            st(lq, vec![var(ip)], cf(0.0)),
            for_(
                j,
                ci(0),
                ci(n),
                vec![st(
                    lq,
                    vec![var(ip)],
                    ld(lq, vec![var(ip)])
                        .add(ld(la, vec![var(ip), var(j)]).mul(ld(lp, vec![var(j)]))),
                )],
            ),
        ]
    };
    // Per-strip S accumulation: each core owns a j-chunk; inner loop over
    // strip rows reads A column-wise *within L1* (single-cycle TCDM, so the
    // column walk is cheap once the strip is local).
    let s_inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc2, value: ld(ls, vec![var(jp)]) },
            for_(
                i2,
                ci(0),
                var(rows),
                vec![Stmt::Assign {
                    var: acc2,
                    value: var(acc2)
                        .add(ld(lr, vec![var(i2)]).mul(ld(la, vec![var(i2), var(jp)]))),
                }],
            ),
            st(ls, vec![var(jp)], var(acc2)),
        ]
    } else {
        vec![for_(
            i2,
            ci(0),
            var(rows),
            vec![st(
                ls,
                vec![var(jp)],
                ld(ls, vec![var(jp)])
                    .add(ld(lr, vec![var(i2)]).mul(ld(la, vec![var(i2), var(jp)]))),
            )],
        )]
    };

    let zero_j = b.loop_var("jz");
    b.body(vec![
        Stmt::LocalAlloc { var: lp, elems: ci(n) },
        Stmt::LocalAlloc { var: ls, elems: ci(n) },
        Stmt::LocalAlloc { var: lr, elems: ci(r1) },
        Stmt::LocalAlloc { var: la, elems: ci(r1 * n) },
        Stmt::LocalAlloc { var: lq, elems: ci(r1) },
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: p,
            host_off: ci(0),
            local: lp,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        // Zero the S accumulator in L1.
        Stmt::For {
            var: zero_j,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![st(ls, vec![var(zero_j)], cf(0.0))],
        },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r1).min(ci(n).sub(var(is).mul(ci(r1)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: a,
                    host_off: var(is).mul(ci(r1 * n)),
                    local: la,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: r,
                    host_off: var(is).mul(ci(r1)),
                    local: lr,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For { var: ip, lo: ci(0), hi: var(rows), par: Par::Cores, body: q_inner },
                Stmt::For { var: jp, lo: ci(0), hi: ci(n), par: Par::Cores, body: s_inner },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: q,
                    host_off: var(is).mul(ci(r1)),
                    local: lq,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
        Stmt::Dma {
            dir: Dir::LocalToHost,
            kind: DmaKind::Merged1D,
            host: s,
            host_off: ci(0),
            local: ls,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        Stmt::DmaWaitAll,
    ])
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let a = data[0].clone();
    let p = data[1].clone();
    let r = data[2].clone();
    for i in 0..n {
        let mut acc = 0.0f32;
        for j in 0..n {
            acc += a[i * n + j] * p[j];
        }
        data[3][i] = acc;
    }
    for j in 0..n {
        let mut acc = 0.0f32;
        for i in 0..n {
            acc += r[i] * a[i * n + j];
        }
        data[4][j] = acc;
    }
}

pub fn build(n: usize) -> Workload {
    Workload {
        name: "bicg",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "P", elems: n, role: Role::In, shape: vec![n] },
            ArraySpec { name: "R", elems: n, role: Role::In, shape: vec![n] },
            ArraySpec { name: "Q", elems: n, role: Role::Out, shape: vec![n] },
            ArraySpec { name: "S", elems: n, role: Role::Out, shape: vec![n] },
        ],
        fargs: vec![],
        unmodified: unmodified(n as i32),
        handwritten: handwritten(n as i32, 28 * 1024, false),
        promoted: Some(handwritten(n as i32, 28 * 1024, true)),
        golden,
        pjrt: PjrtSpec { name: format!("bicg_{n}"), inputs: vec![0, 1, 2], outputs: vec![3, 4] },
    }
}
