//! atax: `B = A·x → Y_i = Σ_j A_{j,i} B_j` (Table 2) — the second phase
//! walks A **column-wise**, which is the paper's showcase for both the
//! post-increment limitation (§3.4: "the increment of one of the two loads
//! is too large") and AutoDMA's word-wise degradation (§3.2).

use super::*;
use crate::compiler::ir::*;

fn unmodified(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("atax");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let x = b.host_array("X", vec![ci(n)]);
    let bv = b.host_array("B", vec![ci(n)]);
    let y = b.host_array("Y", vec![ci(n)]);
    let _n = b.const_param("N", n);
    let (i1, j1) = (b.loop_var("i"), b.loop_var("j"));
    let (i2, j2) = (b.loop_var("i2"), b.loop_var("j2"));
    b.body(vec![
        // Phase 1: B_i = Σ_j A[i][j] * X[j]  (row-wise).
        Stmt::For {
            var: i1,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![
                st(bv, vec![var(i1)], cf(0.0)),
                for_(
                    j1,
                    ci(0),
                    ci(n),
                    vec![st(
                        bv,
                        vec![var(i1)],
                        ld(bv, vec![var(i1)])
                            .add(ld(a, vec![var(i1), var(j1)]).mul(ld(x, vec![var(j1)]))),
                    )],
                ),
            ],
        },
        // Phase 2: Y_i = Σ_j A[j][i] * B[j]  (column-wise!).
        Stmt::For {
            var: i2,
            lo: ci(0),
            hi: ci(n),
            par: Par::Cores,
            body: vec![
                st(y, vec![var(i2)], cf(0.0)),
                for_(
                    j2,
                    ci(0),
                    ci(n),
                    vec![st(
                        y,
                        vec![var(i2)],
                        ld(y, vec![var(i2)])
                            .add(ld(a, vec![var(j2), var(i2)]).mul(ld(bv, vec![var(j2)]))),
                    )],
                ),
            ],
        },
    ])
}

fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    // Phase 1: row strips (X resident). Phase 2: column tiles gathered
    // with a single 2D DMA descriptor per tile — "the DMA engine's
    // capability for gather-scatter transfers and many outstanding requests
    // offers a speed-up of more than 4x even with low spatial locality"
    // (§3.1).
    let r1 = ((l1_words as i32 - n) / n).clamp(1, n).min(48); // phase-1 strip rows
    let t2 = ((l1_words as i32 - 2 * n) / n).clamp(1, n).min(48); // phase-2 column-tile width
    let n_strips = (n + r1 - 1) / r1;
    let n_tiles = (n + t2 - 1) / t2;
    let mut b = KernelBuilder::new(if promoted { "atax_promoted" } else { "atax_hand" });
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let x = b.host_array("X", vec![ci(n)]);
    let bv = b.host_array("B", vec![ci(n)]);
    let y = b.host_array("Y", vec![ci(n)]);
    let _n = b.const_param("N", n);
    // Phase 1 locals.
    let lx = b.local_buf("lX", vec![ci(n)]);
    let la = b.local_buf("lA", vec![ci(r1), ci(n)]);
    let lb = b.local_buf("lB", vec![ci(r1)]);
    let is = b.loop_var("is");
    let rows = b.let_i32("rows");
    let (ip, j) = (b.loop_var("ip"), b.loop_var("j"));
    let acc = b.let_f32("acc");
    // Phase 2 locals.
    let lat = b.local_buf("lAT", vec![ci(n), ci(t2)]);
    let lbf = b.local_buf("lBf", vec![ci(n)]);
    let ly = b.local_buf("lY", vec![ci(t2)]);
    let it = b.loop_var("it");
    let cols = b.let_i32("cols");
    let (cp, j2) = (b.loop_var("cp"), b.loop_var("j2"));
    let acc2 = b.let_f32("acc2");

    let p1_inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc, value: cf(0.0) },
            for_(
                j,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(ld(la, vec![var(ip), var(j)]).mul(ld(lx, vec![var(j)]))),
                }],
            ),
            st(lb, vec![var(ip)], var(acc)),
        ]
    } else {
        vec![
            st(lb, vec![var(ip)], cf(0.0)),
            for_(
                j,
                ci(0),
                ci(n),
                vec![st(
                    lb,
                    vec![var(ip)],
                    ld(lb, vec![var(ip)])
                        .add(ld(la, vec![var(ip), var(j)]).mul(ld(lx, vec![var(j)]))),
                )],
            ),
        ]
    };
    let p2_inner: Vec<Stmt> = if promoted {
        vec![
            Stmt::Let { var: acc2, value: cf(0.0) },
            for_(
                j2,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc2,
                    value: var(acc2)
                        .add(ld(lat, vec![var(j2), var(cp)]).mul(ld(lbf, vec![var(j2)]))),
                }],
            ),
            st(ly, vec![var(cp)], var(acc2)),
        ]
    } else {
        vec![
            st(ly, vec![var(cp)], cf(0.0)),
            for_(
                j2,
                ci(0),
                ci(n),
                vec![st(
                    ly,
                    vec![var(cp)],
                    ld(ly, vec![var(cp)])
                        .add(ld(lat, vec![var(j2), var(cp)]).mul(ld(lbf, vec![var(j2)]))),
                )],
            ),
        ]
    };

    b.body(vec![
        // ---- phase 1: B = A x ----
        Stmt::LocalAlloc { var: lx, elems: ci(n) },
        Stmt::LocalAlloc { var: la, elems: ci(r1 * n) },
        Stmt::LocalAlloc { var: lb, elems: ci(r1) },
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: x,
            host_off: ci(0),
            local: lx,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r1).min(ci(n).sub(var(is).mul(ci(r1)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: a,
                    host_off: var(is).mul(ci(r1 * n)),
                    local: la,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For {
                    var: ip,
                    lo: ci(0),
                    hi: var(rows),
                    par: Par::Cores,
                    body: p1_inner,
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: bv,
                    host_off: var(is).mul(ci(r1)),
                    local: lb,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
        // ---- phase 2: Y_i = Σ_j A[j][i] B[j] over column tiles ----
        Stmt::LocalFreeAll,
        Stmt::LocalAlloc { var: lat, elems: ci(n * t2) },
        Stmt::LocalAlloc { var: lbf, elems: ci(n) },
        Stmt::LocalAlloc { var: ly, elems: ci(t2) },
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: bv,
            host_off: ci(0),
            local: lbf,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        for_(
            it,
            ci(0),
            ci(n_tiles),
            vec![
                Stmt::Let { var: cols, value: ci(t2).min(ci(n).sub(var(it).mul(ci(t2)))) },
                // One 2D descriptor gathers N rows of the column tile.
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Hw2D,
                    host: a,
                    host_off: var(it).mul(ci(t2)),
                    local: lat,
                    local_off: ci(0),
                    rows: ci(n),
                    row_elems: var(cols),
                    host_stride: ci(n),
                    local_stride: ci(t2),
                },
                Stmt::DmaWaitAll,
                Stmt::For { var: cp, lo: ci(0), hi: var(cols), par: Par::Cores, body: p2_inner },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: y,
                    host_off: var(it).mul(ci(t2)),
                    local: ly,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(cols),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
    ])
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let a = data[0].clone();
    let x = data[1].clone();
    for i in 0..n {
        let mut acc = 0.0f32;
        for j in 0..n {
            acc += a[i * n + j] * x[j];
        }
        data[2][i] = acc;
    }
    let bv = data[2].clone();
    for i in 0..n {
        let mut acc = 0.0f32;
        for j in 0..n {
            acc += a[j * n + i] * bv[j];
        }
        data[3][i] = acc;
    }
}

pub fn build(n: usize) -> Workload {
    Workload {
        name: "atax",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "X", elems: n, role: Role::In, shape: vec![n] },
            ArraySpec { name: "B", elems: n, role: Role::Out, shape: vec![n] },
            ArraySpec { name: "Y", elems: n, role: Role::Out, shape: vec![n] },
        ],
        fargs: vec![],
        unmodified: unmodified(n as i32),
        handwritten: handwritten(n as i32, 28 * 1024, false),
        promoted: Some(handwritten(n as i32, 28 * 1024, true)),
        golden,
        pjrt: PjrtSpec { name: format!("atax_{n}"), inputs: vec![0, 1], outputs: vec![2, 3] },
    }
}
