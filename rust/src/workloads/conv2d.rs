//! conv2d: 3×3 stencil `B_{i,j} = Σ_{k,l} c_{k,l} A_{i+k,j+l}` (Table 2,
//! "stencil" domain). Taps are unrolled constants as in Polybench.

use super::*;
use crate::compiler::ir::*;

/// The nine tap coefficients (Polybench-style constants).
pub const TAPS: [[f32; 3]; 3] =
    [[0.2, 0.5, -0.8], [-0.3, 0.6, -0.9], [0.4, 0.7, 0.10]];

fn stencil_expr(a: VarId, i: Expr, j: Expr) -> Expr {
    let mut terms: Vec<Expr> = Vec::new();
    for (k, row) in TAPS.iter().enumerate() {
        for (l, c) in row.iter().enumerate() {
            terms.push(cf(*c).mul(ld(
                a,
                vec![i.clone().add(ci(k as i32)), j.clone().add(ci(l as i32))],
            )));
        }
    }
    let mut e = terms.remove(0);
    for t in terms {
        e = e.add(t);
    }
    e
}

fn unmodified(n: i32) -> Kernel {
    let m = n - 2;
    let mut b = KernelBuilder::new("conv2d");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(m), ci(m)]);
    let _n = b.const_param("N", n);
    let (i, j) = (b.loop_var("i"), b.loop_var("j"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(m),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(m),
            vec![st(bb, vec![var(i), var(j)], stencil_expr(a, var(i), var(j)))],
        )],
    }])
}

fn handwritten(n: i32, l1_words: usize) -> Kernel {
    let m = n - 2;
    // Row strips with a 2-row halo; strips are contiguous (full-width rows).
    let r = ((l1_words as i32 - 2 * n) / (2 * n)).clamp(1, m).min(48);
    let n_strips = (m + r - 1) / r;
    let mut b = KernelBuilder::new("conv2d_hand");
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(m), ci(m)]);
    let _n = b.const_param("N", n);
    let la = b.local_buf("lA", vec![ci(r + 2), ci(n)]);
    let lb = b.local_buf("lB", vec![ci(r), ci(m)]);
    let is = b.loop_var("is");
    let rows = b.let_i32("rows");
    let (ip, j) = (b.loop_var("ip"), b.loop_var("j"));
    b.body(vec![
        Stmt::LocalAlloc { var: la, elems: ci((r + 2) * n) },
        Stmt::LocalAlloc { var: lb, elems: ci(r * m) },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r).min(ci(m).sub(var(is).mul(ci(r)))) },
                // Strip + halo: one merged burst of (rows+2) full rows.
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: a,
                    host_off: var(is).mul(ci(r * n)),
                    local: la,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).add(ci(2)).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For {
                    var: ip,
                    lo: ci(0),
                    hi: var(rows),
                    par: Par::Cores,
                    body: vec![for_(
                        j,
                        ci(0),
                        ci(m),
                        vec![st(lb, vec![var(ip), var(j)], stencil_expr(la, var(ip), var(j)))],
                    )],
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: bb,
                    host_off: var(is).mul(ci(r * m)),
                    local: lb,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(m)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
    ])
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let m = n - 2;
    let a = data[0].clone();
    for i in 0..m {
        for j in 0..m {
            // Same summation order as `stencil_expr` (left-to-right adds).
            let mut acc = TAPS[0][0] * a[i * n + j];
            for (k, row) in TAPS.iter().enumerate() {
                for (l, c) in row.iter().enumerate() {
                    if k == 0 && l == 0 {
                        continue;
                    }
                    acc += *c * a[(i + k) * n + (j + l)];
                }
            }
            data[1][i * m + j] = acc;
        }
    }
}

pub fn build(n: usize) -> Workload {
    let m = n - 2;
    Workload {
        name: "conv2d",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "B", elems: m * m, role: Role::Out, shape: vec![m, m] },
        ],
        fargs: vec![],
        unmodified: unmodified(n as i32),
        handwritten: handwritten(n as i32, 28 * 1024),
        promoted: None, // nothing to promote: single store, no reduction loop
        golden,
        pjrt: PjrtSpec { name: format!("conv2d_{n}"), inputs: vec![0], outputs: vec![1] },
    }
}
