//! gemm: `C = beta*C + alpha*A*B` (Table 2) — the paper's running example
//! for the Xpulpv2 case study (§3.4).

use super::*;
use crate::compiler::ir::*;

/// Row-strip size for the handwritten tiling: B stays resident in L1 and A/C
/// move through row strips — strips are contiguous in memory, so the
/// handwritten code "transfers multiple rows of matrices at once" (§3.2)
/// with a single merged burst.
pub fn strip_rows(n: usize, l1_words: usize) -> usize {
    if 3 * n * n <= l1_words {
        return n; // everything resident: one "strip"
    }
    let left = l1_words.saturating_sub(n * n);
    (left / (2 * n)).clamp(1, n)
}

fn unmodified(n: i32, name: &str) -> Kernel {
    let mut b = KernelBuilder::new(name);
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let beta = b.float_param("beta");
    let (i, j, k) = (b.loop_var("i"), b.loop_var("j"), b.loop_var("k"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(n),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(c, vec![var(i), var(j)], ld(c, vec![var(i), var(j)]).mul(var(beta))),
                for_(
                    k,
                    ci(0),
                    ci(n),
                    vec![st(
                        c,
                        vec![var(i), var(j)],
                        ld(c, vec![var(i), var(j)]).add(
                            var(alpha)
                                .mul(ld(a, vec![var(i), var(k)]))
                                .mul(ld(bb, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }])
}

fn handwritten(n: i32, l1_words: usize, promoted: bool) -> Kernel {
    let r = strip_rows(n as usize, l1_words) as i32;
    let n_strips = (n + r - 1) / r;
    let mut b = KernelBuilder::new(if promoted { "gemm_promoted" } else { "gemm_hand" });
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    let beta = b.float_param("beta");
    let la = b.local_buf("lA", vec![ci(r), ci(n)]);
    let lb = b.local_buf("lB", vec![ci(n), ci(n)]);
    let lc = b.local_buf("lC", vec![ci(r), ci(n)]);
    let is = b.loop_var("is");
    let rows = b.let_i32("rows");
    let (ip, j, k) = (b.loop_var("ip"), b.loop_var("j"), b.loop_var("k"));
    let acc = b.let_f32("acc");

    let inner_acc: Vec<Stmt> = if promoted {
        // Manual register promotion: scalar accumulator, store after loop.
        vec![
            Stmt::Let {
                var: acc,
                value: ld(lc, vec![var(ip), var(j)]).mul(var(beta)),
            },
            for_(
                k,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(
                        var(alpha)
                            .mul(ld(la, vec![var(ip), var(k)]))
                            .mul(ld(lb, vec![var(k), var(j)])),
                    ),
                }],
            ),
            st(lc, vec![var(ip), var(j)], var(acc)),
        ]
    } else {
        vec![
            st(lc, vec![var(ip), var(j)], ld(lc, vec![var(ip), var(j)]).mul(var(beta))),
            for_(
                k,
                ci(0),
                ci(n),
                vec![st(
                    lc,
                    vec![var(ip), var(j)],
                    ld(lc, vec![var(ip), var(j)]).add(
                        var(alpha)
                            .mul(ld(la, vec![var(ip), var(k)]))
                            .mul(ld(lb, vec![var(k), var(j)])),
                    ),
                )],
            ),
        ]
    };

    b.body(vec![
        Stmt::LocalAlloc { var: lb, elems: ci(n * n) },
        Stmt::LocalAlloc { var: la, elems: ci(r * n) },
        Stmt::LocalAlloc { var: lc, elems: ci(r * n) },
        // B is resident for the whole kernel: one merged transfer.
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: bb,
            host_off: ci(0),
            local: lb,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n * n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r).min(ci(n).sub(var(is).mul(ci(r)))) },
                // A and C strips: rows are adjacent in memory — single
                // merged burst of rows*N elements each.
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: a,
                    host_off: var(is).mul(ci(r * n)),
                    local: la,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: c,
                    host_off: var(is).mul(ci(r * n)),
                    local: lc,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For {
                    var: ip,
                    lo: ci(0),
                    hi: var(rows),
                    par: Par::Cores,
                    body: vec![for_(j, ci(0), ci(n), inner_acc)],
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: c,
                    host_off: var(is).mul(ci(r * n)),
                    local: lc,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
    ])
}

/// Host reference (bit-exact against the simulated arithmetic: same
/// association `(alpha*a)*b` and same accumulation order).
pub fn golden_gemm(n: usize, alpha: f32, beta: f32, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..n {
        for j in 0..n {
            let mut acc = c[i * n + j] * beta;
            for k in 0..n {
                acc += (alpha * a[i * n + k]) * b[k * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let (alpha, beta) = (w.fargs[0], w.fargs[1]);
    let a = data[0].clone();
    let b = data[1].clone();
    golden_gemm(n, alpha, beta, &a, &b, &mut data[2]);
}

/// Build the gemm workload for size `n`.
pub fn build(n: usize) -> Workload {
    let ni = n as i32;
    let l1_words = 28 * 1024; // Aurora user L1 (§3.1)
    Workload {
        name: "gemm",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "B", elems: n * n, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "C", elems: n * n, role: Role::InOut, shape: vec![n, n] },
        ],
        fargs: vec![1.5, 1.2],
        unmodified: unmodified(ni, "gemm"),
        handwritten: handwritten(ni, l1_words, false),
        promoted: Some(handwritten(ni, l1_words, true)),
        golden,
        pjrt: PjrtSpec {
            name: format!("gemm_{n}"),
            inputs: vec![0, 1, 2],
            outputs: vec![2],
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{addrspace, metrics};

    #[test]
    fn variants_pass_addrspace() {
        let w = build(12);
        addrspace::analyze(&w.unmodified).unwrap();
        addrspace::analyze(&w.handwritten).unwrap();
        addrspace::analyze(w.promoted.as_ref().unwrap()).unwrap();
    }

    #[test]
    fn handwritten_is_more_complex() {
        // Fig 6: 1D tiling costs 1.7-2.5x LoC, 1.3-1.5x cyclomatic.
        let w = build(128);
        let u = metrics::complexity(&w.unmodified);
        let h = metrics::complexity(&w.handwritten);
        let loc_ratio = h.loc as f64 / u.loc as f64;
        let cyc_ratio = h.cyclomatic as f64 / u.cyclomatic as f64;
        assert!((1.5..3.2).contains(&loc_ratio), "LoC ratio {loc_ratio}");
        assert!((1.0..2.0).contains(&cyc_ratio), "cyclomatic ratio {cyc_ratio}");
    }

    #[test]
    fn strip_rows_fits_budget() {
        let r = strip_rows(128, 28 * 1024);
        assert_eq!(r, 48);
        assert!(128 * 128 + 2 * r * 128 <= 28 * 1024);
        assert_eq!(strip_rows(12, 28 * 1024), 12); // tiny: fully resident
    }

    #[test]
    fn golden_matches_naive() {
        let n = 4;
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..16).map(|i| (15 - i) as f32 * 0.5).collect();
        let mut c = vec![1.0; 16];
        golden_gemm(n, 2.0, 0.5, &a, &b, &mut c);
        // Spot check C[1][2].
        let mut want = 1.0f32 * 0.5;
        for k in 0..4 {
            want += (2.0 * a[4 + k]) * b[k * 4 + 2];
        }
        assert_eq!(c[6], want);
    }
}
