//! 3mm: `E = 2mm(A,B) → F = 2mm(C,D) → G = 2mm(E,F)` (Table 2) — three
//! consecutive offload nests (arrows in the paper's table) sharing the L1
//! heap via `hero_l1_free_all` between nests.

use super::*;
use crate::compiler::ir::*;

/// One alpha-matmul nest `out = alpha * x * y` over N×N host arrays.
fn mm_nest(
    b: &mut KernelBuilder,
    n: i32,
    x: VarId,
    y: VarId,
    out: VarId,
    alpha: VarId,
    tag: &str,
) -> Stmt {
    let (i, j, k) =
        (b.loop_var(&format!("i{tag}")), b.loop_var(&format!("j{tag}")), b.loop_var(&format!("k{tag}")));
    Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(n),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(out, vec![var(i), var(j)], cf(0.0)),
                for_(
                    k,
                    ci(0),
                    ci(n),
                    vec![st(
                        out,
                        vec![var(i), var(j)],
                        ld(out, vec![var(i), var(j)]).add(
                            var(alpha)
                                .mul(ld(x, vec![var(i), var(k)]))
                                .mul(ld(y, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }
}

/// One handwritten strip-tiled alpha-matmul nest (y resident, x/out strips).
#[allow(clippy::too_many_arguments)]
fn mm_nest_hand(
    b: &mut KernelBuilder,
    n: i32,
    r: i32,
    x: VarId,
    y: VarId,
    out: VarId,
    alpha: VarId,
    tag: &str,
    promoted: bool,
) -> Vec<Stmt> {
    let n_strips = (n + r - 1) / r;
    let lx = b.local_buf(&format!("lX{tag}"), vec![ci(r), ci(n)]);
    let ly = b.local_buf(&format!("lY{tag}"), vec![ci(n), ci(n)]);
    let lo = b.local_buf(&format!("lO{tag}"), vec![ci(r), ci(n)]);
    let is = b.loop_var(&format!("is{tag}"));
    let rows = b.let_i32(&format!("rows{tag}"));
    let (ip, j, k) =
        (b.loop_var(&format!("ip{tag}")), b.loop_var(&format!("j{tag}")), b.loop_var(&format!("k{tag}")));
    let inner: Vec<Stmt> = if promoted {
        let acc = b.let_f32(&format!("acc{tag}"));
        vec![
            Stmt::Let { var: acc, value: cf(0.0) },
            for_(
                k,
                ci(0),
                ci(n),
                vec![Stmt::Assign {
                    var: acc,
                    value: var(acc).add(
                        var(alpha)
                            .mul(ld(lx, vec![var(ip), var(k)]))
                            .mul(ld(ly, vec![var(k), var(j)])),
                    ),
                }],
            ),
            st(lo, vec![var(ip), var(j)], var(acc)),
        ]
    } else {
        vec![
            st(lo, vec![var(ip), var(j)], cf(0.0)),
            for_(
                k,
                ci(0),
                ci(n),
                vec![st(
                    lo,
                    vec![var(ip), var(j)],
                    ld(lo, vec![var(ip), var(j)]).add(
                        var(alpha)
                            .mul(ld(lx, vec![var(ip), var(k)]))
                            .mul(ld(ly, vec![var(k), var(j)])),
                    ),
                )],
            ),
        ]
    };
    vec![
        Stmt::LocalAlloc { var: ly, elems: ci(n * n) },
        Stmt::LocalAlloc { var: lx, elems: ci(r * n) },
        Stmt::LocalAlloc { var: lo, elems: ci(r * n) },
        Stmt::Dma {
            dir: Dir::HostToLocal,
            kind: DmaKind::Merged1D,
            host: y,
            host_off: ci(0),
            local: ly,
            local_off: ci(0),
            rows: ci(1),
            row_elems: ci(n * n),
            host_stride: ci(0),
            local_stride: ci(0),
        },
        for_(
            is,
            ci(0),
            ci(n_strips),
            vec![
                Stmt::Let { var: rows, value: ci(r).min(ci(n).sub(var(is).mul(ci(r)))) },
                Stmt::Dma {
                    dir: Dir::HostToLocal,
                    kind: DmaKind::Merged1D,
                    host: x,
                    host_off: var(is).mul(ci(r * n)),
                    local: lx,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
                Stmt::For {
                    var: ip,
                    lo: ci(0),
                    hi: var(rows),
                    par: Par::Cores,
                    body: vec![for_(j, ci(0), ci(n), inner.clone())],
                },
                Stmt::Dma {
                    dir: Dir::LocalToHost,
                    kind: DmaKind::Merged1D,
                    host: out,
                    host_off: var(is).mul(ci(r * n)),
                    local: lo,
                    local_off: ci(0),
                    rows: ci(1),
                    row_elems: var(rows).mul(ci(n)),
                    host_stride: ci(0),
                    local_stride: ci(0),
                },
                Stmt::DmaWaitAll,
            ],
        ),
    ]
}

fn build_kernel(n: i32, variant: u8) -> Kernel {
    let name = match variant {
        0 => "3mm",
        1 => "3mm_hand",
        _ => "3mm_promoted",
    };
    let mut b = KernelBuilder::new(name);
    let a = b.host_array("A", vec![ci(n), ci(n)]);
    let bb = b.host_array("B", vec![ci(n), ci(n)]);
    let c = b.host_array("C", vec![ci(n), ci(n)]);
    let d = b.host_array("D", vec![ci(n), ci(n)]);
    let e = b.host_array("E", vec![ci(n), ci(n)]);
    let f = b.host_array("F", vec![ci(n), ci(n)]);
    let g = b.host_array("G", vec![ci(n), ci(n)]);
    let _n = b.const_param("N", n);
    let alpha = b.float_param("alpha");
    if variant == 0 {
        let n1 = mm_nest(&mut b, n, a, bb, e, alpha, "1");
        let n2 = mm_nest(&mut b, n, c, d, f, alpha, "2");
        let n3 = mm_nest(&mut b, n, e, f, g, alpha, "3");
        b.body(vec![n1, n2, n3])
    } else {
        let promoted = variant == 2;
        let r = super::gemm::strip_rows(n as usize, 28 * 1024) as i32;
        let mut body = mm_nest_hand(&mut b, n, r, a, bb, e, alpha, "1", promoted);
        body.push(Stmt::LocalFreeAll);
        body.extend(mm_nest_hand(&mut b, n, r, c, d, f, alpha, "2", promoted));
        body.push(Stmt::LocalFreeAll);
        body.extend(mm_nest_hand(&mut b, n, r, e, f, g, alpha, "3", promoted));
        b.body(body)
    }
}

fn golden(w: &Workload, data: &mut [Vec<f32>]) {
    let n = w.size;
    let alpha = w.fargs[0];
    let (a, b, c, d) = (data[0].clone(), data[1].clone(), data[2].clone(), data[3].clone());
    super::mm2::golden_mm(n, alpha, &a, &b, &mut data[4]);
    super::mm2::golden_mm(n, alpha, &c, &d, &mut data[5]);
    let (e, f) = (data[4].clone(), data[5].clone());
    super::mm2::golden_mm(n, alpha, &e, &f, &mut data[6]);
}

pub fn build(n: usize) -> Workload {
    let sq = n * n;
    Workload {
        name: "3mm",
        size: n,
        arrays: vec![
            ArraySpec { name: "A", elems: sq, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "B", elems: sq, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "C", elems: sq, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "D", elems: sq, role: Role::In, shape: vec![n, n] },
            ArraySpec { name: "E", elems: sq, role: Role::Out, shape: vec![n, n] },
            ArraySpec { name: "F", elems: sq, role: Role::Out, shape: vec![n, n] },
            ArraySpec { name: "G", elems: sq, role: Role::Out, shape: vec![n, n] },
        ],
        fargs: vec![1.25],
        unmodified: build_kernel(n as i32, 0),
        handwritten: build_kernel(n as i32, 1),
        promoted: Some(build_kernel(n as i32, 2)),
        golden,
        pjrt: PjrtSpec {
            name: format!("mm3_{n}"),
            inputs: vec![0, 1, 2, 3],
            outputs: vec![4, 5, 6],
        },
    }
}
