//! Synthetic mixed-workload job streams for the offload scheduler.
//!
//! A "job" at this layer is plain data — kernel name, problem size,
//! variant, thread count, input seed — so the generator stays independent
//! of the scheduler that consumes it (`sched::Scheduler::submit` turns a
//! [`JobDesc`] into a queued job). The mix is deterministic in the stream
//! seed: the same `(n, seed)` always yields the same job list, which is
//! what makes cross-policy bit-identity checks possible.
//!
//! Sizes are intentionally small (same scale as [`super::all_tiny`]) so a
//! 100-job `hero serve` run completes in seconds of wall time while still
//! exercising every kernel, several tiling variants, and enough distinct
//! (kernel, variant, size, threads) binaries that the scheduler's binary
//! cache sees both hits and misses.

use super::Workload;
use crate::bench_harness::Variant;
use crate::testkit::Rng;

/// One synthetic offload request (scheduler-independent plain data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobDesc {
    pub kernel: &'static str,
    pub size: usize,
    pub variant: Variant,
    pub threads: u32,
    /// Seed for the job's input data (`Workload::gen_data`).
    pub seed: u64,
}

impl JobDesc {
    /// Materialize the workload this job runs.
    pub fn workload(&self) -> Option<Workload> {
        super::build(self.kernel, self.size)
    }
}

/// Kernel menu: each entry is (name, [small size, larger size]). Two sizes
/// per kernel keeps the distinct-binary count at ~2x kernels x variants, so
/// a long stream revisits each binary many times (batching pays off).
const MENU: [(&str, [usize; 2]); 8] = [
    ("gemm", [12, 24]),
    ("2mm", [12, 16]),
    ("3mm", [10, 12]),
    ("atax", [24, 40]),
    ("bicg", [24, 40]),
    ("conv2d", [18, 24]),
    ("covar", [12, 16]),
    ("darknet", [14, 18]),
];

/// SPM-tiled variants only: the unmodified (external-memory) form is one to
/// two orders of magnitude slower to simulate and is covered by the fig4/7
/// benches; a serve stream is meant to model production offload traffic.
const VARIANTS: [Variant; 4] =
    [Variant::Handwritten, Variant::Handwritten, Variant::Promoted, Variant::AutoDma];

/// Generate `n` mixed jobs, deterministically in `seed`.
pub fn mixed_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    let mut rng = Rng::new(seed ^ 0x5EED_0B50);
    (0..n)
        .map(|_| {
            let (kernel, sizes) = *rng.pick(&MENU);
            JobDesc {
                kernel,
                size: *rng.pick(&sizes),
                variant: *rng.pick(&VARIANTS),
                threads: *rng.pick(&[4u32, 8, 8]),
                seed: rng.next_u64(),
            }
        })
        .collect()
}

/// Generate `n` jobs at the smallest size of each kernel only — the fast
/// variant for property tests that run many scheduler configurations.
pub fn tiny_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    mixed_jobs(n, seed)
        .into_iter()
        .map(|mut j| {
            let (_, sizes) = MENU.iter().find(|(k, _)| *k == j.kernel).unwrap();
            j.size = sizes[0];
            j
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        assert_eq!(mixed_jobs(50, 7), mixed_jobs(50, 7));
        assert_ne!(mixed_jobs(50, 7), mixed_jobs(50, 8));
        assert_eq!(mixed_jobs(50, 7).len(), 50);
    }

    #[test]
    fn all_jobs_buildable_and_mixed() {
        let jobs = mixed_jobs(100, 42);
        let mut kernels = std::collections::HashSet::new();
        for j in &jobs {
            let w = j.workload().expect("menu kernel must build");
            assert_eq!(w.size, j.size);
            kernels.insert(j.kernel);
        }
        // 100 draws over 8 kernels: all of them must appear.
        assert_eq!(kernels.len(), MENU.len());
    }

    #[test]
    fn tiny_jobs_use_smallest_sizes() {
        for j in tiny_jobs(40, 3) {
            let (_, sizes) = MENU.iter().find(|(k, _)| *k == j.kernel).unwrap();
            assert_eq!(j.size, sizes[0]);
        }
    }
}
