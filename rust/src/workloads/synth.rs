//! Synthetic mixed-workload job streams for the offload scheduler, plus
//! ingestion of replayable job traces.
//!
//! A "job" at this layer is plain data — kernel name, problem size,
//! variant, thread count, input seed, arrival cycle — so the generator
//! stays independent of the scheduler that consumes it
//! (`sched::Scheduler::submit` turns a [`JobDesc`] into a queued job). The
//! mix is deterministic in the stream seed: the same `(n, seed)` always
//! yields the same job list, which is what makes cross-policy bit-identity
//! checks possible.
//!
//! Sizes are intentionally small (same scale as [`super::all_tiny`]) so a
//! 100-job `hero serve` run completes in seconds of wall time while still
//! exercising every kernel, several tiling variants, and enough distinct
//! (kernel, variant, size, threads) binaries that the scheduler's binary
//! cache sees both hits and misses.
//!
//! Besides the synthetic generators, [`parse_trace`] replays production
//! traffic from a newline-delimited trace file
//! (`arrival-cycle kernel size [variant] [threads] [seed] [priority]
//! [tenant]`), the `hero serve --trace <file>` ingestion path. The
//! optional trailing tenant column bills a job to a named fleet tenant
//! ([`crate::fleet`]); anything after it is a hard parse error, never a
//! silently ignored field.

use super::Workload;
use crate::bench_harness::Variant;
use crate::sched::Priority;
use crate::testkit::Rng;

/// One synthetic offload request (scheduler-independent plain data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobDesc {
    pub kernel: &'static str,
    pub size: usize,
    pub variant: Variant,
    pub threads: u32,
    /// Seed for the job's input data (`Workload::gen_data`).
    pub seed: u64,
    /// Cycle the job becomes available for dispatch (0 = immediately; trace
    /// replay sets real arrival times).
    pub arrival: u64,
    /// QoS class (latency-critical jobs dispatch first and reserve board
    /// DRAM into the priority headroom — see [`crate::sched::Priority`]).
    pub priority: Priority,
}

impl JobDesc {
    /// Materialize the workload this job runs.
    pub fn workload(&self) -> Option<Workload> {
        super::build(self.kernel, self.size)
    }
}

/// Kernel menu: each entry is (name, [small size, larger size]). Two sizes
/// per kernel keeps the distinct-binary count at ~2x kernels x variants, so
/// a long stream revisits each binary many times (batching pays off).
const MENU: [(&str, [usize; 2]); 8] = [
    ("gemm", [12, 24]),
    ("2mm", [12, 16]),
    ("3mm", [10, 12]),
    ("atax", [24, 40]),
    ("bicg", [24, 40]),
    ("conv2d", [18, 24]),
    ("covar", [12, 16]),
    ("darknet", [14, 18]),
];

/// SPM-tiled variants only: the unmodified (external-memory) form is one to
/// two orders of magnitude slower to simulate and is covered by the fig4/7
/// benches; a serve stream is meant to model production offload traffic.
const VARIANTS: [Variant; 4] =
    [Variant::Handwritten, Variant::Handwritten, Variant::Promoted, Variant::AutoDma];

/// Generate `n` mixed jobs, deterministically in `seed`.
pub fn mixed_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    let mut rng = Rng::new(seed ^ 0x5EED_0B50);
    (0..n)
        .map(|_| {
            let (kernel, sizes) = *rng.pick(&MENU);
            JobDesc {
                kernel,
                size: *rng.pick(&sizes),
                variant: *rng.pick(&VARIANTS),
                threads: *rng.pick(&[4u32, 8, 8]),
                seed: rng.next_u64(),
                arrival: 0,
                priority: Priority::Normal,
            }
        })
        .collect()
}

/// Generate `n` jobs at the smallest size of each kernel only — the fast
/// variant for property tests that run many scheduler configurations.
pub fn tiny_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    mixed_jobs(n, seed)
        .into_iter()
        .map(|mut j| {
            let (_, sizes) = MENU.iter().find(|(k, _)| *k == j.kernel).unwrap();
            j.size = sizes[0];
            j
        })
        .collect()
}

/// Generate `n` DMA-heavy jobs: tiled kernels at the larger menu sizes,
/// where staging tiles in and out of the SPMs dominates. The stream for
/// shared-DRAM contention studies (`benches/sched.rs`).
pub fn dma_heavy_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    const HEAVY: [(&str, usize); 4] = [("gemm", 24), ("conv2d", 24), ("darknet", 18), ("2mm", 16)];
    let mut rng = Rng::new(seed ^ 0xD0A_BEEF);
    (0..n)
        .map(|_| {
            let (kernel, size) = *rng.pick(&HEAVY);
            JobDesc {
                kernel,
                size,
                variant: Variant::Handwritten,
                threads: 8,
                seed: rng.next_u64(),
                arrival: 0,
                priority: Priority::Normal,
            }
        })
        .collect()
}

/// Generate `n` jobs alternating DMA-heavy and compute-heavy work — the
/// board-placement study stream (`benches/sched.rs`). DMA-heavy entries are
/// O(N²)-compute kernels whose tile staging dominates (atax/bicg at the
/// large menu size, conv2d); compute-heavy entries are gemm at sizes where
/// the O(N³) inner loops dwarf the O(N²) footprint. On a
/// bandwidth-constrained board this is exactly the mix where stacking two
/// DMA-heavy windows stalls a slot while a compute job could have used it —
/// what pressure-aware placement is for.
pub fn pressure_mix_jobs(n: usize, seed: u64) -> Vec<JobDesc> {
    const DMA_HEAVY: [(&str, usize); 3] = [("atax", 40), ("bicg", 40), ("conv2d", 24)];
    const COMPUTE_HEAVY: [(&str, usize); 2] = [("gemm", 32), ("gemm", 48)];
    let mut rng = Rng::new(seed ^ 0x9A7_71C5);
    (0..n)
        .map(|i| {
            let (kernel, size) =
                *rng.pick(if i % 2 == 0 { &DMA_HEAVY[..] } else { &COMPUTE_HEAVY[..] });
            JobDesc {
                kernel,
                size,
                variant: Variant::Handwritten,
                threads: 8,
                seed: rng.next_u64(),
                arrival: 0,
                priority: Priority::Normal,
            }
        })
        .collect()
}

/// One parsed trace line: the job plus the fleet tenant it bills to, if
/// the line named one (`None` jobs go to the default tenant / a plain
/// scheduler).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceJob {
    pub desc: JobDesc,
    pub tenant: Option<String>,
}

/// Parse a newline-delimited job trace. Line format (whitespace-separated):
///
/// ```text
/// <arrival-cycle> <kernel> <size> [variant] [threads] [seed] [priority] [tenant]
/// ```
///
/// `#` starts a comment; blank lines are skipped. Omitted fields default to
/// `handwritten`, 8 threads, a deterministic per-line seed, `normal`
/// priority (the optional `high`/`hi` marks a latency-critical job) and no
/// tenant (the trailing tenant column bills the job to a named fleet
/// tenant — `hero serve --fleet N --trace <file>`). The parse is strict
/// about what it does understand — unknown kernels, variants or
/// priorities are errors, not silently dropped jobs, and so is anything
/// *after* the tenant column: a malformed or misremembered extra field
/// fails the replay loudly instead of silently changing which jobs run.
/// Jobs are returned sorted by arrival cycle (stable, so same-cycle jobs
/// keep file order): the scheduler dispatches in submission order, and
/// replaying a later arrival first would serialize earlier jobs behind it.
pub fn parse_trace(text: &str) -> Result<Vec<TraceJob>, String> {
    let mut jobs: Vec<TraceJob> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let ln = idx + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() < 3 {
            return Err(format!(
                "trace line {ln}: expected \
                 `arrival kernel size [variant] [threads] [seed] [priority] [tenant]`, \
                 got {line:?}"
            ));
        }
        if f.len() > 8 {
            return Err(format!(
                "trace line {ln}: unexpected trailing field(s) {:?} — the format is \
                 `arrival kernel size [variant] [threads] [seed] [priority] [tenant]`",
                &f[8..]
            ));
        }
        let arrival: u64 =
            f[0].parse().map_err(|_| format!("trace line {ln}: bad arrival cycle {:?}", f[0]))?;
        let kernel = super::canonical(f[1])
            .ok_or_else(|| format!("trace line {ln}: unknown kernel {:?}", f[1]))?;
        let size: usize =
            f[2].parse().map_err(|_| format!("trace line {ln}: bad size {:?}", f[2]))?;
        let variant = match f.get(3).copied() {
            None | Some("handwritten") => Variant::Handwritten,
            Some("unmodified") => Variant::Unmodified,
            Some("promoted") => Variant::Promoted,
            Some("autodma") => Variant::AutoDma,
            Some(v) => return Err(format!("trace line {ln}: unknown variant {v:?}")),
        };
        let threads: u32 = match f.get(4) {
            None => 8,
            Some(t) => t.parse().map_err(|_| format!("trace line {ln}: bad threads {t:?}"))?,
        };
        let seed: u64 = match f.get(5) {
            None => (ln as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ arrival,
            Some(s) => s.parse().map_err(|_| format!("trace line {ln}: bad seed {s:?}"))?,
        };
        let priority = match f.get(6) {
            None => Priority::Normal,
            Some(p) => Priority::parse(p)
                .ok_or_else(|| format!("trace line {ln}: unknown priority {p:?}"))?,
        };
        let tenant = f.get(7).map(|t| t.to_string());
        jobs.push(TraceJob {
            desc: JobDesc { kernel, size, variant, threads, seed, arrival, priority },
            tenant,
        });
    }
    jobs.sort_by_key(|j| j.desc.arrival);
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        assert_eq!(mixed_jobs(50, 7), mixed_jobs(50, 7));
        assert_ne!(mixed_jobs(50, 7), mixed_jobs(50, 8));
        assert_eq!(mixed_jobs(50, 7).len(), 50);
    }

    #[test]
    fn all_jobs_buildable_and_mixed() {
        let jobs = mixed_jobs(100, 42);
        let mut kernels = std::collections::HashSet::new();
        for j in &jobs {
            let w = j.workload().expect("menu kernel must build");
            assert_eq!(w.size, j.size);
            kernels.insert(j.kernel);
        }
        // 100 draws over 8 kernels: all of them must appear.
        assert_eq!(kernels.len(), MENU.len());
    }

    #[test]
    fn tiny_jobs_use_smallest_sizes() {
        for j in tiny_jobs(40, 3) {
            let (_, sizes) = MENU.iter().find(|(k, _)| *k == j.kernel).unwrap();
            assert_eq!(j.size, sizes[0]);
        }
    }

    #[test]
    fn dma_heavy_jobs_are_tiled_and_buildable() {
        let jobs = dma_heavy_jobs(20, 9);
        assert_eq!(jobs, dma_heavy_jobs(20, 9));
        for j in &jobs {
            assert_eq!(j.variant, Variant::Handwritten);
            assert!(j.workload().is_some());
        }
    }

    #[test]
    fn pressure_mix_alternates_dma_and_compute_weight() {
        let jobs = pressure_mix_jobs(12, 3);
        assert_eq!(jobs, pressure_mix_jobs(12, 3));
        for (i, j) in jobs.iter().enumerate() {
            let w = j.workload().expect("mix kernels must build");
            if i % 2 == 0 {
                assert!(
                    matches!(j.kernel, "atax" | "bicg" | "conv2d"),
                    "even slots are DMA-heavy, got {}",
                    j.kernel
                );
            } else {
                assert_eq!(j.kernel, "gemm", "odd slots are compute-heavy");
            }
            assert_eq!(w.size, j.size);
        }
    }

    #[test]
    fn trace_parses_full_and_defaulted_lines() {
        let text = "\
# production replay, cycle-stamped
0 gemm 12 handwritten 8 7
150 atax 24            # defaults: handwritten, 8 threads, derived seed

40000 conv2d 18 autodma 4 99
";
        let jobs = parse_trace(text).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(
            jobs[0],
            TraceJob {
                desc: JobDesc {
                    kernel: "gemm",
                    size: 12,
                    variant: Variant::Handwritten,
                    threads: 8,
                    seed: 7,
                    arrival: 0,
                    priority: Priority::Normal,
                },
                tenant: None,
            }
        );
        assert_eq!(
            (jobs[1].desc.kernel, jobs[1].desc.arrival, jobs[1].desc.threads),
            ("atax", 150, 8)
        );
        assert_eq!(jobs[2].desc.variant, Variant::AutoDma);
        assert_eq!(jobs[2].desc.threads, 4);
        assert_eq!(jobs[2].desc.arrival, 40_000);
        // Determinism of derived seeds.
        assert_eq!(parse_trace(text).unwrap(), jobs);
    }

    #[test]
    fn trace_sorts_by_arrival() {
        let jobs = parse_trace("900 gemm 12\n0 atax 24\n900 bicg 24\n").unwrap();
        assert_eq!(
            jobs.iter().map(|j| (j.desc.arrival, j.desc.kernel)).collect::<Vec<_>>(),
            // Stable: the two cycle-900 jobs keep their file order.
            vec![(0, "atax"), (900, "gemm"), (900, "bicg")]
        );
    }

    #[test]
    fn trace_parses_optional_priority_field() {
        let jobs = parse_trace(
            "0 gemm 12 handwritten 8 7 high\n\
             10 atax 24 handwritten 8 9\n\
             20 bicg 24 handwritten 8 9 hi\n\
             30 gemm 12 handwritten 8 9 normal\n",
        )
        .unwrap();
        assert_eq!(
            jobs.iter().map(|j| j.desc.priority).collect::<Vec<_>>(),
            vec![Priority::High, Priority::Normal, Priority::High, Priority::Normal]
        );
    }

    #[test]
    fn trace_parses_optional_tenant_column() {
        let jobs = parse_trace(
            "0 gemm 12 handwritten 8 7 high interactive\n\
             10 atax 24 handwritten 8 9 normal batch\n\
             20 bicg 24\n",
        )
        .unwrap();
        assert_eq!(
            jobs.iter().map(|j| j.tenant.as_deref()).collect::<Vec<_>>(),
            vec![Some("interactive"), Some("batch"), None]
        );
        assert_eq!(jobs[0].desc.priority, Priority::High, "priority still parses before it");
    }

    #[test]
    fn trace_rejects_malformed_lines() {
        assert!(parse_trace("0 gemm").unwrap_err().contains("line 1"));
        assert!(parse_trace("x gemm 12").unwrap_err().contains("arrival"));
        assert!(parse_trace("0 nope 12").unwrap_err().contains("unknown kernel"));
        assert!(parse_trace("0 gemm 12 turbo").unwrap_err().contains("unknown variant"));
        assert!(parse_trace("0 gemm twelve").unwrap_err().contains("bad size"));
        assert!(
            parse_trace("0 gemm 12 handwritten 8 7 urgent")
                .unwrap_err()
                .contains("unknown priority")
        );
    }

    #[test]
    fn trace_rejects_trailing_fields_after_tenant() {
        // A 9th field is never valid — erroring beats silently ignoring a
        // field the author believed did something.
        let err =
            parse_trace("0 gemm 12 handwritten 8 7 high interactive extra").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("unexpected trailing field"), "{err}");
        assert!(err.contains("extra"), "{err}");
        let err = parse_trace(
            "0 gemm 12\n5 atax 24 handwritten 8 7 normal batch oops why",
        )
        .unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("[\"oops\", \"why\"]"), "{err}");
        // A bad field *in* the tenant position still errors where it is
        // recognizable as something else gone wrong (priority typo shifts
        // everything right): the priority slot rejects it first.
        assert!(
            parse_trace("0 gemm 12 handwritten 8 7 urgent batch")
                .unwrap_err()
                .contains("unknown priority")
        );
    }
}
