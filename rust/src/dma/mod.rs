//! Cluster DMA engine.
//!
//! §2.1: "each accelerator cluster features a DMA engine, which can address
//! the full 64-bit memory space, supports unified virtual memory through the
//! hybrid IOMMU, can transfer up to 1024 bit per clock cycle in and out of
//! the cluster (full duplex), and can have tens of transactions ...
//! outstanding at any time."
//!
//! The engine executes transfer *descriptors*: 1D (one contiguous burst
//! train) or 2D (per-row bursts with distinct device/host strides —
//! scatter/gather, §2.4). Timing is burst-level via [`noc::WidePath`];
//! data movement itself is performed by the accelerator model at enqueue
//! time (the simulator guarantees no observable difference as long as
//! software synchronizes with `dma.wait`, which correct HERO programs do).

use crate::isa::DmaDir;
use crate::noc::{Port, WidePath};

/// A DMA transfer descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    pub dir: DmaDir,
    /// Device-local byte address (TCDM or L2).
    pub dev_addr: u32,
    /// Host virtual byte address (64-bit; translated through the IOMMU).
    pub host_va: u64,
    /// Bytes per row.
    pub row_bytes: u32,
    /// Number of rows (1 for 1D transfers).
    pub rows: u32,
    /// Device address increment between rows.
    pub dev_stride: u32,
    /// Host address increment between rows.
    pub host_stride: u32,
    /// Issue as one merged burst train (1D `hero_memcpy`) rather than
    /// per-row bursts (2D `hero_memcpy2d`).
    pub merged: bool,
}

impl Descriptor {
    /// Total payload bytes.
    pub fn total_bytes(&self) -> u64 {
        self.row_bytes as u64 * self.rows as u64
    }

    /// Number of bursts the engine issues for this descriptor.
    pub fn bursts(&self) -> u64 {
        if self.merged {
            1
        } else {
            self.rows as u64
        }
    }
}

/// An in-flight or completed transfer.
#[derive(Debug, Clone, Copy)]
struct Transfer {
    id: u32,
    done_at: u64,
}

/// Aggregate DMA statistics (feeds the `Dma*` perf events).
#[derive(Debug, Clone, Copy, Default)]
pub struct DmaStats {
    pub transfers: u64,
    pub bursts: u64,
    pub bytes: u64,
    pub busy_cycles: u64,
}

/// The per-cluster DMA engine.
#[derive(Debug)]
pub struct DmaEngine {
    path: WidePath,
    setup_cycles: u64,
    port: Port,
    inflight: Vec<Transfer>,
    next_id: u32,
    pub stats: DmaStats,
}

impl DmaEngine {
    pub fn new(path: WidePath, setup_cycles: u64) -> Self {
        DmaEngine {
            path,
            setup_cycles,
            port: Port::new(),
            inflight: Vec::new(),
            next_id: 1,
            stats: DmaStats::default(),
        }
    }

    pub fn path(&self) -> &WidePath {
        &self.path
    }

    /// Cycles a core is stalled programming a descriptor.
    pub fn setup_cycles(&self) -> u64 {
        self.setup_cycles
    }

    /// Enqueue a transfer at cycle `now` (after the programming core has
    /// paid `setup_cycles`). `translate_cost` is the IOMMU cost accumulated
    /// for the pages this transfer touches (0 if all TLB hits).
    /// Returns `(id, completion_cycle)`.
    pub fn enqueue(&mut self, now: u64, d: &Descriptor, translate_cost: u64) -> (u32, u64) {
        let duration = translate_cost
            + if d.merged {
                self.path.merged_cycles(d.total_bytes())
            } else {
                self.path.scattered_cycles(d.rows as u64, d.row_bytes as u64)
            };
        let (_, end) = self.port.acquire(now, duration);
        let id = self.next_id;
        self.next_id += 1;
        self.inflight.push(Transfer { id, done_at: end });
        self.stats.transfers += 1;
        self.stats.bursts += d.bursts();
        self.stats.bytes += d.total_bytes();
        self.stats.busy_cycles += duration;
        (id, end)
    }

    /// Completion cycle of transfer `id`, if known.
    pub fn completion(&self, id: u32) -> Option<u64> {
        self.inflight.iter().find(|t| t.id == id).map(|t| t.done_at)
    }

    /// Completion cycle of *all* transfers issued so far.
    pub fn all_done_at(&self) -> u64 {
        self.inflight.iter().map(|t| t.done_at).max().unwrap_or(0)
    }

    /// Drop completed bookkeeping up to `now` (keeps the in-flight list
    /// small on long runs).
    pub fn retire(&mut self, now: u64) {
        self.inflight.retain(|t| t.done_at > now);
    }

    /// Reset between offloads.
    pub fn reset(&mut self) {
        self.port.reset();
        self.inflight.clear();
        // Stats persist across offloads; callers snapshot/diff them.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> DmaEngine {
        DmaEngine::new(
            WidePath { beat_bytes: 8, burst_overhead: 25, first_word: 100, max_burst_beats: 256 },
            30,
        )
    }

    fn desc_1d(bytes: u32) -> Descriptor {
        Descriptor {
            dir: DmaDir::HostToDev,
            dev_addr: 0,
            host_va: 0,
            row_bytes: bytes,
            rows: 1,
            dev_stride: 0,
            host_stride: 0,
            merged: true,
        }
    }

    #[test]
    fn merged_transfer_timing() {
        let mut e = engine();
        let (id, done) = e.enqueue(0, &desc_1d(2048), 0);
        // 25 overhead + 100 first word + 256 beats.
        assert_eq!(done, 381);
        assert_eq!(e.completion(id), Some(381));
    }

    #[test]
    fn transfers_serialize_on_engine() {
        let mut e = engine();
        let (_, d1) = e.enqueue(0, &desc_1d(800), 0);
        let (_, d2) = e.enqueue(0, &desc_1d(800), 0);
        assert_eq!(d2 - d1, d1); // second starts when first ends
        assert_eq!(e.all_done_at(), d2);
    }

    #[test]
    fn scattered_counts_bursts_per_row() {
        let mut e = engine();
        let d = Descriptor {
            dir: DmaDir::DevToHost,
            dev_addr: 0,
            host_va: 0,
            row_bytes: 388,
            rows: 97,
            dev_stride: 388,
            host_stride: 512,
            merged: false,
        };
        e.enqueue(0, &d, 0);
        assert_eq!(e.stats.bursts, 97);
        assert_eq!(e.stats.bytes, 388 * 97);
        assert_eq!(e.stats.transfers, 1);
    }

    #[test]
    fn translate_cost_extends_transfer() {
        let mut e = engine();
        let (_, d_no) = e.enqueue(0, &desc_1d(64), 0);
        e.reset();
        let (_, d_tlb) = e.enqueue(0, &desc_1d(64), 600);
        assert_eq!(d_tlb - d_no, 600);
    }

    #[test]
    fn retire_drops_old() {
        let mut e = engine();
        let (id, done) = e.enqueue(0, &desc_1d(64), 0);
        e.retire(done + 1);
        assert_eq!(e.completion(id), None);
    }
}
