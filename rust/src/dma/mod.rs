//! Cluster DMA engine.
//!
//! §2.1: "each accelerator cluster features a DMA engine, which can address
//! the full 64-bit memory space, supports unified virtual memory through the
//! hybrid IOMMU, can transfer up to 1024 bit per clock cycle in and out of
//! the cluster (full duplex), and can have tens of transactions ...
//! outstanding at any time."
//!
//! The engine executes transfer *descriptors*: 1D (one contiguous burst
//! train) or 2D (per-row bursts with distinct device/host strides —
//! scatter/gather, §2.4). Timing is burst-level via [`noc::WidePath`];
//! data movement itself is performed by the accelerator model at enqueue
//! time (the simulator guarantees no observable difference as long as
//! software synchronizes with `dma.wait`, which correct HERO programs do).
//!
//! Main-memory traffic is routed through a [`DramPort`] on the board's
//! [`SharedDram`]: the DRAM side of every transfer reserves bandwidth on
//! the shared ledger, and when concurrent requesters (other clusters, or —
//! at the pool level — other accelerator instances) oversubscribe the peak,
//! the transfer completes late. That extra latency is *contention stall*,
//! accounted exactly once: [`DmaStats::busy_cycles`] is pure NoC data-path
//! occupancy (translate + burst timing) and [`DmaStats::dram_stall_cycles`]
//! is the added DRAM wait; the engine port's occupancy is their sum.

use crate::isa::DmaDir;
use crate::mem::{DramPort, SharedDram};
use crate::noc::{Port, WidePath};

/// A DMA transfer descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    pub dir: DmaDir,
    /// Device-local byte address (TCDM or L2).
    pub dev_addr: u32,
    /// Host virtual byte address (64-bit; translated through the IOMMU).
    pub host_va: u64,
    /// Bytes per row.
    pub row_bytes: u32,
    /// Number of rows (1 for 1D transfers).
    pub rows: u32,
    /// Device address increment between rows.
    pub dev_stride: u32,
    /// Host address increment between rows.
    pub host_stride: u32,
    /// Issue as one merged burst train (1D `hero_memcpy`) rather than
    /// per-row bursts (2D `hero_memcpy2d`).
    pub merged: bool,
}

impl Descriptor {
    /// Total payload bytes.
    pub fn total_bytes(&self) -> u64 {
        self.row_bytes as u64 * self.rows as u64
    }

    /// Number of bursts the engine issues for this descriptor.
    pub fn bursts(&self) -> u64 {
        if self.merged {
            1
        } else {
            self.rows as u64
        }
    }
}

/// An in-flight or completed transfer.
#[derive(Debug, Clone, Copy)]
struct Transfer {
    id: u32,
    done_at: u64,
}

/// Aggregate DMA statistics (feeds the `Dma*` perf events).
#[derive(Debug, Clone, Copy, Default)]
pub struct DmaStats {
    pub transfers: u64,
    pub bursts: u64,
    pub bytes: u64,
    /// NoC data-path occupancy (IOMMU translate + burst timing), excluding
    /// DRAM contention stall — see [`DmaStats::dram_stall_cycles`].
    pub busy_cycles: u64,
    /// Extra cycles transfers waited on the shared DRAM beyond their
    /// uncontended service time. Disjoint from `busy_cycles` by
    /// construction: engine-port occupancy == busy + stall, so nothing is
    /// ever counted twice.
    pub dram_stall_cycles: u64,
}

/// The per-cluster DMA engine.
#[derive(Debug)]
pub struct DmaEngine {
    path: WidePath,
    setup_cycles: u64,
    port: Port,
    /// This engine's requester port on the board's shared DRAM.
    dram_port: DramPort,
    inflight: Vec<Transfer>,
    next_id: u32,
    pub stats: DmaStats,
}

impl DmaEngine {
    pub fn new(path: WidePath, setup_cycles: u64, dram_port: DramPort) -> Self {
        DmaEngine {
            path,
            setup_cycles,
            port: Port::new(),
            dram_port,
            inflight: Vec::new(),
            next_id: 1,
            stats: DmaStats::default(),
        }
    }

    pub fn path(&self) -> &WidePath {
        &self.path
    }

    pub fn dram_port(&self) -> DramPort {
        self.dram_port
    }

    /// Cycles a core is stalled programming a descriptor.
    pub fn setup_cycles(&self) -> u64 {
        self.setup_cycles
    }

    /// Engine-port occupancy: NoC busy plus DRAM stall. Exposed so tests
    /// can pin the counted-once invariant
    /// `occupancy == stats.busy_cycles + stats.dram_stall_cycles`.
    pub fn occupancy_cycles(&self) -> u64 {
        self.port.busy_cycles
    }

    /// Enqueue a transfer at cycle `now` (after the programming core has
    /// paid `setup_cycles`). `translate_cost` is the IOMMU cost accumulated
    /// for the pages this transfer touches (0 if all TLB hits); `dram` is
    /// the shared main memory the transfer's far end lives in.
    /// Returns `(id, completion_cycle)`.
    pub fn enqueue(
        &mut self,
        now: u64,
        d: &Descriptor,
        translate_cost: u64,
        dram: &mut SharedDram,
    ) -> (u32, u64) {
        let noc_cycles = translate_cost
            + if d.merged {
                self.path.merged_cycles(d.total_bytes())
            } else {
                self.path.scattered_cycles(d.rows as u64, d.row_bytes as u64)
            };
        // DRAM side: reserve bandwidth on the shared ledger at this port's
        // NoC drain rate. Uncontended, the DRAM finishes within the NoC
        // window (service time == beat count <= noc_cycles); anything
        // beyond it is contention stall and extends the transfer.
        let start = now.max(self.port.free_at());
        let bytes = d.total_bytes();
        let stall = if bytes > 0 {
            let dram_end = dram.reserve(self.dram_port, start, bytes, self.path.beat_bytes);
            let stall = dram_end.saturating_sub(start + noc_cycles);
            dram.note_stall(self.dram_port, stall);
            stall
        } else {
            0
        };
        let (_, end) = self.port.acquire(now, noc_cycles + stall);
        let id = self.next_id;
        self.next_id += 1;
        self.inflight.push(Transfer { id, done_at: end });
        self.stats.transfers += 1;
        self.stats.bursts += d.bursts();
        self.stats.bytes += bytes;
        self.stats.busy_cycles += noc_cycles;
        self.stats.dram_stall_cycles += stall;
        (id, end)
    }

    /// Completion cycle of transfer `id`, if known.
    pub fn completion(&self, id: u32) -> Option<u64> {
        self.inflight.iter().find(|t| t.id == id).map(|t| t.done_at)
    }

    /// Completion cycle of *all* transfers issued so far.
    pub fn all_done_at(&self) -> u64 {
        self.inflight.iter().map(|t| t.done_at).max().unwrap_or(0)
    }

    /// Drop completed bookkeeping up to `now` (keeps the in-flight list
    /// small on long runs).
    pub fn retire(&mut self, now: u64) {
        self.inflight.retain(|t| t.done_at > now);
    }

    /// Reset between offloads.
    pub fn reset(&mut self) {
        self.port.reset();
        self.inflight.clear();
        // Stats persist across offloads; callers snapshot/diff them.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wide64() -> WidePath {
        WidePath { beat_bytes: 8, burst_overhead: 25, first_word: 100, max_burst_beats: 256 }
    }

    /// A board DRAM whose peak far exceeds one engine's 8 B/cycle drain
    /// rate — the uncontended default, matching the Aurora configuration.
    fn board() -> SharedDram {
        SharedDram::new(0, 384, 0)
    }

    fn engine(dram: &mut SharedDram) -> DmaEngine {
        let port = dram.add_port("dma", false);
        DmaEngine::new(wide64(), 30, port)
    }

    fn desc_1d(bytes: u32) -> Descriptor {
        Descriptor {
            dir: DmaDir::HostToDev,
            dev_addr: 0,
            host_va: 0,
            row_bytes: bytes,
            rows: 1,
            dev_stride: 0,
            host_stride: 0,
            merged: true,
        }
    }

    #[test]
    fn merged_transfer_timing() {
        let mut dram = board();
        let mut e = engine(&mut dram);
        let (id, done) = e.enqueue(0, &desc_1d(2048), 0, &mut dram);
        // 25 overhead + 100 first word + 256 beats; no DRAM stall at
        // 8 B/cycle demand against a 384 B/cycle board.
        assert_eq!(done, 381);
        assert_eq!(e.completion(id), Some(381));
        assert_eq!(e.stats.dram_stall_cycles, 0);
    }

    #[test]
    fn transfers_serialize_on_engine() {
        let mut dram = board();
        let mut e = engine(&mut dram);
        let (_, d1) = e.enqueue(0, &desc_1d(800), 0, &mut dram);
        let (_, d2) = e.enqueue(0, &desc_1d(800), 0, &mut dram);
        assert_eq!(d2 - d1, d1); // second starts when first ends
        assert_eq!(e.all_done_at(), d2);
    }

    #[test]
    fn scattered_counts_bursts_per_row() {
        let mut dram = board();
        let mut e = engine(&mut dram);
        let d = Descriptor {
            dir: DmaDir::DevToHost,
            dev_addr: 0,
            host_va: 0,
            row_bytes: 388,
            rows: 97,
            dev_stride: 388,
            host_stride: 512,
            merged: false,
        };
        e.enqueue(0, &d, 0, &mut dram);
        assert_eq!(e.stats.bursts, 97);
        assert_eq!(e.stats.bytes, 388 * 97);
        assert_eq!(e.stats.transfers, 1);
        assert_eq!(dram.stats(e.dram_port()).bytes, 388 * 97);
    }

    #[test]
    fn translate_cost_extends_transfer() {
        let mut dram = board();
        let mut e = engine(&mut dram);
        let (_, d_no) = e.enqueue(0, &desc_1d(64), 0, &mut dram);
        e.reset();
        let (_, d_tlb) = e.enqueue(0, &desc_1d(64), 600, &mut dram);
        assert_eq!(d_tlb - d_no, 600);
    }

    #[test]
    fn retire_drops_old() {
        let mut dram = board();
        let mut e = engine(&mut dram);
        let (id, done) = e.enqueue(0, &desc_1d(64), 0, &mut dram);
        e.retire(done + 1);
        assert_eq!(e.completion(id), None);
    }

    #[test]
    fn dram_bottleneck_stalls_transfer() {
        // Board peak below the engine's 8 B/cycle drain rate: the DRAM
        // side, not the NoC, bounds the transfer.
        let mut dram = SharedDram::new(0, 4, 0);
        let mut e = engine(&mut dram);
        let (_, done) = e.enqueue(0, &desc_1d(2048), 0, &mut dram);
        // NoC occupancy 381, DRAM service 2048/4 = 512: stall 131.
        assert_eq!(done, 512);
        assert_eq!(e.stats.busy_cycles, 381);
        assert_eq!(e.stats.dram_stall_cycles, 131);
        assert_eq!(dram.stats(e.dram_port()).stall_cycles, 131);
    }

    #[test]
    fn two_engines_contend_on_one_dram() {
        // Two clusters, 8 B/cycle each, sharing a 8 B/cycle board: the
        // second engine's concurrent transfer is served from the residual
        // bandwidth and stalls; a lone engine is unaffected.
        let mut dram = SharedDram::new(0, 8, 0);
        let mut e0 = engine(&mut dram);
        let mut e1 = engine(&mut dram);
        let (_, d0) = e0.enqueue(0, &desc_1d(2048), 0, &mut dram);
        let (_, d1) = e1.enqueue(0, &desc_1d(2048), 0, &mut dram);
        assert_eq!(d0, 381); // full rate: NoC-bound as before
        assert!(d1 > d0, "concurrent transfer must stall ({d1} vs {d0})");
        assert_eq!(e0.stats.dram_stall_cycles, 0);
        assert_eq!(e1.stats.dram_stall_cycles, d1 - 381);
    }

    #[test]
    fn stall_counted_once_between_port_and_stats() {
        // The no-double-count invariant: engine-port occupancy equals NoC
        // busy plus DRAM stall, for stalled and unstalled transfers alike.
        let mut dram = SharedDram::new(0, 4, 0);
        let mut e = engine(&mut dram);
        e.enqueue(0, &desc_1d(2048), 0, &mut dram);
        e.enqueue(0, &desc_1d(64), 17, &mut dram);
        e.enqueue(0, &desc_1d(800), 0, &mut dram);
        assert!(e.stats.dram_stall_cycles > 0);
        assert_eq!(
            e.occupancy_cycles(),
            e.stats.busy_cycles + e.stats.dram_stall_cycles,
            "stall cycles double-counted between Port::acquire and DmaStats"
        );
    }
}
