//! `bench_gate` — the CI cycle-regression gate over `BENCH_*.json` files.
//!
//! ```text
//! bench_gate check <emitted-dir> <baseline-dir>   compare a fresh bench
//!     emission against the committed baselines; exit 1 on any cycle-count
//!     regression, digest drift, or key-set mismatch
//! bench_gate bless <emitted-dir> <baseline-dir>   adopt the emitted files
//!     as the new baseline (then commit them)
//! ```
//!
//! The simulator is deterministic, so `check` compares **exactly**: a
//! metric higher than its baseline is a regression, a differing digest is
//! drift, and there is no noise tolerance to tune. A metric *lower* than
//! its baseline passes with a "re-bless suggested" notice, so improvements
//! land without friction but are visible in CI logs until the baseline is
//! refreshed.
//!
//! Bootstrapping: a committed baseline may carry `"bootstrap": true`
//! (hand-seeded values from an environment that could not run the
//! benches). Against such a file, `check` reports mismatches as warnings
//! and passes — the gate becomes strict the first time someone runs
//! `bench_gate bless` and commits the result, which drops the flag because
//! emitted files never carry it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::exit;

/// Parsed form of one `BENCH_<name>.json` (the exact shape
/// `herov2::bench_harness::emit::BenchJson` renders).
#[derive(Debug, Default, PartialEq)]
struct BenchFile {
    bench: String,
    bootstrap: bool,
    metrics: BTreeMap<String, u64>,
    digests: BTreeMap<String, String>,
}

/// Parse the restricted one-entry-per-line JSON the emitter writes. Strict
/// about what it understands: unknown lines are errors so a corrupted
/// baseline cannot silently pass the gate.
fn parse(text: &str) -> Result<BenchFile, String> {
    #[derive(PartialEq)]
    enum Section {
        Top,
        Metrics,
        Digests,
    }
    let mut f = BenchFile::default();
    let mut section = Section::Top;
    for (i, raw) in text.lines().enumerate() {
        let ln = i + 1;
        let line = raw.trim();
        match line {
            "" | "{" | "}" | "{}" | "}," => {
                if line == "}" || line == "}," {
                    section = Section::Top;
                }
                continue;
            }
            "\"metrics\": {" => {
                section = Section::Metrics;
                continue;
            }
            "\"digests\": {" => {
                section = Section::Digests;
                continue;
            }
            _ => {}
        }
        let (key, value) = line
            .strip_prefix('"')
            .and_then(|r| r.split_once("\":"))
            .ok_or_else(|| format!("line {ln}: expected `\"key\": value`, got {line:?}"))?;
        let value = value.trim().trim_end_matches(',').trim();
        match section {
            Section::Top => match key {
                "bench" => f.bench = value.trim_matches('"').to_string(),
                "bootstrap" => f.bootstrap = value == "true",
                _ => return Err(format!("line {ln}: unknown top-level key {key:?}")),
            },
            Section::Metrics => {
                let v: u64 = value
                    .parse()
                    .map_err(|_| format!("line {ln}: metric {key:?} has non-u64 value"))?;
                f.metrics.insert(key.to_string(), v);
            }
            Section::Digests => {
                f.digests.insert(key.to_string(), value.trim_matches('"').to_string());
            }
        }
    }
    if f.bench.is_empty() {
        return Err("missing \"bench\" name".into());
    }
    Ok(f)
}

fn load(path: &Path) -> Result<BenchFile, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// `BENCH_*.json` paths in `dir`, sorted for stable output.
fn bench_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("cannot list {}: {e}", dir.display()))?;
    let mut out: Vec<PathBuf> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    out.sort();
    Ok(out)
}

/// Outcome of comparing one bench against its baseline.
#[derive(Debug, Default)]
struct Comparison {
    /// Hard failures: regressions, digest drift, key mismatches.
    failures: Vec<String>,
    /// Passing notices: improvements that suggest a re-bless.
    notices: Vec<String>,
}

fn compare(emitted: &BenchFile, baseline: &BenchFile) -> Comparison {
    let mut c = Comparison::default();
    for (key, &base) in &baseline.metrics {
        match emitted.metrics.get(key) {
            None => c.failures.push(format!("metric {key}: missing from the fresh run")),
            Some(&now) if now > base => c.failures.push(format!(
                "metric {key}: REGRESSION {base} -> {now} (+{})",
                now - base
            )),
            Some(&now) if now < base => c.notices.push(format!(
                "metric {key}: improved {base} -> {now} (-{}); re-bless to lock in",
                base - now
            )),
            Some(_) => {}
        }
    }
    for key in emitted.metrics.keys() {
        if !baseline.metrics.contains_key(key) {
            c.failures.push(format!("metric {key}: not in the baseline (bless to adopt)"));
        }
    }
    for (key, base) in &baseline.digests {
        match emitted.digests.get(key) {
            None => c.failures.push(format!("digest {key}: missing from the fresh run")),
            Some(now) if now != base => {
                c.failures.push(format!("digest {key}: DRIFT {base} -> {now}"))
            }
            Some(_) => {}
        }
    }
    for key in emitted.digests.keys() {
        if !baseline.digests.contains_key(key) {
            c.failures.push(format!("digest {key}: not in the baseline (bless to adopt)"));
        }
    }
    c
}

fn check(emitted_dir: &Path, baseline_dir: &Path) -> Result<i32, String> {
    let baselines = bench_files(baseline_dir)?;
    if baselines.is_empty() {
        return Err(format!(
            "no BENCH_*.json baselines in {} — run the benches and `bench_gate bless`",
            baseline_dir.display()
        ));
    }
    let mut failed = false;
    let mut bootstraps: Vec<Bootstrap> = Vec::new();
    for bpath in &baselines {
        let name = bpath.file_name().unwrap().to_string_lossy().into_owned();
        let baseline = load(bpath)?;
        let epath = emitted_dir.join(&name);
        if !epath.exists() {
            if baseline.bootstrap {
                bootstraps.push(Bootstrap { name: name.clone(), baseline_only: Vec::new() });
            }
            println!("FAIL {name}: bench was not run (no {})", epath.display());
            failed = true;
            continue;
        }
        let emitted = load(&epath)?;
        if baseline.bootstrap {
            bootstraps.push(Bootstrap {
                name: name.clone(),
                baseline_only: baseline_only_keys(&emitted, &baseline),
            });
        }
        let c = compare(&emitted, &baseline);
        for n in &c.notices {
            println!("note {name}: {n}");
        }
        if c.failures.is_empty() {
            println!(
                "ok   {name}: {} metrics, {} digests{}",
                baseline.metrics.len(),
                baseline.digests.len(),
                if baseline.bootstrap { " (bootstrap baseline — not yet strict)" } else { "" }
            );
        } else if baseline.bootstrap {
            // Hand-seeded baseline: report, demand a bless, but do not
            // block CI on numbers no machine ever measured.
            for f in &c.failures {
                println!("warn {name} (bootstrap baseline): {f}");
            }
            println!(
                "warn {name}: baseline is bootstrap-seeded — run `bench_gate bless {} {}` \
                 and commit to make the gate strict",
                emitted_dir.display(),
                baseline_dir.display()
            );
        } else {
            for f in &c.failures {
                println!("FAIL {name}: {f}");
            }
            failed = true;
        }
    }
    // Emitted benches with no baseline at all must be blessed explicitly.
    for epath in bench_files(emitted_dir)? {
        let name = epath.file_name().unwrap().to_string_lossy().into_owned();
        if !baseline_dir.join(&name).exists() {
            println!("FAIL {name}: emitted but has no committed baseline (bless to adopt)");
            failed = true;
        }
    }
    // One explicit, grep-able line for every baseline the gate is not yet
    // enforcing — a bootstrap pass must never read like a strict pass.
    if let Some(summary) = bootstrap_summary(&bootstraps, emitted_dir, baseline_dir) {
        println!("{summary}");
    }
    Ok(if failed { 1 } else { 0 })
}

/// One bootstrap-seeded baseline the gate is not yet enforcing, plus the
/// keys it carries that the fresh emission does not.
struct Bootstrap {
    name: String,
    baseline_only: Vec<String>,
}

/// Keys (metrics and digests) present in `baseline` but absent from
/// `emitted` — hand-seeded expectations the bench does not emit yet, which
/// a `bless` would silently drop because it copies the emitted file over
/// the baseline wholesale.
fn baseline_only_keys(emitted: &BenchFile, baseline: &BenchFile) -> Vec<String> {
    baseline
        .metrics
        .keys()
        .filter(|k| !emitted.metrics.contains_key(*k))
        .map(|k| format!("metric {k}"))
        .chain(
            baseline
                .digests
                .keys()
                .filter(|k| !emitted.digests.contains_key(*k))
                .map(|k| format!("digest {k}")),
        )
        .collect()
}

/// The end-of-check summary naming every baseline still on hand-seeded
/// `"bootstrap": true` values (`None` when the gate is fully strict).
/// Baseline-only keys are listed per file: before this, only the
/// emitted-but-unblessed direction was ever named, and a `bless` could
/// silently drop a hand-seeded expectation the bench never learned to emit.
fn bootstrap_summary(
    bootstraps: &[Bootstrap],
    emitted_dir: &Path,
    baseline_dir: &Path,
) -> Option<String> {
    if bootstraps.is_empty() {
        return None;
    }
    let names: Vec<&str> = bootstraps.iter().map(|b| b.name.as_str()).collect();
    let mut s = format!(
        "note {} baseline file(s) still bootstrap-seeded ({}) — their numbers gate \
         nothing until `bench_gate bless {} {}` is run and committed",
        bootstraps.len(),
        names.join(", "),
        emitted_dir.display(),
        baseline_dir.display()
    );
    for b in bootstraps {
        if !b.baseline_only.is_empty() {
            s.push_str(&format!(
                "\nnote {}: baseline-only key(s) with no emitted counterpart ({}) — \
                 blessing now would drop them",
                b.name,
                b.baseline_only.join(", ")
            ));
        }
    }
    Some(s)
}

fn bless(emitted_dir: &Path, baseline_dir: &Path) -> Result<(), String> {
    let emitted = bench_files(emitted_dir)?;
    if emitted.is_empty() {
        return Err(format!(
            "nothing to bless: no BENCH_*.json in {} (run the benches first)",
            emitted_dir.display()
        ));
    }
    std::fs::create_dir_all(baseline_dir)
        .map_err(|e| format!("cannot create {}: {e}", baseline_dir.display()))?;
    for epath in emitted {
        load(&epath)?; // refuse to bless something the gate cannot parse
        let name = epath.file_name().unwrap().to_string_lossy().into_owned();
        let dst = baseline_dir.join(&name);
        std::fs::copy(&epath, &dst)
            .map_err(|e| format!("cannot copy {} -> {}: {e}", epath.display(), dst.display()))?;
        println!("blessed {}", dst.display());
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let usage = "usage: bench_gate <check|bless> <emitted-dir> <baseline-dir>";
    let (cmd, emitted, baseline) = match args.as_slice() {
        [c, e, b] => (c.as_str(), PathBuf::from(e), PathBuf::from(b)),
        _ => {
            eprintln!("{usage}");
            exit(2);
        }
    };
    let outcome = match cmd {
        "check" => check(&emitted, &baseline),
        "bless" => bless(&emitted, &baseline).map(|()| 0),
        _ => {
            eprintln!("{usage}");
            exit(2);
        }
    };
    match outcome {
        Ok(code) => exit(code),
        Err(e) => {
            eprintln!("bench_gate: {e}");
            exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "bench": "sched",
  "metrics": {
    "mixed.pool1.makespan_cycles": 1000,
    "mixed.pool4.makespan_cycles": 400
  },
  "digests": {
    "mixed.digest": "0x00000000deadbeef"
  }
}
"#;

    #[test]
    fn parses_the_emitter_format() {
        let f = parse(SAMPLE).unwrap();
        assert_eq!(f.bench, "sched");
        assert!(!f.bootstrap);
        assert_eq!(f.metrics["mixed.pool1.makespan_cycles"], 1000);
        assert_eq!(f.metrics["mixed.pool4.makespan_cycles"], 400);
        assert_eq!(f.digests["mixed.digest"], "0x00000000deadbeef");
        // Round-trips through the real emitter.
        let mut b = herov2::bench_harness::emit::BenchJson::new("sched");
        b.metric("mixed.pool1.makespan_cycles", 1000);
        b.metric("mixed.pool4.makespan_cycles", 400);
        b.digest("mixed.digest", 0xdead_beef);
        assert_eq!(parse(&b.render()).unwrap(), f);
    }

    #[test]
    fn parses_bootstrap_flag_and_rejects_garbage() {
        let f = parse("{\n  \"bench\": \"x\",\n  \"bootstrap\": true,\n  \"metrics\": {\n  },\n  \"digests\": {\n  }\n}\n").unwrap();
        assert!(f.bootstrap);
        assert!(parse("{\n  \"metrics\": {\n  }\n}\n").is_err(), "missing bench name");
        assert!(parse("{\n  \"bench\": \"x\",\n  \"metrics\": {\n    \"k\": oops\n  }\n}\n").is_err());
        assert!(parse("{\n  \"bench\": \"x\",\n  \"surprise\": 1\n}\n").is_err());
    }

    fn seeded(name: &str, baseline_only: &[&str]) -> Bootstrap {
        Bootstrap {
            name: name.to_string(),
            baseline_only: baseline_only.iter().map(|s| s.to_string()).collect(),
        }
    }

    #[test]
    fn bootstrap_summary_names_every_seeded_baseline() {
        let (e, b) = (PathBuf::from("target/bench-json"), PathBuf::from("baselines"));
        assert_eq!(bootstrap_summary(&[], &e, &b), None, "a strict gate stays silent");
        let s = bootstrap_summary(
            &[seeded("BENCH_sched.json", &[]), seeded("BENCH_offload.json", &[])],
            &e,
            &b,
        )
        .unwrap();
        assert!(s.contains("2 baseline file(s) still bootstrap-seeded"), "{s}");
        assert!(s.contains("BENCH_sched.json, BENCH_offload.json"), "{s}");
        assert!(s.contains("bench_gate bless target/bench-json baselines"), "{s}");
        assert!(!s.contains("baseline-only"), "no phantom key warnings: {s}");
    }

    #[test]
    fn bootstrap_summary_lists_baseline_only_keys() {
        // Regression: keys hand-seeded into a bootstrap baseline but not
        // yet emitted by the bench were never named anywhere (only the
        // emitted-but-unblessed direction was), so a `bless` dropped them
        // silently. The summary must call them out per file.
        let base = parse(concat!(
            "{\n  \"bench\": \"sched\",\n  \"bootstrap\": true,\n  \"metrics\": {\n",
            "    \"autotune.mixed.makespan_cycles\": 900,\n",
            "    \"mixed.pool1.makespan_cycles\": 1000\n  },\n",
            "  \"digests\": {\n    \"autotune.mixed.digest\": \"0x0000000000000001\"\n  }\n}\n"
        ))
        .unwrap();
        let emitted = parse(concat!(
            "{\n  \"bench\": \"sched\",\n  \"metrics\": {\n",
            "    \"mixed.pool1.makespan_cycles\": 1000\n  },\n  \"digests\": {\n  }\n}\n"
        ))
        .unwrap();
        let only = baseline_only_keys(&emitted, &base);
        assert_eq!(
            only,
            vec![
                "metric autotune.mixed.makespan_cycles".to_string(),
                "digest autotune.mixed.digest".to_string()
            ]
        );
        let (e, b) = (PathBuf::from("em"), PathBuf::from("bl"));
        let s = bootstrap_summary(&[seeded("BENCH_sched.json", &["metric autotune.x"])], &e, &b)
            .unwrap();
        assert!(s.contains("baseline-only key(s) with no emitted counterpart"), "{s}");
        assert!(s.contains("metric autotune.x"), "{s}");
        assert!(s.contains("blessing now would drop them"), "{s}");
        // A fully-emitted bootstrap file adds no extra line.
        assert_eq!(baseline_only_keys(&base, &base), Vec::<String>::new());
    }

    #[test]
    fn compare_flags_regressions_drift_and_key_mismatches() {
        let base = parse(SAMPLE).unwrap();
        let mut good = parse(SAMPLE).unwrap();
        let c = compare(&good, &base);
        assert!(c.failures.is_empty() && c.notices.is_empty());
        // Improvement: notice, not failure.
        good.metrics.insert("mixed.pool4.makespan_cycles".into(), 300);
        let c = compare(&good, &base);
        assert!(c.failures.is_empty());
        assert_eq!(c.notices.len(), 1);
        // Regression.
        good.metrics.insert("mixed.pool4.makespan_cycles".into(), 500);
        let c = compare(&good, &base);
        assert!(c.failures.iter().any(|f| f.contains("REGRESSION")));
        // Digest drift.
        let mut drift = parse(SAMPLE).unwrap();
        drift.digests.insert("mixed.digest".into(), "0x0000000000000001".into());
        assert!(compare(&drift, &base).failures.iter().any(|f| f.contains("DRIFT")));
        // Key-set mismatches in both directions.
        let mut missing = parse(SAMPLE).unwrap();
        missing.metrics.remove("mixed.pool1.makespan_cycles");
        missing.metrics.insert("new.metric".into(), 1);
        let c = compare(&missing, &base);
        assert!(c.failures.iter().any(|f| f.contains("missing from the fresh run")));
        assert!(c.failures.iter().any(|f| f.contains("not in the baseline")));
    }
}
