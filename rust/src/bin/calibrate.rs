//! Calibration sweep: per-workload shape metrics (Fig 4/5/7 in one pass),
//! used while tuning the DESIGN.md §5 timing parameters.

use herov2::bench_harness::{run_workload, verify, Variant};
use herov2::config::aurora;
use herov2::workloads;

fn main() {
    let cfg = aurora();
    for w in workloads::all_default() {
        let t0 = std::time::Instant::now();
        let base = run_workload(&cfg, &w, Variant::Unmodified, 1, 7, 20_000_000_000).unwrap();
        let hand = run_workload(&cfg, &w, Variant::Handwritten, 1, 7, 20_000_000_000).unwrap();
        verify(&w, &hand, 7).unwrap();
        let hand8 = run_workload(&cfg, &w, Variant::Handwritten, 8, 7, 20_000_000_000).unwrap();
        let auto8 = run_workload(&cfg, &w, Variant::AutoDma, 8, 7, 20_000_000_000).unwrap();
        let base8 = run_workload(&cfg, &w, Variant::Unmodified, 8, 7, 20_000_000_000).unwrap();
        println!(
            "{:8} N={:4} | fig4 speedup {:5.2} dma% {:4.2} | par speedup {:4.2} | fig7: auto {:5.2} hand {:5.2} | wall {:.1}s",
            w.name, w.size,
            base.cycles() as f64 / hand.cycles() as f64,
            100.0 * hand.dma_cycles() as f64 / hand.cycles() as f64,
            hand.cycles() as f64 / hand8.cycles() as f64,
            base8.cycles() as f64 / auto8.cycles() as f64,
            base8.cycles() as f64 / hand8.cycles() as f64,
            t0.elapsed().as_secs_f64()
        );
    }
}
