//! Shared-virtual-memory offload: the host as a modeled traffic source.
//!
//! Everywhere else in the simulator the host is free — staging writes DRAM
//! directly and only the configured mailbox constants cost cycles. This
//! module makes shared virtual memory a first-class *offload path* with the
//! host on the clock (§2.3 of the paper; pin-vs-copy tradeoff after the
//! Cheshire SVM study, arXiv:2305.04760):
//!
//! - [`SvmSpace`] is a process-wide VA space: page-granular allocations
//!   mapped through one host [`PageTable`], with the functional f32 contents
//!   kept host-side. Kernel jobs name operands by VA
//!   (`PayloadSrc::Svm { va, elems }`) instead of carrying the bytes.
//! - [`SvmMode`] selects how a launch reaches those operands:
//!   - **pin**: zero-copy. The accelerator accesses host pages in place;
//!     every page is translated through the board's persistent [`Iommu`]
//!     (TLB hits free, misses pay the software walk) and every NoC beat
//!     pays the ext-address overhead.
//!   - **copy**: up-front staging. The host pins the operand pages
//!     (per-operand DMA setup + one page-table walk per page) and streams
//!     the bytes in and back out through its DRAM port.
//!   - **auto**: per-launch choice by exact predicted cost (read-only
//!     ledger probes; TLB-refill walks are treated as an amortized
//!     investment — see `sched`'s dispatch path).
//! - All host-side traffic — copy staging, page-table-entry reads, mailbox
//!   descriptors — reserves cycles on the shared board
//!   [`crate::mem::BandwidthLedger`] through a dedicated host port
//!   (`sched::pool`), so placement pressure, SJF inflation and
//!   `probe_stall` see host contention like any other requester.
//!
//! Determinism: every cost here is integer cycles derived from configured
//! constants and ledger state; with SVM disabled the scheduler takes none
//! of these paths and its event sequence is bit-identical to before.

use crate::config::HeroConfig;
use crate::iommu::{Iommu, PageTable};
use crate::sched::{JobHandle, KernelJob, PayloadSrc, Scheduler};
use anyhow::Result;
use std::collections::HashMap;

/// Default host DRAM-port rate in bytes/cycle (`hero serve --host-bw`).
/// Half a typical board drain rate: the host reaches the board DRAM through
/// the narrower system interconnect, not the accelerator NoC.
pub const DEFAULT_HOST_BW: u64 = 8;

/// Bytes of page-table entry read per software walk (one 64-bit PTE; the
/// multi-level walk latency is the configured `iommu.walk_cycles`, this is
/// only the DRAM traffic it generates).
pub const PTE_BYTES: u64 = 8;

/// Element count of the small operands in [`submit_svm_stream`]: 512 B,
/// well under the pin/copy crossover (~1.4 KiB at default rates) — pin
/// should win once the TLB is warm.
pub const SMALL_ELEMS: usize = 128;

/// Element count of the large operands in [`submit_svm_stream`]: 64 KiB,
/// well over the crossover — copy staging should win.
pub const LARGE_ELEMS: usize = 16384;

/// How a launch reaches its SVM operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvmMode {
    /// Zero-copy: access host pages in place through the IOMMU.
    Pin,
    /// Stage through host DMA up front, copy results back.
    Copy,
    /// Choose pin or copy per launch by exact predicted cost.
    Auto,
}

impl SvmMode {
    /// Parse a CLI-style mode name.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pin" => Ok(SvmMode::Pin),
            "copy" => Ok(SvmMode::Copy),
            "auto" => Ok(SvmMode::Auto),
            other => anyhow::bail!("unknown SVM mode '{other}' (expected pin, copy or auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SvmMode::Pin => "pin",
            SvmMode::Copy => "copy",
            SvmMode::Auto => "auto",
        }
    }
}

/// SVM serving configuration (`Scheduler::with_svm`).
#[derive(Debug, Clone, Copy)]
pub struct SvmConfig {
    /// Board-wide default strategy; `KernelJob::svm` overrides per launch.
    pub mode: SvmMode,
    /// Host DRAM-port rate in bytes/cycle.
    pub host_bw: u64,
}

impl SvmConfig {
    pub fn new(mode: SvmMode) -> Self {
        SvmConfig { mode, host_bw: DEFAULT_HOST_BW }
    }

    pub fn with_host_bw(mut self, bw: u64) -> Self {
        self.host_bw = bw.max(1);
        self
    }
}

/// The host process's shared VA space: a page-granular bump allocator over
/// one application [`PageTable`], holding the functional contents of every
/// shared buffer.
///
/// This is the *board-lifetime* counterpart of the per-launch
/// [`crate::host::HostContext`]: buffers outlive launches, so the
/// persistent TLB can stay warm across offloads that revisit them.
#[derive(Debug)]
pub struct SvmSpace {
    page_bytes: u64,
    next_va: u64,
    next_pa: u64,
    pt: PageTable,
    store: HashMap<u64, Vec<f32>>,
}

impl SvmSpace {
    pub fn new(page_bytes: usize) -> Self {
        SvmSpace {
            page_bytes: page_bytes as u64,
            next_va: crate::host::VA_BASE,
            next_pa: 0,
            pt: PageTable::new(page_bytes),
            store: HashMap::new(),
        }
    }

    /// Allocate a shared buffer holding `data`, map its pages, return its VA.
    pub fn alloc_f32(&mut self, data: Vec<f32>) -> u64 {
        let bytes = (data.len().max(1) as u64 * 4).div_ceil(self.page_bytes) * self.page_bytes;
        let va = self.next_va;
        self.pt.map_range(va, self.next_pa, bytes);
        self.next_va += bytes;
        self.next_pa += bytes;
        self.store.insert(va, data);
        va
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn pt(&self) -> &PageTable {
        &self.pt
    }

    /// Element count of the buffer at `va` (allocation-start VAs only).
    pub fn elems(&self, va: u64) -> Option<usize> {
        self.store.get(&va).map(|b| b.len())
    }

    /// Borrow the buffer at `va`.
    pub fn get(&self, va: u64) -> Option<&[f32]> {
        self.store.get(&va).map(|b| b.as_slice())
    }

    /// Copy the buffer at `va` out (host reading results back).
    pub fn read(&self, va: u64) -> Option<Vec<f32>> {
        self.store.get(&va).cloned()
    }

    /// Write a launch's output view back into the buffer at `va`. A view
    /// shorter than the buffer updates only the prefix it covered.
    pub fn write_back(&mut self, va: u64, data: &[f32]) {
        if let Some(buf) = self.store.get_mut(&va) {
            let n = data.len().min(buf.len());
            buf[..n].copy_from_slice(&data[..n]);
        }
    }
}

/// Per-board SVM serving state owned by the scheduler: the shared space
/// plus the board's persistent IOMMU shadow (a pure cost engine — launch
/// numerics never flow through it, preserving the bit-identity invariant).
#[derive(Debug)]
pub struct SvmState {
    pub cfg: SvmConfig,
    pub space: SvmSpace,
    pub iommu: Iommu,
}

impl SvmState {
    pub fn new(cfg: SvmConfig, hw: &HeroConfig) -> Self {
        SvmState {
            cfg,
            space: SvmSpace::new(hw.iommu.page_bytes),
            iommu: Iommu::new(hw.iommu),
        }
    }
}

/// Number of distinct pages the byte range `[va, va + bytes)` touches.
pub fn pages_of(va: u64, bytes: u64, page_bytes: u64) -> u64 {
    (va + bytes.max(1) - 1) / page_bytes - va / page_bytes + 1
}

/// Translate every page a set of `(va, bytes)` operands touches through
/// `iommu` at cycle `now`, filling the TLB as a real pinned access stream
/// would. Returns `(cycles, hits, misses)` for this call alone.
pub fn translate_operands(
    iommu: &mut Iommu,
    pt: &PageTable,
    ops: &[(u64, u64)],
    now: u64,
) -> (u64, u64, u64) {
    let page = pt.page_bytes();
    let (h0, m0) = (iommu.hits, iommu.misses);
    let mut cycles = 0u64;
    for &(va, bytes) in ops {
        let first = va / page;
        let last = (va + bytes.max(1) - 1) / page;
        for p in first..=last {
            let t = iommu
                .translate(p * page, pt, now)
                .expect("SVM operand pages are always mapped by the space allocator");
            cycles += t.cost;
        }
    }
    (cycles, iommu.hits - h0, iommu.misses - m0)
}

/// Data-movement cycles of a pinned access stream: every NoC beat crosses
/// the 64-bit ext-address path and pays its constant overhead
/// (`timing.ext_addr_overhead`). This is the §2.1 "≈3 cycles per remote
/// access" cost, charged per beat — the tradeoff against copy staging is
/// per *byte*, not per element.
pub fn pin_access_cycles(bytes: u64, beat_bytes: u64, ext_addr_overhead: u64) -> u64 {
    bytes.div_ceil(beat_bytes.max(1)) * ext_addr_overhead
}

/// Fixed (non-ledger) cycles of copy staging: per-operand DMA setup plus
/// one software page-table walk per page pinned.
pub fn copy_fixed_cycles(ops: &[(u64, u64)], page_bytes: u64, setup: u64, walk: u64) -> u64 {
    ops.iter().map(|&(va, b)| setup + pages_of(va, b, page_bytes) * walk).sum()
}

/// Bytes copy staging moves through the host DRAM port: the operands in and
/// back out, plus one PTE read per pinned page.
pub fn copy_port_bytes(ops: &[(u64, u64)], page_bytes: u64) -> u64 {
    ops.iter().map(|&(va, b)| 2 * b + pages_of(va, b, page_bytes) * PTE_BYTES).sum()
}

/// Build an in-place scaling kernel `X[i] *= a` over `n` elements — the
/// canonical SVM workload: one operand, read and written through the
/// shared space.
pub fn scale_kernel(name: &str, n: usize) -> crate::compiler::ir::Kernel {
    use crate::compiler::ir::*;
    let mut b = KernelBuilder::new(name);
    let x = b.host_array("X", vec![ci(n as i32)]);
    let a = b.float_param("a");
    let i = b.loop_var("i");
    b.body(vec![par_for(
        i,
        ci(0),
        ci(n as i32),
        vec![st(x, vec![var(i)], var(a).mul(ld(x, vec![var(i)])))],
    )])
}

/// How many distinct small/large buffers [`submit_svm_stream`] cycles over.
/// Few small buffers → plenty of TLB reuse (where pin pays off); more large
/// buffers → a realistic working set for the staging path.
pub const SMALL_BUFFERS: usize = 2;
pub const LARGE_BUFFERS: usize = 4;

/// Submit the canonical SVM serving stream: `n_jobs` scale launches
/// alternating small (TLB-warm, pin-friendly) and large (copy-friendly)
/// operands drawn from a fixed set of shared buffers, so the same buffer
/// is revisited across launches exactly as an iterative host application
/// would. `mode` forces a per-job strategy override (`None` uses the
/// board default).
///
/// Requires SVM serving (`Scheduler::with_svm`); fully deterministic in
/// `seed`.
pub fn submit_svm_stream(
    s: &mut Scheduler,
    n_jobs: usize,
    seed: u64,
    mode: Option<SvmMode>,
) -> Result<Vec<JobHandle>> {
    let small: Vec<u64> = (0..SMALL_BUFFERS)
        .map(|i| s.svm_alloc_f32(crate::workloads::gen_f32(seed ^ (0x51 + i as u64), SMALL_ELEMS)))
        .collect::<Result<_>>()?;
    let large: Vec<u64> = (0..LARGE_BUFFERS)
        .map(|i| s.svm_alloc_f32(crate::workloads::gen_f32(seed ^ (0x1a + i as u64), LARGE_ELEMS)))
        .collect::<Result<_>>()?;
    let mut handles = Vec::with_capacity(n_jobs);
    for i in 0..n_jobs {
        let (va, elems, name) = if i % 2 == 0 {
            (small[(i / 2) % small.len()], SMALL_ELEMS, "svm_scale_s")
        } else {
            (large[(i / 2) % large.len()], LARGE_ELEMS, "svm_scale_l")
        };
        let mut j = KernelJob::from_srcs(
            scale_kernel(name, elems),
            vec![PayloadSrc::Svm { va, elems }],
            vec![1.5],
        );
        j.svm = mode;
        handles.push(s.submit_kernel(j));
    }
    Ok(handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;

    #[test]
    fn mode_parses_and_labels() {
        for (s, m) in [("pin", SvmMode::Pin), ("copy", SvmMode::Copy), ("auto", SvmMode::Auto)] {
            assert_eq!(SvmMode::parse(s).unwrap(), m);
            assert_eq!(m.label(), s);
        }
        assert!(SvmMode::parse("dma").is_err());
    }

    #[test]
    fn config_clamps_host_bw() {
        let c = SvmConfig::new(SvmMode::Auto).with_host_bw(0);
        assert_eq!(c.host_bw, 1);
        assert_eq!(SvmConfig::new(SvmMode::Pin).host_bw, DEFAULT_HOST_BW);
    }

    #[test]
    fn space_allocates_page_aligned_mapped_buffers() {
        let mut sp = SvmSpace::new(4096);
        let a = sp.alloc_f32(vec![1.0; 100]);
        let b = sp.alloc_f32(vec![2.0; 2000]);
        assert_eq!(a % 4096, 0);
        assert_eq!(b - a, 4096, "100 f32 rounds to one page");
        assert_eq!(sp.elems(a), Some(100));
        assert_eq!(sp.elems(b), Some(2000));
        assert_eq!(sp.elems(a + 4), None, "only allocation-start VAs resolve");
        // Every byte of both buffers translates through the page table.
        for off in [0u64, 399, 4096 + 7999] {
            assert!(sp.pt().walk(a + off).is_some());
        }
        assert_eq!(sp.get(a).unwrap()[0], 1.0);
        assert_eq!(sp.read(b).unwrap().len(), 2000);
    }

    #[test]
    fn write_back_updates_the_covered_prefix() {
        let mut sp = SvmSpace::new(4096);
        let va = sp.alloc_f32(vec![0.0; 8]);
        sp.write_back(va, &[9.0, 9.0, 9.0]);
        assert_eq!(sp.read(va).unwrap(), vec![9.0, 9.0, 9.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        sp.write_back(0xdead, &[1.0]); // unknown VA is a no-op
    }

    #[test]
    fn pages_of_counts_touched_pages() {
        assert_eq!(pages_of(0, 1, 4096), 1);
        assert_eq!(pages_of(0, 4096, 4096), 1);
        assert_eq!(pages_of(0, 4097, 4096), 2);
        assert_eq!(pages_of(4000, 200, 4096), 2, "straddles a boundary");
        assert_eq!(pages_of(8192, 0, 4096), 1, "empty range still touches its page");
    }

    #[test]
    fn translate_operands_warms_the_tlb() {
        let mut sp = SvmSpace::new(4096);
        let va = sp.alloc_f32(vec![0.0; 3000]); // 12000 B → 3 pages
        let mut io = Iommu::new(aurora().iommu);
        let walk = aurora().iommu.walk_cycles;
        let (c1, h1, m1) = translate_operands(&mut io, sp.pt(), &[(va, 12000)], 0);
        assert_eq!((c1, h1, m1), (3 * walk, 0, 3));
        let (c2, h2, m2) = translate_operands(&mut io, sp.pt(), &[(va, 12000)], 10);
        assert_eq!((c2, h2, m2), (0, 3, 0), "revisit hits and costs nothing");
    }

    #[test]
    fn cost_helpers_reproduce_the_pin_copy_tradeoff() {
        // Aurora-like constants: 8 B beats, 3-cycle ext overhead, 30-cycle
        // DMA setup, 150-cycle walks, 8 B/cy host port.
        let (beat, ext, setup, walk, hbw) = (8, 3, 30, 150, 8u64);
        let steady_pin = |bytes: u64| pin_access_cycles(bytes, beat, ext);
        let copy = |va: u64, bytes: u64| {
            copy_fixed_cycles(&[(va, bytes)], 4096, setup, walk)
                + copy_port_bytes(&[(va, bytes)], 4096).div_ceil(hbw)
        };
        let (s, l) = (SMALL_ELEMS as u64 * 4, LARGE_ELEMS as u64 * 4);
        assert!(steady_pin(s) < copy(0, s), "small operands favor warm pin");
        assert!(steady_pin(l) > copy(0, l), "large operands favor copy staging");
        assert_eq!(steady_pin(512), 64 * 3);
        assert_eq!(copy_port_bytes(&[(0, 512)], 4096), 2 * 512 + PTE_BYTES);
    }

    #[test]
    fn scale_kernel_builds() {
        let k = scale_kernel("svm_scale_t", 64);
        crate::sched::job::validate_shape(&k, &[64], 1).unwrap();
    }
}
