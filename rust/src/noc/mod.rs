//! On-chip network model.
//!
//! HEROv2's clusters are interconnected by two non-coherent AXI-style
//! networks (§2.1): a *wide* one for high-bandwidth DMA bursts and a *narrow*
//! one for low-latency single-word accesses by cores. Both are end-to-end
//! open-source in the real platform and — critically for the §3.3 case study
//! — the wide network's data width is configurable (32/64/128 bit).
//!
//! We model each network port as a serializing resource with burst-level
//! timing: a burst of `n` beats occupies the data path for `n` cycles; burst
//! issue overhead (address-channel handshake + DRAM access) is paid once per
//! *transfer* for long merged bursts (the AR channel pipelines ahead of the
//! data) but once per *burst* for scattered 2D row transfers, which is
//! exactly why "2D transfer patterns do not fully saturate the given on-chip
//! network" (§3.3, darknet/covar).

/// A serializing port with cycle-stamped occupancy (wide DMA path, narrow
/// remote-access path, icache refill port).
#[derive(Debug, Clone, Default)]
pub struct Port {
    free_at: u64,
    /// Total busy cycles, for utilization reporting.
    pub busy_cycles: u64,
}

impl Port {
    pub fn new() -> Self {
        Port::default()
    }

    /// Occupy the port for `duration` cycles starting no earlier than `now`.
    /// Returns (start, end): the request is serviced in `[start, end)`.
    pub fn acquire(&mut self, now: u64, duration: u64) -> (u64, u64) {
        let start = now.max(self.free_at);
        let end = start + duration;
        self.free_at = end;
        self.busy_cycles += duration;
        (start, end)
    }

    /// Next cycle at which the port is free.
    pub fn free_at(&self) -> u64 {
        self.free_at
    }

    pub fn reset(&mut self) {
        self.free_at = 0;
        self.busy_cycles = 0;
    }
}

/// Timing parameters of the wide (DMA) network path to main memory.
#[derive(Debug, Clone, Copy)]
pub struct WidePath {
    /// Bytes per beat (= data width / 8).
    pub beat_bytes: u64,
    /// Per-burst issue overhead in cycles (AR/AW handshake, NoC traversal,
    /// DRAM bank access). Hidden for all but the first burst of a merged
    /// transfer; paid per row for scattered transfers.
    pub burst_overhead: u64,
    /// First-word latency to DRAM (paid once per transfer).
    pub first_word: u64,
    /// Maximum beats per burst (long transfers are chunked, but chunks of
    /// one transfer pipeline back-to-back).
    pub max_burst_beats: u64,
}

impl WidePath {
    /// Beats needed for `bytes`.
    pub fn beats(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.beat_bytes)
    }

    /// Data-path occupancy of a *merged* (contiguous) transfer of `bytes`:
    /// one issue overhead + back-to-back beats.
    pub fn merged_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.burst_overhead + self.first_word + self.beats(bytes)
    }

    /// Data-path occupancy of a scattered transfer: `rows` bursts of
    /// `row_bytes` each. Every row pays the burst issue overhead — the DMA
    /// engine must reconfigure the address per row (§3.2: "initiates a new
    /// DMA burst for each row, which adds an overhead compared to the single
    /// DMA burst in the handwritten code").
    pub fn scattered_cycles(&self, rows: u64, row_bytes: u64) -> u64 {
        if rows == 0 || row_bytes == 0 {
            return 0;
        }
        self.first_word + rows * (self.burst_overhead + self.beats(row_bytes))
    }
}

/// Timing parameters of the narrow (core remote access) path.
#[derive(Debug, Clone, Copy)]
pub struct NarrowPath {
    /// End-to-end latency of a remote word load (NoC + IOMMU + DRAM),
    /// excluding the ext-CSR overhead charged on the core side.
    pub load_latency: u64,
    /// Port occupancy per remote access (issue rate limit shared by the
    /// cores of a cluster).
    pub service: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wide64() -> WidePath {
        WidePath { beat_bytes: 8, burst_overhead: 25, first_word: 100, max_burst_beats: 256 }
    }

    #[test]
    fn port_serializes() {
        let mut p = Port::new();
        let (s1, e1) = p.acquire(0, 10);
        let (s2, e2) = p.acquire(5, 10);
        assert_eq!((s1, e1), (0, 10));
        assert_eq!((s2, e2), (10, 20));
        assert_eq!(p.busy_cycles, 20);
    }

    #[test]
    fn port_idles_between_requests() {
        let mut p = Port::new();
        p.acquire(0, 4);
        let (s, _) = p.acquire(100, 4);
        assert_eq!(s, 100);
    }

    #[test]
    fn merged_scales_with_width() {
        // A 2 KiB merged transfer: doubling the width halves the beat count.
        let w32 = WidePath { beat_bytes: 4, ..wide64() };
        let w128 = WidePath { beat_bytes: 16, ..wide64() };
        let beats64 = wide64().merged_cycles(2048) - 125;
        let beats32 = w32.merged_cycles(2048) - 125;
        let beats128 = w128.merged_cycles(2048) - 125;
        assert_eq!(beats64, 256);
        assert_eq!(beats32, 512);
        assert_eq!(beats128, 128);
    }

    #[test]
    fn scattered_pays_overhead_per_row() {
        // 97-word rows (darknet tile): scattered vs merged ratios reproduce
        // the §3.3 observation that 2D patterns undersaturate wide links.
        let rows = 97u64;
        let row_bytes = 97 * 4;
        let w = wide64();
        let w128 = WidePath { beat_bytes: 16, ..wide64() };
        let w32 = WidePath { beat_bytes: 4, ..wide64() };
        let c64 = w.scattered_cycles(rows, row_bytes) as f64;
        let c128 = w128.scattered_cycles(rows, row_bytes) as f64;
        let c32 = w32.scattered_cycles(rows, row_bytes) as f64;
        let speedup128 = c64 / c128;
        let slowdown32 = c64 / c32;
        // Paper Fig 8 darknet DMA bars: 0.6× at 32 bit, 1.5× at 128 bit.
        assert!((1.3..1.7).contains(&speedup128), "128-bit speedup {speedup128}");
        assert!((0.55..0.7).contains(&slowdown32), "32-bit speedup {slowdown32}");
    }

    #[test]
    fn beats_round_up() {
        assert_eq!(wide64().beats(1), 1);
        assert_eq!(wide64().beats(8), 1);
        assert_eq!(wide64().beats(9), 2);
        assert_eq!(wide64().beats(0), 0);
    }
}
