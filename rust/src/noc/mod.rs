//! On-chip network model.
//!
//! HEROv2's clusters are interconnected by two non-coherent AXI-style
//! networks (§2.1): a *wide* one for high-bandwidth DMA bursts and a *narrow*
//! one for low-latency single-word accesses by cores. Both are end-to-end
//! open-source in the real platform and — critically for the §3.3 case study
//! — the wide network's data width is configurable (32/64/128 bit).
//!
//! We model each network port as a serializing resource with burst-level
//! timing: a burst of `n` beats occupies the data path for `n` cycles; burst
//! issue overhead (address-channel handshake + DRAM access) is paid once per
//! *transfer* for long merged bursts (the AR channel pipelines ahead of the
//! data) but once per *burst* for scattered 2D row transfers, which is
//! exactly why "2D transfer patterns do not fully saturate the given on-chip
//! network" (§3.3, darknet/covar).

/// A serializing port with cycle-stamped occupancy (wide DMA path, narrow
/// remote-access path, icache refill port).
#[derive(Debug, Clone, Default)]
pub struct Port {
    free_at: u64,
    /// Total busy cycles, for utilization reporting.
    pub busy_cycles: u64,
}

impl Port {
    pub fn new() -> Self {
        Port::default()
    }

    /// Occupy the port for `duration` cycles starting no earlier than `now`.
    /// Returns (start, end): the request is serviced in `[start, end)`.
    pub fn acquire(&mut self, now: u64, duration: u64) -> (u64, u64) {
        let start = now.max(self.free_at);
        let end = start + duration;
        self.free_at = end;
        self.busy_cycles += duration;
        (start, end)
    }

    /// Next cycle at which the port is free.
    pub fn free_at(&self) -> u64 {
        self.free_at
    }

    pub fn reset(&mut self) {
        self.free_at = 0;
        self.busy_cycles = 0;
    }
}

/// Timing parameters of the wide (DMA) network path to main memory.
#[derive(Debug, Clone, Copy)]
pub struct WidePath {
    /// Bytes per beat (= data width / 8).
    pub beat_bytes: u64,
    /// Per-burst issue overhead in cycles (AR/AW handshake, NoC traversal,
    /// DRAM bank access). Hidden for all but the first burst of a merged
    /// transfer; paid per row for scattered transfers.
    pub burst_overhead: u64,
    /// First-word latency to DRAM (paid once per transfer).
    pub first_word: u64,
    /// Maximum beats per burst (long transfers are chunked, but chunks of
    /// one transfer pipeline back-to-back).
    pub max_burst_beats: u64,
}

impl WidePath {
    /// Beats needed for `bytes`.
    pub fn beats(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.beat_bytes)
    }

    /// Bursts a contiguous run of `beats` beats is chunked into (AXI caps a
    /// single burst at `max_burst_beats` beats).
    pub fn bursts_of(&self, beats: u64) -> u64 {
        beats.div_ceil(self.max_burst_beats.max(1))
    }

    /// Visible re-issue cost per chunk after the first of a burst train.
    /// The AR channel pipelines one address phase ahead, so a chunk's issue
    /// overhead hides behind the previous chunk's data phase (one cycle per
    /// beat, up to `max_burst_beats` cycles); only the remainder stalls the
    /// data path. With the default configurations (256-beat bursts, tens of
    /// cycles of overhead) this is zero — chunks stream back-to-back — but
    /// tiny `max_burst_beats` values expose the re-issue cost, which is what
    /// makes the field observable.
    fn reissue_gap(&self) -> u64 {
        self.burst_overhead.saturating_sub(self.max_burst_beats.max(1))
    }

    /// Data-path occupancy of a *merged* (contiguous) transfer of `bytes`:
    /// one issue overhead + beats, chunked into bursts of at most
    /// `max_burst_beats` beats whose re-issue cost pipelines behind data.
    pub fn merged_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let beats = self.beats(bytes);
        self.burst_overhead
            + self.first_word
            + beats
            + (self.bursts_of(beats) - 1) * self.reissue_gap()
    }

    /// Data-path occupancy of a scattered transfer: `rows` bursts of
    /// `row_bytes` each. Every row pays the burst issue overhead — the DMA
    /// engine must reconfigure the address per row (§3.2: "initiates a new
    /// DMA burst for each row, which adds an overhead compared to the single
    /// DMA burst in the handwritten code"). Rows longer than
    /// `max_burst_beats` beats are additionally chunked like merged
    /// transfers.
    pub fn scattered_cycles(&self, rows: u64, row_bytes: u64) -> u64 {
        if rows == 0 || row_bytes == 0 {
            return 0;
        }
        let row_beats = self.beats(row_bytes);
        let row_cost = self.burst_overhead
            + row_beats
            + (self.bursts_of(row_beats) - 1) * self.reissue_gap();
        self.first_word + rows * row_cost
    }
}

/// Timing parameters of the narrow (core remote access) path.
#[derive(Debug, Clone, Copy)]
pub struct NarrowPath {
    /// End-to-end latency of a remote word load (NoC + IOMMU + DRAM),
    /// excluding the ext-CSR overhead charged on the core side.
    pub load_latency: u64,
    /// Port occupancy per remote access (issue rate limit shared by the
    /// cores of a cluster).
    pub service: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wide64() -> WidePath {
        WidePath { beat_bytes: 8, burst_overhead: 25, first_word: 100, max_burst_beats: 256 }
    }

    #[test]
    fn port_serializes() {
        let mut p = Port::new();
        let (s1, e1) = p.acquire(0, 10);
        let (s2, e2) = p.acquire(5, 10);
        assert_eq!((s1, e1), (0, 10));
        assert_eq!((s2, e2), (10, 20));
        assert_eq!(p.busy_cycles, 20);
    }

    #[test]
    fn port_idles_between_requests() {
        let mut p = Port::new();
        p.acquire(0, 4);
        let (s, _) = p.acquire(100, 4);
        assert_eq!(s, 100);
    }

    #[test]
    fn merged_scales_with_width() {
        // A 2 KiB merged transfer: doubling the width halves the beat count.
        let w32 = WidePath { beat_bytes: 4, ..wide64() };
        let w128 = WidePath { beat_bytes: 16, ..wide64() };
        let beats64 = wide64().merged_cycles(2048) - 125;
        let beats32 = w32.merged_cycles(2048) - 125;
        let beats128 = w128.merged_cycles(2048) - 125;
        assert_eq!(beats64, 256);
        assert_eq!(beats32, 512);
        assert_eq!(beats128, 128);
    }

    #[test]
    fn scattered_pays_overhead_per_row() {
        // 97-word rows (darknet tile): scattered vs merged ratios reproduce
        // the §3.3 observation that 2D patterns undersaturate wide links.
        let rows = 97u64;
        let row_bytes = 97 * 4;
        let w = wide64();
        let w128 = WidePath { beat_bytes: 16, ..wide64() };
        let w32 = WidePath { beat_bytes: 4, ..wide64() };
        let c64 = w.scattered_cycles(rows, row_bytes) as f64;
        let c128 = w128.scattered_cycles(rows, row_bytes) as f64;
        let c32 = w32.scattered_cycles(rows, row_bytes) as f64;
        let speedup128 = c64 / c128;
        let slowdown32 = c64 / c32;
        // Paper Fig 8 darknet DMA bars: 0.6× at 32 bit, 1.5× at 128 bit.
        assert!((1.3..1.7).contains(&speedup128), "128-bit speedup {speedup128}");
        assert!((0.55..0.7).contains(&slowdown32), "32-bit speedup {slowdown32}");
    }

    #[test]
    fn merged_chunks_at_max_burst_beats() {
        // 4-beat bursts, 25-cycle overhead: each extra chunk exposes
        // 25 - 4 = 21 cycles the AR pipelining cannot hide.
        let w = WidePath { max_burst_beats: 4, ..wide64() };
        // Exactly one burst: identical to the unchunked model.
        assert_eq!(w.merged_cycles(4 * 8), 25 + 100 + 4);
        // One beat over the boundary: second burst appears.
        assert_eq!(w.merged_cycles(5 * 8), 25 + 100 + 5 + 21);
        // 64 beats = 16 bursts: 15 visible re-issues.
        assert_eq!(w.merged_cycles(64 * 8), 25 + 100 + 64 + 15 * 21);
        // Wide default (256-beat bursts): overhead fully pipelined away, so
        // the historical numbers are unchanged even for multi-burst trains.
        assert_eq!(wide64().merged_cycles(512 * 8), 25 + 100 + 512);
        assert_eq!(wide64().bursts_of(512), 2);
    }

    #[test]
    fn scattered_chunks_long_rows() {
        let w = WidePath { max_burst_beats: 4, ..wide64() };
        // 6-beat rows: 2 bursts per row, one visible re-issue each.
        assert_eq!(w.scattered_cycles(3, 6 * 8), 100 + 3 * (25 + 6 + 21));
        // Rows at the boundary stay single-burst.
        assert_eq!(w.scattered_cycles(3, 4 * 8), 100 + 3 * (25 + 4));
        // Default configuration: unchanged.
        assert_eq!(wide64().scattered_cycles(3, 4 * 8), 100 + 3 * (25 + 4));
    }

    #[test]
    fn bursts_of_rounds_up() {
        let w = wide64();
        assert_eq!(w.bursts_of(1), 1);
        assert_eq!(w.bursts_of(256), 1);
        assert_eq!(w.bursts_of(257), 2);
        let tiny = WidePath { max_burst_beats: 1, ..wide64() };
        assert_eq!(tiny.bursts_of(7), 7);
    }

    #[test]
    fn beats_round_up() {
        assert_eq!(wide64().beats(1), 1);
        assert_eq!(wide64().beats(8), 1);
        assert_eq!(wide64().beats(9), 2);
        assert_eq!(wide64().beats(0), 0);
    }
}
