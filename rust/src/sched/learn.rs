//! Online cycle-prediction refinement (the measure → refine loop).
//!
//! Every completed job carries *exact* measured device cycles, yet the
//! scheduler's ordering (SJF), placement (pressure scoring) and
//! contention-aware inflation all run on the static predictor
//! ([`crate::compiler::metrics::predict_cycles`]) — an intentionally cheap
//! IR walk that can be off by large factors (the AutoDMA variant is even
//! costed by a handwritten *proxy* kernel, see
//! [`crate::sched::policy::predict_job`]). This module closes the loop:
//! a [`LearnStore`] keyed by the same identity space as the binary cache
//! (structural content hash × input elements × effective parallel width ×
//! platform config) blends measurements into a per-key **integer
//! fixed-point EWMA**, and the scheduler consults the refined figure
//! everywhere it used to read the static one.
//!
//! The EWMA is Q·{2^[`FP_SHIFT`]} fixed point, seeded from the static
//! prediction on a key's first observation and updated as
//!
//! ```text
//! r₀ = static_prediction
//! rₖ = (rₖ₋₁ + measuredₖ) / 2        (α = 1/2, integer arithmetic)
//! ```
//!
//! so after k observations the static model's weight is 2^-k — a few
//! repeats of a hot binary and the store speaks from measurement. All
//! arithmetic is u64; no floats, no wall clock, no platform-dependent
//! rounding: refined predictions are exactly replayable, which keeps the
//! cycle-regression bench gate byte-stable and the digest-invariance
//! property tests meaningful.
//!
//! The store also books per-job prediction error, in integer
//! mean-abs-percent form: for every completed job it records how far the
//! *static* prediction and the *refined-at-dispatch* prediction each landed
//! from the measured device cycles. [`crate::sched::ServeReport`] surfaces
//! both, so a serve run shows the before/after value of learning at a
//! glance.

use std::collections::HashMap;

/// Fixed-point fractional bits of an EWMA cell (Q56.8 — job budgets are
/// capped at 1e10 cycles, far below 2^56).
pub const FP_SHIFT: u32 = 8;

/// Identity of "the same work" for prediction refinement: measurements
/// under one key describe one (kernel, problem, parallel width, platform)
/// combination, mirroring the binary cache's key spaces
/// ([`crate::sched::cache::IrKey`] / [`crate::sched::cache::BinKey`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LearnKey {
    /// Structural identity: the IR content hash for kernel jobs
    /// ([`crate::sched::job::kernel_content_key`]), or
    /// [`named_content`] for registry workloads.
    pub content: u64,
    /// Input footprint in f32 elements.
    pub elems: u64,
    /// Effective thread count (clamped to the cluster width, like the
    /// cache keys — an inflated request executes clamped, so it must share
    /// the clamped key's measurements).
    pub threads: u32,
    /// Teams the launch fans out over (1 for named jobs).
    pub teams: u32,
    /// Platform configuration name (predictions are made against the
    /// pool's base config).
    pub config: String,
}

/// Content hash for a *named* registry job: FNV-1a over the kernel name,
/// variant label and problem size (the triple that picks the executed
/// binary — the named-job analogue of the IR content hash).
pub fn named_content(kernel: &str, variant: &str, size: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h = (h ^ 0xff).wrapping_mul(0x0000_0100_0000_01b3);
    };
    eat(kernel.as_bytes());
    eat(variant.as_bytes());
    eat(&(size as u64).to_le_bytes());
    h
}

/// One key's EWMA state.
#[derive(Debug, Clone, Copy)]
struct Cell {
    /// Refined cycle estimate in Q56.8 fixed point.
    fp: u64,
    /// Measurements blended in so far.
    samples: u64,
}

/// The refinement store: per-key EWMA cells plus aggregate prediction-error
/// accounting. Owned by the scheduler when `--learn` is on; absent
/// otherwise, so the learning-off path never touches it.
#[derive(Debug, Default)]
pub struct LearnStore {
    cells: HashMap<LearnKey, Cell>,
    /// Completed jobs whose predictions were scored.
    samples: u64,
    /// Σ per-job abs(static − measured) · 100 / measured.
    static_err_pct_sum: u64,
    /// Σ per-job abs(refined-at-dispatch − measured) · 100 / measured.
    refined_err_pct_sum: u64,
}

impl LearnStore {
    pub fn new() -> Self {
        LearnStore::default()
    }

    /// The refined cycle prediction for `key`: the EWMA estimate
    /// (round-to-nearest out of fixed point) when measurements exist, the
    /// static prediction otherwise. Read-only — safe to call from scoring
    /// paths without perturbing the store.
    pub fn refine(&self, key: &LearnKey, static_prediction: u64) -> u64 {
        match self.cells.get(key) {
            Some(c) => (c.fp + (1 << (FP_SHIFT - 1))) >> FP_SHIFT,
            None => static_prediction,
        }
    }

    /// Blend one measurement into `key`'s cell, seeding the cell from the
    /// static prediction on first observation: `r ← (r + measured) / 2`.
    pub fn observe(&mut self, key: LearnKey, static_prediction: u64, measured: u64) {
        let cell = self
            .cells
            .entry(key)
            .or_insert(Cell { fp: static_prediction << FP_SHIFT, samples: 0 });
        cell.fp = (cell.fp + (measured << FP_SHIFT)) / 2;
        cell.samples += 1;
    }

    /// Book one completed job's prediction error: how far the static and
    /// the refined-at-dispatch predictions each landed from the measured
    /// device cycles, in integer percent of the measurement.
    pub fn score(&mut self, static_prediction: u64, dispatched_prediction: u64, measured: u64) {
        let m = measured.max(1);
        self.samples += 1;
        self.static_err_pct_sum += static_prediction.abs_diff(measured) * 100 / m;
        self.refined_err_pct_sum += dispatched_prediction.abs_diff(measured) * 100 / m;
    }

    /// Completed jobs scored so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Distinct (kernel, size, width, config) keys with measurements.
    pub fn tracked(&self) -> usize {
        self.cells.len()
    }

    /// Mean abs prediction error of the *static* model, in percent.
    pub fn mean_static_err_pct(&self) -> u64 {
        if self.samples == 0 {
            0
        } else {
            self.static_err_pct_sum / self.samples
        }
    }

    /// Mean abs prediction error of the predictions *actually dispatched
    /// with* (refined where measurements existed), in percent.
    pub fn mean_refined_err_pct(&self) -> u64 {
        if self.samples == 0 {
            0
        } else {
            self.refined_err_pct_sum / self.samples
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(content: u64) -> LearnKey {
        LearnKey { content, elems: 1024, threads: 8, teams: 1, config: "aurora".into() }
    }

    #[test]
    fn refine_falls_back_to_static_without_measurements() {
        let s = LearnStore::new();
        assert_eq!(s.refine(&key(1), 5000), 5000);
        assert_eq!(s.tracked(), 0);
    }

    #[test]
    fn ewma_converges_toward_measurements() {
        let mut s = LearnStore::new();
        // Static model says 10_000; the job really takes 2_000.
        s.observe(key(1), 10_000, 2_000);
        let r1 = s.refine(&key(1), 10_000);
        assert_eq!(r1, 6_000, "first blend is the midpoint");
        s.observe(key(1), 10_000, 2_000);
        s.observe(key(1), 10_000, 2_000);
        s.observe(key(1), 10_000, 2_000);
        let r4 = s.refine(&key(1), 10_000);
        assert!(r4 < 2_600, "static weight decays 2^-k: {r4}");
        assert!(r4 >= 2_000, "never overshoots a stable measurement: {r4}");
        // Stability: identical measurements converge monotonically.
        for _ in 0..60 {
            s.observe(key(1), 10_000, 2_000);
        }
        assert_eq!(s.refine(&key(1), 10_000), 2_000);
    }

    #[test]
    fn keys_do_not_cross_contaminate() {
        let mut s = LearnStore::new();
        s.observe(key(1), 1_000, 9_000);
        assert_eq!(s.refine(&key(2), 1_000), 1_000, "other keys stay static");
        let mut k_threads = key(1);
        k_threads.threads = 4;
        assert_eq!(s.refine(&k_threads, 1_000), 1_000, "width is part of the key");
        assert_eq!(s.tracked(), 1);
    }

    #[test]
    fn error_scoring_is_integer_percent() {
        let mut s = LearnStore::new();
        // Static off by 150%, refined off by 10%.
        s.score(2_500, 1_100, 1_000);
        // Static off by 50% (under), refined exact.
        s.score(500, 1_000, 1_000);
        assert_eq!(s.samples(), 2);
        assert_eq!(s.mean_static_err_pct(), 100, "(150 + 50) / 2");
        assert_eq!(s.mean_refined_err_pct(), 5, "(10 + 0) / 2");
    }

    #[test]
    fn zero_measurement_is_safe() {
        let mut s = LearnStore::new();
        s.score(100, 100, 0);
        assert_eq!(s.mean_static_err_pct(), 100 * 100);
        s.observe(key(3), 100, 0);
        assert_eq!(s.refine(&key(3), 100), 50);
    }

    #[test]
    fn named_content_separates_kernel_variant_and_size() {
        let a = named_content("gemm", "handwritten", 12);
        assert_eq!(a, named_content("gemm", "handwritten", 12));
        assert_ne!(a, named_content("gemm", "handwritten", 24));
        assert_ne!(a, named_content("gemm", "autodma", 12));
        assert_ne!(a, named_content("atax", "handwritten", 12));
    }
}
