//! Board-aware placement: which pool instance a chosen job should run on.
//!
//! The original pool placed every job on the earliest-free instance — the
//! right call when instances are independent, but blind to the one
//! resource they share: the carrier board's DRAM bandwidth
//! ([`crate::mem::BandwidthLedger`]). On a bandwidth-constrained board,
//! earliest-free happily opens a DMA-heavy job's occupancy window right on
//! top of another instance's reservation, and the job burns its slot
//! *stalled* — cycles the makespan pays twice, once as dead slot time and
//! once as the delayed tail behind it.
//!
//! [`Placement::Pressure`] scores every candidate slot by the job's
//! **predicted finish time including DRAM contention**:
//!
//! ```text
//! start_i  = max(arrival, free_at(i))
//! window_i = max(predicted_cycles, predict_dma_cycles(bytes, drain_i))
//! stall_i  = probe_stall(i, start_i, bytes)      // read-only ledger what-if
//! finish_i = start_i + window_i + stall_i
//! ```
//!
//! and picks the minimum `(finish, stall, free_at, index)`. The stall term
//! is [`crate::sched::pool::InstancePool::probe_stall`] — the exact stall
//! `assign` would book, i.e. the reserved-rate step function
//! (`SharedDram::pressure_at` at every cycle) integrated over the job's
//! predicted window at the slot's drain rate. The `stall` tie-break is the
//! co-scheduling rule: when two slots predict the same finish, prefer the
//! one that *waits* for the board to clear over the one that burns slot
//! time stalled — which steers DMA-heavy jobs onto non-overlapping DRAM
//! windows and leaves the early slot free for compute-heavy work.
//!
//! Two exact identities keep the engine safe to enable by default:
//!
//! * **Uncontended board ⇒ earliest-free.** With no reservations above the
//!   peak, every `stall_i` is exactly 0 and `window_i` is a per-job
//!   constant across a homogeneous pool, so the score is a monotone
//!   transform of `free_at` and the argmin (including tie-breaks) is
//!   bit-identical to [`crate::sched::pool::InstancePool::pick`]. The
//!   property test
//!   `prop_pressure_placement_identical_to_earliest_free_on_uncontended_board`
//!   pins this.
//! * **All integer.** Scores are u64 arithmetic end to end — no floats, no
//!   platform-dependent rounding, so placements are deterministic and the
//!   cycle-regression gate can compare them exactly.

use super::policy;
use super::pool::InstancePool;

/// Which instance a dispatched job lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// The instance that frees up first (`InstancePool::pick`) — the
    /// board-blind baseline.
    #[default]
    EarliestFree,
    /// Minimize predicted finish time including DRAM-stall inflation from
    /// the board ledger's reserved bandwidth over the job's window.
    Pressure,
}

impl Placement {
    /// Parse a `--placement` argument.
    pub fn parse(s: &str) -> Option<Placement> {
        match s {
            "earliest" | "earliest-free" => Some(Placement::EarliestFree),
            "pressure" | "dram-pressure" => Some(Placement::Pressure),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Placement::EarliestFree => "earliest",
            Placement::Pressure => "pressure",
        }
    }
}

/// One candidate slot's score for a job.
#[derive(Debug, Clone, Copy)]
pub struct SlotScore {
    pub instance: usize,
    /// Cycle the job's occupancy window would open.
    pub start: u64,
    /// Predicted DRAM contention stall inside that window.
    pub stall: u64,
    /// Predicted completion: `start + window + stall`.
    pub finish: u64,
}

/// Score every slot of `pool` for a job of `predicted_cycles` static cycles
/// and `dma_bytes` of board-DRAM traffic, runnable from `arrival`.
///
/// `arrival` is the *effective* arrival the scheduler computes: for a job
/// with dataflow/ordering producers it is the last producer's finish, so
/// the engine scores a chained consumer from the first cycle its input can
/// exist — not from its submission cycle.
pub fn scores(
    pool: &InstancePool,
    arrival: u64,
    predicted_cycles: u64,
    dma_bytes: u64,
    priority: bool,
) -> Vec<SlotScore> {
    scores_from(pool, &[], arrival, predicted_cycles, dma_bytes, priority)
}

/// [`scores`] with an explicit per-slot availability floor: slot `i` cannot
/// open a window before `floor[i]` even if its port frees earlier (missing
/// entries floor at 0, so `&[]` reduces to plain [`scores`]). This is what
/// lets the fleet router ([`crate::fleet`]) score a board through exactly
/// this engine while layering its own *projected* occupancy — jobs already
/// routed to the board but not yet drained — on top of the pool's real
/// port state.
pub fn scores_from(
    pool: &InstancePool,
    floor: &[u64],
    arrival: u64,
    predicted_cycles: u64,
    dma_bytes: u64,
    priority: bool,
) -> Vec<SlotScore> {
    (0..pool.len())
        .map(|i| {
            let free = pool.free_at(i).max(floor.get(i).copied().unwrap_or(0));
            let start = arrival.max(free);
            // The occupancy proxy: the job's static prediction, floored by
            // its uncontended DRAM service time at this slot's drain rate
            // (a narrow heterogeneous slot can be DMA-bound even when the
            // base-config prediction says otherwise).
            let window = predicted_cycles
                .max(policy::predict_dma_cycles(dma_bytes, pool.drain_rate(i)));
            let stall = pool.probe_stall(i, start, dma_bytes, priority);
            SlotScore { instance: i, start, stall, finish: start + window + stall }
        })
        .collect()
}

/// One job in a lookahead window: the per-job inputs [`scores`] needs,
/// detached from the scheduler's internals so the joint search stays a
/// pure function of the pool.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Effective (dependency-aware) arrival.
    pub arrival: u64,
    /// Memoized (possibly learning-refined) cycle prediction.
    pub predicted: u64,
    /// Board-DRAM byte footprint.
    pub dma_bytes: u64,
    /// Reserves board bandwidth as a priority request.
    pub priority: bool,
}

/// Joint lookahead dispatch: pick `(candidate index, instance)` for the
/// *head* of a K-candidate window, scoring the whole window instead of
/// greedily placing `cands[0]`.
///
/// The search builds the K×slots [`SlotScore`] matrix, then evaluates each
/// candidate as the head with a pairwise-interaction cost: the head's own
/// best finish (minimal `(finish, stall, free_at, index)` — exactly the
/// [`choose`] tie-breaks, so a singleton window reduces to today's greedy
/// placement bit-for-bit) plus, for every *other* candidate, its cheapest
/// finish given the head's slot is now busy until the head's finish (same
/// window and stall terms, start pushed to the head's finish; other slots
/// keep their matrix scores). Minimal total wins; ties break toward the
/// earlier candidate in policy order, i.e. toward the job the greedy
/// scheduler would have dispatched. All-integer, read-only on the pool —
/// deterministic and replayable like everything else in this module.
pub fn choose_joint(pool: &InstancePool, cands: &[Candidate]) -> (usize, usize) {
    assert!(!cands.is_empty(), "lookahead window is non-empty");
    let matrix: Vec<Vec<SlotScore>> = cands
        .iter()
        .map(|c| scores(pool, c.arrival, c.predicted, c.dma_bytes, c.priority))
        .collect();
    let best_slot = |row: &[SlotScore]| -> SlotScore {
        row.iter()
            .copied()
            .min_by_key(|s| (s.finish, s.stall, pool.free_at(s.instance), s.instance))
            .expect("pool is non-empty")
    };
    let mut best = (u64::MAX, 0usize);
    for (c, row) in matrix.iter().enumerate() {
        let head = best_slot(row);
        let mut total = head.finish;
        for (d, drow) in matrix.iter().enumerate() {
            if d == c {
                continue;
            }
            let follow = drow
                .iter()
                .map(|s| {
                    if s.instance == head.instance {
                        // Queue behind the head on its slot: same window
                        // and stall terms, start pushed to the head's
                        // predicted finish.
                        let window = s.finish - s.start - s.stall;
                        cands[d].arrival.max(head.finish) + window + s.stall
                    } else {
                        s.finish
                    }
                })
                .min()
                .expect("pool is non-empty");
            total += follow;
        }
        // Strict `<`: ties break toward the earlier candidate in policy
        // order — the job the greedy scheduler would have dispatched.
        if total < best.0 {
            best = (total, c);
        }
    }
    let c = best.1;
    (c, best_slot(&matrix[c]).instance)
}

/// Pick the instance for a job under `placement`. For
/// [`Placement::Pressure`] the winner is the minimal
/// `(finish, stall, free_at, index)` — see the module docs for why each
/// tie-break is load-bearing.
pub fn choose(
    pool: &InstancePool,
    placement: Placement,
    arrival: u64,
    predicted_cycles: u64,
    dma_bytes: u64,
    priority: bool,
) -> usize {
    match placement {
        Placement::EarliestFree => pool.pick(),
        Placement::Pressure => scores(pool, arrival, predicted_cycles, dma_bytes, priority)
            .into_iter()
            .min_by_key(|s| (s.finish, s.stall, pool.free_at(s.instance), s.instance))
            .map(|s| s.instance)
            .expect("pool is non-empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::sched::pool::{BoardSpec, InstancePool};

    #[test]
    fn parse_and_labels() {
        assert_eq!(Placement::parse("earliest"), Some(Placement::EarliestFree));
        assert_eq!(Placement::parse("earliest-free"), Some(Placement::EarliestFree));
        assert_eq!(Placement::parse("pressure"), Some(Placement::Pressure));
        assert_eq!(Placement::parse("dram-pressure"), Some(Placement::Pressure));
        assert_eq!(Placement::parse("best-fit"), None);
        assert_eq!(Placement::default(), Placement::EarliestFree);
        assert_eq!(Placement::Pressure.label(), "pressure");
    }

    #[test]
    fn uncontended_pressure_matches_earliest_free_choice() {
        // With zero board pressure the score reduces to a monotone
        // transform of free_at, so both placements agree — including the
        // lowest-index tie-break on an idle pool.
        let mut p = InstancePool::homogeneous(&aurora(), 3, BoardSpec::uncontended());
        for (arrival, predicted, bytes) in
            [(0u64, 1000u64, 0u64), (0, 500, 4096), (250, 1000, 800), (10_000, 1, 64)]
        {
            let ef = choose(&p, Placement::EarliestFree, arrival, predicted, bytes, false);
            let pr = choose(&p, Placement::Pressure, arrival, predicted, bytes, false);
            assert_eq!(ef, pr, "placements diverged on an uncontended board");
            p.assign(ef, arrival, predicted.max(1), bytes, false);
        }
    }

    #[test]
    fn pressure_avoids_stalling_on_a_saturated_window() {
        // Board peak = one instance's 8 B/cycle drain rate. Instance 0 runs
        // a DMA job whose reservation saturates [0, 100); instance 1 runs a
        // short compute job. A DMA-heavy follow-up arriving at cycle 30:
        //   earliest-free picks instance 1 (free at 30) and burns 70 cycles
        //     stalled behind instance 0's reservation (finish 200);
        //   pressure sees the same finish either way and breaks the tie
        //     away from the stall, landing on instance 0 (starts at 100,
        //     clear board, zero stall) — leaving instance 1 free from cycle
        //     30 for compute work instead of a DRAM wait.
        let mut p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::with_bandwidth(8));
        p.assign(0, 0, 100, 800, false); // reserves 8 B/cy over [0, 100)
        p.assign(1, 0, 30, 0, false);
        let s = scores(&p, 30, 100, 800, false);
        assert_eq!((s[0].start, s[0].stall, s[0].finish), (100, 0, 200));
        assert_eq!((s[1].start, s[1].stall, s[1].finish), (30, 70, 200));
        assert_eq!(choose(&p, Placement::EarliestFree, 30, 100, 800, false), 1);
        assert_eq!(choose(&p, Placement::Pressure, 30, 100, 800, false), 0);
        // A pure compute job keeps going to the earliest-free slot.
        assert_eq!(choose(&p, Placement::Pressure, 30, 100, 0, false), 1);
    }

    #[test]
    fn pressure_prefers_strictly_earlier_finish() {
        // Instance 0 frees at 1000; instance 1 at 0 with a clear board: the
        // earlier slot wins outright on finish, no tie-break needed.
        let mut p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::with_bandwidth(16));
        p.assign(0, 0, 1000, 0, false);
        assert_eq!(choose(&p, Placement::Pressure, 0, 200, 800, false), 1);
    }

    #[test]
    fn joint_singleton_reduces_to_greedy_pressure_choice() {
        // The safety identity for `--lookahead 1`: a one-candidate window
        // must land on exactly the slot the greedy engine picks — same
        // (finish, stall, free_at, index) tie-breaks, bit for bit.
        let mut p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::with_bandwidth(8));
        p.assign(0, 0, 100, 800, false);
        p.assign(1, 0, 30, 0, false);
        for (arrival, predicted, bytes) in [(30u64, 100u64, 800u64), (30, 100, 0), (0, 200, 800)] {
            let c = Candidate { arrival, predicted, dma_bytes: bytes, priority: false };
            let (idx, inst) = choose_joint(&p, &[c]);
            assert_eq!(idx, 0);
            assert_eq!(inst, choose(&p, Placement::Pressure, arrival, predicted, bytes, false));
        }
    }

    #[test]
    fn joint_window_promotes_the_pair_wise_cheaper_head() {
        // One slot, a long job ahead of a short one in policy order. Greedy
        // dispatches the long head; the joint score sees that short-first
        // finishes the *pair* earlier (10 + 110 < 100 + 110) and
        // promotes the short job to head. Equal predictions tie back to
        // policy order.
        let p = InstancePool::homogeneous(&aurora(), 1, BoardSpec::uncontended());
        let cand = |predicted| Candidate { arrival: 0, predicted, dma_bytes: 0, priority: false };
        assert_eq!(choose_joint(&p, &[cand(100), cand(10)]), (1, 0));
        assert_eq!(choose_joint(&p, &[cand(100), cand(100)]), (0, 0));
    }

    #[test]
    fn floored_scores_delay_starts_without_touching_the_ledger() {
        let p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::uncontended());
        // No floor: both slots open at arrival.
        let base = scores_from(&p, &[], 10, 100, 0, false);
        assert_eq!((base[0].start, base[1].start), (10, 10));
        // A projected backlog on slot 0 pushes only that slot's window; a
        // short floor list leaves the uncovered slot at its port state.
        let floored = scores_from(&p, &[500], 10, 100, 0, false);
        assert_eq!((floored[0].start, floored[0].finish), (500, 600));
        assert_eq!((floored[1].start, floored[1].finish), (10, 110));
        // Floors below the port's own free_at are inert.
        let mut q = InstancePool::homogeneous(&aurora(), 1, BoardSpec::uncontended());
        q.assign(0, 0, 300, 0, false);
        let s = scores_from(&q, &[100], 0, 50, 0, false);
        assert_eq!(s[0].start, 300);
    }

    #[test]
    fn dma_floor_widens_the_window_on_narrow_slots() {
        // 4096 B over an 8 B/cycle drain is a 512-cycle DRAM service floor:
        // a 100-cycle static prediction cannot predict a finish before it.
        let p = InstancePool::homogeneous(&aurora(), 1, BoardSpec::uncontended());
        let s = scores(&p, 0, 100, 4096, false);
        assert_eq!(s[0].finish, 512);
    }
}
