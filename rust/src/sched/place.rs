//! Board-aware placement: which pool instance a chosen job should run on.
//!
//! The original pool placed every job on the earliest-free instance — the
//! right call when instances are independent, but blind to the one
//! resource they share: the carrier board's DRAM bandwidth
//! ([`crate::mem::BandwidthLedger`]). On a bandwidth-constrained board,
//! earliest-free happily opens a DMA-heavy job's occupancy window right on
//! top of another instance's reservation, and the job burns its slot
//! *stalled* — cycles the makespan pays twice, once as dead slot time and
//! once as the delayed tail behind it.
//!
//! [`Placement::Pressure`] scores every candidate slot by the job's
//! **predicted finish time including DRAM contention**:
//!
//! ```text
//! start_i  = max(arrival, free_at(i))
//! window_i = max(predicted_cycles, predict_dma_cycles(bytes, drain_i))
//! stall_i  = probe_stall(i, start_i, bytes)      // read-only ledger what-if
//! finish_i = start_i + window_i + stall_i
//! ```
//!
//! and picks the minimum `(finish, stall, free_at, index)`. The stall term
//! is [`crate::sched::pool::InstancePool::probe_stall`] — the exact stall
//! `assign` would book, i.e. the reserved-rate step function
//! (`SharedDram::pressure_at` at every cycle) integrated over the job's
//! predicted window at the slot's drain rate. The `stall` tie-break is the
//! co-scheduling rule: when two slots predict the same finish, prefer the
//! one that *waits* for the board to clear over the one that burns slot
//! time stalled — which steers DMA-heavy jobs onto non-overlapping DRAM
//! windows and leaves the early slot free for compute-heavy work.
//!
//! Two exact identities keep the engine safe to enable by default:
//!
//! * **Uncontended board ⇒ earliest-free.** With no reservations above the
//!   peak, every `stall_i` is exactly 0 and `window_i` is a per-job
//!   constant across a homogeneous pool, so the score is a monotone
//!   transform of `free_at` and the argmin (including tie-breaks) is
//!   bit-identical to [`crate::sched::pool::InstancePool::pick`]. The
//!   property test
//!   `prop_pressure_placement_identical_to_earliest_free_on_uncontended_board`
//!   pins this.
//! * **All integer.** Scores are u64 arithmetic end to end — no floats, no
//!   platform-dependent rounding, so placements are deterministic and the
//!   cycle-regression gate can compare them exactly.

use super::policy;
use super::pool::InstancePool;

/// Which instance a dispatched job lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// The instance that frees up first (`InstancePool::pick`) — the
    /// board-blind baseline.
    #[default]
    EarliestFree,
    /// Minimize predicted finish time including DRAM-stall inflation from
    /// the board ledger's reserved bandwidth over the job's window.
    Pressure,
}

impl Placement {
    /// Parse a `--placement` argument.
    pub fn parse(s: &str) -> Option<Placement> {
        match s {
            "earliest" | "earliest-free" => Some(Placement::EarliestFree),
            "pressure" | "dram-pressure" => Some(Placement::Pressure),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Placement::EarliestFree => "earliest",
            Placement::Pressure => "pressure",
        }
    }
}

/// One candidate slot's score for a job.
#[derive(Debug, Clone, Copy)]
pub struct SlotScore {
    pub instance: usize,
    /// Cycle the job's occupancy window would open.
    pub start: u64,
    /// Predicted DRAM contention stall inside that window.
    pub stall: u64,
    /// Predicted completion: `start + window + stall`.
    pub finish: u64,
}

/// Score every slot of `pool` for a job of `predicted_cycles` static cycles
/// and `dma_bytes` of board-DRAM traffic, runnable from `arrival`.
///
/// `arrival` is the *effective* arrival the scheduler computes: for a job
/// with dataflow/ordering producers it is the last producer's finish, so
/// the engine scores a chained consumer from the first cycle its input can
/// exist — not from its submission cycle.
pub fn scores(
    pool: &InstancePool,
    arrival: u64,
    predicted_cycles: u64,
    dma_bytes: u64,
    priority: bool,
) -> Vec<SlotScore> {
    (0..pool.len())
        .map(|i| {
            let start = arrival.max(pool.free_at(i));
            // The occupancy proxy: the job's static prediction, floored by
            // its uncontended DRAM service time at this slot's drain rate
            // (a narrow heterogeneous slot can be DMA-bound even when the
            // base-config prediction says otherwise).
            let window = predicted_cycles
                .max(policy::predict_dma_cycles(dma_bytes, pool.drain_rate(i)));
            let stall = pool.probe_stall(i, start, dma_bytes, priority);
            SlotScore { instance: i, start, stall, finish: start + window + stall }
        })
        .collect()
}

/// Pick the instance for a job under `placement`. For
/// [`Placement::Pressure`] the winner is the minimal
/// `(finish, stall, free_at, index)` — see the module docs for why each
/// tie-break is load-bearing.
pub fn choose(
    pool: &InstancePool,
    placement: Placement,
    arrival: u64,
    predicted_cycles: u64,
    dma_bytes: u64,
    priority: bool,
) -> usize {
    match placement {
        Placement::EarliestFree => pool.pick(),
        Placement::Pressure => scores(pool, arrival, predicted_cycles, dma_bytes, priority)
            .into_iter()
            .min_by_key(|s| (s.finish, s.stall, pool.free_at(s.instance), s.instance))
            .map(|s| s.instance)
            .expect("pool is non-empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::sched::pool::{BoardSpec, InstancePool};

    #[test]
    fn parse_and_labels() {
        assert_eq!(Placement::parse("earliest"), Some(Placement::EarliestFree));
        assert_eq!(Placement::parse("earliest-free"), Some(Placement::EarliestFree));
        assert_eq!(Placement::parse("pressure"), Some(Placement::Pressure));
        assert_eq!(Placement::parse("dram-pressure"), Some(Placement::Pressure));
        assert_eq!(Placement::parse("best-fit"), None);
        assert_eq!(Placement::default(), Placement::EarliestFree);
        assert_eq!(Placement::Pressure.label(), "pressure");
    }

    #[test]
    fn uncontended_pressure_matches_earliest_free_choice() {
        // With zero board pressure the score reduces to a monotone
        // transform of free_at, so both placements agree — including the
        // lowest-index tie-break on an idle pool.
        let mut p = InstancePool::homogeneous(&aurora(), 3, BoardSpec::uncontended());
        for (arrival, predicted, bytes) in
            [(0u64, 1000u64, 0u64), (0, 500, 4096), (250, 1000, 800), (10_000, 1, 64)]
        {
            let ef = choose(&p, Placement::EarliestFree, arrival, predicted, bytes, false);
            let pr = choose(&p, Placement::Pressure, arrival, predicted, bytes, false);
            assert_eq!(ef, pr, "placements diverged on an uncontended board");
            p.assign(ef, arrival, predicted.max(1), bytes, false);
        }
    }

    #[test]
    fn pressure_avoids_stalling_on_a_saturated_window() {
        // Board peak = one instance's 8 B/cycle drain rate. Instance 0 runs
        // a DMA job whose reservation saturates [0, 100); instance 1 runs a
        // short compute job. A DMA-heavy follow-up arriving at cycle 30:
        //   earliest-free picks instance 1 (free at 30) and burns 70 cycles
        //     stalled behind instance 0's reservation (finish 200);
        //   pressure sees the same finish either way and breaks the tie
        //     away from the stall, landing on instance 0 (starts at 100,
        //     clear board, zero stall) — leaving instance 1 free from cycle
        //     30 for compute work instead of a DRAM wait.
        let mut p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::with_bandwidth(8));
        p.assign(0, 0, 100, 800, false); // reserves 8 B/cy over [0, 100)
        p.assign(1, 0, 30, 0, false);
        let s = scores(&p, 30, 100, 800, false);
        assert_eq!((s[0].start, s[0].stall, s[0].finish), (100, 0, 200));
        assert_eq!((s[1].start, s[1].stall, s[1].finish), (30, 70, 200));
        assert_eq!(choose(&p, Placement::EarliestFree, 30, 100, 800, false), 1);
        assert_eq!(choose(&p, Placement::Pressure, 30, 100, 800, false), 0);
        // A pure compute job keeps going to the earliest-free slot.
        assert_eq!(choose(&p, Placement::Pressure, 30, 100, 0, false), 1);
    }

    #[test]
    fn pressure_prefers_strictly_earlier_finish() {
        // Instance 0 frees at 1000; instance 1 at 0 with a clear board: the
        // earlier slot wins outright on finish, no tie-break needed.
        let mut p = InstancePool::homogeneous(&aurora(), 2, BoardSpec::with_bandwidth(16));
        p.assign(0, 0, 1000, 0, false);
        assert_eq!(choose(&p, Placement::Pressure, 0, 200, 800, false), 1);
    }

    #[test]
    fn dma_floor_widens_the_window_on_narrow_slots() {
        // 4096 B over an 8 B/cycle drain is a 512-cycle DRAM service floor:
        // a 100-cycle static prediction cannot predict a finish before it.
        let p = InstancePool::homogeneous(&aurora(), 1, BoardSpec::uncontended());
        let s = scores(&p, 0, 100, 4096, false);
        assert_eq!(s[0].finish, 512);
    }
}
