//! Aggregate scheduler reporting: throughput, utilization, cache efficacy,
//! and per-QoS-class turnaround percentiles.

use super::Priority;
use std::fmt;

/// Nearest-rank percentile of an ascending-sorted sample set (`pct` in
/// 1..=100). Integer and deterministic — the per-class turnaround numbers
/// feed the cycle-regression gate, so no float rounding is allowed here.
pub fn percentile(sorted: &[u64], pct: u32) -> u64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    let n = sorted.len() as u64;
    let rank = (u64::from(pct) * n).div_ceil(100).clamp(1, n);
    sorted[(rank - 1) as usize]
}

/// Turnaround summary of one [`Priority`] class (completion − arrival, over
/// the completed jobs of that class).
#[derive(Debug, Clone, Copy)]
pub struct ClassReport {
    pub priority: Priority,
    pub jobs: usize,
    /// Times jobs of this class were displaced from an assigned batch slot
    /// by a higher-priority arrival (0 unless preemption is enabled).
    pub preempted: u64,
    pub p50_turnaround_cycles: u64,
    pub p95_turnaround_cycles: u64,
}

/// Per-instance cycle summary.
#[derive(Debug, Clone, Copy)]
pub struct InstanceReport {
    pub jobs: u64,
    /// Occupied cycles on the shared timeline (`noc::Port::busy_cycles`,
    /// including this instance's DRAM contention stalls).
    pub busy_cycles: u64,
    /// Pure device cycles of the jobs run here (excludes compile charges).
    pub device_cycles: u64,
    /// DMA wide-path occupancy summed over this instance's jobs.
    pub dma_busy_cycles: u64,
    /// Cycles this instance's jobs waited on the shared board DRAM.
    pub dram_stall_cycles: u64,
    /// Bytes this instance moved through the shared board DRAM.
    pub dram_bytes: u64,
    /// Wide-NoC width of this instance's configuration (heterogeneous
    /// pools mix widths).
    pub dma_width_bits: u32,
    /// busy / makespan.
    pub utilization: f64,
}

/// One serve run's aggregate outcome.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: &'static str,
    /// Placement engine label ([`crate::sched::Placement`]).
    pub placement: &'static str,
    pub caching: bool,
    pub batching: bool,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub split: usize,
    /// Jobs whose numerics failed the host golden model (should be 0).
    pub verify_failures: usize,
    /// Simulated cycle the last instance went idle.
    pub makespan_cycles: u64,
    pub total_device_cycles: u64,
    /// Simulated compile cycles charged across all dispatches.
    pub compile_cycles: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub freq_mhz: u32,
    /// Shared carrier-board DRAM peak (bytes/cycle; `u64::MAX` when the
    /// board coupling is disabled).
    pub dram_peak_bytes_per_cycle: u64,
    /// Bytes/cycle of the peak reserved for priority-class jobs (QoS
    /// headroom; 0 when the split is off).
    pub dram_priority_headroom: u64,
    /// Aggregate cycles jobs waited on the shared board DRAM.
    pub dram_stall_cycles: u64,
    /// Total bytes moved through the shared board DRAM (ledger accounting;
    /// equals the per-instance sum plus `host_dram_bytes` — the
    /// conservation invariant).
    pub dram_bytes: u64,
    /// Delivered fraction of the board DRAM's peak over the makespan.
    pub dram_utilization: f64,
    /// Default SVM offload strategy label (`Some` when [`crate::svm`]
    /// serving is enabled on the scheduler, `None` otherwise).
    pub svm_mode: Option<&'static str>,
    /// Bytes the host moved through the board DRAM on jobs' behalf (copy
    /// staging, page-table-entry reads, mailbox descriptors). Disjoint
    /// from `dram_bytes`' per-instance sum — the host is its own port.
    pub host_dram_bytes: u64,
    /// Cycles host traffic stretched beyond its uncontended drain time.
    pub host_dram_stall_cycles: u64,
    /// Host-port reservations made (one per descriptor / staging / PTE
    /// burst).
    pub host_requests: u64,
    /// Whether online cycle-prediction refinement was enabled
    /// ([`crate::sched::Scheduler::with_learning`]).
    pub learning: bool,
    /// Joint dispatch window size (1 = classic greedy head dispatch —
    /// [`crate::sched::Scheduler::with_lookahead`]).
    pub lookahead: usize,
    /// Whether priority preemption was enabled
    /// ([`crate::sched::Scheduler::with_preemption`]).
    pub preemption: bool,
    /// Batch-slot displacements across all classes (0 with preemption off).
    pub preemptions: u64,
    /// Whether schedule-time AutoDMA autotuning was enabled
    /// ([`crate::sched::Scheduler::with_autotune`]).
    pub autotune: bool,
    /// Fresh tuning searches run (one per distinct
    /// [`crate::sched::tune::TuneKey`] — kernel × footprint × width ×
    /// instance config).
    pub tune_searches: u64,
    /// Variant choices served from the memoized search results.
    pub tune_hits: u64,
    /// Choices where measured cycles displaced the statically-best variant
    /// (non-zero only with learning on — the measure → re-rank loop).
    pub tune_reranks: u64,
    /// Completed jobs whose predictions were scored against measured device
    /// cycles (learning runs only).
    pub predict_samples: u64,
    /// Mean abs prediction error of the *static* model over those jobs, in
    /// integer percent of the measurement — the "before learning" figure.
    pub predict_err_static_pct: u64,
    /// Mean abs error of the predictions jobs actually dispatched with
    /// (EWMA-refined where measurements existed) — the "after" figure.
    pub predict_err_learned_pct: u64,
    /// Whether fault injection or the watchdog was armed
    /// ([`crate::sched::Scheduler::with_faults`] /
    /// [`crate::sched::Scheduler::with_watchdog`]).
    pub resilience: bool,
    /// Injected transient kernel faults detected (per attempt).
    pub faults_transient: u64,
    /// Injected DMA/NoC timeout faults detected (per attempt).
    pub faults_timeout: u64,
    /// Watchdog deadline overruns (measured or budget-exhausted; never
    /// injected, never retried — deterministic overruns repeat).
    pub faults_deadline: u64,
    /// Retry attempts scheduled after retryable faults.
    pub retries: u64,
    /// Jobs rejected because a fault exhausted the retry budget (or was
    /// non-retryable).
    pub fault_failures: u64,
    /// Jobs evacuated off this board by the fleet router after a board
    /// failure (they complete elsewhere; 0 outside a fleet).
    pub migrated: u64,
    /// Order-stable digest over every completed job's output arrays:
    /// bit-identical results ⇔ identical digest, regardless of policy,
    /// placement, pool size, batching, caching or board bandwidth
    /// (homogeneous pools).
    pub digest: u64,
    /// Turnaround percentiles per QoS class (classes with completed jobs
    /// only; `Normal` first, then `High`).
    pub classes: Vec<ClassReport>,
    pub instances: Vec<InstanceReport>,
}

impl ServeReport {
    /// The class summary for `priority`, if any of its jobs completed.
    pub fn class(&self, priority: Priority) -> Option<&ClassReport> {
        self.classes.iter().find(|c| c.priority == priority)
    }

    /// Completed jobs per simulated second at the accelerator clock.
    pub fn jobs_per_sec(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_cycles as f64 / (self.freq_mhz as f64 * 1e6))
    }

    /// Completed jobs per simulated megacycle (clock-independent form).
    pub fn jobs_per_mcycle(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_cycles as f64 / 1e6)
    }
}

impl fmt::Display for ServeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "policy {} | placement {} | pool {} | cache {} | batching {}",
            self.policy,
            self.placement,
            self.instances.len(),
            if self.caching { "on" } else { "off" },
            if self.batching { "on" } else { "off" },
        )?;
        writeln!(
            f,
            "jobs          : {} submitted, {} completed, {} rejected, {} split, {} verify failures",
            self.submitted, self.completed, self.rejected, self.split, self.verify_failures
        )?;
        writeln!(
            f,
            "makespan      : {} cycles ({:.2} ms at {} MHz)",
            self.makespan_cycles,
            self.makespan_cycles as f64 / (self.freq_mhz as f64 * 1e3),
            self.freq_mhz
        )?;
        writeln!(
            f,
            "throughput    : {:.1} jobs/s ({:.3} jobs/Mcycle)",
            self.jobs_per_sec(),
            self.jobs_per_mcycle()
        )?;
        writeln!(
            f,
            "compile       : {} lowerings, {} cache hits, {} cycles charged",
            self.cache_misses, self.cache_hits, self.compile_cycles
        )?;
        // Self-tuning lines render only when a feature is on, so default
        // serve output stays byte-identical to the pre-self-tuning report.
        if self.learning || self.lookahead > 1 || self.preemption {
            writeln!(
                f,
                "self-tuning   : learn {}, lookahead {}, preempt {} ({} displaced)",
                if self.learning { "on" } else { "off" },
                self.lookahead,
                if self.preemption { "on" } else { "off" },
                self.preemptions
            )?;
        }
        // The autotune line renders only when tuning is on, so default serve
        // output stays byte-identical to the pre-autotune report.
        if self.autotune {
            writeln!(
                f,
                "autotune      : {} search(es), {} memo hit(s), {} rerank(s)",
                self.tune_searches, self.tune_hits, self.tune_reranks
            )?;
        }
        // Resilience lines render only when faults/watchdog are armed, so
        // default serve output stays byte-identical to the fault-free report.
        if self.resilience {
            writeln!(
                f,
                "resilience    : {} transient, {} timeout, {} deadline fault(s); \
                 {} retry(ies), {} failure(s)",
                self.faults_transient,
                self.faults_timeout,
                self.faults_deadline,
                self.retries,
                self.fault_failures
            )?;
        }
        if self.migrated > 0 {
            writeln!(f, "migrated      : {} job(s) evacuated to surviving boards", self.migrated)?;
        }
        if self.learning && self.predict_samples > 0 {
            writeln!(
                f,
                "prediction    : {} sample(s), mean abs err {}% static -> {}% learned",
                self.predict_samples, self.predict_err_static_pct, self.predict_err_learned_pct
            )?;
        }
        if self.dram_peak_bytes_per_cycle == u64::MAX {
            writeln!(f, "board dram    : uncoupled (no shared-bandwidth model)")?;
        } else {
            write!(
                f,
                "board dram    : peak {} B/cy, {} B moved, {} stall cy, util {:>5.1}%",
                self.dram_peak_bytes_per_cycle,
                self.dram_bytes,
                self.dram_stall_cycles,
                100.0 * self.dram_utilization
            )?;
            if self.dram_priority_headroom > 0 {
                write!(f, " ({} B/cy priority headroom)", self.dram_priority_headroom)?;
            }
            writeln!(f)?;
        }
        if let Some(mode) = self.svm_mode {
            writeln!(
                f,
                "host svm      : mode {mode}, {} B host dram, {} stall cy, {} request(s)",
                self.host_dram_bytes, self.host_dram_stall_cycles, self.host_requests
            )?;
        }
        for c in &self.classes {
            writeln!(
                f,
                "class {:<8}: {:>4} jobs, turnaround p50 {:>12} cy, p95 {:>12} cy",
                c.priority.label(),
                c.jobs,
                c.p50_turnaround_cycles,
                c.p95_turnaround_cycles
            )?;
        }
        for (i, inst) in self.instances.iter().enumerate() {
            writeln!(
                f,
                "instance {:>3}  : {:>4} jobs, w{:<3} busy {:>12} cy, dma {:>12} cy, \
                 dram stall {:>10} cy, util {:>5.1}%",
                i,
                inst.jobs,
                inst.dma_width_bits,
                inst.busy_cycles,
                inst.dma_busy_cycles,
                inst.dram_stall_cycles,
                100.0 * inst.utilization
            )?;
        }
        write!(f, "result digest : {:#018x}", self.digest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServeReport {
        ServeReport {
            policy: "fifo",
            placement: "pressure",
            caching: true,
            batching: true,
            submitted: 10,
            completed: 8,
            rejected: 2,
            split: 0,
            verify_failures: 0,
            makespan_cycles: 4_000_000,
            total_device_cycles: 3_900_000,
            compile_cycles: 100_000,
            cache_hits: 6,
            cache_misses: 2,
            freq_mhz: 50,
            dram_peak_bytes_per_cycle: 384,
            dram_priority_headroom: 32,
            dram_stall_cycles: 12_000,
            dram_bytes: 3_000_000,
            dram_utilization: 0.25,
            svm_mode: None,
            host_dram_bytes: 0,
            host_dram_stall_cycles: 0,
            host_requests: 0,
            learning: false,
            lookahead: 1,
            preemption: false,
            preemptions: 0,
            autotune: false,
            tune_searches: 0,
            tune_hits: 0,
            tune_reranks: 0,
            predict_samples: 0,
            predict_err_static_pct: 0,
            predict_err_learned_pct: 0,
            resilience: false,
            faults_transient: 0,
            faults_timeout: 0,
            faults_deadline: 0,
            retries: 0,
            fault_failures: 0,
            migrated: 0,
            digest: 0xdead_beef,
            classes: vec![
                ClassReport {
                    priority: Priority::Normal,
                    jobs: 6,
                    preempted: 0,
                    p50_turnaround_cycles: 900_000,
                    p95_turnaround_cycles: 3_800_000,
                },
                ClassReport {
                    priority: Priority::High,
                    jobs: 2,
                    preempted: 0,
                    p50_turnaround_cycles: 200_000,
                    p95_turnaround_cycles: 450_000,
                },
            ],
            instances: vec![InstanceReport {
                jobs: 8,
                busy_cycles: 4_000_000,
                device_cycles: 3_900_000,
                dma_busy_cycles: 50_000,
                dram_stall_cycles: 12_000,
                dram_bytes: 3_000_000,
                dma_width_bits: 64,
                utilization: 1.0,
            }],
        }
    }

    #[test]
    fn throughput_math() {
        let r = report();
        // 8 jobs in 4 Mcycles at 50 MHz = 80 ms -> 100 jobs/s.
        assert!((r.jobs_per_sec() - 100.0).abs() < 1e-9, "{}", r.jobs_per_sec());
        assert!((r.jobs_per_mcycle() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn renders_all_sections() {
        let s = report().to_string();
        assert!(s.contains("8 completed"));
        assert!(s.contains("placement pressure"));
        assert!(s.contains("jobs/s"));
        assert!(s.contains("board dram"));
        assert!(s.contains("32 B/cy priority headroom"));
        assert!(s.contains("class normal"));
        assert!(s.contains("class high"));
        assert!(s.contains("stall"));
        assert!(s.contains("instance   0"));
        assert!(s.contains("result digest"));
    }

    #[test]
    fn uncoupled_board_renders_distinctly() {
        let mut r = report();
        r.dram_peak_bytes_per_cycle = u64::MAX;
        assert!(r.to_string().contains("uncoupled"));
    }

    #[test]
    fn host_svm_line_renders_only_when_enabled() {
        let mut r = report();
        assert!(!r.to_string().contains("host svm"));
        r.svm_mode = Some("auto");
        r.host_dram_bytes = 131_264;
        r.host_dram_stall_cycles = 97;
        r.host_requests = 17;
        let s = r.to_string();
        assert!(s.contains("host svm      : mode auto"), "{s}");
        assert!(s.contains("131264 B host dram"), "{s}");
        assert!(s.contains("97 stall cy, 17 request(s)"), "{s}");
    }

    #[test]
    fn self_tuning_lines_render_only_when_enabled() {
        let mut r = report();
        let s = r.to_string();
        assert!(!s.contains("self-tuning"), "default report must be unchanged: {s}");
        assert!(!s.contains("prediction"), "default report must be unchanged: {s}");
        r.learning = true;
        r.lookahead = 4;
        r.preemption = true;
        r.preemptions = 3;
        r.predict_samples = 8;
        r.predict_err_static_pct = 140;
        r.predict_err_learned_pct = 12;
        let s = r.to_string();
        assert!(s.contains("learn on, lookahead 4, preempt on (3 displaced)"), "{s}");
        assert!(s.contains("prediction    : 8 sample(s)"), "{s}");
        assert!(s.contains("mean abs err 140% static -> 12% learned"), "{s}");
        // Lookahead alone still surfaces, without a prediction line.
        let mut r = report();
        r.lookahead = 2;
        let s = r.to_string();
        assert!(s.contains("learn off, lookahead 2, preempt off"), "{s}");
        assert!(!s.contains("prediction"), "{s}");
    }

    #[test]
    fn autotune_line_renders_only_when_enabled() {
        let mut r = report();
        assert!(!r.to_string().contains("autotune"), "default report must be unchanged");
        r.autotune = true;
        r.tune_searches = 3;
        r.tune_hits = 17;
        r.tune_reranks = 1;
        let s = r.to_string();
        assert!(s.contains("autotune      : 3 search(es), 17 memo hit(s), 1 rerank(s)"), "{s}");
    }

    #[test]
    fn resilience_lines_render_only_when_enabled() {
        let mut r = report();
        let s = r.to_string();
        assert!(!s.contains("resilience"), "default report must be unchanged: {s}");
        assert!(!s.contains("migrated"), "default report must be unchanged: {s}");
        r.resilience = true;
        r.faults_transient = 5;
        r.faults_timeout = 1;
        r.faults_deadline = 2;
        r.retries = 6;
        r.fault_failures = 2;
        let s = r.to_string();
        assert!(
            s.contains("resilience    : 5 transient, 1 timeout, 2 deadline fault(s)"),
            "{s}"
        );
        assert!(s.contains("6 retry(ies), 2 failure(s)"), "{s}");
        assert!(!s.contains("migrated"), "{s}");
        // Migration surfaces even without local faults armed (the board the
        // jobs left may itself have been fault-free).
        let mut r = report();
        r.migrated = 3;
        let s = r.to_string();
        assert!(s.contains("migrated      : 3 job(s) evacuated"), "{s}");
        assert!(!s.contains("resilience"), "{s}");
    }

    #[test]
    fn class_lookup_and_percentiles() {
        let r = report();
        assert_eq!(r.class(Priority::High).unwrap().jobs, 2);
        assert_eq!(r.class(Priority::Normal).unwrap().p50_turnaround_cycles, 900_000);
        // Nearest-rank percentile: exact, integer, no interpolation.
        let s = [10u64, 20, 30, 40];
        assert_eq!(percentile(&s, 50), 20);
        assert_eq!(percentile(&s, 95), 40);
        assert_eq!(percentile(&s, 100), 40);
        assert_eq!(percentile(&s, 1), 10);
        assert_eq!(percentile(&[7], 95), 7);
        let twenty: Vec<u64> = (1..=20).collect();
        assert_eq!(percentile(&twenty, 95), 19);
        assert_eq!(percentile(&twenty, 50), 10);
    }
}
