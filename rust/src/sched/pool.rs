//! The accelerator instance pool: K instances on **one carrier board**.
//!
//! Each pool slot models one HEROv2 accelerator instance on the shared job
//! timeline. A slot is a serializing resource — the abstraction
//! [`crate::noc::Port`] already provides for NoC data paths — so the pool
//! reuses it: dispatching a job `acquire`s the slot's port for the job's
//! simulated duration, and per-instance utilization falls out of
//! `Port::busy_cycles` divided by the pool makespan.
//!
//! Unlike the original pool (K fully independent simulators), the instances
//! share the board's DRAM: every job's main-memory traffic is reserved on
//! one [`BandwidthLedger`] whose peak is the carrier DRAM bandwidth
//! ([`BoardSpec`]). A job that would drain its instance's NoC rate while
//! other instances are doing the same gets only the residual bandwidth and
//! *stalls* — its occupancy window stretches by the extra DRAM service
//! time, which is what bends pool-scaling curves sub-linear for DMA-heavy
//! streams. With one instance, reservations never overlap (jobs on one
//! slot serialize), so pool=1 results are cycle-identical to the
//! pre-shared-DRAM model as long as the board peak covers a single
//! instance's drain rate.
//!
//! Slots carry their own [`HeroConfig`], so a pool may be *heterogeneous* —
//! e.g. mixed 32/64/128-bit wide-NoC instances built with
//! [`crate::config::preset::with_dma_width`]. An instance's config decides
//! both how its jobs compile/execute and at what rate it drains the shared
//! DRAM (its NoC beat rate, capped by the config's own DRAM peak — the
//! part of the memory path the per-job simulation already accounts for).
//!
//! Functional state is still *not* shared between jobs: every job runs on a
//! fresh `Accel` (its own SPMs and IOMMU), which keeps results independent
//! of placement and policy. The board couples *time*, never memory
//! contents.
//!
//! Everything a placement decision needs is exposed read-only —
//! [`InstancePool::free_at`], [`InstancePool::probe_stall`],
//! [`InstancePool::pressure`] — so both the greedy engine
//! ([`crate::sched::place::choose`]) and the K-wide lookahead matrix
//! ([`crate::sched::place::choose_joint`]) are pure what-if functions of
//! the pool: scoring never mutates the ledger, only `assign` does.

use crate::config::HeroConfig;
use crate::mem::{BandwidthLedger, PortStats};
use crate::noc::Port;

/// Shared carrier-board DRAM parameters for a pool.
#[derive(Debug, Clone, Copy)]
pub struct BoardSpec {
    /// Peak shared DRAM bandwidth in bytes per (accelerator) cycle.
    pub dram_bytes_per_cycle: u64,
    /// Bytes/cycle of the peak reachable only by priority-class jobs
    /// ([`crate::sched::Priority::High`]) — the QoS headroom of
    /// [`crate::mem::BandwidthLedger`]. 0 disables the split.
    pub priority_headroom: u64,
}

impl BoardSpec {
    /// The board the configuration describes (e.g. 384 B/cycle for
    /// Aurora's 19.2 GB/s DDR4 at the 50 MHz accelerator clock — far above
    /// a single instance's 8 B/cycle NoC drain rate, so small pools do not
    /// contend, matching the paper's single-card system balance).
    pub fn from_config(cfg: &HeroConfig) -> Self {
        BoardSpec { dram_bytes_per_cycle: cfg.dram.bytes_per_cycle, priority_headroom: 0 }
    }

    /// An explicit bandwidth cap (contention studies, `--board-bw`).
    pub fn with_bandwidth(bytes_per_cycle: u64) -> Self {
        BoardSpec { dram_bytes_per_cycle: bytes_per_cycle.max(1), priority_headroom: 0 }
    }

    /// No shared-bandwidth coupling: the pre-refactor pool behavior.
    pub fn uncontended() -> Self {
        BoardSpec { dram_bytes_per_cycle: u64::MAX, priority_headroom: 0 }
    }

    /// Keep `bytes_per_cycle` of the peak reachable only by priority jobs
    /// (`hero serve --priority-headroom`).
    pub fn with_priority_headroom(mut self, bytes_per_cycle: u64) -> Self {
        self.priority_headroom = bytes_per_cycle;
        self
    }
}

/// Cycle accounting for one pool slot.
#[derive(Debug, Default, Clone, Copy)]
pub struct InstanceStats {
    /// Jobs completed on this instance.
    pub jobs: u64,
    /// Sum of pure device cycles of those jobs (excludes compile charges).
    pub device_cycles: u64,
    /// Sum of the jobs' DMA-engine busy cycles (wide-NoC occupancy).
    pub dma_busy_cycles: u64,
    /// Cycles this instance's jobs waited on the shared board DRAM.
    pub dram_stall_cycles: u64,
    /// Bytes this instance moved through the shared board DRAM.
    pub dram_bytes: u64,
}

/// One job's placement on the shared timeline.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub start: u64,
    pub end: u64,
    /// Cycles of the occupancy window attributable to DRAM contention.
    pub dram_stall: u64,
}

#[derive(Debug)]
struct Slot {
    port: Port,
    stats: InstanceStats,
    cfg: HeroConfig,
    /// Effective *solo* drain rate toward the board DRAM (bytes/cycle):
    /// the wide-NoC beat rate capped by the config's own DRAM peak. The
    /// per-job simulation already models everything up to this rate
    /// (including a config-level DRAM bottleneck, via the job's own
    /// `SharedDram`), so the board ledger must only add stall *beyond* it
    /// — anything else would double-count the engine-level stall.
    drain_bytes_per_cycle: u64,
}

/// The host's port onto the shared board DRAM (SVM serving): copy staging,
/// page-table-entry reads and mailbox descriptors reserve board bandwidth
/// here, so instance placement, SJF inflation and `probe_stall` see host
/// contention exactly like another accelerator's traffic. Host traffic is
/// never priority-class — QoS headroom stays reserved for priority *jobs*.
#[derive(Debug)]
struct HostPort {
    /// Host link rate in bytes/cycle ([`crate::svm::SvmConfig::host_bw`]).
    rate: u64,
    stats: PortStats,
}

/// A pool of accelerator instances sharing one simulated timeline (starting
/// at cycle 0) and one board DRAM.
#[derive(Debug)]
pub struct InstancePool {
    slots: Vec<Slot>,
    board: BandwidthLedger,
    spec: BoardSpec,
    /// Present iff SVM serving is enabled (`Scheduler::with_svm`).
    host: Option<HostPort>,
}

impl InstancePool {
    /// `k` identical instances of `cfg` on a board.
    pub fn homogeneous(cfg: &HeroConfig, k: usize, board: BoardSpec) -> Self {
        assert!(k >= 1, "pool needs at least one instance");
        Self::heterogeneous(vec![cfg.clone(); k], board)
    }

    /// One instance per config (heterogeneous pool: e.g. mixed NoC widths).
    pub fn heterogeneous(cfgs: Vec<HeroConfig>, board: BoardSpec) -> Self {
        assert!(!cfgs.is_empty(), "pool needs at least one instance");
        let slots = cfgs
            .into_iter()
            .map(|cfg| Slot {
                port: Port::new(),
                stats: InstanceStats::default(),
                drain_bytes_per_cycle: cfg
                    .dma_beat_bytes()
                    .min(cfg.dram.bytes_per_cycle)
                    .max(1),
                cfg,
            })
            .collect();
        InstancePool {
            slots,
            board: BandwidthLedger::new(board.dram_bytes_per_cycle, board.priority_headroom),
            spec: board,
            host: None,
        }
    }

    /// Attach the host's DRAM port at `rate` bytes/cycle (idempotent; the
    /// last rate wins). Until this is called, host traffic is free — the
    /// pre-SVM model.
    pub fn enable_host_port(&mut self, rate: u64) {
        let rate = rate.max(1);
        match &mut self.host {
            Some(h) => h.rate = rate,
            None => {
                self.host = Some(HostPort {
                    rate,
                    stats: PortStats {
                        label: "host".into(),
                        priority: false,
                        bytes: 0,
                        requests: 0,
                        stall_cycles: 0,
                    },
                });
            }
        }
    }

    /// Host link rate, if the host port is attached.
    pub fn host_rate(&self) -> Option<u64> {
        self.host.as_ref().map(|h| h.rate)
    }

    /// Accounting for the host port, if attached.
    pub fn host_stats(&self) -> Option<&PortStats> {
        self.host.as_ref().map(|h| &h.stats)
    }

    /// Reserve `bytes` of host-side traffic on the board ledger starting at
    /// `start`, and return the reservation's total duration (uncontended
    /// service plus any contention wait — both are host-visible latency).
    /// No-op (0 cycles) when the host port is not attached or `bytes` is 0.
    ///
    /// `start` must be at or after the pool's dispatch frontier
    /// ([`InstancePool::earliest_free`]) so the reservation survives the
    /// ledger trim a later [`InstancePool::assign`] performs — the
    /// scheduler's dispatch path reserves at the assignee's `free_at`,
    /// which always satisfies this.
    pub fn host_reserve(&mut self, start: u64, bytes: u64) -> u64 {
        if self.host.is_none() || bytes == 0 {
            return 0;
        }
        debug_assert!(
            start >= self.earliest_free(),
            "host reservation behind the dispatch frontier would be trimmed"
        );
        let InstancePool { board, host, .. } = self;
        let h = host.as_mut().expect("checked above");
        let end = board.reserve(start, bytes, h.rate, false);
        let stall = (end - start).saturating_sub(board.uncontended_cycles(bytes, h.rate, false));
        h.stats.bytes += bytes;
        h.stats.requests += 1;
        h.stats.stall_cycles += stall;
        end - start
    }

    /// Read-only what-if of [`InstancePool::host_reserve`]: the duration the
    /// reservation would take given current ledger state (the SVM `auto`
    /// strategy prices copy staging with this).
    pub fn host_probe(&self, start: u64, bytes: u64) -> u64 {
        let Some(h) = self.host.as_ref() else { return 0 };
        if bytes == 0 {
            return 0;
        }
        self.board.probe(start, bytes, h.rate, false) - start
    }

    /// Replace the board DRAM spec. Only meaningful before any assignment.
    pub fn set_board(&mut self, board: BoardSpec) {
        debug_assert_eq!(self.makespan(), 0, "set_board after assignments");
        self.board = BandwidthLedger::new(board.dram_bytes_per_cycle, board.priority_headroom);
        self.spec = board;
    }

    /// The board DRAM spec this pool was built with.
    pub fn board(&self) -> BoardSpec {
        self.spec
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Platform configuration of instance `i`.
    pub fn cfg(&self, i: usize) -> &HeroConfig {
        &self.slots[i].cfg
    }

    /// The instance that frees up earliest (ties broken toward the lowest
    /// index, so single-job streams always land on instance 0).
    pub fn pick(&self) -> usize {
        self.slots
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.port.free_at(), *i))
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Occupy instance `i` for a job of `duration` cycles that becomes
    /// runnable at `ready_at` (its arrival cycle) and moves `dma_bytes`
    /// through the shared board DRAM. The DRAM demand is reserved on the
    /// board ledger at the instance's NoC drain rate; any service beyond
    /// the uncontended time is contention stall and extends the occupancy.
    /// `priority` jobs reserve into the ledger's headroom slice (QoS).
    pub fn assign(
        &mut self,
        i: usize,
        ready_at: u64,
        duration: u64,
        dma_bytes: u64,
        priority: bool,
    ) -> Assignment {
        // No future reservation can start before the earliest-free slot, so
        // ledger history before that frontier is dead — trim it to keep
        // long serve runs O(outstanding reservations) per assign.
        let frontier = self.earliest_free();
        let InstancePool { slots, board, .. } = self;
        board.trim(frontier);
        let slot = &mut slots[i];
        let start = ready_at.max(slot.port.free_at());
        let mut stall = 0u64;
        if dma_bytes > 0 {
            // The stall floor is the service time at the instance's *solo*
            // drain rate — what the job's own simulation already charged.
            // A board narrower than that rate (e.g. `--board-bw` below the
            // NoC beat rate) is an additional bottleneck and stretches the
            // job, like the engine-level model in `dma::DmaEngine::enqueue`;
            // a config whose own DRAM is the bottleneck was already slowed
            // inside the job and is not charged again here. Deliberately
            // NOT `BandwidthLedger::uncontended_cycles`: clamping the floor
            // to the board peak (or future headroom) would drop exactly the
            // board-imposed wait from the occupancy window, letting DRAM
            // service run past the job's slot time.
            let rate = slot.drain_bytes_per_cycle;
            let dram_end = board.reserve(start, dma_bytes, rate, priority);
            stall = dram_end.saturating_sub(start + dma_bytes.div_ceil(rate));
            slot.stats.dram_stall_cycles += stall;
            slot.stats.dram_bytes += dma_bytes;
        }
        let (s, e) = slot.port.acquire(ready_at, duration + stall);
        debug_assert_eq!(s, start);
        Assignment { start: s, end: e, dram_stall: stall }
    }

    /// Book a completed job's cycle breakdown on instance `i`.
    pub fn record(&mut self, i: usize, device_cycles: u64, dma_busy_cycles: u64) {
        let s = &mut self.slots[i].stats;
        s.jobs += 1;
        s.device_cycles += device_cycles;
        s.dma_busy_cycles += dma_busy_cycles;
    }

    pub fn stats(&self, i: usize) -> InstanceStats {
        self.slots[i].stats
    }

    /// Cycle at which instance `i` frees up (its dispatch frontier).
    pub fn free_at(&self, i: usize) -> u64 {
        self.slots[i].port.free_at()
    }

    /// Cycle at which the earliest-free instance frees up — the pool's
    /// dispatch frontier (what decides which queued jobs have "arrived").
    pub fn earliest_free(&self) -> u64 {
        self.slots.iter().map(|s| s.port.free_at()).min().unwrap_or(0)
    }

    /// Effective solo drain rate of instance `i` toward the board DRAM
    /// (bytes/cycle): its wide-NoC beat rate capped by its own config's
    /// DRAM peak — the rate `assign` reserves at.
    pub fn drain_rate(&self, i: usize) -> u64 {
        self.slots[i].drain_bytes_per_cycle
    }

    /// Contention stall a job of `dma_bytes` would pay if its occupancy
    /// window opened at `start` on instance `i`, given the board ledger's
    /// current reservations — a read-only what-if of exactly the stall
    /// [`InstancePool::assign`] would book ([`BandwidthLedger::probe`]).
    /// The placement engine ([`crate::sched::place`]) scores candidate
    /// slots with this.
    pub fn probe_stall(&self, i: usize, start: u64, dma_bytes: u64, priority: bool) -> u64 {
        if dma_bytes == 0 {
            return 0;
        }
        let rate = self.slots[i].drain_bytes_per_cycle;
        let dram_end = self.board.probe(start, dma_bytes, rate, priority);
        dram_end.saturating_sub(start + dma_bytes.div_ceil(rate))
    }

    /// Simulated cycle at which the last instance goes idle.
    pub fn makespan(&self) -> u64 {
        self.slots.iter().map(|s| s.port.free_at()).max().unwrap_or(0)
    }

    /// Occupied cycles of instance `i` (`noc::Port::busy_cycles`; includes
    /// that instance's DRAM stalls — they occupy the slot).
    pub fn busy_cycles(&self, i: usize) -> u64 {
        self.slots[i].port.busy_cycles
    }

    /// Fraction of the pool makespan instance `i` spent busy.
    pub fn utilization(&self, i: usize) -> f64 {
        let m = self.makespan();
        if m == 0 {
            0.0
        } else {
            self.busy_cycles(i) as f64 / m as f64
        }
    }

    /// Peak shared DRAM bandwidth (bytes/cycle; `u64::MAX` = uncontended).
    pub fn dram_peak(&self) -> u64 {
        self.board.peak()
    }

    /// Total bytes moved through the board DRAM (ledger accounting; equals
    /// the sum of per-instance `dram_bytes`, plus the host port's bytes
    /// when one is enabled — the conservation invariant).
    pub fn dram_total_bytes(&self) -> u64 {
        self.board.total_bytes()
    }

    /// Total DRAM contention stall cycles across all instances.
    pub fn dram_stall_total(&self) -> u64 {
        self.slots.iter().map(|s| s.stats.dram_stall_cycles).sum()
    }

    /// Reserved fraction of the board DRAM peak at the next dispatch
    /// frontier (the cycle where the earliest-free instance would start).
    /// Contention-aware policies use this to inflate predictions.
    pub fn pressure(&self) -> f64 {
        self.board.pressure_at(self.earliest_free())
    }

    /// Fraction of the board DRAM's deliverable bytes actually moved over
    /// the makespan (0.0 for an uncontended board: no meaningful peak).
    pub fn dram_utilization(&self) -> f64 {
        let m = self.makespan();
        let peak = self.board.peak();
        if m == 0 || peak == u64::MAX {
            return 0.0;
        }
        self.board.total_bytes() as f64 / (peak as f64 * m as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{aurora, preset};

    fn pool(k: usize, board: BoardSpec) -> InstancePool {
        InstancePool::homogeneous(&aurora(), k, board)
    }

    #[test]
    fn pick_prefers_least_loaded() {
        let mut p = pool(3, BoardSpec::uncontended());
        assert_eq!(p.pick(), 0);
        p.assign(0, 0, 100, 0, false);
        assert_eq!(p.pick(), 1);
        p.assign(1, 0, 50, 0, false);
        p.assign(2, 0, 60, 0, false);
        assert_eq!(p.pick(), 1); // frees at 50, earliest
    }

    #[test]
    fn assign_serializes_per_instance() {
        let mut p = pool(1, BoardSpec::uncontended());
        let a1 = p.assign(0, 0, 10, 0, false);
        let a2 = p.assign(0, 0, 5, 0, false);
        assert_eq!((a1.start, a1.end), (0, 10));
        assert_eq!((a2.start, a2.end), (10, 15));
        assert_eq!(p.makespan(), 15);
        assert_eq!(p.busy_cycles(0), 15);
    }

    #[test]
    fn arrival_delays_start() {
        let mut p = pool(1, BoardSpec::uncontended());
        let a = p.assign(0, 500, 100, 0, false);
        assert_eq!((a.start, a.end), (500, 600));
        assert_eq!(p.makespan(), 600);
        assert_eq!(p.busy_cycles(0), 100); // idle gap is not busy time
    }

    #[test]
    fn utilization_uses_port_busy_cycles() {
        let mut p = pool(2, BoardSpec::uncontended());
        p.assign(0, 0, 100, 0, false);
        p.assign(1, 0, 50, 0, false);
        assert!((p.utilization(0) - 1.0).abs() < 1e-12);
        assert!((p.utilization(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spreading_beats_one_instance() {
        // Four 100-cycle jobs: pool of 4 finishes in 100, pool of 1 in 400.
        let mut p1 = pool(1, BoardSpec::uncontended());
        let mut p4 = pool(4, BoardSpec::uncontended());
        for _ in 0..4 {
            let i1 = p1.pick();
            p1.assign(i1, 0, 100, 0, false);
            let i4 = p4.pick();
            p4.assign(i4, 0, 100, 0, false);
        }
        assert_eq!(p1.makespan(), 400);
        assert_eq!(p4.makespan(), 100);
    }

    #[test]
    fn overlapping_dma_jobs_contend_on_the_board() {
        // Board peak equals one instance's 8 B/cycle drain rate: two
        // concurrent DMA-heavy jobs must share it.
        let mut p = pool(2, BoardSpec::with_bandwidth(8));
        let a0 = p.assign(0, 0, 100, 400, false);
        // Instance 0 serves its 400 B in 50 cycles at full rate: no stall.
        assert_eq!((a0.start, a0.end, a0.dram_stall), (0, 100, 0));
        // Instance 1 overlaps: blocked for 50 cycles, then 50 at full rate.
        let a1 = p.assign(1, 0, 100, 400, false);
        assert_eq!(a1.dram_stall, 50);
        assert_eq!((a1.start, a1.end), (0, 150));
        assert_eq!(p.stats(1).dram_stall_cycles, 50);
        assert_eq!(p.dram_stall_total(), 50);
        assert_eq!(p.dram_total_bytes(), 800);
        assert_eq!(p.stats(0).dram_bytes + p.stats(1).dram_bytes, 800);
    }

    #[test]
    fn board_slower_than_one_instance_stalls_even_solo() {
        // Peak 4 B/cycle under an 8 B/cycle instance: the board itself is
        // the bottleneck, so even an unshared job stretches (mirroring the
        // engine-level dram_bottleneck_stalls_transfer behavior).
        let mut p = pool(1, BoardSpec::with_bandwidth(4));
        let a = p.assign(0, 0, 100, 400, false);
        // Service takes 400/4 = 100 cycles vs the 400/8 = 50-cycle floor.
        assert_eq!(a.dram_stall, 50);
        assert_eq!(a.end, 150);
    }

    #[test]
    fn config_level_dram_bottleneck_is_not_double_counted() {
        // A config whose own DRAM peak (4 B/cy) is below its NoC beat rate
        // already pays the slowdown inside each job's simulation, so the
        // matching board (BoardSpec::from_config) adds zero extra stall.
        let mut cfg = aurora();
        cfg.dram.bytes_per_cycle = 4;
        let mut p = InstancePool::homogeneous(&cfg, 1, BoardSpec::from_config(&cfg));
        let a = p.assign(0, 0, 200, 400, false);
        assert_eq!(a.dram_stall, 0);
        assert_eq!(a.end, 200);
    }

    #[test]
    fn sequential_jobs_on_one_instance_never_stall() {
        // The pool=1 identity: one instance's reservations cannot overlap,
        // so a board that covers its drain rate adds zero cycles.
        let mut capped = pool(1, BoardSpec::with_bandwidth(8));
        let mut open = pool(1, BoardSpec::uncontended());
        for (dur, bytes) in [(300u64, 800u64), (120, 640), (50, 0), (700, 2048)] {
            let a = capped.assign(0, 0, dur, bytes, false);
            let b = open.assign(0, 0, dur, bytes, false);
            assert_eq!(a.dram_stall, 0);
            assert_eq!((a.start, a.end), (b.start, b.end));
        }
        assert_eq!(capped.makespan(), open.makespan());
    }

    #[test]
    fn heterogeneous_slots_keep_their_configs() {
        let base = aurora();
        let cfgs = vec![
            preset::with_dma_width(&base, 64),
            preset::with_dma_width(&base, 32),
            preset::with_dma_width(&base, 128),
        ];
        let p = InstancePool::heterogeneous(cfgs, BoardSpec::uncontended());
        assert_eq!(p.len(), 3);
        assert_eq!(p.cfg(0).noc.dma_width_bits, 64);
        assert_eq!(p.cfg(1).noc.dma_width_bits, 32);
        assert_eq!(p.cfg(2).noc.dma_width_bits, 128);
        assert_eq!(p.cfg(0).name, "aurora");
        assert_eq!(p.cfg(1).name, "aurora-w32");
        assert_eq!(p.cfg(2).name, "aurora-w128");
    }

    #[test]
    fn priority_jobs_reach_the_headroom_normal_jobs_do_not() {
        // Peak 16 with 8 B/cy headroom: the normal slice is one instance's
        // 8 B/cy drain rate, the headroom another. A priority job overlaps
        // a normal one stall-free on the headroom; a second normal job
        // fights over the 8 B/cy normal slice and stalls.
        let mut p = pool(3, BoardSpec::with_bandwidth(16).with_priority_headroom(8));
        assert_eq!(p.board().priority_headroom, 8);
        let a = p.assign(0, 0, 100, 800, false);
        assert_eq!(a.dram_stall, 0); // the whole normal slice: full rate
        let b = p.assign(1, 0, 100, 400, true);
        assert_eq!(b.dram_stall, 0, "priority rides the 8 B/cy headroom");
        // A second normal job sees a fully-booked normal slice until the
        // first one's reservation ends at cycle 100.
        let c = p.assign(2, 0, 100, 400, false);
        assert_eq!(c.dram_stall, 100, "normal traffic must not reach the headroom");
    }

    #[test]
    fn probe_stall_predicts_assign_exactly() {
        let mut p = pool(2, BoardSpec::with_bandwidth(8));
        p.assign(0, 0, 100, 800, false); // saturates [0, 100)
        let predicted = p.probe_stall(1, 0, 400, false);
        let a = p.assign(1, 0, 50, 400, false);
        assert_eq!(predicted, a.dram_stall);
        assert!(predicted > 0);
        // Zero-byte jobs never stall, probed or assigned.
        assert_eq!(p.probe_stall(1, 0, 0, false), 0);
        // On an uncontended board the probe is exactly zero everywhere.
        let q = pool(2, BoardSpec::uncontended());
        assert_eq!(q.probe_stall(0, 12_345, 1 << 20, false), 0);
        assert_eq!(q.earliest_free(), 0);
        assert_eq!(q.drain_rate(0), aurora().dma_beat_bytes());
    }

    #[test]
    fn host_port_is_absent_until_enabled() {
        let mut p = pool(1, BoardSpec::with_bandwidth(8));
        assert!(p.host_rate().is_none());
        assert!(p.host_stats().is_none());
        assert_eq!(p.host_reserve(0, 4096), 0, "no port: host traffic is free");
        assert_eq!(p.host_probe(0, 4096), 0);
        assert_eq!(p.dram_total_bytes(), 0);
        p.enable_host_port(0);
        assert_eq!(p.host_rate(), Some(1), "rate clamps to at least 1 B/cy");
        p.enable_host_port(8);
        assert_eq!(p.host_rate(), Some(8), "re-enable updates the rate");
    }

    #[test]
    fn host_reserve_books_bytes_and_uncontended_duration() {
        let mut p = pool(1, BoardSpec::with_bandwidth(16));
        p.enable_host_port(8);
        let d = p.host_reserve(0, 800);
        assert_eq!(d, 100, "800 B at 8 B/cy on an otherwise idle board");
        let s = p.host_stats().unwrap();
        assert_eq!((s.bytes, s.requests, s.stall_cycles), (800, 1, 0));
        assert_eq!(s.label, "host");
        assert!(!s.priority, "host traffic never rides the QoS headroom");
        assert_eq!(p.dram_total_bytes(), 800);
        assert_eq!(p.host_reserve(0, 0), 0, "zero-byte reservations are free");
    }

    #[test]
    fn host_traffic_contends_with_instance_dma() {
        // Board peak 8 B/cy: instance 0's job saturates [0, 100); host
        // staging overlapping it must wait, and only the *host* stats book
        // that stall — the conservation split placement relies on.
        let mut p = pool(2, BoardSpec::with_bandwidth(8));
        p.enable_host_port(8);
        p.assign(0, 0, 100, 800, false);
        let probed = p.host_probe(0, 400);
        let d = p.host_reserve(0, 400);
        assert_eq!(d, probed, "host_probe is the exact what-if of host_reserve");
        assert_eq!(d, 150, "blocked 100 cycles, then 50 at full rate");
        assert_eq!(p.host_stats().unwrap().stall_cycles, 100);
        assert_eq!(p.stats(0).dram_stall_cycles, 0, "instance stats untouched");
        assert_eq!(p.dram_total_bytes(), 1200);
        // And the reverse direction: instance placement sees host pressure.
        assert!(p.probe_stall(1, 0, 400, false) > 0);
    }

    #[test]
    fn pressure_tracks_the_dispatch_frontier() {
        let mut p = pool(2, BoardSpec::with_bandwidth(16));
        assert_eq!(p.pressure(), 0.0);
        p.assign(0, 0, 100, 800, false); // reserves 8 B/cycle over [0, 100)
        // Frontier is instance 1's free_at = 0, where half the peak is gone.
        assert!((p.pressure() - 0.5).abs() < 1e-12);
    }
}
