//! The accelerator instance pool.
//!
//! Each pool slot models one HEROv2 accelerator card on the shared job
//! timeline. A slot is a serializing resource — exactly the abstraction
//! [`crate::noc::Port`] already provides for NoC data paths — so the pool
//! reuses it: dispatching a job `acquire`s the slot's port for the job's
//! simulated duration, and per-instance utilization falls out of
//! `Port::busy_cycles` divided by the pool makespan.
//!
//! Functional state is *not* shared between jobs: every job runs on a fresh
//! `Accel` (its own DRAM, SPMs and IOMMU), which is what makes results
//! independent of placement and policy. The pool tracks *time*, not memory.

use crate::noc::Port;

/// Cycle accounting for one pool slot.
#[derive(Debug, Default, Clone, Copy)]
pub struct InstanceStats {
    /// Jobs completed on this instance.
    pub jobs: u64,
    /// Sum of pure device cycles of those jobs (excludes compile charges).
    pub device_cycles: u64,
    /// Sum of the jobs' DMA-engine busy cycles (wide-NoC occupancy).
    pub dma_busy_cycles: u64,
}

/// A pool of `K` accelerator instances sharing one simulated timeline that
/// starts at cycle 0.
#[derive(Debug)]
pub struct InstancePool {
    ports: Vec<Port>,
    stats: Vec<InstanceStats>,
}

impl InstancePool {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "pool needs at least one instance");
        InstancePool { ports: (0..k).map(|_| Port::new()).collect(), stats: vec![InstanceStats::default(); k] }
    }

    pub fn len(&self) -> usize {
        self.ports.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }

    /// The instance that frees up earliest (ties broken toward the lowest
    /// index, so single-job streams always land on instance 0).
    pub fn pick(&self) -> usize {
        self.ports
            .iter()
            .enumerate()
            .min_by_key(|(i, p)| (p.free_at(), *i))
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Occupy instance `i` for `duration` cycles; returns `(start, end)`.
    pub fn assign(&mut self, i: usize, duration: u64) -> (u64, u64) {
        self.ports[i].acquire(0, duration)
    }

    /// Book a completed job's cycle breakdown on instance `i`.
    pub fn record(&mut self, i: usize, device_cycles: u64, dma_busy_cycles: u64) {
        self.stats[i].jobs += 1;
        self.stats[i].device_cycles += device_cycles;
        self.stats[i].dma_busy_cycles += dma_busy_cycles;
    }

    pub fn stats(&self, i: usize) -> InstanceStats {
        self.stats[i]
    }

    /// Simulated cycle at which the last instance goes idle.
    pub fn makespan(&self) -> u64 {
        self.ports.iter().map(|p| p.free_at()).max().unwrap_or(0)
    }

    /// Occupied cycles of instance `i` (`noc::Port::busy_cycles`).
    pub fn busy_cycles(&self, i: usize) -> u64 {
        self.ports[i].busy_cycles
    }

    /// Fraction of the pool makespan instance `i` spent busy.
    pub fn utilization(&self, i: usize) -> f64 {
        let m = self.makespan();
        if m == 0 {
            0.0
        } else {
            self.busy_cycles(i) as f64 / m as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_prefers_least_loaded() {
        let mut p = InstancePool::new(3);
        assert_eq!(p.pick(), 0);
        p.assign(0, 100);
        assert_eq!(p.pick(), 1);
        p.assign(1, 50);
        p.assign(2, 60);
        assert_eq!(p.pick(), 1); // frees at 50, earliest
    }

    #[test]
    fn assign_serializes_per_instance() {
        let mut p = InstancePool::new(1);
        let (s1, e1) = p.assign(0, 10);
        let (s2, e2) = p.assign(0, 5);
        assert_eq!((s1, e1), (0, 10));
        assert_eq!((s2, e2), (10, 15));
        assert_eq!(p.makespan(), 15);
        assert_eq!(p.busy_cycles(0), 15);
    }

    #[test]
    fn utilization_uses_port_busy_cycles() {
        let mut p = InstancePool::new(2);
        p.assign(0, 100);
        p.assign(1, 50);
        assert!((p.utilization(0) - 1.0).abs() < 1e-12);
        assert!((p.utilization(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spreading_beats_one_instance() {
        // Four 100-cycle jobs: pool of 4 finishes in 100, pool of 1 in 400.
        let mut p1 = InstancePool::new(1);
        let mut p4 = InstancePool::new(4);
        for _ in 0..4 {
            let i1 = p1.pick();
            p1.assign(i1, 100);
            let i4 = p4.pick();
            p4.assign(i4, 100);
        }
        assert_eq!(p1.makespan(), 400);
        assert_eq!(p4.makespan(), 100);
    }
}
