//! Arbitrary compiled-kernel jobs for the scheduler.
//!
//! The original scheduler only accepted *named synthetic* workloads
//! ([`crate::workloads::synth::JobDesc`] — a registry name plus a problem
//! size), which meant a user-compiled kernel could never be submitted to a
//! pool. A [`KernelJob`] closes that gap: it carries the kernel IR itself
//! plus the launch payload (initial array contents, float arguments,
//! thread/team counts), so anything the compiler can lower flows through
//! the same policies, binary cache, batching and shared-DRAM board model
//! as the named streams. [`crate::session::Session::launch`] on a pooled
//! session is the front door that builds these.
//!
//! Payloads carry *dataflow*: each input is a [`PayloadSrc`] — either an
//! inline data snapshot or a reference to another kernel job's output
//! array ([`PayloadSrc::Output`]). An output reference is a dependency
//! edge: the scheduler dispatches the consumer only once the producer has
//! settled, and materializes the input directly from the producer's output
//! at dispatch time — the data never round-trips through the submitting
//! host. [`KernelJob::after`] adds pure ordering edges with no data
//! attached.
//!
//! Kernel jobs ride every scheduler feature the named streams do,
//! including the self-tuning loop: with learning enabled the scheduler
//! memoizes a refinement key from the kernel's content hash, input
//! element count, effective thread count and teams, so repeat
//! submissions of a kernel dispatch on *measured* — not just modeled —
//! cycle predictions ([`crate::sched::learn`]).

use super::{JobHandle, Priority};
use crate::compiler::ir::{Kernel, Sym};

/// Where one input array of a [`KernelJob`] comes from.
#[derive(Debug, Clone)]
pub enum PayloadSrc {
    /// An inline snapshot, captured at submission.
    Data(Vec<f32>),
    /// Output array `index` of an earlier kernel job: a dataflow edge. The
    /// scheduler holds the consumer until `producer` settles and then
    /// feeds the producer's output in directly (`elems` is the array's
    /// element count, known up front so shape validation and DMA-cost
    /// predictions need no data).
    Output { producer: JobHandle, index: usize, elems: usize },
    /// A shared-virtual-memory operand: the first `elems` elements of the
    /// buffer at virtual address `va` in the board's [`crate::svm::SvmSpace`].
    /// The job carries no bytes — the scheduler resolves the VA at dispatch
    /// and charges the pin/copy/auto access path (see [`crate::svm`]).
    /// Requires SVM serving to be enabled (`Scheduler::with_svm`).
    Svm { va: u64, elems: usize },
}

impl PayloadSrc {
    /// Element count of the array this source yields.
    pub fn elems(&self) -> usize {
        match self {
            PayloadSrc::Data(v) => v.len(),
            PayloadSrc::Output { elems, .. } | PayloadSrc::Svm { elems, .. } => *elems,
        }
    }

    /// The producing job, for dataflow edges.
    pub fn producer(&self) -> Option<JobHandle> {
        match self {
            PayloadSrc::Data(_) | PayloadSrc::Svm { .. } => None,
            PayloadSrc::Output { producer, .. } => Some(*producer),
        }
    }

    /// Bytes this source holds *inline* (snapshot retention accounting;
    /// output references and SVM operands carry no data until dispatch).
    pub fn inline_bytes(&self) -> u64 {
        match self {
            PayloadSrc::Data(v) => v.len() as u64 * 4,
            PayloadSrc::Output { .. } | PayloadSrc::Svm { .. } => 0,
        }
    }
}

/// One arbitrary-kernel offload request.
///
/// `inputs` holds the source of every `map`-clause array in the kernel's
/// parameter-declaration order (outputs are typically zeroed); the job's
/// result is the final contents of the same arrays. Two `KernelJob`s with
/// structurally identical kernels (same [`kernel_content_key`]) and thread
/// counts share one lowered binary and may batch onto one instance,
/// exactly like same-named synthetic jobs.
#[derive(Debug, Clone)]
pub struct KernelJob {
    /// Display label for traces and reports (defaults to the kernel name).
    pub name: String,
    /// The kernel IR to compile and run.
    pub kernel: Kernel,
    /// Source of every host array's initial contents, in parameter order.
    pub inputs: Vec<PayloadSrc>,
    /// Float parameters, in parameter order.
    pub fargs: Vec<f32>,
    /// OpenMP thread count the kernel is lowered for (clamped to the
    /// instance's cores per cluster at compile time).
    pub threads: u32,
    /// Clusters participating in the offload (OpenMP `num_teams`).
    pub teams: usize,
    /// Cycle the job becomes available for dispatch (0 = immediately). A
    /// job with dataflow or [`KernelJob::after`] edges additionally waits
    /// for its producers: its *effective* arrival is the later of this and
    /// its last producer's finish.
    pub arrival: u64,
    /// QoS class: `High` dispatches before arrived `Normal` work and
    /// reserves board DRAM into the priority headroom
    /// ([`crate::sched::Priority`]).
    pub priority: Priority,
    /// Run the AutoDMA tiling pass before lowering (for kernels written in
    /// plain OpenMP form; handwritten-tiled kernels leave this off).
    pub autodma: bool,
    /// Let the scheduler search the AutoDMA knob space for this job
    /// ([`crate::sched::tune::TuneStore`]) instead of compiling the single
    /// default recipe. Only meaningful with `autodma` set; a tuned job
    /// hashes to a different [`KernelJob::content_key`] so tuned and
    /// untuned submissions never share a binary or a batch.
    pub autotune: bool,
    /// Per-job simulation budget (abort bound — it never changes the timing
    /// of a job that completes). Named synthetic jobs use the scheduler's
    /// fixed budget; kernel jobs carry their own so a session launch keeps
    /// the same budget on a pooled backend as on a single one.
    pub max_cycles: u64,
    /// Pure ordering edges: jobs that must settle before this one may
    /// dispatch, with no data attached (dataflow inputs imply their own
    /// edges — these are for explicit sequencing on top).
    pub after: Vec<JobHandle>,
    /// Per-launch SVM strategy override for [`PayloadSrc::Svm`] operands:
    /// `None` uses the board's configured default
    /// ([`crate::svm::SvmConfig::mode`]).
    pub svm: Option<crate::svm::SvmMode>,
}

impl KernelJob {
    /// A job over `kernel` with default launch parameters: 8 threads, one
    /// team, immediate arrival, no AutoDMA, no dependency edges.
    pub fn new(kernel: Kernel, inputs: Vec<Vec<f32>>, fargs: Vec<f32>) -> Self {
        Self::from_srcs(kernel, inputs.into_iter().map(PayloadSrc::Data).collect(), fargs)
    }

    /// A job whose inputs mix inline data and dataflow edges (what a
    /// pooled [`crate::session::Session`] builds for chained launches).
    pub fn from_srcs(kernel: Kernel, inputs: Vec<PayloadSrc>, fargs: Vec<f32>) -> Self {
        KernelJob {
            name: kernel.name.clone(),
            kernel,
            inputs,
            fargs,
            threads: 8,
            teams: 1,
            arrival: 0,
            priority: Priority::Normal,
            autodma: false,
            autotune: false,
            max_cycles: super::JOB_MAX_CYCLES,
            after: Vec::new(),
            svm: None,
        }
    }

    /// Content key of the binary this job needs (see [`kernel_content_key`]).
    /// Tuned jobs mix the flag in *only when set*, so every pre-existing
    /// untuned key is bit-unchanged.
    pub fn content_key(&self) -> u64 {
        let base = kernel_content_key(&self.kernel, self.autodma);
        if self.autotune {
            tuned_request_key(base)
        } else {
            base
        }
    }

    /// Check the payload against the kernel's signature (see
    /// [`validate_shape`]) plus job-level parameters.
    pub fn validate(&self) -> Result<(), String> {
        if self.teams == 0 {
            return Err(format!("kernel {:?}: teams must be at least 1", self.name));
        }
        let elems: Vec<usize> = self.inputs.iter().map(|s| s.elems()).collect();
        validate_shape(&self.kernel, &elems, self.fargs.len())
    }

    /// Every job this one depends on: explicit [`KernelJob::after`] edges
    /// plus the producers of its dataflow inputs, deduplicated.
    pub fn producers(&self) -> Vec<JobHandle> {
        let mut out: Vec<JobHandle> = self.after.clone();
        for src in &self.inputs {
            if let Some(p) = src.producer() {
                out.push(p);
            }
        }
        out.sort_by_key(|h| h.0);
        out.dedup();
        out
    }

    /// Total bytes of array data the job moves across the DRAM boundary at
    /// least once (the SJF DMA-cost proxy; dataflow inputs count too —
    /// their bytes still cross the board DRAM when the job runs).
    pub fn input_bytes(&self) -> u64 {
        self.inputs.iter().map(|s| s.elems() as u64 * 4).sum()
    }

    /// Bytes of *inline* input snapshots this job retains until it settles
    /// (the serve-loop leak guard's unit of account).
    pub fn inline_input_bytes(&self) -> u64 {
        self.inputs.iter().map(|s| s.inline_bytes()).sum()
    }
}

/// Validate a launch payload against `kernel`'s signature: array and float
/// parameter counts must match, and where an array's extents are
/// compile-time constants, its input must be at least that big — an
/// undersized buffer would let the device read past it into whatever the
/// host allocator placed next. Inputs are described by element counts so
/// dataflow edges (whose data does not exist yet at submission) validate
/// exactly like inline snapshots. This is the one guard shared by
/// [`crate::sched::Scheduler::submit_kernel`] and the session's
/// `LaunchBuilder`, so the two front doors cannot drift.
pub fn validate_shape(
    kernel: &Kernel,
    input_elems: &[usize],
    n_fargs: usize,
) -> Result<(), String> {
    let n_arrays = (0..kernel.n_params)
        .filter(|&v| matches!(kernel.sym(v), Sym::HostArray { .. }))
        .count();
    let n_floats = (0..kernel.n_params)
        .filter(|&v| matches!(kernel.sym(v), Sym::FloatParam))
        .count();
    if input_elems.len() != n_arrays {
        return Err(format!(
            "kernel {:?} has {n_arrays} array parameter(s), got {} input array(s)",
            kernel.name,
            input_elems.len()
        ));
    }
    if n_fargs != n_floats {
        return Err(format!(
            "kernel {:?} has {n_floats} float parameter(s), got {n_fargs}",
            kernel.name,
        ));
    }
    let mut ai = 0;
    for v in 0..kernel.n_params {
        if matches!(kernel.sym(v), Sym::HostArray { .. }) {
            if let Some(declared) = kernel.array_elems(v) {
                let have = input_elems[ai];
                if declared as usize > have {
                    return Err(format!(
                        "array {:?} declares {declared} element(s) but its input holds \
                         only {have}",
                        kernel.sym_name(v)
                    ));
                }
            }
            ai += 1;
        }
    }
    Ok(())
}

/// [`validate_shape`] over concrete input arrays.
pub fn validate_payload(
    kernel: &Kernel,
    inputs: &[Vec<f32>],
    fargs: &[f32],
) -> Result<(), String> {
    let elems: Vec<usize> = inputs.iter().map(|v| v.len()).collect();
    validate_shape(kernel, &elems, fargs.len())
}

/// Structural content key of a kernel: FNV-1a over the full IR (symbol
/// table including array extents and const-parameter values, plus the
/// statement tree) and the AutoDMA flag. Two kernels with equal keys lower
/// to the same binary under the same `LowerOpts`, which is what makes the
/// binary cache and same-binary batching sound for arbitrary kernels —
/// the named-job path gets the same guarantee from its (kernel, variant,
/// size) registry key.
pub fn kernel_content_key(k: &Kernel, autodma: bool) -> u64 {
    use std::fmt::Write as _;
    // Stream the Debug rendering straight into the hash state — the IR
    // dump of a large kernel is several KB, not worth materializing per
    // submission.
    let mut h = Fnv1a(0xcbf2_9ce4_8422_2325);
    write!(h, "{k:?}|autodma={autodma}").expect("hashing writer never fails");
    h.0
}

/// Content key of a *tuning-enabled* submission: `base` with the autotune
/// marker mixed in. Keeps tuned and untuned jobs in disjoint key spaces
/// (no shared batches or cache rows) while leaving untuned keys untouched.
pub fn tuned_request_key(base: u64) -> u64 {
    use std::fmt::Write as _;
    let mut h = Fnv1a(base);
    write!(h, "|autotune").expect("hashing writer never fails");
    h.0
}

/// Content key of one *tuned variant's* binary: the kernel's base content
/// mixed with the chosen AutoDMA recipe. This is the key the binary cache
/// and the learn/tune stores file a tuned compilation under — distinct
/// variants of one kernel get distinct rows, and the measured cycles of a
/// variant refine only that variant.
pub fn tuned_variant_content(base: u64, v: &crate::compiler::TunedVariant) -> u64 {
    use std::fmt::Write as _;
    let mut h = Fnv1a(base);
    write!(h, "|variant={v:?}").expect("hashing writer never fails");
    h.0
}

struct Fnv1a(u64);

impl std::fmt::Write for Fnv1a {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        for &b in s.as_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;

    fn scale(n: i32, name: &str) -> Kernel {
        let mut b = KernelBuilder::new(name);
        let x = b.host_array("X", vec![ci(n)]);
        let a = b.float_param("a");
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(x, vec![var(i)], var(a).mul(ld(x, vec![var(i)])))],
        )])
    }

    #[test]
    fn content_key_is_structural() {
        // Identical structure, independently built: same key.
        assert_eq!(
            kernel_content_key(&scale(32, "s"), false),
            kernel_content_key(&scale(32, "s"), false)
        );
        // Problem size, name and the AutoDMA flag all change the binary.
        assert_ne!(
            kernel_content_key(&scale(32, "s"), false),
            kernel_content_key(&scale(64, "s"), false)
        );
        assert_ne!(
            kernel_content_key(&scale(32, "s"), false),
            kernel_content_key(&scale(32, "t"), false)
        );
        assert_ne!(
            kernel_content_key(&scale(32, "s"), false),
            kernel_content_key(&scale(32, "s"), true)
        );
    }

    #[test]
    fn tuned_keys_are_disjoint_and_stable() {
        let base = kernel_content_key(&scale(32, "s"), true);
        // The tuning flag forks the key space without touching untuned keys.
        let mut j = KernelJob::new(scale(32, "s"), vec![], vec![]);
        j.autodma = true;
        assert_eq!(j.content_key(), base);
        j.autotune = true;
        assert_eq!(j.content_key(), tuned_request_key(base));
        assert_ne!(j.content_key(), base);
        // Distinct variants file under distinct binary-content keys; the
        // same variant always maps to the same key.
        let d = crate::compiler::TunedVariant::default_recipe();
        let t = crate::compiler::TunedVariant {
            staging: true,
            tile_side: Some(64),
            double_buffer: true,
        };
        assert_eq!(tuned_variant_content(base, &d), tuned_variant_content(base, &d));
        assert_ne!(tuned_variant_content(base, &d), tuned_variant_content(base, &t));
        assert_ne!(tuned_variant_content(base, &d), base);
    }

    #[test]
    fn payload_validation_catches_shape_errors() {
        let k = scale(16, "s");
        assert!(validate_payload(&k, &[vec![0.0; 16]], &[1.0]).is_ok());
        // Oversized inputs are harmless; undersized ones are not.
        assert!(validate_payload(&k, &[vec![0.0; 32]], &[1.0]).is_ok());
        assert!(validate_payload(&k, &[], &[1.0]).unwrap_err().contains("array parameter"));
        assert!(
            validate_payload(&k, &[vec![0.0; 16]], &[]).unwrap_err().contains("float parameter")
        );
        assert!(
            validate_payload(&k, &[vec![0.0; 4]], &[1.0]).unwrap_err().contains("declares 16")
        );
        let mut j = KernelJob::new(scale(16, "s"), vec![vec![0.0; 16]], vec![1.0]);
        assert!(j.validate().is_ok());
        j.teams = 0;
        assert!(j.validate().unwrap_err().contains("teams"));
    }

    #[test]
    fn dataflow_srcs_validate_by_element_count() {
        // An output reference with enough elements passes the same guard
        // an inline snapshot would; an undersized one is caught before any
        // data exists.
        let k = scale(16, "s");
        let ok = KernelJob::from_srcs(
            k.clone(),
            vec![PayloadSrc::Output { producer: JobHandle(0), index: 0, elems: 16 }],
            vec![2.0],
        );
        assert!(ok.validate().is_ok());
        assert_eq!(ok.input_bytes(), 64);
        assert_eq!(ok.inline_input_bytes(), 0, "edges hold no inline data");
        assert_eq!(ok.producers(), vec![JobHandle(0)]);
        let small = KernelJob::from_srcs(
            k,
            vec![PayloadSrc::Output { producer: JobHandle(0), index: 0, elems: 4 }],
            vec![2.0],
        );
        assert!(small.validate().unwrap_err().contains("declares 16"));
    }

    #[test]
    fn svm_srcs_are_weightless_until_dispatch() {
        let j = KernelJob::from_srcs(
            scale(16, "s"),
            vec![PayloadSrc::Svm { va: 0x40_0000_0000, elems: 16 }],
            vec![2.0],
        );
        assert!(j.validate().is_ok(), "SVM operands validate by element count");
        assert!(j.svm.is_none(), "no per-launch strategy override by default");
        assert_eq!(j.input_bytes(), 64, "SVM bytes still count for DMA predictions");
        assert_eq!(j.inline_input_bytes(), 0, "but nothing is retained inline");
        assert!(j.producers().is_empty(), "a VA is not a dataflow edge");
    }

    #[test]
    fn producers_dedup_after_and_dataflow_edges() {
        let mut j = KernelJob::from_srcs(
            scale(8, "s"),
            vec![PayloadSrc::Output { producer: JobHandle(3), index: 0, elems: 8 }],
            vec![1.0],
        );
        j.after = vec![JobHandle(5), JobHandle(3)];
        assert_eq!(j.producers(), vec![JobHandle(3), JobHandle(5)]);
    }

    #[test]
    fn job_defaults_and_footprint() {
        let j = KernelJob::new(scale(16, "s"), vec![vec![0.0; 16]], vec![2.0]);
        assert_eq!(j.name, "s");
        assert_eq!((j.threads, j.teams, j.arrival, j.autodma), (8, 1, 0, false));
        assert_eq!(j.priority, Priority::Normal);
        assert!(j.after.is_empty());
        assert_eq!(j.input_bytes(), 64);
        assert_eq!(j.inline_input_bytes(), 64);
        assert_eq!(j.content_key(), KernelJob::new(scale(16, "s"), vec![], vec![]).content_key());
    }
}
