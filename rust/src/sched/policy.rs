//! Scheduling policies.
//!
//! A policy decides two things:
//!
//! * **Ordering** — which queued job dispatches next ([`Policy::pick`]):
//!   FIFO takes the oldest, SJF the one with the smallest static cycle
//!   prediction ([`crate::compiler::metrics::predict_cycles`]).
//! * **Admission** — whether submission itself filters jobs
//!   ([`Policy::admission`]): the capacity-aware policy compares a job's
//!   static SPM footprint (`Lowered::l1_used`) against what
//!   `hero_l1_capacity` reports for the target cluster, and either rejects
//!   oversized jobs or splits them into feasible sub-jobs.
//!
//! Ordering is *contention-aware*: the scheduler feeds [`Policy::pick`]
//! predictions inflated by [`inflate`] with the current shared-DRAM
//! pressure, so under a loaded board SJF deprioritizes DMA-heavy jobs
//! (whose cycles will stretch) in favor of compute-bound ones. On an idle
//! or uncontended board the inflation is zero and ordering is unchanged.

use crate::bench_harness::{variant_kernel, Variant};
use crate::compiler::metrics::{predict_cycles, PredictOpts};
use crate::workloads::Workload;

/// QoS class of a job (the scheduler-level face of the DRAM ledger's
/// priority headroom — see [`crate::mem::BandwidthLedger`]).
///
/// `High` marks a latency-critical job: it dispatches before any `Normal`
/// work that has arrived (strict priority tiers, with the configured
/// policy ordering *within* a tier), and its board-DRAM traffic reserves as
/// a priority request, reaching into the bandwidth slice
/// `--priority-headroom` keeps free of normal traffic. Ordering within a
/// class is unchanged, so an all-`Normal` stream schedules exactly as it
/// did before priorities existed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Best-effort throughput traffic (the default).
    #[default]
    Normal,
    /// Latency-critical: dispatches first, reserves DRAM with priority.
    High,
}

impl Priority {
    /// Parse a trace-file / CLI priority token.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "normal" | "norm" | "lo" => Some(Priority::Normal),
            "high" | "hi" => Some(Priority::High),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Whether this class reserves board DRAM as a priority request.
    pub fn is_high(&self) -> bool {
        matches!(self, Priority::High)
    }
}

/// What the capacity policy does with a job whose SPM footprint exceeds
/// `hero_l1_capacity`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OversizeAction {
    /// Refuse the job (its handle completes as `Rejected`).
    Reject,
    /// Decompose it into same-kernel sub-jobs at half the problem size,
    /// recursively, until the footprint fits (the handle completes as
    /// `Split` with the child handles).
    Split,
}

/// A pluggable scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First in, first out.
    Fifo,
    /// Shortest-predicted-first on static cycle predictions.
    Sjf,
    /// FIFO ordering plus capacity-aware admission control.
    Capacity(OversizeAction),
}

impl Policy {
    /// Parse a `--policy` argument.
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "sjf" => Some(Policy::Sjf),
            "capacity" | "cap" | "cap-split" => Some(Policy::Capacity(OversizeAction::Split)),
            "cap-reject" => Some(Policy::Capacity(OversizeAction::Reject)),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Sjf => "sjf",
            Policy::Capacity(OversizeAction::Split) => "capacity(split)",
            Policy::Capacity(OversizeAction::Reject) => "capacity(reject)",
        }
    }

    /// Admission action, if this policy gates submissions.
    pub fn admission(&self) -> Option<OversizeAction> {
        match self {
            Policy::Capacity(a) => Some(*a),
            _ => None,
        }
    }

    /// Index into `queue` of the job to dispatch next. `predicted` maps a
    /// job id to its static cycle prediction.
    pub fn pick(&self, queue: &[usize], predicted: impl Fn(usize) -> u64) -> usize {
        assert!(!queue.is_empty());
        match self {
            Policy::Fifo | Policy::Capacity(_) => 0,
            Policy::Sjf => {
                // Ties break toward the older job (stable argmin), which is
                // what keeps SJF starvation-free for equal-length jobs.
                let mut best = 0;
                for (i, &id) in queue.iter().enumerate().skip(1) {
                    if predicted(id) < predicted(queue[best]) {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Indices into `queue` in dispatch-preference order — the lookahead
    /// window's candidate ranking. `rank(..)[0]` always equals
    /// [`Policy::pick`]: FIFO/capacity keep submission order, SJF sorts by
    /// prediction with ties breaking toward the older job (the same stable
    /// argmin `pick` computes).
    pub fn rank(&self, queue: &[usize], predicted: impl Fn(usize) -> u64) -> Vec<usize> {
        let mut order: Vec<usize> = (0..queue.len()).collect();
        if matches!(self, Policy::Sjf) {
            order.sort_by_key(|&i| (predicted(queue[i]), i));
        }
        order
    }
}

/// Static cycle prediction for one job: the kernel form the job will
/// *execute*, walked with the job's problem size as the fallback trip count
/// and its thread count as the parallel width.
///
/// For the AutoDma variant the compiler input is the unmodified
/// (external-memory) kernel, but the executed binary is the SPM-tiled
/// AutoDMA output — costed here by its closest static proxy, the
/// handwritten tiling. Predicting the unmodified form instead would
/// over-estimate AutoDma jobs by 1-2 orders of magnitude and invert SJF's
/// ordering for exactly the jobs it is meant to favor.
pub fn predict_job(w: &Workload, variant: Variant, threads: u32) -> u64 {
    let kernel = match variant {
        Variant::AutoDma => variant_kernel(w, Variant::Handwritten),
        _ => variant_kernel(w, variant),
    };
    predict_cycles(
        kernel,
        &PredictOpts { default_trips: w.size as u64, par_ways: threads.max(1) as u64 },
    )
}

/// Static cycle prediction for an arbitrary compiled-kernel job
/// ([`crate::sched::KernelJob`]). Builder kernels carry constant loop
/// bounds, so the fallback trip count rarely fires; 16 matches the
/// [`PredictOpts`] default.
///
/// For an AutoDMA job the submitted IR is the external-memory form but the
/// *executed* binary is the tiled transform output — predicting the input
/// IR would over-estimate by 1-2 orders of magnitude and invert SJF's
/// ordering for exactly the jobs it should favor (the same trap
/// [`predict_job`] avoids for named jobs via the handwritten proxy). So
/// the prediction walks the transformed kernel; when AutoDMA declines, the
/// input IR is what actually runs and is predicted directly.
pub fn predict_kernel_job(
    k: &crate::compiler::ir::Kernel,
    autodma: bool,
    cfg: &crate::config::HeroConfig,
    threads: u32,
) -> u64 {
    let opts = PredictOpts { default_trips: 16, par_ways: threads.max(1) as u64 };
    if autodma {
        let ad = crate::compiler::AutoDmaOpts::for_config(cfg);
        if let Ok((tiled, _)) = crate::compiler::autodma::transform(k, &ad) {
            return predict_cycles(&tiled, &opts);
        }
    }
    predict_cycles(k, &opts)
}

/// Byte footprint of one named job across the DRAM boundary: every mapped
/// array crosses it at least once (tiled variants stage inputs in and
/// results out). The placement engine scores candidate slots on this
/// footprint; [`predict_job_dma_cycles`] turns it into a cycle proxy.
pub fn job_bytes(w: &Workload) -> u64 {
    w.arrays.iter().map(|a| a.elems as u64 * 4).sum()
}

/// Static DMA-cycle proxy for one job: the job's data footprint over the
/// instance's NoC beat rate approximates its uncontended DRAM service time.
pub fn predict_job_dma_cycles(w: &Workload, beat_bytes: u64) -> u64 {
    predict_dma_cycles(job_bytes(w), beat_bytes)
}

/// DMA-cycle proxy from a raw byte footprint (shared by the named and
/// arbitrary-kernel job paths).
pub fn predict_dma_cycles(bytes: u64, beat_bytes: u64) -> u64 {
    bytes / beat_bytes.max(1)
}

/// Host-side copy-staging cycle proxy: fixed per-transfer setup plus the
/// bytes over the host DRAM-port rate ([`crate::svm::SvmConfig::host_bw`]).
/// The SVM `auto` strategy prices the staging alternative with this shape
/// (the exact ledger-aware figure comes from
/// [`crate::sched::InstancePool::host_probe`]).
pub fn predict_host_copy_cycles(bytes: u64, host_bw: u64, setup: u64) -> u64 {
    setup + bytes.div_ceil(host_bw.max(1))
}

/// Inflate a static cycle prediction by the current DRAM pressure: the
/// DMA share of the job stretches proportionally to how much of the board
/// peak is already reserved (fully loaded board ⇒ the DMA share doubles).
pub fn inflate(predicted: u64, predicted_dma: u64, pressure: f64) -> u64 {
    predicted + (predicted_dma as f64 * pressure.clamp(0.0, 1.0)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn priority_parses_orders_and_labels() {
        assert_eq!(Priority::parse("high"), Some(Priority::High));
        assert_eq!(Priority::parse("hi"), Some(Priority::High));
        assert_eq!(Priority::parse("normal"), Some(Priority::Normal));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::High > Priority::Normal, "tier selection relies on Ord");
        assert!(Priority::High.is_high() && !Priority::Normal.is_high());
        assert_eq!(Priority::High.label(), "high");
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(Policy::parse("fifo"), Some(Policy::Fifo));
        assert_eq!(Policy::parse("sjf"), Some(Policy::Sjf));
        assert_eq!(Policy::parse("capacity"), Some(Policy::Capacity(OversizeAction::Split)));
        assert_eq!(Policy::parse("cap-reject"), Some(Policy::Capacity(OversizeAction::Reject)));
        assert_eq!(Policy::parse("lifo"), None);
        assert_eq!(Policy::Sjf.label(), "sjf");
    }

    #[test]
    fn fifo_picks_head_sjf_picks_shortest() {
        let queue = [10usize, 11, 12];
        let predicted = |id: usize| match id {
            10 => 500u64,
            11 => 100,
            _ => 300,
        };
        assert_eq!(Policy::Fifo.pick(&queue, predicted), 0);
        assert_eq!(Policy::Capacity(OversizeAction::Reject).pick(&queue, predicted), 0);
        assert_eq!(Policy::Sjf.pick(&queue, predicted), 1);
    }

    #[test]
    fn sjf_ties_break_toward_older() {
        let queue = [3usize, 4, 5];
        assert_eq!(Policy::Sjf.pick(&queue, |_| 42), 0);
    }

    #[test]
    fn kernel_job_prediction_uses_tiled_form_for_autodma() {
        // An AutoDMA kernel job executes the tiled transform output, not
        // the external-memory input IR; its prediction must reflect that
        // (otherwise SJF inverts for autodma launches).
        let cfg = crate::config::aurora();
        let w = workloads::gemm::build(24);
        let plain = predict_kernel_job(&w.unmodified, false, &cfg, 8);
        let tiled = predict_kernel_job(&w.unmodified, true, &cfg, 8);
        assert!(tiled < plain, "autodma prediction {tiled} must undercut {plain}");
    }

    #[test]
    fn prediction_orders_problem_sizes() {
        let small = workloads::gemm::build(12);
        let big = workloads::gemm::build(24);
        let ps = predict_job(&small, Variant::Handwritten, 8);
        let pb = predict_job(&big, Variant::Handwritten, 8);
        assert!(pb > ps, "{pb} vs {ps}");
    }

    #[test]
    fn inflation_reorders_dma_heavy_jobs_under_pressure() {
        // Job A: compute-bound (little DMA), job B: slightly shorter but
        // DMA-heavy. Idle board: SJF picks B. Loaded board: A.
        let queue = [0usize, 1];
        let stat = |id: usize| if id == 0 { (1000u64, 50u64) } else { (900, 800) };
        let idle = |id: usize| {
            let (p, d) = stat(id);
            inflate(p, d, 0.0)
        };
        let loaded = |id: usize| {
            let (p, d) = stat(id);
            inflate(p, d, 0.9)
        };
        assert_eq!(Policy::Sjf.pick(&queue, idle), 1);
        assert_eq!(Policy::Sjf.pick(&queue, loaded), 0);
        // Inflation never deflates and is clamped.
        assert_eq!(inflate(100, 40, 0.0), 100);
        assert_eq!(inflate(100, 40, 2.0), 140);
    }

    #[test]
    fn dma_prediction_scales_with_footprint_and_width() {
        let small = workloads::gemm::build(12);
        let big = workloads::gemm::build(24);
        assert!(
            predict_job_dma_cycles(&big, 8) > predict_job_dma_cycles(&small, 8)
        );
        assert!(
            predict_job_dma_cycles(&small, 4) > predict_job_dma_cycles(&small, 16)
        );
    }

    #[test]
    fn host_copy_prediction_is_setup_plus_drain() {
        assert_eq!(predict_host_copy_cycles(800, 8, 30), 130);
        assert_eq!(predict_host_copy_cycles(801, 8, 30), 131, "partial beats round up");
        assert_eq!(predict_host_copy_cycles(0, 8, 30), 30);
        assert_eq!(predict_host_copy_cycles(64, 0, 0), 64, "rate clamps to 1");
    }

    #[test]
    fn only_capacity_admits() {
        assert_eq!(Policy::Fifo.admission(), None);
        assert_eq!(Policy::Sjf.admission(), None);
        assert_eq!(
            Policy::Capacity(OversizeAction::Split).admission(),
            Some(OversizeAction::Split)
        );
    }
}
