//! Multi-accelerator offload scheduler (the "serve" subsystem).
//!
//! The paper's offload model (§2.3/§2.4) is one host driving one
//! accelerator through the mailbox: a single `#pragma omp target` region at
//! a time. This module scales that model to production-style traffic: a
//! host runtime that owns a **pool** of simulated accelerator instances and
//! drains a queue of offload **jobs** (workload × variant × size ×
//! configuration), asynchronously.
//!
//! Concept map back to the paper's §2.4 API:
//!
//! | HERO API (per transfer)          | Scheduler (per job)                     |
//! |----------------------------------|-----------------------------------------|
//! | `hero_memcpy_*_async` returns id | [`Scheduler::submit`] returns a handle  |
//! | `hero_memcpy_wait(id)`           | [`Scheduler::wait`] / [`Scheduler::poll`] |
//! | `hero_lN_capacity`               | capacity-aware admission ([`policy`])   |
//! | perf counters                    | [`report::ServeReport`] + [`crate::trace::SchedTrace`] |
//!
//! Pieces:
//!
//! * [`policy`] — pluggable dispatch order (FIFO, shortest-predicted-first
//!   on `compiler::metrics::predict_cycles`) and capacity-aware admission
//!   that rejects or splits jobs whose SPM footprint exceeds what
//!   `hero_l1_capacity` reports.
//! * [`cache`] — lowered-binary cache keyed on (kernel, variant, size,
//!   threads, config); same-kernel jobs batch onto one instance and
//!   amortize the simulated compile charge.
//! * [`pool`] — K accelerator instances (homogeneous or heterogeneous —
//!   e.g. mixed wide-NoC widths) as serializing resources on **one shared
//!   carrier-board DRAM**: each job's main-memory traffic is reserved on a
//!   cycle-accounted bandwidth ledger ([`crate::mem::BandwidthLedger`]),
//!   and oversubscription stretches occupancy windows — contention stall,
//!   surfaced per instance and in aggregate.
//! * [`report`] — aggregate throughput/utilization/DRAM-stall reporting.
//!
//! Every job executes on a *fresh* `Accel` (own SPM/IOMMU state), so
//! results on a homogeneous pool are bit-identical regardless of policy,
//! pool size, batching, caching or board bandwidth — the scheduler and the
//! board model move *time*, never numerics. (A heterogeneous pool may tile
//! kernels differently per instance config, which legitimately reorders
//! float accumulation.) `hero serve` (see `main.rs`) and `benches/sched.rs`
//! are the front-ends.

pub mod cache;
pub mod policy;
pub mod pool;
pub mod report;

pub use crate::workloads::synth::JobDesc;
pub use cache::BinaryCache;
pub use policy::{OversizeAction, Policy};
pub use pool::{BoardSpec, InstancePool};
pub use report::{InstanceReport, ServeReport};

use crate::accel::Accel;
use crate::bench_harness::{self, run_lowered};
use crate::config::HeroConfig;
use crate::runtime::hero_api::{HeroApi, SpmLevel};
use crate::trace::{Event, SchedEvent, SchedTrace};
use crate::workloads::{self, Workload};
use anyhow::{bail, Result};

/// Smallest problem size the capacity policy will split down to.
pub const MIN_SPLIT_SIZE: usize = 8;

/// Most same-binary jobs chained onto one instance per dispatch.
pub const MAX_BATCH: usize = 8;

/// Per-job simulation budget.
const JOB_MAX_CYCLES: u64 = 10_000_000_000;

pub type JobId = usize;

/// Async completion handle returned by [`Scheduler::submit`] (the job-level
/// analogue of `hero_memcpy_*_async`'s transfer id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobHandle(pub JobId);

/// Completion record of one finished job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub instance: usize,
    /// Occupancy window on the instance's simulated timeline.
    pub start: u64,
    pub end: u64,
    /// Pure device cycles of the offload.
    pub device_cycles: u64,
    /// Simulated compile cycles charged to this job (0 when the binary was
    /// cached or a batch predecessor paid).
    pub compile_cycles: u64,
    /// DMA wide-path occupancy of the offload.
    pub dma_busy_cycles: u64,
    /// Bytes the job moved through the shared carrier-board DRAM.
    pub dma_bytes: u64,
    /// Cycles the job's occupancy window stretched waiting on the shared
    /// board DRAM (0 on an uncontended board).
    pub dram_stall_cycles: u64,
    /// FNV-1a digest over every output array's f32 bits.
    pub digest: u64,
    /// Host golden-model verification result (always true when the
    /// scheduler runs with verification off).
    pub verified: bool,
}

/// Life cycle of a submitted job.
#[derive(Debug, Clone)]
pub enum JobState {
    /// Waiting in the queue.
    Queued,
    /// Refused: admission control, unknown kernel, compile or run error.
    Rejected { reason: String },
    /// Oversized job decomposed into the given sub-jobs (capacity policy).
    Split { children: Vec<JobHandle> },
    /// Ran to completion.
    Done(JobOutcome),
}

impl JobState {
    /// A job is *settled* once it can make no further progress; every
    /// handle must settle eventually (the no-starvation invariant).
    pub fn settled(&self) -> bool {
        !matches!(self, JobState::Queued)
    }
}

struct JobRecord {
    spec: JobDesc,
    predicted: u64,
    /// Static DMA-cycle proxy (SJF contention-aware inflation).
    predicted_dma: u64,
    state: JobState,
}

/// The offload scheduler: job queue + policy + binary cache + instance pool.
pub struct Scheduler {
    cfg: HeroConfig,
    policy: Policy,
    pool: InstancePool,
    cache: BinaryCache,
    batching: bool,
    verify: bool,
    /// What `hero_l1_capacity` reports for a cluster of this configuration.
    l1_capacity: u32,
    jobs: Vec<JobRecord>,
    queue: Vec<JobId>,
    pub trace: SchedTrace,
}

impl Scheduler {
    /// `pool_size` identical instances of `cfg` on the board the config
    /// describes (`BoardSpec::from_config`).
    pub fn new(cfg: HeroConfig, pool_size: usize, policy: Policy) -> Self {
        assert!(pool_size >= 1, "pool needs at least one instance");
        Self::new_heterogeneous(vec![cfg; pool_size], policy)
    }

    /// One instance per config — a heterogeneous pool (e.g. mixed 32/64/128
    /// bit wide-NoC instances from [`crate::config::preset::with_dma_width`]).
    /// The first config is the *base*: it decides the board DRAM bandwidth,
    /// admission thresholds use the most constrained instance, and SJF
    /// predictions use the base NoC width.
    pub fn new_heterogeneous(cfgs: Vec<HeroConfig>, policy: Policy) -> Self {
        assert!(!cfgs.is_empty(), "pool needs at least one instance");
        // Ask the HERO API itself, on a throwaway instance per distinct
        // config, how much user L1 a cluster offers — the admission
        // threshold is the runtime's own answer (the minimum across the
        // pool, so admitted jobs fit every instance), not a re-derivation.
        let mut seen: Vec<String> = Vec::new();
        let mut l1_capacity = u32::MAX;
        for c in &cfgs {
            if !seen.contains(&c.name) {
                seen.push(c.name.clone());
                let accel = Accel::new(c.clone(), 1 << 20);
                let mut api = HeroApi::new(&accel);
                l1_capacity = l1_capacity.min(api.capacity(SpmLevel::L1(0)));
            }
        }
        let cfg = cfgs[0].clone();
        let board = BoardSpec::from_config(&cfg);
        Scheduler {
            pool: InstancePool::heterogeneous(cfgs, board),
            cache: BinaryCache::new(true),
            batching: true,
            verify: true,
            l1_capacity,
            jobs: Vec::new(),
            queue: Vec::new(),
            trace: SchedTrace::new(),
            cfg,
            policy,
        }
    }

    /// Override the shared carrier-board DRAM spec (must precede
    /// submissions; contention studies and `hero serve --board-bw`).
    pub fn with_board(mut self, board: BoardSpec) -> Self {
        self.pool.set_board(board);
        self
    }

    /// Disable/enable the lowered-binary cache (on by default).
    pub fn with_cache(mut self, on: bool) -> Self {
        self.cache = BinaryCache::new(on);
        self
    }

    /// Disable/enable same-binary batching (on by default).
    pub fn with_batching(mut self, on: bool) -> Self {
        self.batching = on;
        self
    }

    /// Disable/enable per-job golden-model verification (on by default).
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Jobs submitted so far (including rejected/split ones).
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Jobs still waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Current state of a handle.
    pub fn state(&self, h: JobHandle) -> &JobState {
        &self.jobs[h.0].state
    }

    /// Completion record, if the job has finished (non-blocking probe — the
    /// `hero_memcpy` test-for-completion analogue).
    pub fn poll(&self, h: JobHandle) -> Option<&JobOutcome> {
        match &self.jobs[h.0].state {
            JobState::Done(o) => Some(o),
            _ => None,
        }
    }

    /// Submit one job; returns immediately with its handle.
    pub fn submit(&mut self, desc: JobDesc) -> JobHandle {
        let id = self.jobs.len();
        self.trace.record(SchedEvent::Submitted { job: id });
        self.jobs.push(JobRecord {
            spec: desc,
            predicted: 0,
            predicted_dma: 0,
            state: JobState::Queued,
        });
        if !workloads::known(desc.kernel) {
            self.reject(id, format!("unknown kernel {:?}", desc.kernel));
            return JobHandle(id);
        }
        // Only SJF reads predictions and only capacity admission needs the
        // binary, so FIFO submission skips building the workload entirely.
        // Threads are clamped to the cluster width exactly as compilation
        // will clamp them (`cache::key_for`), so inflated thread counts
        // cannot deflate a job's prediction relative to how it executes.
        if matches!(self.policy, Policy::Sjf) {
            let w = desc.workload().unwrap();
            let eff_threads = desc.threads.min(self.cfg.accel.cores_per_cluster as u32);
            self.jobs[id].predicted = policy::predict_job(&w, desc.variant, eff_threads);
            self.jobs[id].predicted_dma =
                policy::predict_job_dma_cycles(&w, self.cfg.dma_beat_bytes());
        }
        if let Some(action) = self.policy.admission() {
            let w = desc.workload().unwrap();
            match self.spm_footprint(&w, desc) {
                Ok(bytes) if bytes <= self.l1_capacity => {}
                Ok(bytes) => {
                    let reason = format!(
                        "SPM footprint {bytes} B exceeds hero_l1_capacity {} B",
                        self.l1_capacity
                    );
                    self.oversize(id, desc, action, reason);
                    return JobHandle(id);
                }
                Err(e) if crate::compiler::lower::is_l1_overflow(&e) => {
                    self.oversize(id, desc, action, e.to_string());
                    return JobHandle(id);
                }
                Err(e) => {
                    self.reject(id, format!("compile failed: {e}"));
                    return JobHandle(id);
                }
            }
        }
        self.queue.push(id);
        JobHandle(id)
    }

    /// Submit a whole stream.
    pub fn submit_all(&mut self, descs: &[JobDesc]) -> Vec<JobHandle> {
        descs.iter().map(|d| self.submit(*d)).collect()
    }

    fn reject(&mut self, id: JobId, reason: String) {
        self.trace.record(SchedEvent::Rejected { job: id, reason: reason.clone() });
        self.jobs[id].state = JobState::Rejected { reason };
    }

    fn oversize(&mut self, id: JobId, desc: JobDesc, action: OversizeAction, reason: String) {
        match action {
            OversizeAction::Reject => self.reject(id, reason),
            OversizeAction::Split => {
                let half = desc.size / 2;
                if half < MIN_SPLIT_SIZE {
                    self.reject(id, format!("{reason}; cannot split below N={MIN_SPLIT_SIZE}"));
                    return;
                }
                // Children are independent problem instances at feasible
                // granularity, with seeds derived from the parent's.
                let c0 = self.submit(JobDesc {
                    size: half,
                    seed: desc.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 1,
                    ..desc
                });
                let c1 = self.submit(JobDesc {
                    size: half,
                    seed: desc.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 2,
                    ..desc
                });
                let children = vec![c0, c1];
                self.trace.record(SchedEvent::Split {
                    job: id,
                    children: children.iter().map(|h| h.0).collect(),
                });
                self.jobs[id].state = JobState::Split { children };
            }
        }
    }

    /// Static SPM footprint of a job: the lowered binary's `l1_used`.
    fn spm_footprint(&mut self, w: &Workload, desc: JobDesc) -> Result<u32> {
        let lowered = self.cache.probe(&self.cfg, w, desc.variant, desc.threads)?;
        Ok(lowered.l1_used)
    }

    /// Dispatch the next job (plus its batch) onto the earliest-free
    /// instance. Returns `false` when the queue is empty.
    pub fn step(&mut self) -> Result<bool> {
        if self.queue.is_empty() {
            return Ok(false);
        }
        // The target instance is known before job selection (earliest-free
        // slot), so ordering can be contention-aware: predictions inflate
        // with the DRAM pressure at the dispatch frontier, steering SJF
        // away from DMA-heavy jobs while the board is loaded.
        let inst = self.pool.pick();
        let icfg = self.pool.cfg(inst).clone();
        let frontier = self.pool.free_at(inst);
        let policy = self.policy;
        let pressure = self.pool.pressure();
        // Jobs that have arrived by the dispatch frontier compete under the
        // policy; a job whose arrival is still in the future must not jump
        // ahead of ready work (it would idle the instance and serialize
        // everything behind the gap). Only when nothing has arrived yet
        // does the earliest future arrival dispatch (the instance waits).
        let arrived: Vec<usize> = (0..self.queue.len())
            .filter(|&p| self.jobs[self.queue[p]].spec.arrival <= frontier)
            .collect();
        let qi = if arrived.is_empty() {
            (0..self.queue.len())
                .min_by_key(|&p| (self.jobs[self.queue[p]].spec.arrival, p))
                .expect("queue is non-empty")
        } else {
            let sub: Vec<JobId> = arrived.iter().map(|&p| self.queue[p]).collect();
            let k = policy.pick(&sub, |id| {
                policy::inflate(self.jobs[id].predicted, self.jobs[id].predicted_dma, pressure)
            });
            arrived[k]
        };
        let head = self.queue.remove(qi);
        let spec = self.jobs[head].spec;
        let w = workloads::build(spec.kernel, spec.size)
            .expect("queued jobs have known kernels");

        // Gather same-binary followers from the queue (batching). Only
        // jobs already arrived by the head's start may chain — batching a
        // future arrival would park the instance on its gap.
        let head_start = frontier.max(spec.arrival);
        let mut batch = vec![head];
        if self.batching {
            let mut i = 0;
            while i < self.queue.len() && batch.len() < MAX_BATCH {
                let cand = self.jobs[self.queue[i]].spec;
                if cand.kernel == spec.kernel
                    && cand.size == spec.size
                    && cand.variant == spec.variant
                    && cand.threads == spec.threads
                    && cand.arrival <= head_start
                {
                    batch.push(self.queue.remove(i));
                } else {
                    i += 1;
                }
            }
        }

        // Compile for the *instance's* configuration (the cache key includes
        // the config name, so heterogeneous pools keep per-width binaries).
        let (lowered, compile_cost) =
            match self.cache.acquire(&icfg, &w, spec.variant, spec.threads) {
                Ok(x) => x,
                Err(e) => {
                    // The binary fails for every job of the batch alike.
                    let reason = format!("compile failed: {e}");
                    for id in batch {
                        self.reject(id, reason.clone());
                    }
                    return Ok(true);
                }
            };
        if compile_cost > 0 {
            self.trace.record(SchedEvent::CompileMiss { job: head, cycles: compile_cost });
        } else {
            self.trace.record(SchedEvent::CompileHit { job: head });
        }

        let followers = batch.len() - 1;
        let mut charge = compile_cost;
        for id in batch {
            let seed = self.jobs[id].spec.seed;
            let arrival = self.jobs[id].spec.arrival;
            match run_lowered(&icfg, &w, &lowered, seed, JOB_MAX_CYCLES) {
                Err(e) => {
                    // The lowering happened even though the job failed:
                    // book the pending compile charge on the instance so it
                    // neither vanishes nor migrates onto a cached follower.
                    if charge > 0 {
                        self.pool.assign(inst, arrival, charge, 0);
                        charge = 0;
                    }
                    self.reject(id, format!("execution failed: {e}"));
                }
                Ok(out) => {
                    let verified = !self.verify || bench_harness::verify(&w, &out, seed).is_ok();
                    let digest = digest_arrays(&out.arrays);
                    let dma_busy = out.result.perf.get(Event::DmaBusyCycles);
                    let dma_bytes = out.result.perf.get(Event::DmaBytes);
                    let a = self.pool.assign(
                        inst,
                        arrival,
                        charge + out.result.total_cycles,
                        dma_bytes,
                    );
                    self.pool.record(inst, out.result.device_cycles, dma_busy);
                    self.trace.record(SchedEvent::Dispatched {
                        job: id,
                        instance: inst,
                        start: a.start,
                        batched: if id == head { followers } else { 0 },
                    });
                    self.trace.record(SchedEvent::Completed {
                        job: id,
                        instance: inst,
                        end: a.end,
                        dram_stall: a.dram_stall,
                    });
                    self.jobs[id].state = JobState::Done(JobOutcome {
                        instance: inst,
                        start: a.start,
                        end: a.end,
                        device_cycles: out.result.device_cycles,
                        compile_cycles: charge,
                        dma_busy_cycles: dma_busy,
                        dma_bytes,
                        dram_stall_cycles: a.dram_stall,
                        digest,
                        verified,
                    });
                    charge = 0; // the batch head pays the compile once
                }
            }
        }
        Ok(true)
    }

    /// Run the queue dry.
    pub fn drain(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Drive the scheduler until `h` settles (the `hero_memcpy_wait`
    /// analogue). Note a `Split` parent settles at submission; wait on its
    /// children to wait for the decomposed work.
    pub fn wait(&mut self, h: JobHandle) -> Result<&JobState> {
        while !self.jobs[h.0].state.settled() {
            if !self.step()? {
                bail!("job {} is queued but the queue is empty", h.0);
            }
        }
        Ok(&self.jobs[h.0].state)
    }

    /// Aggregate report over everything submitted so far.
    pub fn report(&self) -> ServeReport {
        let (mut completed, mut rejected, mut split, mut verify_failures) = (0, 0, 0, 0);
        let mut total_device = 0u64;
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        for rec in &self.jobs {
            match &rec.state {
                JobState::Done(o) => {
                    completed += 1;
                    total_device += o.device_cycles;
                    if !o.verified {
                        verify_failures += 1;
                    }
                    // Chain in job-id order: stable across dispatch orders.
                    digest = (digest ^ o.digest).wrapping_mul(0x0000_0100_0000_01b3);
                }
                JobState::Rejected { .. } => rejected += 1,
                JobState::Split { .. } => split += 1,
                JobState::Queued => {}
            }
        }
        let makespan = self.pool.makespan();
        let instances = (0..self.pool.len())
            .map(|i| {
                let s = self.pool.stats(i);
                InstanceReport {
                    jobs: s.jobs,
                    busy_cycles: self.pool.busy_cycles(i),
                    device_cycles: s.device_cycles,
                    dma_busy_cycles: s.dma_busy_cycles,
                    dram_stall_cycles: s.dram_stall_cycles,
                    dram_bytes: s.dram_bytes,
                    dma_width_bits: self.pool.cfg(i).noc.dma_width_bits,
                    utilization: self.pool.utilization(i),
                }
            })
            .collect();
        ServeReport {
            policy: self.policy.label(),
            caching: self.cache.enabled(),
            batching: self.batching,
            submitted: self.jobs.len(),
            completed,
            rejected,
            split,
            verify_failures,
            makespan_cycles: makespan,
            total_device_cycles: total_device,
            // Single source of truth: what the cache actually charged —
            // per-job outcomes can miss a charge booked for a failed head.
            compile_cycles: self.cache.stats.charged_cycles,
            cache_hits: self.cache.stats.hits,
            cache_misses: self.cache.stats.misses,
            freq_mhz: self.cfg.accel.freq_mhz,
            dram_peak_bytes_per_cycle: self.pool.dram_peak(),
            dram_stall_cycles: self.pool.dram_stall_total(),
            dram_bytes: self.pool.dram_total_bytes(),
            dram_utilization: self.pool.dram_utilization(),
            digest,
            instances,
        }
    }
}

/// FNV-1a over the f32 bit patterns of a job's arrays (bit-identity check).
pub fn digest_arrays(arrays: &[Vec<f32>]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for a in arrays {
        for v in a {
            for b in v.to_bits().to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::Variant;
    use crate::config::aurora;

    fn job(kernel: &'static str, size: usize, seed: u64) -> JobDesc {
        JobDesc { kernel, size, variant: Variant::Handwritten, threads: 8, seed, arrival: 0 }
    }

    /// Aurora with a TCDM small enough that mid-size kernels overflow it —
    /// the capacity-policy test bed.
    fn small_l1_cfg() -> crate::config::HeroConfig {
        let mut cfg = aurora();
        cfg.accel.l1_bytes = 16 * 1024;
        cfg
    }

    #[test]
    fn submit_returns_immediately_and_wait_completes() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        let h = s.submit(job("gemm", 12, 3));
        assert!(matches!(s.state(h), JobState::Queued));
        assert!(s.poll(h).is_none());
        let state = s.wait(h).unwrap();
        let JobState::Done(o) = state else { panic!("not done: {state:?}") };
        assert!(o.verified);
        assert!(o.end > o.start);
        assert!(s.poll(h).is_some());
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h = s.submit(job("nope", 12, 3));
        assert!(matches!(s.state(h), JobState::Rejected { .. }));
    }

    #[test]
    fn fifo_dispatches_in_submission_order() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        let specs =
            [job("gemm", 24, 1), job("atax", 24, 2), job("gemm", 12, 3), job("conv2d", 18, 4)];
        s.submit_all(&specs);
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn sjf_dispatches_shortest_first() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Sjf).with_batching(false);
        // Big job first, small job second: SJF must reorder.
        s.submit(job("gemm", 24, 1));
        s.submit(job("gemm", 12, 2));
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![1, 0]);
        // Both still complete (no starvation).
        assert!(s.state(JobHandle(0)).settled());
        assert!(s.state(JobHandle(1)).settled());
    }

    #[test]
    fn batching_chains_same_binary_jobs() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        for seed in 0..5 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 5);
        // One lowering shared by the whole batch, all chained on instance 0.
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.instances[0].jobs, 5);
        assert_eq!(r.instances[1].jobs, 0);
        // Exactly one job (the head) paid compile cycles.
        let paid: Vec<u64> = (0..5)
            .filter_map(|i| s.poll(JobHandle(i)).map(|o| o.compile_cycles))
            .collect();
        assert_eq!(paid.iter().filter(|&&c| c > 0).count(), 1);
    }

    #[test]
    fn cache_serves_repeat_dispatches_without_batching() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        for seed in 0..4 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.cache_hits, 3);
        // Cached dispatches are cheaper: only the first carried the charge.
        assert!(r.compile_cycles > 0);
        assert_eq!(
            r.compile_cycles,
            s.poll(JobHandle(0)).unwrap().compile_cycles
        );
    }

    #[test]
    fn pool_spreads_distinct_binaries() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        s.submit(job("gemm", 12, 1));
        s.submit(job("atax", 24, 2));
        s.submit(job("conv2d", 18, 3));
        s.submit(job("bicg", 24, 4));
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        assert!(r.instances[0].jobs > 0 && r.instances[1].jobs > 0, "{r}");
        // Spreading must beat the serial sum of occupancies.
        let serial: u64 = r.instances.iter().map(|i| i.busy_cycles).sum();
        assert!(r.makespan_cycles < serial);
    }

    #[test]
    fn capacity_policy_rejects_oversize() {
        let mut s =
            Scheduler::new(small_l1_cfg(), 1, Policy::Capacity(OversizeAction::Reject));
        // gemm N=64 handwritten keeps B (16 KiB) + strips resident: > 14 KiB
        // of user L1 on the shrunken config.
        let h = s.submit(job("gemm", 64, 1));
        let JobState::Rejected { reason } = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(
            reason.contains("hero_l1_capacity") || reason.contains("L1 overflow"),
            "{reason}"
        );
        // A job that fits is admitted and completes.
        let ok = s.submit(job("gemm", 16, 2));
        s.drain().unwrap();
        assert!(matches!(s.state(ok), JobState::Done(_)));
    }

    #[test]
    fn capacity_policy_splits_oversize_to_feasible_children() {
        let mut s = Scheduler::new(small_l1_cfg(), 2, Policy::Capacity(OversizeAction::Split));
        let h = s.submit(job("gemm", 64, 9));
        let JobState::Split { children } = s.state(h).clone() else {
            panic!("expected split, got {:?}", s.state(h));
        };
        assert_eq!(children.len(), 2);
        s.drain().unwrap();
        for c in &children {
            let JobState::Done(o) = s.state(*c) else {
                panic!("child not done: {:?}", s.state(*c));
            };
            assert!(o.verified);
        }
        // Children run the same kernel at feasible granularity.
        for c in &children {
            assert_eq!(s.jobs[c.0].spec.kernel, "gemm");
            assert_eq!(s.jobs[c.0].spec.size, 32);
        }
        let r = s.report();
        assert_eq!(r.split, 1);
        assert_eq!(r.completed, 2);
    }

    #[test]
    fn arrival_cycle_delays_dispatch() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let early = s.submit(job("gemm", 12, 1));
        let late = s.submit(JobDesc { arrival: 500_000_000, ..job("gemm", 12, 2) });
        s.drain().unwrap();
        let e = s.poll(early).unwrap();
        let l = s.poll(late).unwrap();
        assert!(e.end < 500_000_000, "early job should finish well before the late arrival");
        assert_eq!(l.start, 500_000_000, "late job must wait for its arrival cycle");
        let r = s.report();
        assert!(r.makespan_cycles > 500_000_000);
    }

    #[test]
    fn constrained_board_stalls_overlapping_jobs_but_not_pool1() {
        // Board bandwidth equal to one instance's NoC drain rate: a pool of
        // 2 must stall where windows overlap, and a pool of 1 must be
        // cycle-identical to the uncontended baseline.
        let jobs: Vec<JobDesc> = (0..4).map(|i| job("gemm", 24, i)).collect();
        let run = |pool: usize, board: BoardSpec| {
            let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
                .with_board(board)
                .with_batching(false)
                .with_verify(false);
            s.submit_all(&jobs);
            s.drain().unwrap();
            s.report()
        };
        let beat = aurora().dma_beat_bytes();
        let open1 = run(1, BoardSpec::uncontended());
        let capped1 = run(1, BoardSpec::with_bandwidth(beat));
        assert_eq!(open1.makespan_cycles, capped1.makespan_cycles);
        assert_eq!(open1.digest, capped1.digest);
        assert_eq!(capped1.dram_stall_cycles, 0);
        assert!(capped1.dram_bytes > 0);
        let capped2 = run(2, BoardSpec::with_bandwidth(beat));
        assert_eq!(capped2.digest, open1.digest, "contention must never change numerics");
        assert!(capped2.dram_stall_cycles > 0, "overlapping DMA windows must contend");
        assert!(
            capped2.makespan_cycles < capped1.makespan_cycles,
            "two instances still beat one despite contention"
        );
        // Conservation: the board ledger and the per-instance/per-job books
        // agree on every byte.
        let per_inst: u64 = capped2.instances.iter().map(|i| i.dram_bytes).sum();
        assert_eq!(capped2.dram_bytes, per_inst);
    }

    #[test]
    fn heterogeneous_pool_compiles_per_instance_config() {
        use crate::config::preset::with_dma_width;
        let base = aurora();
        let cfgs = vec![with_dma_width(&base, 64), with_dma_width(&base, 128)];
        let mut s = Scheduler::new_heterogeneous(cfgs, Policy::Fifo).with_batching(false);
        for seed in 0..4 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        assert_eq!(r.verify_failures, 0);
        assert_eq!(r.instances[0].dma_width_bits, 64);
        assert_eq!(r.instances[1].dma_width_bits, 128);
        // Both instances ran jobs, and each width needed its own lowering.
        assert!(r.instances.iter().all(|i| i.jobs > 0), "{r}");
        assert_eq!(r.cache_misses, 2);
    }

    #[test]
    fn digest_is_policy_and_pool_invariant() {
        let specs = [job("gemm", 12, 5), job("atax", 24, 6), job("gemm", 12, 7)];
        let mut digests = Vec::new();
        for (policy, pool, cache, batch) in [
            (Policy::Fifo, 1, true, true),
            (Policy::Sjf, 3, true, false),
            (Policy::Fifo, 2, false, true),
        ] {
            let mut s = Scheduler::new(aurora(), pool, policy)
                .with_cache(cache)
                .with_batching(batch);
            s.submit_all(&specs);
            s.drain().unwrap();
            let r = s.report();
            assert_eq!(r.completed, 3);
            assert_eq!(r.verify_failures, 0);
            digests.push(r.digest);
        }
        assert!(digests.windows(2).all(|w| w[0] == w[1]), "{digests:#x?}");
    }
}
