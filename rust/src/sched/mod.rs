//! Multi-accelerator offload scheduler (the "serve" subsystem).
//!
//! The paper's offload model (§2.3/§2.4) is one host driving one
//! accelerator through the mailbox: a single `#pragma omp target` region at
//! a time. This module scales that model to production-style traffic: a
//! host runtime that owns a **pool** of simulated accelerator instances and
//! drains a queue of offload **jobs** (workload × variant × size ×
//! configuration), asynchronously.
//!
//! Concept map back to the paper's §2.4 API:
//!
//! | HERO API (per transfer)          | Scheduler (per job)                     |
//! |----------------------------------|-----------------------------------------|
//! | `hero_memcpy_*_async` returns id | [`Scheduler::submit`] returns a handle  |
//! | `hero_memcpy_wait(id)`           | [`Scheduler::wait`] / [`Scheduler::poll`] |
//! | `hero_lN_capacity`               | capacity-aware admission ([`policy`])   |
//! | perf counters                    | [`report::ServeReport`] + [`crate::trace::SchedTrace`] |
//!
//! Pieces:
//!
//! * [`policy`] — pluggable dispatch order (FIFO, shortest-predicted-first
//!   on `compiler::metrics::predict_cycles`) and capacity-aware admission
//!   that rejects or splits jobs whose SPM footprint exceeds what
//!   `hero_l1_capacity` reports.
//! * [`cache`] — lowered-binary cache keyed on (kernel, variant, size,
//!   threads, config); same-kernel jobs batch onto one instance and
//!   amortize the simulated compile charge.
//! * [`pool`] — K accelerator instances (homogeneous or heterogeneous —
//!   e.g. mixed wide-NoC widths) as serializing resources on **one shared
//!   carrier-board DRAM**: each job's main-memory traffic is reserved on a
//!   cycle-accounted bandwidth ledger ([`crate::mem::BandwidthLedger`]),
//!   and oversubscription stretches occupancy windows — contention stall,
//!   surfaced per instance and in aggregate.
//! * [`place`] — board-aware placement: [`Placement::Pressure`] scores
//!   candidate slots by predicted finish time *including* DRAM-stall
//!   inflation from the board ledger, instead of blindly taking the
//!   earliest-free instance; bit-identical to earliest-free on an
//!   uncontended board.
//! * [`report`] — aggregate throughput/utilization/DRAM-stall reporting,
//!   including per-[`Priority`]-class p50/p95 turnaround.
//!
//! Jobs carry a QoS class ([`Priority`]): `High` jobs dispatch before any
//! arrived `Normal` work (strict tiers, policy order within a tier) and
//! reserve board DRAM as priority requests, reaching the bandwidth slice
//! [`BoardSpec::with_priority_headroom`] keeps free of normal traffic.
//!
//! Jobs come in two kinds sharing one queue: *named* synthetic workloads
//! ([`JobDesc`] — a registry name plus problem size, what `hero serve`
//! streams) and *arbitrary compiled kernels* ([`KernelJob`] — the kernel IR
//! plus its launch payload, submitted via [`Scheduler::submit_kernel`] or,
//! preferably, through a pooled [`crate::session::Session`]). Both flow
//! through the same policies, binary cache (content-hash keys for IR jobs),
//! batching and board model; kernel jobs return their output arrays in
//! [`JobOutcome::arrays`].
//!
//! Kernel jobs carry **cross-job dataflow**: an input may reference an
//! earlier job's output ([`PayloadSrc::Output`]) instead of snapshotting
//! data at submission. The scheduler holds such a consumer until its
//! producers settle (its *effective arrival* is the last producer's
//! finish — [`SchedEvent::DependencyReady`] marks the moment), retains the
//! demanded output arrays in an internal feed store, and materializes the
//! consumer's payload directly from it at dispatch — a chained pipeline
//! never round-trips data through the submitting host. A failed producer
//! cascades rejection to its queued consumers.
//!
//! With **SVM serving** enabled ([`Scheduler::with_svm`] — see
//! [`crate::svm`]), kernel jobs may name operands by *virtual address*
//! ([`PayloadSrc::Svm`]) in the board's shared VA space instead of carrying
//! bytes. Dispatch serves such operands through a per-board persistent
//! IOMMU shadow and a per-launch strategy — `pin` (zero-copy, TLB-costed
//! in-place access), `copy` (host DMA staging) or `auto` (exact predicted
//! cost decides per launch) — and every host-side byte (staging,
//! page-table-entry reads, mailbox descriptors) reserves board DRAM
//! through a dedicated host port, so placement and SJF see the host as
//! one more contender. Launch results write back into the shared space.
//!
//! The scheduler can **self-tune** (all three pieces off by default and
//! individually gated, so the default event sequence is untouched):
//! [`Scheduler::with_learning`] closes the measure→refine loop, blending
//! each settled job's measured device cycles into a deterministic
//! fixed-point EWMA ([`learn`]) that SJF ordering, pressure placement and
//! contention inflation then consult instead of the raw static predictor;
//! [`Scheduler::with_lookahead`] scores the next K policy-ranked jobs
//! *jointly* against the pool's slots (the [`place::choose_joint`] search
//! over the `SlotScore` matrix) instead of greedily placing the head; and
//! [`Scheduler::with_preemption`] lets an arrived High job displace
//! queued-but-assigned Normal batch followers back into the queue
//! ([`SchedEvent::Preempted`]) — never a kernel mid-flight, so numerics
//! and digests are untouched by construction. On top of those,
//! [`Scheduler::with_autotune`] closes the compiler↔scheduler loop:
//! AutoDMA dispatches search the tiling/double-buffering knob space
//! ([`crate::compiler::autotune`]) once per (kernel, footprint, width,
//! instance config) — memoized in a [`tune::TuneStore`] living next to the
//! binary cache — and compile the winning recipe's binary instead of the
//! single default, per instance config on a heterogeneous pool; with
//! learning also on, measured cycles re-rank the candidates.
//!
//! Every job executes on a *fresh* `Accel` (own SPM/IOMMU state) through
//! the shared offload core ([`crate::session::core`]), so results on a
//! homogeneous pool are bit-identical regardless of policy, pool size,
//! batching, caching, board bandwidth or SVM strategy — the scheduler and
//! the board model move *time*, never numerics (the SVM IOMMU shadow is a
//! pure cost engine; functional data lives in the host-side space). (A
//! heterogeneous pool may tile kernels differently per instance config,
//! which legitimately reorders float accumulation.)
//!
//! **Resilience** (all off by default; see [`crate::fault`] and
//! `fault/README.md`): [`Scheduler::with_faults`] arms a seeded
//! [`fault::FaultPlan`] that deterministically faults attempts (transient
//! kernel faults, DMA/NoC timeouts); [`Scheduler::with_watchdog`] arms a
//! per-job deadline (predicted cycles × multiplier, floored by each
//! kernel job's own `max_cycles` budget) that turns overruns into
//! deadline faults; [`Scheduler::with_retry`] bounds how many times a
//! faulted job re-enters the queue (exponential backoff in cycles,
//! priority/arrival/dataflow preserved). A faulted attempt occupies its
//! instance but never touches numerics: its result is discarded before
//! any digest, feed, SVM write-back or learning observation, so a stream
//! whose faults are all eventually retried successfully digests
//! bit-identically to the fault-free run (property-tested). With no plan
//! and no watchdog, every code path — and its event sequence — is
//! bit-identical to the pre-fault scheduler. `hero serve` (see
//! `main.rs`) and `benches/sched.rs` are the front-ends.

pub mod cache;
pub mod job;
pub mod learn;
pub mod place;
pub mod policy;
pub mod pool;
pub mod report;
pub mod tune;

pub use crate::fault::{FaultKind, FaultPlan};
pub use crate::svm::{SvmConfig, SvmMode};
pub use crate::workloads::synth::JobDesc;
pub use cache::BinaryCache;
pub use job::{KernelJob, PayloadSrc};
pub use place::Placement;
pub use policy::{OversizeAction, Policy, Priority};
pub use pool::{BoardSpec, InstancePool};
pub use report::{ClassReport, InstanceReport, ServeReport};

use crate::accel::Accel;
use crate::bench_harness::{self, run_lowered, Variant};
use crate::fault;
use crate::config::HeroConfig;
use crate::runtime::hero_api::{HeroApi, SpmLevel};
use crate::runtime::omp::OffloadResult;
use crate::trace::{Event, PerfCounters, SchedEvent, SchedTrace};
use crate::workloads::{self, Workload};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Smallest problem size the capacity policy will split down to.
pub const MIN_SPLIT_SIZE: usize = 8;

/// Most same-binary jobs chained onto one instance per dispatch.
pub const MAX_BATCH: usize = 8;

/// Per-job simulation budget.
const JOB_MAX_CYCLES: u64 = 10_000_000_000;

pub type JobId = usize;

/// Async completion handle returned by [`Scheduler::submit`] (the job-level
/// analogue of `hero_memcpy_*_async`'s transfer id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobHandle(pub JobId);

/// Completion record of one finished job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub instance: usize,
    /// Occupancy window on the instance's simulated timeline.
    pub start: u64,
    pub end: u64,
    /// Pure device cycles of the offload.
    pub device_cycles: u64,
    /// End-to-end cycles of the offload as the host observes them (device
    /// plus mailbox/driver overheads).
    pub total_cycles: u64,
    /// Simulated compile cycles charged to this job (0 when the binary was
    /// cached or a batch predecessor paid).
    pub compile_cycles: u64,
    /// DMA wide-path occupancy of the offload.
    pub dma_busy_cycles: u64,
    /// Bytes the job moved through the shared carrier-board DRAM.
    pub dma_bytes: u64,
    /// Cycles the job's occupancy window stretched waiting on the shared
    /// board DRAM (0 on an uncontended board).
    pub dram_stall_cycles: u64,
    /// FNV-1a digest over every output array's f32 bits.
    pub digest: u64,
    /// Host golden-model verification result (always true when the
    /// scheduler runs with verification off; arbitrary-kernel jobs have no
    /// registry golden model and report true).
    pub verified: bool,
    /// Final contents of the job's arrays. Kept for arbitrary-kernel jobs
    /// (their caller needs the outputs back — a session's wait moves them
    /// out via [`Scheduler::take_payload`]); named synthetic jobs skip the
    /// copy so long serve runs stay lean.
    pub arrays: Option<Vec<Vec<f32>>>,
    /// Device performance counters of the offload (arbitrary-kernel jobs
    /// only, same rationale as `arrays`).
    pub perf: Option<Box<PerfCounters>>,
}

/// Life cycle of a submitted job.
#[derive(Debug, Clone)]
pub enum JobState {
    /// Waiting in the queue.
    Queued,
    /// Refused: admission control, unknown kernel, compile or run error.
    Rejected { reason: String },
    /// Oversized job decomposed into the given sub-jobs (capacity policy).
    Split { children: Vec<JobHandle> },
    /// Evacuated off this board by the fleet router after a board failure
    /// and resubmitted on a surviving board — the router's fleet handle
    /// follows the job to its new board ([`crate::fleet::Router`]).
    Migrated,
    /// Ran to completion.
    Done(JobOutcome),
}

impl JobState {
    /// A job is *settled* once it can make no further progress; every
    /// handle must settle eventually (the no-starvation invariant).
    pub fn settled(&self) -> bool {
        !matches!(self, JobState::Queued)
    }
}

/// What a queued job runs: a registry workload or an arbitrary kernel.
#[derive(Debug, Clone)]
enum JobSpec {
    Named(JobDesc),
    Kernel(Arc<KernelJob>),
    /// A kernel job whose payload (IR + input snapshots) has been released
    /// after settling, so long `hero serve` runs stop growing memory — the
    /// metadata a settled job still needs lives on the [`JobRecord`].
    Retired,
}

/// Same-binary identity: jobs with equal batch keys share one lowered
/// binary (per instance config) and may chain onto one dispatch. Thread
/// counts are the *raw* requested values, not clamped to any config: a
/// batch compiles once with the head's threads, so equal raw counts are
/// what guarantees the followers get their own lowering on every instance
/// of a heterogeneous pool (clamping to the base config would batch
/// 8- and 12-thread jobs together and run the followers with the head's
/// binary on a wider instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BatchKey {
    Named { kernel: &'static str, size: usize, variant: Variant, threads: u32 },
    Ir { content: u64, threads: u32 },
}

struct JobRecord {
    spec: JobSpec,
    batch: BatchKey,
    /// Cycle the job becomes available for dispatch (kept here so settled
    /// jobs can release their [`JobSpec`] payload). A job with producers
    /// additionally waits for them: see [`Scheduler::effective_arrival`].
    arrival: u64,
    /// QoS class: dispatch tier + board-DRAM reservation class.
    priority: Priority,
    /// Producers this job waits on (dataflow inputs + explicit `after`
    /// edges), as job ids — always earlier ids, so the graph is acyclic by
    /// construction.
    after: Vec<JobId>,
    /// Whether this job's demand on its producers' outputs has been
    /// registered in the feed store (set once the job is admitted to the
    /// queue; rejection before admission must not unbalance the refcounts).
    registered: bool,
    /// Faulted dispatch attempts so far (0 until the job first faults —
    /// the retry counter bounded by [`Scheduler::with_retry`]).
    attempts: u32,
    /// Earliest cycle a retried job may dispatch (exponential backoff;
    /// 0 for never-faulted jobs — floors [`Scheduler::effective_arrival`]).
    not_before: u64,
    /// Memoized cycle prediction — computed once at submit, *refreshed in
    /// place* when online learning refines the job's key, and read
    /// everywhere a scheduling decision needs it ([`Policy::pick`],
    /// [`place::scores`], capacity inflation). Never recomputed per
    /// decision.
    predicted: u64,
    /// The static model's original figure, frozen at submit — the
    /// refinement baseline and the "before learning" term of the
    /// prediction-error report.
    predicted_static: u64,
    /// Refinement identity, memoized at submit (learning runs only).
    learn_key: Option<learn::LearnKey>,
    /// Static DMA-cycle proxy (SJF contention-aware inflation).
    predicted_dma: u64,
    /// Byte footprint across the board DRAM (placement scoring).
    dma_bytes: u64,
    state: JobState,
}

/// One producer output array retained for queued consumers, with the
/// number of consumers still to feed (dropped at zero — the feed store
/// never outlives its demand).
struct FeedSlot {
    data: Vec<f32>,
    consumers: usize,
}

/// The offload scheduler: job queue + policy + binary cache + instance pool.
pub struct Scheduler {
    cfg: HeroConfig,
    policy: Policy,
    placement: Placement,
    pool: InstancePool,
    cache: BinaryCache,
    batching: bool,
    verify: bool,
    /// What `hero_l1_capacity` reports for a cluster of this configuration.
    l1_capacity: u32,
    jobs: Vec<JobRecord>,
    queue: Vec<JobId>,
    /// Producer outputs retained for not-yet-dispatched consumers, keyed
    /// by (producer job, output array index). Populated when a demanded
    /// producer completes; drained as consumers dispatch — this is what
    /// lets [`Scheduler::take_payload`] move a producer's outcome out
    /// without starving its queued consumers.
    feeds: HashMap<(JobId, usize), FeedSlot>,
    /// Demand registered before the producer completed: (producer, index)
    /// -> number of queued consumers to feed at its completion.
    feed_demand: HashMap<(JobId, usize), usize>,
    /// Reverse edge index: producer -> consumer job ids, in submission
    /// order. Completion/rejection handling looks up exactly the affected
    /// consumers instead of scanning the whole jobs table (edge-free
    /// streams never touch it).
    consumers_of: HashMap<JobId, Vec<JobId>>,
    /// Shared-virtual-memory serving state ([`Scheduler::with_svm`]):
    /// the board VA space, its persistent IOMMU cost shadow and the
    /// configured strategy. `None` (the default) leaves every pre-SVM code
    /// path — and its event sequence — untouched.
    svm: Option<crate::svm::SvmState>,
    /// Online prediction refinement ([`Scheduler::with_learning`]). `None`
    /// (the default) leaves every static-prediction code path untouched.
    learn: Option<learn::LearnStore>,
    /// Joint dispatch window ([`Scheduler::with_lookahead`]): how many
    /// policy-ranked head candidates are scored jointly against the pool.
    /// 1 (the default) is the classic greedy head dispatch, bit-identical
    /// to the pre-lookahead scheduler.
    lookahead: usize,
    /// Whether arrived High jobs may displace queued-but-assigned Normal
    /// batch followers ([`Scheduler::with_preemption`]; off by default).
    preempt: bool,
    /// Displacement counts by the *displaced* job's class
    /// (`[Normal, High]`).
    preempted: [u64; 2],
    /// Whether AutoDMA dispatches pick a tuned variant
    /// ([`Scheduler::with_autotune`]; off by default, leaving every
    /// pre-autotune code path — and its event sequence — untouched).
    autotune: bool,
    /// Memoized tuning searches (cheap and empty while autotuning is off).
    tune: tune::TuneStore,
    /// Injected fault schedule ([`Scheduler::with_faults`]). `None` (the
    /// default) leaves every pre-fault code path — and its event
    /// sequence — untouched.
    faults: Option<fault::FaultPlan>,
    /// Most retries a faulted job gets before failing permanently
    /// ([`Scheduler::with_retry`]; 0 = first fault is final).
    retry_limit: u32,
    /// Watchdog deadline multiplier over a job's predicted cycles
    /// ([`Scheduler::with_watchdog`]; `None` = watchdog off).
    watchdog: Option<u64>,
    /// Faults seen, by [`fault::FaultKind::index`]:
    /// `[transient, timeout, deadline]`.
    fault_counts: [u64; 3],
    /// Retry dispatch attempts issued.
    retries: u64,
    /// Jobs that failed permanently to a fault (retries exhausted or a
    /// non-retryable deadline overrun).
    fault_failures: u64,
    /// Jobs the fleet router evacuated off this board after a board
    /// failure ([`Scheduler::mark_migrated`]).
    migrated: u64,
    pub trace: SchedTrace,
}

/// What a tuned dispatch remembers until its batch members complete:
/// enough to file each measured run under the chosen variant's own
/// refinement key ([`tune::variant_learn_key`]).
struct TunedRun {
    key: tune::TuneKey,
    variant: crate::compiler::TunedVariant,
    /// The variant's static prediction (the observation seed).
    static_predicted: u64,
    teams: u32,
}

impl Scheduler {
    /// `pool_size` identical instances of `cfg` on the board the config
    /// describes (`BoardSpec::from_config`).
    pub fn new(cfg: HeroConfig, pool_size: usize, policy: Policy) -> Self {
        assert!(pool_size >= 1, "pool needs at least one instance");
        Self::new_heterogeneous(vec![cfg; pool_size], policy)
    }

    /// One instance per config — a heterogeneous pool (e.g. mixed 32/64/128
    /// bit wide-NoC instances from [`crate::config::preset::with_dma_width`]).
    /// The first config is the *base*: it decides the board DRAM bandwidth,
    /// admission thresholds use the most constrained instance, and SJF
    /// predictions use the base NoC width.
    pub fn new_heterogeneous(cfgs: Vec<HeroConfig>, policy: Policy) -> Self {
        assert!(!cfgs.is_empty(), "pool needs at least one instance");
        // Ask the HERO API itself, on a throwaway instance per distinct
        // config, how much user L1 a cluster offers — the admission
        // threshold is the runtime's own answer (the minimum across the
        // pool, so admitted jobs fit every instance), not a re-derivation.
        let mut seen: Vec<String> = Vec::new();
        let mut l1_capacity = u32::MAX;
        for c in &cfgs {
            if !seen.contains(&c.name) {
                seen.push(c.name.clone());
                let accel = Accel::new(c.clone(), 1 << 20);
                let api = HeroApi::new(&accel);
                l1_capacity = l1_capacity.min(api.capacity(SpmLevel::L1(0)));
            }
        }
        let cfg = cfgs[0].clone();
        let board = BoardSpec::from_config(&cfg);
        Scheduler {
            pool: InstancePool::heterogeneous(cfgs, board),
            cache: BinaryCache::new(true),
            batching: true,
            verify: true,
            l1_capacity,
            jobs: Vec::new(),
            queue: Vec::new(),
            feeds: HashMap::new(),
            feed_demand: HashMap::new(),
            consumers_of: HashMap::new(),
            svm: None,
            learn: None,
            lookahead: 1,
            preempt: false,
            preempted: [0, 0],
            autotune: false,
            tune: tune::TuneStore::new(),
            faults: None,
            retry_limit: 0,
            watchdog: None,
            fault_counts: [0; 3],
            retries: 0,
            fault_failures: 0,
            migrated: 0,
            trace: SchedTrace::new(),
            cfg,
            policy,
            placement: Placement::EarliestFree,
        }
    }

    /// Override the shared carrier-board DRAM spec (must precede
    /// submissions; contention studies and `hero serve --board-bw`).
    pub fn with_board(mut self, board: BoardSpec) -> Self {
        self.pool.set_board(board);
        self
    }

    /// Choose the placement engine (must precede submissions — placements
    /// other than earliest-free need per-job predictions computed at
    /// submit time).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_placement after submissions");
        self.placement = placement;
        self
    }

    /// Disable/enable the lowered-binary cache (on by default).
    pub fn with_cache(mut self, on: bool) -> Self {
        self.cache = BinaryCache::new(on);
        self
    }

    /// Disable/enable same-binary batching (on by default).
    pub fn with_batching(mut self, on: bool) -> Self {
        self.batching = on;
        self
    }

    /// Disable/enable per-job golden-model verification (on by default).
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Enable online cycle-prediction refinement (off by default; must
    /// precede submissions — learning changes what submit memoizes). Every
    /// settled job's measured device cycles feed a deterministic integer
    /// fixed-point EWMA keyed by (content × elems × width × config), and
    /// SJF ordering, pressure placement and contention inflation read the
    /// refined figure. See [`learn`].
    pub fn with_learning(mut self, on: bool) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_learning after submissions");
        self.learn = on.then(learn::LearnStore::new);
        self
    }

    /// Set the joint dispatch window (must precede submissions): score the
    /// next `k` policy-ranked head candidates *jointly* against the pool's
    /// slots instead of greedily placing the single head. `k <= 1` (the
    /// default) keeps the classic greedy dispatch bit-identical.
    pub fn with_lookahead(mut self, k: usize) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_lookahead after submissions");
        self.lookahead = k.max(1);
        self
    }

    /// Allow arrived High jobs to displace queued-but-assigned Normal
    /// batch followers back into the queue (off by default). Displacement
    /// happens strictly *between* member executions — never mid-kernel —
    /// so results and digests are untouched; the displaced job keeps its
    /// arrival stamp and owes no compile charge (the binary stays cached),
    /// the "credit for cycles not yet burned".
    pub fn with_preemption(mut self, on: bool) -> Self {
        self.preempt = on;
        self
    }

    /// Enable schedule-time AutoDMA tuning (must precede submissions):
    /// every AutoDMA dispatch consults the [`tune::TuneStore`] — searching
    /// the tiling/double-buffering/variant space on first sight of a
    /// `(kernel, size, width, config)` key ([`crate::compiler::autotune`])
    /// — and compiles the winning recipe instead of the default one. The
    /// tuned request hashes to its own content key, so tuned and untuned
    /// submissions never share a cache row or a batch; with tuning off, no
    /// key, event or decision changes.
    pub fn with_autotune(mut self, on: bool) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_autotune after submissions");
        self.autotune = on;
        self
    }

    /// Arm a deterministic fault-injection plan (must precede submissions
    /// — instance faults price timeout occupancy off predictions, which
    /// changes what submit memoizes). Faulted attempts occupy their
    /// instance but discard their result; pair with
    /// [`Scheduler::with_retry`] to make them survivable. See
    /// [`crate::fault`].
    pub fn with_faults(mut self, plan: fault::FaultPlan) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_faults after submissions");
        self.faults = Some(plan);
        self
    }

    /// Bound how many retries a faulted job gets before it fails
    /// permanently (0, the default, makes the first fault final). Retries
    /// re-enter the queue as ready jobs — priority, arrival stamp and
    /// dataflow edges intact — after an exponential backoff in cycles
    /// ([`fault::backoff_cycles`]).
    pub fn with_retry(mut self, attempts: u32) -> Self {
        self.retry_limit = attempts;
        self
    }

    /// Arm the watchdog (must precede submissions — deadlines are priced
    /// off predictions): a job whose measured cycles exceed `mult` × its
    /// predicted cycles — or whose simulation budget
    /// ([`KernelJob::max_cycles`]) runs out — faults with a deterministic,
    /// non-retryable deadline overrun instead of completing.
    pub fn with_watchdog(mut self, mult: u64) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_watchdog after submissions");
        self.watchdog = Some(mult.max(1));
        self
    }

    /// Whether fault injection or the watchdog is armed.
    pub fn resilience_enabled(&self) -> bool {
        self.faults.is_some() || self.watchdog.is_some()
    }

    /// The configured retry bound.
    pub fn retry_limit(&self) -> u32 {
        self.retry_limit
    }

    /// Whether online prediction refinement is enabled.
    pub fn learning_enabled(&self) -> bool {
        self.learn.is_some()
    }

    /// The joint dispatch window (1 = greedy head dispatch).
    pub fn lookahead_window(&self) -> usize {
        self.lookahead
    }

    /// Whether priority preemption is enabled.
    pub fn preemption_enabled(&self) -> bool {
        self.preempt
    }

    /// Whether schedule-time AutoDMA tuning is enabled.
    pub fn autotune_enabled(&self) -> bool {
        self.autotune
    }

    /// Enable shared-virtual-memory serving (must precede submissions):
    /// jobs may carry [`PayloadSrc::Svm`] operands, served under
    /// `cfg.mode` (pin / copy / auto, overridable per job), and the host
    /// becomes a modeled traffic source — its staging, page-table-entry
    /// reads and mailbox descriptors reserve board DRAM at `cfg.host_bw`
    /// bytes/cycle through the pool's host port. See [`crate::svm`].
    pub fn with_svm(mut self, cfg: crate::svm::SvmConfig) -> Self {
        debug_assert!(self.jobs.is_empty(), "with_svm after submissions");
        self.pool.enable_host_port(cfg.host_bw);
        self.svm = Some(crate::svm::SvmState::new(cfg, &self.cfg));
        self
    }

    /// Whether SVM serving is enabled.
    pub fn svm_enabled(&self) -> bool {
        self.svm.is_some()
    }

    /// Allocate a shared buffer holding `data` in the board's SVM space
    /// and return its virtual address (what [`PayloadSrc::Svm`] names).
    /// Allocation is host-side bookkeeping — no simulated cycles.
    pub fn svm_alloc_f32(&mut self, data: Vec<f32>) -> Result<u64> {
        match self.svm.as_mut() {
            Some(s) => Ok(s.space.alloc_f32(data)),
            None => bail!("SVM is not enabled on this scheduler (Scheduler::with_svm)"),
        }
    }

    /// Read a shared buffer back (the host observing offload results).
    /// `None` for an unknown VA or when SVM serving is disabled.
    pub fn svm_read_f32(&self, va: u64) -> Option<Vec<f32>> {
        self.svm.as_ref()?.space.read(va)
    }

    /// Validate a job's SVM operands at submission: they require SVM
    /// serving, and every VA must name an allocated buffer large enough
    /// for the claimed element count (an undersized view would slice out
    /// of bounds at dispatch).
    fn check_svm(&self, kjob: &KernelJob) -> std::result::Result<(), String> {
        let Some(svm) = self.svm.as_ref() else {
            if kjob.inputs.iter().any(|s| matches!(s, PayloadSrc::Svm { .. })) {
                return Err(
                    "job carries SVM operand(s) but SVM serving is not enabled \
                     (Scheduler::with_svm)"
                        .into(),
                );
            }
            return Ok(());
        };
        for src in &kjob.inputs {
            let PayloadSrc::Svm { va, elems } = src else { continue };
            match svm.space.elems(*va) {
                None => {
                    return Err(format!("SVM operand va {va:#x} is not an allocated buffer"))
                }
                Some(have) if have < *elems => {
                    return Err(format!(
                        "SVM operand va {va:#x} holds {have} element(s), job expects {elems}"
                    ))
                }
                Some(_) => {}
            }
        }
        Ok(())
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Whether submissions must compute static predictions: SJF orders on
    /// them, pressure placement scores slots with them, lookahead ranks
    /// candidates with them, and online learning refines (and error-scores)
    /// them. Plain earliest-free FIFO streams skip the workload build
    /// entirely.
    fn needs_predictions(&self) -> bool {
        matches!(self.policy, Policy::Sjf)
            || self.placement == Placement::Pressure
            || self.learn.is_some()
            || self.lookahead > 1
            // Resilience prices timeout occupancy and watchdog deadlines
            // off the predicted cycles.
            || self.watchdog.is_some()
            || self.faults.as_ref().is_some_and(|p| p.has_instance_faults())
    }

    /// Bytes of kernel-job input snapshots the scheduler still retains,
    /// plus producer outputs held in the feed store for queued consumers.
    /// Settled jobs release their payloads (the internal `Retired` spec)
    /// and dispatched consumers drain their feeds, so after a drain this
    /// is 0 — the leak guard for long `hero serve` runs.
    pub fn retained_input_bytes(&self) -> u64 {
        let snapshots: u64 = self
            .jobs
            .iter()
            .map(|r| match &r.spec {
                JobSpec::Kernel(k) => k.inline_input_bytes(),
                _ => 0,
            })
            .sum();
        let feeds: u64 = self.feeds.values().map(|f| f.data.len() as u64 * 4).sum();
        snapshots + feeds
    }

    /// Release a settled kernel job's payload (input snapshots + IR). The
    /// outcome keeps everything a caller can still ask for; named jobs
    /// carry no payload to release.
    fn release_payload(&mut self, id: JobId) {
        if matches!(self.jobs[id].spec, JobSpec::Kernel(_)) {
            self.jobs[id].spec = JobSpec::Retired;
        }
    }

    /// A queued job is *ready* once every producer has settled as `Done`
    /// (a failed producer cascades rejection instead, so queued jobs only
    /// ever wait on queued-or-done producers).
    fn ready(&self, id: JobId) -> bool {
        self.jobs[id].after.iter().all(|&p| matches!(self.jobs[p].state, JobState::Done(_)))
    }

    /// Dependency-aware arrival: a job cannot start before its declared
    /// arrival cycle *or* its last producer's finish — the readiness rule
    /// the policy tiers, the placement engine and the pool occupancy all
    /// score with. A retried job is additionally floored by its backoff
    /// (`not_before`, 0 for never-faulted jobs).
    fn effective_arrival(&self, id: JobId) -> u64 {
        let deps = self.jobs[id]
            .after
            .iter()
            .map(|&p| match &self.jobs[p].state {
                JobState::Done(o) => o.end,
                _ => u64::MAX,
            })
            .max()
            .unwrap_or(0);
        self.jobs[id].arrival.max(deps).max(self.jobs[id].not_before)
    }

    /// Validate a kernel job's dataflow/ordering edges at submission:
    /// every edge must point at an *earlier* job (acyclic by construction)
    /// that has not failed, and an output reference must name an existing
    /// array of a kernel producer with the element count the edge claims.
    fn check_dataflow(&self, id: JobId, kjob: &KernelJob) -> std::result::Result<(), String> {
        for h in &kjob.after {
            if h.0 >= id {
                return Err(format!("ordering edge to job {} which is not an earlier job", h.0));
            }
            match &self.jobs[h.0].state {
                JobState::Rejected { .. } => {
                    return Err(format!("producer job {} was rejected", h.0))
                }
                JobState::Split { .. } => return Err(format!("producer job {} was split", h.0)),
                JobState::Migrated => {
                    return Err(format!("producer job {} was migrated off this board", h.0))
                }
                JobState::Queued | JobState::Done(_) => {}
            }
        }
        for src in &kjob.inputs {
            let PayloadSrc::Output { producer, index, elems } = src else { continue };
            if producer.0 >= id {
                return Err(format!(
                    "dataflow edge to job {} which is not an earlier job",
                    producer.0
                ));
            }
            let rec = &self.jobs[producer.0];
            let have = match &rec.state {
                JobState::Queued => {
                    let JobSpec::Kernel(p) = &rec.spec else {
                        return Err(format!(
                            "producer job {} is not a kernel job (named jobs keep no payload)",
                            producer.0
                        ));
                    };
                    if *index >= p.inputs.len() {
                        return Err(format!(
                            "producer job {} has {} array(s), no output {index}",
                            producer.0,
                            p.inputs.len()
                        ));
                    }
                    p.inputs[*index].elems()
                }
                JobState::Done(o) => {
                    let Some(arrays) = &o.arrays else {
                        // Completed named jobs never retain outputs — say
                        // so, instead of implying an ordering mistake.
                        return Err(if matches!(rec.spec, JobSpec::Named(_)) {
                            format!(
                                "producer job {} is not a kernel job (named jobs keep \
                                 no payload)",
                                producer.0
                            )
                        } else {
                            format!(
                                "producer job {}'s outputs were already released",
                                producer.0
                            )
                        });
                    };
                    if *index >= arrays.len() {
                        return Err(format!(
                            "producer job {} has {} array(s), no output {index}",
                            producer.0,
                            arrays.len()
                        ));
                    }
                    arrays[*index].len()
                }
                JobState::Rejected { .. } => {
                    return Err(format!("producer job {} was rejected", producer.0))
                }
                JobState::Split { .. } => {
                    return Err(format!("producer job {} was split", producer.0))
                }
                JobState::Migrated => {
                    return Err(format!("producer job {} was migrated off this board", producer.0))
                }
            };
            if have != *elems {
                return Err(format!(
                    "dataflow edge expects {elems} element(s) but producer job {} \
                     output {index} holds {have}",
                    producer.0
                ));
            }
        }
        Ok(())
    }

    /// Register an admitted consumer's demand on its producers' outputs:
    /// already-done producers get their array cloned into the feed store
    /// right away; queued ones get a demand mark that
    /// [`Scheduler::retain_demanded_outputs`] converts at completion.
    fn register_dataflow(&mut self, id: JobId, kjob: &KernelJob) {
        self.jobs[id].registered = true;
        for src in &kjob.inputs {
            let PayloadSrc::Output { producer, index, .. } = src else { continue };
            let key = (producer.0, *index);
            if matches!(self.jobs[producer.0].state, JobState::Done(_)) {
                if let Some(f) = self.feeds.get_mut(&key) {
                    f.consumers += 1;
                } else {
                    let JobState::Done(o) = &self.jobs[producer.0].state else {
                        unreachable!("matched above")
                    };
                    let arrays = o.arrays.as_ref().expect("validated by check_dataflow");
                    self.feeds
                        .insert(key, FeedSlot { data: arrays[*index].clone(), consumers: 1 });
                }
            } else {
                *self.feed_demand.entry(key).or_insert(0) += 1;
            }
        }
    }

    /// Withdraw a job's demand on its producers' outputs — on dispatch
    /// (the feed was consumed) and on rejection (it never will be). Feed
    /// slots are dropped when their last consumer withdraws.
    fn unregister_dataflow(&mut self, id: JobId) {
        if !self.jobs[id].registered {
            return;
        }
        self.jobs[id].registered = false;
        let JobSpec::Kernel(kjob) = self.jobs[id].spec.clone() else { return };
        for src in &kjob.inputs {
            let PayloadSrc::Output { producer, index, .. } = src else { continue };
            let key = (producer.0, *index);
            if let Some(n) = self.feed_demand.get_mut(&key) {
                *n -= 1;
                if *n == 0 {
                    self.feed_demand.remove(&key);
                }
            } else if let Some(f) = self.feeds.get_mut(&key) {
                f.consumers -= 1;
                if f.consumers == 0 {
                    self.feeds.remove(&key);
                }
            }
        }
    }

    /// A demanded producer just completed: clone the demanded output
    /// arrays into the feed store (before any caller can
    /// [`Scheduler::take_payload`] them away).
    fn retain_demanded_outputs(&mut self, id: JobId) {
        let keys: Vec<(JobId, usize)> =
            self.feed_demand.keys().copied().filter(|k| k.0 == id).collect();
        for key in keys {
            let count = self.feed_demand.remove(&key).expect("collected above");
            let JobState::Done(o) = &self.jobs[id].state else {
                unreachable!("retain_demanded_outputs runs right after completion")
            };
            let arrays = o.arrays.as_ref().expect("kernel producers keep their outputs");
            self.feeds.insert(key, FeedSlot { data: arrays[key.1].clone(), consumers: count });
        }
    }

    /// Surface consumers whose last producer just settled in the trace.
    /// The recorded cycle is the consumer's *effective arrival* — not
    /// necessarily this producer's end: with producers on several
    /// instances (or a declared future arrival) the constraint that
    /// actually gates the consumer is the latest of them.
    fn announce_ready(&mut self, producer: JobId) {
        let Some(consumers) = self.consumers_of.get(&producer) else { return };
        for &c in consumers {
            if matches!(self.jobs[c].state, JobState::Queued) && self.ready(c) {
                let at = self.effective_arrival(c);
                self.trace.record(SchedEvent::DependencyReady { job: c, producer, at });
            }
        }
    }

    /// A failed job takes its queued consumers down with it — their input
    /// will never exist. Recursion handles chains.
    fn cascade_reject(&mut self, failed: JobId) {
        let consumers: Vec<JobId> = match self.consumers_of.get(&failed) {
            Some(v) => v
                .iter()
                .copied()
                .filter(|&c| matches!(self.jobs[c].state, JobState::Queued))
                .collect(),
            None => return,
        };
        for c in consumers {
            self.queue.retain(|&q| q != c);
            self.reject(c, format!("producer job {failed} failed"));
        }
    }

    /// The pool's base platform configuration (instance 0's).
    pub fn config(&self) -> &HeroConfig {
        &self.cfg
    }

    /// The instance pool, read-only — the fleet router scores candidate
    /// boards against each pool's occupancy without disturbing it.
    pub fn pool(&self) -> &InstancePool {
        &self.pool
    }

    /// The binary cache, read-only — the fleet router's affinity scoring
    /// asks which boards already hold a kernel's lowered binary
    /// ([`cache::BinaryCache::contains`]/[`cache::BinaryCache::contains_ir`]).
    pub fn cache(&self) -> &BinaryCache {
        &self.cache
    }

    /// Jobs submitted so far (including rejected/split ones).
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Jobs still waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Current state of a handle, or `None` for a handle this scheduler
    /// never issued (a foreign or stale `JobHandle` must not panic).
    pub fn state(&self, h: JobHandle) -> Option<&JobState> {
        self.jobs.get(h.0).map(|r| &r.state)
    }

    /// Completion record, if the job has finished (non-blocking probe — the
    /// `hero_memcpy` test-for-completion analogue). `None` for unfinished
    /// jobs and for foreign handles alike.
    pub fn poll(&self, h: JobHandle) -> Option<&JobOutcome> {
        match &self.jobs.get(h.0)?.state {
            JobState::Done(o) => Some(o),
            _ => None,
        }
    }

    /// Move a finished kernel job's payload (output arrays + perf counters)
    /// out of the scheduler, leaving the outcome as lean as a named job's —
    /// this is how a pooled [`crate::session::Session`] collects results
    /// without the scheduler retaining every launch's data forever. `None`
    /// for unfinished/foreign handles, named jobs, or an already-taken
    /// payload. Always safe with dataflow: outputs demanded by queued
    /// consumers are cloned into the feed store at completion, so taking
    /// the payload cannot starve a chained launch.
    pub fn take_payload(
        &mut self,
        h: JobHandle,
    ) -> Option<(Vec<Vec<f32>>, Option<Box<PerfCounters>>)> {
        match &mut self.jobs.get_mut(h.0)?.state {
            JobState::Done(o) => {
                let arrays = o.arrays.take()?;
                Some((arrays, o.perf.take()))
            }
            _ => None,
        }
    }

    /// Submit one job; returns immediately with its handle.
    pub fn submit(&mut self, desc: JobDesc) -> JobHandle {
        let id = self.jobs.len();
        self.trace.record(SchedEvent::Submitted { job: id, priority: desc.priority });
        let eff_threads = desc.threads.min(self.cfg.accel.cores_per_cluster as u32);
        self.jobs.push(JobRecord {
            spec: JobSpec::Named(desc),
            batch: BatchKey::Named {
                kernel: desc.kernel,
                size: desc.size,
                variant: desc.variant,
                threads: desc.threads,
            },
            arrival: desc.arrival,
            priority: desc.priority,
            after: Vec::new(),
            registered: false,
            attempts: 0,
            not_before: 0,
            predicted: 0,
            predicted_static: 0,
            learn_key: None,
            predicted_dma: 0,
            dma_bytes: 0,
            state: JobState::Queued,
        });
        if !workloads::known(desc.kernel) {
            self.reject(id, format!("unknown kernel {:?}", desc.kernel));
            return JobHandle(id);
        }
        // Only SJF ordering and pressure placement read predictions, and
        // only capacity admission needs the binary, so earliest-free FIFO
        // submission skips building the workload entirely — and a policy
        // that needs both shares one build. Threads are clamped to the
        // cluster width exactly as compilation will clamp them
        // (`cache::key_for`), so inflated thread counts cannot deflate a
        // job's prediction relative to how it executes.
        let admission = self.policy.admission();
        let w = (self.needs_predictions() || admission.is_some())
            .then(|| desc.workload().expect("known kernels build"));
        if self.needs_predictions() {
            let w = w.as_ref().expect("built above");
            let bytes = policy::job_bytes(w);
            let stat = policy::predict_job(w, desc.variant, eff_threads);
            self.jobs[id].predicted = stat;
            self.jobs[id].predicted_static = stat;
            self.jobs[id].predicted_dma =
                policy::predict_dma_cycles(bytes, self.cfg.dma_beat_bytes());
            self.jobs[id].dma_bytes = bytes;
            // Learning: memoize the refinement key and start from the
            // refined figure right away — a job submitted after its key has
            // measurements never dispatches on the stale static estimate.
            if let Some(learn) = self.learn.as_ref() {
                let key = learn::LearnKey {
                    content: learn::named_content(desc.kernel, desc.variant.label(), desc.size),
                    elems: bytes / 4,
                    threads: eff_threads,
                    teams: 1,
                    config: self.cfg.name.clone(),
                };
                self.jobs[id].predicted = learn.refine(&key, stat);
                self.jobs[id].learn_key = Some(key);
            }
        }
        if let Some(action) = admission {
            let w = w.as_ref().expect("built above");
            match self.spm_footprint(w, desc) {
                Ok(bytes) if bytes <= self.l1_capacity => {}
                Ok(bytes) => {
                    let reason = format!(
                        "SPM footprint {bytes} B exceeds hero_l1_capacity {} B",
                        self.l1_capacity
                    );
                    self.oversize(id, desc, action, reason);
                    return JobHandle(id);
                }
                Err(e) if crate::compiler::lower::is_l1_overflow(&e) => {
                    self.oversize(id, desc, action, e.to_string());
                    return JobHandle(id);
                }
                Err(e) => {
                    self.reject(id, format!("compile failed: {e}"));
                    return JobHandle(id);
                }
            }
        }
        self.queue.push(id);
        JobHandle(id)
    }

    /// Submit a whole stream.
    pub fn submit_all(&mut self, descs: &[JobDesc]) -> Vec<JobHandle> {
        descs.iter().map(|d| self.submit(*d)).collect()
    }

    /// Submit an arbitrary compiled-kernel job; returns immediately with
    /// its handle. The job flows through the same policies, binary cache,
    /// batching and shared-DRAM board model as named synthetic jobs; its
    /// outputs come back in [`JobOutcome::arrays`].
    pub fn submit_kernel(&mut self, kjob: KernelJob) -> JobHandle {
        let id = self.jobs.len();
        self.trace.record(SchedEvent::Submitted { job: id, priority: kjob.priority });
        // A scheduler-wide `--autotune` promotes every AutoDMA submission to
        // a tuned request, exactly as if the job had asked itself: the
        // request key (and so the batch identity) diverges from the untuned
        // stream's before any batching or admission decision is made.
        let kjob = if self.autotune && kjob.autodma && !kjob.autotune {
            let mut kjob = kjob;
            kjob.autotune = true;
            kjob
        } else {
            kjob
        };
        let content = kjob.content_key();
        let eff_threads = kjob.threads.min(self.cfg.accel.cores_per_cluster as u32);
        let after: Vec<JobId> = kjob.producers().iter().map(|h| h.0).collect();
        // Reverse edge index: each (deduplicated) producer learns about
        // this consumer, so completion/rejection handling never scans.
        for &p in &after {
            if p < id {
                self.consumers_of.entry(p).or_default().push(id);
            }
        }
        let kjob = Arc::new(kjob);
        self.jobs.push(JobRecord {
            spec: JobSpec::Kernel(kjob.clone()),
            batch: BatchKey::Ir { content, threads: kjob.threads },
            arrival: kjob.arrival,
            priority: kjob.priority,
            after,
            registered: false,
            attempts: 0,
            not_before: 0,
            predicted: 0,
            predicted_static: 0,
            learn_key: None,
            predicted_dma: 0,
            dma_bytes: kjob.input_bytes(),
            state: JobState::Queued,
        });
        // Shape checks up front (shared with the session's LaunchBuilder —
        // see `job::validate_shape`): a mismatched or undersized payload
        // would otherwise fail deep inside the marshalling path of whatever
        // instance it lands on, or worse, read past its buffers. Dataflow
        // edges validate by element count — their data does not exist yet.
        if let Err(reason) = kjob.validate() {
            self.reject(id, reason);
            return JobHandle(id);
        }
        if let Err(reason) = self.check_dataflow(id, &kjob) {
            self.reject(id, reason);
            return JobHandle(id);
        }
        if let Err(reason) = self.check_svm(&kjob) {
            self.reject(id, reason);
            return JobHandle(id);
        }
        if self.needs_predictions() {
            let stat =
                policy::predict_kernel_job(&kjob.kernel, kjob.autodma, &self.cfg, eff_threads);
            self.jobs[id].predicted = stat;
            self.jobs[id].predicted_static = stat;
            self.jobs[id].predicted_dma =
                policy::predict_dma_cycles(kjob.input_bytes(), self.cfg.dma_beat_bytes());
            if let Some(learn) = self.learn.as_ref() {
                let key = learn::LearnKey {
                    content,
                    elems: kjob.input_bytes() / 4,
                    threads: eff_threads,
                    teams: kjob.teams as u32,
                    config: self.cfg.name.clone(),
                };
                self.jobs[id].predicted = learn.refine(&key, stat);
                self.jobs[id].learn_key = Some(key);
            }
        }
        if let Some(action) = self.policy.admission() {
            // An arbitrary kernel has no registry problem-size semantics to
            // halve, so the split action degrades to rejection.
            let cannot_split = matches!(action, OversizeAction::Split)
                .then_some("; arbitrary kernels cannot be split")
                .unwrap_or("");
            match self.cache.probe_ir(&self.cfg, &kjob.kernel, kjob.autodma, kjob.threads, content)
            {
                Ok(l) if l.l1_used <= self.l1_capacity => {}
                Ok(l) => {
                    let reason = format!(
                        "SPM footprint {} B exceeds hero_l1_capacity {} B{cannot_split}",
                        l.l1_used, self.l1_capacity
                    );
                    self.reject(id, reason);
                    return JobHandle(id);
                }
                Err(e) if crate::compiler::lower::is_l1_overflow(&e) => {
                    self.reject(id, format!("{e}{cannot_split}"));
                    return JobHandle(id);
                }
                Err(e) => {
                    self.reject(id, format!("compile failed: {e}"));
                    return JobHandle(id);
                }
            }
        }
        // Admitted: register demand on producer outputs so they stay
        // retained until this consumer dispatches.
        self.register_dataflow(id, &kjob);
        self.queue.push(id);
        JobHandle(id)
    }

    fn reject(&mut self, id: JobId, reason: String) {
        self.trace.record(SchedEvent::Rejected { job: id, reason: reason.clone() });
        self.jobs[id].state = JobState::Rejected { reason };
        // Withdraw feed demand before the payload (and with it the src
        // list) is released, then take queued consumers down too.
        self.unregister_dataflow(id);
        self.release_payload(id);
        self.cascade_reject(id);
    }

    fn oversize(&mut self, id: JobId, desc: JobDesc, action: OversizeAction, reason: String) {
        match action {
            OversizeAction::Reject => self.reject(id, reason),
            OversizeAction::Split => {
                let half = desc.size / 2;
                if half < MIN_SPLIT_SIZE {
                    self.reject(id, format!("{reason}; cannot split below N={MIN_SPLIT_SIZE}"));
                    return;
                }
                // Children are independent problem instances at feasible
                // granularity, with seeds derived from the parent's.
                let c0 = self.submit(JobDesc {
                    size: half,
                    seed: desc.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 1,
                    ..desc
                });
                let c1 = self.submit(JobDesc {
                    size: half,
                    seed: desc.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 2,
                    ..desc
                });
                let children = vec![c0, c1];
                self.trace.record(SchedEvent::Split {
                    job: id,
                    children: children.iter().map(|h| h.0).collect(),
                });
                self.jobs[id].state = JobState::Split { children };
            }
        }
    }

    /// Static SPM footprint of a job: the lowered binary's `l1_used`.
    fn spm_footprint(&mut self, w: &Workload, desc: JobDesc) -> Result<u32> {
        let lowered = self.cache.probe(&self.cfg, w, desc.variant, desc.threads)?;
        Ok(lowered.l1_used)
    }

    /// Dispatch the next job (plus its batch) onto the instance the
    /// placement engine picks. Returns `false` when the queue is empty.
    pub fn step(&mut self) -> Result<bool> {
        if self.queue.is_empty() {
            return Ok(false);
        }
        // The dispatch frontier (earliest-free slot) is known before job
        // selection, so ordering can be contention-aware: predictions
        // inflate with the DRAM pressure at the frontier, steering SJF
        // away from DMA-heavy jobs while the board is loaded.
        let frontier = self.pool.earliest_free();
        let policy = self.policy;
        let pressure = self.pool.pressure();
        // Dependency-aware readiness: only jobs whose producers have all
        // settled compete for dispatch, and a consumer's effective arrival
        // is its last producer's finish — it can never start before its
        // input exists. Producers always carry earlier ids and sit in the
        // same queue, so the ready frontier is never empty.
        let ready: Vec<usize> =
            (0..self.queue.len()).filter(|&p| self.ready(self.queue[p])).collect();
        if ready.is_empty() {
            bail!("dependency deadlock: {} queued job(s), none ready", self.queue.len());
        }
        // Ready jobs that have arrived by the dispatch frontier compete
        // under the policy; a job whose arrival is still in the future must
        // not jump ahead of ready work (it would idle the instance and
        // serialize everything behind the gap). Only when nothing has
        // arrived yet does the earliest future arrival dispatch (the
        // instance waits).
        let arrived: Vec<usize> = ready
            .iter()
            .copied()
            .filter(|&p| self.effective_arrival(self.queue[p]) <= frontier)
            .collect();
        let (qi, joint_inst) = if arrived.is_empty() {
            // Same-cycle future arrivals still respect the priority tier
            // (Reverse: High sorts first), then submission order.
            let p = ready
                .iter()
                .copied()
                .min_by_key(|&p| {
                    let r = &self.jobs[self.queue[p]];
                    (self.effective_arrival(self.queue[p]), std::cmp::Reverse(r.priority), p)
                })
                .expect("ready is non-empty");
            (p, None)
        } else {
            // Strict priority tiers: latency-critical jobs dispatch before
            // any arrived normal work; the policy orders *within* the top
            // tier, so an all-Normal stream is scheduled exactly as before
            // priorities existed.
            let top = arrived
                .iter()
                .map(|&p| self.jobs[self.queue[p]].priority)
                .max()
                .expect("arrived is non-empty");
            let tier: Vec<usize> = arrived
                .into_iter()
                .filter(|&p| self.jobs[self.queue[p]].priority == top)
                .collect();
            let sub: Vec<JobId> = tier.iter().map(|&p| self.queue[p]).collect();
            if self.lookahead > 1 && sub.len() > 1 {
                // Joint lookahead dispatch: rank the tier under the policy,
                // then score the first K candidates *jointly* against the
                // pool's slots — the head choice and its slot fall out of
                // one all-integer search instead of greedy pick-then-place.
                let order = policy.rank(&sub, |id| {
                    policy::inflate(self.jobs[id].predicted, self.jobs[id].predicted_dma, pressure)
                });
                let cands: Vec<place::Candidate> = order
                    .iter()
                    .take(self.lookahead)
                    .map(|&t| {
                        let id = sub[t];
                        place::Candidate {
                            arrival: self.effective_arrival(id),
                            predicted: self.jobs[id].predicted,
                            dma_bytes: self.jobs[id].dma_bytes,
                            priority: self.jobs[id].priority.is_high(),
                        }
                    })
                    .collect();
                let (c, inst) = place::choose_joint(&self.pool, &cands);
                (tier[order[c]], Some(inst))
            } else {
                let k = policy.pick(&sub, |id| {
                    policy::inflate(self.jobs[id].predicted, self.jobs[id].predicted_dma, pressure)
                });
                (tier[k], None)
            }
        };
        let head = self.queue.remove(qi);
        let spec = self.jobs[head].spec.clone();
        let head_key = self.jobs[head].batch;
        let head_eff = self.effective_arrival(head);
        // Board-aware placement: score candidate slots for the chosen job
        // (earliest-free placement ignores the score arguments; a joint
        // lookahead search already settled the slot together with the
        // head). The arrival the engine scores with is the
        // dependency-aware one.
        let inst = match joint_inst {
            Some(i) => i,
            None => place::choose(
                &self.pool,
                self.placement,
                head_eff,
                self.jobs[head].predicted,
                self.jobs[head].dma_bytes,
                self.jobs[head].priority.is_high(),
            ),
        };
        let icfg = self.pool.cfg(inst).clone();

        // Gather same-binary followers from the queue (batching). Only
        // *ready* jobs already arrived (dependency-aware) by the head's
        // start may chain — batching a future arrival would park the
        // instance on its gap, and a consumer of an unfinished producer
        // has no input yet (a pipeline of identical chained stages thus
        // never batches with itself) — and only jobs of the head's own
        // priority class: a Normal follower riding a High head would
        // execute ahead of other queued High work, a priority inversion
        // through the batch mechanism. (All-Normal streams are unaffected:
        // every job is in the head's class.)
        let head_start = self.pool.free_at(inst).max(head_eff);
        let head_priority = self.jobs[head].priority;
        let mut batch = vec![head];
        if self.batching {
            let mut i = 0;
            while i < self.queue.len() && batch.len() < MAX_BATCH {
                let cand = self.queue[i];
                if self.jobs[cand].batch == head_key
                    && self.ready(cand)
                    && self.effective_arrival(cand) <= head_start
                    && self.jobs[cand].priority == head_priority
                {
                    batch.push(self.queue.remove(i));
                } else {
                    i += 1;
                }
            }
        }

        // Compile for the *instance's* configuration (the cache key includes
        // the config name, so heterogeneous pools keep per-width binaries).
        // Named jobs also materialize their workload here (shared by the
        // whole batch); kernel jobs carry their IR along.
        let mut tuned_run: Option<TunedRun> = None;
        let acquired = match &spec {
            JobSpec::Named(desc) => {
                let w = workloads::build(desc.kernel, desc.size)
                    .expect("queued jobs have known kernels");
                if self.autotune && desc.variant == Variant::AutoDma {
                    let bytes = policy::job_bytes(&w);
                    self.acquire_tuned(&icfg, &w.unmodified, bytes, desc.threads, 1, head).map(
                        |(lowered, cost, run)| {
                            tuned_run = Some(run);
                            (lowered, cost, Some(w))
                        },
                    )
                } else {
                    self.cache
                        .acquire(&icfg, &w, desc.variant, desc.threads)
                        .map(|(lowered, cost)| (lowered, cost, Some(w)))
                }
            }
            JobSpec::Kernel(kjob) => {
                let BatchKey::Ir { content, .. } = head_key else {
                    unreachable!("kernel jobs carry IR batch keys")
                };
                if kjob.autodma && kjob.autotune {
                    self.acquire_tuned(
                        &icfg,
                        &kjob.kernel,
                        kjob.input_bytes(),
                        kjob.threads,
                        kjob.teams as u32,
                        head,
                    )
                    .map(|(lowered, cost, run)| {
                        tuned_run = Some(run);
                        (lowered, cost, None)
                    })
                } else {
                    self.cache
                        .acquire_ir(&icfg, &kjob.kernel, kjob.autodma, kjob.threads, content)
                        .map(|(lowered, cost, _)| (lowered, cost, None))
                }
            }
            JobSpec::Retired => unreachable!("retired jobs are never queued"),
        };
        let (lowered, compile_cost, w) = match acquired {
            Ok(x) => x,
            Err(e) => {
                // The binary fails for every job of the batch alike.
                let reason = format!("compile failed: {e}");
                for id in batch {
                    self.reject(id, reason.clone());
                }
                return Ok(true);
            }
        };
        if compile_cost > 0 {
            self.trace.record(SchedEvent::CompileMiss { job: head, cycles: compile_cost });
        } else {
            self.trace.record(SchedEvent::CompileHit { job: head });
        }

        let followers = batch.len() - 1;
        let mut charge = compile_cost;
        let mut displaced: Vec<JobId> = Vec::new();
        let mut requeue: Vec<JobId> = Vec::new();
        for (bi, id) in batch.iter().copied().enumerate() {
            // Priority preemption: a batch follower is *queued-but-assigned*
            // — gathered onto this instance but not yet executing. Before it
            // commits, an arrived-and-ready High job may displace it (and
            // everything gathered behind it) back into the queue; the next
            // step's strict tiers then dispatch the High job first. The
            // in-flight member is never touched, so numerics and digests
            // cannot drift; the displaced job keeps its arrival stamp and
            // will re-dispatch against the already-cached binary — its
            // unburned cycles cost it nothing.
            if self.preempt && bi > 0 && !self.jobs[id].priority.is_high() {
                let planned = self.pool.free_at(inst).max(self.effective_arrival(id));
                let high = self.queue.iter().copied().find(|&q| {
                    self.jobs[q].priority.is_high()
                        && self.ready(q)
                        && self.effective_arrival(q) <= planned
                });
                if let Some(by) = high {
                    for &d in &batch[bi..] {
                        self.trace.record(SchedEvent::Preempted { job: d, by, at: planned });
                        let class = if self.jobs[d].priority.is_high() { 1 } else { 0 };
                        self.preempted[class] += 1;
                    }
                    displaced = batch[bi..].to_vec();
                    break;
                }
            }
            let member = self.jobs[id].spec.clone();
            let arrival = self.effective_arrival(id);
            let priority = self.jobs[id].priority;
            // Every job executes on a fresh accelerator through the shared
            // session core; only the payload source differs per spec kind.
            // Dataflow inputs materialize here, straight out of the feed
            // store — the producer's output never round-trips through the
            // submitting host.
            let ran: Result<(OffloadResult, Vec<Vec<f32>>, bool, bool)> = match &member {
                JobSpec::Named(desc) => {
                    let w = w.as_ref().expect("named batches carry their workload");
                    run_lowered(&icfg, w, &lowered, desc.seed, JOB_MAX_CYCLES).map(|out| {
                        let verified =
                            !self.verify || bench_harness::verify(w, &out, desc.seed).is_ok();
                        (out.result, out.arrays, verified, false)
                    })
                }
                JobSpec::Kernel(kjob) => {
                    let resolved: std::result::Result<Vec<&[f32]>, String> = kjob
                        .inputs
                        .iter()
                        .map(|src| match src {
                            PayloadSrc::Data(v) => Ok(v.as_slice()),
                            // Both pin and copy see the same functional
                            // bytes — only the cycle accounting below
                            // differs — so dispatch reads the host-side
                            // store directly in every mode.
                            PayloadSrc::Svm { va, elems } => self
                                .svm
                                .as_ref()
                                .and_then(|svm| svm.space.get(*va))
                                .map(|buf| &buf[..*elems])
                                .ok_or_else(|| {
                                    format!("internal: SVM buffer at va {va:#x} vanished")
                                }),
                            PayloadSrc::Output { producer, index, .. } => self
                                .feeds
                                .get(&(producer.0, *index))
                                .map(|f| f.data.as_slice())
                                .ok_or_else(|| {
                                    format!(
                                        "internal: producer job {} output {index} not \
                                         retained for this consumer",
                                        producer.0
                                    )
                                }),
                        })
                        .collect();
                    match resolved {
                        Ok(refs) => crate::session::core::run_arrays(
                            &icfg,
                            &lowered,
                            &refs,
                            &kjob.fargs,
                            kjob.teams,
                            kjob.max_cycles,
                        )
                        .map(|(result, arrays)| (result, arrays, true, true)),
                        Err(msg) => Err(anyhow!(msg)),
                    }
                }
                JobSpec::Retired => unreachable!("retired jobs are never queued"),
            };
            match ran {
                Err(e) => {
                    // The lowering happened even though the job failed:
                    // book the pending compile charge on the instance so it
                    // neither vanishes nor migrates onto a cached follower.
                    if charge > 0 {
                        self.pool.assign(inst, arrival, charge, 0, false);
                        charge = 0;
                    }
                    // With the watchdog armed, an exhausted simulation
                    // budget ([`KernelJob::max_cycles`]) is a detected
                    // deadline fault — the instance burned the whole
                    // budget — not an execution error.
                    if self.watchdog.is_some() && crate::accel::is_budget_exhausted(&e) {
                        let budget = match &member {
                            JobSpec::Kernel(kjob) => kjob.max_cycles,
                            _ => JOB_MAX_CYCLES,
                        };
                        self.settle_fault(
                            id,
                            inst,
                            arrival,
                            priority,
                            budget,
                            fault::FaultKind::DeadlineExceeded,
                            &mut requeue,
                        );
                    } else {
                        self.reject(id, format!("execution failed: {e}"));
                    }
                }
                Ok((result, arrays, verified, keep_payload)) => {
                    // Fault gate: injected draws first, then the watchdog's
                    // measured deadline. A faulted attempt books its
                    // occupancy window and nothing else — no digest, feed,
                    // SVM write-back or learning observation — so a stream
                    // whose faults are all retried successfully stays
                    // numerically identical to the fault-free run.
                    if let Some(kind) = self.fault_for(id, &member, result.total_cycles) {
                        let occupancy = match kind {
                            // A transient fault ran to completion before
                            // spoiling its result; deadline-class faults
                            // hold the instance until the watchdog fires.
                            fault::FaultKind::Transient => result.total_cycles,
                            _ => self.deadline_for(id, &member),
                        };
                        self.settle_fault(
                            id,
                            inst,
                            arrival,
                            priority,
                            charge + occupancy,
                            kind,
                            &mut requeue,
                        );
                        charge = 0; // the faulted head still paid the compile
                        continue;
                    }
                    let digest = digest_arrays(&arrays);
                    let dma_busy = result.perf.get(Event::DmaBusyCycles);
                    let mut dma_bytes = result.perf.get(Event::DmaBytes);
                    // SVM operand service: resolve the offload strategy,
                    // charge its deterministic cost on the instance, and
                    // route all host-side traffic (mailbox descriptor,
                    // page-table-entry reads, copy staging) through the
                    // pool's host port so it contends with instance DMA.
                    let mut svm_cycles = 0u64;
                    if let (JobSpec::Kernel(kjob), Some(svm)) = (&member, self.svm.as_mut()) {
                        let ops: Vec<(u64, u64)> = kjob
                            .inputs
                            .iter()
                            .filter_map(|s| match s {
                                PayloadSrc::Svm { va, elems } => Some((*va, *elems as u64 * 4)),
                                _ => None,
                            })
                            .collect();
                        if !ops.is_empty() {
                            let host_start = arrival.max(self.pool.free_at(inst));
                            // The mailbox descriptor rides the host port in
                            // every mode — VA-described operands still need
                            // announcing to the device.
                            svm_cycles += self
                                .pool
                                .host_reserve(host_start, crate::host::Mailbox::DESCRIPTOR_BYTES);
                            let op_bytes: u64 = ops.iter().map(|o| o.1).sum();
                            let page = svm.space.page_bytes();
                            let walk = svm.iommu.cfg().walk_cycles;
                            let setup = icfg.dma.setup_cycles;
                            let beat = icfg.dma_beat_bytes();
                            let ext = icfg.timing.ext_addr_overhead;
                            let mode = match kjob.svm.unwrap_or(svm.cfg.mode) {
                                SvmMode::Auto => {
                                    // Exact probes, no reservation: pin pays
                                    // per-beat external-address overhead plus
                                    // whatever extra stall the operand bytes
                                    // add on the instance port; copy pays its
                                    // fixed setup+walk cost plus the host
                                    // port's drain of the staged bytes.
                                    // TLB-refill walks are a one-time
                                    // investment amortized across reuse, so
                                    // they are excluded from the pin estimate
                                    // (but still charged when they occur).
                                    let pin = crate::svm::pin_access_cycles(op_bytes, beat, ext)
                                        + self
                                            .pool
                                            .probe_stall(
                                                inst,
                                                host_start,
                                                dma_bytes + op_bytes,
                                                priority.is_high(),
                                            )
                                            .saturating_sub(self.pool.probe_stall(
                                                inst,
                                                host_start,
                                                dma_bytes,
                                                priority.is_high(),
                                            ));
                                    let copy = crate::svm::copy_fixed_cycles(
                                        &ops, page, setup, walk,
                                    ) + self.pool.host_probe(
                                        host_start,
                                        crate::svm::copy_port_bytes(&ops, page),
                                    );
                                    if pin <= copy {
                                        SvmMode::Pin
                                    } else {
                                        SvmMode::Copy
                                    }
                                }
                                m => m,
                            };
                            let (mut hits, mut misses) = (0u64, 0u64);
                            match mode {
                                SvmMode::Pin => {
                                    let (tc, h, m) = crate::svm::translate_operands(
                                        &mut svm.iommu,
                                        svm.space.pt(),
                                        &ops,
                                        host_start,
                                    );
                                    hits = h;
                                    misses = m;
                                    svm_cycles += tc
                                        + crate::svm::pin_access_cycles(op_bytes, beat, ext);
                                    // Each miss's page walk reads a PTE from
                                    // board DRAM on the host's behalf.
                                    svm_cycles += self.pool.host_reserve(
                                        host_start,
                                        misses * crate::svm::PTE_BYTES,
                                    );
                                    // Pinned operands stream over the NoC as
                                    // instance traffic.
                                    dma_bytes += op_bytes;
                                }
                                SvmMode::Copy => {
                                    svm_cycles += crate::svm::copy_fixed_cycles(
                                        &ops, page, setup, walk,
                                    );
                                    svm_cycles += self.pool.host_reserve(
                                        host_start,
                                        crate::svm::copy_port_bytes(&ops, page),
                                    );
                                }
                                SvmMode::Auto => unreachable!("resolved above"),
                            }
                            self.trace.record(SchedEvent::SvmResolved {
                                job: id,
                                mode: mode.label(),
                                cycles: svm_cycles,
                                hits,
                                misses,
                            });
                            // SVM buffers are shared memory: the device's
                            // result becomes host-visible in place. Jobs
                            // touching the same buffer see submission-order
                            // data visibility (a modeling simplification —
                            // the queue dispatches in submission order).
                            for (idx, src) in kjob.inputs.iter().enumerate() {
                                if let PayloadSrc::Svm { va, .. } = src {
                                    svm.space.write_back(*va, &arrays[idx]);
                                }
                            }
                        }
                    }
                    let a = self.pool.assign(
                        inst,
                        arrival,
                        charge + result.total_cycles + svm_cycles,
                        dma_bytes,
                        priority.is_high(),
                    );
                    self.pool.record(inst, result.device_cycles, dma_busy);
                    self.trace.record(SchedEvent::Dispatched {
                        job: id,
                        instance: inst,
                        start: a.start,
                        batched: if id == head { followers } else { 0 },
                    });
                    self.trace.record(SchedEvent::Completed {
                        job: id,
                        instance: inst,
                        end: a.end,
                        dram_stall: a.dram_stall,
                    });
                    self.jobs[id].state = JobState::Done(JobOutcome {
                        instance: inst,
                        start: a.start,
                        end: a.end,
                        device_cycles: result.device_cycles,
                        total_cycles: result.total_cycles,
                        compile_cycles: charge,
                        dma_busy_cycles: dma_busy,
                        dma_bytes,
                        dram_stall_cycles: a.dram_stall,
                        digest,
                        verified,
                        perf: keep_payload.then(|| Box::new(result.perf)),
                        arrays: keep_payload.then_some(arrays),
                    });
                    // Dataflow bookkeeping, in order: drop the feeds this
                    // job consumed, retain the outputs queued consumers
                    // demanded (before anyone can take the payload), and
                    // surface newly-ready consumers in the trace.
                    self.unregister_dataflow(id);
                    self.retain_demanded_outputs(id);
                    self.announce_ready(id);
                    // The job has settled: its input snapshot (and kernel
                    // IR) will never be read again — release it so long
                    // serve runs stop growing memory.
                    self.release_payload(id);
                    // Measure → refine: blend the measured device cycles
                    // into the EWMA store and refresh the memoized
                    // predictions of queued jobs sharing the key.
                    if self.learn.is_some() {
                        self.learn_from(id, result.device_cycles);
                    }
                    // Tuned dispatches additionally file the measurement
                    // under the chosen *variant's* key, so the next choose()
                    // for this kernel re-ranks against real cycles (the
                    // measure → re-rank loop of the tuning store).
                    if let (Some(run), Some(learn)) = (tuned_run.as_ref(), self.learn.as_mut()) {
                        learn.observe(
                            tune::variant_learn_key(&run.key, &run.variant, run.teams),
                            run.static_predicted,
                            result.device_cycles,
                        );
                    }
                    charge = 0; // the batch head pays the compile once
                }
            }
        }
        // Faulted members re-enter at the *back* of the queue: their
        // backoff (`not_before` flooring the effective arrival) — not
        // queue position — is what delays the next attempt.
        self.queue.extend(requeue);
        // Displaced followers return to the *front* of the queue in their
        // original order: they were next in line, and the strict priority
        // tiers — not queue position — are what hands the next dispatch to
        // the preempting High job.
        for (k, d) in displaced.iter().enumerate() {
            self.queue.insert(k, *d);
        }
        Ok(true)
    }

    /// What fault (if any) this attempt suffers: injected plan draws
    /// first, then the watchdog's measured-deadline check.
    fn fault_for(&self, id: JobId, member: &JobSpec, total_cycles: u64) -> Option<fault::FaultKind> {
        if let Some(kind) =
            self.faults.as_ref().and_then(|p| p.draw(id as u64, self.jobs[id].attempts))
        {
            return Some(kind);
        }
        (self.watchdog.is_some() && total_cycles > self.deadline_for(id, member))
            .then_some(fault::FaultKind::DeadlineExceeded)
    }

    /// A job's deadline: watchdog multiplier × its predicted cycles
    /// ([`fault::DEFAULT_WATCHDOG_MULT`] when only a fault plan is armed),
    /// capped by a kernel job's own simulation budget
    /// ([`KernelJob::max_cycles`]).
    fn deadline_for(&self, id: JobId, member: &JobSpec) -> u64 {
        let mult = self.watchdog.unwrap_or(fault::DEFAULT_WATCHDOG_MULT);
        let mut deadline = self.jobs[id].predicted.max(1).saturating_mul(mult);
        if let JobSpec::Kernel(kjob) = member {
            deadline = deadline.min(kjob.max_cycles);
        }
        deadline
    }

    /// Book a faulted attempt's occupancy window (plus any pending compile
    /// charge; no useful DRAM traffic), record it, and either requeue the
    /// job for a backed-off retry or fail it permanently — permanent
    /// failures cascade to dataflow consumers exactly like rejections.
    #[allow(clippy::too_many_arguments)]
    fn settle_fault(
        &mut self,
        id: JobId,
        inst: usize,
        arrival: u64,
        priority: Priority,
        occupancy: u64,
        kind: fault::FaultKind,
        requeue: &mut Vec<JobId>,
    ) {
        let a = self.pool.assign(inst, arrival, occupancy, 0, priority.is_high());
        self.trace.record(SchedEvent::Faulted {
            job: id,
            instance: inst,
            kind: kind.label(),
            at: a.end,
        });
        self.fault_counts[kind.index()] += 1;
        if kind.retryable() && self.jobs[id].attempts < self.retry_limit {
            self.jobs[id].attempts += 1;
            let attempt = self.jobs[id].attempts;
            let at = a.end.saturating_add(fault::backoff_cycles(attempt));
            self.jobs[id].not_before = at;
            self.retries += 1;
            self.trace.record(SchedEvent::Retried { job: id, attempt, at });
            requeue.push(id);
        } else {
            self.fault_failures += 1;
            let attempts = self.jobs[id].attempts + 1;
            self.reject(id, format!("{} fault after {attempts} attempt(s)", kind.label()));
        }
    }

    /// Feed one settled job's measured device cycles back into the
    /// refinement store: score the static and dispatched predictions
    /// against the measurement, blend the measurement into the job's EWMA
    /// cell, and refresh the memoized prediction of every queued job
    /// awaiting the same key (the single place predictions are ever
    /// rewritten after submit).
    fn learn_from(&mut self, id: JobId, measured: u64) {
        let Some(key) = self.jobs[id].learn_key.clone() else { return };
        let stat = self.jobs[id].predicted_static;
        let used = self.jobs[id].predicted;
        let learn = self.learn.as_mut().expect("caller checked learning is on");
        learn.score(stat, used, measured);
        learn.observe(key.clone(), stat, measured);
        // Equal keys mean equal static predictions (both are pure functions
        // of the key's identity), so one refined figure serves every queued
        // job awaiting this key.
        let refined = learn.refine(&key, stat);
        for &q in &self.queue {
            if self.jobs[q].learn_key.as_ref() == Some(&key) {
                self.jobs[q].predicted = refined;
            }
        }
    }

    /// Pick (or recall) the tuned variant for an AutoDMA dispatch and
    /// compile it for the instance's configuration. The tuning key carries
    /// the *instance's* config name, so a heterogeneous pool searches — and
    /// may choose — per instance kind; the compiled binary is cached under
    /// the variant's own content hash ([`job::tuned_variant_content`]),
    /// keeping tuned rows apart from default-recipe rows.
    fn acquire_tuned(
        &mut self,
        icfg: &HeroConfig,
        k: &crate::compiler::ir::Kernel,
        input_bytes: u64,
        threads: u32,
        teams: u32,
        job: JobId,
    ) -> Result<(Arc<Lowered>, u64, TunedRun)> {
        let base = job::kernel_content_key(k, true);
        let key = tune::TuneKey {
            content: base,
            elems: input_bytes / 4,
            threads: threads.min(icfg.accel.cores_per_cluster as u32),
            config: icfg.name.clone(),
        };
        let choice = self.tune.choose(&key, k, icfg, teams, self.learn.as_ref());
        if choice.fresh {
            // Memo hits are silent: a same-kernel stream tunes once, loudly.
            self.trace.record(SchedEvent::Tuned {
                job,
                variant: choice.variant.label(),
                candidates: choice.candidates,
                predicted: choice.predicted,
                default_predicted: choice.default_predicted,
            });
        }
        let static_predicted =
            self.tune.static_predicted(&key, &choice.variant).expect("chosen from the memo");
        let content = job::tuned_variant_content(base, &choice.variant);
        let (lowered, cost, _) =
            self.cache.acquire_ir_tuned(icfg, k, &choice.variant, threads, content)?;
        Ok((lowered, cost, TunedRun { key, variant: choice.variant, static_predicted, teams }))
    }

    /// Run the queue dry.
    pub fn drain(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Dispatch until the pool's earliest-free cycle reaches `cycle` or
    /// the queue runs dry — how the fleet router advances a board to its
    /// failure point: every dispatch whose slot freed before the failure
    /// completes (jobs are never killed mid-run), the queued remainder is
    /// left for [`Scheduler::evacuate`].
    pub fn step_until(&mut self, cycle: u64) -> Result<()> {
        while !self.queue.is_empty() && self.pool.earliest_free() < cycle {
            self.step()?;
        }
        Ok(())
    }

    /// Pull every queued job off this board (a board failure): named jobs
    /// come back as `(handle, descriptor)` pairs for the router to
    /// resubmit elsewhere — still `Queued` until the router settles each
    /// via [`Scheduler::mark_migrated`] / [`Scheduler::fail_evacuated`].
    /// Kernel jobs carry board-local dataflow and payloads, so they
    /// cannot move: they fail in place (cascading to their consumers).
    pub fn evacuate(&mut self) -> Vec<(JobHandle, JobDesc)> {
        let ids = std::mem::take(&mut self.queue);
        let mut out = Vec::new();
        for id in ids {
            // A cascade from an earlier kernel-job failure may have
            // already settled this entry.
            if !matches!(self.jobs[id].state, JobState::Queued) {
                continue;
            }
            match &self.jobs[id].spec {
                JobSpec::Named(desc) => out.push((JobHandle(id), *desc)),
                _ => self.reject(id, "board failed before dispatch".to_string()),
            }
        }
        out
    }

    /// Settle an evacuated job as migrated: the router resubmitted it on
    /// a surviving board and its fleet handle now points there.
    pub fn mark_migrated(&mut self, h: JobHandle) {
        debug_assert!(
            matches!(self.jobs[h.0].state, JobState::Queued),
            "only evacuated (still-queued) jobs migrate"
        );
        self.jobs[h.0].state = JobState::Migrated;
        self.migrated += 1;
    }

    /// Fail an evacuated job the router could not re-route (no healthy
    /// board left).
    pub fn fail_evacuated(&mut self, h: JobHandle, reason: String) {
        self.reject(h.0, reason);
    }

    /// Drive the scheduler until `h` settles (the `hero_memcpy_wait`
    /// analogue). Note a `Split` parent settles at submission; wait on its
    /// children to wait for the decomposed work. A foreign or stale handle
    /// is an error, not a panic.
    pub fn wait(&mut self, h: JobHandle) -> Result<&JobState> {
        if h.0 >= self.jobs.len() {
            bail!("unknown job handle {} ({} jobs submitted)", h.0, self.jobs.len());
        }
        while !self.jobs[h.0].state.settled() {
            if !self.step()? {
                bail!("job {} is queued but the queue is empty", h.0);
            }
        }
        Ok(&self.jobs[h.0].state)
    }

    /// Aggregate report over everything submitted so far.
    pub fn report(&self) -> ServeReport {
        let (mut completed, mut rejected, mut split, mut verify_failures) = (0, 0, 0, 0);
        let mut total_device = 0u64;
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        // Per-QoS-class turnaround samples (completion − arrival).
        let mut turnarounds: Vec<(Priority, Vec<u64>)> =
            vec![(Priority::Normal, Vec::new()), (Priority::High, Vec::new())];
        for rec in &self.jobs {
            match &rec.state {
                JobState::Done(o) => {
                    completed += 1;
                    total_device += o.device_cycles;
                    if !o.verified {
                        verify_failures += 1;
                    }
                    // Chain in job-id order: stable across dispatch orders.
                    digest = (digest ^ o.digest).wrapping_mul(0x0000_0100_0000_01b3);
                    let class = turnarounds
                        .iter_mut()
                        .find(|(p, _)| *p == rec.priority)
                        .expect("every priority class is pre-seeded");
                    class.1.push(o.end.saturating_sub(rec.arrival));
                }
                JobState::Rejected { .. } => rejected += 1,
                JobState::Split { .. } => split += 1,
                // Counted via self.migrated; the job completes elsewhere.
                JobState::Migrated => {}
                JobState::Queued => {}
            }
        }
        let classes = turnarounds
            .into_iter()
            .filter(|(_, samples)| !samples.is_empty())
            .map(|(priority, mut samples)| {
                samples.sort_unstable();
                ClassReport {
                    priority,
                    jobs: samples.len(),
                    preempted: self.preempted[if priority.is_high() { 1 } else { 0 }],
                    p50_turnaround_cycles: report::percentile(&samples, 50),
                    p95_turnaround_cycles: report::percentile(&samples, 95),
                }
            })
            .collect();
        let makespan = self.pool.makespan();
        let instances = (0..self.pool.len())
            .map(|i| {
                let s = self.pool.stats(i);
                InstanceReport {
                    jobs: s.jobs,
                    busy_cycles: self.pool.busy_cycles(i),
                    device_cycles: s.device_cycles,
                    dma_busy_cycles: s.dma_busy_cycles,
                    dram_stall_cycles: s.dram_stall_cycles,
                    dram_bytes: s.dram_bytes,
                    dma_width_bits: self.pool.cfg(i).noc.dma_width_bits,
                    utilization: self.pool.utilization(i),
                }
            })
            .collect();
        ServeReport {
            policy: self.policy.label(),
            placement: self.placement.label(),
            caching: self.cache.enabled(),
            batching: self.batching,
            submitted: self.jobs.len(),
            completed,
            rejected,
            split,
            verify_failures,
            makespan_cycles: makespan,
            total_device_cycles: total_device,
            // Single source of truth: what the cache actually charged —
            // per-job outcomes can miss a charge booked for a failed head.
            compile_cycles: self.cache.stats.charged_cycles,
            cache_hits: self.cache.stats.hits,
            cache_misses: self.cache.stats.misses,
            freq_mhz: self.cfg.accel.freq_mhz,
            dram_peak_bytes_per_cycle: self.pool.dram_peak(),
            dram_priority_headroom: self.pool.board().priority_headroom,
            dram_stall_cycles: self.pool.dram_stall_total(),
            dram_bytes: self.pool.dram_total_bytes(),
            dram_utilization: self.pool.dram_utilization(),
            svm_mode: self.svm.as_ref().map(|s| s.cfg.mode.label()),
            host_dram_bytes: self.pool.host_stats().map_or(0, |s| s.bytes),
            host_dram_stall_cycles: self.pool.host_stats().map_or(0, |s| s.stall_cycles),
            host_requests: self.pool.host_stats().map_or(0, |s| s.requests),
            learning: self.learn.is_some(),
            lookahead: self.lookahead,
            preemption: self.preempt,
            preemptions: self.preempted.iter().sum(),
            // Per-job opt-in (LaunchBuilder::autotune) surfaces the line too.
            autotune: self.autotune || self.tune.searches() > 0,
            tune_searches: self.tune.searches(),
            tune_hits: self.tune.hits(),
            tune_reranks: self.tune.reranks(),
            predict_samples: self.learn.as_ref().map_or(0, |l| l.samples()),
            predict_err_static_pct: self.learn.as_ref().map_or(0, |l| l.mean_static_err_pct()),
            predict_err_learned_pct: self.learn.as_ref().map_or(0, |l| l.mean_refined_err_pct()),
            resilience: self.resilience_enabled(),
            faults_transient: self.fault_counts[fault::FaultKind::Transient.index()],
            faults_timeout: self.fault_counts[fault::FaultKind::Timeout.index()],
            faults_deadline: self.fault_counts[fault::FaultKind::DeadlineExceeded.index()],
            retries: self.retries,
            fault_failures: self.fault_failures,
            migrated: self.migrated,
            digest,
            classes,
            instances,
        }
    }
}

/// FNV-1a over the f32 bit patterns of a job's arrays (bit-identity check).
pub fn digest_arrays(arrays: &[Vec<f32>]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for a in arrays {
        for v in a {
            for b in v.to_bits().to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::Variant;
    use crate::config::aurora;

    fn job(kernel: &'static str, size: usize, seed: u64) -> JobDesc {
        JobDesc {
            kernel,
            size,
            variant: Variant::Handwritten,
            threads: 8,
            seed,
            arrival: 0,
            priority: Priority::Normal,
        }
    }

    /// Aurora with a TCDM small enough that mid-size kernels overflow it —
    /// the capacity-policy test bed.
    fn small_l1_cfg() -> crate::config::HeroConfig {
        let mut cfg = aurora();
        cfg.accel.l1_bytes = 16 * 1024;
        cfg
    }

    #[test]
    fn submit_returns_immediately_and_wait_completes() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        let h = s.submit(job("gemm", 12, 3));
        assert!(matches!(s.state(h), Some(JobState::Queued)));
        assert!(s.poll(h).is_none());
        let state = s.wait(h).unwrap();
        let JobState::Done(o) = state else { panic!("not done: {state:?}") };
        assert!(o.verified);
        assert!(o.end > o.start);
        assert!(o.total_cycles > o.device_cycles);
        // Named jobs keep serve runs lean: no payload copies.
        assert!(o.arrays.is_none() && o.perf.is_none());
        assert!(s.poll(h).is_some());
    }

    #[test]
    fn unknown_kernel_rejected() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h = s.submit(job("nope", 12, 3));
        assert!(matches!(s.state(h), Some(JobState::Rejected { .. })));
    }

    #[test]
    fn foreign_handles_are_safe() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        assert!(s.state(JobHandle(7)).is_none());
        assert!(s.poll(JobHandle(7)).is_none());
        let err = s.wait(JobHandle(7)).unwrap_err();
        assert!(err.to_string().contains("unknown job handle"), "{err}");
        // A genuine handle still works afterwards.
        let h = s.submit(job("gemm", 12, 1));
        assert!(matches!(s.wait(h).unwrap(), JobState::Done(_)));
    }

    #[test]
    fn fifo_dispatches_in_submission_order() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        let specs =
            [job("gemm", 24, 1), job("atax", 24, 2), job("gemm", 12, 3), job("conv2d", 18, 4)];
        s.submit_all(&specs);
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn sjf_dispatches_shortest_first() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Sjf).with_batching(false);
        // Big job first, small job second: SJF must reorder.
        s.submit(job("gemm", 24, 1));
        s.submit(job("gemm", 12, 2));
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![1, 0]);
        // Both still complete (no starvation).
        assert!(s.state(JobHandle(0)).unwrap().settled());
        assert!(s.state(JobHandle(1)).unwrap().settled());
    }

    #[test]
    fn batching_chains_same_binary_jobs() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        for seed in 0..5 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 5);
        // One lowering shared by the whole batch, all chained on instance 0.
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.instances[0].jobs, 5);
        assert_eq!(r.instances[1].jobs, 0);
        // Exactly one job (the head) paid compile cycles.
        let paid: Vec<u64> = (0..5)
            .filter_map(|i| s.poll(JobHandle(i)).map(|o| o.compile_cycles))
            .collect();
        assert_eq!(paid.iter().filter(|&&c| c > 0).count(), 1);
    }

    #[test]
    fn cache_serves_repeat_dispatches_without_batching() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        for seed in 0..4 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.cache_hits, 3);
        // Cached dispatches are cheaper: only the first carried the charge.
        assert!(r.compile_cycles > 0);
        assert_eq!(
            r.compile_cycles,
            s.poll(JobHandle(0)).unwrap().compile_cycles
        );
    }

    #[test]
    fn pool_spreads_distinct_binaries() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        s.submit(job("gemm", 12, 1));
        s.submit(job("atax", 24, 2));
        s.submit(job("conv2d", 18, 3));
        s.submit(job("bicg", 24, 4));
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        assert!(r.instances[0].jobs > 0 && r.instances[1].jobs > 0, "{r}");
        // Spreading must beat the serial sum of occupancies.
        let serial: u64 = r.instances.iter().map(|i| i.busy_cycles).sum();
        assert!(r.makespan_cycles < serial);
    }

    #[test]
    fn capacity_policy_rejects_oversize() {
        let mut s =
            Scheduler::new(small_l1_cfg(), 1, Policy::Capacity(OversizeAction::Reject));
        // gemm N=64 handwritten keeps B (16 KiB) + strips resident: > 14 KiB
        // of user L1 on the shrunken config.
        let h = s.submit(job("gemm", 64, 1));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(
            reason.contains("hero_l1_capacity") || reason.contains("L1 overflow"),
            "{reason}"
        );
        // A job that fits is admitted and completes.
        let ok = s.submit(job("gemm", 16, 2));
        s.drain().unwrap();
        assert!(matches!(s.state(ok), Some(JobState::Done(_))));
    }

    #[test]
    fn capacity_policy_splits_oversize_to_feasible_children() {
        let mut s = Scheduler::new(small_l1_cfg(), 2, Policy::Capacity(OversizeAction::Split));
        let h = s.submit(job("gemm", 64, 9));
        let JobState::Split { children } = s.state(h).unwrap().clone() else {
            panic!("expected split, got {:?}", s.state(h));
        };
        assert_eq!(children.len(), 2);
        s.drain().unwrap();
        for c in &children {
            let Some(JobState::Done(o)) = s.state(*c) else {
                panic!("child not done: {:?}", s.state(*c));
            };
            assert!(o.verified);
        }
        // Children run the same kernel at feasible granularity.
        for c in &children {
            let JobSpec::Named(d) = &s.jobs[c.0].spec else { panic!("child is not named") };
            assert_eq!(d.kernel, "gemm");
            assert_eq!(d.size, 32);
        }
        let r = s.report();
        assert_eq!(r.split, 1);
        assert_eq!(r.completed, 2);
    }

    #[test]
    fn arrival_cycle_delays_dispatch() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let early = s.submit(job("gemm", 12, 1));
        let late = s.submit(JobDesc { arrival: 500_000_000, ..job("gemm", 12, 2) });
        s.drain().unwrap();
        let e = s.poll(early).unwrap();
        let l = s.poll(late).unwrap();
        assert!(e.end < 500_000_000, "early job should finish well before the late arrival");
        assert_eq!(l.start, 500_000_000, "late job must wait for its arrival cycle");
        let r = s.report();
        assert!(r.makespan_cycles > 500_000_000);
    }

    #[test]
    fn constrained_board_stalls_overlapping_jobs_but_not_pool1() {
        // Board bandwidth equal to one instance's NoC drain rate: a pool of
        // 2 must stall where windows overlap, and a pool of 1 must be
        // cycle-identical to the uncontended baseline.
        let jobs: Vec<JobDesc> = (0..4).map(|i| job("gemm", 24, i)).collect();
        let run = |pool: usize, board: BoardSpec| {
            let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
                .with_board(board)
                .with_batching(false)
                .with_verify(false);
            s.submit_all(&jobs);
            s.drain().unwrap();
            s.report()
        };
        let beat = aurora().dma_beat_bytes();
        let open1 = run(1, BoardSpec::uncontended());
        let capped1 = run(1, BoardSpec::with_bandwidth(beat));
        assert_eq!(open1.makespan_cycles, capped1.makespan_cycles);
        assert_eq!(open1.digest, capped1.digest);
        assert_eq!(capped1.dram_stall_cycles, 0);
        assert!(capped1.dram_bytes > 0);
        let capped2 = run(2, BoardSpec::with_bandwidth(beat));
        assert_eq!(capped2.digest, open1.digest, "contention must never change numerics");
        assert!(capped2.dram_stall_cycles > 0, "overlapping DMA windows must contend");
        assert!(
            capped2.makespan_cycles < capped1.makespan_cycles,
            "two instances still beat one despite contention"
        );
        // Conservation: the board ledger and the per-instance/per-job books
        // agree on every byte.
        let per_inst: u64 = capped2.instances.iter().map(|i| i.dram_bytes).sum();
        assert_eq!(capped2.dram_bytes, per_inst);
    }

    #[test]
    fn heterogeneous_pool_compiles_per_instance_config() {
        use crate::config::preset::with_dma_width;
        let base = aurora();
        let cfgs = vec![with_dma_width(&base, 64), with_dma_width(&base, 128)];
        let mut s = Scheduler::new_heterogeneous(cfgs, Policy::Fifo).with_batching(false);
        for seed in 0..4 {
            s.submit(job("gemm", 12, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        assert_eq!(r.verify_failures, 0);
        assert_eq!(r.instances[0].dma_width_bits, 64);
        assert_eq!(r.instances[1].dma_width_bits, 128);
        // Both instances ran jobs, and each width needed its own lowering.
        assert!(r.instances.iter().all(|i| i.jobs > 0), "{r}");
        assert_eq!(r.cache_misses, 2);
    }

    #[test]
    fn digest_is_policy_and_pool_invariant() {
        let specs = [job("gemm", 12, 5), job("atax", 24, 6), job("gemm", 12, 7)];
        let mut digests = Vec::new();
        for (policy, pool, cache, batch) in [
            (Policy::Fifo, 1, true, true),
            (Policy::Sjf, 3, true, false),
            (Policy::Fifo, 2, false, true),
        ] {
            let mut s = Scheduler::new(aurora(), pool, policy)
                .with_cache(cache)
                .with_batching(batch);
            s.submit_all(&specs);
            s.drain().unwrap();
            let r = s.report();
            assert_eq!(r.completed, 3);
            assert_eq!(r.verify_failures, 0);
            digests.push(r.digest);
        }
        assert!(digests.windows(2).all(|w| w[0] == w[1]), "{digests:#x?}");
    }

    /// `y[i] = a*x[i] + y[i]` built with the public `KernelBuilder` — the
    /// arbitrary-kernel test payload (not a `workloads::by_name` entry).
    fn saxpy(n: i32) -> crate::compiler::ir::Kernel {
        use crate::compiler::ir::*;
        let mut b = KernelBuilder::new("saxpy_custom");
        let x = b.host_array("X", vec![ci(n)]);
        let y = b.host_array("Y", vec![ci(n)]);
        let a = b.float_param("a");
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(
                y,
                vec![var(i)],
                var(a).mul(ld(x, vec![var(i)])).add(ld(y, vec![var(i)])),
            )],
        )])
    }

    fn saxpy_job(n: i32, seed: u64) -> KernelJob {
        let xs = crate::workloads::gen_f32(seed, n as usize);
        let ys = crate::workloads::gen_f32(seed ^ 0xFF, n as usize);
        KernelJob::new(saxpy(n), vec![xs, ys], vec![3.0])
    }

    #[test]
    fn kernel_job_runs_and_returns_outputs() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h = s.submit_kernel(saxpy_job(64, 5));
        let state = s.wait(h).unwrap();
        let JobState::Done(o) = state else { panic!("not done: {state:?}") };
        assert!(o.verified);
        let arrays = o.arrays.as_ref().expect("kernel jobs carry their outputs");
        assert_eq!(arrays.len(), 2);
        let xs = crate::workloads::gen_f32(5, 64);
        let ys = crate::workloads::gen_f32(5 ^ 0xFF, 64);
        for i in 0..64 {
            assert_eq!(arrays[1][i], 3.0 * xs[i] + ys[i], "y[{i}]");
        }
        assert!(o.perf.is_some());
        assert!(o.device_cycles > 0);
    }

    #[test]
    fn kernel_jobs_batch_and_share_one_binary() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        for seed in 0..4 {
            s.submit_kernel(saxpy_job(64, seed));
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        // Structurally identical kernels hit one content-keyed entry and
        // chain onto instance 0 like a same-named batch.
        assert_eq!(r.cache_misses, 1);
        assert_eq!(r.instances[0].jobs, 4);
        assert_eq!(r.instances[1].jobs, 0);
    }

    #[test]
    fn kernel_and_named_jobs_share_one_queue() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo).with_batching(false);
        let hn = s.submit(job("gemm", 12, 1));
        let hk = s.submit_kernel(saxpy_job(32, 2));
        s.drain().unwrap();
        assert!(matches!(s.state(hn), Some(JobState::Done(_))));
        assert!(matches!(s.state(hk), Some(JobState::Done(_))));
        let r = s.report();
        assert_eq!(r.completed, 2);
        assert_eq!(r.cache_misses, 2);
    }

    #[test]
    fn kernel_job_payload_mismatch_rejected() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        // Two arrays declared, one provided.
        let h = s.submit_kernel(KernelJob::new(saxpy(16), vec![vec![0.0; 16]], vec![1.0]));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("array parameter"), "{reason}");
        // Wrong float-arg count.
        let h = s.submit_kernel(KernelJob::new(saxpy(16), vec![vec![0.0; 16]; 2], vec![]));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("float parameter"), "{reason}");
    }

    #[test]
    fn kernel_job_undersized_input_rejected() {
        // Constant-extent arrays must be backed by big-enough inputs — the
        // device would otherwise read past the buffer (same guard the
        // session's LaunchBuilder applies).
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h = s.submit_kernel(KernelJob::new(
            saxpy(64),
            vec![vec![0.0; 4], vec![0.0; 64]],
            vec![1.0],
        ));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("declares 64"), "{reason}");
    }

    #[test]
    fn take_payload_moves_outputs_out_once() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h = s.submit_kernel(saxpy_job(32, 3));
        s.drain().unwrap();
        let (arrays, perf) = s.take_payload(h).unwrap();
        assert_eq!(arrays.len(), 2);
        assert!(perf.is_some());
        // Second take: nothing left; metadata survives, payload is gone.
        assert!(s.take_payload(h).is_none());
        let o = s.poll(h).unwrap();
        assert!(o.device_cycles > 0);
        assert!(o.arrays.is_none() && o.perf.is_none());
        // Named jobs and foreign handles have no payload either.
        let hn = s.submit(job("gemm", 12, 1));
        s.drain().unwrap();
        assert!(s.take_payload(hn).is_none());
        assert!(s.take_payload(JobHandle(99)).is_none());
    }

    #[test]
    fn high_priority_dispatches_before_arrived_normal_work() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        s.submit(job("gemm", 12, 1));
        s.submit(job("atax", 24, 2));
        let hp = s.submit(JobDesc { priority: Priority::High, ..job("conv2d", 18, 3) });
        s.drain().unwrap();
        // The high job jumps the whole arrived queue; FIFO order within the
        // normal tier is untouched.
        assert_eq!(s.trace.dispatch_order(), vec![hp.0, 0, 1]);
        let r = s.report();
        assert_eq!(r.completed, 3);
        // Per-class turnaround reporting: both classes present, and the
        // queue-jumping high job turned around faster than the normal p50.
        let high = r.class(Priority::High).unwrap();
        let normal = r.class(Priority::Normal).unwrap();
        assert_eq!((high.jobs, normal.jobs), (1, 2));
        assert!(high.p95_turnaround_cycles <= normal.p50_turnaround_cycles);
        // The submit events carry the class.
        assert!(s
            .trace
            .events
            .iter()
            .any(|e| matches!(e, SchedEvent::Submitted { job, priority }
                if *job == hp.0 && priority.is_high())));
    }

    #[test]
    fn priority_breaks_same_cycle_future_arrival_ties() {
        // Nothing has arrived at the frontier: the earliest future arrival
        // dispatches, and among same-cycle arrivals the High job goes
        // first — the tier applies on this path too.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        s.submit(JobDesc { arrival: 1_000_000, ..job("gemm", 12, 1) });
        let hp = s.submit(JobDesc {
            arrival: 1_000_000,
            priority: Priority::High,
            ..job("atax", 24, 2)
        });
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![hp.0, 0]);
    }

    #[test]
    fn normal_followers_do_not_batch_onto_a_high_head() {
        // A Normal same-binary follower riding a High head would execute
        // ahead of other queued High work — batches stay within one class.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let hi1 = s.submit(JobDesc { priority: Priority::High, ..job("gemm", 12, 1) });
        let no = s.submit(job("gemm", 12, 2)); // same binary as hi1
        let hi2 = s.submit(JobDesc { priority: Priority::High, ..job("atax", 24, 3) });
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![hi1.0, hi2.0, no.0]);
        // Same-class same-binary jobs still batch.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        for seed in 0..3 {
            s.submit(JobDesc { priority: Priority::High, ..job("gemm", 12, seed) });
        }
        s.drain().unwrap();
        assert_eq!(s.report().cache_misses, 1);
        assert_eq!(s.trace.dispatch_order(), vec![0, 1, 2]);
    }

    #[test]
    fn all_normal_streams_are_unaffected_by_the_priority_tier() {
        // The tier filter must be a no-op for streams that never use
        // priorities: same dispatch order and digest as always.
        let mut s = Scheduler::new(aurora(), 1, Policy::Sjf).with_batching(false);
        s.submit(job("gemm", 24, 1));
        s.submit(job("gemm", 12, 2));
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![1, 0], "SJF still orders the normal tier");
    }

    #[test]
    fn pressure_placement_matches_earliest_free_on_an_uncontended_board() {
        // The safety identity the placement engine guarantees: with no
        // board contention, pressure scoring is bit-identical to
        // earliest-free — same dispatch sequence, same instances, same
        // makespan, same digest.
        let jobs: Vec<JobDesc> =
            (0..6).map(|i| job(["gemm", "atax", "conv2d"][i % 3], 24, i as u64)).collect();
        let run = |placement: Placement| {
            let mut s = Scheduler::new(aurora(), 3, Policy::Fifo)
                .with_placement(placement)
                .with_board(BoardSpec::uncontended())
                .with_verify(false);
            s.submit_all(&jobs);
            s.drain().unwrap();
            s
        };
        let ef = run(Placement::EarliestFree);
        let pr = run(Placement::Pressure);
        assert_eq!(ef.trace.events, pr.trace.events);
        let (re, rp) = (ef.report(), pr.report());
        assert_eq!(re.makespan_cycles, rp.makespan_cycles);
        assert_eq!(re.digest, rp.digest);
        assert_eq!(rp.placement, "pressure");
        for i in 0..3 {
            assert_eq!(re.instances[i].busy_cycles, rp.instances[i].busy_cycles);
        }
    }

    #[test]
    fn kernel_job_payloads_are_released_after_settling() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let h1 = s.submit_kernel(saxpy_job(64, 1));
        let h2 = s.submit_kernel(saxpy_job(64, 2));
        assert_eq!(s.retained_input_bytes(), 2 * 2 * 64 * 4);
        s.drain().unwrap();
        // Settled jobs drop their input snapshots (the serve-loop leak);
        // outcomes still hold everything a caller can ask for.
        assert_eq!(s.retained_input_bytes(), 0);
        assert!(s.poll(h1).unwrap().arrays.is_some());
        let (arrays, _) = s.take_payload(h2).unwrap();
        assert_eq!(arrays.len(), 2);
        // Rejected kernel jobs release immediately.
        let bad = s.submit_kernel(KernelJob::new(saxpy(16), vec![vec![0.0; 16]], vec![]));
        assert!(matches!(s.state(bad), Some(JobState::Rejected { .. })));
        assert_eq!(s.retained_input_bytes(), 0);
    }

    #[test]
    fn chained_kernel_job_consumes_producer_output() {
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
        let xs = crate::workloads::gen_f32(7, 64);
        let ys = crate::workloads::gen_f32(8, 64);
        let a = s.submit_kernel(KernelJob::new(saxpy(64), vec![xs.clone(), ys.clone()], vec![3.0]));
        // B reads A's output array 1 (the updated Y) as its X input; its
        // own Y starts zeroed. Same kernel content as A — the readiness
        // check must keep it out of A's batch.
        let b = s.submit_kernel(KernelJob::from_srcs(
            saxpy(64),
            vec![
                PayloadSrc::Output { producer: a, index: 1, elems: 64 },
                PayloadSrc::Data(vec![0.0; 64]),
            ],
            vec![2.0],
        ));
        assert!(s.retained_input_bytes() > 0);
        s.drain().unwrap();
        let (start_b, end_a) = (s.poll(b).unwrap().start, s.poll(a).unwrap().end);
        assert!(start_b >= end_a, "consumer started at {start_b} before producer ended at {end_a}");
        let ob = s.poll(b).unwrap();
        let arrays = ob.arrays.as_ref().expect("kernel jobs carry their outputs");
        for i in 0..64 {
            let ya = 3.0f32 * xs[i] + ys[i];
            assert_eq!(arrays[1][i], 2.0f32 * ya, "chained y[{i}]");
        }
        // Readiness surfaced in the trace; nothing retained after drain.
        assert!(s.trace.events.iter().any(|e| matches!(e,
            SchedEvent::DependencyReady { job, producer, .. }
                if *job == b.0 && *producer == a.0)));
        assert_eq!(s.retained_input_bytes(), 0);
    }

    #[test]
    fn consumer_of_rejected_producer_is_rejected() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        // Producer rejected at submission (arity mismatch).
        let bad = s.submit_kernel(KernelJob::new(saxpy(16), vec![vec![0.0; 16]], vec![1.0]));
        assert!(matches!(s.state(bad), Some(JobState::Rejected { .. })));
        let c = s.submit_kernel(KernelJob::from_srcs(
            saxpy(16),
            vec![
                PayloadSrc::Output { producer: bad, index: 1, elems: 16 },
                PayloadSrc::Data(vec![0.0; 16]),
            ],
            vec![1.0],
        ));
        let Some(JobState::Rejected { reason }) = s.state(c) else {
            panic!("expected rejection, got {:?}", s.state(c));
        };
        assert!(reason.contains("rejected"), "{reason}");
        // An edge whose element-count claim disagrees with the producer is
        // caught before any data exists.
        let a = s.submit_kernel(saxpy_job(64, 1));
        let c = s.submit_kernel(KernelJob::from_srcs(
            saxpy(64),
            vec![
                PayloadSrc::Output { producer: a, index: 1, elems: 128 },
                PayloadSrc::Data(vec![0.0; 64]),
            ],
            vec![1.0],
        ));
        let Some(JobState::Rejected { reason }) = s.state(c) else {
            panic!("expected rejection, got {:?}", s.state(c));
        };
        assert!(reason.contains("expects 128"), "{reason}");
        s.drain().unwrap();
    }

    #[test]
    fn failed_producer_cascades_to_queued_consumers() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let mut p = saxpy_job(32, 1);
        p.max_cycles = 1; // aborts mid-run: an execution failure at dispatch
        let a = s.submit_kernel(p);
        let b = s.submit_kernel(KernelJob::from_srcs(
            saxpy(32),
            vec![
                PayloadSrc::Output { producer: a, index: 1, elems: 32 },
                PayloadSrc::Data(vec![0.0; 32]),
            ],
            vec![1.0],
        ));
        s.drain().unwrap();
        assert!(matches!(s.state(a), Some(JobState::Rejected { .. })));
        let Some(JobState::Rejected { reason }) = s.state(b) else {
            panic!("expected cascaded rejection, got {:?}", s.state(b));
        };
        assert!(reason.contains("producer job"), "{reason}");
        assert_eq!(s.pending(), 0, "cascaded consumers must leave the queue");
        assert_eq!(s.retained_input_bytes(), 0);
    }

    #[test]
    fn dataflow_survives_take_payload() {
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let a = s.submit_kernel(saxpy_job(32, 5));
        s.drain().unwrap();
        // Consumer registered after the producer completed, and the
        // producer's payload moved out before the consumer runs: the feed
        // store must have its own copy.
        let b = s.submit_kernel(KernelJob::from_srcs(
            saxpy(32),
            vec![
                PayloadSrc::Output { producer: a, index: 1, elems: 32 },
                PayloadSrc::Data(vec![0.0; 32]),
            ],
            vec![2.0],
        ));
        let (arrays, _) = s.take_payload(a).unwrap();
        s.drain().unwrap();
        let ob = s.poll(b).unwrap();
        let got = ob.arrays.as_ref().unwrap();
        for i in 0..32 {
            assert_eq!(got[1][i], 2.0f32 * arrays[1][i], "y[{i}]");
        }
        assert_eq!(s.retained_input_bytes(), 0, "feeds drain with their consumers");
    }

    #[test]
    fn after_edge_orders_without_dataflow() {
        // A pure ordering edge serializes two jobs a pool of 2 would
        // otherwise run concurrently.
        let mut s = Scheduler::new(aurora(), 2, Policy::Fifo).with_batching(false);
        let a = s.submit_kernel(saxpy_job(64, 1));
        let mut ordered = saxpy_job(32, 2);
        ordered.after = vec![a];
        let b = s.submit_kernel(ordered);
        s.drain().unwrap();
        let (oa_end, ob_start) = (s.poll(a).unwrap().end, s.poll(b).unwrap().start);
        assert!(ob_start >= oa_end, "ordered job started {ob_start} before {oa_end}");
        // Without the edge the second job starts immediately on instance 1.
        let mut s2 = Scheduler::new(aurora(), 2, Policy::Fifo).with_batching(false);
        s2.submit_kernel(saxpy_job(64, 1));
        let b2 = s2.submit_kernel(saxpy_job(32, 2));
        s2.drain().unwrap();
        assert_eq!(s2.poll(b2).unwrap().start, 0);
    }

    #[test]
    fn kernel_job_capacity_admission_applies() {
        // gemm's handwritten tiling at N=64 overflows the shrunken L1; the
        // same IR submitted as an arbitrary kernel must be refused by the
        // capacity policy (split degrades to reject — no size semantics).
        let w = crate::workloads::gemm::build(64);
        for action in [OversizeAction::Reject, OversizeAction::Split] {
            let mut s = Scheduler::new(small_l1_cfg(), 1, Policy::Capacity(action));
            let h = s.submit_kernel(KernelJob::new(
                w.handwritten.clone(),
                w.gen_data(3),
                w.fargs.clone(),
            ));
            let Some(JobState::Rejected { reason }) = s.state(h) else {
                panic!("expected rejection, got {:?}", s.state(h));
            };
            assert!(
                reason.contains("hero_l1_capacity") || reason.contains("L1 overflow"),
                "{reason}"
            );
        }
    }

    // ---- shared-virtual-memory serving ----------------------------------

    fn svm_sched(mode: SvmMode) -> Scheduler {
        Scheduler::new(aurora(), 1, Policy::Fifo)
            .with_board(BoardSpec::with_bandwidth(16))
            .with_svm(SvmConfig::new(mode).with_host_bw(8))
    }

    /// The offload strategy moves cycles, never numerics: the same stream
    /// served pinned, copied, or auto-selected yields bit-identical report
    /// digests — and auto's makespan is no worse than the better fixed
    /// strategy (the Cheshire pin-vs-copy tradeoff, arXiv:2305.04760).
    #[test]
    fn svm_modes_are_digest_identical_and_auto_is_no_worse() {
        let mut runs = Vec::new();
        for over in [Some(SvmMode::Pin), Some(SvmMode::Copy), None] {
            let mut s = svm_sched(SvmMode::Auto);
            crate::svm::submit_svm_stream(&mut s, 16, 7, over).unwrap();
            s.drain().unwrap();
            let r = s.report();
            assert_eq!(r.completed, 16);
            assert!(r.host_dram_bytes > 0, "host traffic must be accounted");
            runs.push((r.digest, r.makespan_cycles, s.trace.render()));
        }
        assert_eq!(runs[0].0, runs[1].0, "pin vs copy digests diverge");
        assert_eq!(runs[1].0, runs[2].0, "copy vs auto digests diverge");
        let (pin, copy, auto) = (runs[0].1, runs[1].1, runs[2].1);
        assert!(auto <= pin.min(copy), "auto {auto} worse than pin {pin} / copy {copy}");
        // Auto genuinely mixes strategies on this stream: small reused
        // buffers pin (TLB warms), large streaming buffers copy.
        let auto_trace = &runs[2].2;
        assert!(auto_trace.contains("(pin:"), "{auto_trace}");
        assert!(auto_trace.contains("(copy:"), "{auto_trace}");
    }

    #[test]
    fn svm_operands_require_enablement_and_valid_buffers() {
        // No with_svm: VA-described operands cannot be served.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let k = crate::svm::scale_kernel("svm_scale_s", 64);
        let h = s.submit_kernel(KernelJob::from_srcs(
            k,
            vec![PayloadSrc::Svm { va: 0x40_0000_0000, elems: 64 }],
            vec![1.5],
        ));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("SVM serving is not enabled"), "{reason}");

        // Enabled, but the VA was never allocated / the view is oversized.
        let mut s = svm_sched(SvmMode::Pin);
        let k = crate::svm::scale_kernel("svm_scale_s", 64);
        let h = s.submit_kernel(KernelJob::from_srcs(
            k,
            vec![PayloadSrc::Svm { va: 0xdead_0000, elems: 64 }],
            vec![1.5],
        ));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("not an allocated buffer"), "{reason}");

        let va = s.svm_alloc_f32(vec![1.0; 16]).unwrap();
        let k = crate::svm::scale_kernel("svm_scale_s", 64);
        let h = s.submit_kernel(KernelJob::from_srcs(
            k,
            vec![PayloadSrc::Svm { va, elems: 64 }],
            vec![1.5],
        ));
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("holds 16 element(s)"), "{reason}");
    }

    /// SVM buffers are shared memory: the device result lands in the host's
    /// space, and a second job on the same buffer consumes it (submission
    /// order = data visibility).
    #[test]
    fn svm_write_back_chains_through_the_shared_buffer() {
        let mut s = svm_sched(SvmMode::Copy);
        let va = s.svm_alloc_f32(vec![2.0; 64]).unwrap();
        for _ in 0..2 {
            let k = crate::svm::scale_kernel("svm_scale_s", 64);
            let h = s.submit_kernel(KernelJob::from_srcs(
                k,
                vec![PayloadSrc::Svm { va, elems: 64 }],
                vec![1.5],
            ));
            let state = s.wait(h).unwrap();
            assert!(matches!(state, JobState::Done(_)), "{state:?}");
        }
        let out = s.svm_read_f32(va).unwrap();
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|&v| v == 4.5), "2.0 * 1.5 * 1.5 = {}", out[0]);
    }

    /// Enabling SVM must not perturb jobs that carry no SVM operands: the
    /// host port exists but sees no traffic, and serving is bit-identical
    /// to a scheduler without the subsystem.
    #[test]
    fn svm_enablement_leaves_plain_jobs_untouched() {
        let run = |svm: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
            if svm {
                s = s.with_svm(SvmConfig::new(SvmMode::Auto));
            }
            for seed in 0..4 {
                s.submit(job("gemm", 12, seed));
            }
            s.submit_kernel(saxpy_job(64, 9));
            s.drain().unwrap();
            (s.report(), s.trace.render())
        };
        let (plain, plain_trace) = run(false);
        let (svm, svm_trace) = run(true);
        assert_eq!(plain.digest, svm.digest);
        assert_eq!(plain.makespan_cycles, svm.makespan_cycles);
        assert_eq!(plain_trace, svm_trace);
        assert_eq!(svm.host_dram_bytes, 0);
        assert_eq!(svm.host_requests, 0);
        assert_eq!(svm.svm_mode, Some("auto"));
        assert_eq!(plain.svm_mode, None);
    }

    #[test]
    fn learning_scores_every_settled_job_without_touching_numerics() {
        // Wiring test for the measure -> refine loop: with learning on,
        // every completed job contributes one sample to the error report,
        // and both error figures are populated — while the digest stays
        // bit-identical to the learning-off run (refinement moves
        // predictions, never payloads).
        let jobs: Vec<JobDesc> =
            (0..6).map(|i| job(["gemm", "atax", "conv2d"][i % 3], 24, i as u64)).collect();
        let run = |learn: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Sjf)
                .with_batching(false)
                .with_learning(learn);
            s.submit_all(&jobs);
            s.drain().unwrap();
            s.report()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(on.completed, jobs.len());
        assert_eq!(off.digest, on.digest);
        assert!(on.learning);
        assert!(!off.learning);
        assert_eq!(on.predict_samples, jobs.len() as u64);
        assert_eq!(off.predict_samples, 0);
        // Refined error can never exceed static error under the EWMA: the
        // first observation of a key scores refined == static, and every
        // later one scores a figure pulled toward the measurement.
        assert!(on.predict_err_learned_pct <= on.predict_err_static_pct);
    }

    #[test]
    fn learning_refresh_reorders_queued_jobs_behind_a_repeat_offender() {
        // Two copies of the same kernel job whose `let`-bound trip count
        // the static model cannot see (it assumes 16 trips; the loop runs
        // 2000). A short job submitted *after* them statically looks the
        // same size. Once the first long copy completes, `learn_from`
        // rewrites the queued copy's memoized prediction, and SJF promotes
        // the short job ahead of it — with learning off, submission order
        // holds throughout.
        fn opaque(name: &str, trips: i32) -> crate::compiler::ir::Kernel {
            use crate::compiler::ir::*;
            let mut b = KernelBuilder::new(name);
            let x = b.host_array("X", vec![ci(64)]);
            let n = b.let_i32("n");
            let i = b.loop_var("i");
            b.body(vec![
                Stmt::Let { var: n, value: ci(trips) },
                for_(i, ci(0), var(n), vec![st(x, vec![ci(0)], ld(x, vec![ci(0)]).add(cf(1.0)))]),
            ])
        }
        let long = opaque("refresh_long", 2000);
        let short = opaque("refresh_short", 50);
        let run = |learn: bool| {
            let mut s = Scheduler::new(aurora(), 1, Policy::Sjf)
                .with_batching(false)
                .with_verify(false)
                .with_learning(learn);
            for k in [&long, &long, &short] {
                s.submit_kernel(KernelJob::new(k.clone(), vec![vec![0.0; 64]], Vec::new()));
            }
            s.drain().unwrap();
            (s.trace.dispatch_order(), s.report().digest)
        };
        let (static_order, static_digest) = run(false);
        let (learned_order, learned_digest) = run(true);
        assert_eq!(static_order, vec![0, 1, 2], "equal static predictions keep queue order");
        assert_eq!(learned_order, vec![0, 2, 1], "refresh promotes the short job");
        assert_eq!(static_digest, learned_digest, "reordering must never change numerics");
    }

    #[test]
    fn preemption_displaces_batch_followers_for_a_high_arrival() {
        // Three same-binary Normal jobs gather into one batch at cycle 0;
        // a High job lands at cycle 1 — long before the followers' planned
        // starts. With preemption the two followers are displaced back
        // into the queue, the High job dispatches next, and the followers
        // re-batch behind it on the cached binary. Numerics are untouched.
        let run = |preempt: bool| {
            let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_preemption(preempt);
            for seed in 0..3 {
                s.submit(job("gemm", 12, seed));
            }
            s.submit(JobDesc { arrival: 1, priority: Priority::High, ..job("atax", 24, 7) });
            s.drain().unwrap();
            s
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.trace.dispatch_order(), vec![0, 1, 2, 3]);
        assert_eq!(on.trace.dispatch_order(), vec![0, 3, 1, 2]);
        let (roff, ron) = (off.report(), on.report());
        assert_eq!(ron.completed, 4);
        assert_eq!(roff.digest, ron.digest, "displacement must never change numerics");
        assert_eq!((roff.preemptions, ron.preemptions), (0, 2));
        assert_eq!(ron.class(Priority::Normal).unwrap().preempted, 2);
        assert_eq!(ron.class(Priority::High).unwrap().preempted, 0);
        assert!(ron.preemption && !roff.preemption);
        // Both displaced followers carry Preempted events naming the High
        // job, and the binary compiled for the original batch head is a
        // cache hit when they re-dispatch.
        let preempted: Vec<usize> = on
            .trace
            .events
            .iter()
            .filter_map(|e| match e {
                SchedEvent::Preempted { job, by, .. } if *by == 3 => Some(*job),
                _ => None,
            })
            .collect();
        assert_eq!(preempted, vec![1, 2]);
        assert_eq!(ron.cache_misses, roff.cache_misses);
        // The High job's turnaround strictly improves by skipping the
        // followers it displaced.
        let hi = |r: &ServeReport| r.class(Priority::High).unwrap().p95_turnaround_cycles;
        assert!(hi(&ron) < hi(&roff), "{} vs {}", hi(&ron), hi(&roff));
    }

    #[test]
    fn preemption_never_displaces_high_followers() {
        // A High batch head with High followers: a later High arrival has
        // no displacement claim — preemption acts across classes, never
        // within one.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_preemption(true);
        for seed in 0..2 {
            s.submit(JobDesc { priority: Priority::High, ..job("gemm", 12, seed) });
        }
        s.submit(JobDesc { arrival: 1, priority: Priority::High, ..job("atax", 24, 5) });
        s.drain().unwrap();
        assert_eq!(s.trace.dispatch_order(), vec![0, 1, 2]);
        assert_eq!(s.report().preemptions, 0);
    }

    #[test]
    fn lookahead_window_keeps_digest_and_completes_everything() {
        // The joint search reorders only within the policy-ranked window:
        // every job still completes, numerics never move, and K=1 is the
        // greedy dispatch bit for bit (trace included).
        let jobs: Vec<JobDesc> =
            (0..8).map(|i| job(["gemm", "atax", "conv2d"][i % 3], 24, i as u64)).collect();
        let run = |k: usize| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Sjf)
                .with_batching(false)
                .with_lookahead(k);
            s.submit_all(&jobs);
            s.drain().unwrap();
            s
        };
        let greedy = run(1);
        let joint = run(4);
        assert_eq!(greedy.trace.events, run(1).trace.events, "K=1 is deterministic");
        let (rg, rj) = (greedy.report(), joint.report());
        assert_eq!(rj.completed, jobs.len());
        assert_eq!(rg.digest, rj.digest, "lookahead must never change numerics");
        assert_eq!((rg.lookahead, rj.lookahead), (1, 4));
    }

    #[test]
    fn autotune_beats_the_default_recipe_without_changing_numerics() {
        // conv2d N=182 is an overshoot case: the default AutoDMA descent
        // halves its tile side to 59 (a 4×4 tile grid) where side 64 fits
        // outright (3×3) — the tuner finds the win, the default recipe
        // never does. Two same-kernel jobs with batching off additionally
        // exercise the memo table (one search, one hit).
        let stream = || [job("conv2d", 182, 21), job("conv2d", 182, 22)];
        let run = |tune: bool| {
            let mut s = Scheduler::new(aurora(), 1, Policy::Fifo)
                .with_batching(false)
                .with_autotune(tune);
            for mut d in stream() {
                d.variant = Variant::AutoDma;
                s.submit(d);
            }
            s.drain().unwrap();
            s
        };
        let off = run(false);
        let on = run(true);
        let (roff, ron) = (off.report(), on.report());
        assert_eq!((roff.completed, ron.completed), (2, 2));
        assert_eq!((roff.verify_failures, ron.verify_failures), (0, 0));
        assert_eq!(roff.digest, ron.digest, "tuned recipes must preserve every bit");
        assert!(ron.autotune && !roff.autotune);
        assert_eq!((roff.tune_searches, roff.tune_hits), (0, 0));
        assert_eq!((ron.tune_searches, ron.tune_hits), (1, 1));
        assert!(
            ron.makespan_cycles < roff.makespan_cycles,
            "tuned {} must beat default {}",
            ron.makespan_cycles,
            roff.makespan_cycles
        );
        // The fresh search announces itself (once), memo hits stay silent.
        let tuned: Vec<&SchedEvent> = on
            .trace
            .events
            .iter()
            .filter(|e| matches!(e, SchedEvent::Tuned { .. }))
            .collect();
        assert_eq!(tuned.len(), 1, "{tuned:?}");
        let SchedEvent::Tuned { variant, candidates, predicted, default_predicted, .. } =
            tuned[0]
        else {
            unreachable!()
        };
        assert_ne!(variant.as_str(), "default");
        assert!(*candidates > 1);
        assert!(*predicted < *default_predicted);
    }

    #[test]
    fn heterogeneous_pool_tunes_per_instance_config() {
        use crate::config::preset::with_dma_width;
        let base = aurora();
        let cfgs = vec![with_dma_width(&base, 64), with_dma_width(&base, 128)];
        let mut s = Scheduler::new_heterogeneous(cfgs, Policy::Fifo)
            .with_batching(false)
            .with_autotune(true);
        for seed in 0..4 {
            s.submit(JobDesc { variant: Variant::AutoDma, ..job("gemm", 24, seed) });
        }
        s.drain().unwrap();
        let r = s.report();
        assert_eq!(r.completed, 4);
        assert_eq!(r.verify_failures, 0);
        assert!(r.instances.iter().all(|i| i.jobs > 0), "{r}");
        // The tuning key carries the instance's config name: each width ran
        // its own search (and kept its own binary).
        assert_eq!(r.tune_searches, 2, "{r}");
        assert_eq!(r.tune_hits, 2);
        assert!(r.cache_misses >= 2);
    }

    #[test]
    fn transient_faults_retry_and_preserve_numerics() {
        let plan = crate::fault::parse("seed=3,transient=30").unwrap();
        // Premises, checked against the same pure draw the scheduler uses:
        // at least one first attempt faults, and every job clears within
        // the retry budget below (so nothing fails permanently).
        assert!((0..12u64).any(|j| plan.draw(j, 0).is_some()), "seed must fault someone");
        for j in 0..12u64 {
            assert!((0..=8).any(|a| plan.draw(j, a).is_none()), "job {j} must clear");
        }
        let run = |faulted: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Fifo).with_retry(8);
            if faulted {
                s = s.with_faults(plan.clone());
            }
            for seed in 0..12 {
                s.submit(job("gemm", if seed % 2 == 0 { 12 } else { 24 }, seed));
            }
            s.drain().unwrap();
            s
        };
        let clean = run(false);
        let injected = run(true);
        let (rc, rf) = (clean.report(), injected.report());
        assert_eq!((rc.completed, rf.completed), (12, 12));
        assert_eq!(rf.fault_failures, 0, "{rf}");
        assert!(rf.faults_transient > 0, "{rf}");
        assert_eq!(rf.retries, rf.faults_transient, "every fault must be retried");
        assert!(rf.resilience && !rc.resilience);
        // Faulted attempts discard their results before digesting: a stream
        // whose faults are all retried is numerically untouched.
        assert_eq!(rc.digest, rf.digest, "retried faults must not touch numerics");
        assert!(injected.trace.events.iter().any(|e| matches!(e, SchedEvent::Faulted { .. })));
        assert!(injected.trace.events.iter().any(|e| matches!(e, SchedEvent::Retried { .. })));
    }

    #[test]
    fn same_plan_same_seed_is_deterministic() {
        let run = || {
            let mut s = Scheduler::new(aurora(), 2, Policy::Fifo)
                .with_faults(crate::fault::parse("seed=3,transient=30").unwrap())
                .with_retry(8);
            for seed in 0..10 {
                s.submit(job("gemm", 12, seed));
            }
            s.drain().unwrap();
            s
        };
        let (a, b) = (run(), run());
        assert_eq!(a.trace.events, b.trace.events, "fault schedule must be reproducible");
        assert_eq!(a.report().digest, b.report().digest);
    }

    #[test]
    fn exhausted_retries_fail_permanently() {
        // transient=100 faults every attempt: 1 initial + 2 retries, then
        // the job fails for good.
        let plan = crate::fault::parse("seed=1,transient=100").unwrap();
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_faults(plan).with_retry(2);
        let h = s.submit(job("gemm", 12, 0));
        s.drain().unwrap();
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected permanent fault, got {:?}", s.state(h));
        };
        assert!(reason.contains("transient fault after 3 attempt(s)"), "{reason}");
        let r = s.report();
        assert_eq!(r.completed, 0);
        assert_eq!(r.faults_transient, 3, "{r}");
        assert_eq!(r.retries, 2);
        assert_eq!(r.fault_failures, 1);
    }

    #[test]
    fn permanent_fault_cascades_to_dataflow_consumers() {
        let plan = crate::fault::parse("seed=1,transient=100").unwrap();
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_faults(plan).with_retry(1);
        let a = s.submit_kernel(saxpy_job(32, 1));
        let b = s.submit_kernel(KernelJob::from_srcs(
            saxpy(32),
            vec![
                PayloadSrc::Output { producer: a, index: 1, elems: 32 },
                PayloadSrc::Data(vec![0.0; 32]),
            ],
            vec![1.0],
        ));
        s.drain().unwrap();
        let Some(JobState::Rejected { reason }) = s.state(a) else {
            panic!("expected permanent fault, got {:?}", s.state(a));
        };
        assert!(reason.contains("transient fault after 2 attempt(s)"), "{reason}");
        let Some(JobState::Rejected { reason }) = s.state(b) else {
            panic!("expected cascaded rejection, got {:?}", s.state(b));
        };
        assert!(reason.contains("producer job"), "{reason}");
        assert_eq!(s.pending(), 0, "cascaded consumers must leave the queue");
    }

    #[test]
    fn watchdog_turns_budget_exhaustion_into_deadline_fault() {
        // Without the watchdog an exhausted simulation budget stays a plain
        // execution failure (the pre-fault contract)...
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
        let mut p = saxpy_job(32, 1);
        p.max_cycles = 1;
        let h = s.submit_kernel(p);
        s.drain().unwrap();
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected rejection, got {:?}", s.state(h));
        };
        assert!(reason.contains("execution failed"), "{reason}");
        // ...with it armed, the same overrun is a detected deadline fault:
        // non-retryable even with a retry budget.
        let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_watchdog(4).with_retry(3);
        let mut p = saxpy_job(32, 1);
        p.max_cycles = 1;
        let h = s.submit_kernel(p);
        s.drain().unwrap();
        let Some(JobState::Rejected { reason }) = s.state(h) else {
            panic!("expected deadline fault, got {:?}", s.state(h));
        };
        assert!(reason.contains("deadline fault after 1 attempt(s)"), "{reason}");
        let r = s.report();
        assert_eq!(r.faults_deadline, 1, "{r}");
        assert_eq!(r.retries, 0, "deadline faults are never retried");
        assert_eq!(r.fault_failures, 1);
    }

    #[test]
    fn resilience_off_is_bit_identical_to_default() {
        let run = |armed: bool| {
            let mut s = Scheduler::new(aurora(), 2, Policy::Fifo);
            if armed {
                // An empty plan and a retry budget arm nothing: no draws,
                // no watchdog, so every event must match the default run.
                s = s.with_faults(fault::FaultPlan::default()).with_retry(5);
            }
            for seed in 0..8 {
                s.submit(job("gemm", 12, seed));
            }
            s.drain().unwrap();
            s
        };
        let (plain, armed) = (run(false), run(true));
        assert_eq!(plain.trace.events, armed.trace.events);
        assert_eq!(plain.report().digest, armed.report().digest);
        assert!(!armed.report().resilience || armed.report().faults_transient == 0);
    }
}
