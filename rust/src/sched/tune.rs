//! Schedule-time tuned-variant selection (the tuning cache next to
//! [`super::cache::BinaryCache`]).
//!
//! [`crate::compiler::autotune::tune`] is a deterministic search, but it is
//! not free: it transforms, lowers and scores every candidate recipe. The
//! [`TuneStore`] memoizes one search result per
//! `(kernel content, input elems, threads, config)` key — the same identity
//! space the binary cache and [`super::learn::LearnStore`] use — so a
//! stream of same-kernel jobs searches once and every later dispatch is a
//! table lookup. Because the key carries the *instance's* config name, a
//! heterogeneous pool tunes per instance kind: the same job can pick a
//! different variant (and therefore a different binary) on a wide-NoC
//! instance than on a narrow one.
//!
//! Selection is [`choose`](TuneStore::choose): rank the memoized candidates
//! by predicted cycles — refined through the [`super::learn::LearnStore`]
//! when learning is on, under each *variant's own* content key
//! ([`super::job::tuned_variant_content`]) — and take the strict argmin,
//! first-wins. With learning off the choice is the static winner, the same
//! on every run; with learning on, measured cycles of a variant re-rank
//! only that variant, so a mispredicted recipe loses its slot after real
//! runs (the measure → re-rank loop). Either way the decision is a pure
//! function of store state, so identical streams make identical choices.

use super::job::tuned_variant_content;
use super::learn::{LearnKey, LearnStore};
use crate::compiler::autotune::{tune, TuneResult, TunedVariant};
use crate::compiler::ir::Kernel;
use crate::config::HeroConfig;
use std::collections::HashMap;

/// Identity of one tuning search: which kernel, at which input footprint,
/// lowered how wide, for which platform. Mirrors
/// [`super::cache::IrKey`]/[`super::learn::LearnKey`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TuneKey {
    /// Base structural content hash of the kernel with AutoDMA on
    /// ([`super::job::kernel_content_key`]) — *not* the tuned request key;
    /// variants derive their per-binary keys from this.
    pub content: u64,
    /// Input footprint in f32 elements.
    pub elems: u64,
    /// Effective thread count (clamped to the instance's cluster width).
    pub threads: u32,
    /// Instance configuration name (per-slot tuning on heterogeneous pools).
    pub config: String,
}

/// The outcome of one schedule-time variant selection.
#[derive(Debug, Clone)]
pub struct Choice {
    pub variant: TunedVariant,
    /// The score the variant won with (learn-refined when learning is on).
    pub predicted: u64,
    /// The default recipe's *static* prediction — the "untuned" yardstick
    /// surfaced in traces and reports.
    pub default_predicted: u64,
    /// Surviving candidates in the memoized search result.
    pub candidates: usize,
    /// Whether this choice ran the search (first sight of the key) rather
    /// than hitting the memo table.
    pub fresh: bool,
}

/// The refinement identity of one tuned variant under `key`: measurements
/// filed here describe exactly this recipe of this kernel on this config.
/// Used by [`TuneStore::choose`] for ranking and by the scheduler when it
/// books a tuned job's measured cycles.
pub fn variant_learn_key(key: &TuneKey, variant: &TunedVariant, teams: u32) -> LearnKey {
    LearnKey {
        content: tuned_variant_content(key.content, variant),
        elems: key.elems,
        threads: key.threads,
        teams,
        config: key.config.clone(),
    }
}

/// Memoized tuning searches plus selection statistics. Owned by the
/// scheduler when `--autotune` is on; absent choices cost nothing.
#[derive(Debug, Default)]
pub struct TuneStore {
    entries: HashMap<TuneKey, TuneResult>,
    /// Fresh searches run (memo misses).
    searches: u64,
    /// Choices served from the memo table.
    hits: u64,
    /// Choices where learn-refined ranking displaced the static winner.
    reranks: u64,
}

impl TuneStore {
    pub fn new() -> Self {
        TuneStore::default()
    }

    /// Pick the variant to compile for `key`, searching on first sight and
    /// ranking the memoized candidates by (optionally learn-refined)
    /// predicted cycles — strict argmin, first-wins, so the default recipe
    /// (always candidate 0) is only displaced by a strictly better score.
    pub fn choose(
        &mut self,
        key: &TuneKey,
        k: &Kernel,
        cfg: &HeroConfig,
        teams: u32,
        meas: Option<&LearnStore>,
    ) -> Choice {
        let fresh = !self.entries.contains_key(key);
        if fresh {
            self.searches += 1;
            self.entries.insert(key.clone(), tune(k, cfg, key.threads));
        } else {
            self.hits += 1;
        }
        let result = self.entries.get(key).expect("inserted above");
        let (mut best, mut best_score) = (0, u64::MAX);
        for (i, c) in result.candidates.iter().enumerate() {
            let score = match meas {
                Some(m) => m.refine(&variant_learn_key(key, &c.variant, teams), c.predicted),
                None => c.predicted,
            };
            if score < best_score {
                best = i;
                best_score = score;
            }
        }
        if result.candidates[best].variant != result.best().variant {
            self.reranks += 1;
        }
        Choice {
            variant: result.candidates[best].variant,
            predicted: best_score,
            default_predicted: result.default_predicted(),
            candidates: result.candidates.len(),
            fresh,
        }
    }

    /// The memoized static prediction of `variant` under `key` (the seed a
    /// measurement observation blends against), if the search has run and
    /// kept the variant.
    pub fn static_predicted(&self, key: &TuneKey, variant: &TunedVariant) -> Option<u64> {
        self.entries
            .get(key)?
            .candidates
            .iter()
            .find(|c| c.variant == *variant)
            .map(|c| c.predicted)
    }

    /// Distinct keys searched so far.
    pub fn tracked(&self) -> usize {
        self.entries.len()
    }

    /// Fresh searches run (memo misses).
    pub fn searches(&self) -> u64 {
        self.searches
    }

    /// Choices served from the memo table.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Choices where measurements displaced the static winner.
    pub fn reranks(&self) -> u64 {
        self.reranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::sched::job::kernel_content_key;

    fn key_for(k: &Kernel, cfg: &HeroConfig, elems: u64) -> TuneKey {
        TuneKey {
            content: kernel_content_key(k, true),
            elems,
            threads: 8,
            config: cfg.name.clone(),
        }
    }

    #[test]
    fn choices_are_deterministic_and_memoized() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let key = key_for(&w.unmodified, &cfg, 3 * 112 * 112);
        let mut store = TuneStore::new();
        let a = store.choose(&key, &w.unmodified, &cfg, 1, None);
        assert!(a.fresh, "first sight of a key runs the search");
        let b = store.choose(&key, &w.unmodified, &cfg, 1, None);
        assert!(!b.fresh, "second choice hits the memo table");
        assert_eq!(a.variant, b.variant);
        assert_eq!(a.predicted, b.predicted);
        assert_eq!((store.searches(), store.hits(), store.tracked()), (1, 1, 1));
        // Same inputs in a fresh store: same chosen variant (the
        // TuneStore-decisions-are-deterministic acceptance criterion).
        let mut other = TuneStore::new();
        let c = other.choose(&key, &w.unmodified, &cfg, 1, None);
        assert_eq!(a.variant, c.variant);
        assert_eq!(a.predicted, c.predicted);
    }

    #[test]
    fn static_choice_beats_default_where_the_search_found_a_win() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let key = key_for(&w.unmodified, &cfg, 3 * 112 * 112);
        let mut store = TuneStore::new();
        let c = store.choose(&key, &w.unmodified, &cfg, 1, None);
        assert!(c.predicted < c.default_predicted, "{c:?}");
        assert!(c.candidates > 1);
        assert!(!c.variant.is_default());
        assert_eq!(store.reranks(), 0, "no measurements, no re-ranking");
        assert_eq!(store.static_predicted(&key, &c.variant), Some(c.predicted));
    }

    #[test]
    fn measurements_rerank_the_choice() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let key = key_for(&w.unmodified, &cfg, 3 * 112 * 112);
        let mut store = TuneStore::new();
        let mut learn = LearnStore::new();
        let first = store.choose(&key, &w.unmodified, &cfg, 1, Some(&learn));
        assert!(!first.variant.is_default());
        // The statically-favored variant measures far slower than predicted;
        // the default recipe measures exactly as predicted.
        let stat = store.static_predicted(&key, &first.variant).unwrap();
        let def = first.default_predicted;
        for _ in 0..8 {
            learn.observe(variant_learn_key(&key, &first.variant, 1), stat, def * 10);
            learn.observe(
                variant_learn_key(&key, &TunedVariant::default_recipe(), 1),
                def,
                def,
            );
        }
        let second = store.choose(&key, &w.unmodified, &cfg, 1, Some(&learn));
        assert_ne!(second.variant, first.variant, "measured cycles must re-rank");
        assert_eq!(store.reranks(), 1);
        // Measurements refine per-variant: a third store with no
        // measurements still makes the static choice.
        let no_meas = store.choose(&key, &w.unmodified, &cfg, 1, None);
        assert_eq!(no_meas.variant, first.variant);
    }

    #[test]
    fn keys_separate_configs_and_sizes() {
        let cfg = aurora();
        let w = crate::workloads::gemm::build(112);
        let mut store = TuneStore::new();
        let k1 = key_for(&w.unmodified, &cfg, 3 * 112 * 112);
        let mut k2 = k1.clone();
        k2.config = "other".into();
        store.choose(&k1, &w.unmodified, &cfg, 1, None);
        store.choose(&k2, &w.unmodified, &cfg, 1, None);
        assert_eq!(store.searches(), 2, "per-config keys search separately");
        assert_eq!(store.tracked(), 2);
    }
}
