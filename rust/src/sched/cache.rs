//! The lowered-binary cache.
//!
//! Compiling a kernel (AutoDMA + address-space legalization + Xpulpv2
//! lowering) is host-side work the scheduler models with a simulated cycle
//! charge. Same-kernel jobs in a stream amortize it: the first dispatch of
//! a `(kernel, variant, size, threads, config)` combination lowers the
//! kernel and pays [`compile_cost_cycles`]; every later job reuses the
//! cached [`Lowered`] binary for free. This is the mechanism behind the
//! scheduler's batching — a batch of same-binary jobs pays one compile.

use crate::bench_harness::{compile_workload, variant_kernel, Variant};
use crate::compiler::{metrics, Lowered};
use crate::config::HeroConfig;
use crate::workloads::Workload;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Simulated compile-charge model: a fixed driver/JIT overhead plus a
/// per-statement lowering cost, in accelerator cycles (a few ms of host
/// time at the 50 MHz Aurora device clock).
pub const COMPILE_BASE_CYCLES: u64 = 25_000;
pub const COMPILE_CYCLES_PER_LOC: u64 = 1_500;

/// Cycles charged for lowering one workload variant.
pub fn compile_cost_cycles(w: &Workload, variant: Variant) -> u64 {
    let loc = metrics::complexity(variant_kernel(w, variant)).loc as u64;
    COMPILE_BASE_CYCLES + loc * COMPILE_CYCLES_PER_LOC
}

/// Cache key: everything that changes the lowered program.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BinKey {
    pub kernel: &'static str,
    pub variant: &'static str,
    pub size: usize,
    /// Effective core count the kernel was lowered for.
    pub threads: u32,
    pub config: String,
    pub xpulp: bool,
}

/// Build the cache key for a job on a platform configuration. The variant
/// is normalized the way `variant_kernel` resolves it — a Promoted request
/// on a workload without a promoted form compiles the handwritten kernel,
/// so it must share that cache entry rather than duplicate it.
pub fn key_for(cfg: &HeroConfig, w: &Workload, variant: Variant, threads: u32) -> BinKey {
    let variant = match variant {
        Variant::Promoted if w.promoted.is_none() => Variant::Handwritten,
        v => v,
    };
    BinKey {
        kernel: w.name,
        variant: variant.label(),
        size: w.size,
        threads: threads.min(cfg.accel.cores_per_cluster as u32),
        config: cfg.name.clone(),
        xpulp: cfg.accel.isa.xpulp,
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    /// Lowerings performed.
    pub misses: u64,
    /// Acquires served from the cache.
    pub hits: u64,
    /// Simulated compile cycles charged to dispatches.
    pub charged_cycles: u64,
}

struct Entry {
    lowered: Arc<Lowered>,
    cost: u64,
    /// Whether a dispatch has paid this entry's compile charge yet (probes
    /// from admission control fill the cache without consuming the charge).
    charged: bool,
}

/// Binary cache keyed on [`BinKey`]. With caching disabled every acquire
/// lowers afresh and pays the full charge — the scheduler bench's baseline.
pub struct BinaryCache {
    enabled: bool,
    map: HashMap<BinKey, Entry>,
    pub stats: CacheStats,
}

impl BinaryCache {
    pub fn new(enabled: bool) -> Self {
        BinaryCache { enabled, map: HashMap::new(), stats: CacheStats::default() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Number of distinct binaries currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch the binary for a job, lowering it on a miss. Returns the
    /// binary and the simulated compile cycles to charge this dispatch
    /// (non-zero exactly once per key while caching is on).
    pub fn acquire(
        &mut self,
        cfg: &HeroConfig,
        w: &Workload,
        variant: Variant,
        threads: u32,
    ) -> Result<(Arc<Lowered>, u64)> {
        if !self.enabled {
            let (lowered, _) = compile_workload(cfg, w, variant, threads)?;
            let cost = compile_cost_cycles(w, variant);
            self.stats.misses += 1;
            self.stats.charged_cycles += cost;
            return Ok((Arc::new(lowered), cost));
        }
        let key = key_for(cfg, w, variant, threads);
        if !self.map.contains_key(&key) {
            let (lowered, _) = compile_workload(cfg, w, variant, threads)?;
            let cost = compile_cost_cycles(w, variant);
            self.stats.misses += 1;
            self.map.insert(key.clone(), Entry { lowered: Arc::new(lowered), cost, charged: false });
        } else {
            self.stats.hits += 1;
        }
        let e = self.map.get_mut(&key).unwrap();
        let charge = if e.charged { 0 } else { e.cost };
        e.charged = true;
        self.stats.charged_cycles += charge;
        Ok((e.lowered.clone(), charge))
    }

    /// Admission probe: lower (and cache) without consuming the compile
    /// charge — the first real dispatch still pays it. With caching
    /// disabled the probe cannot be stored, so capacity admission on an
    /// uncached scheduler lowers each admitted job once at submit and again
    /// at dispatch; both lowerings show up in `stats.misses`.
    pub fn probe(
        &mut self,
        cfg: &HeroConfig,
        w: &Workload,
        variant: Variant,
        threads: u32,
    ) -> Result<Arc<Lowered>> {
        if !self.enabled {
            let (lowered, _) = compile_workload(cfg, w, variant, threads)?;
            self.stats.misses += 1;
            return Ok(Arc::new(lowered));
        }
        let key = key_for(cfg, w, variant, threads);
        if !self.map.contains_key(&key) {
            let (lowered, _) = compile_workload(cfg, w, variant, threads)?;
            let cost = compile_cost_cycles(w, variant);
            self.stats.misses += 1;
            self.map.insert(key.clone(), Entry { lowered: Arc::new(lowered), cost, charged: false });
        }
        Ok(self.map.get(&key).unwrap().lowered.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::workloads;

    #[test]
    fn charges_once_then_hits() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(true);
        let (_, cost1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(cost1 > 0);
        let (_, cost2) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert_eq!(cost2, 0);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.charged_cycles, cost1);
    }

    #[test]
    fn probe_fills_without_charging() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(true);
        let lowered = c.probe(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(lowered.l1_used > 0);
        assert_eq!(c.stats.charged_cycles, 0);
        // First dispatch after the probe still pays the compile.
        let (_, cost) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(cost > 0);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cfg = aurora();
        let w12 = workloads::gemm::build(12);
        let w16 = workloads::gemm::build(16);
        let mut c = BinaryCache::new(true);
        let (_, c1) = c.acquire(&cfg, &w12, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w16, Variant::Handwritten, 8).unwrap();
        let (_, c3) = c.acquire(&cfg, &w12, Variant::Promoted, 8).unwrap();
        let (_, c4) = c.acquire(&cfg, &w12, Variant::Handwritten, 4).unwrap();
        assert!(c1 > 0 && c2 > 0 && c3 > 0 && c4 > 0);
        assert_eq!(c.stats.misses, 4);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn disabled_cache_always_pays() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(false);
        let (_, c1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(c1 > 0 && c2 > 0);
        assert_eq!(c.stats.misses, 2);
        assert_eq!(c.stats.hits, 0);
        assert!(c.is_empty());
    }

    #[test]
    fn promoted_without_promoted_form_shares_handwritten_entry() {
        // conv2d has no promoted kernel: a Promoted job compiles the
        // handwritten form and must hit its cache entry.
        let cfg = aurora();
        let w = workloads::conv2d::build(18);
        let k_p = key_for(&cfg, &w, Variant::Promoted, 8);
        let k_h = key_for(&cfg, &w, Variant::Handwritten, 8);
        assert_eq!(k_p, k_h);
        let mut c = BinaryCache::new(true);
        let (_, c1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w, Variant::Promoted, 8).unwrap();
        assert!(c1 > 0);
        assert_eq!(c2, 0);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn threads_normalized_to_cluster_width() {
        let cfg = aurora(); // 8 cores per cluster
        let w = workloads::gemm::build(12);
        let k8 = key_for(&cfg, &w, Variant::Handwritten, 8);
        let k99 = key_for(&cfg, &w, Variant::Handwritten, 99);
        assert_eq!(k8, k99);
    }
}
