//! The lowered-binary cache.
//!
//! Compiling a kernel (AutoDMA + address-space legalization + Xpulpv2
//! lowering) is host-side work the scheduler models with a simulated cycle
//! charge. Same-kernel jobs in a stream amortize it: the first dispatch of
//! a `(kernel, variant, size, threads, config)` combination lowers the
//! kernel and pays [`compile_cost_cycles`]; every later job reuses the
//! cached [`Lowered`] binary for free. This is the mechanism behind the
//! scheduler's batching — a batch of same-binary jobs pays one compile.
//!
//! Two key spaces share the cache: registry workloads are keyed by
//! [`BinKey`] (name, variant, size, threads, config), arbitrary
//! compiled-kernel jobs by [`IrKey`] (a structural content hash from
//! [`super::job::kernel_content_key`], threads, config). Both sides share
//! the hit/miss/charge statistics, so `hero serve` reports are uniform.

use crate::bench_harness::{compile_kernel, compile_workload, variant_kernel, Variant};
use crate::compiler::ir::Kernel;
use crate::compiler::{metrics, AutoDmaReport, Lowered};
use crate::config::HeroConfig;
use crate::workloads::Workload;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Simulated compile-charge model: a fixed driver/JIT overhead plus a
/// per-statement lowering cost, in accelerator cycles (a few ms of host
/// time at the 50 MHz Aurora device clock).
pub const COMPILE_BASE_CYCLES: u64 = 25_000;
pub const COMPILE_CYCLES_PER_LOC: u64 = 1_500;

/// Cycles charged for lowering one workload variant.
pub fn compile_cost_cycles(w: &Workload, variant: Variant) -> u64 {
    compile_kernel_cost_cycles(variant_kernel(w, variant))
}

/// Cycles charged for lowering an arbitrary kernel (same LoC-proportional
/// model the registry workloads pay).
pub fn compile_kernel_cost_cycles(k: &Kernel) -> u64 {
    COMPILE_BASE_CYCLES + metrics::complexity(k).loc as u64 * COMPILE_CYCLES_PER_LOC
}

/// Cache key: everything that changes the lowered program.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BinKey {
    pub kernel: &'static str,
    pub variant: &'static str,
    pub size: usize,
    /// Effective core count the kernel was lowered for.
    pub threads: u32,
    pub config: String,
    pub xpulp: bool,
}

/// Build the cache key for a job on a platform configuration. The variant
/// is normalized the way `variant_kernel` resolves it — a Promoted request
/// on a workload without a promoted form compiles the handwritten kernel,
/// so it must share that cache entry rather than duplicate it.
pub fn key_for(cfg: &HeroConfig, w: &Workload, variant: Variant, threads: u32) -> BinKey {
    let variant = match variant {
        Variant::Promoted if w.promoted.is_none() => Variant::Handwritten,
        v => v,
    };
    BinKey {
        kernel: w.name,
        variant: variant.label(),
        size: w.size,
        threads: threads.min(cfg.accel.cores_per_cluster as u32),
        config: cfg.name.clone(),
        xpulp: cfg.accel.isa.xpulp,
    }
}

/// Cache key for an arbitrary compiled-kernel job: everything that changes
/// the lowered program. `content` is the structural IR hash
/// ([`super::job::kernel_content_key`], which folds in the AutoDMA flag).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct IrKey {
    pub content: u64,
    /// Effective core count the kernel is lowered for.
    pub threads: u32,
    pub config: String,
    pub xpulp: bool,
}

/// Build the IR cache key for a kernel job on a platform configuration
/// (threads normalized to the cluster width, like [`key_for`]).
pub fn ir_key_for(cfg: &HeroConfig, content: u64, threads: u32) -> IrKey {
    IrKey {
        content,
        threads: threads.min(cfg.accel.cores_per_cluster as u32),
        config: cfg.name.clone(),
        xpulp: cfg.accel.isa.xpulp,
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    /// Lowerings performed.
    pub misses: u64,
    /// Acquires served from the cache.
    pub hits: u64,
    /// Simulated compile cycles charged to dispatches.
    pub charged_cycles: u64,
}

struct Entry {
    lowered: Arc<Lowered>,
    /// AutoDMA transformation report, when the entry's compile ran the pass.
    report: Option<AutoDmaReport>,
    cost: u64,
    /// Whether a dispatch has paid this entry's compile charge yet (probes
    /// from admission control fill the cache without consuming the charge).
    charged: bool,
}

/// Ensure `key` is present in `map`, lowering via `compile` on a miss
/// (which returns the binary, its report, and its compile cost) and
/// booking the miss/hit on `stats`. The single fill path under both key
/// spaces and both the acquire and probe entry points.
fn fill<K: std::hash::Hash + Eq + Clone>(
    map: &mut HashMap<K, Entry>,
    stats: &mut CacheStats,
    key: &K,
    compile: impl FnOnce() -> Result<(Lowered, Option<AutoDmaReport>, u64)>,
    count_hit: bool,
) -> Result<()> {
    if !map.contains_key(key) {
        let (lowered, report, cost) = compile()?;
        stats.misses += 1;
        map.insert(key.clone(), Entry { lowered: Arc::new(lowered), report, cost, charged: false });
    } else if count_hit {
        stats.hits += 1;
    }
    Ok(())
}

/// [`fill`] + consume the entry's one-time compile charge (the acquire
/// semantics). Returns the binary, the cycles to charge this dispatch, and
/// the entry's AutoDMA report.
fn fill_and_charge<K: std::hash::Hash + Eq + Clone>(
    map: &mut HashMap<K, Entry>,
    stats: &mut CacheStats,
    key: &K,
    compile: impl FnOnce() -> Result<(Lowered, Option<AutoDmaReport>, u64)>,
) -> Result<(Arc<Lowered>, u64, Option<AutoDmaReport>)> {
    fill(map, stats, key, compile, true)?;
    let e = map.get_mut(key).unwrap();
    let charge = if e.charged { 0 } else { e.cost };
    e.charged = true;
    stats.charged_cycles += charge;
    Ok((e.lowered.clone(), charge, e.report.clone()))
}

/// The caching-disabled path: lower afresh, count the miss, optionally pay
/// the full charge (acquires pay, probes do not).
fn compile_uncached(
    stats: &mut CacheStats,
    compile: impl FnOnce() -> Result<(Lowered, Option<AutoDmaReport>, u64)>,
    pay: bool,
) -> Result<(Arc<Lowered>, u64, Option<AutoDmaReport>)> {
    let (lowered, report, cost) = compile()?;
    stats.misses += 1;
    let charge = if pay {
        stats.charged_cycles += cost;
        cost
    } else {
        0
    };
    Ok((Arc::new(lowered), charge, report))
}

/// Binary cache keyed on [`BinKey`] (registry workloads) and [`IrKey`]
/// (arbitrary kernels). With caching disabled every acquire lowers afresh
/// and pays the full charge — the scheduler bench's baseline.
pub struct BinaryCache {
    enabled: bool,
    map: HashMap<BinKey, Entry>,
    ir_map: HashMap<IrKey, Entry>,
    pub stats: CacheStats,
}

impl BinaryCache {
    pub fn new(enabled: bool) -> Self {
        BinaryCache {
            enabled,
            map: HashMap::new(),
            ir_map: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Number of distinct binaries currently cached (both key spaces).
    pub fn len(&self) -> usize {
        self.map.len() + self.ir_map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty() && self.ir_map.is_empty()
    }

    /// Fetch the binary for a job, lowering it on a miss. Returns the
    /// binary and the simulated compile cycles to charge this dispatch
    /// (non-zero exactly once per key while caching is on).
    pub fn acquire(
        &mut self,
        cfg: &HeroConfig,
        w: &Workload,
        variant: Variant,
        threads: u32,
    ) -> Result<(Arc<Lowered>, u64)> {
        let compile = || {
            compile_workload(cfg, w, variant, threads)
                .map(|(l, r)| (l, r, compile_cost_cycles(w, variant)))
        };
        if !self.enabled {
            return compile_uncached(&mut self.stats, compile, true).map(|(l, c, _)| (l, c));
        }
        let key = key_for(cfg, w, variant, threads);
        fill_and_charge(&mut self.map, &mut self.stats, &key, compile).map(|(l, c, _)| (l, c))
    }

    /// Fetch the binary for an arbitrary-kernel job, lowering on a miss —
    /// the [`IrKey`] analogue of [`BinaryCache::acquire`]. `content` is the
    /// job's structural hash ([`super::job::kernel_content_key`], which
    /// already folds in `autodma`). Also returns the entry's AutoDMA
    /// report, for front doors that surface it (`hero run`).
    pub fn acquire_ir(
        &mut self,
        cfg: &HeroConfig,
        k: &Kernel,
        autodma: bool,
        threads: u32,
        content: u64,
    ) -> Result<(Arc<Lowered>, u64, Option<AutoDmaReport>)> {
        let compile = || {
            compile_kernel(cfg, k, autodma, threads)
                .map(|(l, r)| (l, r, compile_kernel_cost_cycles(k)))
        };
        if !self.enabled {
            return compile_uncached(&mut self.stats, compile, true);
        }
        let key = ir_key_for(cfg, content, threads);
        fill_and_charge(&mut self.ir_map, &mut self.stats, &key, compile)
    }

    /// Fetch the binary for a *tuned* kernel job: like
    /// [`BinaryCache::acquire_ir`], but lowering under a chosen AutoDMA
    /// recipe ([`crate::compiler::TunedVariant`]) on a miss. `content` must
    /// be the variant's binary-content key
    /// ([`super::job::tuned_variant_content`]) so distinct recipes of one
    /// kernel occupy distinct rows; the compile charge is the same
    /// LoC-proportional cost as the untuned path (the tuning *search* is
    /// host-side work, surfaced as an untimed `Tuned` trace event, never a
    /// device-cycle charge).
    pub fn acquire_ir_tuned(
        &mut self,
        cfg: &HeroConfig,
        k: &Kernel,
        variant: &crate::compiler::TunedVariant,
        threads: u32,
        content: u64,
    ) -> Result<(Arc<Lowered>, u64, Option<AutoDmaReport>)> {
        let compile = || {
            crate::bench_harness::compile_kernel_tuned(cfg, k, variant, threads)
                .map(|(l, r)| (l, r, compile_kernel_cost_cycles(k)))
        };
        if !self.enabled {
            return compile_uncached(&mut self.stats, compile, true);
        }
        let key = ir_key_for(cfg, content, threads);
        fill_and_charge(&mut self.ir_map, &mut self.stats, &key, compile)
    }

    /// Admission probe: lower (and cache) without consuming the compile
    /// charge — the first real dispatch still pays it. With caching
    /// disabled the probe cannot be stored, so capacity admission on an
    /// uncached scheduler lowers each admitted job once at submit and again
    /// at dispatch; both lowerings show up in `stats.misses`.
    pub fn probe(
        &mut self,
        cfg: &HeroConfig,
        w: &Workload,
        variant: Variant,
        threads: u32,
    ) -> Result<Arc<Lowered>> {
        let compile = || {
            compile_workload(cfg, w, variant, threads)
                .map(|(l, r)| (l, r, compile_cost_cycles(w, variant)))
        };
        if !self.enabled {
            return compile_uncached(&mut self.stats, compile, false).map(|(l, ..)| l);
        }
        let key = key_for(cfg, w, variant, threads);
        fill(&mut self.map, &mut self.stats, &key, compile, false)?;
        Ok(self.map.get(&key).unwrap().lowered.clone())
    }

    /// Admission probe for an arbitrary-kernel job: lower (and cache)
    /// without consuming the compile charge (see [`BinaryCache::probe`]).
    pub fn probe_ir(
        &mut self,
        cfg: &HeroConfig,
        k: &Kernel,
        autodma: bool,
        threads: u32,
        content: u64,
    ) -> Result<Arc<Lowered>> {
        let compile = || {
            compile_kernel(cfg, k, autodma, threads)
                .map(|(l, r)| (l, r, compile_kernel_cost_cycles(k)))
        };
        if !self.enabled {
            return compile_uncached(&mut self.stats, compile, false).map(|(l, ..)| l);
        }
        let key = ir_key_for(cfg, content, threads);
        fill(&mut self.ir_map, &mut self.stats, &key, compile, false)?;
        Ok(self.ir_map.get(&key).unwrap().lowered.clone())
    }

    /// Read-only warmth check: is a binary for `key` already cached? Unlike
    /// [`BinaryCache::probe`] this never compiles or fills — it exists for
    /// cross-board affinity scoring (`fleet`), where a router asks many
    /// boards the same question and must not mutate any of them. Always
    /// `false` with caching disabled (nothing is ever retained).
    pub fn contains(&self, key: &BinKey) -> bool {
        self.enabled && self.map.contains_key(key)
    }

    /// Read-only warmth check for the [`IrKey`] space (see
    /// [`BinaryCache::contains`]).
    pub fn contains_ir(&self, key: &IrKey) -> bool {
        self.enabled && self.ir_map.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::workloads;

    #[test]
    fn charges_once_then_hits() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(true);
        let (_, cost1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(cost1 > 0);
        let (_, cost2) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert_eq!(cost2, 0);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.charged_cycles, cost1);
    }

    #[test]
    fn probe_fills_without_charging() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(true);
        let lowered = c.probe(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(lowered.l1_used > 0);
        assert_eq!(c.stats.charged_cycles, 0);
        // First dispatch after the probe still pays the compile.
        let (_, cost) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(cost > 0);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let cfg = aurora();
        let w12 = workloads::gemm::build(12);
        let w16 = workloads::gemm::build(16);
        let mut c = BinaryCache::new(true);
        let (_, c1) = c.acquire(&cfg, &w12, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w16, Variant::Handwritten, 8).unwrap();
        let (_, c3) = c.acquire(&cfg, &w12, Variant::Promoted, 8).unwrap();
        let (_, c4) = c.acquire(&cfg, &w12, Variant::Handwritten, 4).unwrap();
        assert!(c1 > 0 && c2 > 0 && c3 > 0 && c4 > 0);
        assert_eq!(c.stats.misses, 4);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn disabled_cache_always_pays() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut c = BinaryCache::new(false);
        let (_, c1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(c1 > 0 && c2 > 0);
        assert_eq!(c.stats.misses, 2);
        assert_eq!(c.stats.hits, 0);
        assert!(c.is_empty());
    }

    #[test]
    fn promoted_without_promoted_form_shares_handwritten_entry() {
        // conv2d has no promoted kernel: a Promoted job compiles the
        // handwritten form and must hit its cache entry.
        let cfg = aurora();
        let w = workloads::conv2d::build(18);
        let k_p = key_for(&cfg, &w, Variant::Promoted, 8);
        let k_h = key_for(&cfg, &w, Variant::Handwritten, 8);
        assert_eq!(k_p, k_h);
        let mut c = BinaryCache::new(true);
        let (_, c1) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        let (_, c2) = c.acquire(&cfg, &w, Variant::Promoted, 8).unwrap();
        assert!(c1 > 0);
        assert_eq!(c2, 0);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn threads_normalized_to_cluster_width() {
        let cfg = aurora(); // 8 cores per cluster
        let w = workloads::gemm::build(12);
        let k8 = key_for(&cfg, &w, Variant::Handwritten, 8);
        let k99 = key_for(&cfg, &w, Variant::Handwritten, 99);
        assert_eq!(k8, k99);
    }

    #[test]
    fn ir_path_charges_once_then_hits() {
        use crate::sched::job::kernel_content_key;
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let k = &w.handwritten;
        let content = kernel_content_key(k, false);
        let mut c = BinaryCache::new(true);
        let (l1, c1, _) = c.acquire_ir(&cfg, k, false, 8, content).unwrap();
        assert!(c1 > 0);
        assert!(l1.l1_used > 0);
        let (_, c2, _) = c.acquire_ir(&cfg, k, false, 8, content).unwrap();
        assert_eq!(c2, 0);
        assert_eq!((c.stats.misses, c.stats.hits), (1, 1));
        assert_eq!(c.len(), 1);
        // IR keys live in their own space: the registry entry for the same
        // kernel does not collide with the content-hash entry.
        let (_, c3) = c.acquire(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(c3 > 0);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn tuned_path_charges_once_and_keeps_variants_apart() {
        use crate::compiler::TunedVariant;
        use crate::sched::job::{kernel_content_key, tuned_variant_content};
        let cfg = aurora();
        let w = workloads::gemm::build(112);
        let base = kernel_content_key(&w.unmodified, true);
        let default = TunedVariant::default_recipe();
        let tiled = TunedVariant { staging: true, tile_side: Some(64), double_buffer: false };
        let mut c = BinaryCache::new(true);
        let dc = tuned_variant_content(base, &default);
        let (_, c1, r1) = c.acquire_ir_tuned(&cfg, &w.unmodified, &default, 8, dc).unwrap();
        assert!(c1 > 0);
        assert!(r1.is_some(), "staged variants carry an AutoDMA report");
        let (_, c2, _) = c.acquire_ir_tuned(&cfg, &w.unmodified, &default, 8, dc).unwrap();
        assert_eq!(c2, 0, "same variant hits its entry");
        // A different recipe is a different binary: separate row, own charge.
        let tc = tuned_variant_content(base, &tiled);
        let (_, c3, _) = c.acquire_ir_tuned(&cfg, &w.unmodified, &tiled, 8, tc).unwrap();
        assert!(c3 > 0);
        assert_eq!((c.stats.misses, c.stats.hits), (2, 1));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn contains_is_read_only_and_false_when_disabled() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let key = key_for(&cfg, &w, Variant::Handwritten, 8);
        let mut c = BinaryCache::new(true);
        assert!(!c.contains(&key), "cold cache has nothing");
        assert_eq!(c.stats.misses, 0, "contains never compiles");
        c.probe(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(c.contains(&key), "probe fills the entry contains sees");
        // Disabled cache: probe cannot retain, contains stays false.
        let mut off = BinaryCache::new(false);
        off.probe(&cfg, &w, Variant::Handwritten, 8).unwrap();
        assert!(!off.contains(&key));
    }

    #[test]
    fn contains_ir_tracks_the_ir_key_space() {
        use crate::sched::job::kernel_content_key;
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let content = kernel_content_key(&w.handwritten, false);
        let key = ir_key_for(&cfg, content, 8);
        let mut c = BinaryCache::new(true);
        assert!(!c.contains_ir(&key));
        c.probe_ir(&cfg, &w.handwritten, false, 8, content).unwrap();
        assert!(c.contains_ir(&key));
        // The BinKey space is disjoint: warming an IR entry does not warm
        // the registry entry for the same kernel.
        assert!(!c.contains(&key_for(&cfg, &w, Variant::Handwritten, 8)));
    }

    #[test]
    fn ir_probe_fills_without_charging() {
        use crate::sched::job::kernel_content_key;
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let content = kernel_content_key(&w.handwritten, false);
        let mut c = BinaryCache::new(true);
        let lowered = c.probe_ir(&cfg, &w.handwritten, false, 8, content).unwrap();
        assert!(lowered.l1_used > 0);
        assert_eq!(c.stats.charged_cycles, 0);
        let (_, cost, _) = c.acquire_ir(&cfg, &w.handwritten, false, 8, content).unwrap();
        assert!(cost > 0);
        assert_eq!(c.stats.misses, 1);
    }
}
