//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a *seeded, reproducible* failure schedule: given the
//! same plan, a serve run injects exactly the same faults at exactly the
//! same points, so faulty runs are as bit-reproducible as healthy ones —
//! every fault is expressed in simulated cycles or drawn from a counter-based
//! hash, never from wall-clock or ambient randomness. Three fault classes
//! model the failure modes a carrier-board fleet sees:
//!
//! * **Transient kernel faults** ([`FaultKind::Transient`]): the offload
//!   runs to completion but delivers a fault instead of a result (a soft
//!   error in the datapath). The instance was occupied for the full run;
//!   the result is discarded and never touches digests, feeds or learning.
//! * **DMA/NoC timeouts** ([`FaultKind::Timeout`]): the offload hangs on
//!   its transfer path and the watchdog reclaims the instance after the
//!   job's deadline elapses (deadline = watchdog multiplier × predicted
//!   cycles; see `sched/README.md`).
//! * **Board failures** ([`BoardFault`]): in a fleet, a whole board goes
//!   unhealthy at cycle `down_at` (optionally recovering at `up_at`). The
//!   router drains dispatches that started before the failure, evacuates
//!   the queued remainder to surviving boards, and records the health
//!   timeline (see `fleet/README.md`).
//!
//! A fourth kind, [`FaultKind::DeadlineExceeded`], is *detected*, not
//! injected: with a watchdog armed, a job whose measured cycles exceed its
//! deadline — or whose simulation budget ([`crate::sched::KernelJob`]'s
//! `max_cycles`) runs out — faults instead of completing. Detected
//! deadline faults are deterministic (the same job overruns every time),
//! so they fail permanently rather than burning retries.
//!
//! ## Determinism contract
//!
//! Instance-level faults are drawn per `(job, attempt)` from a splitmix64
//! hash of the plan seed ([`FaultPlan::draw`]) — no RNG state advances, so
//! whether job 17's second attempt faults is a pure function of the plan,
//! independent of pool size, placement, policy or what other jobs did.
//! Retried attempts re-draw with a fresh counter, which is what lets a
//! transiently-faulted job eventually succeed.
//!
//! ## Backoff math
//!
//! Retry `n` (1-based) of a faulted job becomes eligible
//! [`RETRY_BACKOFF_CYCLES`]` × 2^(n-1)` cycles after the faulted attempt's
//! occupancy window closed ([`backoff_cycles`]; the shift saturates at 20
//! so the delay stays finite). The job re-enters the queue as ready work
//! with its priority, arrival stamp and dataflow edges intact — only its
//! *effective arrival* is floored by the backoff.

/// Cycles of backoff before a faulted job's first retry; doubles per
/// attempt ([`backoff_cycles`]).
pub const RETRY_BACKOFF_CYCLES: u64 = 1_000;

/// Watchdog deadline multiplier assumed when a plan injects timeout
/// faults but no explicit multiplier was configured
/// (`Scheduler::with_watchdog`).
pub const DEFAULT_WATCHDOG_MULT: u64 = 4;

/// Exponential backoff: the delay between a faulted attempt settling and
/// its retry (attempt `n`, 1-based) becoming eligible for dispatch.
pub fn backoff_cycles(attempt: u32) -> u64 {
    RETRY_BACKOFF_CYCLES << attempt.saturating_sub(1).min(20)
}

/// What kind of fault a job suffered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The offload completed but produced a fault instead of a result.
    Transient,
    /// The offload's DMA/NoC path hung; the watchdog reclaimed the
    /// instance at the job's deadline.
    Timeout,
    /// The job overran its measured deadline or simulation budget
    /// (detected, deterministic, never retried).
    DeadlineExceeded,
}

impl FaultKind {
    /// Stable label (trace events and report lines).
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Timeout => "timeout",
            FaultKind::DeadlineExceeded => "deadline",
        }
    }

    /// Index into per-kind counters (`[transient, timeout, deadline]`).
    pub fn index(&self) -> usize {
        match self {
            FaultKind::Transient => 0,
            FaultKind::Timeout => 1,
            FaultKind::DeadlineExceeded => 2,
        }
    }

    /// Whether the retry policy applies: injected faults are worth
    /// retrying (the next attempt draws fresh), detected deadline
    /// overruns are deterministic and are not.
    pub fn retryable(&self) -> bool {
        !matches!(self, FaultKind::DeadlineExceeded)
    }
}

/// A board-level failure in a fleet: the board is unhealthy from
/// `down_at`, optionally recovering at `up_at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoardFault {
    pub board: usize,
    /// Cycle the board goes unhealthy (dispatches that started earlier
    /// complete; the queued remainder is evacuated).
    pub down_at: u64,
    /// Cycle the board rejoins the healthy set, if it recovers.
    pub up_at: Option<u64>,
}

/// A seeded, reproducible fault schedule (see the module docs for the
/// taxonomy and determinism contract). The default plan injects nothing.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    /// Hash seed for the per-(job, attempt) instance-fault draws.
    pub seed: u64,
    /// Percent (0–100) of attempts that suffer a transient kernel fault.
    pub transient_pct: u32,
    /// Percent (0–100) of attempts that suffer a DMA/NoC timeout.
    pub timeout_pct: u32,
    /// Board-level failures (fleet runs only; single boards ignore them).
    pub boards: Vec<BoardFault>,
}

/// splitmix64 finalizer — the counter-based hash behind [`FaultPlan::draw`].
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Whether the plan injects instance-level (per-attempt) faults at
    /// all — what obliges the scheduler to compute predictions (timeout
    /// occupancy is deadline-priced).
    pub fn has_instance_faults(&self) -> bool {
        self.transient_pct > 0 || self.timeout_pct > 0
    }

    /// Deterministically decide whether attempt `attempt` of job `job`
    /// faults, and how. Pure function of `(seed, job, attempt)`.
    pub fn draw(&self, job: u64, attempt: u32) -> Option<FaultKind> {
        if !self.has_instance_faults() {
            return None;
        }
        let h = mix(self.seed ^ mix(job ^ (u64::from(attempt) << 40)));
        let roll = (h % 100) as u32;
        if roll < self.transient_pct {
            Some(FaultKind::Transient)
        } else if roll < self.transient_pct + self.timeout_pct {
            Some(FaultKind::Timeout)
        } else {
            None
        }
    }

    /// The plan's board failures that apply to a fleet of `boards`
    /// boards, in `down_at` order (ties by board index — the order the
    /// router processes them in).
    pub fn kills_for(&self, boards: usize) -> Vec<BoardFault> {
        let mut kills: Vec<BoardFault> =
            self.boards.iter().copied().filter(|k| k.board < boards).collect();
        kills.sort_by_key(|k| (k.down_at, k.board));
        kills
    }
}

/// Parse a `--faults` plan: comma-separated clauses
/// `seed=N`, `transient=PCT`, `timeout=PCT`, `kill=BOARD@CYCLE`,
/// `recover=BOARD@CYCLE` — or the literal `demo` preset (seed 7, 10%
/// transient faults, board 1 killed mid-stream), the CI smoke plan.
/// Percentages must sum to at most 100; `recover` needs a matching
/// earlier `kill` with a smaller cycle.
pub fn parse(spec: &str) -> Result<FaultPlan, String> {
    if spec == "demo" {
        return Ok(FaultPlan {
            seed: 7,
            transient_pct: 10,
            timeout_pct: 0,
            boards: vec![BoardFault { board: 1, down_at: 1_000_000, up_at: None }],
        });
    }
    let mut plan = FaultPlan::default();
    for raw in spec.split(',') {
        let raw = raw.trim();
        let Some((key, val)) = raw.split_once('=') else {
            return Err(format!(
                "fault clause {raw:?}: expected `key=value` \
                 (seed=N, transient=PCT, timeout=PCT, kill=B@C, recover=B@C)"
            ));
        };
        let number = |field: &str, what: &str| -> Result<u64, String> {
            field.parse().map_err(|_| format!("fault clause {raw:?}: bad {what} {field:?}"))
        };
        let board_at = |what: &str| -> Result<(usize, u64), String> {
            let Some((b, c)) = val.split_once('@') else {
                return Err(format!("fault clause {raw:?}: expected `{what}=BOARD@CYCLE`"));
            };
            Ok((number(b, "board")? as usize, number(c, "cycle")?))
        };
        match key {
            "seed" => plan.seed = number(val, "seed")?,
            "transient" => plan.transient_pct = number(val, "percentage")? as u32,
            "timeout" => plan.timeout_pct = number(val, "percentage")? as u32,
            "kill" => {
                let (board, down_at) = board_at("kill")?;
                if plan.boards.iter().any(|k| k.board == board) {
                    return Err(format!("duplicate kill for board {board}"));
                }
                plan.boards.push(BoardFault { board, down_at, up_at: None });
            }
            "recover" => {
                let (board, up_at) = board_at("recover")?;
                let Some(k) = plan.boards.iter_mut().find(|k| k.board == board) else {
                    return Err(format!("recover for board {board} without a matching kill"));
                };
                if up_at <= k.down_at {
                    return Err(format!(
                        "board {board} recovers at cycle {up_at}, not after its kill at \
                         cycle {}",
                        k.down_at
                    ));
                }
                k.up_at = Some(up_at);
            }
            _ => return Err(format!("unknown fault clause {raw:?}")),
        }
    }
    if plan.transient_pct + plan.timeout_pct > 100 {
        return Err(format!(
            "transient ({}) + timeout ({}) percentages exceed 100",
            plan.transient_pct, plan.timeout_pct
        ));
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_grammar_and_rejects_garbage() {
        let p = parse("seed=42,transient=5,timeout=3,kill=1@5000,recover=1@9000").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!((p.transient_pct, p.timeout_pct), (5, 3));
        assert_eq!(
            p.boards,
            vec![BoardFault { board: 1, down_at: 5000, up_at: Some(9000) }]
        );
        let demo = parse("demo").unwrap();
        assert!(demo.has_instance_faults());
        assert_eq!(demo.kills_for(2).len(), 1);
        assert_eq!(demo.kills_for(1).len(), 0, "kills outside the fleet are dropped");
        assert!(parse("").unwrap_err().contains("key=value"));
        assert!(parse("chaos=1").unwrap_err().contains("unknown fault clause"));
        assert!(parse("seed=x").unwrap_err().contains("bad seed"));
        assert!(parse("kill=1").unwrap_err().contains("BOARD@CYCLE"));
        assert!(parse("kill=1@5,kill=1@9").unwrap_err().contains("duplicate kill"));
        assert!(parse("recover=0@5").unwrap_err().contains("without a matching kill"));
        assert!(parse("kill=0@9,recover=0@9").unwrap_err().contains("not after its kill"));
        assert!(parse("transient=80,timeout=30").unwrap_err().contains("exceed 100"));
    }

    #[test]
    fn draws_are_deterministic_and_attempt_sensitive() {
        let p = parse("seed=7,transient=10,timeout=10").unwrap();
        for job in 0..64u64 {
            for attempt in 0..4u32 {
                assert_eq!(p.draw(job, attempt), p.draw(job, attempt), "pure function");
            }
        }
        // Roughly the configured rate, and not all draws agree across
        // attempts (what makes retries worth anything).
        let faults = (0..1000u64).filter(|&j| p.draw(j, 0).is_some()).count();
        assert!((100..350).contains(&faults), "~20% of 1000 draws, got {faults}");
        let changed = (0..1000u64).filter(|&j| p.draw(j, 0) != p.draw(j, 1)).count();
        assert!(changed > 0, "fresh attempts must re-draw");
        assert_eq!(FaultPlan::default().draw(3, 0), None, "empty plans never fault");
    }

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_cycles(1), RETRY_BACKOFF_CYCLES);
        assert_eq!(backoff_cycles(2), 2 * RETRY_BACKOFF_CYCLES);
        assert_eq!(backoff_cycles(3), 4 * RETRY_BACKOFF_CYCLES);
        assert_eq!(backoff_cycles(21), backoff_cycles(40), "shift saturates");
    }

    #[test]
    fn kind_labels_and_retryability() {
        assert_eq!(FaultKind::Transient.label(), "transient");
        assert_eq!(FaultKind::Timeout.label(), "timeout");
        assert_eq!(FaultKind::DeadlineExceeded.label(), "deadline");
        assert!(FaultKind::Transient.retryable());
        assert!(FaultKind::Timeout.retryable());
        assert!(!FaultKind::DeadlineExceeded.retryable());
        assert_eq!(FaultKind::Timeout.index(), 1);
    }
}
