//! Regeneration of every table and figure in the paper's evaluation (§3).
//!
//! Each `figN` function runs the relevant workload matrix through the full
//! stack, verifies the numerics against the host golden model, and returns
//! structured rows; the `benches/figN.rs` targets print them side by side
//! with the paper's reported values. Absolute cycle counts are not expected
//! to match the authors' FPGA (DESIGN.md §6) — the *shape* (who wins, by
//! roughly what factor) is the reproduction target.

use super::{geomean, run_workload, verify, RunOutcome, Variant};
use crate::compiler::metrics;
use crate::config::{aurora, HeroConfig};
use crate::isa::Inst;
use crate::workloads::{self, Workload};
use anyhow::Result;

const MAX_CYCLES: u64 = 50_000_000_000;
const SEED: u64 = 2022;

/// Use tiny sizes when `HERO_FAST=1` (CI smoke runs).
pub fn bench_workloads() -> Vec<Workload> {
    if std::env::var("HERO_FAST").as_deref() == Ok("1") {
        workloads::all_tiny()
    } else {
        workloads::all_default()
    }
}

fn checked(cfg: &HeroConfig, w: &Workload, v: Variant, threads: u32) -> Result<RunOutcome> {
    let out = run_workload(cfg, w, v, threads, SEED, MAX_CYCLES)?;
    verify(w, &out, SEED)?;
    Ok(out)
}

// --- Fig 4 ------------------------------------------------------------------

/// Fig 4: speed-up of local-memory execution with handwritten DMA over
/// execution on external main memory (1 thread), plus the DMA cycle share.
pub struct Fig4Row {
    pub name: &'static str,
    pub speedup: f64,
    pub dma_share_pct: f64,
}

pub fn fig4(cfg: &HeroConfig) -> Result<Vec<Fig4Row>> {
    let mut rows = Vec::new();
    for w in bench_workloads() {
        let base = checked(cfg, &w, Variant::Unmodified, 1)?;
        let hand = checked(cfg, &w, Variant::Handwritten, 1)?;
        rows.push(Fig4Row {
            name: w.name,
            speedup: base.cycles() as f64 / hand.cycles() as f64,
            dma_share_pct: 100.0 * hand.dma_cycles() as f64 / hand.cycles() as f64,
        });
    }
    Ok(rows)
}

// --- Fig 5 ------------------------------------------------------------------

/// Fig 5: 8-thread vs 1-thread speed-up: computation-only, overall, and the
/// DMA share at 8 threads.
pub struct Fig5Row {
    pub name: &'static str,
    pub comp_speedup: f64,
    pub overall_speedup: f64,
    pub dma_share_pct: f64,
}

pub fn fig5(cfg: &HeroConfig) -> Result<Vec<Fig5Row>> {
    let threads = cfg.accel.cores_per_cluster as u32;
    let mut rows = Vec::new();
    for w in bench_workloads() {
        let t1 = checked(cfg, &w, Variant::Handwritten, 1)?;
        let t8 = checked(cfg, &w, Variant::Handwritten, threads)?;
        rows.push(Fig5Row {
            name: w.name,
            comp_speedup: t1.compute_cycles() as f64 / t8.compute_cycles() as f64,
            overall_speedup: t1.cycles() as f64 / t8.cycles() as f64,
            dma_share_pct: 100.0 * t8.dma_cycles() as f64 / t8.cycles() as f64,
        });
    }
    Ok(rows)
}

// --- Fig 6 ------------------------------------------------------------------

/// Fig 6: code complexity of the handwritten tiled implementation relative
/// to the unmodified program (CCCC lines-of-code and McCabe cyclomatic).
pub struct Fig6Row {
    pub name: &'static str,
    pub loc_unmodified: u32,
    pub loc_handwritten: u32,
    pub cyc_unmodified: u32,
    pub cyc_handwritten: u32,
}

impl Fig6Row {
    pub fn loc_ratio(&self) -> f64 {
        self.loc_handwritten as f64 / self.loc_unmodified as f64
    }
    pub fn cyc_ratio(&self) -> f64 {
        self.cyc_handwritten as f64 / self.cyc_unmodified as f64
    }
}

pub fn fig6() -> Vec<Fig6Row> {
    workloads::all_default()
        .iter()
        .map(|w| {
            let u = metrics::complexity(&w.unmodified);
            let h = metrics::complexity(&w.handwritten);
            Fig6Row {
                name: w.name,
                loc_unmodified: u.loc,
                loc_handwritten: h.loc,
                cyc_unmodified: u.cyclomatic,
                cyc_handwritten: h.cyclomatic,
            }
        })
        .collect()
}

// --- Fig 7 ------------------------------------------------------------------

/// Fig 7: speed-up of compiler-generated (AutoDMA) and handwritten tiling
/// over execution on external main memory, 8 threads.
pub struct Fig7Row {
    pub name: &'static str,
    pub autodma_speedup: f64,
    pub handwritten_speedup: f64,
}

pub fn fig7(cfg: &HeroConfig) -> Result<Vec<Fig7Row>> {
    let threads = cfg.accel.cores_per_cluster as u32;
    let mut rows = Vec::new();
    for w in bench_workloads() {
        let base = checked(cfg, &w, Variant::Unmodified, threads)?;
        let auto = checked(cfg, &w, Variant::AutoDma, threads)?;
        let hand = checked(cfg, &w, Variant::Handwritten, threads)?;
        rows.push(Fig7Row {
            name: w.name,
            autodma_speedup: base.cycles() as f64 / auto.cycles() as f64,
            handwritten_speedup: base.cycles() as f64 / hand.cycles() as f64,
        });
    }
    Ok(rows)
}

// --- Fig 8 ------------------------------------------------------------------

/// Fig 8: effect of the accelerator on-chip network data width (32/128 bit
/// vs the 64-bit default) on DMA, computation, and total cycles.
pub struct Fig8Row {
    pub name: &'static str,
    pub width_bits: u32,
    pub dma_ratio: f64,
    pub comp_ratio: f64,
    pub total_ratio: f64,
}

pub fn fig8(base_cfg: &HeroConfig) -> Result<Vec<Fig8Row>> {
    let threads = base_cfg.accel.cores_per_cluster as u32;
    let mut rows = Vec::new();
    for w in bench_workloads() {
        let run_width = |bits: u32| -> Result<RunOutcome> {
            let mut cfg = base_cfg.clone();
            cfg.noc.dma_width_bits = bits;
            checked(&cfg, &w, Variant::Handwritten, threads)
        };
        let r64 = run_width(64)?;
        for bits in [32u32, 128] {
            let r = run_width(bits)?;
            rows.push(Fig8Row {
                name: w.name,
                width_bits: bits,
                dma_ratio: r64.dma_cycles() as f64 / r.dma_cycles().max(1) as f64,
                comp_ratio: r64.compute_cycles() as f64 / r.compute_cycles() as f64,
                total_ratio: r64.cycles() as f64 / r.cycles() as f64,
            });
        }
    }
    Ok(rows)
}

// --- Fig 9 ------------------------------------------------------------------

/// Fig 9: speed-up of the Xpulpv2 ISA extension over RV32IMAFC, with
/// handwritten DMA and 8 threads. Three bars: compiler-generated Xpulpv2,
/// + manual register promotion, + expert inline assembly (modeled — see
/// [`expert_factor`]).
pub struct Fig9Row {
    pub name: &'static str,
    pub xpulp_speedup: f64,
    pub promoted_speedup: f64,
    pub expert_speedup: f64,
    /// Innermost-loop instruction counts (base ISA vs Xpulpv2 vs promoted)
    /// — the paper's 10 → 5 → 4 analysis for gemm.
    pub inner_base: usize,
    pub inner_xpulp: usize,
    pub inner_promoted: usize,
}

/// Expert-written inline assembly comparator, as a factor on the promoted
/// compiler output. §3.4 found the compiler's instructions "perform on-par
/// or better than the expert-written instructions" — for covar the compiler
/// *outperformed* the expert "due to better scheduling". We model the expert
/// at parity except covar's documented scheduling loss.
pub fn expert_factor(name: &str) -> f64 {
    match name {
        "covar" => 0.94,
        _ => 1.0,
    }
}

pub fn fig9(base_cfg: &HeroConfig) -> Result<Vec<Fig9Row>> {
    let threads = base_cfg.accel.cores_per_cluster as u32;
    let mut base_isa = base_cfg.clone();
    base_isa.accel.isa.xpulp = false;
    let mut rows = Vec::new();
    for w in bench_workloads() {
        let base = checked(&base_isa, &w, Variant::Handwritten, threads)?;
        let xp = checked(base_cfg, &w, Variant::Handwritten, threads)?;
        let prom = checked(base_cfg, &w, Variant::Promoted, threads)?;
        let s1 = base.cycles() as f64 / xp.cycles() as f64;
        let s2 = base.cycles() as f64 / prom.cycles() as f64;
        rows.push(Fig9Row {
            name: w.name,
            xpulp_speedup: s1,
            promoted_speedup: s2,
            expert_speedup: s2 * expert_factor(w.name),
            inner_base: inner_loop_len(&base_prog(&base_isa, &w, Variant::Handwritten)?),
            inner_xpulp: inner_loop_len(&base_prog(base_cfg, &w, Variant::Handwritten)?),
            inner_promoted: inner_loop_len(&base_prog(base_cfg, &w, Variant::Promoted)?),
        });
    }
    Ok(rows)
}

fn base_prog(
    cfg: &HeroConfig,
    w: &Workload,
    v: Variant,
) -> Result<crate::isa::Program> {
    let opts = crate::compiler::LowerOpts::for_config(cfg);
    let kernel = match v {
        Variant::Handwritten => &w.handwritten,
        Variant::Promoted => w.promoted.as_ref().unwrap_or(&w.handwritten),
        _ => &w.unmodified,
    };
    let (lowered, _) = crate::compiler::compile(kernel, &opts, None)?;
    Ok(lowered.program)
}

/// Length of the (static) innermost loop body: the smallest hardware-loop
/// body, or the smallest backward-branch span when no hardware loops exist.
pub fn inner_loop_len(p: &crate::isa::Program) -> usize {
    let mut best = usize::MAX;
    for (i, inst) in p.insts.iter().enumerate() {
        match inst {
            Inst::HwLoop { start, end, .. } => {
                best = best.min((*end - *start) as usize);
            }
            Inst::Branch { target, .. } if (*target as usize) < i => {
                best = best.min(i - *target as usize + 1);
            }
            _ => {}
        }
    }
    if best == usize::MAX {
        0
    } else {
        best
    }
}

// --- Tables ------------------------------------------------------------------

/// Table 1: platform configurations.
pub fn table1() -> String {
    use crate::config::resources;
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>12} {:>12} {:>14}\n",
        "Configuration", "Aurora", "Blizzard", "Cyclone"
    ));
    let cfgs = [aurora(), crate::config::blizzard(), crate::config::cyclone()];
    let row = |label: &str, f: &dyn Fn(&HeroConfig) -> String| -> String {
        format!(
            "{:<16} {:>12} {:>12} {:>14}\n",
            label,
            f(&cfgs[0]),
            f(&cfgs[1]),
            f(&cfgs[2])
        )
    };
    out.push_str(&row("Host ISA", &|c| c.host.isa.clone()));
    out.push_str(&row("Host Core Arch.", &|c| c.host.core_arch.clone()));
    out.push_str(&row("Host # Cores", &|c| c.host.n_cores.to_string()));
    out.push_str(&row("Accel. ISA", &|c| c.accel.isa.name()));
    out.push_str(&row("Accel. Core", &|c| c.accel.core_arch.clone()));
    out.push_str(&row("Accel. # Cores", &|c| c.n_accel_cores().to_string()));
    out.push_str(&row("Carrier", &|c| c.carrier.clone()));
    out.push_str(&row("Freq. (MHz)", &|c| c.accel.freq_mhz.to_string()));
    out.push_str(&row("Status", &|c| c.status.clone()));
    out.push('\n');
    // E9: the FPGA resource model vs the paper's reported utilization.
    let u = resources::utilization(&aurora(), &resources::ZU9EG);
    let est = resources::estimate(&aurora(), &resources::ZU9EG);
    out.push_str(&format!(
        "Aurora on ZU9EG (resource model): CLB {:.1} % (paper 98.1 %), BRAM {:.1} % \
         (paper 24.2 %), DSP {:.1} % (paper 2.9 %), est. {:.0} MHz (paper 50 MHz)\n",
        100.0 * u.clb,
        100.0 * u.bram,
        100.0 * u.dsp,
        est.freq_mhz
    ));
    out
}

/// Table 2: evaluated kernels with complexity classes.
pub fn table2() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<8} {:>6} {:<44} {:>8} {:>8}\n",
        "Kernel", "N", "Accelerated computation", "space", "compute"
    ));
    let desc: &[(&str, &str, &str, &str)] = &[
        ("2mm", "C = alpha*A*B", "N^2", "N^3"),
        ("3mm", "E = 2mm(A,B) -> F = 2mm(C,D) -> G = 2mm(E,F)", "N^2", "N^3"),
        ("atax", "B = A*x -> Y_i = sum_j A[j,i]*B_j", "N^2", "N^2"),
        ("bicg", "Q = A*P -> S_j = sum_i R_i*A[i,j]", "N^2", "N^2"),
        ("conv2d", "B[i,j] = sum_kl c[k,l]*A[i+k,j+l]", "N^2", "N^2"),
        ("covar", "E = a*sum D; D -= E; S = D^T*D", "N^2", "N^3"),
        ("darknet", "YOLO conv layer as C = alpha*A*B (2D-tiled)", "N^2", "N^3"),
        ("gemm", "C = beta*C + alpha*A*B", "N^2", "N^3"),
    ];
    for (w, (_, d, s, c)) in workloads::all_default().iter().zip(desc) {
        out.push_str(&format!("{:<8} {:>6} {:<44} {:>8} {:>8}\n", w.name, w.size, d, s, c));
    }
    out
}

/// Summary line used by several benches.
pub fn summarize_speedups(label: &str, xs: &[f64]) -> String {
    format!(
        "{label}: min {:.2}x, max {:.2}x, geomean {:.2}x",
        xs.iter().cloned().fold(f64::INFINITY, f64::min),
        xs.iter().cloned().fold(0.0, f64::max),
        geomean(xs)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_matches_paper_categories() {
        let rows = fig6();
        // The six 1D-tiled kernels: modest overhead. darknet (2D): higher.
        // covar (2 passes of 2D tiling): highest LoC overhead.
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        for n in ["2mm", "3mm", "atax", "bicg", "conv2d", "gemm"] {
            let r = by_name(n);
            assert!(
                (1.3..3.6).contains(&r.loc_ratio()),
                "{n} LoC ratio {:.2}",
                r.loc_ratio()
            );
        }
        let dk = by_name("darknet");
        assert!(dk.loc_ratio() > 2.5, "darknet 2D tiling should cost more LoC");
        assert!(dk.cyc_ratio() > 2.0, "darknet 2D tiling adds decision points");
        // covar's two 2D passes are expensive in absolute added lines (the
        // paper's 6.3x ratio divides by a one-line kernel; our unmodified
        // covar already carries three nests, so the ratio is smaller but the
        // absolute overhead is the largest — see EXPERIMENTS.md).
        let cv = by_name("covar");
        let added = |r: &Fig6Row| r.loc_handwritten - r.loc_unmodified;
        for n in ["2mm", "gemm", "conv2d", "bicg", "atax", "darknet"] {
            assert!(
                added(cv) >= added(by_name(n)),
                "covar's two 2D passes must add more lines than {n}: {} vs {}",
                added(cv),
                added(by_name(n))
            );
        }
    }

    #[test]
    fn inner_loop_len_finds_hwloop() {
        use crate::isa::{Inst as I, Program};
        let p = Program::new(vec![
            I::Li { rd: 1, imm: 3 },
            I::HwLoop { l: 0, count: 1, start: 2, end: 5 },
            I::Nop,
            I::Nop,
            I::Nop,
            I::Halt,
        ]);
        assert_eq!(inner_loop_len(&p), 3);
    }

    #[test]
    fn tables_render() {
        let t1 = table1();
        assert!(t1.contains("Aurora"));
        assert!(t1.contains("RV32IMAFCXpulpv2"));
        let t2 = table2();
        assert!(t2.contains("darknet"));
    }
}
