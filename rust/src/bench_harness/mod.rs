//! Benchmark harness: run workloads through the full stack and regenerate
//! the paper's tables and figures.
//!
//! Every evaluation artifact (Table 1/2, Figs 4–9) has a `cargo bench`
//! target built on [`run_workload`]: compile a workload variant for a
//! platform configuration, execute it on the simulated accelerator through
//! the OpenMP offload runtime, verify the numerics against the host golden
//! model (and, when artifacts are built, against the PJRT-executed
//! JAX/Pallas golden model), and report cycle counts and counter breakdowns.

pub mod emit;
pub mod figures;
pub mod stats;

use crate::compiler::{self, AutoDmaOpts, AutoDmaReport, LowerOpts};
use crate::config::HeroConfig;
use crate::runtime::omp::OffloadResult;
use crate::workloads::Workload;
use anyhow::{anyhow, bail, Result};

/// Which form of the kernel to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Plain OpenMP, executing on external main memory (Fig 4/7 baseline).
    Unmodified,
    /// Handwritten tiling + DMA (Figs 4, 5, 8, 9).
    Handwritten,
    /// Handwritten + manual register promotion (Fig 9, second bar).
    Promoted,
    /// Compiler-generated tiling + DMA (Fig 7).
    AutoDma,
}

impl Variant {
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Unmodified => "unmodified",
            Variant::Handwritten => "handwritten",
            Variant::Promoted => "promoted",
            Variant::AutoDma => "autodma",
        }
    }
}

/// Outcome of one full-stack run.
pub struct RunOutcome {
    pub result: OffloadResult,
    /// Final contents of every mapped array.
    pub arrays: Vec<Vec<f32>>,
    /// AutoDMA report (AutoDma variant only).
    pub report: Option<AutoDmaReport>,
    /// Static instruction count of the lowered kernel.
    pub text_size: usize,
}

impl RunOutcome {
    /// Cycles attributable to DMA (descriptor setup + core-visible waits).
    pub fn dma_cycles(&self) -> u64 {
        self.result.perf.dma_attributed_cycles()
    }

    /// Total device cycles.
    pub fn cycles(&self) -> u64 {
        self.result.device_cycles
    }

    /// Compute cycles = total − DMA-attributable.
    pub fn compute_cycles(&self) -> u64 {
        self.cycles().saturating_sub(self.dma_cycles())
    }
}

/// The kernel form a variant executes (pre-AutoDMA for the AutoDma variant).
pub fn variant_kernel<'a>(w: &'a Workload, variant: Variant) -> &'a crate::compiler::Kernel {
    match variant {
        Variant::Unmodified | Variant::AutoDma => &w.unmodified,
        Variant::Handwritten => &w.handwritten,
        Variant::Promoted => w.promoted.as_ref().unwrap_or(&w.handwritten),
    }
}

/// Lower an arbitrary kernel for `threads` OpenMP threads on `cfg` — the
/// one lowering recipe (thread clamping, optional AutoDMA) shared by the
/// named-workload path below and the scheduler's kernel-job cache.
pub fn compile_kernel(
    cfg: &HeroConfig,
    k: &compiler::Kernel,
    autodma: bool,
    threads: u32,
) -> Result<(compiler::Lowered, Option<AutoDmaReport>)> {
    let mut opts = LowerOpts::for_config(cfg);
    opts.n_cores = threads.min(cfg.accel.cores_per_cluster as u32);
    let ad = autodma.then(|| AutoDmaOpts::for_config(cfg));
    compiler::compile(k, &opts, ad.as_ref())
}

/// Lower a kernel under a tuned AutoDMA recipe ([`crate::compiler::autotune`]).
/// Same thread clamping as [`compile_kernel`]; the variant supplies (or
/// suppresses) the AutoDMA options. `TunedVariant::default_recipe()` compiles
/// bit-identically to `compile_kernel(cfg, k, true, threads)`.
pub fn compile_kernel_tuned(
    cfg: &HeroConfig,
    k: &compiler::Kernel,
    variant: &compiler::TunedVariant,
    threads: u32,
) -> Result<(compiler::Lowered, Option<AutoDmaReport>)> {
    let mut opts = LowerOpts::for_config(cfg);
    opts.n_cores = threads.min(cfg.accel.cores_per_cluster as u32);
    let ad = variant.autodma_opts(cfg);
    compiler::compile(k, &opts, ad.as_ref())
}

/// Compile one workload variant for `threads` OpenMP threads, without
/// running it. The scheduler's binary cache is built on this entry point.
pub fn compile_workload(
    cfg: &HeroConfig,
    w: &Workload,
    variant: Variant,
    threads: u32,
) -> Result<(compiler::Lowered, Option<AutoDmaReport>)> {
    compile_kernel(cfg, variant_kernel(w, variant), variant == Variant::AutoDma, threads)
}

/// Run an already-lowered kernel on a fresh accelerator instance: allocate
/// and fill shared buffers, offload, read the arrays back. A thin layer
/// over the shared offload core ([`crate::session::core::run_arrays`]),
/// which the scheduler and [`crate::session::Session`] use too.
pub fn run_lowered(
    cfg: &HeroConfig,
    w: &Workload,
    lowered: &compiler::Lowered,
    seed: u64,
    max_cycles: u64,
) -> Result<RunOutcome> {
    let data = w.gen_data(seed);
    let refs: Vec<&[f32]> = data.iter().map(|a| a.as_slice()).collect();
    let (result, arrays) =
        crate::session::core::run_arrays(cfg, lowered, &refs, &w.fargs, 1, max_cycles)?;
    Ok(RunOutcome { result, arrays, report: None, text_size: lowered.program.len() })
}

/// Compile and run one workload variant on a fresh accelerator instance.
///
/// `threads` = OpenMP thread count (1 or the cluster's core count).
pub fn run_workload(
    cfg: &HeroConfig,
    w: &Workload,
    variant: Variant,
    threads: u32,
    seed: u64,
    max_cycles: u64,
) -> Result<RunOutcome> {
    let (lowered, report) = compile_workload(cfg, w, variant, threads)?;
    let mut out = run_lowered(cfg, w, &lowered, seed, max_cycles)?;
    out.report = report;
    Ok(out)
}

/// Verify final array contents against the host golden model (shared by
/// [`verify`] and the session-based front doors, which hold arrays rather
/// than a [`RunOutcome`]).
pub fn verify_arrays(w: &Workload, arrays: &[Vec<f32>], seed: u64) -> Result<()> {
    let expected = w.expected(seed);
    for (i, (got, want)) in arrays.iter().zip(&expected).enumerate() {
        crate::runtime::pjrt::assert_allclose(got, want, 1e-4, 1e-5)
            .map_err(|e| anyhow!("{} array {} ({}): {e}", w.name, i, w.arrays[i].name))?;
    }
    Ok(())
}

/// Verify a run against the host golden model.
pub fn verify(w: &Workload, outcome: &RunOutcome, seed: u64) -> Result<()> {
    verify_arrays(w, &outcome.arrays, seed)
}

/// Verify final array contents against the PJRT-executed JAX/Pallas
/// artifact (the three-layer golden path). Returns Ok(false) when the
/// artifact has not been built (`make artifacts`), Ok(true) on successful
/// verification.
pub fn verify_pjrt_arrays(
    rt: &mut crate::runtime::pjrt::PjrtRuntime,
    w: &Workload,
    arrays: &[Vec<f32>],
    seed: u64,
) -> Result<bool> {
    if !rt.available(&w.pjrt.name) {
        return Ok(false);
    }
    let data = w.gen_data(seed);
    let inputs: Vec<(&[f32], &[usize])> = w
        .pjrt
        .inputs
        .iter()
        .map(|&i| (data[i].as_slice(), w.arrays[i].shape.as_slice()))
        .collect();
    let outs = rt.exec_f32(&w.pjrt.name, &inputs)?;
    if outs.len() != w.pjrt.outputs.len() {
        bail!("{}: artifact returned {} outputs, expected {}", w.name, outs.len(), w.pjrt.outputs.len());
    }
    for (out, &ai) in outs.iter().zip(&w.pjrt.outputs) {
        crate::runtime::pjrt::assert_allclose(&arrays[ai], out, 2e-3, 1e-4)
            .map_err(|e| anyhow!("{} vs PJRT, array {}: {e}", w.name, w.arrays[ai].name))?;
    }
    Ok(true)
}

/// [`verify_pjrt_arrays`] over a [`RunOutcome`].
pub fn verify_pjrt(
    rt: &mut crate::runtime::pjrt::PjrtRuntime,
    w: &Workload,
    outcome: &RunOutcome,
    seed: u64,
) -> Result<bool> {
    verify_pjrt_arrays(rt, w, &outcome.arrays, seed)
}

/// Geometric mean (the paper summarizes normalized numbers this way, §3.1).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::workloads;

    #[test]
    fn gemm_all_variants_verify_tiny() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        for variant in [
            Variant::Unmodified,
            Variant::Handwritten,
            Variant::Promoted,
            Variant::AutoDma,
        ] {
            for threads in [1, 8] {
                let out = run_workload(&cfg, &w, variant, threads, 7, 200_000_000)
                    .unwrap_or_else(|e| panic!("{} t{threads}: {e}", variant.label()));
                verify(&w, &out, 7)
                    .unwrap_or_else(|e| panic!("{} t{threads}: {e}", variant.label()));
            }
        }
    }

    #[test]
    fn handwritten_is_faster_than_unmodified() {
        let cfg = aurora();
        let w = workloads::gemm::build(24);
        let base = run_workload(&cfg, &w, Variant::Unmodified, 1, 3, 500_000_000).unwrap();
        let hand = run_workload(&cfg, &w, Variant::Handwritten, 1, 3, 500_000_000).unwrap();
        assert!(
            hand.cycles() * 2 < base.cycles(),
            "handwritten {} vs unmodified {}",
            hand.cycles(),
            base.cycles()
        );
    }

    #[test]
    fn parallel_is_faster() {
        let cfg = aurora();
        let w = workloads::gemm::build(24);
        let t1 = run_workload(&cfg, &w, Variant::Handwritten, 1, 3, 500_000_000).unwrap();
        let t8 = run_workload(&cfg, &w, Variant::Handwritten, 8, 3, 500_000_000).unwrap();
        let speedup = t1.cycles() as f64 / t8.cycles() as f64;
        assert!(speedup > 3.0, "8-thread speedup only {speedup}");
    }

    #[test]
    fn all_workloads_all_variants_verify_tiny() {
        let cfg = aurora();
        for w in workloads::all_tiny() {
            for variant in [
                Variant::Unmodified,
                Variant::Handwritten,
                Variant::Promoted,
                Variant::AutoDma,
            ] {
                let out = run_workload(&cfg, &w, variant, 8, 11, 500_000_000)
                    .unwrap_or_else(|e| panic!("{} {}: {e}", w.name, variant.label()));
                verify(&w, &out, 11)
                    .unwrap_or_else(|e| panic!("{} {}: {e}", w.name, variant.label()));
            }
        }
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
