//! Minimal statistics for the custom (`harness = false`) bench targets:
//! median, mean, and IQR over repeated measurements.

/// Summary of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p25: f64,
    pub p75: f64,
    pub min: f64,
    pub max: f64,
}

/// Summarize (sorts a copy).
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| -> f64 {
        let idx = p * (v.len() - 1) as f64;
        let (lo, hi) = (idx.floor() as usize, idx.ceil() as usize);
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
        }
    };
    Summary {
        n: v.len(),
        mean: v.iter().sum::<f64>() / v.len() as f64,
        median: q(0.5),
        p25: q(0.25),
        p75: q(0.75),
        min: v[0],
        max: v[v.len() - 1],
    }
}

/// Wall-clock a closure `n` times, returning seconds per run.
pub fn time_runs<F: FnMut()>(n: usize, mut f: F) -> Vec<f64> {
    (0..n)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p25, 2.0);
        assert_eq!(s.p75, 4.0);
    }

    #[test]
    fn single_element() {
        let s = summarize(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p25, 7.0);
    }
}
