//! Machine-readable bench results for the CI cycle-regression gate.
//!
//! Every bench that reports simulated cycle counts also emits a
//! `BENCH_<name>.json` file via [`BenchJson`]: a flat map of **cycle
//! metrics** (u64 simulated cycles or counts — never wall-clock) plus
//! **digests** (u64 bit-identity hashes, rendered as hex strings so JSON
//! number precision can never corrupt them). The simulator is fully
//! deterministic, so the `bench-gate` CI job (`src/bin/bench_gate.rs`)
//! compares fresh emissions against the baselines committed under
//! `rust/benches/baselines/` **exactly** — any cycle-count regression or
//! digest drift fails the build, with no noise tolerance to tune.
//!
//! Workflow:
//!
//! * benches call [`BenchJson::emit`], writing into `$BENCH_JSON_DIR`
//!   (default `target/bench-json/`);
//! * `bench_gate check <emitted> <baseline>` fails on regressions/drift,
//!   and reports improvements as "re-bless suggested";
//! * `bench_gate bless <emitted> <baseline>` adopts the current numbers as
//!   the new committed baseline.
//!
//! The rendering is deliberately one `"key": value` per line so baseline
//! diffs in review read like a perf report.

use std::io::Write as _;
use std::path::PathBuf;

/// One bench's machine-readable result set. Keys keep insertion order so
/// the rendered file is stable run to run.
#[derive(Debug, Default)]
pub struct BenchJson {
    name: String,
    metrics: Vec<(String, u64)>,
    digests: Vec<(String, u64)>,
}

/// One-shot form of [`BenchJson`]: write `BENCH_<name>.json` from slices of
/// cycle metrics and digests. Benches that accumulate results across
/// sections use the builder instead.
pub fn emit_json(
    name: &str,
    metrics: &[(&str, u64)],
    digests: &[(&str, u64)],
) -> std::io::Result<PathBuf> {
    let mut b = BenchJson::new(name);
    for (k, v) in metrics {
        b.metric(*k, *v);
    }
    for (k, v) in digests {
        b.digest(*k, *v);
    }
    b.emit()
}

/// Directory `BENCH_*.json` files are written to: `$BENCH_JSON_DIR`, or
/// `target/bench-json` relative to the working directory.
pub fn out_dir() -> PathBuf {
    std::env::var_os("BENCH_JSON_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target").join("bench-json"))
}

impl BenchJson {
    pub fn new(name: impl Into<String>) -> Self {
        BenchJson { name: name.into(), ..Default::default() }
    }

    /// Record a cycle/count metric (simulated cycles, stall totals, job
    /// counts — anything deterministic; never wall-clock).
    pub fn metric(&mut self, key: impl Into<String>, value: u64) {
        self.metrics.push((key.into(), value));
    }

    /// Record a bit-identity digest (rendered as a hex string).
    pub fn digest(&mut self, key: impl Into<String>, value: u64) {
        self.digests.push((key.into(), value));
    }

    /// Render the JSON document (stable key order, one entry per line).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", self.name));
        s.push_str("  \"metrics\": {\n");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            let comma = if i + 1 < self.metrics.len() { "," } else { "" };
            s.push_str(&format!("    \"{k}\": {v}{comma}\n"));
        }
        s.push_str("  },\n");
        s.push_str("  \"digests\": {\n");
        for (i, (k, v)) in self.digests.iter().enumerate() {
            let comma = if i + 1 < self.digests.len() { "," } else { "" };
            s.push_str(&format!("    \"{k}\": \"{v:#018x}\"{comma}\n"));
        }
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }

    /// Write `BENCH_<name>.json` into [`out_dir`], creating it as needed.
    /// Returns the path written. Benches print it so CI logs show where
    /// the gate's input came from.
    pub fn emit(&self) -> std::io::Result<PathBuf> {
        let dir = out_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_stable_flat_json() {
        let mut b = BenchJson::new("sched");
        b.metric("mixed.pool1.makespan_cycles", 123456);
        b.metric("mixed.pool4.makespan_cycles", 45678);
        b.digest("mixed.digest", 0xdead_beef);
        let s = b.render();
        assert_eq!(s, b.render(), "rendering is deterministic");
        assert!(s.contains("\"bench\": \"sched\""));
        assert!(s.contains("    \"mixed.pool1.makespan_cycles\": 123456,\n"));
        assert!(s.contains("    \"mixed.pool4.makespan_cycles\": 45678\n"));
        assert!(s.contains("    \"mixed.digest\": \"0x00000000deadbeef\"\n"));
        // Valid-JSON shape guards: balanced braces, no trailing commas.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert!(!s.contains(",\n  }"));
    }

    #[test]
    fn empty_sections_render_as_empty_objects() {
        let s = BenchJson::new("x").render();
        assert!(s.contains("\"metrics\": {\n  },"));
        assert!(s.contains("\"digests\": {\n  }\n"));
    }
}
