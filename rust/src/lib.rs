//! # HEROv2 — Heterogeneous Research Platform, reproduced as a Rust + JAX + Pallas stack
//!
//! This crate reproduces the system described in *"HEROv2: Full-Stack
//! Open-Source Research Platform for Heterogeneous Computing"* (Kurth,
//! Forsberg, Benini; IEEE TC 2022) as a three-layer software platform:
//!
//! * **Layer 3 (this crate)** — the platform itself: a cycle-approximate
//!   simulator of the HEROv2 hardware (RV32+Xpulpv2 clusters, banked TCDM
//!   SPMs, DMA engine, hybrid IOMMU, configurable on-chip network, host
//!   model), a mini heterogeneous compiler (AutoDMA tiling + DMA inference,
//!   address-space legalization, Xpulpv2 codegen), an OpenMP-style offload
//!   runtime and the HERO API.
//! * **Layer 2 (`python/compile`, build-time)** — JAX kernel graphs for every
//!   evaluated workload, AOT-lowered to HLO text.
//! * **Layer 1 (`python/compile/kernels`, build-time)** — Pallas kernels whose
//!   `BlockSpec` tiling mirrors the paper's SPM tiling.
//!
//! At run time the Rust binary is self-contained: `runtime::pjrt` loads the
//! AOT artifacts via the PJRT C API and uses them as the golden functional
//! model that the simulated accelerator is verified against (build with the
//! `pjrt-xla` feature; without it those checks skip with a warning). Python
//! never runs on the request path.
//!
//! `ARCHITECTURE.md` at the repo root is the subsystem map — every module
//! below with its role, its layer, and where its prose documentation lives
//! (`compiler/README.md`, `sched/README.md`, `session/README.md`,
//! `svm/README.md`, `fleet/README.md`).
//!
//! ## The `session` front door (start here)
//!
//! The [`session`] module is the **recommended entry point** for client
//! code: one handle-based API ([`session::Session`]) over every way of
//! running a kernel. `Session::single(cfg)` wraps one accelerator,
//! `Session::pool(cfg, k)` an instance pool behind the offload scheduler —
//! the client code is identical either way. Buffers
//! (`session.buffer_from_f32(..)`) replace raw `HostBuf` plumbing and have
//! a **first-class lifecycle**: generation-tagged handles,
//! `Session::free` with slot reuse (stale handles are rejected), and
//! `Session::resident_bytes` so long serve loops stay bounded.
//! `session.launch(&kernel).args(..).fargs(..).teams(n).submit()` is
//! async-by-default with `session.wait(..)` returning cycles, perf
//! counters and an output digest — and launches **chain through buffers**:
//! `.writes(&buf)` keeps an output device-resident, and a later launch
//! that `.reads` it before the producer resolved gets a dataflow edge
//! instead of a snapshot, its payload materializing producer-to-consumer
//! with zero host round-trips (see `session/README.md`). `hero run`,
//! `hero serve`, all examples and the offload/perf/ablation benches go
//! through it; the lower-level surfaces below remain as thin layers over
//! the same core ([`session::core`]), so offload semantics exist exactly
//! once.
//!
//! ## Offload scheduler
//!
//! The [`sched`] module scales the paper's one-host/one-accelerator offload
//! model (§2.3/§2.4) to a stream of concurrent heterogeneous jobs: an
//! asynchronous job queue whose handles mirror `hero_memcpy_*_async`
//! semantics at the job level, pluggable dispatch policies (FIFO,
//! shortest-predicted-first on [`compiler::metrics::predict_cycles`], and
//! capacity-aware admission against `hero_l1_capacity` that rejects or
//! splits oversized jobs), a lowered-binary cache that lets same-kernel
//! jobs batch and amortize compile cost, and aggregate throughput /
//! per-instance utilization reporting built on [`noc::Port::busy_cycles`].
//! Jobs are either *named* synthetic workloads ([`workloads::synth`]) or
//! *arbitrary compiled kernels* ([`sched::KernelJob`] — what a pooled
//! [`session::Session`] submits), both flowing through the same policies,
//! cache, batching and board model. Kernel jobs carry **cross-job
//! dataflow** ([`sched::PayloadSrc::Output`] + `KernelJob::after`): a
//! consumer dispatches only once its producers settle (its effective
//! arrival is the last producer's finish), and its payload materializes
//! from the scheduler's feed store, never through the submitting host.
//! Pool instances share **one carrier-board DRAM** ([`mem::dram`]): each
//! job's main-memory traffic reserves bandwidth on a cycle-accounted
//! ledger, so oversubscribed boards stretch occupancy windows (contention
//! stall) and pool-scaling curves bend realistically; pools may be
//! heterogeneous (mixed wide-NoC widths via
//! [`config::preset::with_dma_width`]) and SJF ordering is
//! contention-aware. Placement is board-aware too:
//! [`sched::Placement::Pressure`] scores candidate instances by predicted
//! finish time including DRAM stall (bit-identical to earliest-free on an
//! uncontended board), and jobs carry a QoS class ([`sched::Priority`])
//! that jumps the queue and reserves DRAM into the board's priority
//! headroom. **Shared virtual memory** is a first-class offload path
//! ([`svm`]): jobs may describe operands by host virtual address
//! ([`sched::PayloadSrc::Svm`]), resolved through a per-board IOMMU shadow
//! with deterministic TLB hit/miss/walk accounting, under a configurable
//! pin / copy / auto offload strategy — and the host itself is a modeled
//! traffic source whose staging, page-walk and mailbox-descriptor bytes
//! reserve board DRAM through a dedicated host port. Front-ends: the
//! `hero serve` CLI subcommand (synthetic streams or `--trace` replay;
//! `--placement`, `--priority-headroom`, `--svm`, `--host-bw`), the job
//! generators in [`workloads::synth`], and `benches/sched.rs`.
//!
//! ## Multi-board fleet serving
//!
//! The [`fleet`] module scales serving past a single carrier board: a
//! front-tier [`fleet::Router`] owns N independent schedulers (each its
//! own pool, DRAM ledger, binary cache and learning state) behind one
//! submission API. Jobs are tagged with a tenant ([`fleet::TenantId`])
//! whose fair-share quotas (in-flight jobs, resident bytes) and default
//! [`sched::Priority`] are enforced at admission — an over-quota
//! submission never reaches a board. Cross-board placement reuses the
//! single-board scoring ([`sched::place::scores_from`]) against the
//! router's projected per-slot backlog, plus a binary-cache **affinity**
//! term: cache-cold boards pay the predicted compile cost in their score,
//! so repeated kernels concentrate on warm boards
//! ([`fleet::RoutePolicy::Finish`]; `RoundRobin` is the blind baseline).
//! A fleet of one board with the default tenant is event-sequence
//! bit-identical to driving the scheduler directly (property-tested).
//! Front-ends: `Session::fleet(cfg, boards, pool_per_board)`,
//! `hero serve --fleet N [--tenants spec] [--route finish|round-robin]`
//! (traces may tag jobs with a `tenant` column), and the `fleet.*`
//! studies in `benches/sched.rs`.
//!
//! ## Fault injection & resilient serving
//!
//! The [`fault`] module makes failure a first-class, *deterministic*
//! platform scenario: a seeded [`fault::FaultPlan`] schedules transient
//! kernel faults and DMA/NoC timeouts per `(job, attempt)` and board
//! failures per cycle, all reproducible run-to-run. The scheduler detects
//! faults (including a watchdog deadline of predicted cycles × a
//! configurable multiplier, honoring each kernel job's own `max_cycles`
//! budget) and retries them with bounded attempts and exponential
//! backoff in cycles — priority, arrival and dataflow edges preserved;
//! permanent failures still cascade to consumers. The fleet router
//! tracks per-board health, evacuates queued jobs off a failed board to
//! surviving boards (re-quoted through the same placement scoring,
//! affinity included) and can queue quota-refused submissions for
//! re-admission (retry-after). With no plan and no watchdog, every code
//! path — and its event sequence — is bit-identical to the fault-free
//! scheduler (property-tested). Front-ends: `hero serve --faults PLAN
//! --retry N --watchdog MULT [--queue N]` and the `fault.*` study in
//! `benches/sched.rs`; prose: `fault/README.md`.

pub mod accel;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod compiler;
pub mod config;
pub mod dma;
pub mod fault;
pub mod fleet;
pub mod host;
pub mod iommu;
pub mod isa;
pub mod mem;
pub mod noc;
pub mod runtime;
pub mod sched;
pub mod session;
pub mod svm;
pub mod testkit;
pub mod trace;
pub mod workloads;

pub use session::Session;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
