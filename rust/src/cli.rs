//! Tiny declarative argument parser shared by the `hero` subcommands.
//!
//! Each subcommand declares its accepted boolean flags and value-taking
//! options up front ([`Spec`]); [`parse`] then rejects anything it does not
//! recognize instead of silently ignoring it — previously a typo like
//! `--polcy sjf` would fall back to the default policy without a word, and
//! `hero serve` carried ad-hoc code just to distinguish `--trace <file>`
//! from a dangling `--trace`. Malformed option values are errors too
//! (`--jobs x` used to silently become the default).

use std::collections::HashMap;

/// What one subcommand accepts.
pub struct Spec {
    /// Boolean flags, spelled with their dashes (e.g. `"--events"`).
    pub flags: &'static [&'static str],
    /// Value-taking options (e.g. `"--pool"`).
    pub opts: &'static [&'static str],
    /// Greatest number of positional arguments accepted (e.g. the kernel
    /// name of `hero run`).
    pub max_positional: usize,
}

/// Parsed arguments of one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    flags: Vec<&'static str>,
    opts: HashMap<&'static str, String>,
    /// Positional arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| *f == name)
    }

    /// Raw value of an option, if given.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Parsed value of an option: `Ok(None)` when absent, an error (instead
    /// of a silent default) when present but malformed.
    pub fn parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => {
                v.parse::<T>().map(Some).map_err(|_| format!("{name} got invalid value {v:?}"))
            }
        }
    }
}

/// Parse `raw` against `spec`. Unknown `--flags`, missing or flag-shaped
/// option values, and excess positional arguments are all errors.
pub fn parse(spec: &Spec, raw: &[String]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut i = 0;
    while i < raw.len() {
        let tok = &raw[i];
        if tok.starts_with("--") {
            if let Some(&canon) = spec.flags.iter().find(|f| **f == tok.as_str()) {
                args.flags.push(canon);
            } else if let Some(&canon) = spec.opts.iter().find(|o| **o == tok.as_str()) {
                match raw.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        args.opts.insert(canon, v.clone());
                        i += 1;
                    }
                    _ => return Err(format!("{tok} expects a value")),
                }
            } else {
                let mut known: Vec<&str> =
                    spec.flags.iter().chain(spec.opts.iter()).copied().collect();
                known.sort_unstable();
                return Err(format!("unknown flag {tok}; accepted: {}", known.join(" ")));
            }
        } else {
            if args.positional.len() >= spec.max_positional {
                return Err(format!("unexpected argument {tok:?}"));
            }
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: Spec = Spec {
        flags: &["--events", "--no-cache"],
        opts: &["--pool", "--trace"],
        max_positional: 1,
    };

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_options_and_positionals() {
        let a = parse(&SPEC, &strs(&["gemm", "--events", "--pool", "4"])).unwrap();
        assert!(a.flag("--events"));
        assert!(!a.flag("--no-cache"));
        assert_eq!(a.opt("--pool"), Some("4"));
        assert_eq!(a.parsed::<usize>("--pool"), Ok(Some(4)));
        assert_eq!(a.parsed::<usize>("--trace"), Ok(None));
        assert_eq!(a.positional, vec!["gemm"]);
    }

    #[test]
    fn rejects_unknown_flags() {
        // The `--polcy` typo class: an error listing what is accepted.
        let e = parse(&SPEC, &strs(&["--evnets"])).unwrap_err();
        assert!(e.contains("unknown flag --evnets"), "{e}");
        assert!(e.contains("--events"), "{e}");
    }

    #[test]
    fn rejects_missing_or_flag_shaped_values() {
        assert!(parse(&SPEC, &strs(&["--trace"])).unwrap_err().contains("expects a value"));
        assert!(
            parse(&SPEC, &strs(&["--trace", "--events"]))
                .unwrap_err()
                .contains("expects a value")
        );
        // A value is consumed, not treated as a positional.
        let a = parse(&SPEC, &strs(&["--trace", "jobs.txt"])).unwrap();
        assert_eq!(a.opt("--trace"), Some("jobs.txt"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn rejects_excess_positionals_and_bad_values() {
        assert!(parse(&SPEC, &strs(&["a", "b"])).unwrap_err().contains("unexpected"));
        let a = parse(&SPEC, &strs(&["--pool", "many"])).unwrap();
        assert!(a.parsed::<usize>("--pool").is_err());
    }
}
