//! The shared offload core: one marshal/run path under every front door.
//!
//! [`offload_lowered`] is the §2.3 offload sequence — argument marshalling,
//! the single 4-GiB-window check, mailbox round trip, device run — that
//! every way of launching a kernel ultimately goes through.
//! [`run_arrays`] wraps it with the host side: build a fresh accelerator,
//! allocate shared buffers for the given array contents, offload, read the
//! arrays back.
//!
//! [`crate::runtime::omp::offload`], the benchmark harness's
//! [`crate::bench_harness::run_lowered`] and the scheduler's dispatch path
//! are thin layers over these two functions, so offload semantics exist
//! exactly once; [`crate::session::Session`] is the recommended client API
//! on top.

use crate::accel::Accel;
use crate::compiler::Lowered;
use crate::config::HeroConfig;
use crate::host::{HostBuf, HostContext};
use crate::runtime::omp::OffloadResult;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Execute one `target` region: marshal `map`-clause pointers, ring the
/// mailbox, run the device until the offload manager reports completion.
///
/// `bufs` must match `lowered.arrays` order; `fargs` matches
/// `lowered.floats`. `n_teams` clusters participate (OpenMP `num_teams`).
pub fn offload_lowered(
    accel: &mut Accel,
    lowered: &Lowered,
    bufs: &[&HostBuf],
    fargs: &[f32],
    n_teams: usize,
    max_cycles: u64,
) -> Result<OffloadResult> {
    if bufs.len() != lowered.arrays.len() {
        bail!("expected {} buffers, got {}", lowered.arrays.len(), bufs.len());
    }
    if fargs.len() != lowered.floats.len() {
        bail!("expected {} float args, got {}", lowered.floats.len(), fargs.len());
    }
    // All map-clause pointers must share the 4 GiB window (one ext-CSR
    // write per kernel — §2.2.1).
    let hi = bufs.first().map(|b| b.hi()).unwrap_or((crate::host::VA_BASE >> 32) as u32);
    for b in bufs {
        if b.hi() != hi {
            bail!("map-clause buffers span multiple 4 GiB windows");
        }
    }
    // Driver: load the device ELF (decoded program) + invalidate stale TLB
    // entries. The flush is epoch-conditional: an unchanged page table
    // keeps the TLB warm across offloads (`iommu.flush_on_offload = true`
    // restores the old flush-every-offload driver).
    accel.load_program(Arc::new(lowered.program.clone()), n_teams)?;
    accel.flush_tlb_if_stale();
    // Marshal arguments: x10 = VA[63:32], x11.. = VA[31:0] per array.
    let mut args: Vec<u32> = vec![hi];
    args.extend(bufs.iter().map(|b| b.lo()));
    accel.set_args(&args, fargs)?;
    // Snapshot counters so the result reports only this offload.
    let before = accel.perf_aggregate();
    let device_cycles = accel.run(max_cycles)?;
    let mut perf = accel.perf_aggregate();
    perf.sub(&before);
    let overhead = crate::host::Mailbox::round_trip_cycles(&accel.cfg);
    Ok(OffloadResult { device_cycles, total_cycles: device_cycles + overhead, perf })
}

/// Run a lowered binary on a fresh accelerator instance: allocate a shared
/// buffer per entry of `arrays` (initialized to its contents), offload,
/// and return the offload result together with the final contents of every
/// array.
///
/// Inputs are borrowed slices so callers that chain launches (the
/// scheduler feeding a consumer job from a producer's retained outputs,
/// the session feeding a dataflow edge) never have to copy a payload just
/// to run it.
///
/// This is the execution model every launch path shares: each launch gets
/// its own SPM/IOMMU state, so results depend only on the binary and the
/// input data — never on what ran before (the scheduler's bit-identity
/// invariant).
pub fn run_arrays(
    cfg: &HeroConfig,
    lowered: &Lowered,
    arrays: &[&[f32]],
    fargs: &[f32],
    n_teams: usize,
    max_cycles: u64,
) -> Result<(OffloadResult, Vec<Vec<f32>>)> {
    // Size DRAM to the data (plus slack for page rounding).
    let total_elems: usize = arrays.iter().map(|a| a.len()).sum();
    let dram = (total_elems * 4 + (arrays.len() + 2) * cfg.iommu.page_bytes).max(1 << 20);
    let mut accel = Accel::new(cfg.clone(), dram);
    let mut host = HostContext::new();
    let bufs: Vec<HostBuf> = arrays
        .iter()
        .map(|a| host.alloc(&mut accel, a.len()))
        .collect::<Result<_>>()?;
    for (buf, data) in bufs.iter().zip(arrays) {
        host.write_f32(&mut accel, buf, data);
    }
    let refs: Vec<&HostBuf> = bufs.iter().collect();
    let result = offload_lowered(&mut accel, lowered, &refs, fargs, n_teams, max_cycles)?;
    let out = bufs.iter().map(|b| host.read_f32(&accel, b)).collect();
    Ok((result, out))
}
