//! `Session` — the unified heterogeneous client API (the recommended entry
//! point).
//!
//! The platform used to expose three disjoint offload surfaces:
//! [`crate::runtime::omp::offload`] for synchronous single-accelerator
//! runs, [`crate::runtime::hero_api::HeroApi`] which threads `&mut Accel`
//! through every call, and [`crate::sched::Scheduler`] which only accepted
//! named synthetic workloads. A [`Session`] is the one front door over all
//! of them, mirroring the original HERO platform's single-API-over-many-
//! accelerators design (§2.3/§2.4):
//!
//! * [`Session::single`] owns one accelerator configuration,
//!   [`Session::pool`] an instance pool behind the offload scheduler — the
//!   client code is identical either way, and `&mut Accel` never appears.
//! * [`Session::buffer_from_f32`] / [`Session::buffer_zeroed`] replace raw
//!   `HostBuf` handling (the 4-GiB-window discipline lives in the shared
//!   offload core, checked once for everyone).
//! * [`Session::launch`] starts a builder:
//!   `session.launch(&kernel).args(&[&x, &y]).fargs(&[a]).teams(n).submit()`
//!   returns a [`Launch`] handle, async by default;
//!   [`Session::wait`] resolves it to a [`LaunchResult`] (device/total
//!   cycles, perf counters, output digest) and materializes the outputs in
//!   the session's buffers.
//! * [`Session::submit_workload`] / [`Session::run_workload`] are the
//!   registry-workload conveniences `hero run`, the examples and the
//!   benches use; [`Session::submit_jobs`] / [`Session::drain`] /
//!   [`Session::report`] drive named job streams on a pooled session
//!   (`hero serve`).
//!
//! Launches are snapshot-in / copy-out: argument buffers are captured at
//! `submit` and written back at `wait`, so a pooled launch behaves exactly
//! like a single-accelerator one — and every launch runs on a fresh
//! accelerator through [`core::run_arrays`], which is what makes the two
//! paths bit-identical (the equivalence tests in `tests/session.rs` pin
//! this down).

pub mod core;

use crate::bench_harness::{variant_kernel, Variant};
use crate::compiler::ir::Kernel;
use crate::compiler::AutoDmaReport;
use crate::config::HeroConfig;
use crate::sched::cache::BinaryCache;
use crate::sched::job::kernel_content_key;
use crate::sched::{
    digest_arrays, JobDesc, JobHandle, JobState, KernelJob, Policy, Priority, Scheduler,
    ServeReport,
};
use crate::trace::PerfCounters;
use crate::workloads::Workload;
use anyhow::{anyhow, bail, ensure, Result};

/// Default per-launch simulation budget (matches `hero run`).
const LAUNCH_MAX_CYCLES: u64 = 100_000_000_000;

/// A session-owned f32 buffer handle (replaces raw `HostBuf` plumbing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Buffer {
    id: usize,
}

/// An in-flight launch handle (the job-level analogue of the HERO API's
/// `hero_memcpy_*_async` transfer ids). Resolve it with [`Session::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Launch {
    id: usize,
}

/// Outcome of one resolved launch.
#[derive(Debug, Clone)]
pub struct LaunchResult {
    /// Device cycles from offload-manager wakeup to completion.
    pub device_cycles: u64,
    /// End-to-end cycles as the host observes them (device + mailbox +
    /// driver overheads).
    pub total_cycles: u64,
    /// Aggregated device performance counters for this launch.
    pub perf: PerfCounters,
    /// FNV-1a digest over the final f32 bits of every argument array —
    /// identical across single and pooled execution of the same launch.
    pub digest: u64,
    /// Pool instance the launch ran on (`None` on a single session).
    pub instance: Option<usize>,
    /// Simulated compile cycles charged (0 when the binary was cached).
    pub compile_cycles: u64,
    /// AutoDMA transformation report of the binary this launch ran, when it
    /// was compiled with the pass (single sessions; also present on cache
    /// hits — the entry keeps its report. Use `compile_cycles > 0` to tell
    /// whether *this* launch paid for the compile).
    pub autodma: Option<AutoDmaReport>,
}

impl LaunchResult {
    /// Cycles attributable to DMA (descriptor setup + core-visible waits).
    pub fn dma_cycles(&self) -> u64 {
        self.perf.dma_attributed_cycles()
    }

    /// Compute cycles = device − DMA-attributable.
    pub fn compute_cycles(&self) -> u64 {
        self.device_cycles.saturating_sub(self.dma_cycles())
    }
}

/// A submitted registry workload: the launch plus its argument buffers (in
/// the workload's array order), for reading outputs back after the wait.
#[derive(Debug, Clone)]
pub struct WorkloadRun {
    pub launch: Launch,
    pub buffers: Vec<Buffer>,
}

/// A completed registry workload (see [`Session::run_workload`]).
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub result: LaunchResult,
    /// Final contents of every array, in the workload's array order.
    pub arrays: Vec<Vec<f32>>,
    pub buffers: Vec<Buffer>,
}

/// Everything a deferred single-backend launch needs to execute.
struct SingleSpec {
    kernel: Kernel,
    autodma: bool,
    args: Vec<usize>,
    inputs: Vec<Vec<f32>>,
    fargs: Vec<f32>,
    teams: usize,
    threads: u32,
    max_cycles: u64,
}

enum LaunchState {
    /// Queued on a single session; executes at wait (async by default).
    PendingSingle(Box<SingleSpec>),
    /// Submitted to the pooled scheduler.
    PendingPool { handle: JobHandle, args: Vec<usize> },
    Done(Box<LaunchResult>),
    Failed(String),
}

enum Backend {
    Single { cfg: HeroConfig, cache: BinaryCache },
    Pool { sched: Scheduler },
}

/// The unified offload session. See the [`session`](crate::session)
/// module docs for the full tour.
pub struct Session {
    buffers: Vec<Vec<f32>>,
    launches: Vec<LaunchState>,
    backend: Backend,
}

impl Session {
    /// A session over one accelerator of configuration `cfg`.
    pub fn single(cfg: HeroConfig) -> Session {
        Session {
            buffers: Vec::new(),
            launches: Vec::new(),
            backend: Backend::Single { cfg, cache: BinaryCache::new(true) },
        }
    }

    /// A session over a pool of `k` identical instances of `cfg` behind the
    /// offload scheduler (FIFO dispatch, board DRAM from the config). For
    /// full control over policy, board bandwidth or heterogeneous pools,
    /// build the [`Scheduler`] yourself and use [`Session::with_scheduler`].
    pub fn pool(cfg: HeroConfig, k: usize) -> Session {
        Session::with_scheduler(Scheduler::new(cfg, k, Policy::Fifo))
    }

    /// A session over an explicitly configured scheduler.
    pub fn with_scheduler(sched: Scheduler) -> Session {
        Session {
            buffers: Vec::new(),
            launches: Vec::new(),
            backend: Backend::Pool { sched },
        }
    }

    /// The session's base platform configuration.
    pub fn config(&self) -> &HeroConfig {
        match &self.backend {
            Backend::Single { cfg, .. } => cfg,
            Backend::Pool { sched } => sched.config(),
        }
    }

    // --- buffers ---------------------------------------------------------

    /// Allocate a session buffer initialized from `data`.
    pub fn buffer_from_f32(&mut self, data: &[f32]) -> Buffer {
        self.buffers.push(data.to_vec());
        Buffer { id: self.buffers.len() - 1 }
    }

    /// Allocate a zero-initialized session buffer of `elems` f32 (outputs).
    pub fn buffer_zeroed(&mut self, elems: usize) -> Buffer {
        self.buffers.push(vec![0.0; elems]);
        Buffer { id: self.buffers.len() - 1 }
    }

    /// Overwrite a buffer's contents (length may change).
    pub fn write_f32(&mut self, buf: &Buffer, data: &[f32]) -> Result<()> {
        ensure!(buf.id < self.buffers.len(), "buffer does not belong to this session");
        self.buffers[buf.id] = data.to_vec();
        Ok(())
    }

    /// Read a buffer's current contents (outputs become visible after the
    /// producing launch's [`Session::wait`]).
    pub fn read_f32(&self, buf: &Buffer) -> Result<Vec<f32>> {
        self.buffers
            .get(buf.id)
            .cloned()
            .ok_or_else(|| anyhow!("buffer does not belong to this session"))
    }

    /// Read several buffers at once (e.g. a [`WorkloadRun`]'s).
    pub fn arrays(&self, bufs: &[Buffer]) -> Result<Vec<Vec<f32>>> {
        bufs.iter().map(|b| self.read_f32(b)).collect()
    }

    // --- launches --------------------------------------------------------

    /// Start a launch builder over `kernel` (cloned into the launch).
    pub fn launch(&mut self, kernel: &Kernel) -> LaunchBuilder<'_> {
        LaunchBuilder {
            kernel: kernel.clone(),
            autodma: false,
            args: Vec::new(),
            fargs: Vec::new(),
            teams: 1,
            threads: None,
            priority: Priority::Normal,
            max_cycles: LAUNCH_MAX_CYCLES,
            err: None,
            session: self,
        }
    }

    /// Resolve a launch: execute it (single sessions defer to here; pooled
    /// sessions drive the scheduler until the job settles), write the
    /// outputs back into the argument buffers, and return the result.
    /// Waiting a second time returns the memoized result.
    pub fn wait(&mut self, launch: &Launch) -> Result<LaunchResult> {
        ensure!(launch.id < self.launches.len(), "launch does not belong to this session");
        match &self.launches[launch.id] {
            LaunchState::Done(r) => return Ok((**r).clone()),
            LaunchState::Failed(e) => bail!("launch previously failed: {e}"),
            _ => {}
        }
        let state = std::mem::replace(
            &mut self.launches[launch.id],
            LaunchState::Failed("launch interrupted mid-wait".into()),
        );
        let run = match state {
            LaunchState::PendingSingle(spec) => self.run_single(*spec),
            LaunchState::PendingPool { handle, args } => self.finish_pool(handle, &args),
            LaunchState::Done(_) | LaunchState::Failed(_) => unreachable!("handled above"),
        };
        match run {
            Ok(r) => {
                self.launches[launch.id] = LaunchState::Done(Box::new(r.clone()));
                Ok(r)
            }
            Err(e) => {
                self.launches[launch.id] = LaunchState::Failed(e.to_string());
                Err(e)
            }
        }
    }

    /// The memoized result of an already-waited launch (non-blocking).
    pub fn poll(&self, launch: &Launch) -> Option<&LaunchResult> {
        match self.launches.get(launch.id)? {
            LaunchState::Done(r) => Some(&**r),
            _ => None,
        }
    }

    fn run_single(&mut self, spec: SingleSpec) -> Result<LaunchResult> {
        let Backend::Single { cfg, cache } = &mut self.backend else {
            unreachable!("single launches only queue on single sessions")
        };
        let content = kernel_content_key(&spec.kernel, spec.autodma);
        let (lowered, compile_cycles, autodma) =
            cache.acquire_ir(cfg, &spec.kernel, spec.autodma, spec.threads, content)?;
        let (result, arrays) = core::run_arrays(
            cfg,
            &lowered,
            &spec.inputs,
            &spec.fargs,
            spec.teams,
            spec.max_cycles,
        )?;
        let digest = digest_arrays(&arrays);
        for (&bid, data) in spec.args.iter().zip(arrays) {
            self.buffers[bid] = data;
        }
        Ok(LaunchResult {
            device_cycles: result.device_cycles,
            total_cycles: result.total_cycles,
            perf: result.perf,
            digest,
            instance: None,
            compile_cycles,
            autodma,
        })
    }

    fn finish_pool(&mut self, handle: JobHandle, args: &[usize]) -> Result<LaunchResult> {
        let Backend::Pool { sched } = &mut self.backend else {
            unreachable!("pool launches only queue on pooled sessions")
        };
        match sched.wait(handle)? {
            JobState::Done(_) => {}
            JobState::Rejected { reason } => bail!("launch rejected by the scheduler: {reason}"),
            JobState::Split { .. } => bail!("kernel launches never split"),
            JobState::Queued => unreachable!("wait settles the job"),
        }
        // Move the payload out rather than cloning it, so the scheduler
        // does not retain every launch's data for the session's lifetime.
        let (arrays, perf) = sched
            .take_payload(handle)
            .ok_or_else(|| anyhow!("scheduler returned no arrays for a kernel job"))?;
        let o = sched.poll(handle).expect("job settled as Done above");
        let result = LaunchResult {
            device_cycles: o.device_cycles,
            total_cycles: o.total_cycles,
            perf: perf.map(|p| *p).unwrap_or_default(),
            digest: o.digest,
            instance: Some(o.instance),
            compile_cycles: o.compile_cycles,
            autodma: None,
        };
        for (&bid, data) in args.iter().zip(arrays) {
            self.buffers[bid] = data;
        }
        Ok(result)
    }

    // --- registry workloads ----------------------------------------------

    /// Submit a registry workload: allocate a buffer per array (inputs from
    /// the workload's deterministic generator at `seed`, outputs zeroed)
    /// and launch the chosen variant's kernel.
    pub fn submit_workload(
        &mut self,
        w: &Workload,
        variant: Variant,
        threads: u32,
        seed: u64,
    ) -> Result<WorkloadRun> {
        let data = w.gen_data(seed);
        let buffers: Vec<Buffer> = data.iter().map(|d| self.buffer_from_f32(d)).collect();
        let kernel = variant_kernel(w, variant).clone();
        let refs: Vec<&Buffer> = buffers.iter().collect();
        let launch = self
            .launch(&kernel)
            .autodma(variant == Variant::AutoDma)
            .args(&refs)
            .fargs(&w.fargs)
            .threads(threads)
            .submit()?;
        Ok(WorkloadRun { launch, buffers })
    }

    /// Submit, wait and read back one registry workload (the synchronous
    /// convenience the benches use).
    pub fn run_workload(
        &mut self,
        w: &Workload,
        variant: Variant,
        threads: u32,
        seed: u64,
    ) -> Result<WorkloadOutcome> {
        let run = self.submit_workload(w, variant, threads, seed)?;
        let result = self.wait(&run.launch)?;
        let arrays = self.arrays(&run.buffers)?;
        Ok(WorkloadOutcome { result, arrays, buffers: run.buffers })
    }

    // --- named job streams (pooled sessions) -----------------------------

    fn sched(&self) -> Result<&Scheduler> {
        match &self.backend {
            Backend::Pool { sched } => Ok(sched),
            Backend::Single { .. } => bail!("named job streams need a pooled session"),
        }
    }

    fn sched_mut(&mut self) -> Result<&mut Scheduler> {
        match &mut self.backend {
            Backend::Pool { sched } => Ok(sched),
            Backend::Single { .. } => bail!("named job streams need a pooled session"),
        }
    }

    /// Submit a stream of named synthetic jobs (pooled sessions; the
    /// `hero serve` path).
    pub fn submit_jobs(&mut self, jobs: &[JobDesc]) -> Result<Vec<JobHandle>> {
        Ok(self.sched_mut()?.submit_all(jobs))
    }

    /// State of a named job handle (pooled sessions).
    pub fn job_state(&self, h: JobHandle) -> Option<&JobState> {
        self.sched().ok()?.state(h)
    }

    /// Run everything outstanding to completion: pooled sessions drain the
    /// scheduler queue, single sessions execute every pending launch — and
    /// on both backends every pending [`Launch`] is resolved, exactly as if
    /// [`Session::wait`] had been called on each (successful launches get
    /// their outputs written back and [`Session::poll`] returns `Some`).
    /// A failing launch does not stop the drain: the rest still resolve,
    /// and the first failure is returned at the end.
    pub fn drain(&mut self) -> Result<()> {
        let mut first_err = None;
        if let Backend::Pool { sched } = &mut self.backend {
            if let Err(e) = sched.drain() {
                first_err = Some(e);
            }
        }
        for id in 0..self.launches.len() {
            if matches!(
                self.launches[id],
                LaunchState::PendingSingle(_) | LaunchState::PendingPool { .. }
            ) {
                if let Err(e) = self.wait(&Launch { id }) {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Aggregate serve report (pooled sessions).
    pub fn report(&self) -> Result<ServeReport> {
        Ok(self.sched()?.report())
    }

    /// Rendered scheduler event log (pooled sessions).
    pub fn events(&self) -> Result<String> {
        Ok(self.sched()?.trace.render())
    }
}

/// Builder returned by [`Session::launch`]. Defaults: no AutoDMA, one team,
/// the configuration's full cluster width as the thread count, and a
/// 100 G-cycle simulation budget.
pub struct LaunchBuilder<'s> {
    session: &'s mut Session,
    kernel: Kernel,
    autodma: bool,
    args: Vec<usize>,
    fargs: Vec<f32>,
    teams: usize,
    threads: Option<u32>,
    priority: Priority,
    max_cycles: u64,
    err: Option<String>,
}

impl LaunchBuilder<'_> {
    /// Bind the kernel's host-array parameters, in declaration order.
    pub fn args(mut self, bufs: &[&Buffer]) -> Self {
        for b in bufs {
            self = self.arg(b);
        }
        self
    }

    /// Bind the next host-array parameter.
    pub fn arg(mut self, buf: &Buffer) -> Self {
        if buf.id >= self.session.buffers.len() {
            self.err = Some("argument buffer does not belong to this session".into());
        } else {
            self.args.push(buf.id);
        }
        self
    }

    /// Bind the kernel's float parameters, in declaration order.
    pub fn fargs(mut self, fargs: &[f32]) -> Self {
        self.fargs.extend_from_slice(fargs);
        self
    }

    /// Clusters participating in the offload (OpenMP `num_teams`).
    pub fn teams(mut self, n: usize) -> Self {
        self.teams = n;
        self
    }

    /// OpenMP thread count the kernel is lowered for (clamped to the
    /// cluster width at compile time).
    pub fn threads(mut self, t: u32) -> Self {
        self.threads = Some(t);
        self
    }

    /// Run the AutoDMA tiling pass before lowering (for kernels written in
    /// plain OpenMP form).
    pub fn autodma(mut self, on: bool) -> Self {
        self.autodma = on;
        self
    }

    /// QoS class of the launch ([`Priority::High`] = latency-critical). On
    /// a pooled session a high-priority launch dispatches before arrived
    /// normal work and reserves board DRAM into the priority headroom; a
    /// single-accelerator session has nothing to contend with, so the
    /// class is recorded but changes nothing there.
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Override the simulation budget for this launch.
    pub fn max_cycles(mut self, cycles: u64) -> Self {
        self.max_cycles = cycles;
        self
    }

    /// Submit the launch: snapshots the argument buffers and returns an
    /// async [`Launch`] handle (resolve with [`Session::wait`]).
    pub fn submit(self) -> Result<Launch> {
        if let Some(e) = self.err {
            bail!("{e}");
        }
        let threads = self
            .threads
            .unwrap_or_else(|| self.session.config().accel.cores_per_cluster as u32);
        let inputs: Vec<Vec<f32>> =
            self.args.iter().map(|&id| self.session.buffers[id].clone()).collect();
        // One shared guard with `Scheduler::submit_kernel`: parameter
        // counts and declared-constant extents vs the snapshot (an
        // undersized buffer would let the device read past it).
        if let Err(e) = crate::sched::job::validate_payload(&self.kernel, &inputs, &self.fargs) {
            bail!("{e}");
        }
        let state = match &mut self.session.backend {
            Backend::Single { .. } => LaunchState::PendingSingle(Box::new(SingleSpec {
                kernel: self.kernel,
                autodma: self.autodma,
                args: self.args,
                inputs,
                fargs: self.fargs,
                teams: self.teams,
                threads,
                max_cycles: self.max_cycles,
            })),
            Backend::Pool { sched } => {
                let mut job = KernelJob::new(self.kernel, inputs, self.fargs);
                job.threads = threads;
                job.teams = self.teams;
                job.priority = self.priority;
                job.autodma = self.autodma;
                job.max_cycles = self.max_cycles;
                let handle = sched.submit_kernel(job);
                LaunchState::PendingPool { handle, args: self.args }
            }
        };
        self.session.launches.push(state);
        Ok(Launch { id: self.session.launches.len() - 1 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;
    use crate::config::aurora;
    use crate::workloads;

    fn scale_kernel(n: i32) -> Kernel {
        let mut b = KernelBuilder::new("scale2");
        let x = b.host_array("X", vec![ci(n)]);
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(x, vec![var(i)], ld(x, vec![var(i)]).mul(cf(2.0)))],
        )])
    }

    #[test]
    fn single_launch_roundtrip() {
        let mut sess = Session::single(aurora());
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let x = sess.buffer_from_f32(&data);
        let launch = sess.launch(&scale_kernel(64)).args(&[&x]).submit().unwrap();
        // Async by default: the buffer is untouched until the wait.
        assert_eq!(sess.read_f32(&x).unwrap(), data);
        assert!(sess.poll(&launch).is_none());
        let res = sess.wait(&launch).unwrap();
        assert!(res.device_cycles > 0);
        assert!(res.total_cycles > res.device_cycles);
        assert!(res.compile_cycles > 0);
        assert_eq!(res.instance, None);
        let got = sess.read_f32(&x).unwrap();
        for i in 0..64 {
            assert_eq!(got[i], 2.0 * i as f32, "x[{i}]");
        }
        // Waiting again returns the memoized result.
        let again = sess.wait(&launch).unwrap();
        assert_eq!(again.digest, res.digest);
        assert!(sess.poll(&launch).is_some());
    }

    #[test]
    fn repeated_launches_hit_the_binary_cache() {
        let mut sess = Session::single(aurora());
        let x = sess.buffer_from_f32(&[1.0; 32]);
        let l1 = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let r1 = sess.wait(&l1).unwrap();
        let l2 = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let r2 = sess.wait(&l2).unwrap();
        assert!(r1.compile_cycles > 0);
        assert_eq!(r2.compile_cycles, 0, "structurally identical kernel must hit");
        // The second launch consumed the first one's output (4.0 = 1*2*2).
        assert_eq!(sess.read_f32(&x).unwrap()[0], 4.0);
    }

    #[test]
    fn misuse_is_an_error_not_a_panic() {
        let mut sess = Session::single(aurora());
        let foreign = Buffer { id: 99 };
        assert!(sess.read_f32(&foreign).is_err());
        assert!(sess.write_f32(&foreign, &[0.0]).is_err());
        assert!(sess.launch(&scale_kernel(8)).arg(&foreign).submit().is_err());
        // Undersized buffer for a constant-extent array.
        let small = sess.buffer_from_f32(&[0.0; 4]);
        let err = sess.launch(&scale_kernel(8)).args(&[&small]).submit().unwrap_err();
        assert!(err.to_string().contains("8 element(s)"), "{err}");
        // Arity mismatch is caught at submit (the shared payload guard).
        let err = sess.launch(&scale_kernel(8)).submit().unwrap_err();
        assert!(err.to_string().contains("array parameter"), "{err}");
        // Foreign launch handle.
        assert!(sess.wait(&Launch { id: 42 }).is_err());
        assert!(sess.poll(&Launch { id: 42 }).is_none());
        // Named streams are a pooled-session feature.
        assert!(sess.submit_jobs(&[]).is_err());
        assert!(sess.report().is_err());
    }

    #[test]
    fn workload_launch_verifies_and_reports_autodma() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::AutoDma, 8, 7).unwrap();
        crate::bench_harness::verify_arrays(&w, &out.arrays, 7).unwrap();
        assert!(out.result.autodma.is_some(), "AutoDma compile must surface its report");
        assert!(out.result.dma_cycles() > 0);
        assert!(out.result.compute_cycles() < out.result.device_cycles);
    }

    #[test]
    fn pool_session_runs_kernels_and_streams() {
        let mut sess = Session::pool(aurora(), 2);
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let x = sess.buffer_from_f32(&data);
        let launch = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let res = sess.wait(&launch).unwrap();
        assert_eq!(res.instance, Some(0));
        assert_eq!(sess.read_f32(&x).unwrap()[3], 6.0);
        // Named streams ride the same session.
        let handles = sess
            .submit_jobs(&crate::workloads::synth::tiny_jobs(3, 9))
            .unwrap();
        sess.drain().unwrap();
        for h in &handles {
            assert!(sess.job_state(*h).unwrap().settled());
        }
        let report = sess.report().unwrap();
        assert_eq!(report.completed, 4, "kernel launch + 3 named jobs");
        assert!(sess.events().unwrap().contains("submit"));
    }

    #[test]
    fn launch_priority_reaches_the_pooled_scheduler() {
        let mut sess = Session::pool(aurora(), 1);
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let l = sess
            .launch(&scale_kernel(16))
            .args(&[&x])
            .priority(Priority::High)
            .submit()
            .unwrap();
        sess.wait(&l).unwrap();
        // The QoS class rides into the scheduler's submit event.
        assert!(sess.events().unwrap().contains("[high]"));
        // On a single session the class is accepted and changes nothing.
        let mut single = Session::single(aurora());
        let y = single.buffer_from_f32(&[1.0; 16]);
        let l2 = single
            .launch(&scale_kernel(16))
            .args(&[&y])
            .priority(Priority::High)
            .submit()
            .unwrap();
        let r = single.wait(&l2).unwrap();
        assert!(r.device_cycles > 0);
    }

    #[test]
    fn drain_resolves_pooled_launches() {
        // drain() must behave identically on both backends: outputs written
        // back and poll() returning Some without an explicit wait().
        let mut sess = Session::pool(aurora(), 1);
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let l = sess.launch(&scale_kernel(16)).args(&[&x]).submit().unwrap();
        assert!(sess.poll(&l).is_none());
        sess.drain().unwrap();
        assert!(sess.poll(&l).is_some());
        assert_eq!(sess.read_f32(&x).unwrap()[0], 2.0);
    }

    #[test]
    fn pool_rejection_surfaces_at_wait() {
        let mut cfg = aurora();
        cfg.accel.l1_bytes = 16 * 1024;
        let sched = Scheduler::new(cfg, 1, Policy::Capacity(crate::sched::OversizeAction::Reject));
        let mut sess = Session::with_scheduler(sched);
        let w = workloads::gemm::build(64);
        let run = sess.submit_workload(&w, Variant::Handwritten, 8, 1).unwrap();
        let err = sess.wait(&run.launch).unwrap_err();
        assert!(err.to_string().contains("rejected"), "{err}");
    }
}
