//! `Session` — the unified heterogeneous client API (the recommended entry
//! point).
//!
//! The platform used to expose three disjoint offload surfaces:
//! [`crate::runtime::omp::offload`] for synchronous single-accelerator
//! runs, [`crate::runtime::hero_api::HeroApi`] which threads `&mut Accel`
//! through every call, and [`crate::sched::Scheduler`] which only accepted
//! named synthetic workloads. A [`Session`] is the one front door over all
//! of them, mirroring the original HERO platform's single-API-over-many-
//! accelerators design (§2.3/§2.4):
//!
//! * [`Session::single`] owns one accelerator configuration,
//!   [`Session::pool`] an instance pool behind the offload scheduler — the
//!   client code is identical either way, and `&mut Accel` never appears.
//! * **Buffers have a lifecycle** (see `session/README.md`):
//!   [`Session::buffer_from_f32`] / [`Session::buffer_zeroed`] allocate
//!   generation-tagged handles, [`Session::free`] releases one (its slot
//!   is reused by the next allocation, and stale handles are rejected),
//!   and [`Session::resident_bytes`] reports what the session holds — a
//!   long-running serve loop that frees what it no longer needs stays
//!   bounded.
//! * [`Session::launch`] starts a builder:
//!   `session.launch(&kernel).args(&[&x, &y]).fargs(&[a]).teams(n).submit()`
//!   returns a [`Launch`] handle, async by default;
//!   [`Session::wait`] resolves it to a [`LaunchResult`] (device/total
//!   cycles, perf counters, output digest) and materializes the outputs in
//!   the session's buffers.
//! * **Launches chain through buffers** without host round-trips:
//!   [`LaunchBuilder::writes`] marks a parameter as a device-resident
//!   output, and a later launch that [`LaunchBuilder::reads`] (or
//!   `.writes`, for in-place updates) the same buffer *before* the
//!   producer resolved gets a dataflow edge instead of a data snapshot —
//!   the producer's output feeds the consumer directly (on a pooled
//!   session via [`crate::sched::PayloadSrc::Output`] and the scheduler's
//!   feed store; on a single session at producer resolution). Waiting the
//!   tail of a chain resolves its producers first.
//! * [`Session::submit_workload`] / [`Session::run_workload`] are the
//!   registry-workload conveniences `hero run`, the examples and the
//!   benches use; [`Session::submit_jobs`] / [`Session::drain`] /
//!   [`Session::report`] drive named job streams on a pooled session
//!   (`hero serve`).
//! * **Shared virtual memory** ([`crate::svm`], pooled sessions whose
//!   scheduler was built with [`Scheduler::with_svm`]):
//!   [`Session::svm_alloc_f32`] returns a virtual address,
//!   [`LaunchBuilder::svm_arg`] binds a parameter to it with no snapshot
//!   (the scheduler resolves the VA through the board IOMMU at dispatch,
//!   under the configured pin/copy/auto strategy or a per-launch
//!   [`LaunchBuilder::svm`] override), and [`Session::svm_read_f32`]
//!   observes the device's result in the shared space.
//! * **Self-tuning scheduling** rides along transparently on pooled
//!   sessions: build the scheduler with
//!   [`Scheduler::with_learning`] / [`Scheduler::with_lookahead`] /
//!   [`Scheduler::with_preemption`] (CLI: `hero serve --learn
//!   --lookahead K --preempt`) and launches dispatch on
//!   measurement-refined cycle predictions, jointly-placed lookahead
//!   windows and High-over-Normal batch displacement — all of which move
//!   *time*, never the numerics a launch returns
//!   ([`crate::sched::learn`], `sched/README.md`).
//!
//! * **Fleet serving** scales past one carrier board:
//!   [`Session::fleet`] (or [`Session::with_router`]) fronts N
//!   independent boards with the [`crate::fleet`] router — tenant-tagged
//!   named job streams with per-tenant quotas, affinity-aware
//!   cross-board placement, a merged [`crate::fleet::FleetReport`]
//!   ([`Session::fleet_report`]) and interleaved per-board event logs.
//!   Kernel launches and SVM stay single-board features.
//!
//! Non-chained launches are snapshot-in / copy-out exactly as before:
//! argument buffers are captured at `submit` and written back at `wait`,
//! so a pooled launch behaves exactly like a single-accelerator one — and
//! every launch runs on a fresh accelerator through [`core::run_arrays`],
//! which is what makes the paths bit-identical (the equivalence tests in
//! `tests/session.rs` and the chained-pipeline property in
//! `tests/properties.rs` pin this down).

pub mod core;

use crate::bench_harness::{variant_kernel, Variant};
use crate::compiler::ir::Kernel;
use crate::compiler::AutoDmaReport;
use crate::config::HeroConfig;
use crate::sched::cache::BinaryCache;
use crate::sched::job::{kernel_content_key, tuned_variant_content, validate_shape};
use crate::sched::{
    digest_arrays, JobDesc, JobHandle, JobState, KernelJob, PayloadSrc, Policy, Priority,
    Scheduler, ServeReport,
};
use crate::trace::PerfCounters;
use crate::workloads::Workload;
use anyhow::{anyhow, bail, ensure, Result};

/// Default per-launch simulation budget (matches `hero run`).
const LAUNCH_MAX_CYCLES: u64 = 100_000_000_000;

/// A session-owned f32 buffer handle (replaces raw `HostBuf` plumbing).
/// Handles carry a generation: after [`Session::free`] the slot may be
/// reused, and the stale handle is rejected instead of aliasing the new
/// buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Buffer {
    id: usize,
    gen: u32,
}

/// An in-flight launch handle (the job-level analogue of the HERO API's
/// `hero_memcpy_*_async` transfer ids). Resolve it with [`Session::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Launch {
    id: usize,
}

/// Outcome of one resolved launch.
#[derive(Debug, Clone)]
pub struct LaunchResult {
    /// Device cycles from offload-manager wakeup to completion.
    pub device_cycles: u64,
    /// End-to-end cycles as the host observes them (device + mailbox +
    /// driver overheads).
    pub total_cycles: u64,
    /// Aggregated device performance counters for this launch.
    pub perf: PerfCounters,
    /// FNV-1a digest over the final f32 bits of every argument array —
    /// identical across single and pooled execution of the same launch.
    pub digest: u64,
    /// Pool instance the launch ran on (`None` on a single session).
    pub instance: Option<usize>,
    /// Simulated compile cycles charged (0 when the binary was cached).
    pub compile_cycles: u64,
    /// AutoDMA transformation report of the binary this launch ran, when it
    /// was compiled with the pass (single sessions; also present on cache
    /// hits — the entry keeps its report. Use `compile_cycles > 0` to tell
    /// whether *this* launch paid for the compile).
    pub autodma: Option<AutoDmaReport>,
}

impl LaunchResult {
    /// Cycles attributable to DMA (descriptor setup + core-visible waits).
    pub fn dma_cycles(&self) -> u64 {
        self.perf.dma_attributed_cycles()
    }

    /// Compute cycles = device − DMA-attributable.
    pub fn compute_cycles(&self) -> u64 {
        self.device_cycles.saturating_sub(self.dma_cycles())
    }
}

/// A submitted registry workload: the launch plus its argument buffers (in
/// the workload's array order), for reading outputs back after the wait.
#[derive(Debug, Clone)]
pub struct WorkloadRun {
    pub launch: Launch,
    pub buffers: Vec<Buffer>,
}

/// A completed registry workload (see [`Session::run_workload`]).
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub result: LaunchResult,
    /// Final contents of every array, in the workload's array order.
    pub arrays: Vec<Vec<f32>>,
    pub buffers: Vec<Buffer>,
}

/// How a launch parameter relates to its session buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArgKind {
    /// Legacy read-write binding ([`LaunchBuilder::arg`]): eager snapshot
    /// in, written back at resolve, no dataflow marker.
    Arg,
    /// Input-only ([`LaunchBuilder::reads`]): snapshot (or dataflow edge),
    /// the kernel's final view of the array is discarded.
    Read,
    /// Device-resident output ([`LaunchBuilder::writes`]): written back at
    /// resolve, and marked pending so later launches chain on it.
    Write,
}

/// Where one launch parameter's initial contents come from.
#[derive(Debug, Clone)]
enum LocalSrc {
    /// Eager snapshot, captured at submit.
    Data(Vec<f32>),
    /// Output array `index` of unresolved launch `launch` (dataflow edge):
    /// materialized when the producer resolves, never through the host.
    Dep { launch: usize, index: usize, elems: usize },
    /// Shared-virtual-memory operand ([`LaunchBuilder::svm_arg`]): the
    /// scheduler resolves the VA through the board IOMMU at dispatch.
    /// Pooled sessions only.
    Svm { va: u64, elems: usize },
}

impl LocalSrc {
    fn elems(&self) -> usize {
        match self {
            LocalSrc::Data(v) => v.len(),
            LocalSrc::Dep { elems, .. } | LocalSrc::Svm { elems, .. } => *elems,
        }
    }
}

/// One ordered launch parameter as the builder records it.
enum BuilderBind {
    /// A session buffer, with its access mode.
    Buf(ArgKind, Buffer),
    /// A shared-virtual-memory operand (no session buffer involved).
    Svm { va: u64, elems: usize },
}

/// One buffer slot of the session heap.
#[derive(Debug)]
struct Slot {
    /// Bumped at [`Session::free`]: stale handles are detected exactly.
    gen: u32,
    /// Current resident contents; `None` while the slot sits on the free
    /// list (unreachable through any live handle).
    data: Option<Vec<f32>>,
    /// The unresolved launch (and parameter index) whose output will
    /// overwrite this buffer — the dataflow marker consumers chain on.
    pending: Option<(usize, usize)>,
}

/// Everything a deferred single-backend launch needs to execute.
struct SingleSpec {
    kernel: Kernel,
    autodma: bool,
    autotune: bool,
    /// Per-parameter binding: kind + slot + the generation at submit
    /// (write-back skips slots freed in the meantime).
    binds: Vec<(ArgKind, usize, u32)>,
    inputs: Vec<LocalSrc>,
    fargs: Vec<f32>,
    teams: usize,
    threads: u32,
    max_cycles: u64,
}

enum LaunchState {
    /// Queued on a single session; executes at wait (async by default).
    PendingSingle(Box<SingleSpec>),
    /// Submitted to the pooled scheduler. `deps` are the session launch
    /// ids of dataflow producers (resolved first at wait, so buffers
    /// become visible in submission order on both backends).
    PendingPool { handle: JobHandle, binds: Vec<(ArgKind, usize, u32)>, deps: Vec<usize> },
    Done(Box<LaunchResult>),
    Failed(String),
}

enum Backend {
    Single { cfg: HeroConfig, cache: BinaryCache },
    Pool { sched: Scheduler },
    /// N independent boards behind the fleet router ([`crate::fleet`]).
    /// Serves named job streams through [`Session::router_mut`]; kernel
    /// launches and SVM need a single board and are rejected.
    Fleet { router: crate::fleet::Router },
}

/// The unified offload session. See the [`session`](crate::session)
/// module docs and `session/README.md` for the full tour.
pub struct Session {
    slots: Vec<Slot>,
    free_ids: Vec<usize>,
    launches: Vec<LaunchState>,
    /// Single-backend reverse dataflow index: producer launch id ->
    /// unresolved consumer launch ids. Feeding at producer resolution
    /// looks up exactly the affected consumers (entries are consumed with
    /// the producer; chain-free sessions never touch it).
    single_consumers: std::collections::HashMap<usize, Vec<usize>>,
    backend: Backend,
}

impl Session {
    /// A session over one accelerator of configuration `cfg`.
    pub fn single(cfg: HeroConfig) -> Session {
        Session {
            slots: Vec::new(),
            free_ids: Vec::new(),
            launches: Vec::new(),
            single_consumers: std::collections::HashMap::new(),
            backend: Backend::Single { cfg, cache: BinaryCache::new(true) },
        }
    }

    /// A session over a pool of `k` identical instances of `cfg` behind the
    /// offload scheduler (FIFO dispatch, board DRAM from the config). For
    /// full control over policy, board bandwidth or heterogeneous pools,
    /// build the [`Scheduler`] yourself and use [`Session::with_scheduler`].
    pub fn pool(cfg: HeroConfig, k: usize) -> Session {
        Session::with_scheduler(Scheduler::new(cfg, k, Policy::Fifo))
    }

    /// A session over an explicitly configured scheduler.
    pub fn with_scheduler(sched: Scheduler) -> Session {
        Session {
            slots: Vec::new(),
            free_ids: Vec::new(),
            launches: Vec::new(),
            single_consumers: std::collections::HashMap::new(),
            backend: Backend::Pool { sched },
        }
    }

    /// A session over a *fleet*: `boards` identical carrier boards of
    /// `pool_per_board` instances each behind the front-tier router
    /// ([`crate::fleet::Router`], predicted-finish routing, the unlimited
    /// default tenant). Named job streams flow through
    /// [`Session::router_mut`]; [`Session::drain`],
    /// [`Session::fleet_report`] and [`Session::events`] cover the whole
    /// fleet. For custom routing, tenants or per-board configuration,
    /// build the router yourself and use [`Session::with_router`].
    pub fn fleet(cfg: HeroConfig, boards: usize, pool_per_board: usize) -> Session {
        Session::with_router(crate::fleet::Router::homogeneous(&cfg, boards, pool_per_board))
    }

    /// A session over an explicitly configured fleet router.
    pub fn with_router(router: crate::fleet::Router) -> Session {
        Session {
            slots: Vec::new(),
            free_ids: Vec::new(),
            launches: Vec::new(),
            single_consumers: std::collections::HashMap::new(),
            backend: Backend::Fleet { router },
        }
    }

    /// The fleet router, read-only (fleet sessions).
    pub fn router(&self) -> Result<&crate::fleet::Router> {
        match &self.backend {
            Backend::Fleet { router } => Ok(router),
            _ => bail!("this session does not front a fleet (build one with Session::fleet)"),
        }
    }

    /// The fleet router (fleet sessions) — the submission surface for
    /// tenant-tagged job streams ([`crate::fleet::Router::submit_for`]).
    pub fn router_mut(&mut self) -> Result<&mut crate::fleet::Router> {
        match &mut self.backend {
            Backend::Fleet { router } => Ok(router),
            _ => bail!("this session does not front a fleet (build one with Session::fleet)"),
        }
    }

    /// Merged fleet report (fleet sessions).
    pub fn fleet_report(&self) -> Result<crate::fleet::FleetReport> {
        Ok(self.router()?.report())
    }

    /// The session's base platform configuration.
    pub fn config(&self) -> &HeroConfig {
        match &self.backend {
            Backend::Single { cfg, .. } => cfg,
            Backend::Pool { sched } => sched.config(),
            Backend::Fleet { router } => router.board(0).config(),
        }
    }

    // --- buffers ---------------------------------------------------------

    fn alloc(&mut self, data: Vec<f32>) -> Buffer {
        if let Some(id) = self.free_ids.pop() {
            let s = &mut self.slots[id];
            s.data = Some(data);
            Buffer { id, gen: s.gen }
        } else {
            self.slots.push(Slot { gen: 0, data: Some(data), pending: None });
            Buffer { id: self.slots.len() - 1, gen: 0 }
        }
    }

    /// Bounds- and generation-check a handle.
    fn slot_index(&self, buf: &Buffer) -> Result<usize> {
        let s = self
            .slots
            .get(buf.id)
            .ok_or_else(|| anyhow!("buffer does not belong to this session"))?;
        ensure!(
            s.gen == buf.gen,
            "stale buffer handle: the buffer was freed (and its slot possibly reused)"
        );
        Ok(buf.id)
    }

    fn slot_data(&self, buf: &Buffer) -> Result<&Vec<f32>> {
        let id = self.slot_index(buf)?;
        self.slots[id].data.as_ref().ok_or_else(|| anyhow!("buffer was freed"))
    }

    /// Allocate a session buffer initialized from `data`. Freed slots are
    /// reused before the heap grows.
    pub fn buffer_from_f32(&mut self, data: &[f32]) -> Buffer {
        self.alloc(data.to_vec())
    }

    /// Allocate a zero-initialized session buffer of `elems` f32 (outputs).
    pub fn buffer_zeroed(&mut self, elems: usize) -> Buffer {
        self.alloc(vec![0.0; elems])
    }

    /// Release a buffer: its bytes leave [`Session::resident_bytes`] and
    /// its slot is reused by the next allocation; the handle (and any copy
    /// of it) is dead from here on. A buffer that is the pending output of
    /// an unresolved launch cannot be freed — wait for (or drain) the
    /// launch first.
    pub fn free(&mut self, buf: &Buffer) -> Result<()> {
        let id = self.slot_index(buf)?;
        if let Some((launch, _)) = self.slots[id].pending {
            bail!(
                "buffer is the pending output of unresolved launch {launch}; \
                 wait for it (or drain) before freeing"
            );
        }
        let s = &mut self.slots[id];
        s.gen = s.gen.wrapping_add(1);
        s.data = None;
        self.free_ids.push(id);
        Ok(())
    }

    /// Bytes currently resident in the session's buffer heap. Grows with
    /// allocations, shrinks with [`Session::free`] — after freeing what a
    /// pipeline no longer needs, this returns to its watermark (no
    /// monotonic growth in long serve loops).
    pub fn resident_bytes(&self) -> u64 {
        self.slots.iter().map(|s| s.data.as_ref().map_or(0, |d| d.len() as u64 * 4)).sum()
    }

    /// Overwrite a buffer's contents (length may change). Rejected while
    /// the buffer is the pending output of an unresolved launch — the
    /// dataflow chained on it would silently diverge otherwise.
    pub fn write_f32(&mut self, buf: &Buffer, data: &[f32]) -> Result<()> {
        let id = self.slot_index(buf)?;
        if let Some((launch, _)) = self.slots[id].pending {
            bail!("buffer is the pending output of unresolved launch {launch}");
        }
        self.slots[id].data = Some(data.to_vec());
        Ok(())
    }

    /// Read a buffer's current contents. While the buffer is the pending
    /// output of an unresolved launch this is the *pre-launch* snapshot
    /// view (launches are async); outputs become visible after the
    /// producing launch's [`Session::wait`].
    pub fn read_f32(&self, buf: &Buffer) -> Result<Vec<f32>> {
        Ok(self.slot_data(buf)?.clone())
    }

    /// Read several buffers at once (e.g. a [`WorkloadRun`]'s).
    pub fn arrays(&self, bufs: &[Buffer]) -> Result<Vec<Vec<f32>>> {
        bufs.iter().map(|b| self.read_f32(b)).collect()
    }

    // --- launches --------------------------------------------------------

    /// Start a launch builder over `kernel` (cloned into the launch).
    pub fn launch(&mut self, kernel: &Kernel) -> LaunchBuilder<'_> {
        LaunchBuilder {
            kernel: kernel.clone(),
            autodma: false,
            autotune: false,
            binds: Vec::new(),
            fargs: Vec::new(),
            teams: 1,
            threads: None,
            priority: Priority::Normal,
            svm_mode: None,
            max_cycles: LAUNCH_MAX_CYCLES,
            err: None,
            session: self,
        }
    }

    /// Resolve a launch: execute it (single sessions defer to here; pooled
    /// sessions drive the scheduler until the job settles), write the
    /// outputs back into the argument buffers, and return the result.
    /// Dataflow producers resolve first, so waiting the tail of a chain
    /// resolves the whole chain (and write-backs land in submission
    /// order). Waiting a second time returns the memoized result.
    pub fn wait(&mut self, launch: &Launch) -> Result<LaunchResult> {
        ensure!(launch.id < self.launches.len(), "launch does not belong to this session");
        // Resolve the transitive producer chain first, iteratively —
        // dataflow edges always point at earlier launches, so ascending id
        // order is a topological order and an arbitrarily deep chain costs
        // no recursion.
        let mut need: Vec<usize> = Vec::new();
        let mut stack = vec![launch.id];
        let mut seen = std::collections::HashSet::new();
        while let Some(id) = stack.pop() {
            for p in self.producer_launches(id) {
                if seen.insert(p) {
                    need.push(p);
                    stack.push(p);
                }
            }
        }
        need.sort_unstable();
        for p in need {
            // A producer failure is not this wait's error yet: every
            // launch between it and the requested one settles as failed in
            // order, and the final resolve reports the chain.
            let _ = self.resolve_now(p);
        }
        self.resolve_now(launch.id)
    }

    /// Dataflow producers of an unresolved launch (empty once settled).
    fn producer_launches(&self, id: usize) -> Vec<usize> {
        match &self.launches[id] {
            LaunchState::PendingSingle(spec) => spec
                .inputs
                .iter()
                .filter_map(|s| match s {
                    LocalSrc::Dep { launch, .. } => Some(*launch),
                    LocalSrc::Data(_) => None,
                })
                .collect(),
            LaunchState::PendingPool { deps, .. } => deps.clone(),
            LaunchState::Done(_) | LaunchState::Failed(_) => Vec::new(),
        }
    }

    /// Settle one launch whose producers have all settled already (the
    /// iterative engine behind [`Session::wait`]). Memoized results return
    /// directly; a failed producer fails this launch too.
    fn resolve_now(&mut self, id: usize) -> Result<LaunchResult> {
        match &self.launches[id] {
            LaunchState::Done(r) => return Ok((**r).clone()),
            LaunchState::Failed(e) => bail!("launch previously failed: {e}"),
            _ => {}
        }
        let write_slots = self.write_slots(id);
        for p in self.producer_launches(id) {
            if let LaunchState::Failed(e) = &self.launches[p] {
                let msg = format!("producer launch {p} failed: {e}");
                self.launches[id] = LaunchState::Failed(msg.clone());
                self.clear_pending(id, &write_slots);
                bail!("{msg}");
            }
        }
        let state = std::mem::replace(
            &mut self.launches[id],
            LaunchState::Failed("launch interrupted mid-wait".into()),
        );
        let run = match state {
            LaunchState::PendingSingle(spec) => self.run_single(id, *spec),
            LaunchState::PendingPool { handle, binds, .. } => self.finish_pool(handle, &binds),
            LaunchState::Done(_) | LaunchState::Failed(_) => unreachable!("handled above"),
        };
        let out = match run {
            Ok(r) => {
                self.launches[id] = LaunchState::Done(Box::new(r.clone()));
                Ok(r)
            }
            Err(e) => {
                self.launches[id] = LaunchState::Failed(e.to_string());
                Err(e)
            }
        };
        // Settled either way: buffers this launch was going to overwrite
        // are no longer pending (on failure they keep their old contents).
        self.clear_pending(id, &write_slots);
        out
    }

    /// The memoized result of an already-waited launch (non-blocking).
    pub fn poll(&self, launch: &Launch) -> Option<&LaunchResult> {
        match self.launches.get(launch.id)? {
            LaunchState::Done(r) => Some(&**r),
            _ => None,
        }
    }

    /// Drop the pending-output markers a settled launch left on its write
    /// slots (`slots` is the launch's own recorded Write bindings — no
    /// heap scan; a marker that moved on to a later chained writer is left
    /// alone by the ownership check).
    fn clear_pending(&mut self, launch: usize, slots: &[usize]) {
        for &sid in slots {
            if matches!(self.slots[sid].pending, Some((l, _)) if l == launch) {
                self.slots[sid].pending = None;
            }
        }
    }

    /// Slot ids of an unresolved launch's Write bindings (empty once
    /// settled) — what `clear_pending` needs.
    fn write_slots(&self, id: usize) -> Vec<usize> {
        let binds = match &self.launches[id] {
            LaunchState::PendingSingle(spec) => &spec.binds,
            LaunchState::PendingPool { binds, .. } => binds,
            LaunchState::Done(_) | LaunchState::Failed(_) => return Vec::new(),
        };
        binds
            .iter()
            .filter(|(k, _, _)| *k == ArgKind::Write)
            .map(|(_, s, _)| *s)
            .collect()
    }

    /// Replace dataflow edges on unresolved single-backend launches with
    /// the freshly produced arrays (the single-session analogue of the
    /// scheduler's feed store — consumers are fed at producer resolution,
    /// never through a host round-trip of the session heap).
    fn feed_single_consumers(&mut self, producer: usize, arrays: &[Vec<f32>]) {
        // Direct lookup, and the entry is consumed with the producer: no
        // new consumer can register on it afterwards (the buffer stops
        // being pending once the producer resolves).
        let Some(consumers) = self.single_consumers.remove(&producer) else { return };
        for c in consumers {
            if let LaunchState::PendingSingle(spec) = &mut self.launches[c] {
                for src in &mut spec.inputs {
                    if let LocalSrc::Dep { launch, index, .. } = src {
                        if *launch == producer {
                            *src = LocalSrc::Data(arrays[*index].clone());
                        }
                    }
                }
            }
        }
    }

    /// Write a resolved launch's output arrays back into its buffers.
    /// `Read` bindings are skipped (input-only), and a slot freed since
    /// submit (generation mismatch) is left alone.
    fn write_back(&mut self, binds: &[(ArgKind, usize, u32)], arrays: Vec<Vec<f32>>) {
        for ((kind, slot, gen), data) in binds.iter().zip(arrays) {
            if matches!(kind, ArgKind::Read) {
                continue;
            }
            let s = &mut self.slots[*slot];
            if s.gen == *gen && s.data.is_some() {
                s.data = Some(data);
            }
        }
    }

    fn run_single(&mut self, id: usize, spec: SingleSpec) -> Result<LaunchResult> {
        let Backend::Single { cfg, cache } = &mut self.backend else {
            unreachable!("single launches only queue on single sessions")
        };
        // A tuned launch searches the AutoDMA knob space (deterministic —
        // same kernel, config and width always pick the same winner) and
        // compiles the winning recipe under its own cache key; untuned
        // launches keep their pre-existing keys bit-unchanged.
        let (lowered, compile_cycles, autodma) = if spec.autodma && spec.autotune {
            let result = crate::compiler::autotune::tune(&spec.kernel, cfg, spec.threads);
            let variant = result.best().variant;
            let content = tuned_variant_content(kernel_content_key(&spec.kernel, true), &variant);
            cache.acquire_ir_tuned(cfg, &spec.kernel, &variant, spec.threads, content)?
        } else {
            let content = kernel_content_key(&spec.kernel, spec.autodma);
            cache.acquire_ir(cfg, &spec.kernel, spec.autodma, spec.threads, content)?
        };
        let mut refs: Vec<&[f32]> = Vec::with_capacity(spec.inputs.len());
        for src in &spec.inputs {
            match src {
                LocalSrc::Data(v) => refs.push(v.as_slice()),
                LocalSrc::Dep { launch, .. } => {
                    bail!("internal: producer launch {launch} left unresolved")
                }
                LocalSrc::Svm { .. } => {
                    bail!("internal: SVM operands are rejected at submit on single sessions")
                }
            }
        }
        let (result, arrays) =
            core::run_arrays(cfg, &lowered, &refs, &spec.fargs, spec.teams, spec.max_cycles)?;
        let digest = digest_arrays(&arrays);
        self.feed_single_consumers(id, &arrays);
        self.write_back(&spec.binds, arrays);
        Ok(LaunchResult {
            device_cycles: result.device_cycles,
            total_cycles: result.total_cycles,
            perf: result.perf,
            digest,
            instance: None,
            compile_cycles,
            autodma,
        })
    }

    fn finish_pool(
        &mut self,
        handle: JobHandle,
        binds: &[(ArgKind, usize, u32)],
    ) -> Result<LaunchResult> {
        let Backend::Pool { sched } = &mut self.backend else {
            unreachable!("pool launches only queue on pooled sessions")
        };
        match sched.wait(handle)? {
            JobState::Done(_) => {}
            JobState::Rejected { reason } => bail!("launch rejected by the scheduler: {reason}"),
            JobState::Split { .. } => bail!("kernel launches never split"),
            JobState::Migrated => unreachable!("only the fleet router migrates jobs"),
            JobState::Queued => unreachable!("wait settles the job"),
        }
        // Move the payload out rather than cloning it, so the scheduler
        // does not retain every launch's data for the session's lifetime
        // (outputs demanded by chained consumers were already cloned into
        // the scheduler's feed store at completion).
        let (arrays, perf) = sched
            .take_payload(handle)
            .ok_or_else(|| anyhow!("scheduler returned no arrays for a kernel job"))?;
        let o = sched.poll(handle).expect("job settled as Done above");
        let result = LaunchResult {
            device_cycles: o.device_cycles,
            total_cycles: o.total_cycles,
            perf: perf.map(|p| *p).unwrap_or_default(),
            digest: o.digest,
            instance: Some(o.instance),
            compile_cycles: o.compile_cycles,
            autodma: None,
        };
        self.write_back(binds, arrays);
        Ok(result)
    }

    // --- registry workloads ----------------------------------------------

    /// Submit a registry workload: allocate a buffer per array (inputs from
    /// the workload's deterministic generator at `seed`, outputs zeroed)
    /// and launch the chosen variant's kernel.
    pub fn submit_workload(
        &mut self,
        w: &Workload,
        variant: Variant,
        threads: u32,
        seed: u64,
    ) -> Result<WorkloadRun> {
        let data = w.gen_data(seed);
        let buffers: Vec<Buffer> = data.iter().map(|d| self.buffer_from_f32(d)).collect();
        let kernel = variant_kernel(w, variant).clone();
        let refs: Vec<&Buffer> = buffers.iter().collect();
        let launch = self
            .launch(&kernel)
            .autodma(variant == Variant::AutoDma)
            .args(&refs)
            .fargs(&w.fargs)
            .threads(threads)
            .submit()?;
        Ok(WorkloadRun { launch, buffers })
    }

    /// Submit, wait and read back one registry workload compiled under the
    /// *tuned* AutoDMA recipe ([`LaunchBuilder::autotune`]): the `hero run
    /// --autotune` path and the bench harness's tuned arm. Numerics are
    /// bit-identical to the untuned AutoDMA variant — only the tiling
    /// schedule may differ.
    pub fn run_workload_tuned(
        &mut self,
        w: &Workload,
        threads: u32,
        seed: u64,
    ) -> Result<WorkloadOutcome> {
        let data = w.gen_data(seed);
        let buffers: Vec<Buffer> = data.iter().map(|d| self.buffer_from_f32(d)).collect();
        let kernel = variant_kernel(w, Variant::AutoDma).clone();
        let refs: Vec<&Buffer> = buffers.iter().collect();
        let launch = self
            .launch(&kernel)
            .autodma(true)
            .autotune(true)
            .args(&refs)
            .fargs(&w.fargs)
            .threads(threads)
            .submit()?;
        let result = self.wait(&launch)?;
        let arrays = self.arrays(&buffers)?;
        Ok(WorkloadOutcome { result, arrays, buffers })
    }

    /// Submit, wait and read back one registry workload (the synchronous
    /// convenience the benches use).
    pub fn run_workload(
        &mut self,
        w: &Workload,
        variant: Variant,
        threads: u32,
        seed: u64,
    ) -> Result<WorkloadOutcome> {
        let run = self.submit_workload(w, variant, threads, seed)?;
        let result = self.wait(&run.launch)?;
        let arrays = self.arrays(&run.buffers)?;
        Ok(WorkloadOutcome { result, arrays, buffers: run.buffers })
    }

    // --- named job streams (pooled sessions) -----------------------------

    fn sched(&self) -> Result<&Scheduler> {
        match &self.backend {
            Backend::Pool { sched } => Ok(sched),
            Backend::Single { .. } => bail!("named job streams need a pooled session"),
            Backend::Fleet { .. } => {
                bail!("fleet sessions serve job streams through Session::router_mut")
            }
        }
    }

    fn sched_mut(&mut self) -> Result<&mut Scheduler> {
        match &mut self.backend {
            Backend::Pool { sched } => Ok(sched),
            Backend::Single { .. } => bail!("named job streams need a pooled session"),
            Backend::Fleet { .. } => {
                bail!("fleet sessions serve job streams through Session::router_mut")
            }
        }
    }

    /// Submit a stream of named synthetic jobs (pooled sessions; the
    /// `hero serve` path).
    pub fn submit_jobs(&mut self, jobs: &[JobDesc]) -> Result<Vec<JobHandle>> {
        Ok(self.sched_mut()?.submit_all(jobs))
    }

    /// State of a named job handle (pooled sessions).
    pub fn job_state(&self, h: JobHandle) -> Option<&JobState> {
        self.sched().ok()?.state(h)
    }

    /// Run everything outstanding to completion: pooled sessions drain the
    /// scheduler queue, single sessions execute every pending launch — and
    /// on both backends every pending [`Launch`] is resolved, exactly as if
    /// [`Session::wait`] had been called on each (successful launches get
    /// their outputs written back and [`Session::poll`] returns `Some`).
    /// A failing launch does not stop the drain: the rest still resolve,
    /// and the first failure is returned at the end.
    pub fn drain(&mut self) -> Result<()> {
        let mut first_err = None;
        match &mut self.backend {
            Backend::Pool { sched } => {
                if let Err(e) = sched.drain() {
                    first_err = Some(e);
                }
            }
            Backend::Fleet { router } => {
                if let Err(e) = router.drain() {
                    first_err = Some(e);
                }
            }
            Backend::Single { .. } => {}
        }
        for id in 0..self.launches.len() {
            if matches!(
                self.launches[id],
                LaunchState::PendingSingle(_) | LaunchState::PendingPool { .. }
            ) {
                if let Err(e) = self.wait(&Launch { id }) {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Aggregate serve report (pooled sessions).
    pub fn report(&self) -> Result<ServeReport> {
        Ok(self.sched()?.report())
    }

    /// Rendered scheduler event log (pooled sessions) — covers pooled
    /// kernel launches too: submit/compile/dispatch/complete per launch,
    /// plus `ready` lines when a chained launch's last producer settles
    /// ([`crate::trace::SchedEvent::DependencyReady`]). Fleet sessions
    /// return all boards' logs interleaved on one timeline, each line
    /// prefixed with its board id ([`crate::fleet::Router::events`]).
    pub fn events(&self) -> Result<String> {
        match &self.backend {
            Backend::Fleet { router } => Ok(router.events()),
            _ => Ok(self.sched()?.trace.render()),
        }
    }

    // --- shared virtual memory (pooled sessions) --------------------------

    /// Allocate a shared-virtual-memory buffer holding `data` and return
    /// its virtual address (bind it with [`LaunchBuilder::svm_arg`]).
    /// Needs a pooled session whose scheduler was built with
    /// [`Scheduler::with_svm`].
    pub fn svm_alloc_f32(&mut self, data: Vec<f32>) -> Result<u64> {
        match &mut self.backend {
            Backend::Pool { sched } => sched.svm_alloc_f32(data),
            Backend::Single { .. } | Backend::Fleet { .. } => {
                bail!("SVM buffers need a pooled session with SVM serving enabled")
            }
        }
    }

    /// Read a shared-virtual-memory buffer back (the host observing
    /// offload results in place — no launch write-back involved).
    pub fn svm_read_f32(&self, va: u64) -> Result<Vec<f32>> {
        self.sched()?
            .svm_read_f32(va)
            .ok_or_else(|| anyhow!("va {va:#x} is not an allocated SVM buffer"))
    }
}

/// Builder returned by [`Session::launch`]. Defaults: no AutoDMA, one team,
/// the configuration's full cluster width as the thread count, and a
/// 100 G-cycle simulation budget.
///
/// Bind the kernel's host-array parameters in declaration order, choosing
/// a mode per parameter: [`LaunchBuilder::arg`] (legacy read-write
/// snapshot), [`LaunchBuilder::reads`] (input-only) or
/// [`LaunchBuilder::writes`] (device-resident output that later launches
/// chain on).
pub struct LaunchBuilder<'s> {
    session: &'s mut Session,
    kernel: Kernel,
    autodma: bool,
    autotune: bool,
    binds: Vec<BuilderBind>,
    fargs: Vec<f32>,
    teams: usize,
    threads: Option<u32>,
    priority: Priority,
    svm_mode: Option<crate::svm::SvmMode>,
    max_cycles: u64,
    err: Option<String>,
}

impl LaunchBuilder<'_> {
    fn bind(mut self, buf: &Buffer, kind: ArgKind) -> Self {
        if self.err.is_some() {
            return self;
        }
        match self.session.slot_index(buf) {
            Err(e) => self.err = Some(e.to_string()),
            Ok(id) => {
                if kind == ArgKind::Arg {
                    if let Some((l, _)) = self.session.slots[id].pending {
                        self.err = Some(format!(
                            "buffer is the pending output of launch {l}; chain it \
                             explicitly with .reads() or .writes()"
                        ));
                        return self;
                    }
                }
                self.binds.push(BuilderBind::Buf(kind, *buf));
            }
        }
        self
    }

    /// Bind the kernel's host-array parameters, in declaration order
    /// (legacy read-write mode, as [`LaunchBuilder::arg`]).
    pub fn args(mut self, bufs: &[&Buffer]) -> Self {
        for b in bufs {
            self = self.arg(b);
        }
        self
    }

    /// Bind the next host-array parameter (read-write snapshot semantics:
    /// captured at submit, written back at wait — PR 3 behavior,
    /// bit-identical). Refuses a buffer that is pending as another
    /// launch's output; chain those with [`LaunchBuilder::reads`] /
    /// [`LaunchBuilder::writes`] instead.
    pub fn arg(self, buf: &Buffer) -> Self {
        self.bind(buf, ArgKind::Arg)
    }

    /// Bind the next host-array parameter as an *input*: the kernel's
    /// final view of it is discarded (no write-back). If the buffer is the
    /// pending output of an unresolved launch, this records a dataflow
    /// edge — the producer's output feeds this launch directly, with no
    /// host round-trip.
    pub fn reads(self, buf: &Buffer) -> Self {
        self.bind(buf, ArgKind::Read)
    }

    /// Bind the next host-array parameter as a device-resident *output*:
    /// written back at resolve, and marked pending so later launches can
    /// consume it by handle. On a buffer already pending from an earlier
    /// launch this chains an in-place update (read-modify-write): the
    /// earlier output is this launch's initial contents.
    pub fn writes(self, buf: &Buffer) -> Self {
        self.bind(buf, ArgKind::Write)
    }

    /// Bind the next host-array parameter to a *shared-virtual-memory*
    /// buffer by virtual address ([`Session::svm_alloc_f32`]): no snapshot
    /// is taken — the scheduler resolves the VA through the board IOMMU at
    /// dispatch under the session's SVM offload strategy, and the device's
    /// result lands back in the shared space
    /// ([`Session::svm_read_f32`]). Pooled sessions with SVM serving
    /// enabled only.
    pub fn svm_arg(mut self, va: u64, elems: usize) -> Self {
        if self.err.is_none() {
            self.binds.push(BuilderBind::Svm { va, elems });
        }
        self
    }

    /// Override the SVM offload strategy for this launch (defaults to the
    /// scheduler's configured mode).
    pub fn svm(mut self, mode: crate::svm::SvmMode) -> Self {
        self.svm_mode = Some(mode);
        self
    }

    /// Bind the kernel's float parameters, in declaration order.
    pub fn fargs(mut self, fargs: &[f32]) -> Self {
        self.fargs.extend_from_slice(fargs);
        self
    }

    /// Clusters participating in the offload (OpenMP `num_teams`).
    pub fn teams(mut self, n: usize) -> Self {
        self.teams = n;
        self
    }

    /// OpenMP thread count the kernel is lowered for (clamped to the
    /// cluster width at compile time).
    pub fn threads(mut self, t: u32) -> Self {
        self.threads = Some(t);
        self
    }

    /// Run the AutoDMA tiling pass before lowering (for kernels written in
    /// plain OpenMP form).
    pub fn autodma(mut self, on: bool) -> Self {
        self.autodma = on;
        self
    }

    /// Search the AutoDMA knob space for this launch
    /// ([`crate::compiler::autotune`]) instead of compiling the single
    /// default recipe: tile side, double-buffering and lowering variant are
    /// ranked by the cycle model and the winner's binary is compiled under
    /// its own cache key. Implies nothing unless [`LaunchBuilder::autodma`]
    /// is also on; on a pooled session the scheduler's
    /// [`Scheduler::with_autotune`](crate::sched::Scheduler::with_autotune)
    /// store memoizes the search across launches.
    pub fn autotune(mut self, on: bool) -> Self {
        self.autotune = on;
        self
    }

    /// QoS class of the launch ([`Priority::High`] = latency-critical). On
    /// a pooled session a high-priority launch dispatches before arrived
    /// normal work and reserves board DRAM into the priority headroom; a
    /// single-accelerator session has nothing to contend with, so the
    /// class is recorded but changes nothing there.
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Override the simulation budget for this launch.
    pub fn max_cycles(mut self, cycles: u64) -> Self {
        self.max_cycles = cycles;
        self
    }

    /// Submit the launch and return an async [`Launch`] handle (resolve
    /// with [`Session::wait`]). Ready buffers are snapshotted here;
    /// parameters bound to a *pending* buffer become dataflow edges whose
    /// payload materializes only when the producing launch settles.
    pub fn submit(self) -> Result<Launch> {
        if let Some(e) = self.err {
            bail!("{e}");
        }
        let threads = self
            .threads
            .unwrap_or_else(|| self.session.config().accel.cores_per_cluster as u32);
        // A buffer can be the pending output of at most one launch.
        let mut writes: Vec<usize> = self
            .binds
            .iter()
            .filter_map(|b| match b {
                BuilderBind::Buf(ArgKind::Write, buf) => Some(buf.id),
                _ => None,
            })
            .collect();
        writes.sort_unstable();
        if writes.windows(2).any(|w| w[0] == w[1]) {
            bail!("a buffer is bound with .writes() twice in one launch");
        }
        if matches!(self.session.backend, Backend::Single { .. })
            && self.binds.iter().any(|b| matches!(b, BuilderBind::Svm { .. }))
        {
            bail!("SVM operands need a pooled session with SVM serving enabled");
        }
        // Build the payload source per parameter: pending buffers chain,
        // SVM operands stay VA-described, everything else snapshots
        // (exactly PR 3's submit-time capture).
        let mut srcs: Vec<LocalSrc> = Vec::with_capacity(self.binds.len());
        let mut dep_handles: Vec<Option<JobHandle>> = Vec::with_capacity(self.binds.len());
        let mut binds_rec: Vec<(ArgKind, usize, u32)> = Vec::with_capacity(self.binds.len());
        for bind in &self.binds {
            let (kind, buf) = match bind {
                BuilderBind::Buf(kind, buf) => (kind, buf),
                BuilderBind::Svm { va, elems } => {
                    srcs.push(LocalSrc::Svm { va: *va, elems: *elems });
                    dep_handles.push(None);
                    // Placeholder keeping the per-parameter zip aligned;
                    // `Read` is skipped at write-back (the scheduler lands
                    // SVM results in the shared space, not a session slot).
                    binds_rec.push((ArgKind::Read, 0, u32::MAX));
                    continue;
                }
            };
            let slot = &self.session.slots[buf.id];
            let data = slot.data.as_ref().expect("bound buffers are live");
            match slot.pending {
                Some((p, i)) => {
                    // `.writes` of an in-place kernel cannot change the
                    // element count, so the producing output is as long as
                    // the resident snapshot.
                    srcs.push(LocalSrc::Dep { launch: p, index: i, elems: data.len() });
                    dep_handles.push(match &self.session.launches[p] {
                        LaunchState::PendingPool { handle, .. } => Some(*handle),
                        _ => None,
                    });
                }
                None => {
                    srcs.push(LocalSrc::Data(data.clone()));
                    dep_handles.push(None);
                }
            }
            binds_rec.push((*kind, buf.id, buf.gen));
        }
        // One shared guard with `Scheduler::submit_kernel`: parameter
        // counts and declared-constant extents vs the payload (an
        // undersized buffer would let the device read past it). Dataflow
        // edges validate by element count — their data does not exist yet.
        let elems: Vec<usize> = srcs.iter().map(|s| s.elems()).collect();
        if let Err(e) = validate_shape(&self.kernel, &elems, self.fargs.len()) {
            bail!("{e}");
        }
        // Dataflow producers of this launch, deduplicated — the pool state
        // stores them for wait-ordering, the single backend indexes
        // producer -> consumer for feeding at resolution.
        let mut dep_launches: Vec<usize> = srcs
            .iter()
            .filter_map(|s| match s {
                LocalSrc::Dep { launch, .. } => Some(*launch),
                LocalSrc::Data(_) | LocalSrc::Svm { .. } => None,
            })
            .collect();
        dep_launches.sort_unstable();
        dep_launches.dedup();
        let write_marks: Vec<(usize, usize)> = self
            .binds
            .iter()
            .enumerate()
            .filter_map(|(i, b)| match b {
                BuilderBind::Buf(ArgKind::Write, buf) => Some((buf.id, i)),
                _ => None,
            })
            .collect();
        let state = match &mut self.session.backend {
            Backend::Fleet { .. } => bail!(
                "kernel launches are not routed across a fleet; use a single or pooled \
                 session (fleet sessions serve named job streams via Session::router_mut)"
            ),
            Backend::Single { .. } => LaunchState::PendingSingle(Box::new(SingleSpec {
                kernel: self.kernel,
                autodma: self.autodma,
                autotune: self.autotune,
                binds: binds_rec,
                inputs: srcs,
                fargs: self.fargs,
                teams: self.teams,
                threads,
                max_cycles: self.max_cycles,
            })),
            Backend::Pool { sched } => {
                let mut pool_srcs: Vec<PayloadSrc> = Vec::with_capacity(srcs.len());
                for (s, h) in srcs.into_iter().zip(&dep_handles) {
                    pool_srcs.push(match s {
                        LocalSrc::Data(v) => PayloadSrc::Data(v),
                        LocalSrc::Svm { va, elems } => PayloadSrc::Svm { va, elems },
                        LocalSrc::Dep { launch, index, elems } => {
                            let Some(producer) = h else {
                                bail!("internal: producer launch {launch} is not pooled")
                            };
                            PayloadSrc::Output { producer: *producer, index, elems }
                        }
                    });
                }
                let mut job = KernelJob::from_srcs(self.kernel, pool_srcs, self.fargs);
                job.threads = threads;
                job.teams = self.teams;
                job.priority = self.priority;
                job.autodma = self.autodma;
                job.autotune = self.autotune;
                job.svm = self.svm_mode;
                job.max_cycles = self.max_cycles;
                let handle = sched.submit_kernel(job);
                LaunchState::PendingPool { handle, binds: binds_rec, deps: dep_launches.clone() }
            }
        };
        let single = matches!(state, LaunchState::PendingSingle(_));
        self.session.launches.push(state);
        let id = self.session.launches.len() - 1;
        // Mark this launch's outputs pending: later launches chain on
        // them by handle, and free/write are blocked until it resolves.
        for (slot, idx) in write_marks {
            self.session.slots[slot].pending = Some((id, idx));
        }
        // Single backend: each producer learns about this consumer so
        // feeding at resolution is a direct lookup, never a scan.
        if single {
            for &p in &dep_launches {
                self.session.single_consumers.entry(p).or_default().push(id);
            }
        }
        Ok(Launch { id })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::*;
    use crate::config::aurora;
    use crate::workloads;

    fn scale_kernel(n: i32) -> Kernel {
        let mut b = KernelBuilder::new("scale2");
        let x = b.host_array("X", vec![ci(n)]);
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(x, vec![var(i)], ld(x, vec![var(i)]).mul(cf(2.0)))],
        )])
    }

    #[test]
    fn single_launch_roundtrip() {
        let mut sess = Session::single(aurora());
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let x = sess.buffer_from_f32(&data);
        let launch = sess.launch(&scale_kernel(64)).args(&[&x]).submit().unwrap();
        // Async by default: the buffer is untouched until the wait.
        assert_eq!(sess.read_f32(&x).unwrap(), data);
        assert!(sess.poll(&launch).is_none());
        let res = sess.wait(&launch).unwrap();
        assert!(res.device_cycles > 0);
        assert!(res.total_cycles > res.device_cycles);
        assert!(res.compile_cycles > 0);
        assert_eq!(res.instance, None);
        let got = sess.read_f32(&x).unwrap();
        for i in 0..64 {
            assert_eq!(got[i], 2.0 * i as f32, "x[{i}]");
        }
        // Waiting again returns the memoized result.
        let again = sess.wait(&launch).unwrap();
        assert_eq!(again.digest, res.digest);
        assert!(sess.poll(&launch).is_some());
    }

    #[test]
    fn repeated_launches_hit_the_binary_cache() {
        let mut sess = Session::single(aurora());
        let x = sess.buffer_from_f32(&[1.0; 32]);
        let l1 = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let r1 = sess.wait(&l1).unwrap();
        let l2 = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let r2 = sess.wait(&l2).unwrap();
        assert!(r1.compile_cycles > 0);
        assert_eq!(r2.compile_cycles, 0, "structurally identical kernel must hit");
        // The second launch consumed the first one's output (4.0 = 1*2*2).
        assert_eq!(sess.read_f32(&x).unwrap()[0], 4.0);
    }

    #[test]
    fn tuned_launches_match_untuned_numerics_on_both_backends() {
        // `.autotune(true)` may pick a different tiling recipe, but every
        // surviving candidate computes the same values — the digest is the
        // contract, on the single backend and through the pooled scheduler.
        let w = crate::workloads::gemm::build(24);
        let run = |mut sess: Session, tune: bool| {
            let data = w.gen_data(7);
            let bufs: Vec<Buffer> = data.iter().map(|d| sess.buffer_from_f32(d)).collect();
            let refs: Vec<&Buffer> = bufs.iter().collect();
            let l = sess
                .launch(&w.unmodified)
                .autodma(true)
                .autotune(tune)
                .args(&refs)
                .fargs(&w.fargs)
                .submit()
                .unwrap();
            sess.wait(&l).unwrap().digest
        };
        let base = run(Session::single(aurora()), false);
        assert_eq!(run(Session::single(aurora()), true), base);
        assert_eq!(run(Session::pool(aurora(), 2), true), base);
    }

    #[test]
    fn misuse_is_an_error_not_a_panic() {
        let mut sess = Session::single(aurora());
        let foreign = Buffer { id: 99, gen: 0 };
        assert!(sess.read_f32(&foreign).is_err());
        assert!(sess.write_f32(&foreign, &[0.0]).is_err());
        assert!(sess.free(&foreign).is_err());
        assert!(sess.launch(&scale_kernel(8)).arg(&foreign).submit().is_err());
        // Undersized buffer for a constant-extent array.
        let small = sess.buffer_from_f32(&[0.0; 4]);
        let err = sess.launch(&scale_kernel(8)).args(&[&small]).submit().unwrap_err();
        assert!(err.to_string().contains("8 element(s)"), "{err}");
        // Arity mismatch is caught at submit (the shared payload guard).
        let err = sess.launch(&scale_kernel(8)).submit().unwrap_err();
        assert!(err.to_string().contains("array parameter"), "{err}");
        // Foreign launch handle.
        assert!(sess.wait(&Launch { id: 42 }).is_err());
        assert!(sess.poll(&Launch { id: 42 }).is_none());
        // Named streams are a pooled-session feature.
        assert!(sess.submit_jobs(&[]).is_err());
        assert!(sess.report().is_err());
    }

    #[test]
    fn buffer_free_and_reuse() {
        let mut sess = Session::single(aurora());
        assert_eq!(sess.resident_bytes(), 0);
        let a = sess.buffer_from_f32(&[1.0; 64]);
        let watermark = sess.resident_bytes();
        assert_eq!(watermark, 256);
        let b = sess.buffer_from_f32(&[2.0; 16]);
        assert_eq!(sess.resident_bytes(), watermark + 64);
        sess.free(&b).unwrap();
        assert_eq!(sess.resident_bytes(), watermark);
        // The freed slot is reused; the stale handle is rejected everywhere.
        let c = sess.buffer_zeroed(8);
        assert_eq!(sess.resident_bytes(), watermark + 32);
        assert!(sess.read_f32(&b).is_err());
        assert!(sess.write_f32(&b, &[0.0]).is_err());
        assert!(sess.free(&b).is_err());
        assert!(sess.launch(&scale_kernel(8)).arg(&b).submit().is_err());
        assert_eq!(sess.read_f32(&c).unwrap(), vec![0.0; 8]);
        assert_eq!(sess.read_f32(&a).unwrap(), vec![1.0; 64]);
        // Freeing the rest returns the heap to empty.
        sess.free(&a).unwrap();
        sess.free(&c).unwrap();
        assert_eq!(sess.resident_bytes(), 0);
    }

    #[test]
    fn chained_launches_stay_device_resident() {
        let mut sess = Session::single(aurora());
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let x = sess.buffer_from_f32(&data);
        let l1 = sess.launch(&scale_kernel(32)).writes(&x).submit().unwrap();
        // Pending: pre-launch contents stay readable, but the buffer can
        // be neither freed nor overwritten mid-flight.
        assert_eq!(sess.read_f32(&x).unwrap(), data);
        assert!(sess.free(&x).is_err());
        assert!(sess.write_f32(&x, &data).is_err());
        // Chained in-place update: stage 2's input is stage 1's output.
        let l2 = sess.launch(&scale_kernel(32)).writes(&x).submit().unwrap();
        // Waiting the tail resolves the whole chain.
        let r2 = sess.wait(&l2).unwrap();
        assert!(r2.device_cycles > 0);
        assert!(sess.poll(&l1).is_some(), "producers resolve transitively");
        let got = sess.read_f32(&x).unwrap();
        for i in 0..32 {
            assert_eq!(got[i], 4.0 * i as f32, "x[{i}]");
        }
        // Resolved: the buffer is free-able again.
        sess.free(&x).unwrap();
        assert_eq!(sess.resident_bytes(), 0);
    }

    #[test]
    fn reads_is_input_only_and_arg_of_pending_is_rejected() {
        let mut sess = Session::single(aurora());
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let l = sess.launch(&scale_kernel(16)).reads(&x).submit().unwrap();
        let r = sess.wait(&l).unwrap();
        assert!(r.device_cycles > 0);
        // The kernel doubled its own copy, but .reads() never writes back.
        assert_eq!(sess.read_f32(&x).unwrap(), vec![1.0; 16]);
        // Legacy .arg() refuses a pending buffer: chaining is explicit.
        let y = sess.buffer_from_f32(&[1.0; 16]);
        let _w = sess.launch(&scale_kernel(16)).writes(&y).submit().unwrap();
        let err = sess.launch(&scale_kernel(16)).arg(&y).submit().unwrap_err();
        assert!(err.to_string().contains("pending output"), "{err}");
        // Double .writes() of one buffer in one launch is rejected.
        let err =
            sess.launch(&scale_kernel(16)).writes(&x).writes(&x).submit().unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
        sess.drain().unwrap();
    }

    #[test]
    fn pooled_chain_matches_single_and_emits_ready_event() {
        let data: Vec<f32> = (0..64).map(|i| (i % 7) as f32).collect();
        let run = |sess: &mut Session| {
            let x = sess.buffer_from_f32(&data);
            let a = sess.launch(&scale_kernel(64)).writes(&x).submit().unwrap();
            let b = sess.launch(&scale_kernel(64)).writes(&x).submit().unwrap();
            // Waiting the consumer resolves the producer first on both
            // backends.
            let rb = sess.wait(&b).unwrap();
            let ra = sess.wait(&a).unwrap();
            (ra.digest, rb.digest, sess.read_f32(&x).unwrap())
        };
        let (sa, sb, sx) = run(&mut Session::single(aurora()));
        let mut pool = Session::pool(aurora(), 2);
        let (pa, pb, px) = run(&mut pool);
        assert_eq!(sa, pa, "producer digests must be bit-identical");
        assert_eq!(sb, pb, "consumer digests must be bit-identical");
        assert_eq!(sx, px);
        assert_eq!(px[1], 4.0 * 1.0);
        // The dependency-readiness event surfaces through Session::events.
        assert!(pool.events().unwrap().contains("ready"), "{}", pool.events().unwrap());
    }

    #[test]
    fn workload_launch_verifies_and_reports_autodma() {
        let cfg = aurora();
        let w = workloads::gemm::build(12);
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::AutoDma, 8, 7).unwrap();
        crate::bench_harness::verify_arrays(&w, &out.arrays, 7).unwrap();
        assert!(out.result.autodma.is_some(), "AutoDma compile must surface its report");
        assert!(out.result.dma_cycles() > 0);
        assert!(out.result.compute_cycles() < out.result.device_cycles);
    }

    #[test]
    fn pool_session_runs_kernels_and_streams() {
        let mut sess = Session::pool(aurora(), 2);
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let x = sess.buffer_from_f32(&data);
        let launch = sess.launch(&scale_kernel(32)).args(&[&x]).submit().unwrap();
        let res = sess.wait(&launch).unwrap();
        assert_eq!(res.instance, Some(0));
        assert_eq!(sess.read_f32(&x).unwrap()[3], 6.0);
        // Named streams ride the same session.
        let handles = sess
            .submit_jobs(&crate::workloads::synth::tiny_jobs(3, 9))
            .unwrap();
        sess.drain().unwrap();
        for h in &handles {
            assert!(sess.job_state(*h).unwrap().settled());
        }
        let report = sess.report().unwrap();
        assert_eq!(report.completed, 4, "kernel launch + 3 named jobs");
        assert!(sess.events().unwrap().contains("submit"));
    }

    #[test]
    fn launch_priority_reaches_the_pooled_scheduler() {
        let mut sess = Session::pool(aurora(), 1);
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let l = sess
            .launch(&scale_kernel(16))
            .args(&[&x])
            .priority(Priority::High)
            .submit()
            .unwrap();
        sess.wait(&l).unwrap();
        // The QoS class rides into the scheduler's submit event.
        assert!(sess.events().unwrap().contains("[high]"));
        // On a single session the class is accepted and changes nothing.
        let mut single = Session::single(aurora());
        let y = single.buffer_from_f32(&[1.0; 16]);
        let l2 = single
            .launch(&scale_kernel(16))
            .args(&[&y])
            .priority(Priority::High)
            .submit()
            .unwrap();
        let r = single.wait(&l2).unwrap();
        assert!(r.device_cycles > 0);
    }

    #[test]
    fn drain_resolves_pooled_launches() {
        // drain() must behave identically on both backends: outputs written
        // back and poll() returning Some without an explicit wait().
        let mut sess = Session::pool(aurora(), 1);
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let l = sess.launch(&scale_kernel(16)).args(&[&x]).submit().unwrap();
        assert!(sess.poll(&l).is_none());
        sess.drain().unwrap();
        assert!(sess.poll(&l).is_some());
        assert_eq!(sess.read_f32(&x).unwrap()[0], 2.0);
    }

    #[test]
    fn pool_rejection_surfaces_at_wait() {
        let mut cfg = aurora();
        cfg.accel.l1_bytes = 16 * 1024;
        let sched = Scheduler::new(cfg, 1, Policy::Capacity(crate::sched::OversizeAction::Reject));
        let mut sess = Session::with_scheduler(sched);
        let w = workloads::gemm::build(64);
        let run = sess.submit_workload(&w, Variant::Handwritten, 8, 1).unwrap();
        let err = sess.wait(&run.launch).unwrap_err();
        assert!(err.to_string().contains("rejected"), "{err}");
    }

    #[test]
    fn fleet_session_serves_named_streams() {
        let mut sess = Session::fleet(aurora(), 2, 1);
        let jobs = crate::workloads::synth::tiny_jobs(6, 11);
        let handles: Vec<_> = {
            let router = sess.router_mut().unwrap();
            jobs.iter().map(|j| router.submit(*j)).collect()
        };
        sess.drain().unwrap();
        for h in &handles {
            assert!(sess.router().unwrap().poll(*h).is_some());
        }
        let report = sess.fleet_report().unwrap();
        assert_eq!(report.completed, 6);
        assert_eq!(report.boards.len(), 2);
        assert!(sess.events().unwrap().contains("[b0] "));
        // Fleet sessions reject single-board surfaces instead of panicking.
        assert!(sess.report().is_err());
        assert!(sess.submit_jobs(&[]).is_err());
        assert!(sess.svm_alloc_f32(vec![0.0; 4]).is_err());
        let x = sess.buffer_from_f32(&[1.0; 16]);
        let err = sess.launch(&scale_kernel(16)).args(&[&x]).submit().unwrap_err();
        assert!(err.to_string().contains("fleet"), "{err}");
        // A pooled session is not a fleet.
        assert!(Session::pool(aurora(), 1).router().is_err());
    }

    #[test]
    fn svm_launches_ride_the_pooled_session() {
        use crate::svm::{SvmConfig, SvmMode};
        let sched = Scheduler::new(aurora(), 1, Policy::Fifo)
            .with_svm(SvmConfig::new(SvmMode::Copy));
        let mut sess = Session::with_scheduler(sched);
        let va = sess.svm_alloc_f32(vec![3.0; 32]).unwrap();
        // No snapshot: the parameter is VA-described and the result lands
        // in the shared space, not a session buffer.
        let l = sess
            .launch(&scale_kernel(32))
            .svm_arg(va, 32)
            .svm(SvmMode::Pin)
            .submit()
            .unwrap();
        let r = sess.wait(&l).unwrap();
        assert!(r.device_cycles > 0);
        assert_eq!(sess.svm_read_f32(va).unwrap(), vec![6.0; 32]);
        assert!(sess.svm_read_f32(0xdead).is_err());
        assert!(sess.events().unwrap().contains("svm"), "{}", sess.events().unwrap());

        // Single sessions reject SVM operands and allocations outright.
        let mut single = Session::single(aurora());
        assert!(single.svm_alloc_f32(vec![0.0; 4]).is_err());
        let err = single.launch(&scale_kernel(32)).svm_arg(va, 32).submit().unwrap_err();
        assert!(err.to_string().contains("pooled session"), "{err}");

        // A pooled session without SVM serving rejects the allocation too.
        let mut plain = Session::pool(aurora(), 1);
        assert!(plain.svm_alloc_f32(vec![0.0; 4]).is_err());
    }
}
