//! Hybrid IOMMU: software-managed TLB + on-accelerator page-table walking.
//!
//! §2.1/§2.3: the accelerator shares the *virtual* address space of the host
//! application. The IOMMU is "hybrid": a hardware TLB translates virtual
//! user-space addresses to physical ones; misses are handled *in software*
//! by the accelerator itself, which walks the application page table (made
//! readable by the host driver) and fills the TLB. A hit adds ≈3 cycles to a
//! remote access (modelled as `timing.ext_addr_overhead` on the access
//! path); a miss costs a software walk (`iommu.walk_cycles`).

use crate::config::{IommuConfig, MissMode};
use std::collections::HashMap;

/// Host-managed page table: virtual page number → physical page number.
///
/// Models the user-space application page table (ARM VMSAv8-64 or RISC-V
/// Sv39 on real HEROv2); we keep only the final-level mapping since the
/// multi-level walk cost is a configured constant.
#[derive(Debug, Default, Clone)]
pub struct PageTable {
    map: HashMap<u64, u64>,
    page_bits: u32,
    /// Bumped on every mapping change; the driver flushes the TLB only when
    /// an offload observes a new epoch (see `Accel::flush_tlb_if_stale`).
    epoch: u64,
}

impl PageTable {
    pub fn new(page_bytes: usize) -> Self {
        assert!(page_bytes.is_power_of_two());
        PageTable { map: HashMap::new(), page_bits: page_bytes.trailing_zeros(), epoch: 0 }
    }

    pub fn page_bytes(&self) -> u64 {
        1 << self.page_bits
    }

    /// Mapping-change generation counter. Any `map_page`/`map_range` call
    /// advances it, so cached translations can be invalidated exactly when
    /// the table actually changed.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Map the virtual page containing `va` to the physical page containing
    /// `pa` (both rounded down).
    pub fn map_page(&mut self, va: u64, pa: u64) {
        self.epoch += 1;
        self.map.insert(va >> self.page_bits, pa >> self.page_bits);
    }

    /// Map a contiguous virtual range onto a contiguous physical range.
    ///
    /// A page-granular table can only express ranges whose virtual and
    /// physical starts share the same in-page offset (as the host `mmap`
    /// path guarantees); anything else would silently translate to the
    /// wrong physical bytes, so it is rejected loudly.
    pub fn map_range(&mut self, va: u64, pa: u64, bytes: u64) {
        let pb = self.page_bytes();
        assert_eq!(
            va % pb,
            pa % pb,
            "map_range: va {va:#x} and pa {pa:#x} must share a page offset \
             (page size {pb} B)"
        );
        self.epoch += 1;
        let first = va >> self.page_bits;
        let last = (va + bytes.max(1) - 1) >> self.page_bits;
        for (i, vpn) in (first..=last).enumerate() {
            self.map.insert(vpn, (pa >> self.page_bits) + i as u64);
        }
    }

    /// Walk: translate `va` → physical address, or None if unmapped
    /// (a real system would deliver a fault to the host).
    pub fn walk(&self, va: u64) -> Option<u64> {
        let ppn = *self.map.get(&(va >> self.page_bits))?;
        Some((ppn << self.page_bits) | (va & (self.page_bytes() - 1)))
    }
}

/// Result of an IOMMU translation, with its cycle cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Translation {
    pub pa: u64,
    /// Cycles spent on translation: 0 on a TLB hit (the constant hit
    /// overhead is charged on the access path), `walk` cycles on a miss.
    pub cost: u64,
    pub hit: bool,
}

#[derive(Debug, Clone, Copy)]
struct TlbEntry {
    vpn: u64,
    ppn: u64,
    last_use: u64,
}

/// The hybrid IOMMU: a fully-associative LRU TLB, software-filled.
/// `Clone` supports what-if costing: a cloned shadow can be translated
/// against speculatively without warming the real TLB.
#[derive(Debug, Clone)]
pub struct Iommu {
    cfg: IommuConfig,
    entries: Vec<TlbEntry>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// Busy-until cycle of the dedicated miss-handler core (DedicatedCore
    /// mode): concurrent misses queue on it.
    handler_free: u64,
}

impl Iommu {
    pub fn new(cfg: IommuConfig) -> Self {
        Iommu { cfg, entries: Vec::new(), tick: 0, hits: 0, misses: 0, handler_free: 0 }
    }

    pub fn cfg(&self) -> &IommuConfig {
        &self.cfg
    }

    fn page_bits(&self) -> u32 {
        self.cfg.page_bytes.trailing_zeros()
    }

    /// Invalidate all TLB entries (host driver does this between offloads).
    pub fn flush(&mut self) {
        self.entries.clear();
    }

    /// Translate a 64-bit virtual address at cycle `now`. On a TLB miss the
    /// accelerator walks `pt` in software and fills the entry.
    ///
    /// Returns `None` for an unmapped address (fatal in the simulator:
    /// offloaded kernels only touch mapped buffers).
    pub fn translate(&mut self, va: u64, pt: &PageTable, now: u64) -> Option<Translation> {
        self.tick += 1;
        let vpn = va >> self.page_bits();
        let off = va & (self.cfg.page_bytes as u64 - 1);
        if let Some(e) = self.entries.iter_mut().find(|e| e.vpn == vpn) {
            e.last_use = self.tick;
            self.hits += 1;
            return Some(Translation { pa: (e.ppn << self.page_bits()) | off, cost: 0, hit: true });
        }
        // Miss: software walk (VMM library, §2.3).
        self.misses += 1;
        let pa_page = pt.walk(vpn << self.page_bits())?;
        let ppn = pa_page >> self.page_bits();
        if self.entries.len() >= self.cfg.tlb_entries {
            // Evict LRU.
            let (i, _) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .expect("non-empty");
            self.entries.swap_remove(i);
        }
        self.entries.push(TlbEntry { vpn, ppn, last_use: self.tick });
        let cost = match self.cfg.miss_mode {
            MissMode::SelfService => self.cfg.walk_cycles,
            MissMode::DedicatedCore => {
                // The dedicated handler core overlaps the walk with the
                // faulting core's pipeline drain, but concurrent misses
                // queue on it.
                let start = now.max(self.handler_free);
                let service = self.cfg.walk_cycles / 2;
                self.handler_free = start + service;
                (start + service).saturating_sub(now)
            }
        };
        Some(Translation { pa: (ppn << self.page_bits()) | off, cost, hit: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;

    fn setup() -> (Iommu, PageTable) {
        let cfg = aurora().iommu;
        let mut pt = PageTable::new(cfg.page_bytes);
        pt.map_range(0x7f00_0000_0000, 0x10_0000, 1 << 20); // 1 MiB buffer
        (Iommu::new(cfg), pt)
    }

    #[test]
    fn hit_after_miss() {
        let (mut io, pt) = setup();
        let t1 = io.translate(0x7f00_0000_0100, &pt, 0).unwrap();
        assert!(!t1.hit);
        assert_eq!(t1.cost, aurora().iommu.walk_cycles);
        assert_eq!(t1.pa, 0x10_0100);
        let t2 = io.translate(0x7f00_0000_0200, &pt, 10).unwrap();
        assert!(t2.hit);
        assert_eq!(t2.cost, 0);
        assert_eq!(t2.pa, 0x10_0200);
    }

    #[test]
    fn contiguous_mapping_is_page_accurate() {
        let (mut io, pt) = setup();
        // Page 3, offset 12.
        let va = 0x7f00_0000_0000u64 + 3 * 4096 + 12;
        let t = io.translate(va, &pt, 0).unwrap();
        assert_eq!(t.pa, 0x10_0000 + 3 * 4096 + 12);
    }

    #[test]
    fn unmapped_returns_none() {
        let (mut io, pt) = setup();
        assert!(io.translate(0xdead_0000_0000, &pt, 0).is_none());
    }

    #[test]
    fn lru_eviction() {
        let (mut io, mut pt) = setup();
        let n = aurora().iommu.tlb_entries;
        pt.map_range(0x10_0000_0000, 0x2000_0000, (n as u64 + 2) * 4096);
        // Fill the TLB with n+1 distinct pages: entry 0 gets evicted.
        for i in 0..=n as u64 {
            io.translate(0x10_0000_0000 + i * 4096, &pt, i).unwrap();
        }
        assert_eq!(io.misses, n as u64 + 1);
        let t = io.translate(0x10_0000_0000, &pt, 100).unwrap();
        assert!(!t.hit, "first page should have been LRU-evicted");
    }

    #[test]
    fn dedicated_mode_queues() {
        let mut cfg = aurora().iommu;
        cfg.miss_mode = MissMode::DedicatedCore;
        let mut pt = PageTable::new(cfg.page_bytes);
        pt.map_range(0, 0, 1 << 20);
        let mut io = Iommu::new(cfg);
        let c1 = io.translate(0, &pt, 0).unwrap().cost;
        let c2 = io.translate(4096, &pt, 0).unwrap().cost; // queues behind c1
        assert_eq!(c1, cfg.walk_cycles / 2);
        assert_eq!(c2, cfg.walk_cycles);
    }

    #[test]
    fn flush_empties_tlb() {
        let (mut io, pt) = setup();
        io.translate(0x7f00_0000_0000, &pt, 0).unwrap();
        io.flush();
        let t = io.translate(0x7f00_0000_0000, &pt, 0).unwrap();
        assert!(!t.hit);
    }

    #[test]
    fn map_range_with_equal_page_offsets_crosses_pages() {
        // Regression: the old code carried a dead `let _ = pb;` and never
        // checked the offset precondition. An offset-carrying (but equal on
        // both sides) range must still translate byte-accurately across
        // every page it touches.
        let mut pt = PageTable::new(4096);
        pt.map_range(0x1800, 0x5800, 0x2000); // starts mid-page, spans 3 pages
        assert_eq!(pt.walk(0x1800).unwrap(), 0x5800);
        assert_eq!(pt.walk(0x2000).unwrap(), 0x6000); // next page boundary
        assert_eq!(pt.walk(0x37fc).unwrap(), 0x77fc); // last mapped byte's word
    }

    #[test]
    #[should_panic(expected = "share a page offset")]
    fn map_range_rejects_mismatched_page_offsets() {
        // Differing in-page offsets are unrepresentable in a page-granular
        // table; silently accepting them used to corrupt translations.
        let mut pt = PageTable::new(4096);
        pt.map_range(0x1800, 0x5000, 0x1000);
    }

    #[test]
    fn epoch_advances_only_on_mapping_changes() {
        let mut pt = PageTable::new(4096);
        assert_eq!(pt.epoch(), 0);
        pt.map_page(0x1000, 0x2000);
        assert_eq!(pt.epoch(), 1);
        pt.map_range(0x4000, 0x8000, 8192);
        assert_eq!(pt.epoch(), 2);
        // Reads never advance it.
        let _ = pt.walk(0x1000);
        assert_eq!(pt.epoch(), 2);
    }

    #[test]
    fn refill_costs_are_exact_in_both_miss_modes() {
        // SelfService: every miss pays the full software walk; hits are free
        // (the constant hit overhead is charged on the access path).
        let (mut io, pt) = setup();
        let walk = aurora().iommu.walk_cycles;
        let miss = io.translate(0x7f00_0000_0000, &pt, 0).unwrap();
        assert_eq!((miss.hit, miss.cost), (false, walk));
        let hit = io.translate(0x7f00_0000_0040, &pt, 5).unwrap();
        assert_eq!((hit.hit, hit.cost), (true, 0));
        assert_eq!((io.hits, io.misses), (1, 1));
        // DedicatedCore: walk/2 service time, and a later lone miss (handler
        // idle again) pays exactly walk/2 — not a stale queue penalty.
        let mut cfg = aurora().iommu;
        cfg.miss_mode = MissMode::DedicatedCore;
        let mut pt2 = PageTable::new(cfg.page_bytes);
        pt2.map_range(0, 0, 1 << 20);
        let mut io2 = Iommu::new(cfg);
        assert_eq!(io2.translate(0, &pt2, 0).unwrap().cost, walk / 2);
        assert_eq!(io2.translate(4096, &pt2, 1_000).unwrap().cost, walk / 2);
    }

    #[test]
    fn clone_makes_an_independent_shadow() {
        // What-if costing translates against a cloned IOMMU; the shadow's
        // fills must not warm the real TLB.
        let (mut io, pt) = setup();
        let mut shadow = io.clone();
        assert!(!shadow.translate(0x7f00_0000_0000, &pt, 0).unwrap().hit);
        assert!(shadow.translate(0x7f00_0000_0000, &pt, 1).unwrap().hit);
        let t = io.translate(0x7f00_0000_0000, &pt, 2).unwrap();
        assert!(!t.hit, "shadow fills must not leak into the original");
    }
}
