//! Memory subsystem: the shared carrier-board DRAM, L2 SPM, per-cluster
//! TCDM L1 SPMs, the device address map, and the deterministic O(1) heap
//! allocator.
//!
//! HEROv2's accelerator memory hierarchy is *software-managed* (§2.1): no
//! data caches — multi-banked L1 scratch-pads with single-cycle access,
//! a shared L2 SPM, and shared off-chip DRAM reached through the on-chip
//! network and (for virtual addresses) the hybrid IOMMU.
//!
//! The SPM types ([`Tcdm`], [`WordMem`]) separate storage from timing:
//! they store words and expose geometry (bank mapping); cycle costs are
//! applied by the cluster and NoC models that call into them. Main memory
//! is different — it is a *contended* resource shared by every DMA engine
//! and (at the pool level) every accelerator instance on the board, so
//! [`dram::SharedDram`] owns both the storage and a cycle-accounted
//! bandwidth/arbitration model; requesters route their traffic through
//! [`dram::DramPort`] handles which account bytes and contention stalls.

pub mod dram;
pub mod o1heap;

pub use dram::{BandwidthLedger, DramPort, PortStats, SharedDram};
pub use o1heap::O1Heap;

/// Device (native, 32-bit) address map.
///
/// Mirrors PULP conventions: each cluster's TCDM is at a fixed offset, the
/// L2 SPM is shared, and everything above `HOST_WINDOW` is only reachable
/// through the 64-bit ext-address path.
pub mod map {
    /// Base address of cluster `i`'s TCDM.
    pub const TCDM_BASE: u32 = 0x1000_0000;
    /// Address stride between clusters.
    pub const CLUSTER_STRIDE: u32 = 0x0040_0000;
    /// Base address of the shared L2 SPM.
    pub const L2_BASE: u32 = 0x1C00_0000;

    /// Region a native 32-bit address falls into.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Region {
        /// TCDM of cluster `.0`, at byte offset `.1`.
        Tcdm(usize, u32),
        /// L2 SPM at byte offset `.0`.
        L2(u32),
        /// Not mapped in the native address space.
        Unmapped,
    }

    /// Decode a native address (given L1/L2 sizes in bytes).
    pub fn decode(addr: u32, n_clusters: usize, l1_bytes: u32, l2_bytes: u32) -> Region {
        if addr >= L2_BASE {
            let off = addr - L2_BASE;
            if off < l2_bytes {
                return Region::L2(off);
            }
            return Region::Unmapped;
        }
        if addr >= TCDM_BASE {
            let rel = addr - TCDM_BASE;
            let cl = (rel / CLUSTER_STRIDE) as usize;
            let off = rel % CLUSTER_STRIDE;
            if cl < n_clusters && off < l1_bytes {
                return Region::Tcdm(cl, off);
            }
        }
        Region::Unmapped
    }

    /// TCDM base address of cluster `cl`.
    pub fn tcdm_base(cl: usize) -> u32 {
        TCDM_BASE + cl as u32 * CLUSTER_STRIDE
    }
}

/// Word-addressed backing store shared by all SPM/DRAM models.
#[derive(Debug, Clone)]
pub struct WordMem {
    words: Vec<u32>,
}

impl WordMem {
    /// Create a zeroed memory of `bytes` (must be 4-aligned).
    pub fn new(bytes: usize) -> Self {
        assert_eq!(bytes % 4, 0, "memory size must be word-aligned");
        WordMem { words: vec![0; bytes / 4] }
    }

    pub fn bytes(&self) -> usize {
        self.words.len() * 4
    }

    /// Load the 32-bit word at byte offset `off` (must be 4-aligned).
    #[inline(always)]
    pub fn load(&self, off: u32) -> u32 {
        debug_assert_eq!(off % 4, 0, "unaligned load at {off:#x}");
        self.words[(off / 4) as usize]
    }

    /// Store the 32-bit word at byte offset `off`.
    #[inline(always)]
    pub fn store(&mut self, off: u32, val: u32) {
        debug_assert_eq!(off % 4, 0, "unaligned store at {off:#x}");
        self.words[(off / 4) as usize] = val;
    }

    /// Bulk copy out of this memory (used by the DMA data path).
    pub fn read_words(&self, off: u32, out: &mut [u32]) {
        let base = (off / 4) as usize;
        out.copy_from_slice(&self.words[base..base + out.len()]);
    }

    /// Bulk copy into this memory.
    pub fn write_words(&mut self, off: u32, data: &[u32]) {
        let base = (off / 4) as usize;
        self.words[base..base + data.len()].copy_from_slice(data);
    }

    /// View as f32 (bit-cast) — convenience for tests and data staging.
    pub fn load_f32(&self, off: u32) -> f32 {
        f32::from_bits(self.load(off))
    }

    pub fn store_f32(&mut self, off: u32, v: f32) {
        self.store(off, v.to_bits());
    }
}

/// Per-cluster tightly-coupled data memory: multi-banked, word-interleaved.
///
/// §2.1: "the cores have single-cycle access to a multi-banked,
/// tightly-coupled L1 data SPM. A default banking factor of two allows any
/// core to access any bank in any cycle with a low probability of
/// contention." Bank conflicts are arbitrated per cycle by the cluster
/// model; this type provides storage and the address→bank mapping.
#[derive(Debug, Clone)]
pub struct Tcdm {
    pub mem: WordMem,
    n_banks: usize,
}

impl Tcdm {
    pub fn new(bytes: usize, n_banks: usize) -> Self {
        assert!(n_banks > 0);
        Tcdm { mem: WordMem::new(bytes), n_banks }
    }

    /// Bank index of a byte offset (word-interleaved).
    #[inline(always)]
    pub fn bank_of(&self, off: u32) -> usize {
        ((off / 4) as usize) % self.n_banks
    }

    pub fn n_banks(&self) -> usize {
        self.n_banks
    }

    /// Change bank count (Fig 8: the 128-bit configuration changes the TCDM
    /// interconnect from 14×16 to 18×32).
    pub fn set_banks(&mut self, n: usize) {
        assert!(n > 0);
        self.n_banks = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_map_decodes() {
        use map::*;
        let l1 = 128 * 1024;
        let l2 = 1024 * 1024;
        assert_eq!(decode(TCDM_BASE, 2, l1, l2), Region::Tcdm(0, 0));
        assert_eq!(decode(TCDM_BASE + 0x40, 2, l1, l2), Region::Tcdm(0, 0x40));
        assert_eq!(decode(tcdm_base(1) + 8, 2, l1, l2), Region::Tcdm(1, 8));
        assert_eq!(decode(L2_BASE + 16, 2, l1, l2), Region::L2(16));
        assert_eq!(decode(0x0000_0000, 2, l1, l2), Region::Unmapped);
        assert_eq!(decode(TCDM_BASE + l1, 1, l1, l2), Region::Unmapped);
        assert_eq!(decode(L2_BASE + l2, 2, l1, l2), Region::Unmapped);
    }

    #[test]
    fn word_mem_roundtrip() {
        let mut m = WordMem::new(64);
        m.store(0, 0xdead_beef);
        m.store_f32(4, 1.5);
        assert_eq!(m.load(0), 0xdead_beef);
        assert_eq!(m.load_f32(4), 1.5);
    }

    #[test]
    fn bulk_copy_roundtrip() {
        let mut m = WordMem::new(64);
        m.write_words(8, &[1, 2, 3]);
        let mut out = [0u32; 3];
        m.read_words(8, &mut out);
        assert_eq!(out, [1, 2, 3]);
    }

    #[test]
    fn bank_interleaving() {
        let t = Tcdm::new(1024, 16);
        assert_eq!(t.bank_of(0), 0);
        assert_eq!(t.bank_of(4), 1);
        assert_eq!(t.bank_of(64), 0);
        // Stride-4 words with 16 banks: consecutive words hit distinct banks.
        let banks: Vec<usize> = (0..16).map(|i| t.bank_of(i * 4)).collect();
        let uniq: std::collections::HashSet<_> = banks.iter().collect();
        assert_eq!(uniq.len(), 16);
    }

    #[test]
    #[should_panic]
    fn oob_load_panics() {
        let m = WordMem::new(16);
        m.load(16);
    }
}
