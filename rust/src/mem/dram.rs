//! Shared carrier-board DRAM: one contended main memory behind the NoC.
//!
//! HEROv2's accelerator clusters do not own private DRAM — they share the
//! board's off-chip main memory behind the on-chip network, and the paper's
//! case studies show DMA bandwidth at the DRAM boundary is the first-order
//! bottleneck for multi-cluster offload. This module models that boundary:
//!
//! * [`BandwidthLedger`] — a cycle-accounted reservation model of a link
//!   with a peak byte rate. Requesters reserve service for a byte count at
//!   a per-port rate cap (their NoC drain rate); when concurrent
//!   reservations exceed the peak, later requests are served from the
//!   residual bandwidth and stretch in time. Grant order is request order,
//!   which in the simulator is the rotating per-cycle cluster/core
//!   arbitration — i.e. round-robin at the cycle level. An optional
//!   *priority headroom* keeps a slice of the peak free for
//!   priority-flagged ports (QoS for latency-critical requesters). The
//!   headroom is not just an accounting knob: the offload scheduler maps
//!   [`crate::sched::Priority::High`] jobs onto priority reservations
//!   (`hero serve --priority-headroom`), so latency-critical traffic keeps
//!   a guaranteed slice of the board peak while normal jobs contend for
//!   the remainder — the Cheshire-style interconnect QoS split.
//! * [`SharedDram`] — the board DRAM itself: word storage plus a
//!   [`BandwidthLedger`] and per-[`DramPort`] accounting (bytes served,
//!   stall cycles). The accelerator's DMA engines and the narrow
//!   ext-address path route their main-memory traffic through `DramPort`
//!   handles instead of touching storage directly; the instance pool in
//!   [`crate::sched::pool`] reuses the ledger to couple whole accelerator
//!   instances onto one board.
//!
//! Burst math is shared with [`crate::noc::WidePath`]: a transfer's
//! uncontended DRAM service time is its beat count (`WidePath::beats`),
//! because the wide NoC drains one beat per cycle — so with the default
//! configurations (DRAM peak far above one NoC port's rate) the ledger
//! never stalls anything and all timings are bit-identical to the
//! pre-shared-DRAM model. Contention becomes visible exactly when the sum
//! of concurrent port rates exceeds the configured peak.

use super::WordMem;

/// Handle to one requester port of a [`SharedDram`] (a cluster DMA engine,
/// the narrow ext-address path, or a whole pool instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramPort(pub(crate) usize);

impl DramPort {
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Per-port accounting of a [`SharedDram`].
#[derive(Debug, Clone)]
pub struct PortStats {
    pub label: String,
    /// Whether this port may reserve into the priority headroom.
    pub priority: bool,
    /// Bytes served through this port (DMA payload + narrow words).
    pub bytes: u64,
    /// Reservations made.
    pub requests: u64,
    /// Extra cycles this port's transfers waited on the shared DRAM beyond
    /// their uncontended service time.
    pub stall_cycles: u64,
}

/// Cycle-accounted bandwidth reservations on a link with a peak byte rate.
///
/// The reserved rate over time is kept as a piecewise-constant step
/// function: sorted `(cycle, total rate, normal-class rate)` breakpoints,
/// each applying until the next breakpoint (the trailing segment is always
/// back at 0 — reservations are finite). Two class constraints hold at
/// every cycle: *normal* traffic in aggregate stays within
/// `peak - priority_headroom`, and *all* traffic stays within `peak` — so
/// priority reservations are absorbed by the headroom first and only the
/// spill past it competes with normal traffic, while normal traffic never
/// reaches the headroom at all. All arithmetic is integer and
/// deterministic.
#[derive(Debug, Clone)]
pub struct BandwidthLedger {
    peak: u64,
    /// Bandwidth normal ports may not use (kept free for priority ports).
    priority_headroom: u64,
    /// `(from-cycle, total reserved rate, normal-class reserved rate)`.
    segs: Vec<(u64, u64, u64)>,
    total_bytes: u64,
}

impl BandwidthLedger {
    /// `peak` in bytes per cycle (clamped to at least 1); `u64::MAX` models
    /// an uncontended link. `priority_headroom` bytes/cycle are reachable
    /// only by priority reservations.
    pub fn new(peak: u64, priority_headroom: u64) -> Self {
        BandwidthLedger {
            peak: peak.max(1),
            priority_headroom,
            segs: Vec::new(),
            total_bytes: 0,
        }
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Total bytes reserved through this ledger so far.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Total and normal-class reserved rates at cycle `t`, plus the cycle
    /// where the current segment ends (`u64::MAX` for the trailing free
    /// segment).
    fn rates_and_end_at(&self, t: u64) -> (u64, u64, u64) {
        let idx = self.segs.partition_point(|s| s.0 <= t);
        let (total, normal) =
            if idx == 0 { (0, 0) } else { (self.segs[idx - 1].1, self.segs[idx - 1].2) };
        let end = self.segs.get(idx).map_or(u64::MAX, |s| s.0);
        (total, normal, end)
    }

    /// Total reserved rate at cycle `t`.
    pub fn rate_at(&self, t: u64) -> u64 {
        self.rates_and_end_at(t).0
    }

    /// Highest total reserved rate anywhere on the ledger (for invariant
    /// checks: never exceeds `peak`).
    pub fn max_rate(&self) -> u64 {
        self.segs.iter().map(|s| s.1).max().unwrap_or(0)
    }

    /// Insert a breakpoint at `t` carrying the prevailing rates.
    fn ensure_breakpoint(&mut self, t: u64) {
        if let Err(pos) = self.segs.binary_search_by_key(&t, |s| s.0) {
            let (total, normal) =
                if pos == 0 { (0, 0) } else { (self.segs[pos - 1].1, self.segs[pos - 1].2) };
            self.segs.insert(pos, (t, total, normal));
        }
    }

    /// Add `delta` to the reserved rate over `[from, to)`; non-priority
    /// traffic also books against the normal-class track.
    fn add(&mut self, from: u64, to: u64, delta: u64, priority: bool) {
        if from >= to || delta == 0 {
            return;
        }
        self.ensure_breakpoint(from);
        self.ensure_breakpoint(to);
        for seg in &mut self.segs {
            if (from..to).contains(&seg.0) {
                seg.1 += delta;
                if !priority {
                    seg.2 += delta;
                }
            }
        }
    }

    /// Usable peak for one requester class (priority requesters reach into
    /// the headroom, normal ones do not).
    fn usable_cap(&self, priority: bool) -> u64 {
        if priority {
            self.peak
        } else {
            self.peak.saturating_sub(self.priority_headroom).max(1)
        }
    }

    /// Plan service for `bytes` from `start` against the current
    /// reservations, returning the completion cycle. The `(from, to, rate)`
    /// segments the request would occupy are pushed into `taken` when the
    /// caller intends to commit them — probes pass `None` and stay
    /// allocation-free (one probe per pool slot per dispatched job adds
    /// up). Shared read-only core of [`BandwidthLedger::reserve`] and
    /// [`BandwidthLedger::probe`].
    fn plan(
        &self,
        start: u64,
        bytes: u64,
        rate_cap: u64,
        priority: bool,
        mut taken: Option<&mut Vec<(u64, u64, u64)>>,
    ) -> u64 {
        let cap = self.usable_cap(priority);
        let rate_cap = rate_cap.clamp(1, cap);
        let mut remaining = bytes;
        let mut t = start;
        loop {
            let (total, normal, seg_end) = self.rates_and_end_at(t);
            // A priority request is limited only by the physical peak; a
            // normal request additionally may not push the *normal-class*
            // aggregate past the usable (headroom-free) slice — priority
            // traffic riding the headroom does not count against it.
            let avail = if priority {
                self.peak.saturating_sub(total).min(rate_cap)
            } else {
                cap.saturating_sub(normal)
                    .min(self.peak.saturating_sub(total))
                    .min(rate_cap)
            };
            if avail == 0 {
                // Fully booked segment; reservations are finite, so a later
                // segment always has residual bandwidth.
                debug_assert!(seg_end != u64::MAX);
                t = seg_end;
                continue;
            }
            let span = seg_end - t;
            let served = avail.saturating_mul(span);
            if served >= remaining {
                let need = remaining.div_ceil(avail);
                if let Some(taken) = taken.as_mut() {
                    taken.push((t, t + need, avail));
                }
                t += need;
                break;
            }
            if let Some(taken) = taken.as_mut() {
                taken.push((t, seg_end, avail));
            }
            remaining -= served;
            t = seg_end;
        }
        t
    }

    /// Reserve service for `bytes` starting no earlier than `start`, at a
    /// per-cycle rate of at most `rate_cap` and at most the residual
    /// bandwidth. Returns the cycle at which the last byte is served.
    ///
    /// The uncontended service time is `bytes.div_ceil(rate_cap)` (capped
    /// at the usable peak); any extra latency is contention stall caused by
    /// earlier reservations.
    pub fn reserve(&mut self, start: u64, bytes: u64, rate_cap: u64, priority: bool) -> u64 {
        if bytes == 0 {
            return start;
        }
        let mut taken = Vec::new();
        let end = self.plan(start, bytes, rate_cap, priority, Some(&mut taken));
        for (from, to, rate) in taken {
            self.add(from, to, rate, priority);
        }
        self.total_bytes += bytes;
        end
    }

    /// Completion cycle [`BandwidthLedger::reserve`] *would* return for this
    /// request, without committing anything — the placement engine's
    /// what-if query ([`crate::sched::place`]). Because the planned segments
    /// integrate the reserved-rate step function over the request's window,
    /// this is the exact windowed form of [`BandwidthLedger::pressure_at`]:
    /// on a ledger with zero reserved rate over the window it returns
    /// exactly `start + bytes.div_ceil(rate_cap)`, so a pressure-aware
    /// placement degenerates bit-exactly to earliest-free on an uncontended
    /// board.
    pub fn probe(&self, start: u64, bytes: u64, rate_cap: u64, priority: bool) -> u64 {
        if bytes == 0 {
            return start;
        }
        self.plan(start, bytes, rate_cap, priority, None)
    }

    /// Uncontended service time of `bytes` at `rate_cap` on this ledger
    /// (what [`BandwidthLedger::reserve`] returns minus `start` when no
    /// other reservation is in the way). Uses the same usable-peak clamp
    /// as `reserve` — a non-priority request never sees the headroom, so
    /// the headroom-induced slowdown is not misreported as contention.
    pub fn uncontended_cycles(&self, bytes: u64, rate_cap: u64, priority: bool) -> u64 {
        if bytes == 0 {
            return 0;
        }
        bytes.div_ceil(rate_cap.clamp(1, self.usable_cap(priority)))
    }

    /// Drop breakpoints entirely before `before` (keeps the prevailing
    /// rate) so long-running simulations stay O(outstanding reservations).
    pub fn trim(&mut self, before: u64) {
        let idx = self.segs.partition_point(|s| s.0 <= before);
        if idx >= 2 {
            self.segs.drain(..idx - 1);
        }
    }

    /// Reserved fraction of the peak at cycle `t` (0.0 on an uncontended
    /// link).
    pub fn pressure_at(&self, t: u64) -> f64 {
        if self.peak == u64::MAX {
            return 0.0;
        }
        self.rate_at(t) as f64 / self.peak as f64
    }
}

/// The carrier board's shared main memory: word storage plus the bandwidth
/// ledger and per-port stall accounting. See the module docs for the model.
#[derive(Debug)]
pub struct SharedDram {
    /// Backing word storage (physical byte addresses from 0). Host-side
    /// staging (`host::HostContext`) writes it directly; at the *pool*
    /// level, host traffic (SVM copy staging, page-table walks, mailbox
    /// descriptors) is cycle-accounted through a dedicated host port on
    /// the pool's [`BandwidthLedger`] — see `sched::pool`.
    pub mem: WordMem,
    ledger: BandwidthLedger,
    ports: Vec<PortStats>,
}

impl SharedDram {
    /// `bytes` of storage; `peak_bytes_per_cycle` of shared bandwidth;
    /// `priority_headroom` bytes/cycle reachable only by priority ports.
    pub fn new(bytes: usize, peak_bytes_per_cycle: u64, priority_headroom: u64) -> Self {
        SharedDram {
            mem: WordMem::new(bytes),
            ledger: BandwidthLedger::new(peak_bytes_per_cycle, priority_headroom),
            ports: Vec::new(),
        }
    }

    /// Register a requester; the returned handle routes traffic and stats.
    pub fn add_port(&mut self, label: impl Into<String>, priority: bool) -> DramPort {
        self.ports.push(PortStats {
            label: label.into(),
            priority,
            bytes: 0,
            requests: 0,
            stall_cycles: 0,
        });
        DramPort(self.ports.len() - 1)
    }

    pub fn peak_bytes_per_cycle(&self) -> u64 {
        self.ledger.peak()
    }

    /// Total bytes served across all ports (ledger traffic + narrow words).
    pub fn total_bytes(&self) -> u64 {
        self.ports.iter().map(|p| p.bytes).sum()
    }

    pub fn stats(&self, port: DramPort) -> &PortStats {
        &self.ports[port.0]
    }

    pub fn n_ports(&self) -> usize {
        self.ports.len()
    }

    /// Reserve DRAM service for a transfer of `bytes` through `port`,
    /// draining at most `rate_cap` bytes/cycle (the port's NoC beat rate),
    /// starting no earlier than `start`. Returns the completion cycle of
    /// the DRAM side; the caller compares it against the transfer's NoC
    /// occupancy to derive the contention stall (and reports it back via
    /// [`SharedDram::note_stall`] so it is counted exactly once).
    pub fn reserve(&mut self, port: DramPort, start: u64, bytes: u64, rate_cap: u64) -> u64 {
        let p = &mut self.ports[port.0];
        p.bytes += bytes;
        p.requests += 1;
        let priority = p.priority;
        self.ledger.reserve(start, bytes, rate_cap, priority)
    }

    /// Uncontended DRAM service time for `bytes` at `rate_cap` through
    /// `port` (honors the port's priority class).
    pub fn uncontended_cycles(&self, port: DramPort, bytes: u64, rate_cap: u64) -> u64 {
        self.ledger.uncontended_cycles(bytes, rate_cap, self.ports[port.0].priority)
    }

    /// Book contention stall cycles on a port (derived by the caller as
    /// actual completion minus uncontended completion).
    pub fn note_stall(&mut self, port: DramPort, cycles: u64) {
        self.ports[port.0].stall_cycles += cycles;
    }

    /// Word load through a port (narrow ext-address path). Single-word
    /// accesses are latency-bound — their cost is `timing.remote_word` on
    /// the core side — so they are tallied for conservation accounting but
    /// do not walk the ledger.
    pub fn port_load(&mut self, port: DramPort, pa: u32) -> u32 {
        self.ports[port.0].bytes += 4;
        self.mem.load(pa)
    }

    /// Word store through a port (posted write on the narrow path).
    pub fn port_store(&mut self, port: DramPort, pa: u32, val: u32) {
        self.ports[port.0].bytes += 4;
        self.mem.store(pa, val);
    }

    /// Reserved fraction of peak bandwidth at cycle `t`.
    pub fn pressure_at(&self, t: u64) -> f64 {
        self.ledger.pressure_at(t)
    }

    /// Forget ledger history before `before` (bounded memory on long runs).
    pub fn trim(&mut self, before: u64) {
        self.ledger.trim(before);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_reservation_runs_at_rate_cap() {
        let mut l = BandwidthLedger::new(384, 0);
        // 2048 B at 8 B/cycle: 256 cycles, no stall.
        assert_eq!(l.reserve(100, 2048, 8, false), 356);
        assert_eq!(l.uncontended_cycles(2048, 8, false), 256);
        assert_eq!(l.total_bytes(), 2048);
        assert_eq!(l.rate_at(100), 8);
        assert_eq!(l.rate_at(355), 8);
        assert_eq!(l.rate_at(356), 0);
    }

    #[test]
    fn concurrent_reservations_share_the_peak() {
        // Peak 12, two requesters at 8 B/cycle each, same start.
        let mut l = BandwidthLedger::new(12, 0);
        let e1 = l.reserve(0, 800, 8, false);
        assert_eq!(e1, 100);
        // Second gets the residual 4 B/cycle while the first runs, then the
        // full 8: 100 cycles * 4 B = 400 B, remaining 400 B at 8 B = 50 cy.
        let e2 = l.reserve(0, 800, 8, false);
        assert_eq!(e2, 150);
        assert_eq!(l.rate_at(0), 12);
        assert_eq!(l.rate_at(100), 8);
        assert_eq!(l.rate_at(150), 0);
        assert_eq!(l.max_rate(), 12);
        assert_eq!(l.total_bytes(), 1600);
    }

    #[test]
    fn saturated_segment_defers_service() {
        let mut l = BandwidthLedger::new(8, 0);
        l.reserve(0, 80, 8, false); // occupies [0, 10) fully
        let e = l.reserve(0, 40, 8, false);
        assert_eq!(e, 15); // waits 10, then 5 cycles at 8 B/cycle
        assert_eq!(l.max_rate(), 8);
    }

    #[test]
    fn priority_headroom_is_reserved_for_priority_ports() {
        // Peak 12 with 4 B/cycle of priority headroom: normal ports see 8.
        let mut l = BandwidthLedger::new(12, 4);
        let normal = l.reserve(0, 800, 8, false);
        assert_eq!(normal, 100); // rate 8 = peak - headroom
        // The floor agrees with reserve's cap: headroom slowdown on a
        // too-eager rate is intrinsic, not contention.
        assert_eq!(l.uncontended_cycles(800, 12, false), 100);
        assert_eq!(l.uncontended_cycles(800, 12, true), 67);
        // A priority request overlapping it still gets 4 B/cycle.
        let prio = l.reserve(0, 400, 8, true);
        assert_eq!(prio, 100);
        assert_eq!(l.rate_at(0), 12);
        // A second normal request is fully blocked until cycle 100.
        let blocked = l.reserve(0, 80, 8, false);
        assert_eq!(blocked, 110);
    }

    #[test]
    fn priority_traffic_rides_the_headroom_without_starving_the_normal_slice() {
        // Peak 16 with 8 B/cy of headroom. A priority reservation at
        // 8 B/cy is absorbed entirely by the headroom, so a concurrent
        // normal request still gets the full 8 B/cy normal slice — the
        // classes only collide at the physical peak.
        let mut l = BandwidthLedger::new(16, 8);
        assert_eq!(l.reserve(0, 800, 8, true), 100);
        assert_eq!(l.reserve(0, 800, 8, false), 100, "normal slice must stay available");
        assert_eq!(l.rate_at(0), 16);
        // The physical peak still binds everyone: a third request of
        // either class is fully deferred behind the saturated link.
        assert_eq!(l.probe(0, 80, 8, false), 110);
        assert_eq!(l.probe(0, 80, 8, true), 110);
        assert_eq!(l.max_rate(), 16);
    }

    #[test]
    fn reservations_compose_across_partial_overlap() {
        let mut l = BandwidthLedger::new(10, 0);
        l.reserve(50, 100, 10, false); // [50, 60) at 10
        // Starts at 40: 10 cycles at 10, then stalled [50,60), finishes after.
        let e = l.reserve(40, 200, 10, false);
        assert_eq!(e, 70);
        assert_eq!(l.rate_at(55), 10);
        assert_eq!(l.max_rate(), 10);
    }

    #[test]
    fn probe_matches_reserve_without_committing() {
        let mut l = BandwidthLedger::new(12, 0);
        l.reserve(0, 800, 8, false); // [0, 100) at 8
        // A second 8 B/cycle request overlapping it: 4 B/cycle residual for
        // 100 cycles, then full rate — probe predicts exactly what reserve
        // would do, but leaves the ledger untouched.
        let before_bytes = l.total_bytes();
        let planned = l.probe(0, 800, 8, false);
        assert_eq!(planned, 150);
        assert_eq!(l.total_bytes(), before_bytes);
        assert_eq!(l.rate_at(120), 0, "probe must not reserve");
        assert_eq!(l.reserve(0, 800, 8, false), planned);
        // Empty window: probe is the uncontended service time exactly.
        assert_eq!(l.probe(500, 64, 8, false), 508);
        assert_eq!(l.probe(500, 0, 8, false), 500);
        // Priority probes reach the headroom like priority reserves.
        let mut h = BandwidthLedger::new(12, 4);
        h.reserve(0, 800, 8, false); // normal: capped at 8, [0, 100)
        assert_eq!(h.probe(0, 400, 8, true), 100); // 4 B/cy of headroom
        assert_eq!(h.probe(0, 80, 8, false), 110); // normal: fully blocked
    }

    #[test]
    fn trim_preserves_future_reservations() {
        let mut l = BandwidthLedger::new(8, 0);
        l.reserve(0, 80, 8, false);
        l.reserve(1000, 80, 8, false);
        l.trim(500);
        assert_eq!(l.rate_at(1005), 8);
        assert_eq!(l.rate_at(500), 0);
        // New reservations still honor what survived the trim: the link is
        // fully booked over [1000, 1010), so service runs [1010, 1020).
        let e = l.reserve(1000, 80, 8, false);
        assert_eq!(e, 1020);
    }

    #[test]
    fn uncapped_ledger_never_stalls() {
        let mut l = BandwidthLedger::new(u64::MAX, 0);
        for i in 0..16 {
            let e = l.reserve(0, 4096, 8, false);
            assert_eq!(e, 512, "request {i} stalled on an uncapped ledger");
        }
        assert_eq!(l.pressure_at(0), 0.0);
    }

    #[test]
    fn shared_dram_ports_account_bytes_and_stalls() {
        let mut d = SharedDram::new(64, 8, 0);
        let a = d.add_port("cluster0-dma", false);
        let b = d.add_port("cluster1-dma", false);
        let e1 = d.reserve(a, 0, 80, 8);
        let e2 = d.reserve(b, 0, 80, 8);
        assert_eq!((e1, e2), (10, 20));
        let stall = e2 - d.uncontended_cycles(b, 80, 8);
        d.note_stall(b, stall);
        assert_eq!(d.stats(b).stall_cycles, 10);
        assert_eq!(d.stats(a).bytes, 80);
        assert_eq!(d.stats(b).bytes, 80);
        assert_eq!(d.total_bytes(), 160);
        // Narrow words tally into port bytes without walking the ledger.
        d.mem.store(0, 7);
        assert_eq!(d.port_load(a, 0), 7);
        assert_eq!(d.stats(a).bytes, 84);
        d.port_store(a, 4, 9);
        assert_eq!(d.mem.load(4), 9);
        assert_eq!(d.stats(a).bytes, 88);
    }

    #[test]
    fn pressure_reflects_reserved_fraction() {
        let mut d = SharedDram::new(0, 16, 0);
        let p = d.add_port("dma", false);
        d.reserve(p, 0, 80, 8);
        assert!((d.pressure_at(0) - 0.5).abs() < 1e-12);
        assert_eq!(d.pressure_at(10), 0.0);
    }
}
